//! Host-side packed-state assembly.
//!
//! Mirrors python/compile/model.py `state_layout`: a packed state is
//! `[logits (B*V) ; kcache (L,B,Hkv,C,D) ; vcache (L,B,Hkv,C,D)]` flat
//! f32. This module does the memcpy choreography between that layout and
//! the per-chunk `[L,Hkv,seq,D]` planes the KV store materializes.

use anyhow::{bail, Result};

use crate::kvstore::KvChunk;
use crate::manifest::ModelConfig;

/// A packed state staged in host memory (before upload / after download).
#[derive(Debug, Clone)]
pub struct HostState {
    pub data: Vec<f32>,
    pub batch: usize,
    pub max_ctx: usize,
    pub logits_n: usize,
    pub cache_n: usize,
    // architecture copies for offset math
    n_layers: usize,
    n_kv_heads: usize,
    head_dim: usize,
}

impl HostState {
    /// Fresh all-zero state for a (config, batch, ctx) bucket.
    pub fn zeros(cfg: &ModelConfig, batch: usize, max_ctx: usize) -> Self {
        let logits_n = batch * cfg.vocab;
        let cache_n = cfg.n_layers * batch * cfg.n_kv_heads * max_ctx * cfg.head_dim;
        HostState {
            data: vec![0f32; logits_n + 2 * cache_n],
            batch,
            max_ctx,
            logits_n,
            cache_n,
            n_layers: cfg.n_layers,
            n_kv_heads: cfg.n_kv_heads,
            head_dim: cfg.head_dim,
        }
    }

    /// Wrap a downloaded state vector.
    pub fn from_vec(cfg: &ModelConfig, batch: usize, max_ctx: usize, data: Vec<f32>) -> Result<Self> {
        let mut s = Self::zeros(cfg, batch, max_ctx);
        if data.len() != s.data.len() {
            bail!("state size mismatch: {} vs {}", data.len(), s.data.len());
        }
        s.data = data;
        Ok(s)
    }

    pub fn total_elems(&self) -> usize {
        self.logits_n + 2 * self.cache_n
    }

    /// Flat offset of cache position (plane, l, b, h, slot) where plane
    /// 0 = K, 1 = V; points at a contiguous `head_dim` run.
    #[inline]
    fn off(&self, plane: usize, l: usize, b: usize, h: usize, slot: usize) -> usize {
        self.logits_n
            + plane * self.cache_n
            + ((((l * self.batch + b) * self.n_kv_heads + h) * self.max_ctx) + slot) * self.head_dim
    }

    /// Splice a materialized chunk's KV planes into batch element `b`
    /// starting at cache slot `slot`. Chunk planes are `[L,Hkv,seq,D]`.
    pub fn splice_chunk(&mut self, b: usize, slot: usize, chunk: &KvChunk) -> Result<()> {
        let (l_n, h_n, seq, d) = (
            chunk.n_layers as usize,
            chunk.n_kv_heads as usize,
            chunk.seq_len as usize,
            chunk.head_dim as usize,
        );
        if l_n != self.n_layers || h_n != self.n_kv_heads || d != self.head_dim {
            bail!("chunk/config shape mismatch");
        }
        if slot + seq > self.max_ctx {
            bail!("chunk of {seq} tokens does not fit at slot {slot} (C={})", self.max_ctx);
        }
        if b >= self.batch {
            bail!("batch index {b} out of range {}", self.batch);
        }
        let run = seq * d;
        for (plane, src_all) in [(0, &chunk.k), (1, &chunk.v)] {
            for l in 0..l_n {
                for h in 0..h_n {
                    let src = &src_all[(l * h_n + h) * run..(l * h_n + h + 1) * run];
                    let dst_off = self.off(plane, l, b, h, slot);
                    self.data[dst_off..dst_off + run].copy_from_slice(src);
                }
            }
        }
        Ok(())
    }

    /// Extract `[slot, slot+seq)` of batch element `b` as a KV chunk
    /// (the materialization path after an ingest prefill).
    pub fn extract_chunk(&self, cfg_id: u32, b: usize, slot: usize, seq: usize) -> KvChunk {
        assert!(slot + seq <= self.max_ctx && b < self.batch);
        let run = seq * self.head_dim;
        let plane_elems = self.n_layers * self.n_kv_heads * run;
        let mut k = Vec::with_capacity(plane_elems);
        let mut v = Vec::with_capacity(plane_elems);
        for (plane, dst) in [(0, &mut k), (1, &mut v)] {
            for l in 0..self.n_layers {
                for h in 0..self.n_kv_heads {
                    let off = self.off(plane, l, b, h, slot);
                    dst.extend_from_slice(&self.data[off..off + run]);
                }
            }
        }
        KvChunk {
            config_id: cfg_id,
            n_layers: self.n_layers as u32,
            n_kv_heads: self.n_kv_heads as u32,
            seq_len: seq as u32,
            head_dim: self.head_dim as u32,
            k,
            v,
        }
    }

    /// The logits of batch element `b` (from a downloaded state).
    pub fn logits(&self, b: usize) -> &[f32] {
        let v = self.logits_n / self.batch;
        &self.data[b * v..(b + 1) * v]
    }
}

/// Greedy-argmax over one element's logits.
pub fn argmax(logits: &[f32]) -> usize {
    let mut best = 0;
    let mut best_v = f32::MIN;
    for (i, &x) in logits.iter().enumerate() {
        if x > best_v {
            best_v = x;
            best = i;
        }
    }
    best
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::manifest::Manifest;

    fn cfg() -> ModelConfig {
        // Host-state splicing only needs config dims — golden metadata
        // suffices when the real artifacts aren't built.
        Manifest::load_or_golden().unwrap().config("tiny").unwrap().clone()
    }

    fn test_chunk(cfg: &ModelConfig, seq: usize, seed: f32) -> KvChunk {
        let plane = cfg.n_layers * cfg.n_kv_heads * seq * cfg.head_dim;
        KvChunk {
            config_id: 1,
            n_layers: cfg.n_layers as u32,
            n_kv_heads: cfg.n_kv_heads as u32,
            seq_len: seq as u32,
            head_dim: cfg.head_dim as u32,
            k: (0..plane).map(|i| i as f32 + seed).collect(),
            v: (0..plane).map(|i| -(i as f32) - seed).collect(),
        }
    }

    #[test]
    fn splice_then_extract_roundtrip() {
        let cfg = cfg();
        let mut st = HostState::zeros(&cfg, 2, 512);
        let chunk = test_chunk(&cfg, 64, 5.0);
        st.splice_chunk(1, 128, &chunk).unwrap();
        let back = st.extract_chunk(1, 1, 128, 64);
        assert_eq!(back.k, chunk.k);
        assert_eq!(back.v, chunk.v);
        // other element untouched
        let other = st.extract_chunk(1, 0, 128, 64);
        assert!(other.k.iter().all(|&x| x == 0.0));
    }

    #[test]
    fn adjacent_chunks_dont_overlap() {
        let cfg = cfg();
        let mut st = HostState::zeros(&cfg, 1, 512);
        let a = test_chunk(&cfg, 32, 1.0);
        let b = test_chunk(&cfg, 32, 1000.0);
        st.splice_chunk(0, 0, &a).unwrap();
        st.splice_chunk(0, 32, &b).unwrap();
        assert_eq!(st.extract_chunk(1, 0, 0, 32).k, a.k);
        assert_eq!(st.extract_chunk(1, 0, 32, 32).k, b.k);
    }

    #[test]
    fn bounds_checked() {
        let cfg = cfg();
        let mut st = HostState::zeros(&cfg, 1, 128);
        let chunk = test_chunk(&cfg, 64, 0.0);
        assert!(st.splice_chunk(0, 100, &chunk).is_err()); // overflows C
        assert!(st.splice_chunk(1, 0, &chunk).is_err()); // bad batch idx
    }

    #[test]
    fn logits_view() {
        let cfg = cfg();
        let mut st = HostState::zeros(&cfg, 2, 128);
        st.data[cfg.vocab] = 42.0; // element 1, logit 0
        assert_eq!(st.logits(1)[0], 42.0);
        assert_eq!(st.logits(0)[0], 0.0);
    }

    #[test]
    fn argmax_basics() {
        assert_eq!(argmax(&[0.0, 3.0, -1.0, 3.0]), 1); // first max wins
        assert_eq!(argmax(&[-5.0]), 0);
    }
}
