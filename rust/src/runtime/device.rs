//! Thin wrapper over the PJRT CPU client with typed upload/download
//! helpers. All device objects live on the thread that created them; the
//! overlap pipeline keeps device work on the executor thread and only
//! stages host memory on the loader thread.

use std::cell::RefCell;
use std::collections::HashMap;
use std::rc::Rc;

use anyhow::{Context, Result};
use xla::{Literal, PjRtBuffer, PjRtClient, PjRtLoadedExecutable};

/// The PJRT device (CPU plugin in this testbed).
pub struct Device {
    client: PjRtClient,
    /// Compiled `state[0:n]` slice readers, keyed by (total, n) — see
    /// [`Device::read_prefix_f32`].
    prefix_readers: RefCell<HashMap<(usize, usize), Rc<PjRtLoadedExecutable>>>,
}

impl Device {
    pub fn cpu() -> Result<Self> {
        Ok(Device {
            client: PjRtClient::cpu().context("creating PJRT CPU client")?,
            prefix_readers: RefCell::new(HashMap::new()),
        })
    }

    pub fn platform(&self) -> String {
        self.client.platform_name()
    }

    /// Compile an HLO-text artifact into a loaded executable.
    pub fn compile_hlo_text(&self, path: &std::path::Path) -> Result<PjRtLoadedExecutable> {
        let proto = xla::HloModuleProto::from_text_file(path)
            .map_err(|e| anyhow::anyhow!("parsing HLO text {path:?}: {e}"))?;
        let comp = xla::XlaComputation::from_proto(&proto);
        self.client.compile(&comp).map_err(|e| anyhow::anyhow!("compiling {path:?}: {e}"))
    }

    /// Upload an f32 tensor.
    pub fn upload_f32(&self, data: &[f32], dims: &[usize]) -> Result<PjRtBuffer> {
        Ok(self.client.buffer_from_host_buffer(data, dims, None)?)
    }

    /// Upload an i32 tensor.
    pub fn upload_i32(&self, data: &[i32], dims: &[usize]) -> Result<PjRtBuffer> {
        Ok(self.client.buffer_from_host_buffer(data, dims, None)?)
    }

    /// Download a whole f32 buffer.
    pub fn download_f32(&self, buf: &PjRtBuffer) -> Result<Vec<f32>> {
        let lit: Literal = buf.to_literal_sync()?;
        Ok(lit.to_vec::<f32>()?)
    }

    /// Read the first `n` f32 elements of a buffer without transferring
    /// the rest (the per-step logits read of the packed state).
    ///
    /// xla_extension 0.5.1's TFRT CPU client does not implement
    /// `CopyRawToHost`, so this goes through a compiled slice computation
    /// (see [`Device::compile_prefix_reader`]) executed on-device: only
    /// the tiny slice output is transferred to host.
    pub fn read_prefix_f32(&self, buf: &PjRtBuffer, n: usize) -> Result<Vec<f32>> {
        let total = xla::ArrayShape::try_from(&buf.on_device_shape()?)?.element_count();
        if total == n {
            let lit: Literal = buf.to_literal_sync()?;
            return Ok(lit.to_vec::<f32>()?);
        }
        let exe = self.prefix_reader(total, n)?;
        let result = exe.execute_b(&[buf])?;
        let lit = result[0][0].to_literal_sync()?;
        Ok(lit.to_vec::<f32>()?)
    }

    /// Compiled (and cached) `f(state f32[total]) -> state[0:n]`.
    fn prefix_reader(&self, total: usize, n: usize) -> Result<Rc<PjRtLoadedExecutable>> {
        if let Some(e) = self.prefix_readers.borrow().get(&(total, n)) {
            return Ok(e.clone());
        }
        let builder = xla::XlaBuilder::new("prefix_reader");
        let param = builder
            .parameter(0, xla::ElementType::F32, &[total as i64], "state")
            .map_err(|e| anyhow::anyhow!("builder parameter: {e}"))?;
        let sliced =
            param.slice_in_dim1(0, n as i64, 0).map_err(|e| anyhow::anyhow!("slice: {e}"))?;
        let comp = builder.build(&sliced).map_err(|e| anyhow::anyhow!("build: {e}"))?;
        let exe = Rc::new(
            self.client.compile(&comp).map_err(|e| anyhow::anyhow!("compile prefix reader: {e}"))?,
        );
        self.prefix_readers.borrow_mut().insert((total, n), exe.clone());
        Ok(exe)
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn upload_download_roundtrip() {
        let d = Device::cpu().unwrap();
        let data: Vec<f32> = (0..24).map(|x| x as f32).collect();
        let buf = d.upload_f32(&data, &[4, 6]).unwrap();
        assert_eq!(d.download_f32(&buf).unwrap(), data);
    }

    #[test]
    fn prefix_read_matches_full() {
        let d = Device::cpu().unwrap();
        let data: Vec<f32> = (0..1000).map(|x| (x as f32).sin()).collect();
        let buf = d.upload_f32(&data, &[1000]).unwrap();
        let head = d.read_prefix_f32(&buf, 10).unwrap();
        assert_eq!(&head, &data[..10]);
    }

    #[test]
    fn i32_upload() {
        let d = Device::cpu().unwrap();
        let buf = d.upload_i32(&[1, 2, 3], &[1, 3]).unwrap();
        let lit: Literal = buf.to_literal_sync().unwrap();
        assert_eq!(lit.to_vec::<i32>().unwrap(), vec![1, 2, 3]);
    }
}
