//! Weight loading: raw f32 blobs (python `aot.export_weights`) → device
//! buffers, uploaded once per session in `PARAM_ORDER`.

use anyhow::{bail, Context, Result};
use xla::PjRtBuffer;

use super::device::Device;
use crate::manifest::{Manifest, ModelConfig};

/// Read one weight blob into host memory.
pub fn read_blob(path: &std::path::Path, expect_elems: usize) -> Result<Vec<f32>> {
    let bytes = std::fs::read(path).with_context(|| format!("reading weight {path:?}"))?;
    if bytes.len() != expect_elems * 4 {
        bail!("weight {path:?}: {} bytes, expected {}", bytes.len(), expect_elems * 4);
    }
    let mut out = vec![0f32; expect_elems];
    // safety: plain LE f32 copy
    unsafe {
        std::ptr::copy_nonoverlapping(bytes.as_ptr(), out.as_mut_ptr() as *mut u8, bytes.len());
    }
    Ok(out)
}

/// Upload all weights of a config in manifest `param_order`.
pub fn load_weights(dev: &Device, m: &Manifest, cfg: &ModelConfig) -> Result<Vec<PjRtBuffer>> {
    let mut by_name: std::collections::HashMap<&str, &crate::manifest::WeightEntry> =
        cfg.weights.iter().map(|w| (w.name.as_str(), w)).collect();
    let mut out = Vec::with_capacity(m.param_order.len());
    for name in &m.param_order {
        let w = by_name
            .remove(name.as_str())
            .with_context(|| format!("weight {name} missing from manifest for {}", cfg.name))?;
        let elems: usize = w.shape.iter().product();
        let host = read_blob(&m.weight_path(cfg, w), elems)?;
        out.push(dev.upload_f32(&host, &w.shape)?);
    }
    if !by_name.is_empty() {
        bail!("unconsumed weights: {:?}", by_name.keys().collect::<Vec<_>>());
    }
    Ok(out)
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn loads_all_configs() {
        crate::require_artifacts!();
        let m = Manifest::load(crate::artifacts_dir()).unwrap();
        let dev = Device::cpu().unwrap();
        for name in ["tiny", "small"] {
            let cfg = m.config(name).unwrap();
            let bufs = load_weights(&dev, &m, cfg).unwrap();
            assert_eq!(bufs.len(), m.param_order.len());
        }
    }

    #[test]
    fn blob_size_validated() {
        let dir = crate::util::tempdir::TempDir::new("matkv-weights-test").unwrap();
        let p = dir.path().join("w.bin");
        std::fs::write(&p, [0u8; 12]).unwrap();
        assert_eq!(read_blob(&p, 3).unwrap(), vec![0f32; 3]);
        assert!(read_blob(&p, 4).is_err());
    }
}
