//! PJRT runtime: loads AOT artifacts (HLO text) and executes them on the
//! CPU PJRT client via the `xla` crate.
//!
//! Hot-path design (see python/compile/model.py `state_layout`): every
//! entry point is a *packed-state* computation — one flat f32 output that
//! rust feeds straight back into the next `execute_b` call, so the KV
//! cache never leaves the device during chunked prefill or decode; only
//! the `B*vocab` logits prefix is copied to host per step for sampling.
//!
//! Weights are uploaded once per config at session creation and shared by
//! every entry point (python exports them in `PARAM_ORDER`).

pub mod device;
pub mod session;
pub mod state;
pub mod weights;

pub use device::Device;
pub use session::{ModelSession, StepStats};
pub use state::HostState;
