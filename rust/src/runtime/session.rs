//! A compiled model session: weights resident on device, entry points
//! lazily compiled per (S, B, C) bucket, packed-state stepping.

use std::cell::RefCell;
use std::collections::HashMap;
use std::rc::Rc;
use std::time::Instant;

use anyhow::{bail, Context, Result};
use xla::{PjRtBuffer, PjRtLoadedExecutable};

use super::device::Device;
use super::state::HostState;
use super::weights::load_weights;
use crate::manifest::{Manifest, ModelConfig};

/// A packed state resident on device, tagged with its bucket shape.
pub struct StateBuf {
    pub buf: PjRtBuffer,
    pub batch: usize,
    pub max_ctx: usize,
}

/// Cumulative execution statistics (profiling/bench input).
#[derive(Debug, Default, Clone)]
pub struct StepStats {
    pub steps: u64,
    pub execute_secs: f64,
    pub compile_secs: f64,
    pub upload_secs: f64,
    pub logits_read_secs: f64,
}

/// One model config loaded on one PJRT device.
pub struct ModelSession {
    dev: Device,
    cfg: ModelConfig,
    weights: Vec<PjRtBuffer>,
    exes: RefCell<HashMap<(usize, usize, usize), Rc<PjRtLoadedExecutable>>>,
    artifact_paths: HashMap<(usize, usize, usize), std::path::PathBuf>,
    pub stats: RefCell<StepStats>,
}

impl ModelSession {
    /// Create a session: PJRT client + weight upload (entry points compile
    /// lazily on first use).
    pub fn new(manifest: &Manifest, config_name: &str) -> Result<Self> {
        let dev = Device::cpu()?;
        let cfg = manifest.config(config_name)?.clone();
        let weights = load_weights(&dev, manifest, &cfg)?;
        let artifact_paths = cfg
            .artifacts
            .iter()
            .map(|a| ((a.s, a.b, a.c), manifest.path(&a.file)))
            .collect();
        Ok(ModelSession {
            dev,
            cfg,
            weights,
            exes: RefCell::new(HashMap::new()),
            artifact_paths,
            stats: RefCell::new(StepStats::default()),
        })
    }

    pub fn config(&self) -> &ModelConfig {
        &self.cfg
    }

    pub fn device(&self) -> &Device {
        &self.dev
    }

    /// Lazily compile (and cache) the (s, b, c) entry point.
    pub fn executable(&self, s: usize, b: usize, c: usize) -> Result<Rc<PjRtLoadedExecutable>> {
        if let Some(e) = self.exes.borrow().get(&(s, b, c)) {
            return Ok(e.clone());
        }
        let path = self
            .artifact_paths
            .get(&(s, b, c))
            .with_context(|| format!("no artifact for s={s} b={b} c={c} ({})", self.cfg.name))?;
        let start = Instant::now();
        let exe = Rc::new(self.dev.compile_hlo_text(path)?);
        self.stats.borrow_mut().compile_secs += start.elapsed().as_secs_f64();
        self.exes.borrow_mut().insert((s, b, c), exe.clone());
        Ok(exe)
    }

    /// Pre-compile a set of buckets (hides compile latency from benches).
    pub fn warmup(&self, buckets: &[(usize, usize, usize)]) -> Result<()> {
        for &(s, b, c) in buckets {
            self.executable(s, b, c)?;
        }
        Ok(())
    }

    /// Upload a host-staged state.
    pub fn upload_state(&self, st: &HostState) -> Result<StateBuf> {
        let start = Instant::now();
        let buf = self.dev.upload_f32(&st.data, &[st.total_elems()])?;
        self.stats.borrow_mut().upload_secs += start.elapsed().as_secs_f64();
        Ok(StateBuf { buf, batch: st.batch, max_ctx: st.max_ctx })
    }

    /// Fresh zero state on device for a (batch, ctx) bucket.
    pub fn zero_state(&self, batch: usize, max_ctx: usize) -> Result<StateBuf> {
        self.upload_state(&HostState::zeros(&self.cfg, batch, max_ctx))
    }

    /// Download a device state into host form.
    pub fn download_state(&self, st: &StateBuf) -> Result<HostState> {
        let data = self.dev.download_f32(&st.buf)?;
        HostState::from_vec(&self.cfg, st.batch, st.max_ctx, data)
    }

    /// One append step: S-bucket chosen by `tokens.len() / batch`.
    ///
    /// `tokens` is row-major `[batch, s]` (pad with any id beyond
    /// `qlen[b]`), `qlen[b]` ∈ 1..=s live tokens, `cache_len[b]` the live
    /// cache length before this call. Consumes and returns the device
    /// state; the old state buffer remains valid (functional update) and
    /// is dropped by the caller going out of scope.
    pub fn step(
        &self,
        tokens: &[i32],
        qlen: &[i32],
        cache_len: &[i32],
        state: &StateBuf,
    ) -> Result<StateBuf> {
        let b = state.batch;
        if tokens.len() % b != 0 || qlen.len() != b || cache_len.len() != b {
            bail!("step arg shapes inconsistent with batch {b}");
        }
        let s = tokens.len() / b;
        let exe = self.executable(s, b, state.max_ctx)?;
        let tok_buf = self.dev.upload_i32(tokens, &[b, s])?;
        let qlen_buf = self.dev.upload_i32(qlen, &[b])?;
        let clen_buf = self.dev.upload_i32(cache_len, &[b])?;
        let mut args: Vec<&PjRtBuffer> = self.weights.iter().collect();
        args.push(&tok_buf);
        args.push(&qlen_buf);
        args.push(&clen_buf);
        args.push(&state.buf);
        let start = Instant::now();
        let mut out = exe.execute_b(&args).map_err(|e| anyhow::anyhow!("execute: {e}"))?;
        {
            let mut st = self.stats.borrow_mut();
            st.execute_secs += start.elapsed().as_secs_f64();
            st.steps += 1;
        }
        let buf = out
            .pop()
            .and_then(|mut replica| if replica.len() == 1 { replica.pop() } else { None })
            .context("expected exactly one output buffer (packed state)")?;
        Ok(StateBuf { buf, batch: b, max_ctx: state.max_ctx })
    }

    /// Read the `[batch, vocab]` logits prefix of a device state.
    pub fn read_logits(&self, state: &StateBuf) -> Result<Vec<f32>> {
        let start = Instant::now();
        let out = self.dev.read_prefix_f32(&state.buf, state.batch * self.cfg.vocab)?;
        self.stats.borrow_mut().logits_read_secs += start.elapsed().as_secs_f64();
        Ok(out)
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::runtime::state::argmax;

    // These tests execute HLO through PJRT: golden metadata is not
    // enough, they need the real AOT artifacts.
    use crate::require_artifacts;

    fn session() -> ModelSession {
        let m = Manifest::load(crate::artifacts_dir()).unwrap();
        ModelSession::new(&m, "tiny").unwrap()
    }

    #[test]
    fn golden_numerics_match_python() {
        require_artifacts!();
        // Cross-language handshake: replay artifacts/<cfg>/golden.json.
        use crate::util::json::Json;
        let m = Manifest::load(crate::artifacts_dir()).unwrap();
        for name in ["tiny", "small"] {
            let sess = ModelSession::new(&m, name).unwrap();
            let golden = Json::parse(
                &std::fs::read_to_string(m.path(&format!("{name}/golden.json"))).unwrap(),
            )
            .unwrap();
            let s = golden.get("s").unwrap().as_usize().unwrap();
            let c = golden.get("c").unwrap().as_usize().unwrap();
            let tokens: Vec<i32> = golden
                .get("tokens")
                .unwrap()
                .as_arr()
                .unwrap()
                .iter()
                .map(|t| t.as_f64().unwrap() as i32)
                .collect();
            assert_eq!(tokens.len(), s);
            let qlen = golden.get("qlen").unwrap().as_f64().unwrap() as i32;
            let state = sess.zero_state(1, c).unwrap();
            let out = sess.step(&tokens, &[qlen], &[0], &state).unwrap();
            let logits = sess.read_logits(&out).unwrap();
            let expect: Vec<f64> = golden
                .get("logits_head")
                .unwrap()
                .as_arr()
                .unwrap()
                .iter()
                .map(|x| x.as_f64().unwrap())
                .collect();
            for (i, e) in expect.iter().enumerate() {
                assert!(
                    (logits[i] as f64 - e).abs() < 1e-3 * e.abs().max(1.0),
                    "{name} logit {i}: {} vs {e}",
                    logits[i]
                );
            }
            let am = golden.get("argmax").unwrap().as_usize().unwrap();
            assert_eq!(argmax(&logits[..sess.config().vocab]), am, "{name} argmax");
        }
    }

    #[test]
    fn state_feedback_roundtrip() {
        require_artifacts!();
        // two chunked steps == python invariant (indirectly): just check
        // the state can be fed back and logits change deterministically
        let sess = session();
        let c = sess.config().max_ctx;
        let state = sess.zero_state(1, c).unwrap();
        let t1: Vec<i32> = (0..32).map(|i| (i * 3) % 512).collect();
        let s1 = sess.step(&t1, &[32], &[0], &state).unwrap();
        let l1 = sess.read_logits(&s1).unwrap();
        let s2 = sess.step(&t1, &[32], &[32], &s1).unwrap();
        let l2 = sess.read_logits(&s2).unwrap();
        assert_ne!(l1, l2);
        // replay determinism
        let state_b = sess.zero_state(1, c).unwrap();
        let s1b = sess.step(&t1, &[32], &[0], &state_b).unwrap();
        assert_eq!(l1, sess.read_logits(&s1b).unwrap());
    }

    #[test]
    fn decode_bucket_s1() {
        require_artifacts!();
        let sess = session();
        let c = sess.config().max_ctx;
        let state = sess.zero_state(1, c).unwrap();
        let s1 = sess.step(&[7], &[1], &[0], &state).unwrap();
        let logits = sess.read_logits(&s1).unwrap();
        assert_eq!(logits.len(), 512);
        assert!(logits.iter().all(|x| x.is_finite()));
    }

    #[test]
    fn batch4_independent_elements() {
        require_artifacts!();
        let sess = session();
        let c = sess.config().max_ctx;
        let state = sess.zero_state(4, c).unwrap();
        // element 0 and 2 get identical tokens — identical logits expected
        let mut tokens = vec![0i32; 4 * 32];
        for i in 0..32 {
            tokens[i] = (i as i32 * 5) % 512; // b0
            tokens[2 * 32 + i] = (i as i32 * 5) % 512; // b2
            tokens[32 + i] = (i as i32 * 11 + 3) % 512; // b1
            tokens[3 * 32 + i] = (i as i32 * 13 + 7) % 512; // b3
        }
        let out = sess.step(&tokens, &[32; 4], &[0; 4], &state).unwrap();
        let logits = sess.read_logits(&out).unwrap();
        let v = sess.config().vocab;
        assert_eq!(&logits[..v], &logits[2 * v..3 * v]);
        assert_ne!(&logits[..v], &logits[v..2 * v]);
    }

    #[test]
    fn stats_accumulate() {
        require_artifacts!();
        let sess = session();
        let c = sess.config().max_ctx;
        let state = sess.zero_state(1, c).unwrap();
        let _ = sess.step(&[1], &[1], &[0], &state).unwrap();
        let st = sess.stats.borrow();
        assert_eq!(st.steps, 1);
        assert!(st.execute_secs > 0.0);
        assert!(st.compile_secs > 0.0);
    }
}
