//! `matkv` — leader binary: CLI over the MatKV serving stack.
//!
//! ```text
//! matkv info                         # manifest / artifact summary
//! matkv serve --config tiny ...      # synthetic RAG workload end-to-end
//! matkv economics                    # ten-day rule + Fig 1 trend
//! ```

use anyhow::Result;

use matkv::coordinator::baselines::cacheblend_mode;
use matkv::coordinator::{
    execute_schedule, BatchPolicy, Engine, EngineOptions, ExecOptions, Fleet, FleetCostModel,
    FleetSpec, OverlapOptions, Routing, SchedOptions, SchedPolicy, Scheduler, ServeMode,
};
use matkv::hwsim::economics::fig1_trend;
use matkv::hwsim::{ArchSpec, DeviceProfile, StorageProfile, TenDayRule};
use matkv::kvstore::{AdmissionPolicy, KvFormat, KvStore, TierMetrics, WarmMode};
use matkv::obs::{MetricsRegistry, Sampler};
use matkv::util::cli::Args;
use matkv::util::tempdir::TempDir;
use matkv::workload::{ArrivalGen, Corpus, RequestGen, TurboRagProfile};
use matkv::Manifest;

const USAGE: &str = "usage: matkv <info|serve|economics> [flags]
  serve flags: --config tiny|small|base --requests N --batch B --docs N
               --doc-tokens N --mode matkv|vanilla|cacheblend --overlap
               --storage 9100pro|raid0|pm9a3|dram --kv-dir PATH
               --hot-tier-bytes N (DRAM hot tier in front of flash, 0=off)
               --warm-tier-bytes N (quantized warm tier behind the hot tier:
                           evictions demote, hits dequantize+promote, 0=off)
               --warm-mode q8|q4 (warm-tier codec: q8 [default], or q4 —
                           ~8x fewer resident bytes than f32, priced at
                           its own modeled dequant rate; requires
                           --warm-tier-bytes)
               --admission lru|tinylfu (hot-tier admission: plain LRU
                           [default], or TinyLFU — a frequency sketch
                           gates evicting admissions so one sequential
                           scan cannot flush the resident set; requires
                           --hot-tier-bytes)
               --kv-format v1|v2|v3|v4 (on-disk KV planes:
                           f32|f16|f16+checksum|q4+checksum; default v3 —
                           v3/v4 verify a per-chunk payload checksum on
                           every read; v4 stores q4 planes, ~4x fewer
                           flash bytes than v1 and half of v2/v3, and
                           charges a modeled dequant on every load)
               --shards N (JBOD of N independent simulated devices, default 1)
               --faults SPEC (deterministic fault plan, e.g.
                           seed=7,shard0:die@2,worker1:crash@0.5 —
                           slow/stall/die/corrupt/wfail windows keyed on
                           per-shard read sequences, worker crashes on the
                           fleet's virtual clock)
               --max-retries N (with --faults: flash read retries before
                           the degradation ladder, default 3)
               --retry-backoff-ms N (with --faults: base retry backoff,
                           doubled per attempt and charged on the shard
                           link, default 2)
               --prefetch (with --overlap: warm the DRAM tiers from upcoming
                           batches' planned retrieval top-K)
               --policy fifo|affinity (batch formation: arrival order, or
                           tier-affinity grouping with a starvation bound)
               --arrival-rate R (simulated Poisson arrivals/sec; 0 = the
                           whole workload arrives at t=0)
               --max-wait-ms N (release a partial batch after the oldest
                           request waited this long, default 50)
               --service-ms N (modeled executor seconds per batch; builds
                           the backlog continuous batching selects from)
               --max-age-batches N (affinity: force-include a request
                           passed over N times, default 8)
               --fleet SPEC (simulate dispatching the planned schedule
                           across a heterogeneous worker pool, e.g.
                           h100:1,rtx4090:3 — names from the serving
                           catalog; emits per-worker utilization, energy
                           and latency percentiles on the virtual clock)
               --routing rr|role (with --fleet: round-robin baseline, or
                           role-aware — KV-resident batches to low-end
                           decode workers, cache-miss/prefill-heavy ones
                           to the high-end card; default rr)
               --pcie-contention on|off (with --fleet: queue H2D uploads
                           on each worker's modeled PCIe link [on], or
                           grant every transfer its wire time with no
                           queueing — the pre-interconnect flat charge
                           [off]; default on)
               --trace PATH (write a Chrome/Perfetto trace-event JSON:
                           scheduler queueing, per-chunk tier outcomes,
                           link reservations with their queued-vs-wire
                           split, per-worker dispatch windows, and a
                           per-request critical-path attribution report;
                           same seed + config => byte-identical file)
               --metrics-json PATH (dump the run's full PhaseBreakdown,
                           per-shard stats, tier stats, host-bus/link
                           snapshots, fleet worker reports and the
                           registry time series as one JSON document)
               --metrics-prom PATH (dump the unified metrics registry as
                           Prometheus text exposition; same seed +
                           config => byte-identical file)
               --sample-period SECS (virtual-clock period of the registry
                           time-series sampler embedded in
                           --metrics-json, default 0.1)
               --smoke (CI-sized defaults: 8 requests over 8 docs of
                           256 tokens, unless overridden explicitly)";

fn storage_profile(name: &str) -> Result<StorageProfile> {
    Ok(match name {
        "9100pro" => StorageProfile::ssd_9100pro(),
        "raid0" => StorageProfile::raid0_4x9100(),
        "pm9a3" => StorageProfile::ssd_pm9a3(),
        "dram" => StorageProfile::dram(),
        other => anyhow::bail!("unknown storage profile {other}"),
    })
}

fn main() -> Result<()> {
    let args = Args::from_env().map_err(|e| anyhow::anyhow!("{e}\n{USAGE}"))?;
    match args.command.as_deref() {
        Some("info") => info(),
        Some("serve") => serve(&args),
        Some("economics") => economics(),
        _ => {
            eprintln!("{USAGE}");
            std::process::exit(2);
        }
    }
}

fn info() -> Result<()> {
    let m = Manifest::load(matkv::artifacts_dir())?;
    println!("manifest v{} — chunk={} query_bucket={}", m.version, m.chunk_tokens, m.query_bucket);
    for (name, cfg) in &m.configs {
        println!(
            "  {name:6} L={} d={} heads={}/{} ctx={} params={:.1}M artifacts={} kv/tok={}B",
            cfg.n_layers,
            cfg.d_model,
            cfg.n_heads,
            cfg.n_kv_heads,
            cfg.max_ctx,
            cfg.param_count as f64 / 1e6,
            cfg.artifacts.len(),
            cfg.kv_bytes_per_token,
        );
    }
    Ok(())
}

fn serve(args: &Args) -> Result<()> {
    let config = args.str("config", "tiny");
    let smoke = args.flag("smoke");
    let requests = args.usize("requests", if smoke { 8 } else { 16 });
    let batch = args.usize("batch", 4);
    let docs = args.usize("docs", if smoke { 8 } else { 24 });
    let doc_tokens = args.usize("doc-tokens", if smoke { 256 } else { 512 });
    let mode_name = args.str("mode", "matkv");
    let overlap = args.flag("overlap");
    let shards = args.usize("shards", 1);
    let prefetch = args.flag("prefetch");
    if prefetch && !overlap {
        anyhow::bail!("--prefetch warms ahead of the overlap pipeline; it requires --overlap");
    }
    // Prefetch lands in whichever DRAM tier exists (hot, or quantized
    // into a warm-only store) — any nonzero tier will do.
    if prefetch
        && args.usize("hot-tier-bytes", 0) == 0
        && args.usize("warm-tier-bytes", 0) == 0
    {
        anyhow::bail!(
            "--prefetch warms the DRAM tiers; set --hot-tier-bytes or --warm-tier-bytes > 0"
        );
    }

    let fleet_spec = match args.opt("fleet") {
        Some(s) => Some(FleetSpec::parse(s)?),
        None => None,
    };
    let routing = Routing::parse(&args.str("routing", "rr"))?;
    if args.opt("routing").is_some() && fleet_spec.is_none() {
        anyhow::bail!("--routing selects a fleet dispatch policy; it requires --fleet");
    }
    let pcie_contention = match args.str("pcie-contention", "on").as_str() {
        "on" => true,
        "off" => false,
        other => anyhow::bail!("--pcie-contention takes on|off, got {other}"),
    };
    if args.opt("pcie-contention").is_some() && fleet_spec.is_none() {
        anyhow::bail!("--pcie-contention shapes fleet H2D uploads; it requires --fleet");
    }

    let faults = match args.opt("faults") {
        Some(spec) => Some(std::sync::Arc::new(matkv::hwsim::FaultPlan::parse(spec)?)),
        None => None,
    };
    if faults.is_none()
        && (args.opt("max-retries").is_some() || args.opt("retry-backoff-ms").is_some())
    {
        anyhow::bail!("--max-retries/--retry-backoff-ms tune fault recovery; they require --faults");
    }

    let m = Manifest::load(matkv::artifacts_dir())?;
    let corpus = Corpus::generate(docs, doc_tokens, docs.min(16), 42);
    let _tmp;
    let dir = match args.opt("kv-dir") {
        Some(d) => std::path::PathBuf::from(d),
        None => {
            let t = TempDir::new("matkv-serve")?;
            let p = t.path().to_path_buf();
            _tmp = t;
            p
        }
    };
    let mut kv =
        KvStore::open_sharded(&dir, storage_profile(&args.str("storage", "9100pro"))?, shards)?;
    kv.set_hot_tier(args.usize("hot-tier-bytes", 0));
    kv.set_warm_tier(args.usize("warm-tier-bytes", 0));
    match args.str("kv-format", "v3").as_str() {
        "v1" => kv.set_format(KvFormat::V1),
        "v2" => kv.set_format(KvFormat::V2),
        "v3" => kv.set_format(KvFormat::V3),
        "v4" => kv.set_format(KvFormat::V4),
        other => anyhow::bail!("unknown kv format {other}"),
    }
    match args.str("warm-mode", "q8").as_str() {
        "q8" => kv.set_warm_mode(WarmMode::Q8),
        "q4" => {
            if args.usize("warm-tier-bytes", 0) == 0 {
                anyhow::bail!("--warm-mode picks the warm-tier codec; it requires --warm-tier-bytes");
            }
            kv.set_warm_mode(WarmMode::Q4);
        }
        other => anyhow::bail!("--warm-mode takes q8|q4, got {other}"),
    }
    match args.str("admission", "lru").as_str() {
        "lru" => kv.set_admission(AdmissionPolicy::Lru),
        "tinylfu" => {
            if args.usize("hot-tier-bytes", 0) == 0 {
                anyhow::bail!("--admission gates the hot tier; it requires --hot-tier-bytes");
            }
            kv.set_admission(AdmissionPolicy::TinyLfu);
        }
        other => anyhow::bail!("--admission takes lru|tinylfu, got {other}"),
    }
    if let Some(plan) = &faults {
        kv.set_faults(Some(plan.clone()));
        kv.set_retry_policy(args.usize("max-retries", 3), args.f64("retry-backoff-ms", 2.0) / 1e3);
        // Vanilla safety-net price when flash is unrecoverable: a
        // modeled ~50µs of prefill per recomputed token at the
        // stand-in scale (the fleet re-prices lost chunks per worker
        // through its roofline on top of this store-level charge).
        kv.set_recompute_model(50e-6);
    }
    // The trace handle threads through every layer; wired LAST so the
    // tiers/links it fans out to are the ones this run actually uses.
    let trace_path = args.opt("trace").map(std::path::PathBuf::from);
    let metrics_path = args.opt("metrics-json").map(std::path::PathBuf::from);
    let prom_path = args.opt("metrics-prom").map(std::path::PathBuf::from);
    let bus = if trace_path.is_some() {
        matkv::trace::TraceBus::recording()
    } else {
        matkv::trace::TraceBus::disabled()
    };
    kv.set_trace(bus.clone());
    // The unified registry + its virtual-clock sampler: every subsystem
    // registers here, and the scheduler/fleet advance the sampler on
    // their deterministic clocks. Registered after the tiers are wired
    // so the registry sees the tiers this run actually uses.
    let registry = MetricsRegistry::new();
    let sampler = std::sync::Arc::new(std::sync::Mutex::new(Sampler::new(
        registry.clone(),
        args.f64("sample-period", 0.1),
    )));
    kv.register_metrics(&registry)?;
    let opts = EngineOptions::for_config(&m, &config)?;
    let engine = Engine::new(&m, opts, kv, corpus.texts())?;

    eprintln!("[ingest] {docs} docs x {doc_tokens} tokens ...");
    let ing = engine.ingest_corpus(&corpus, doc_tokens)?;
    eprintln!(
        "[ingest] prefill {:.2}s, materialized {:.1} MB (sim write {:.3}s)",
        ing.prefill_wall_secs,
        ing.materialized_bytes as f64 / 1e6,
        ing.write_device_secs
    );

    let serve_mode = match mode_name.as_str() {
        "matkv" => ServeMode::MatKv,
        "vanilla" => ServeMode::Vanilla,
        "cacheblend" => cacheblend_mode(doc_tokens),
        other => anyhow::bail!("unknown mode {other}"),
    };

    // The fleet simulator (and its per-batch service estimator) costs
    // work at the stand-in architecture scale, over the same storage
    // profile the store throttles to.
    let arch = ArchSpec::standin_for(&config);
    let storage = storage_profile(&args.str("storage", "9100pro"))?;
    let mut fleet = fleet_spec.as_ref().map(|spec| {
        let mut f = Fleet::new(
            spec,
            routing,
            FleetCostModel {
                arch: arch.clone(),
                storage: storage.clone(),
                chunk_tokens: doc_tokens,
                query_tokens: 20,
                chunk_step: engine.opts.chunk_step,
            },
        );
        f.set_contention(pcie_contention);
        f.set_trace(bus.clone());
        if let Some(plan) = &faults {
            f.set_faults(plan.clone());
            let (kv, plan) = (engine.kv.clone(), plan.clone());
            f.set_lost_chunks(std::sync::Arc::new(move |id| {
                plan.shard_dead(kv.shard_index_of(id))
            }));
        }
        f
    });
    if let Some(f) = fleet.as_mut() {
        f.register_metrics(&registry)?;
        f.set_sampler(sampler.clone());
    }

    // Every serve path goes through the scheduler: a queue of (possibly
    // simulated-Poisson) arrivals, a size-or-timeout release condition,
    // and a batch-formation policy.
    let policy_name = args.str("policy", "fifo");
    let policy = match policy_name.as_str() {
        "fifo" => SchedPolicy::Fifo,
        "affinity" => {
            SchedPolicy::TierAffinity { max_age_batches: args.usize("max-age-batches", 8) }
        }
        other => anyhow::bail!("unknown scheduling policy {other}"),
    };
    let rate = args.f64("arrival-rate", 0.0);
    // With a fleet and no explicit --service-ms, the planner's release
    // clock uses the fleet's per-batch cost model instead of a flat
    // estimate (the backlog then drains at the fleet's modeled rate);
    // the store answers which chunks are materialized, so cache-miss
    // batches price as on-device recompute.
    let estimator = match (&fleet, args.opt("service-ms")) {
        (Some(f), None) => {
            let kv = engine.kv.clone();
            Some(f.service_estimator_with(std::sync::Arc::new(move |id| kv.contains(id))))
        }
        _ => None,
    };
    let mut sched = Scheduler::new(
        engine.loader_ctx(),
        SchedOptions {
            batch: BatchPolicy {
                max_batch: batch,
                max_wait_secs: args.f64("max-wait-ms", 50.0) / 1e3,
            },
            policy,
            service_estimate_secs: args.f64("service-ms", 0.0) / 1e3,
            estimator,
        },
    );
    sched.set_trace(bus.clone());
    sched.set_metrics(&registry, Some(sampler.clone()))?;
    if rate > 0.0 {
        let mut gen =
            ArrivalGen::new(TurboRagProfile::default(), corpus.n_topics, 1.0, rate, 7);
        sched.enqueue_timed(gen.take(&corpus, requests));
    } else {
        let mut gen = RequestGen::new(TurboRagProfile::default(), corpus.n_topics, 1.0, 7);
        sched.enqueue_now(gen.take(&corpus, requests));
    }
    let exec = if overlap {
        ExecOptions::overlapped(OverlapOptions { prefetch, ..OverlapOptions::default() })
    } else {
        ExecOptions::sequential()
    };
    // Plan and execute separately so the fleet can dispatch the very
    // schedule the engine serves (the plan needs retrieval when a fleet
    // will price the batches). Both store snapshots — DRAM residency
    // and the materialized-on-flash set — are taken BEFORE execution:
    // the fleet must price this schedule against the store as it stood
    // when the run started, not after the run itself filled the tiers
    // (which would model a serve with no storage reads at all).
    let schedule = if fleet.is_some() {
        sched.plan_with_retrieval()
    } else {
        sched.plan_for_exec(&exec)
    };
    let resident_before = fleet.as_ref().map(|_| engine.kv.resident_set());
    let materialized_before: Option<std::collections::HashSet<matkv::vectordb::ChunkId>> =
        fleet.as_ref().map(|_| {
            schedule
                .batches
                .iter()
                .flat_map(|b| b.chunk_ids())
                .filter(|&id| engine.kv.contains(id))
                .collect()
        });
    let out = execute_schedule(&engine, &schedule, serve_mode, &exec)?;

    eprintln!(
        "[sched] policy={policy_name} {} batches ({} full / {} timeout releases), \
         queue wait mean {:.1}ms / max {:.1}ms, forced includes {}",
        out.sched.batches,
        out.sched.full_releases,
        out.sched.timeout_releases,
        out.sched.mean_wait_secs * 1e3,
        out.sched.max_wait_secs * 1e3,
        out.sched.forced_includes,
    );
    if overlap {
        let rep = &out.overlap;
        eprintln!(
            "[overlap] loader busy {:.2}s, exec busy {:.2}s, stalls {:.3}s",
            rep.loader_busy_secs, rep.exec_busy_secs, rep.exec_stall_secs
        );
        if prefetch {
            eprintln!(
                "[prefetch] busy {:.2}s, warmed {} (resident {}, absent {}, rejected {}), \
                 device {:.3}s off the loader path",
                rep.prefetch_busy_secs,
                rep.prefetch_warmed,
                rep.prefetch_already_resident,
                rep.prefetch_absent,
                rep.prefetch_rejected,
                rep.prefetch_device_secs,
            );
        }
    }
    let (responses, metrics) = (out.responses, out.metrics);

    let h100 = DeviceProfile::h100();
    println!("mode={mode_name} overlap={overlap} requests={} batch={batch}", responses.len());
    println!(
        "measured: total {:.2}s | retrieve {:.3}s | load {:.3}s | prefill {:.3}s | decode {:.3}s | {:.1} tok/s",
        metrics.total_wall_secs,
        metrics.retrieve_secs,
        metrics.load_wall_secs,
        metrics.prefill_wall_secs,
        metrics.decode_wall_secs,
        metrics.throughput()
    );
    if let Some(tier) = engine.kv.hot_tier() {
        const MIB: f64 = (1 << 20) as f64;
        use std::sync::atomic::Ordering::Relaxed;
        println!(
            "hot tier ({}, {:.0} MiB budget): {} hits / {} misses ({:.0}% hit), {:.1} MiB resident, \
             {:.1} MiB device reads saved, {} admissions gated off",
            tier.admission().label(),
            tier.budget() as f64 / MIB,
            tier.stats.hits.load(Relaxed),
            tier.stats.misses.load(Relaxed),
            100.0 * tier.stats.hit_ratio(),
            tier.bytes() as f64 / MIB,
            tier.stats.bytes_saved.load(Relaxed) as f64 / MIB,
            tier.stats.admission_rejected.load(Relaxed),
        );
    }
    if let Some(tier) = engine.kv.warm_tier() {
        const MIB: f64 = (1 << 20) as f64;
        use std::sync::atomic::Ordering::Relaxed;
        println!(
            "warm tier ({}, {:.0} MiB budget): {} hits / {} misses ({:.0}% hit), \
             {:.1} MiB resident, {:.1} MiB device reads saved, dequant {:.3}s (q4 {:.3}s), \
             quant {:.3}s (q4 {:.3}s)",
            tier.mode().label(),
            tier.budget() as f64 / MIB,
            tier.stats.hits.load(Relaxed),
            tier.stats.misses.load(Relaxed),
            100.0 * tier.stats.hit_ratio(),
            tier.bytes() as f64 / MIB,
            tier.stats.bytes_saved.load(Relaxed) as f64 / MIB,
            tier.stats.dequant_secs(),
            tier.stats.q4_dequant_secs(),
            tier.stats.quant_secs(),
            tier.stats.q4_quant_secs(),
        );
    }
    if engine.kv.n_shards() > 1 {
        use std::sync::atomic::Ordering::Relaxed;
        println!("shards ({} devices, {} io threads):", engine.kv.n_shards(), engine.kv.io_threads());
        for shard in engine.kv.shards() {
            let st = &shard.stats;
            println!(
                "  shard {:02}: {} reads / {:.1} MB read / {:.3}s device / peak queue {} / \
                 backlog {:.3}s / link queued {:.3}s | {} writes",
                shard.index(),
                st.reads.load(Relaxed),
                st.bytes_read.load(Relaxed) as f64 / 1e6,
                st.read_device_secs(),
                st.peak_queue_depth.load(Relaxed),
                shard.backlog_secs(),
                shard.link().stats.queued_secs(),
                st.writes.load(Relaxed),
            );
        }
    }
    // The shared host-side bus only carries tier traffic (warm-hit
    // promotion, eviction demotion); quiet runs print nothing.
    let bus = engine.kv.bus().stats.snapshot();
    if bus.reserves > 0 {
        println!(
            "host bus: {} reserves / {:.1} MB / busy {:.3}s / queued {:.3}s / peak backlog {:.3}s",
            bus.reserves,
            bus.bytes_by_class.iter().sum::<u64>() as f64 / 1e6,
            bus.busy_secs,
            bus.queued_secs,
            bus.peak_backlog_secs,
        );
    }
    println!(
        "simulated H100 @ {} scale: load {:.4}s | prefill {:.4}s | decode {:.4}s | total {:.4}s",
        arch.name,
        metrics.load_secs_on(&arch, &storage),
        metrics.prefill_secs_on(&arch, &h100),
        metrics.decode_secs_on(&arch, &h100),
        metrics.total_secs_on(&arch, &h100, &storage)
    );
    if metrics.q4_dequant_secs > 0.0 {
        // The q4 trade is priced, not free: fewer flash bytes, but
        // every v4 record / q4 warm hit pays its unpack on the load path.
        println!("  of which q4 dequant: {:.4}s", metrics.q4_dequant_secs);
    }
    if faults.is_some() {
        println!(
            "fault recovery (store): {} retries ({:.4}s backoff) | {} checksum failures | \
             {} chunks recomputed ({:.4}s, {} degraded tokens)",
            metrics.retries,
            metrics.retry_backoff_secs,
            metrics.checksum_failures,
            metrics.recomputed_chunks,
            metrics.recompute_fallback_secs,
            metrics.degraded_tokens,
        );
    }

    // Fleet simulation: dispatch the exact schedule the engine just
    // served across the worker pool on the virtual clock.
    let mut fleet_report = None;
    if let Some(fleet) = fleet.as_mut() {
        fleet.seed_resident(&resident_before.unwrap_or_default());
        let materialized = materialized_before.unwrap_or_default();
        let rep = fleet.dispatch(&schedule.batches, &|id| materialized.contains(&id));
        println!(
            "fleet ({} workers, routing={}, pcie {}): {} prefill-heavy / {} KV-resident batches, \
             makespan {:.2}s (virtual), {:.1} tok/s, {:.2} kJ, {:.4} tok/J",
            rep.workers.len(),
            rep.routing.label(),
            if rep.contention { "queued" } else { "flat" },
            rep.prefill_batches,
            rep.decode_batches,
            rep.makespan_secs,
            rep.throughput(),
            rep.total_kj,
            rep.tokens_per_joule,
        );
        for (i, w) in rep.workers.iter().enumerate() {
            println!(
                "  worker {i:02} {:8} [{:7}]: {} batches / {} reqs / {} tokens | busy {:.2}s \
                 ({:.0}% util) | load {:.3}s | transfer {:.3}s | link queued {:.3}s \
                 (peak {:.3}s) | {:.2} kJ",
                w.name,
                w.role.label(),
                w.batches,
                w.requests,
                w.tokens_out,
                w.busy_secs,
                100.0 * w.utilization,
                w.load_secs,
                w.transfer_secs,
                w.link.queued_secs,
                w.link.peak_backlog_secs,
                w.energy_kj,
            );
        }
        let l = &rep.latency;
        println!(
            "  latency (virtual, arrival→completion): mean {:.1}ms | p50 {:.1}ms | \
             p95 {:.1}ms | p99 {:.1}ms",
            l.mean * 1e3,
            l.p50 * 1e3,
            l.p95 * 1e3,
            l.p99 * 1e3,
        );
        if faults.is_some() {
            println!(
                "  fault recovery (fleet): {} requests requeued | {} chunks recomputed \
                 ({:.4}s surcharge, {} degraded tokens)",
                rep.metrics.requeued_requests,
                rep.metrics.recomputed_chunks,
                rep.metrics.recompute_fallback_secs,
                rep.metrics.degraded_tokens,
            );
        }
        fleet_report = Some(rep);
    }

    for r in responses.iter().take(2) {
        println!("  req {} -> {:?} (docs {:?})", r.request_id, r.text, r.retrieved);
    }

    if let Some(path) = &trace_path {
        std::fs::write(path, bus.to_chrome_json())?;
        eprintln!("[trace] {} events, {} request paths -> {}", bus.len(), bus.paths().len(), path.display());
    }
    // Close the sampler's tail at the schedule makespan; a fleet
    // dispatch already finished it at its (later) makespan, in which
    // case this is a no-op.
    sampler.lock().unwrap().finish(out.sched.makespan_secs);
    if let Some(path) = &prom_path {
        std::fs::write(path, registry.to_prometheus())?;
        eprintln!("[metrics] prometheus -> {}", path.display());
    }
    if let Some(path) = &metrics_path {
        // One document: the exhaustive PhaseBreakdown, per-shard device
        // stats, the DRAM tiers, the shared host bus, (when a fleet
        // dispatched) the full fleet report with per-worker link
        // snapshots, and the registry's sampled time series.
        use std::sync::atomic::Ordering::Relaxed;
        let shard_rows: Vec<String> = engine
            .kv
            .shards()
            .iter()
            .map(|s| {
                format!(
                    "{{\"shard\":{},\"reads\":{},\"bytes_read\":{},\"device_secs\":{:.9},\
                     \"peak_queue\":{},\"backlog_secs\":{:.9},\"writes\":{},\"link\":{}}}",
                    s.index(),
                    s.stats.reads.load(Relaxed),
                    s.stats.bytes_read.load(Relaxed),
                    s.stats.read_device_secs(),
                    s.stats.peak_queue_depth.load(Relaxed),
                    s.backlog_secs(),
                    s.stats.writes.load(Relaxed),
                    s.link().stats.snapshot().to_json(),
                )
            })
            .collect();
        let mut tier_rows: Vec<String> = Vec::new();
        if let Some(t) = engine.kv.hot_tier() {
            let (b, c) = t.residency();
            tier_rows.push(t.stats.to_full_json(b, c));
        }
        if let Some(t) = engine.kv.warm_tier() {
            let (b, c) = t.residency();
            tier_rows.push(t.stats.to_full_json(b, c));
        }
        let doc = format!(
            "{{\"mode\":\"{}\",\"config\":\"{}\",\"phases\":{},\"shards\":[{}],\
             \"tiers\":[{}],\"host_bus\":{},\"fleet\":{},\"series\":{}}}",
            mode_name,
            config,
            metrics.to_json(),
            shard_rows.join(","),
            tier_rows.join(","),
            engine.kv.bus().stats.snapshot().to_json(),
            fleet_report.as_ref().map_or_else(|| "null".to_string(), |r| r.to_json()),
            sampler.lock().unwrap().to_json(),
        );
        std::fs::write(path, doc)?;
        eprintln!("[metrics] -> {}", path.display());
    }
    Ok(())
}

fn economics() -> Result<()> {
    let rule = TenDayRule::paper_anchor();
    println!("Ten-day rule (paper anchor: LLaMA-70B/1024 tok, H100 vs 9100 Pro)");
    println!("  recompute cost : ${:.6}/access", rule.recompute_cost_usd());
    println!("  storage cost   : ${:.4} for {} MB", rule.storage_cost_usd(), rule.kv_bytes >> 20);
    println!("  break-even     : {:.1} days", rule.break_even_days());
    println!(
        "  @1/hour access : {:.0}x cheaper, {:.0}x lower prefill latency",
        rule.cost_ratio_at_interval(3600.0),
        rule.latency_ratio()
    );
    println!("\nFig 1 — cost/performance trend:");
    println!("  year  gpu    TFLOPs/k$   ssd      GB/s/(k$/TB)  GB/$");
    for r in fig1_trend() {
        println!(
            "  {}  {:6} {:9.1}   {:8} {:10.1}  {:6.1}",
            r.year, r.gpu, r.gpu_tflops_per_kusd, r.ssd, r.ssd_gbps_per_kusd_tb, r.ssd_gb_per_usd
        );
    }
    Ok(())
}
