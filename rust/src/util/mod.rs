//! Dependency-free infrastructure substrates.
//!
//! This build runs fully offline, so the usual ecosystem crates are
//! replaced by small purpose-built implementations:
//!
//! * [`json`] — minimal JSON parser (manifest.json / golden.json ABI).
//! * [`aio`] — thread-pool async file I/O with write-behind handles (the
//!   role DeepNVMe's `async_io` plays in the paper's prototype).
//! * [`cli`] — flag-style argument parsing for the leader binary.
//! * [`bench`] — measurement harness (warmup + timed iterations +
//!   mean/p50/p99) used by every `benches/` target.
//! * [`tempdir`] — self-cleaning temporary directories for tests/benches.
//! * [`half`] — bit-level f32 ⇄ f16 conversion (the v2 KV file format).

pub mod aio;
pub mod bench;
pub mod cli;
pub mod half;
pub mod json;
pub mod tempdir;
