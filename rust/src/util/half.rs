//! Software f32 ⇄ f16 (IEEE 754 binary16) conversion.
//!
//! The v2 materialized-KV format stores K/V planes as f16 — half the
//! flash bytes and half the simulated device-read time of the v1 f32
//! planes (real deployments store KV caches in fp16 anyway; f32 was the
//! testbed's convenience). The build runs fully offline, so instead of
//! the `half` crate this is a small, exhaustively-tested bit-level
//! implementation: round-to-nearest-even, subnormals preserved, NaNs
//! canonicalized.

/// Convert an `f32` to f16 bits (round-to-nearest-even).
pub fn f32_to_f16_bits(x: f32) -> u16 {
    let bits = x.to_bits();
    let sign = ((bits >> 16) & 0x8000) as u16;
    let exp = ((bits >> 23) & 0xff) as i32;
    let man = bits & 0x007f_ffff;
    if exp == 0xff {
        // Inf / NaN (NaN payload is not preserved, only NaN-ness).
        return if man == 0 { sign | 0x7c00 } else { sign | 0x7e00 };
    }
    let e = exp - 127 + 15; // re-bias f32 → f16
    if e >= 31 {
        return sign | 0x7c00; // overflow → ±inf
    }
    if e <= 0 {
        if e < -10 {
            return sign; // too small for a subnormal → ±0
        }
        // Subnormal: shift the implicit-1 mantissa into place.
        let man = man | 0x0080_0000;
        let shift = (14 - e) as u32; // 14..=24
        let half_man = man >> shift;
        let rem = man & ((1u32 << shift) - 1);
        let halfway = 1u32 << (shift - 1);
        let round = (rem > halfway || (rem == halfway && half_man & 1 == 1)) as u32;
        return sign | (half_man + round) as u16;
    }
    let half = ((e as u32) << 10) | (man >> 13);
    let rem = man & 0x1fff;
    let round = (rem > 0x1000 || (rem == 0x1000 && half & 1 == 1)) as u32;
    // A mantissa carry correctly bumps the exponent (and rounds to inf
    // at the top of the range).
    sign | (half + round) as u16
}

/// Convert f16 bits to an `f32`.
pub fn f16_bits_to_f32(h: u16) -> f32 {
    let sign = ((h as u32) & 0x8000) << 16;
    let exp = ((h >> 10) & 0x1f) as u32;
    let man = (h & 0x03ff) as u32;
    let bits = match (exp, man) {
        (0, 0) => sign,
        (0, _) => {
            // Subnormal: renormalize into an f32 normal.
            let mut exp32 = 113u32; // would be f16 exp 1 re-biased
            let mut m = man;
            while m & 0x0400 == 0 {
                m <<= 1;
                exp32 -= 1;
            }
            sign | (exp32 << 23) | ((m & 0x03ff) << 13)
        }
        (31, 0) => sign | 0x7f80_0000,
        (31, _) => sign | 0x7fc0_0000,
        _ => sign | ((exp + 112) << 23) | (man << 13),
    };
    f32::from_bits(bits)
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn exhaustive_f16_roundtrip() {
        // Every non-NaN f16 bit pattern survives f16 → f32 → f16 exactly.
        for h in 0..=u16::MAX {
            let is_nan = h & 0x7c00 == 0x7c00 && h & 0x03ff != 0;
            let back = f32_to_f16_bits(f16_bits_to_f32(h));
            if is_nan {
                assert_eq!(back & 0x7c00, 0x7c00);
                assert_ne!(back & 0x03ff, 0, "NaN collapsed to inf: {h:#06x}");
            } else {
                assert_eq!(back, h, "pattern {h:#06x}");
            }
        }
    }

    #[test]
    fn exact_values() {
        for (x, bits) in [
            (0.0f32, 0x0000u16),
            (-0.0, 0x8000),
            (1.0, 0x3c00),
            (-2.0, 0xc000),
            (0.5, 0x3800),
            (65504.0, 0x7bff),        // f16 max normal
            (6.103_515_6e-5, 0x0400), // f16 min normal
            (5.960_464_5e-8, 0x0001), // f16 min subnormal
            (f32::INFINITY, 0x7c00),
            (f32::NEG_INFINITY, 0xfc00),
        ] {
            assert_eq!(f32_to_f16_bits(x), bits, "{x}");
            assert_eq!(f16_bits_to_f32(bits).to_bits(), x.to_bits(), "{bits:#06x}");
        }
        assert!(f16_bits_to_f32(f32_to_f16_bits(f32::NAN)).is_nan());
    }

    #[test]
    fn overflow_and_underflow_saturate() {
        assert_eq!(f32_to_f16_bits(1e9), 0x7c00); // → +inf
        assert_eq!(f32_to_f16_bits(-1e9), 0xfc00);
        assert_eq!(f32_to_f16_bits(1e-9), 0x0000); // → +0
        assert_eq!(f32_to_f16_bits(-1e-9), 0x8000);
    }

    #[test]
    fn integers_up_to_2048_are_exact() {
        // The 11-bit significand holds integers |x| <= 2048 exactly —
        // the property the kvstore roundtrip tests rely on.
        for i in -2048i32..=2048 {
            let x = i as f32;
            assert_eq!(f16_bits_to_f32(f32_to_f16_bits(x)), x, "{i}");
        }
    }

    #[test]
    fn relative_error_bounded() {
        // Round-to-nearest over the normal range: |err| <= 2^-11 * |x|.
        let mut x = 1.000_123f32;
        while x < 60_000.0 {
            let y = f16_bits_to_f32(f32_to_f16_bits(x));
            assert!(((y - x) / x).abs() <= 1.0 / 2048.0, "{x} -> {y}");
            x *= 1.37;
        }
    }

    #[test]
    fn round_to_nearest_even() {
        // 1 + 2^-11 is exactly halfway between 1.0 and the next f16;
        // even mantissa (1.0) wins.
        assert_eq!(f32_to_f16_bits(1.0 + 1.0 / 2048.0), 0x3c00);
        // 1 + 3*2^-11 is halfway between 0x3c01 and 0x3c02; even wins.
        assert_eq!(f32_to_f16_bits(1.0 + 3.0 / 2048.0), 0x3c02);
    }
}
