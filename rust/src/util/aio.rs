//! Thread-pool async file I/O — the role DeepNVMe's `async_io` plays in
//! the paper's prototype: write-behind materialization and concurrent
//! reads that overlap with compute on the caller's thread.
//!
//! A fixed pool of worker threads consumes closures from a channel;
//! submitters get a [`Pending`] handle they can `wait()` on (or drop into
//! a drain list). No work-stealing, no async runtime — bounded, simple,
//! deterministic shutdown.

use std::sync::mpsc::{sync_channel, Receiver, SyncSender};
use std::sync::{Arc, Condvar, Mutex};
use std::thread::JoinHandle;

type Job = Box<dyn FnOnce() + Send + 'static>;

/// Handle to an in-flight I/O task producing `T`.
pub struct Pending<T> {
    slot: Arc<(Mutex<Option<T>>, Condvar)>,
}

impl<T> Pending<T> {
    fn new() -> (Self, Self) {
        let slot = Arc::new((Mutex::new(None), Condvar::new()));
        (Pending { slot: slot.clone() }, Pending { slot })
    }

    fn fill(&self, v: T) {
        let (m, cv) = &*self.slot;
        *m.lock().unwrap() = Some(v);
        cv.notify_all();
    }

    /// Block until the task completes and take its result.
    pub fn wait(self) -> T {
        let (m, cv) = &*self.slot;
        let mut guard = m.lock().unwrap();
        loop {
            if let Some(v) = guard.take() {
                return v;
            }
            guard = cv.wait(guard).unwrap();
        }
    }

    /// Non-blocking poll.
    pub fn try_take(&self) -> Option<T> {
        self.slot.0.lock().unwrap().take()
    }
}

/// Fixed-size I/O thread pool.
pub struct IoPool {
    tx: Option<SyncSender<Job>>,
    workers: Vec<JoinHandle<()>>,
}

impl IoPool {
    pub fn new(threads: usize) -> Self {
        let (tx, rx) = sync_channel::<Job>(threads * 4);
        let rx = Arc::new(Mutex::new(rx));
        let workers = (0..threads.max(1))
            .map(|i| {
                let rx: Arc<Mutex<Receiver<Job>>> = rx.clone();
                std::thread::Builder::new()
                    .name(format!("matkv-io-{i}"))
                    .spawn(move || loop {
                        let job = rx.lock().unwrap().recv();
                        match job {
                            Ok(job) => job(),
                            Err(_) => return, // pool dropped
                        }
                    })
                    .expect("spawning io worker")
            })
            .collect();
        IoPool { tx: Some(tx), workers }
    }

    /// Number of worker threads (the pool's maximum I/O concurrency —
    /// sharded stores size this off the shard count).
    pub fn threads(&self) -> usize {
        self.workers.len()
    }

    /// Submit a task; returns a waitable handle.
    pub fn submit<T: Send + 'static>(
        &self,
        f: impl FnOnce() -> T + Send + 'static,
    ) -> Pending<T> {
        let (theirs, ours) = Pending::new();
        let tx = self.tx.as_ref().expect("pool shut down");
        tx.send(Box::new(move || theirs.fill(f()))).expect("io pool alive");
        ours
    }

    /// Submit a batch and wait for all results, in order.
    pub fn map_wait<T: Send + 'static>(
        &self,
        fs: Vec<Box<dyn FnOnce() -> T + Send + 'static>>,
    ) -> Vec<T> {
        let handles: Vec<Pending<T>> = fs.into_iter().map(|f| self.submit(f)).collect();
        handles.into_iter().map(Pending::wait).collect()
    }
}

impl Drop for IoPool {
    fn drop(&mut self) {
        self.tx.take(); // closes channel; workers exit
        for w in self.workers.drain(..) {
            let _ = w.join();
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use std::sync::atomic::{AtomicUsize, Ordering};

    #[test]
    fn submit_and_wait() {
        let pool = IoPool::new(2);
        assert_eq!(pool.threads(), 2);
        let h = pool.submit(|| 21 * 2);
        assert_eq!(h.wait(), 42);
    }

    #[test]
    fn zero_threads_clamped_to_one() {
        let pool = IoPool::new(0);
        assert_eq!(pool.threads(), 1);
        assert_eq!(pool.submit(|| 5).wait(), 5);
    }

    #[test]
    fn many_tasks_all_complete() {
        let pool = IoPool::new(4);
        let counter = Arc::new(AtomicUsize::new(0));
        let handles: Vec<_> = (0..100)
            .map(|i| {
                let c = counter.clone();
                pool.submit(move || {
                    c.fetch_add(1, Ordering::SeqCst);
                    i
                })
            })
            .collect();
        let sum: usize = handles.into_iter().map(Pending::wait).sum();
        assert_eq!(sum, 99 * 100 / 2);
        assert_eq!(counter.load(Ordering::SeqCst), 100);
    }

    #[test]
    fn map_wait_preserves_order() {
        let pool = IoPool::new(3);
        let fs: Vec<Box<dyn FnOnce() -> usize + Send>> = (0..10usize)
            .map(|i| {
                Box::new(move || {
                    std::thread::sleep(std::time::Duration::from_millis(10 - i as u64));
                    i
                }) as Box<dyn FnOnce() -> usize + Send>
            })
            .collect();
        assert_eq!(pool.map_wait(fs), (0..10).collect::<Vec<_>>());
    }

    #[test]
    fn drop_joins_workers() {
        let pool = IoPool::new(2);
        let h = pool.submit(|| 1);
        drop(pool); // must not hang
        assert_eq!(h.wait(), 1);
    }

    #[test]
    fn try_take_nonblocking() {
        let pool = IoPool::new(1);
        let h = pool.submit(|| {
            std::thread::sleep(std::time::Duration::from_millis(50));
            7
        });
        // immediately: probably not done
        let _ = h.try_take();
        std::thread::sleep(std::time::Duration::from_millis(100));
        assert_eq!(h.try_take(), Some(7));
    }
}
