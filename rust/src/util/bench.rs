//! Measurement harness for the `benches/` targets (criterion is
//! unavailable offline; this provides the same discipline: warmup,
//! repeated timed iterations, robust summary statistics).

use std::time::Instant;

/// Summary of repeated measurements (seconds).
#[derive(Debug, Clone)]
pub struct Summary {
    pub iters: usize,
    pub mean: f64,
    pub p50: f64,
    pub p95: f64,
    pub min: f64,
    pub max: f64,
}

impl Summary {
    pub fn of(mut samples: Vec<f64>) -> Summary {
        assert!(!samples.is_empty());
        samples.sort_by(|a, b| a.total_cmp(b));
        let n = samples.len();
        let pick = |p: f64| samples[(((n - 1) as f64) * p).round() as usize];
        Summary {
            iters: n,
            mean: samples.iter().sum::<f64>() / n as f64,
            p50: pick(0.5),
            p95: pick(0.95),
            min: samples[0],
            max: samples[n - 1],
        }
    }
}

impl std::fmt::Display for Summary {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        write!(
            f,
            "mean {:>9.4}s  p50 {:>9.4}s  p95 {:>9.4}s  (n={})",
            self.mean, self.p50, self.p95, self.iters
        )
    }
}

/// Run `f` with `warmup` discarded iterations then `iters` timed ones.
pub fn measure<T>(warmup: usize, iters: usize, mut f: impl FnMut() -> T) -> Summary {
    for _ in 0..warmup {
        std::hint::black_box(f());
    }
    let samples = (0..iters.max(1))
        .map(|_| {
            let t0 = Instant::now();
            std::hint::black_box(f());
            t0.elapsed().as_secs_f64()
        })
        .collect();
    Summary::of(samples)
}

/// Pretty table printer for paper-style rows.
pub struct Table {
    pub title: String,
    header: Vec<String>,
    rows: Vec<Vec<String>>,
}

impl Table {
    pub fn new(title: &str, header: &[&str]) -> Self {
        Table {
            title: title.to_string(),
            header: header.iter().map(|s| s.to_string()).collect(),
            rows: Vec::new(),
        }
    }

    pub fn row(&mut self, cells: &[String]) {
        assert_eq!(cells.len(), self.header.len(), "table row width");
        self.rows.push(cells.to_vec());
    }

    pub fn print(&self) {
        let mut widths: Vec<usize> = self.header.iter().map(|h| h.len()).collect();
        for r in &self.rows {
            for (i, c) in r.iter().enumerate() {
                widths[i] = widths[i].max(c.len());
            }
        }
        println!("\n=== {} ===", self.title);
        let fmt_row = |cells: &[String]| {
            cells
                .iter()
                .enumerate()
                .map(|(i, c)| format!("{:>w$}", c, w = widths[i]))
                .collect::<Vec<_>>()
                .join("  ")
        };
        println!("{}", fmt_row(&self.header));
        println!("{}", "-".repeat(widths.iter().sum::<usize>() + 2 * (widths.len() - 1)));
        for r in &self.rows {
            println!("{}", fmt_row(r));
        }
    }
}

/// Format seconds compactly.
pub fn fmt_secs(s: f64) -> String {
    if s >= 1.0 {
        format!("{s:.2}s")
    } else if s >= 1e-3 {
        format!("{:.1}ms", s * 1e3)
    } else {
        format!("{:.0}us", s * 1e6)
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn summary_stats() {
        let s = Summary::of(vec![3.0, 1.0, 2.0]);
        assert_eq!(s.min, 1.0);
        assert_eq!(s.max, 3.0);
        assert_eq!(s.p50, 2.0);
        assert!((s.mean - 2.0).abs() < 1e-12);
    }

    #[test]
    fn measure_counts_iters() {
        let mut calls = 0;
        let s = measure(2, 5, || calls += 1);
        assert_eq!(s.iters, 5);
        assert_eq!(calls, 7);
    }

    #[test]
    fn fmt_secs_ranges() {
        assert_eq!(fmt_secs(2.0), "2.00s");
        assert_eq!(fmt_secs(0.0025), "2.5ms");
        assert_eq!(fmt_secs(2.5e-5), "25us");
    }

    #[test]
    #[should_panic]
    fn table_width_checked() {
        let mut t = Table::new("t", &["a", "b"]);
        t.row(&["x".to_string()]);
    }
}
