//! Minimal recursive-descent JSON parser.
//!
//! Supports the full JSON grammar (objects, arrays, strings with escapes,
//! numbers, booleans, null). Only what the artifact ABI needs — no
//! serialization framework, no zero-copy tricks.

use std::collections::BTreeMap;
use std::fmt;

/// A parsed JSON value.
#[derive(Debug, Clone, PartialEq)]
pub enum Json {
    Null,
    Bool(bool),
    Num(f64),
    Str(String),
    Arr(Vec<Json>),
    Obj(BTreeMap<String, Json>),
}

/// Parse error with byte offset.
#[derive(Debug, Clone)]
pub struct JsonError {
    pub msg: String,
    pub offset: usize,
}

impl fmt::Display for JsonError {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        write!(f, "JSON error at byte {}: {}", self.offset, self.msg)
    }
}

impl std::error::Error for JsonError {}

impl Json {
    pub fn parse(input: &str) -> Result<Json, JsonError> {
        let mut p = Parser { bytes: input.as_bytes(), pos: 0 };
        p.skip_ws();
        let v = p.value()?;
        p.skip_ws();
        if p.pos != p.bytes.len() {
            return Err(p.err("trailing data"));
        }
        Ok(v)
    }

    // -- typed accessors ----------------------------------------------------
    pub fn get(&self, key: &str) -> Option<&Json> {
        match self {
            Json::Obj(m) => m.get(key),
            _ => None,
        }
    }

    pub fn as_f64(&self) -> Option<f64> {
        match self {
            Json::Num(n) => Some(*n),
            _ => None,
        }
    }

    pub fn as_usize(&self) -> Option<usize> {
        self.as_f64().map(|n| n as usize)
    }

    pub fn as_str(&self) -> Option<&str> {
        match self {
            Json::Str(s) => Some(s),
            _ => None,
        }
    }

    pub fn as_arr(&self) -> Option<&[Json]> {
        match self {
            Json::Arr(a) => Some(a),
            _ => None,
        }
    }

    pub fn as_obj(&self) -> Option<&BTreeMap<String, Json>> {
        match self {
            Json::Obj(m) => Some(m),
            _ => None,
        }
    }

    /// `obj.field` chain with error context (for ABI parsing).
    pub fn req(&self, key: &str) -> anyhow::Result<&Json> {
        self.get(key).ok_or_else(|| anyhow::anyhow!("missing JSON key {key:?}"))
    }
}

struct Parser<'a> {
    bytes: &'a [u8],
    pos: usize,
}

impl<'a> Parser<'a> {
    fn err(&self, msg: &str) -> JsonError {
        JsonError { msg: msg.to_string(), offset: self.pos }
    }

    fn peek(&self) -> Option<u8> {
        self.bytes.get(self.pos).copied()
    }

    fn bump(&mut self) -> Option<u8> {
        let b = self.peek()?;
        self.pos += 1;
        Some(b)
    }

    fn skip_ws(&mut self) {
        while matches!(self.peek(), Some(b' ' | b'\t' | b'\n' | b'\r')) {
            self.pos += 1;
        }
    }

    fn expect(&mut self, b: u8) -> Result<(), JsonError> {
        if self.bump() == Some(b) {
            Ok(())
        } else {
            self.pos = self.pos.saturating_sub(1);
            Err(self.err(&format!("expected {:?}", b as char)))
        }
    }

    fn lit(&mut self, s: &str, v: Json) -> Result<Json, JsonError> {
        if self.bytes[self.pos..].starts_with(s.as_bytes()) {
            self.pos += s.len();
            Ok(v)
        } else {
            Err(self.err(&format!("expected {s}")))
        }
    }

    fn value(&mut self) -> Result<Json, JsonError> {
        match self.peek().ok_or_else(|| self.err("unexpected end"))? {
            b'{' => self.object(),
            b'[' => self.array(),
            b'"' => Ok(Json::Str(self.string()?)),
            b't' => self.lit("true", Json::Bool(true)),
            b'f' => self.lit("false", Json::Bool(false)),
            b'n' => self.lit("null", Json::Null),
            b'-' | b'0'..=b'9' => self.number(),
            c => Err(self.err(&format!("unexpected {:?}", c as char))),
        }
    }

    fn object(&mut self) -> Result<Json, JsonError> {
        self.expect(b'{')?;
        let mut m = BTreeMap::new();
        self.skip_ws();
        if self.peek() == Some(b'}') {
            self.pos += 1;
            return Ok(Json::Obj(m));
        }
        loop {
            self.skip_ws();
            let k = self.string()?;
            self.skip_ws();
            self.expect(b':')?;
            self.skip_ws();
            let v = self.value()?;
            m.insert(k, v);
            self.skip_ws();
            match self.bump() {
                Some(b',') => continue,
                Some(b'}') => return Ok(Json::Obj(m)),
                _ => return Err(self.err("expected , or }")),
            }
        }
    }

    fn array(&mut self) -> Result<Json, JsonError> {
        self.expect(b'[')?;
        let mut a = Vec::new();
        self.skip_ws();
        if self.peek() == Some(b']') {
            self.pos += 1;
            return Ok(Json::Arr(a));
        }
        loop {
            self.skip_ws();
            a.push(self.value()?);
            self.skip_ws();
            match self.bump() {
                Some(b',') => continue,
                Some(b']') => return Ok(Json::Arr(a)),
                _ => return Err(self.err("expected , or ]")),
            }
        }
    }

    fn string(&mut self) -> Result<String, JsonError> {
        self.expect(b'"')?;
        let mut out = String::new();
        loop {
            match self.bump().ok_or_else(|| self.err("unterminated string"))? {
                b'"' => return Ok(out),
                b'\\' => match self.bump().ok_or_else(|| self.err("bad escape"))? {
                    b'"' => out.push('"'),
                    b'\\' => out.push('\\'),
                    b'/' => out.push('/'),
                    b'b' => out.push('\u{8}'),
                    b'f' => out.push('\u{c}'),
                    b'n' => out.push('\n'),
                    b'r' => out.push('\r'),
                    b't' => out.push('\t'),
                    b'u' => {
                        let hex: String = (0..4)
                            .map(|_| self.bump().map(|b| b as char).unwrap_or('!'))
                            .collect();
                        let cp = u32::from_str_radix(&hex, 16)
                            .map_err(|_| self.err("bad \\u escape"))?;
                        out.push(char::from_u32(cp).unwrap_or('\u{fffd}'));
                    }
                    _ => return Err(self.err("bad escape")),
                },
                b if b < 0x80 => out.push(b as char),
                b => {
                    // multi-byte UTF-8: copy raw bytes
                    let start = self.pos - 1;
                    let len = match b {
                        0xC0..=0xDF => 2,
                        0xE0..=0xEF => 3,
                        _ => 4,
                    };
                    self.pos = (start + len).min(self.bytes.len());
                    out.push_str(
                        std::str::from_utf8(&self.bytes[start..self.pos])
                            .map_err(|_| self.err("bad utf8"))?,
                    );
                }
            }
        }
    }

    fn number(&mut self) -> Result<Json, JsonError> {
        let start = self.pos;
        if self.peek() == Some(b'-') {
            self.pos += 1;
        }
        while matches!(self.peek(), Some(b'0'..=b'9' | b'.' | b'e' | b'E' | b'+' | b'-')) {
            self.pos += 1;
        }
        std::str::from_utf8(&self.bytes[start..self.pos])
            .ok()
            .and_then(|s| s.parse::<f64>().ok())
            .map(Json::Num)
            .ok_or_else(|| self.err("bad number"))
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn scalars() {
        assert_eq!(Json::parse("42").unwrap(), Json::Num(42.0));
        assert_eq!(Json::parse("-1.5e3").unwrap(), Json::Num(-1500.0));
        assert_eq!(Json::parse("true").unwrap(), Json::Bool(true));
        assert_eq!(Json::parse("null").unwrap(), Json::Null);
        assert_eq!(Json::parse("\"hi\"").unwrap(), Json::Str("hi".into()));
    }

    #[test]
    fn nested_structure() {
        let v = Json::parse(r#"{"a": [1, {"b": "x"}, null], "c": {"d": false}}"#).unwrap();
        assert_eq!(v.get("a").unwrap().as_arr().unwrap()[0], Json::Num(1.0));
        assert_eq!(v.get("a").unwrap().as_arr().unwrap()[1].get("b").unwrap().as_str(), Some("x"));
        assert_eq!(v.get("c").unwrap().get("d").unwrap(), &Json::Bool(false));
    }

    #[test]
    fn string_escapes() {
        let v = Json::parse(r#""a\n\t\"\\ A""#).unwrap();
        assert_eq!(v.as_str().unwrap(), "a\n\t\"\\ A");
    }

    #[test]
    fn unicode_passthrough() {
        let v = Json::parse("\"héllo wörld ☃\"").unwrap();
        assert_eq!(v.as_str().unwrap(), "héllo wörld ☃");
    }

    #[test]
    fn whitespace_tolerant() {
        let v = Json::parse(" { \"k\" :\n[ 1 , 2 ] } ").unwrap();
        assert_eq!(v.get("k").unwrap().as_arr().unwrap().len(), 2);
    }

    #[test]
    fn errors_reported() {
        assert!(Json::parse("{").is_err());
        assert!(Json::parse("[1,]").is_err());
        assert!(Json::parse("12 34").is_err());
        assert!(Json::parse("\"unterminated").is_err());
        assert!(Json::parse("nul").is_err());
    }

    #[test]
    fn parses_real_manifest() {
        // Real AOT output when built, golden metadata otherwise — both
        // are full-size manifests exercising every JSON production.
        let path = if crate::manifest::artifacts_present() {
            crate::artifacts_dir().join("manifest.json")
        } else {
            crate::manifest::golden_dir().join("manifest.json")
        };
        let text = std::fs::read_to_string(path).expect("golden manifest missing");
        let v = Json::parse(&text).unwrap();
        assert!(v.get("configs").unwrap().as_obj().unwrap().contains_key("tiny"));
    }
}
