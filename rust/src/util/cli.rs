//! Tiny flag-style argument parser for the leader binary and examples:
//! `--name value` pairs plus boolean `--flag`s after a subcommand word.

use std::collections::HashMap;

/// Parsed command line: subcommand + flags.
#[derive(Debug, Default)]
pub struct Args {
    pub command: Option<String>,
    flags: HashMap<String, String>,
}

impl Args {
    /// Parse from an iterator of arguments (excluding argv[0]).
    pub fn parse(argv: impl IntoIterator<Item = String>) -> Result<Self, String> {
        let mut out = Args::default();
        let mut it = argv.into_iter().peekable();
        if let Some(first) = it.peek() {
            if !first.starts_with("--") {
                out.command = it.next();
            }
        }
        while let Some(a) = it.next() {
            let Some(name) = a.strip_prefix("--") else {
                return Err(format!("unexpected positional argument {a:?}"));
            };
            match it.peek() {
                Some(v) if !v.starts_with("--") => {
                    out.flags.insert(name.to_string(), it.next().unwrap());
                }
                _ => {
                    out.flags.insert(name.to_string(), "true".to_string());
                }
            }
        }
        Ok(out)
    }

    pub fn from_env() -> Result<Self, String> {
        Self::parse(std::env::args().skip(1))
    }

    pub fn str(&self, name: &str, default: &str) -> String {
        self.flags.get(name).cloned().unwrap_or_else(|| default.to_string())
    }

    pub fn opt(&self, name: &str) -> Option<&str> {
        self.flags.get(name).map(String::as_str)
    }

    pub fn usize(&self, name: &str, default: usize) -> usize {
        self.flags.get(name).and_then(|v| v.parse().ok()).unwrap_or(default)
    }

    pub fn f64(&self, name: &str, default: f64) -> f64 {
        self.flags.get(name).and_then(|v| v.parse().ok()).unwrap_or(default)
    }

    pub fn flag(&self, name: &str) -> bool {
        matches!(self.flags.get(name).map(String::as_str), Some("true") | Some("1") | Some("yes"))
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn parse(s: &str) -> Args {
        Args::parse(s.split_whitespace().map(String::from)).unwrap()
    }

    #[test]
    fn subcommand_and_flags() {
        let a = parse("serve --config tiny --requests 32 --overlap");
        assert_eq!(a.command.as_deref(), Some("serve"));
        assert_eq!(a.str("config", "x"), "tiny");
        assert_eq!(a.usize("requests", 0), 32);
        assert!(a.flag("overlap"));
        assert!(!a.flag("missing"));
    }

    #[test]
    fn defaults() {
        let a = parse("info");
        assert_eq!(a.usize("batch", 4), 4);
        assert_eq!(a.f64("skew", 1.0), 1.0);
        assert_eq!(a.opt("none"), None);
    }

    #[test]
    fn rejects_positionals_after_flags() {
        assert!(Args::parse(["serve".into(), "oops".into()]).is_err());
    }

    #[test]
    fn no_subcommand() {
        let a = parse("--x 1");
        assert_eq!(a.command, None);
        assert_eq!(a.usize("x", 0), 1);
    }
}
