//! Self-cleaning temporary directories (tests, benches, CLI default
//! KV-store location).

use std::path::{Path, PathBuf};
use std::sync::atomic::{AtomicU64, Ordering};

static COUNTER: AtomicU64 = AtomicU64::new(0);

/// A directory removed on drop.
#[derive(Debug)]
pub struct TempDir {
    path: PathBuf,
}

impl TempDir {
    pub fn new(prefix: &str) -> std::io::Result<Self> {
        let n = COUNTER.fetch_add(1, Ordering::Relaxed);
        let path = std::env::temp_dir().join(format!(
            "{prefix}-{}-{}-{n}",
            std::process::id(),
            std::time::SystemTime::now()
                .duration_since(std::time::UNIX_EPOCH)
                .map(|d| d.subsec_nanos())
                .unwrap_or(0),
        ));
        std::fs::create_dir_all(&path)?;
        Ok(TempDir { path })
    }

    pub fn path(&self) -> &Path {
        &self.path
    }
}

impl Drop for TempDir {
    fn drop(&mut self) {
        let _ = std::fs::remove_dir_all(&self.path);
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn creates_and_cleans() {
        let p;
        {
            let d = TempDir::new("matkv-test").unwrap();
            p = d.path().to_path_buf();
            std::fs::write(p.join("f"), b"x").unwrap();
            assert!(p.exists());
        }
        assert!(!p.exists());
    }

    #[test]
    fn unique_paths() {
        let a = TempDir::new("matkv-test").unwrap();
        let b = TempDir::new("matkv-test").unwrap();
        assert_ne!(a.path(), b.path());
    }
}
