//! # MatKV — trading compute for flash storage in LLM inference
//!
//! Reproduction of *MatKV* (Shin et al., CS.DC 2025) as a three-layer
//! rust + JAX + Pallas serving stack:
//!
//! * **L3 (this crate)** — the paper's system contribution: the ingest
//!   pipeline that materializes document KV caches to flash, the serve
//!   path that loads them instead of recomputing prefill, an online
//!   serving scheduler with tier-aware continuous batching, the
//!   decode/IO overlap pipeline, the Vanilla and CacheBlend-style
//!   baselines, plus every substrate they need (vector DB, KV store with
//!   storage-device simulation, tokenizer, workload generation,
//!   hardware/energy/economics models).
//! * **L2 (python/compile, build-time)** — a LLaMA-architecture model in
//!   JAX whose single packed-state entry point serves chunked prefill,
//!   query sub-prefill over loaded KVs, and decode; AOT-lowered to HLO
//!   text per (config, S, B, C) bucket.
//! * **L1 (python/compile/kernels, build-time)** — Pallas flash-attention
//!   and RMSNorm kernels lowered into the same HLO.
//!
//! At serving time only this crate runs: [`runtime`] loads the AOT
//! artifacts through the PJRT CPU client (`xla` crate) and the decode
//! loop stays device-resident via packed-state buffer feedback.

pub mod coordinator;
pub mod hwsim;
pub mod util;
pub mod kvstore;
pub mod manifest;
pub mod obs;
pub mod runtime;
pub mod tokenizer;
pub mod trace;
pub mod vectordb;
pub mod workload;

pub use manifest::{Manifest, ModelConfig};

/// Convenience result alias used across the crate.
pub type Result<T> = anyhow::Result<T>;

/// Locate the artifacts directory: `$MATKV_ARTIFACTS` or `./artifacts`.
pub fn artifacts_dir() -> std::path::PathBuf {
    std::env::var_os("MATKV_ARTIFACTS")
        .map(std::path::PathBuf::from)
        .unwrap_or_else(|| std::path::PathBuf::from("artifacts"))
}
