//! Shared scenario builders for examples and the paper-table benches.
//!
//! Every bench in `rust/benches/` regenerates one table/figure of the
//! paper by driving a [`Scenario`] — a fully ingested engine over a
//! synthetic corpus — through the serve modes under measurement.

use anyhow::Result;

use super::engine::{Engine, EngineOptions};
use crate::hwsim::StorageProfile;
use crate::kvstore::{KvStore, WarmMode};
use crate::util::tempdir::TempDir;
use crate::workload::{Corpus, RagRequest, RequestGen, TurboRagProfile};
use crate::Manifest;

/// A ready-to-serve deployment (corpus ingested, KVs materialized).
pub struct Scenario {
    pub engine: Engine,
    pub corpus: Corpus,
    pub doc_tokens: usize,
    /// Hot-tier budget to re-apply when the storage device is swapped.
    hot_tier_bytes: usize,
    /// Warm-tier budget to re-apply on the same occasion.
    warm_tier_bytes: usize,
    /// Warm-tier codec to re-apply alongside the budget.
    warm_mode: WarmMode,
    /// Shard count to re-apply on reopen (the on-disk layout pins it).
    shards: usize,
    /// Keep the KV directory alive for the scenario's lifetime.
    _kv_dir: TempDir,
}

/// Scenario construction knobs.
#[derive(Debug, Clone)]
pub struct ScenarioSpec {
    pub config: String,
    pub storage: StorageProfile,
    pub n_docs: usize,
    pub doc_tokens: usize,
    pub seed: u64,
    /// DRAM hot-tier budget in bytes (0 = flash only).
    pub hot_tier_bytes: usize,
    /// Quantized warm-tier budget in bytes behind the hot tier
    /// (0 = none). Hot-tier evictions demote here; warm hits
    /// dequantize + promote.
    pub warm_tier_bytes: usize,
    /// Warm-tier codec: q8 (default, ~4x fewer resident bytes than
    /// f32) or q4 (~8x, at its own modeled dequant rate).
    pub warm_mode: WarmMode,
    /// Simulated independent storage devices (1 = the classic single
    /// bus; >1 = a JBOD, `profile` describing each member device).
    pub shards: usize,
}

impl Default for ScenarioSpec {
    fn default() -> Self {
        ScenarioSpec {
            config: "tiny".into(),
            storage: StorageProfile::raid0_4x9100(),
            n_docs: 16,
            doc_tokens: 1024,
            seed: 42,
            hot_tier_bytes: 0,
            warm_tier_bytes: 0,
            warm_mode: WarmMode::Q8,
            shards: 1,
        }
    }
}

impl Scenario {
    /// Build and ingest.
    pub fn build(spec: ScenarioSpec) -> Result<Scenario> {
        let manifest = Manifest::load(crate::artifacts_dir())?;
        let corpus =
            Corpus::generate(spec.n_docs, spec.doc_tokens, spec.n_docs.min(16), spec.seed);
        let kv_dir = TempDir::new("matkv-scenario")?;
        let mut kv = KvStore::open_sharded(kv_dir.path(), spec.storage, spec.shards.max(1))?;
        kv.set_hot_tier(spec.hot_tier_bytes);
        kv.set_warm_tier(spec.warm_tier_bytes);
        kv.set_warm_mode(spec.warm_mode);
        let opts = EngineOptions::for_config(&manifest, &spec.config)?;
        let engine = Engine::new(&manifest, opts, kv, corpus.texts())?;
        engine.ingest_corpus(&corpus, spec.doc_tokens)?;
        Ok(Scenario {
            engine,
            corpus,
            doc_tokens: spec.doc_tokens,
            hot_tier_bytes: spec.hot_tier_bytes,
            warm_tier_bytes: spec.warm_tier_bytes,
            warm_mode: spec.warm_mode,
            shards: spec.shards.max(1),
            _kv_dir: kv_dir,
        })
    }

    /// TurboRAG-profile request stream (paper §V-B: top-k chunks of
    /// `doc_tokens`, ~20-token query, `output_tokens` answer).
    pub fn requests(&self, n: usize, top_k: usize, output_tokens: usize) -> Vec<RagRequest> {
        let mut gen = RequestGen::new(
            TurboRagProfile { top_k, query_tokens: 20.0, output_tokens },
            self.corpus.n_topics,
            1.0,
            7,
        );
        gen.take(&self.corpus, n)
    }

    /// Swap the simulated storage device (Table III).
    pub fn set_storage(&mut self, profile: StorageProfile) {
        // Arc<KvStore> is shared with loader contexts; re-opening is the
        // clean way to swap the throttle everywhere at once. The hot
        // tier restarts cold, exactly like a real node after a device
        // swap. The shard count must match the on-disk layout (the
        // marker file rejects anything else).
        let dir = self._kv_dir.path().to_path_buf();
        let mut store =
            KvStore::open_sharded(dir, profile, self.shards).expect("reopen kvstore");
        store.set_hot_tier(self.hot_tier_bytes);
        store.set_warm_tier(self.warm_tier_bytes);
        store.set_warm_mode(self.warm_mode);
        self.engine.kv = std::sync::Arc::new(store);
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::coordinator::{serve_overlapped_with, OverlapOptions, ServeMode};

    // These suites execute models through PJRT: golden metadata is not
    // enough, they need the real AOT artifacts.
    use crate::require_artifacts;

    #[test]
    fn scenario_builds_and_serves() {
        require_artifacts!();
        let mut spec = ScenarioSpec::default();
        spec.n_docs = 4;
        spec.doc_tokens = 256;
        spec.storage = StorageProfile::dram();
        let sc = Scenario::build(spec).unwrap();
        let reqs = sc.requests(2, 1, 3);
        let (r, m) = sc.engine.serve_all(&reqs, 2, ServeMode::MatKv).unwrap();
        assert_eq!(r.len(), 2);
        assert_eq!(m.tokens_out, 6);
    }

    #[test]
    fn scenario_hot_tier_hits_on_repeat_traffic() {
        require_artifacts!();
        let mut spec = ScenarioSpec::default();
        spec.n_docs = 4;
        spec.doc_tokens = 256;
        spec.storage = StorageProfile::dram();
        spec.hot_tier_bytes = 256 << 20;
        let sc = Scenario::build(spec).unwrap();
        let reqs = sc.requests(4, 1, 2);
        let (_, cold) = sc.engine.serve_all(&reqs, 2, ServeMode::MatKv).unwrap();
        let (_, warm) = sc.engine.serve_all(&reqs, 2, ServeMode::MatKv).unwrap();
        assert!(warm.cache_hits > 0, "no hot-tier hits on repeat traffic");
        assert!(warm.load_device_secs < cold.load_device_secs);
    }

    #[test]
    fn storage_swap_changes_profile() {
        require_artifacts!();
        let mut spec = ScenarioSpec::default();
        spec.n_docs = 2;
        spec.doc_tokens = 256;
        spec.storage = StorageProfile::dram();
        let mut sc = Scenario::build(spec).unwrap();
        assert_eq!(sc.engine.kv.profile().name, "DRAM");
        sc.set_storage(StorageProfile::ssd_9100pro());
        assert_eq!(sc.engine.kv.profile().name, "9100Pro");
        // materialized files survive the swap
        assert_eq!(sc.engine.kv.len().unwrap(), 2);
    }

    #[test]
    fn sharded_scenario_serves_and_rolls_up_per_shard_reads() {
        require_artifacts!();
        let mut spec = ScenarioSpec::default();
        spec.n_docs = 8;
        spec.doc_tokens = 256;
        spec.storage = StorageProfile::dram();
        spec.shards = 4;
        let mut sc = Scenario::build(spec).unwrap();
        assert_eq!(sc.engine.kv.n_shards(), 4);
        let reqs = sc.requests(4, 2, 2);
        let (r, m) = sc.engine.serve_all(&reqs, 2, ServeMode::MatKv).unwrap();
        assert_eq!(r.len(), 4);
        assert_eq!(m.shard_reads.len(), 4);
        assert_eq!(m.shard_reads.iter().sum::<u64>() as usize, m.load_reads);
        assert_eq!(m.shard_bytes.iter().sum::<u64>() as usize, m.loaded_bytes);
        // the storage swap preserves the sharded layout
        sc.set_storage(StorageProfile::dram());
        assert_eq!(sc.engine.kv.n_shards(), 4);
        assert_eq!(sc.engine.kv.len().unwrap(), 8);
    }

    #[test]
    fn prefetch_overlap_converts_misses_to_tier_hits() {
        require_artifacts!();
        let mut spec = ScenarioSpec::default();
        spec.n_docs = 8;
        spec.doc_tokens = 256;
        spec.storage = StorageProfile::dram();
        spec.hot_tier_bytes = 256 << 20;
        spec.shards = 2;
        let sc = Scenario::build(spec).unwrap();
        let reqs = sc.requests(8, 2, 2);
        let opts = OverlapOptions { prefetch: true, lookahead: 3 };
        let (r, m, rep) =
            serve_overlapped_with(&sc.engine, &reqs, 2, ServeMode::MatKv, &opts).unwrap();
        assert_eq!(r.len(), 8);
        // The prefetcher processed upcoming batches: every id it saw was
        // either warmed, already warm, or (rarely, under admission
        // pressure) rejected — never an error, never absent.
        assert!(
            rep.prefetch_warmed + rep.prefetch_already_resident + rep.prefetch_rejected > 0,
            "{rep:?}"
        );
        assert_eq!(rep.prefetch_absent, 0);
        assert!(m.cache_hits > 0);
        // the serve answers match a plain overlapped run
        let sc2 = {
            let mut spec = ScenarioSpec::default();
            spec.n_docs = 8;
            spec.doc_tokens = 256;
            spec.storage = StorageProfile::dram();
            spec.hot_tier_bytes = 256 << 20;
            spec.shards = 2;
            Scenario::build(spec).unwrap()
        };
        let (r2, _, _) = crate::coordinator::serve_overlapped(
            &sc2.engine,
            &sc2.requests(8, 2, 2),
            2,
            ServeMode::MatKv,
        )
        .unwrap();
        for (a, b) in r.iter().zip(&r2) {
            assert_eq!(a.tokens, b.tokens, "prefetch changed generated tokens");
        }
    }
}
