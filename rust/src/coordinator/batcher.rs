//! Dynamic batching queue.
//!
//! Requests accumulate until either the target batch size is reached or
//! the oldest request has waited `max_wait` — the standard
//! size-or-timeout policy of LLM serving systems (vLLM, HF-TGI), applied
//! over the AOT batch buckets {1, 2, 4, 8}.

use std::collections::VecDeque;
use std::time::{Duration, Instant};

use crate::workload::RagRequest;

/// Batching policy knobs.
#[derive(Debug, Clone, Copy)]
pub struct BatchPolicy {
    /// Preferred batch size (rounded up to a bucket by the engine).
    pub max_batch: usize,
    /// Max time the oldest queued request may wait before a partial
    /// batch is released.
    pub max_wait: Duration,
}

impl Default for BatchPolicy {
    fn default() -> Self {
        BatchPolicy { max_batch: 8, max_wait: Duration::from_millis(50) }
    }
}

/// FIFO dynamic batcher.
pub struct Batcher {
    policy: BatchPolicy,
    queue: VecDeque<(RagRequest, Instant)>,
}

impl Batcher {
    pub fn new(policy: BatchPolicy) -> Self {
        Batcher { policy, queue: VecDeque::new() }
    }

    pub fn push(&mut self, req: RagRequest) {
        self.queue.push_back((req, Instant::now()));
    }

    pub fn push_all(&mut self, reqs: impl IntoIterator<Item = RagRequest>) {
        let now = Instant::now();
        for r in reqs {
            self.queue.push_back((r, now));
        }
    }

    pub fn pending(&self) -> usize {
        self.queue.len()
    }

    /// Release a batch if policy conditions hold (size reached, or oldest
    /// request timed out). `None` = keep waiting.
    pub fn next_batch(&mut self) -> Option<Vec<RagRequest>> {
        if self.queue.is_empty() {
            return None;
        }
        let oldest_wait = self.queue.front().map(|(_, t)| t.elapsed()).unwrap_or_default();
        if self.queue.len() >= self.policy.max_batch || oldest_wait >= self.policy.max_wait {
            let n = self.queue.len().min(self.policy.max_batch);
            return Some(self.queue.drain(..n).map(|(r, _)| r).collect());
        }
        None
    }

    /// Drain everything into maximal batches (offline/bench mode).
    pub fn drain_batches(&mut self) -> Vec<Vec<RagRequest>> {
        let mut out = Vec::new();
        while !self.queue.is_empty() {
            let n = self.queue.len().min(self.policy.max_batch);
            out.push(self.queue.drain(..n).map(|(r, _)| r).collect());
        }
        out
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn req(id: u64) -> RagRequest {
        RagRequest { id, query: "q".into(), top_k: 2, output_tokens: 4, topic: 0 }
    }

    #[test]
    fn releases_on_size() {
        let mut b = Batcher::new(BatchPolicy { max_batch: 3, max_wait: Duration::from_secs(60) });
        b.push(req(0));
        b.push(req(1));
        assert!(b.next_batch().is_none()); // below size, not timed out
        b.push(req(2));
        let batch = b.next_batch().unwrap();
        assert_eq!(batch.iter().map(|r| r.id).collect::<Vec<_>>(), vec![0, 1, 2]);
        assert_eq!(b.pending(), 0);
    }

    #[test]
    fn releases_partial_on_timeout() {
        let mut b = Batcher::new(BatchPolicy { max_batch: 8, max_wait: Duration::from_millis(5) });
        b.push(req(0));
        std::thread::sleep(Duration::from_millis(10));
        let batch = b.next_batch().unwrap();
        assert_eq!(batch.len(), 1);
    }

    #[test]
    fn oversize_queue_splits() {
        let mut b = Batcher::new(BatchPolicy { max_batch: 4, max_wait: Duration::ZERO });
        b.push_all((0..10).map(req));
        let batches = b.drain_batches();
        assert_eq!(batches.iter().map(Vec::len).collect::<Vec<_>>(), vec![4, 4, 2]);
        // FIFO order preserved across batches
        assert_eq!(batches[2][1].id, 9);
    }

    #[test]
    fn empty_queue_yields_none() {
        let mut b = Batcher::new(BatchPolicy::default());
        assert!(b.next_batch().is_none());
        assert!(b.drain_batches().is_empty());
    }
}
