//! The online serving scheduler: one queue, pluggable batch-formation
//! policies, tier-aware continuous batching.
//!
//! Before this module, batch formation lived in three places that could
//! not see each other: `serve_all` and the overlap pipeline both sliced
//! requests with fixed `chunks(batch_size)`, and the old `Batcher`'s
//! size-or-timeout queue was wired to nothing. The scheduler collapses
//! them: requests enter a queue stamped with **simulated arrival times**
//! ([`crate::workload::ArrivalGen`]), a release condition (the absorbed
//! size-or-timeout policy of vLLM/HF-TGI, now on a *virtual* clock so
//! timing behavior is deterministic and testable without sleeps) decides
//! *when* a batch leaves, and a [`SchedPolicy`] decides *which* requests
//! ride it:
//!
//! * [`SchedPolicy::Fifo`] — arrival order. With every request arriving
//!   at t = 0 this reproduces the historical `reqs.chunks(batch_size)`
//!   slicing bit-for-bit, which is how [`Engine::serve_all`] and
//!   [`super::overlap::serve_overlapped_with`] stay thin wrappers.
//! * [`SchedPolicy::TierAffinity`] — scores each queued request by how
//!   many of its retrieval top-K chunks will *not* need a storage-device
//!   read: overlap with the hot tier's resident snapshot
//!   ([`crate::kvstore::KvStore::resident_ids`]), with recently-released
//!   batches' chunks (they just filled the tier), and with chunks already
//!   claimed by batchmates (one `load_many` call reads a repeated id
//!   once — splice reuse). Greedy highest-score-first, ties to the
//!   oldest. A **hard age bound** (`max_age_batches`) force-includes any
//!   request passed over that many times, oldest first, so no request
//!   starves behind better-scoring traffic.
//!
//! The whole schedule is planned up front on the virtual clock, so the
//! overlap prefetcher reads upcoming batches' top-K straight from the
//! plan (the scheduler knows the real future) instead of re-running
//! retrieval per batch.
//!
//! [`Engine::serve_all`]: super::engine::Engine::serve_all

use std::collections::{HashMap, HashSet, VecDeque};
use std::sync::{Arc, Mutex};

use anyhow::Result;

use super::engine::{Engine, LoaderCtx, Response, ServeMode};
use super::metrics::PhaseBreakdown;
use super::overlap::{run_pipeline, OverlapOptions, OverlapReport};
use crate::obs::{Counter, Gauge, MetricsRegistry, Sampler};
use crate::trace::{Arg, TraceBus};
use crate::vectordb::ChunkId;
use crate::workload::{RagRequest, TimedRequest};

/// Release-condition knobs (the absorbed `Batcher` policy): a batch
/// leaves the queue when `max_batch` requests are pending, or when the
/// oldest pending request has waited `max_wait_secs` on the virtual
/// clock — whichever comes first.
#[derive(Debug, Clone, Copy)]
pub struct BatchPolicy {
    /// Preferred batch size (rounded up to an AOT bucket by the engine).
    pub max_batch: usize,
    /// Max virtual seconds the oldest queued request may wait before a
    /// partial batch is released.
    pub max_wait_secs: f64,
}

impl Default for BatchPolicy {
    fn default() -> Self {
        BatchPolicy { max_batch: 8, max_wait_secs: 0.050 }
    }
}

/// Batch-formation policy: which pending requests share a batch.
#[derive(Debug, Clone, Copy, PartialEq, Eq, Default)]
pub enum SchedPolicy {
    /// Arrival order (today's `chunks(batch_size)` behavior).
    #[default]
    Fifo,
    /// Tier-affinity scoring with a hard starvation bound: a request
    /// passed over `max_age_batches` times is force-included in the next
    /// batch, oldest first.
    TierAffinity { max_age_batches: usize },
}

/// Per-batch executor-busy model consulted by the planner in place of
/// the flat `service_estimate_secs` knob. The fleet installs one
/// ([`super::fleet::Fleet::service_estimator`]) so the release clock
/// sees realistic per-batch costs — a cache-miss batch occupies the
/// executor longer than a KV-resident one — which is what shapes the
/// continuous-batching backlog under mixed traffic.
pub trait ServiceEstimator: Send + Sync {
    /// Modeled executor-busy virtual seconds for one released batch.
    /// `retrieved[i]` pairs with `reqs[i]` (the planner computes
    /// retrieval whenever an estimator is installed).
    fn batch_secs(&self, reqs: &[RagRequest], retrieved: &[Vec<ChunkId>]) -> f64;
}

/// Scheduler construction knobs.
#[derive(Clone, Default)]
pub struct SchedOptions {
    pub batch: BatchPolicy,
    pub policy: SchedPolicy,
    /// Virtual seconds the executor is modeled busy per released batch.
    /// Arrivals keep landing while a batch "executes", which is what
    /// builds the backlog continuous batching selects from; 0 releases
    /// as soon as the condition fires (the offline/batch-replay shape,
    /// where the whole backlog is visible at t = 0 anyway). Ignored
    /// when `estimator` is set.
    pub service_estimate_secs: f64,
    /// Per-batch service model replacing the flat knob above (forces
    /// retrieval at plan time — the estimate needs the chunk sets).
    pub estimator: Option<Arc<dyn ServiceEstimator>>,
}

impl std::fmt::Debug for SchedOptions {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        f.debug_struct("SchedOptions")
            .field("batch", &self.batch)
            .field("policy", &self.policy)
            .field("service_estimate_secs", &self.service_estimate_secs)
            .field("estimator", &self.estimator.as_ref().map(|_| "per-batch"))
            .finish()
    }
}

/// How recently-released batches count toward the warm set: chunks
/// loaded within this many batches are assumed still resident. A small
/// window approximates LRU recency without simulating eviction.
const RECENT_BATCH_WINDOW: usize = 4;

/// One batch the scheduler has committed to, in release order.
#[derive(Debug, Clone)]
pub struct PlannedBatch {
    pub reqs: Vec<RagRequest>,
    /// Retrieval top-K per request (same order as `reqs`). Populated
    /// (`len == reqs.len()`) when the policy or the overlap prefetcher
    /// needed it at plan time; empty (`len == 0`) otherwise.
    pub retrieved: Vec<Vec<ChunkId>>,
    /// Virtual arrival time per request (same order as `reqs`) — what
    /// the fleet dispatcher diffs against batch completion for the
    /// per-request latency percentiles.
    pub arrivals: Vec<f64>,
    /// Virtual time the release condition fired.
    pub release_secs: f64,
}

impl PlannedBatch {
    /// All chunk ids this batch will splice, element order preserved
    /// (duplicates included — `load_many` collapses them).
    pub fn chunk_ids(&self) -> Vec<ChunkId> {
        self.retrieved.iter().flatten().copied().collect()
    }

    /// The planned per-request top-K, when the plan computed it. Staging
    /// passes this to [`LoaderCtx::stage_matkv_with`] so retrieval runs
    /// once per request, at plan time, not again per batch.
    ///
    /// [`LoaderCtx::stage_matkv_with`]: super::engine::LoaderCtx::stage_matkv_with
    pub fn planned_retrieval(&self) -> Option<&[Vec<ChunkId>]> {
        (!self.retrieved.is_empty()).then_some(self.retrieved.as_slice())
    }
}

/// Queue/policy telemetry of one planning pass.
#[derive(Debug, Clone, Default)]
pub struct SchedReport {
    pub requests: usize,
    pub batches: usize,
    /// Batches released because the queue reached `max_batch`.
    pub full_releases: usize,
    /// Batches released because the oldest request hit `max_wait_secs`.
    pub timeout_releases: usize,
    /// Requests force-included by the starvation age bound.
    pub forced_includes: usize,
    /// Mean / max virtual seconds from arrival to batch release.
    pub mean_wait_secs: f64,
    pub max_wait_secs: f64,
    /// Virtual time of the last release.
    pub makespan_secs: f64,
    /// Real (wall) seconds the planner spent on retrieval. Staging
    /// reuses the planned top-K, so this is where the whole run's
    /// retrieval cost lives when the policy/prefetcher needed it
    /// (`PhaseBreakdown::retrieve_secs` then reads ~0).
    pub plan_retrieve_secs: f64,
}

/// The planned schedule: batches in release order plus queue telemetry.
#[derive(Debug, Clone, Default)]
pub struct Schedule {
    pub batches: Vec<PlannedBatch>,
    pub report: SchedReport,
}

/// Execution strategy for [`Scheduler::run`].
#[derive(Debug, Clone, Default)]
pub struct ExecOptions {
    /// `None`: serve each planned batch to completion before the next
    /// (the classic `serve_all`). `Some`: the §III-C loader/executor
    /// overlap pipeline, optionally with hot-tier prefetch.
    pub overlap: Option<OverlapOptions>,
}

impl ExecOptions {
    pub fn sequential() -> Self {
        ExecOptions { overlap: None }
    }

    pub fn overlapped(opts: OverlapOptions) -> Self {
        ExecOptions { overlap: Some(opts) }
    }
}

/// Everything a scheduled run produces. Responses come back in batch
/// (release) order — identical to request order under [`SchedPolicy::Fifo`]
/// with offline arrivals, reordered under affinity scheduling.
pub struct ServeOutcome {
    pub responses: Vec<Response>,
    pub metrics: PhaseBreakdown,
    pub overlap: OverlapReport,
    pub sched: SchedReport,
}

struct Queued {
    req: RagRequest,
    arrival: f64,
    retrieved: Vec<ChunkId>,
    /// Releases this request was pending for but not selected into
    /// (the starvation-age counter).
    passed_over: usize,
}

/// Registry instruments for the planning loop, installed by
/// [`Scheduler::set_metrics`]. The queue-depth gauge is snapshotted at
/// each release (after the batch leaves the queue), and the sampler —
/// when shared — is advanced to each release's virtual time so queue
/// series stay aligned with the rest of the registry.
struct SchedMetrics {
    queue_depth: Gauge,
    releases: Counter,
    batched_requests: Counter,
    sampler: Option<Arc<Mutex<Sampler>>>,
}

/// The scheduler: a virtual-time request queue plus the release
/// condition and batch-formation policy. Build one, enqueue a trace,
/// then either [`Scheduler::run`] it through an engine or
/// [`Scheduler::plan`] the batches for a custom driver.
pub struct Scheduler {
    ctx: LoaderCtx,
    opts: SchedOptions,
    queue: Vec<Queued>,
    /// Trace handle; planning runs entirely on the virtual clock, so
    /// its lifecycle instants are *clocked* (real trace timestamps).
    trace: TraceBus,
    /// Registry instruments, when attached (see [`Scheduler::set_metrics`]).
    metrics: Option<SchedMetrics>,
}

impl Scheduler {
    pub fn new(ctx: LoaderCtx, opts: SchedOptions) -> Self {
        Scheduler { ctx, opts, queue: Vec::new(), trace: TraceBus::disabled(), metrics: None }
    }

    /// Attach a trace bus: each planned request gets a `queued` instant
    /// at its virtual arrival and each batch a `release` instant at the
    /// time the release condition fired, on the `sched` track.
    pub fn set_trace(&mut self, trace: TraceBus) {
        self.trace = trace;
    }

    /// Register the scheduler's instruments into `reg` under
    /// `matkv.sched.*` and optionally share the registry [`Sampler`]:
    /// planning then advances it to each release's *virtual* time, so
    /// queue-depth samples land on the same aligned grid as every other
    /// registered series. Call once per registry (a second call on the
    /// same registry fails on the duplicate names).
    pub fn set_metrics(
        &mut self,
        reg: &MetricsRegistry,
        sampler: Option<Arc<Mutex<Sampler>>>,
    ) -> Result<()> {
        let queue_depth = reg.gauge(
            "matkv.sched.queue_depth",
            &[],
            "requests pending in the scheduler queue at the last batch release",
        )?;
        let releases = reg.counter("matkv.sched.releases", &[], "batches released by the planner")?;
        let batched_requests = reg.counter(
            "matkv.sched.batched_requests",
            &[],
            "requests placed into released batches",
        )?;
        self.metrics = Some(SchedMetrics { queue_depth, releases, batched_requests, sampler });
        Ok(())
    }

    /// The batch-replay shape the serve wrappers use: FIFO policy,
    /// release as soon as possible, every request arriving at t = 0 —
    /// which reproduces `reqs.chunks(batch_size)` exactly.
    pub fn offline(ctx: LoaderCtx, batch_size: usize) -> Self {
        Scheduler::new(
            ctx,
            SchedOptions {
                batch: BatchPolicy { max_batch: batch_size.max(1), max_wait_secs: 0.0 },
                policy: SchedPolicy::Fifo,
                service_estimate_secs: 0.0,
                estimator: None,
            },
        )
    }

    /// Enqueue one request at a virtual arrival time.
    pub fn enqueue(&mut self, req: RagRequest, arrival_secs: f64) {
        self.queue.push(Queued {
            req,
            arrival: arrival_secs.max(0.0),
            retrieved: Vec::new(),
            passed_over: 0,
        });
    }

    /// Enqueue a batch-replay workload: everything arrives at t = 0.
    pub fn enqueue_now(&mut self, reqs: impl IntoIterator<Item = RagRequest>) {
        for r in reqs {
            self.enqueue(r, 0.0);
        }
    }

    /// Enqueue a timed trace (see [`crate::workload::ArrivalGen`]).
    pub fn enqueue_timed(&mut self, trace: impl IntoIterator<Item = TimedRequest>) {
        for t in trace {
            self.enqueue(t.req, t.arrival_secs);
        }
    }

    pub fn pending(&self) -> usize {
        self.queue.len()
    }

    /// Form the batch schedule, draining the queue. Retrieval top-K is
    /// computed per request only when the policy needs it; use
    /// [`Scheduler::plan_with_retrieval`] when a downstream consumer
    /// (e.g. the overlap prefetcher) wants the per-batch chunk sets
    /// regardless of policy.
    pub fn plan(&mut self) -> Schedule {
        let want = matches!(self.opts.policy, SchedPolicy::TierAffinity { .. });
        self.plan_inner(want)
    }

    /// [`Scheduler::plan`] with retrieval top-K populated on every
    /// planned batch.
    pub fn plan_with_retrieval(&mut self) -> Schedule {
        self.plan_inner(true)
    }

    fn plan_inner(&mut self, want_retrieval: bool) -> Schedule {
        // A per-batch service estimator needs the chunk sets to price a
        // batch, so it forces retrieval at plan time.
        let want_retrieval = want_retrieval || self.opts.estimator.is_some();
        let mut report = SchedReport::default();
        let mut incoming: VecDeque<Queued> = {
            let mut q = std::mem::take(&mut self.queue);
            // stable: equal arrival times keep enqueue order
            q.sort_by(|a, b| a.arrival.total_cmp(&b.arrival));
            if want_retrieval {
                let t0 = std::time::Instant::now();
                for e in &mut q {
                    if e.retrieved.is_empty() {
                        e.retrieved = self.ctx.retrieval.retrieve(&e.req.query, e.req.top_k);
                    }
                }
                report.plan_retrieve_secs = t0.elapsed().as_secs_f64();
            }
            q.into()
        };
        let max_batch = self.opts.batch.max_batch.max(1);
        let max_wait = self.opts.batch.max_wait_secs.max(0.0);
        let service = self.opts.service_estimate_secs.max(0.0);
        let affinity = matches!(self.opts.policy, SchedPolicy::TierAffinity { .. });

        // Warm-set model for affinity scoring: the hot tier's residency
        // snapshot at plan time, plus the chunks of the last
        // RECENT_BATCH_WINDOW planned batches (they fill the tier as
        // they execute; maintained incrementally as a refcounted window,
        // not re-cloned per release). q8 warm-tier residents are scored
        // at a *discount* — they avoid the device read but pay the
        // dequant pass. Advisory — eviction is not simulated.
        let (resident, warm_resident): (HashSet<ChunkId>, HashSet<ChunkId>) = if affinity {
            (
                self.ctx.kv.hot_resident_ids().into_iter().collect(),
                self.ctx.kv.warm_resident_ids().into_iter().collect(),
            )
        } else {
            (HashSet::new(), HashSet::new())
        };
        let mut recent: VecDeque<Vec<ChunkId>> = VecDeque::new();
        let mut recent_counts: HashMap<ChunkId, usize> = HashMap::new();

        let mut pending: VecDeque<Queued> = VecDeque::new();
        let mut batches: Vec<PlannedBatch> = Vec::new();
        let mut waits: Vec<f64> = Vec::new();
        let mut t = 0.0f64;
        let mut t_free = 0.0f64; // executor modeled free again at this time

        loop {
            t = t.max(t_free);
            while incoming.front().is_some_and(|q| q.arrival <= t) {
                pending.push_back(incoming.pop_front().expect("peeked"));
            }
            if pending.is_empty() {
                match incoming.front() {
                    Some(q) => {
                        t = t.max(q.arrival);
                        continue;
                    }
                    None => break,
                }
            }
            if pending.len() < max_batch {
                match incoming.front() {
                    Some(q) => {
                        let deadline = pending.front().expect("non-empty").arrival + max_wait;
                        if q.arrival <= deadline {
                            // another request lands before the timeout:
                            // keep filling instead of releasing short
                            t = t.max(q.arrival);
                            continue;
                        }
                        t = t.max(deadline);
                        report.timeout_releases += 1;
                    }
                    None => {
                        // Trace drained: nothing can ever fill this
                        // batch, so release now rather than charging the
                        // telemetry a phantom max_wait.
                        report.timeout_releases += 1;
                    }
                }
            } else {
                report.full_releases += 1;
            }

            let selected = match self.opts.policy {
                SchedPolicy::Fifo => fifo_select(&mut pending, max_batch),
                SchedPolicy::TierAffinity { max_age_batches } => affinity_select(
                    &mut pending,
                    max_batch,
                    max_age_batches,
                    &resident,
                    &warm_resident,
                    &recent_counts,
                    &mut report,
                ),
            };

            let mut batch_chunks: Vec<ChunkId> = Vec::new();
            let mut reqs = Vec::with_capacity(selected.len());
            let mut retrieved = Vec::with_capacity(selected.len());
            let mut arrivals = Vec::with_capacity(selected.len());
            for q in selected {
                waits.push(t - q.arrival);
                arrivals.push(q.arrival);
                if affinity {
                    batch_chunks.extend(q.retrieved.iter().copied());
                }
                reqs.push(q.req);
                if want_retrieval {
                    retrieved.push(q.retrieved);
                }
            }
            if affinity {
                for &id in &batch_chunks {
                    *recent_counts.entry(id).or_insert(0) += 1;
                }
                recent.push_back(batch_chunks);
                if recent.len() > RECENT_BATCH_WINDOW {
                    for id in recent.pop_front().expect("len checked") {
                        if let Some(c) = recent_counts.get_mut(&id) {
                            *c -= 1;
                            if *c == 0 {
                                recent_counts.remove(&id);
                            }
                        }
                    }
                }
            }
            // Per-batch cost estimate when a model is installed; the
            // flat knob otherwise.
            let batch_service = match &self.opts.estimator {
                Some(est) => est.batch_secs(&reqs, &retrieved).max(0.0),
                None => service,
            };
            if self.trace.enabled() {
                for (r, &a) in reqs.iter().zip(&arrivals) {
                    self.trace.instant("sched", "queued", a, &[("req", Arg::U(r.id))]);
                }
                self.trace.instant(
                    "sched",
                    "release",
                    t,
                    &[
                        ("batch", Arg::U(batches.len() as u64)),
                        ("n", Arg::U(reqs.len() as u64)),
                    ],
                );
            }
            if let Some(m) = &self.metrics {
                m.queue_depth.set((pending.len() + incoming.len()) as f64);
                m.releases.inc();
                m.batched_requests.add(reqs.len() as u64);
                if let Some(s) = &m.sampler {
                    s.lock().unwrap().advance_to(t);
                }
            }
            batches.push(PlannedBatch { reqs, retrieved, arrivals, release_secs: t });
            t_free = t + batch_service;
        }

        report.requests = waits.len();
        report.batches = batches.len();
        report.mean_wait_secs = if waits.is_empty() {
            0.0
        } else {
            waits.iter().sum::<f64>() / waits.len() as f64
        };
        report.max_wait_secs = waits.iter().fold(0.0f64, |a, &b| a.max(b));
        report.makespan_secs = batches.last().map(|b| b.release_secs).unwrap_or(0.0);
        Schedule { batches, report }
    }

    /// Plan the schedule exactly as [`Scheduler::run`] would for `exec`:
    /// retrieval is computed when the policy needs it, when the overlap
    /// prefetcher will read it from the plan, or when a per-batch
    /// service estimator is installed. Drains the queue; the caller can
    /// inspect or fleet-dispatch the plan before (and independently of)
    /// executing it with [`execute_schedule`].
    pub fn plan_for_exec(&mut self, exec: &ExecOptions) -> Schedule {
        let want_retrieval = matches!(self.opts.policy, SchedPolicy::TierAffinity { .. })
            || exec.overlap.as_ref().is_some_and(|o| o.prefetch);
        self.plan_inner(want_retrieval)
    }

    /// Plan the schedule and drive it through `engine`: sequentially
    /// (each batch to completion) or through the overlap pipeline — in
    /// which case the prefetcher warms upcoming batches from the plan's
    /// retrieval sets rather than re-running retrieval.
    pub fn run(mut self, engine: &Engine, mode: ServeMode, exec: &ExecOptions) -> Result<ServeOutcome> {
        let schedule = self.plan_for_exec(exec);
        execute_schedule(engine, &schedule, mode, exec)
    }
}

/// Drive a planned schedule through `engine` — the execution half of
/// [`Scheduler::run`], split out so callers that need the plan itself
/// (the CLI's fleet report dispatches the very schedule it executes)
/// don't plan twice.
pub fn execute_schedule(
    engine: &Engine,
    schedule: &Schedule,
    mode: ServeMode,
    exec: &ExecOptions,
) -> Result<ServeOutcome> {
    let (responses, metrics, overlap) = match &exec.overlap {
        Some(opts) => run_pipeline(engine, &schedule.batches, mode, opts)?,
        None => {
            let ctx = engine.loader_ctx();
            let mut responses =
                Vec::with_capacity(schedule.batches.iter().map(|b| b.reqs.len()).sum());
            let mut agg = PhaseBreakdown::default();
            for b in &schedule.batches {
                // Reuse the plan's retrieval when it was computed;
                // staging must not pay for the search twice.
                let staged = match mode {
                    ServeMode::Vanilla => ctx.stage_vanilla_with(&b.reqs, b.planned_retrieval())?,
                    ServeMode::MatKv | ServeMode::CacheBlend { .. } => {
                        ctx.stage_matkv_with(&b.reqs, b.planned_retrieval())?
                    }
                };
                let (r, m) = engine.exec_staged(staged, mode)?;
                responses.extend(r);
                agg.add(&m);
            }
            let report = OverlapReport { batches: schedule.batches.len(), ..Default::default() };
            (responses, agg, report)
        }
    };
    Ok(ServeOutcome { responses, metrics, overlap, sched: schedule.report.clone() })
}

/// Arrival order, oldest first.
fn fifo_select(pending: &mut VecDeque<Queued>, max_batch: usize) -> Vec<Queued> {
    let n = pending.len().min(max_batch);
    pending.drain(..n).collect()
}

/// Tier-affinity selection. `pending` is arrival-ordered; overdue
/// requests (starvation bound) are taken first, oldest first, then the
/// remaining slots fill greedily by a weighted score of the request's
/// chunks that need no device read: **2 points** for a full-value save
/// (hot-resident snapshot ∪ recent-batch window ∪ chunks batchmates
/// already claimed) and **1 point** for a q8 warm-tier resident, which
/// skips the device but pays a dequant pass on promotion. Ties go to
/// the oldest request.
fn affinity_select(
    pending: &mut VecDeque<Queued>,
    max_batch: usize,
    max_age_batches: usize,
    resident: &HashSet<ChunkId>,
    warm_resident: &HashSet<ChunkId>,
    recent: &HashMap<ChunkId, usize>,
    report: &mut SchedReport,
) -> Vec<Queued> {
    let n = pending.len().min(max_batch);
    let mut selected: Vec<Queued> = Vec::with_capacity(n);
    let mut batch_chunks: HashSet<ChunkId> = HashSet::new();

    // 1. Hard age bound: anything passed over max_age_batches times
    //    rides this batch, oldest first (front-to-back scan).
    let mut i = 0;
    while i < pending.len() && selected.len() < n {
        if pending[i].passed_over >= max_age_batches {
            let q = pending.remove(i).expect("index checked");
            batch_chunks.extend(q.retrieved.iter().copied());
            report.forced_includes += 1;
            selected.push(q);
        } else {
            i += 1;
        }
    }

    // 2. Greedy affinity fill. Strict-greater replacement keeps ties on
    //    the oldest request (pending is arrival-ordered).
    while selected.len() < n && !pending.is_empty() {
        let score_of = |q: &Queued| {
            q.retrieved
                .iter()
                .map(|id| {
                    if resident.contains(id)
                        || recent.contains_key(id)
                        || batch_chunks.contains(id)
                    {
                        2
                    } else if warm_resident.contains(id) {
                        1 // device read avoided, dequant still owed
                    } else {
                        0
                    }
                })
                .sum::<usize>()
        };
        let mut best = 0usize;
        let mut best_score = score_of(&pending[0]);
        for j in 1..pending.len() {
            let score = score_of(&pending[j]);
            if score > best_score {
                best = j;
                best_score = score;
            }
        }
        let q = pending.remove(best).expect("index checked");
        batch_chunks.extend(q.retrieved.iter().copied());
        selected.push(q);
    }

    for q in pending.iter_mut() {
        q.passed_over += 1;
    }
    selected
}

#[cfg(test)]
mod tests {
    use std::sync::Arc;

    use super::*;
    use crate::coordinator::engine::{EngineOptions, Retrieval};
    use crate::hwsim::StorageProfile;
    use crate::kvstore::store::config_id;
    use crate::kvstore::{KvChunk, KvStore};
    use crate::manifest::Manifest;
    use crate::util::tempdir::TempDir;
    use crate::vectordb::VectorIndex;
    use crate::workload::{ArrivalGen, Corpus, RagRequest, RequestGen, TurboRagProfile};

    const DOC_TOKENS: usize = 256;

    /// A loader context over the golden metadata manifest: the real
    /// retrieval stack ([`Retrieval::for_corpus`], exactly what
    /// `Engine::new` builds) and a real tiered store, no PJRT anywhere.
    fn golden_ctx(
        corpus: &Corpus,
        hot_tier_bytes: usize,
        shards: usize,
    ) -> (TempDir, LoaderCtx) {
        let m = Manifest::load_or_golden().expect("golden manifest");
        let opts = EngineOptions::for_config(&m, "tiny").unwrap();
        let cfg = m.config("tiny").unwrap().clone();
        let retrieval =
            Arc::new(Retrieval::for_corpus(corpus.texts(), cfg.vocab as u32, opts.embed_dim));
        let dir = TempDir::new("matkv-sched-test").unwrap();
        let mut kv = KvStore::open_sharded(dir.path(), StorageProfile::dram(), shards).unwrap();
        kv.disable_throttle();
        kv.set_hot_tier(hot_tier_bytes);
        {
            let mut ix = retrieval.index.write().unwrap();
            for d in &corpus.docs {
                let (ids, _) = retrieval.tokenizer.encode_block(&d.text, DOC_TOKENS);
                ix.insert(d.id, retrieval.embedder.embed(&ids));
                kv.store_sync(d.id, &golden_chunk(&cfg)).unwrap();
            }
        }
        (dir, LoaderCtx { retrieval, kv: Arc::new(kv), cfg, opts })
    }

    /// A chunk whose dims match the golden tiny config, so
    /// `stage_matkv` can splice it.
    fn golden_chunk(cfg: &crate::manifest::ModelConfig) -> KvChunk {
        let plane = cfg.n_layers * cfg.n_kv_heads * DOC_TOKENS * cfg.head_dim;
        KvChunk {
            config_id: config_id(cfg),
            n_layers: cfg.n_layers as u32,
            n_kv_heads: cfg.n_kv_heads as u32,
            seq_len: DOC_TOKENS as u32,
            head_dim: cfg.head_dim as u32,
            k: vec![1.0; plane],
            v: vec![-1.0; plane],
        }
    }

    fn req(id: u64, topic: usize) -> RagRequest {
        RagRequest {
            id,
            query: format!("query {topic}"),
            top_k: 1,
            output_tokens: 4,
            topic,
        }
    }

    fn sched(ctx: LoaderCtx, batch: usize, policy: SchedPolicy) -> Scheduler {
        Scheduler::new(
            ctx,
            SchedOptions {
                batch: BatchPolicy { max_batch: batch, max_wait_secs: 0.0 },
                policy,
                service_estimate_secs: 0.0,
                estimator: None,
            },
        )
    }

    #[test]
    fn fifo_offline_reproduces_chunks_batching() {
        let corpus = Corpus::generate(8, 64, 8, 1);
        let (_d, ctx) = golden_ctx(&corpus, 0, 1);
        let mut gen = RequestGen::new(TurboRagProfile::default(), 8, 1.0, 7);
        let reqs = gen.take(&corpus, 10);
        let mut s = Scheduler::offline(ctx, 4);
        s.enqueue_now(reqs.iter().cloned());
        let plan = s.plan();
        // bit-for-bit the reqs.chunks(4) slicing
        let want: Vec<Vec<u64>> =
            reqs.chunks(4).map(|c| c.iter().map(|r| r.id).collect()).collect();
        let got: Vec<Vec<u64>> =
            plan.batches.iter().map(|b| b.reqs.iter().map(|r| r.id).collect()).collect();
        assert_eq!(got, want);
        assert_eq!(plan.report.requests, 10);
        assert_eq!(plan.report.batches, 3);
        assert_eq!(plan.report.max_wait_secs, 0.0);
        // fifo without prefetch needs no retrieval
        assert!(plan.batches.iter().all(|b| b.retrieved.iter().all(Vec::is_empty)));
    }

    #[test]
    fn timeout_release_is_deterministic_on_the_virtual_clock() {
        // The old Batcher test slept 10ms of wall time and hoped; the
        // scheduler's clock is injected via arrival stamps, so the
        // timeout release is exact.
        let corpus = Corpus::generate(4, 64, 4, 1);
        let (_d, ctx) = golden_ctx(&corpus, 0, 1);
        let mut s = Scheduler::new(
            ctx,
            SchedOptions {
                batch: BatchPolicy { max_batch: 8, max_wait_secs: 0.005 },
                policy: SchedPolicy::Fifo,
                service_estimate_secs: 0.0,
                estimator: None,
            },
        );
        s.enqueue(req(0, 0), 0.0);
        s.enqueue(req(1, 1), 10.0); // far past the first deadline
        let plan = s.plan();
        assert_eq!(plan.batches.len(), 2, "timeout must release a partial batch");
        assert_eq!(plan.batches[0].reqs[0].id, 0);
        // batch 0 waits out the deadline (a future arrival existed);
        // batch 1 releases at its arrival — the trace is drained, so no
        // phantom max_wait is charged.
        assert!((plan.batches[0].release_secs - 0.005).abs() < 1e-12);
        assert!((plan.batches[1].release_secs - 10.0).abs() < 1e-12);
        assert_eq!(plan.report.timeout_releases, 2);
        assert_eq!(plan.report.full_releases, 0);
        assert!((plan.report.max_wait_secs - 0.005).abs() < 1e-12);
    }

    #[test]
    fn size_release_fires_before_timeout() {
        let corpus = Corpus::generate(4, 64, 4, 1);
        let (_d, ctx) = golden_ctx(&corpus, 0, 1);
        let mut s = Scheduler::new(
            ctx,
            SchedOptions {
                batch: BatchPolicy { max_batch: 3, max_wait_secs: 60.0 },
                policy: SchedPolicy::Fifo,
                service_estimate_secs: 0.0,
                estimator: None,
            },
        );
        for i in 0..3 {
            s.enqueue(req(i, i as usize), 0.001 * i as f64);
        }
        let plan = s.plan();
        assert_eq!(plan.batches.len(), 1);
        assert_eq!(plan.report.full_releases, 1);
        assert!((plan.batches[0].release_secs - 0.002).abs() < 1e-12);
    }

    #[test]
    fn service_estimate_builds_backlog() {
        // 10 requests arriving 1ms apart, 5ms service per batch of 2:
        // the executor falls behind and later batches release back to
        // back at the service cadence.
        let corpus = Corpus::generate(4, 64, 4, 1);
        let (_d, ctx) = golden_ctx(&corpus, 0, 1);
        let mut s = Scheduler::new(
            ctx,
            SchedOptions {
                batch: BatchPolicy { max_batch: 2, max_wait_secs: 0.1 },
                policy: SchedPolicy::Fifo,
                service_estimate_secs: 0.005,
                estimator: None,
            },
        );
        for i in 0..10u64 {
            s.enqueue(req(i, 0), 0.001 * i as f64);
        }
        let plan = s.plan();
        assert_eq!(plan.batches.len(), 5);
        for w in plan.batches.windows(2) {
            assert!(
                w[1].release_secs - w[0].release_secs >= 0.005 - 1e-12,
                "releases must respect the service estimate"
            );
        }
        assert!(plan.report.mean_wait_secs > 0.0);
    }

    #[test]
    fn per_batch_estimator_replaces_flat_service_knob() {
        // An estimator pricing each batch by its size: releases must be
        // spaced by the per-batch estimate (0.004s/request), the flat
        // knob must be ignored, and retrieval must be forced so the
        // estimator sees the chunk sets.
        struct PerRequest;
        impl ServiceEstimator for PerRequest {
            fn batch_secs(&self, reqs: &[RagRequest], retrieved: &[Vec<ChunkId>]) -> f64 {
                assert_eq!(retrieved.len(), reqs.len(), "estimator must see retrieval");
                0.004 * reqs.len() as f64
            }
        }
        let corpus = Corpus::generate(4, 64, 4, 1);
        let (_d, ctx) = golden_ctx(&corpus, 0, 1);
        let mut s = Scheduler::new(
            ctx,
            SchedOptions {
                batch: BatchPolicy { max_batch: 2, max_wait_secs: 0.0 },
                policy: SchedPolicy::Fifo,
                service_estimate_secs: 99.0, // must be ignored
                estimator: Some(Arc::new(PerRequest)),
            },
        );
        for i in 0..5u64 {
            s.enqueue(req(i, 0), 0.0);
        }
        let plan = s.plan();
        assert_eq!(plan.batches.len(), 3); // 2 + 2 + 1
        // batch 0 at 0, then +0.008 per full batch released before it
        assert!((plan.batches[0].release_secs - 0.0).abs() < 1e-12);
        assert!((plan.batches[1].release_secs - 0.008).abs() < 1e-12);
        assert!((plan.batches[2].release_secs - 0.016).abs() < 1e-12);
        // forced retrieval populated every batch
        assert!(plan.batches.iter().all(|b| b.retrieved.len() == b.reqs.len()));
    }

    #[test]
    fn planned_batches_carry_arrivals() {
        let corpus = Corpus::generate(4, 64, 4, 1);
        let (_d, ctx) = golden_ctx(&corpus, 0, 1);
        let mut s = Scheduler::new(
            ctx,
            SchedOptions {
                batch: BatchPolicy { max_batch: 2, max_wait_secs: 0.001 },
                policy: SchedPolicy::Fifo,
                service_estimate_secs: 0.0,
                estimator: None,
            },
        );
        s.enqueue(req(0, 0), 0.000);
        s.enqueue(req(1, 1), 0.0005);
        s.enqueue(req(2, 2), 1.0);
        let plan = s.plan();
        assert_eq!(plan.batches.len(), 2);
        assert_eq!(plan.batches[0].arrivals, vec![0.000, 0.0005]);
        assert_eq!(plan.batches[1].arrivals, vec![1.0]);
        for b in &plan.batches {
            assert_eq!(b.arrivals.len(), b.reqs.len());
            for &a in &b.arrivals {
                assert!(a <= b.release_secs + 1e-12, "arrival after release");
            }
        }
    }

    #[test]
    fn affinity_groups_chunk_sharers() {
        // Interleaved topics A,B,A,B,... (identical query per topic, so
        // retrieval is identical within a topic) — affinity must reorder
        // the batch stream into chunk-pure batches via the pairwise
        // sharing term, while fifo keeps them interleaved.
        let corpus = Corpus::generate(8, 64, 8, 1);
        let (_d, ctx) = golden_ctx(&corpus, 64 << 20, 1);
        let mut rng = crate::workload::Rng::new(5);
        let qa = corpus.query_for_topic(0, 12, &mut rng);
        let qb = corpus.query_for_topic(3, 12, &mut rng);
        assert_ne!(
            ctx.retrieval.retrieve(&qa, 1),
            ctx.retrieval.retrieve(&qb, 1),
            "test needs two queries with distinct top-1 chunks"
        );
        let reqs: Vec<RagRequest> = (0..8)
            .map(|i| RagRequest {
                id: i,
                query: if i % 2 == 0 { qa.clone() } else { qb.clone() },
                top_k: 1,
                output_tokens: 2,
                topic: (i % 2) as usize,
            })
            .collect();

        let mut s = sched(ctx, 4, SchedPolicy::TierAffinity { max_age_batches: 64 });
        s.enqueue_now(reqs.iter().cloned());
        let plan = s.plan();
        assert_eq!(plan.batches.len(), 2);
        for b in &plan.batches {
            let chunk_sets: HashSet<Vec<ChunkId>> = b.retrieved.iter().cloned().collect();
            assert_eq!(chunk_sets.len(), 1, "affinity batch mixes chunk sets: {:?}", b.retrieved);
        }
        // every request served exactly once despite the reorder
        let mut ids: Vec<u64> =
            plan.batches.iter().flat_map(|b| b.reqs.iter().map(|r| r.id)).collect();
        ids.sort_unstable();
        assert_eq!(ids, (0..8).collect::<Vec<_>>());
    }

    #[test]
    fn affinity_scores_warm_residents_at_a_discount() {
        // Score ladder: hot-resident (2) > warm-resident (1) > cold (0).
        // A warm hit skips the device read but still owes the dequant
        // pass, so it must rank between the other two.
        let mk = |id: u64, retrieved: Vec<ChunkId>| Queued {
            req: req(id, 0),
            arrival: 0.0,
            retrieved,
            passed_over: 0,
        };
        let resident: HashSet<ChunkId> = [100].into_iter().collect();
        let warm: HashSet<ChunkId> = [200].into_iter().collect();
        let recent = HashMap::new();
        let mut report = SchedReport::default();
        // enqueue coldest first so greedy (not FIFO) order is observable
        let mut pending: VecDeque<Queued> =
            vec![mk(0, vec![300]), mk(1, vec![200]), mk(2, vec![100])].into();
        for want in [2u64, 1, 0] {
            let sel = affinity_select(
                &mut pending,
                1,
                usize::MAX,
                &resident,
                &warm,
                &recent,
                &mut report,
            );
            assert_eq!(sel.len(), 1);
            assert_eq!(sel[0].req.id, want, "selection order must follow the score ladder");
        }
        assert_eq!(report.forced_includes, 0);
    }

    #[test]
    fn starvation_bound_forces_release() {
        // One cold request against a stream of warm ones: pure affinity
        // would defer it to the very last batch; the age bound pulls it
        // into a batch no later than max_age_batches releases after it
        // became eligible.
        let corpus = Corpus::generate(8, 64, 8, 1);
        let (_d, ctx) = golden_ctx(&corpus, 64 << 20, 1);
        // Warm the tier with topic 0's chunk so the warm stream scores
        // above the cold request from the very first batch.
        let mut rng = crate::workload::Rng::new(6);
        let warm_query = corpus.query_for_topic(0, 12, &mut rng);
        let warm_ids = ctx.retrieval.retrieve(&warm_query, 1);
        ctx.kv.load_many(&warm_ids).unwrap();

        // first topic whose top-1 chunk differs from the warm one
        // (retrieval is topical but not perfect; scan instead of hoping)
        let cold_query = (1..corpus.n_topics)
            .map(|topic| corpus.query_for_topic(topic, 12, &mut rng))
            .find(|q| ctx.retrieval.retrieve(q, 1) != warm_ids)
            .expect("some topic must retrieve a different chunk");

        let build = |max_age: usize, ctx: LoaderCtx| {
            let mut s = sched(ctx, 2, SchedPolicy::TierAffinity { max_age_batches: max_age });
            // cold request enqueued FIRST: fifo would serve it at once
            s.enqueue(
                RagRequest {
                    id: 99,
                    query: cold_query.clone(),
                    top_k: 1,
                    output_tokens: 2,
                    topic: 5,
                },
                0.0,
            );
            for i in 0..10u64 {
                s.enqueue(
                    RagRequest {
                        id: i,
                        query: warm_query.clone(),
                        top_k: 1,
                        output_tokens: 2,
                        topic: 0,
                    },
                    0.0,
                );
            }
            s.plan()
        };

        // effectively unbounded age: the cold request starves to the end
        let (_d2, ctx2) = golden_ctx(&corpus, 64 << 20, 1);
        ctx2.kv.load_many(&warm_ids).unwrap();
        let lax = build(usize::MAX, ctx2);
        let last = lax.batches.last().unwrap();
        assert!(
            last.reqs.iter().any(|r| r.id == 99),
            "without the bound the cold request should sort last"
        );

        // tight bound: released within max_age batches
        let tight = build(2, ctx);
        let pos = tight
            .batches
            .iter()
            .position(|b| b.reqs.iter().any(|r| r.id == 99))
            .expect("cold request must be served");
        assert!(pos <= 2, "age bound violated: released in batch {pos}");
        assert!(tight.report.forced_includes >= 1);
    }

    #[test]
    fn online_loop_stages_end_to_end_against_golden_manifest() {
        // Queue → policy → staging, over the golden metadata manifest:
        // a Poisson/Zipf trace is planned under tier affinity and every
        // planned batch is staged through the real loader path (tiered
        // sharded store, host-state splice) — no PJRT anywhere.
        let corpus = Corpus::generate(12, 64, 12, 3);
        let (_d, ctx) = golden_ctx(&corpus, 32 << 20, 2);
        let mut gen = ArrivalGen::new(
            TurboRagProfile { top_k: 2, query_tokens: 12.0, output_tokens: 4 },
            corpus.n_topics,
            1.1,
            200.0,
            9,
        );
        let trace = gen.take(&corpus, 24);
        let mut s = Scheduler::new(
            ctx.clone(),
            SchedOptions {
                batch: BatchPolicy { max_batch: 4, max_wait_secs: 0.02 },
                policy: SchedPolicy::TierAffinity { max_age_batches: 4 },
                service_estimate_secs: 0.01,
                estimator: None,
            },
        );
        s.enqueue_timed(trace);
        let plan = s.plan();
        assert_eq!(plan.report.requests, 24);
        let mut staged_reqs = 0;
        let mut agg = PhaseBreakdown::default();
        for b in &plan.batches {
            assert!(!b.reqs.is_empty() && b.reqs.len() <= 4);
            assert_eq!(b.reqs.len(), b.retrieved.len());
            let staged = ctx.stage_matkv(&b.reqs).unwrap();
            assert_eq!(staged.ids.len(), b.reqs.len());
            // the plan's retrieval matches what staging retrieves
            assert_eq!(staged.retrieved, b.retrieved);
            staged_reqs += staged.ids.len();
            agg.add(&staged.metrics);
        }
        assert_eq!(staged_reqs, 24);
        assert_eq!(agg.loaded_tokens, 24 * 2 * DOC_TOKENS);
        // device reads + tier/splice reuse account for every chunk load
        assert_eq!(agg.load_reads + agg.cache_hits, 24 * 2);
        assert!(agg.cache_hits > 0, "skewed repeat traffic must reuse the tier");
        assert_eq!(agg.shard_reads.iter().sum::<u64>() as usize, agg.load_reads);
    }

    #[test]
    fn fleet_dispatch_deterministic_on_poisson_zipf_trace() {
        // Satellite: same fixed Poisson×Zipf trace + same fleet spec →
        // identical per-worker assignment and identical p50/p95/p99 on
        // the virtual clock, run to run (the whole pipeline — arrivals,
        // plan, dispatch — is deterministic by construction).
        use crate::coordinator::fleet::{Fleet, FleetCostModel, FleetSpec, Routing};
        use crate::hwsim::{ArchSpec, StorageProfile};
        let corpus = Corpus::generate(16, 64, 16, 3);
        let (_d, ctx) = golden_ctx(&corpus, 32 << 20, 1);
        let mut gen = ArrivalGen::new(
            TurboRagProfile { top_k: 2, query_tokens: 12.0, output_tokens: 4 },
            corpus.n_topics,
            1.1,
            150.0,
            9,
        );
        let trace = gen.take(&corpus, 32);
        let mut s = Scheduler::new(
            ctx.clone(),
            SchedOptions {
                batch: BatchPolicy { max_batch: 4, max_wait_secs: 0.02 },
                policy: SchedPolicy::Fifo,
                service_estimate_secs: 0.0,
                estimator: None,
            },
        );
        s.enqueue_timed(trace);
        let plan = s.plan_with_retrieval();
        let model = FleetCostModel {
            arch: ArchSpec::llama_70b(),
            storage: StorageProfile::ssd_9100pro(),
            chunk_tokens: DOC_TOKENS,
            query_tokens: 12,
            chunk_step: 256,
        };
        let run = || {
            let mut fleet = Fleet::new(
                &FleetSpec::parse("h100:1,rtx4090:3").unwrap(),
                Routing::RoleAware,
                model.clone(),
            );
            fleet.seed_resident(&ctx.kv.resident_set());
            fleet.dispatch(&plan.batches, &|id| ctx.kv.contains(id))
        };
        let (a, b) = (run(), run());
        assert_eq!(a.assignments, b.assignments, "per-worker assignment must replay");
        assert_eq!(a.latency, b.latency, "percentiles must replay");
        assert!(a.latency.p50 <= a.latency.p95 && a.latency.p95 <= a.latency.p99);
        assert!(a.latency.p99 > 0.0, "completions happen strictly after arrivals");
        assert_eq!(a.requests, 32, "every queued request dispatched exactly once");
    }

    #[test]
    fn faulted_fleet_dispatch_replays_bit_identically_on_poisson_zipf_trace() {
        // Satellite: same seed + same fault plan ⇒ the same
        // degradation schedule, bit for bit — the fleet-determinism
        // contract extended to the failure path. One flash shard is
        // dead from the start and a decode card crashes mid-trace;
        // every request still completes, on the recompute safety net
        // and the surviving workers.
        use crate::coordinator::fleet::{Fleet, FleetCostModel, FleetSpec, Routing};
        use crate::hwsim::{ArchSpec, FaultPlan, StorageProfile};
        let corpus = Corpus::generate(16, 64, 16, 3);
        let (_d, ctx) = golden_ctx(&corpus, 32 << 20, 2);
        let mut gen = ArrivalGen::new(
            TurboRagProfile { top_k: 2, query_tokens: 12.0, output_tokens: 4 },
            corpus.n_topics,
            1.1,
            150.0,
            9,
        );
        let trace = gen.take(&corpus, 32);
        let mut s = Scheduler::new(
            ctx.clone(),
            SchedOptions {
                batch: BatchPolicy { max_batch: 4, max_wait_secs: 0.02 },
                policy: SchedPolicy::Fifo,
                service_estimate_secs: 0.0,
                estimator: None,
            },
        );
        s.enqueue_timed(trace);
        let plan = s.plan_with_retrieval();
        let model = FleetCostModel {
            arch: ArchSpec::llama_70b(),
            storage: StorageProfile::ssd_9100pro(),
            chunk_tokens: DOC_TOKENS,
            query_tokens: 12,
            chunk_step: 256,
        };
        let fault = Arc::new(FaultPlan::parse("seed=5,shard0:die@0,worker3:crash@0.05").unwrap());
        let run = || {
            let mut fleet = Fleet::new(
                &FleetSpec::parse("h100:1,rtx4090:3").unwrap(),
                Routing::RoleAware,
                model.clone(),
            );
            fleet.seed_resident(&ctx.kv.resident_set());
            fleet.set_faults(fault.clone());
            let (kv, plan_ref) = (ctx.kv.clone(), fault.clone());
            fleet.set_lost_chunks(Arc::new(move |id| plan_ref.shard_dead(kv.shard_index_of(id))));
            fleet.dispatch(&plan.batches, &|id| ctx.kv.contains(id))
        };
        let (a, b) = (run(), run());
        assert_eq!(a.assignments, b.assignments, "faulted assignment trail must replay");
        assert_eq!(a.latency, b.latency, "faulted percentiles must replay");
        assert_eq!(a.makespan_secs, b.makespan_secs);
        assert_eq!(a.requests, 32, "zero failed requests under faults");
        assert!(a.metrics.recomputed_chunks > 0, "the dead shard's chunks must recompute");
        assert!(a.metrics.degraded_tokens > 0);
        assert!(a.metrics.recompute_fallback_secs > 0.0);
    }

    #[test]
    fn affinity_reads_no_more_than_fifo_on_skewed_replay() {
        // The co-design claim at unit scale: same trace, same store
        // shape, equal batch size — affinity's schedule must touch the
        // device no more than fifo's, and with many topics cycling
        // through a small tier it should be strictly better.
        let corpus = Corpus::generate(32, 64, 32, 4);
        let tier_bytes = 8 * golden_chunk(
            &Manifest::load_or_golden().unwrap().config("tiny").unwrap().clone(),
        )
        .dram_bytes();
        let mut gen = ArrivalGen::new(
            TurboRagProfile { top_k: 1, query_tokens: 12.0, output_tokens: 2 },
            corpus.n_topics,
            0.0, // uniform topics: worst case for an LRU, best for grouping
            0.0, // offline: full backlog visible, both policies see it
            11,
        );
        let trace = gen.take(&corpus, 96);
        let mut reads = Vec::new();
        let mut hits = Vec::new();
        for policy in [
            SchedPolicy::Fifo,
            SchedPolicy::TierAffinity { max_age_batches: 16 },
        ] {
            let (_d, ctx) = golden_ctx(&corpus, tier_bytes, 1);
            let mut s = sched(ctx.clone(), 8, policy);
            s.enqueue_timed(trace.clone());
            let plan = s.plan_with_retrieval();
            for b in &plan.batches {
                ctx.kv.load_many(&b.chunk_ids()).unwrap();
            }
            reads.push(ctx.kv.stats.reads.load(std::sync::atomic::Ordering::Relaxed));
            let loaded: u64 = plan.batches.iter().map(|b| b.chunk_ids().len() as u64).sum();
            hits.push(loaded - reads.last().unwrap());
        }
        assert!(
            reads[1] < reads[0],
            "affinity must save device reads: fifo {} vs affinity {}",
            reads[0],
            reads[1]
        );
        assert!(hits[1] > hits[0], "affinity must reuse more: {hits:?}");
    }
}
