//! The MatKV coordinator — the paper's system contribution (L3).
//!
//! * [`ingest`] — document ingestion: chunk → embed → vector-DB insert,
//!   prefill on the device, materialize the KV cache to flash
//!   (write-behind), Fig 3a.
//! * [`engine`] — the serve path, Fig 3b: retrieve top-K → **load**
//!   materialized KVs (MatKV) *or* recompute them (Vanilla baseline) →
//!   query sub-prefill → batched greedy decode.
//! * [`scheduler`] — the online serving scheduler: one request queue
//!   with simulated arrival times, the size-or-timeout release condition
//!   (absorbed from the old `Batcher`) on a deterministic virtual clock,
//!   and pluggable batch-formation policies (FIFO, tier affinity with a
//!   starvation bound). `serve_all` and `serve_overlapped_with` are thin
//!   wrappers over it.
//! * [`overlap`] — the §III-C optimization: a loader thread stages batch
//!   n+1's KVs from flash while the device decodes batch n; the
//!   prefetcher warms upcoming batches straight from the scheduler's
//!   plan.
//! * [`fleet`] — the heterogeneous device fleet: N simulated GPU
//!   workers (serving-catalog profiles + per-worker energy meters)
//!   consuming the scheduler's planned batches on the virtual clock,
//!   with pluggable routing (round-robin / role-aware) and an explicit
//!   host→device KV transfer charge — the paper's low-end-decode
//!   premise (Fig 10) at serving scale.
//! * [`baselines`] — the CacheBlend-style partial-recompute comparator.
//! * [`metrics`] — per-phase latency breakdown + simulated device costs.

pub mod baselines;
pub mod engine;
pub mod experiments;
pub mod fleet;
pub mod ingest;
pub mod metrics;
pub mod overlap;
pub mod scheduler;

pub use engine::{Engine, EngineOptions, Response, ServeMode};
pub use fleet::{
    BatchCost, BatchWork, Fleet, FleetCostModel, FleetReport, FleetSpec, Role, Routing,
    WorkerReport,
};
pub use ingest::{IngestStats, Ingestor};
pub use metrics::{LatencySummary, LogHistogram, PhaseBreakdown, Percentiles};
pub use experiments::{Scenario, ScenarioSpec};
pub use overlap::{serve_overlapped, serve_overlapped_with, OverlapOptions, OverlapReport};
pub use scheduler::{
    execute_schedule, BatchPolicy, ExecOptions, PlannedBatch, SchedOptions, SchedPolicy,
    SchedReport, Schedule, Scheduler, ServeOutcome, ServiceEstimator,
};
