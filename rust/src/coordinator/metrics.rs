//! Per-phase latency/cost accounting.
//!
//! Every serve path reports **measured wall-clock** on this testbed (CPU
//! PJRT + simulated storage device) *and* an architecture-independent
//! **work trace** of what was executed (live tokens appended, live
//! context attended, device invocations). The benches cost that same
//! trace under the real LLaMA architecture each config stands in for
//! ([`crate::hwsim::standin::ArchSpec`]) — this is how the paper's
//! H100-scale figures are regenerated without distorting the
//! compute-vs-IO crossovers (FLOPs shrink quadratically with model width
//! but KV bytes only linearly, so costing our scaled configs directly
//! would flip every crossover; see DESIGN.md "Substitutions").

use crate::hwsim::profiles::{DeviceProfile, StorageProfile};
use crate::hwsim::standin::ArchSpec;
use crate::hwsim::Link;

/// Architecture-independent record of executed transformer work.
#[derive(Debug, Clone, Copy, Default, PartialEq)]
pub struct WorkTrace {
    /// Σ live tokens appended (over batch elements and steps).
    pub sum_s: f64,
    /// Σ (live tokens × live context) — the attention term.
    pub sum_s_ctx: f64,
    /// Σ live context per element-step — KV bytes touched per unit
    /// kv_bytes_per_token.
    pub sum_ctx: f64,
    /// Device invocations (each streams the weights once).
    pub steps: f64,
}

impl WorkTrace {
    /// Record one batch element's share of an append step.
    #[inline]
    pub fn record_elem(&mut self, s_live: usize, ctx_live: usize) {
        self.sum_s += s_live as f64;
        self.sum_s_ctx += (s_live * ctx_live) as f64;
        self.sum_ctx += ctx_live as f64;
    }

    /// Record one device invocation.
    #[inline]
    pub fn record_step(&mut self) {
        self.steps += 1.0;
    }

    pub fn add(&mut self, other: &WorkTrace) {
        self.sum_s += other.sum_s;
        self.sum_s_ctx += other.sum_s_ctx;
        self.sum_ctx += other.sum_ctx;
        self.steps += other.steps;
    }

    pub fn to_json(&self) -> String {
        format!(
            "{{\"sum_s\":{},\"sum_s_ctx\":{},\"sum_ctx\":{},\"steps\":{}}}",
            self.sum_s, self.sum_s_ctx, self.sum_ctx, self.steps
        )
    }
}

/// Latency breakdown of one batch (or an aggregate of many).
#[derive(Debug, Clone, Default)]
pub struct PhaseBreakdown {
    /// Vector-DB top-K search (host).
    pub retrieve_secs: f64,
    /// Wall time loading materialized KVs (throttled storage device).
    pub load_wall_secs: f64,
    /// Simulated storage-device seconds of those loads (at executed scale).
    pub load_device_secs: f64,
    /// Bytes of KV read from the storage device (executed scale;
    /// hot-tier hits excluded).
    pub loaded_bytes: usize,
    /// Tokens of KV spliced from the store, hot-tier hits included
    /// (architecture-independent; all of them cross PCIe at serve time).
    pub loaded_tokens: usize,
    /// Number of chunk reads issued to the storage device.
    pub load_reads: usize,
    /// Device reads per shard (index = shard; empty when no loads ran).
    /// The JBOD rollup: `shard_reads.len()` is the shard count, and the
    /// spread across entries shows routing balance.
    pub shard_reads: Vec<u64>,
    /// Bytes read from the device, per shard.
    pub shard_bytes: Vec<u64>,
    /// Simulated device seconds, per shard. Aggregate device *time*
    /// stays the sum, but the JBOD's wall cost is the max entry — the
    /// slowest device — which is what shrinks with more shards.
    pub shard_device_secs: Vec<f64>,
    /// Peak in-flight reads per shard (high-water mark; merged by max).
    pub shard_peak_queue: Vec<u64>,
    /// Chunk loads served by the DRAM hot tier (no device read). Warm
    /// hits are counted separately in `warm_hits`.
    pub cache_hits: usize,
    /// Tokens of KV served by the hot tier (subset of `loaded_tokens`).
    pub cache_tokens: usize,
    /// On-disk bytes the hot tier avoided reading (executed scale).
    pub cache_bytes_saved: usize,
    /// Chunk loads served by the q8 warm tier: no device read, but the
    /// planes were dequantized (see `dequant_secs`).
    pub warm_hits: usize,
    /// Tokens of KV served by the warm tier (subset of `loaded_tokens`,
    /// disjoint from `cache_tokens`).
    pub warm_tokens: usize,
    /// On-disk bytes the warm tier avoided reading (executed scale).
    pub warm_bytes_saved: usize,
    /// Modeled q8→f32 dequantization seconds charged to warm hits
    /// (testbed scale; the architecture-scale charge is folded into
    /// [`PhaseBreakdown::load_secs_on`]).
    pub dequant_secs: f64,
    /// Modeled f32→q8 quantization seconds the serve path paid admitting
    /// chunks into the warm tier (testbed scale, symmetric to
    /// `dequant_secs`; demote-on-evict charges accrue to the tier's
    /// [`crate::kvstore::CacheStats`] instead — they are not tied to one
    /// batch).
    pub quant_secs: f64,
    /// Tokens of KV this serve path's loads quantized *into* the warm
    /// tier (direct q8 admissions: warm-only stores and chunks oversize
    /// for hot). The architecture-scale quantize charge in
    /// [`PhaseBreakdown::load_secs_on`] reads this, symmetric to
    /// `warm_tokens`' dequant charge; demote-on-evict admissions are
    /// not batch-attributable and live in the tier's `CacheStats` only.
    pub warm_admit_tokens: usize,
    /// Modeled q4→f32 dequantization seconds charged to this serve
    /// path's loads — v4 flash records unpacked on read plus q4-mode
    /// warm hits. Kept apart from the q8 `dequant_secs` so fig JSONs
    /// can attribute the deeper-compression trade to its own clock
    /// (store-modeled; [`PhaseBreakdown::load_secs_on`] adds it as-is).
    pub q4_dequant_secs: f64,
    /// Host→device state upload wall time.
    pub upload_secs: f64,
    /// Prefill (doc recompute and/or query sub-prefill) wall time.
    pub prefill_wall_secs: f64,
    /// Executed prefill work.
    pub prefill_trace: WorkTrace,
    /// Decode wall time.
    pub decode_wall_secs: f64,
    /// Executed decode work.
    pub decode_trace: WorkTrace,
    /// End-to-end wall time.
    pub total_wall_secs: f64,
    /// Requests served.
    pub requests: usize,
    /// Tokens generated.
    pub tokens_out: usize,
    /// Virtual-clock busy seconds per fleet worker (index = worker;
    /// empty when no fleet dispatched this work). Merged element-wise
    /// like the shard rollups.
    pub worker_busy_secs: Vec<f64>,
    /// Batches dispatched per fleet worker.
    pub worker_batches: Vec<u64>,
    /// Modeled host→device KV transfer seconds per fleet worker — the
    /// PCIe charge a batch pays when its chunks were loaded by a
    /// different worker (or sit in host DRAM, not on this device).
    pub worker_transfer_secs: Vec<f64>,
    /// Seconds each fleet worker's H2D uploads spent queued behind
    /// earlier traffic on its PCIe link — the contention signal (0 when
    /// the link never saturated, or queueing was switched off).
    pub worker_link_queued_secs: Vec<f64>,
    /// High-water backlog each worker's PCIe link saw (seconds of
    /// traffic ahead of a reservation's completion). A gauge: merged by
    /// element-wise max, like `shard_peak_queue`.
    pub worker_link_peak_backlog_secs: Vec<f64>,
    /// Per-request end-to-end latency on the virtual clock (arrival →
    /// batch completion), recorded by the fleet dispatcher. Empty for
    /// wall-clock serve paths, which have no virtual completion times.
    pub request_latency: Percentiles,
    /// Shard-read retries the degradation ladder spent (fault plans
    /// only; every counter below is 0 on a clean run).
    pub retries: usize,
    /// Simulated seconds spent in retry backoff, charged on shard links.
    pub retry_backoff_secs: f64,
    /// Reads whose v3 payload checksum rejected corrupted bytes.
    pub checksum_failures: usize,
    /// Chunks served by the Vanilla recompute safety net (flash
    /// unrecoverable; their tokens were re-prefilled instead of loaded).
    pub recomputed_chunks: usize,
    /// Modeled seconds of that fallback recompute (store scale; the
    /// fleet dispatcher re-prices lost chunks per worker on top).
    pub recompute_fallback_secs: f64,
    /// In-flight requests requeued off a crashed fleet worker (their
    /// arrival times are preserved, so `request_latency` reflects the
    /// disruption honestly).
    pub requeued_requests: usize,
    /// Tokens served in degraded mode — via the recompute fallback
    /// rather than a healthy load path.
    pub degraded_tokens: usize,
}

/// Element-wise `a[i] += b[i]`, growing `a` as needed.
fn merge_add<T: Copy + Default + std::ops::AddAssign>(a: &mut Vec<T>, b: &[T]) {
    if a.len() < b.len() {
        a.resize(b.len(), T::default());
    }
    for (x, &y) in a.iter_mut().zip(b) {
        *x += y;
    }
}

/// Element-wise `a[i] = max(a[i], b[i])`, growing `a` as needed (gauges
/// like peak queue depth merge by high-water mark, not by sum).
fn merge_max(a: &mut Vec<u64>, b: &[u64]) {
    if a.len() < b.len() {
        a.resize(b.len(), 0);
    }
    for (x, &y) in a.iter_mut().zip(b) {
        *x = (*x).max(y);
    }
}

/// [`merge_max`] for float gauges (link backlog high-water marks).
fn merge_max_f64(a: &mut Vec<f64>, b: &[f64]) {
    if a.len() < b.len() {
        a.resize(b.len(), 0.0);
    }
    for (x, &y) in a.iter_mut().zip(b) {
        *x = x.max(y);
    }
}

impl PhaseBreakdown {
    /// Record one device read against `shard` (engine rollup while
    /// walking `load_many` results).
    pub fn record_shard_read(&mut self, shard: usize, bytes: usize, device_secs: f64) {
        if self.shard_reads.len() <= shard {
            self.shard_reads.resize(shard + 1, 0);
        }
        if self.shard_bytes.len() <= shard {
            self.shard_bytes.resize(shard + 1, 0);
        }
        if self.shard_device_secs.len() <= shard {
            self.shard_device_secs.resize(shard + 1, 0.0);
        }
        self.shard_reads[shard] += 1;
        self.shard_bytes[shard] += bytes as u64;
        self.shard_device_secs[shard] += device_secs;
    }

    /// Merge another breakdown (sequential aggregation).
    pub fn add(&mut self, other: &PhaseBreakdown) {
        self.retrieve_secs += other.retrieve_secs;
        self.load_wall_secs += other.load_wall_secs;
        self.load_device_secs += other.load_device_secs;
        self.loaded_bytes += other.loaded_bytes;
        self.loaded_tokens += other.loaded_tokens;
        self.load_reads += other.load_reads;
        merge_add(&mut self.shard_reads, &other.shard_reads);
        merge_add(&mut self.shard_bytes, &other.shard_bytes);
        merge_add(&mut self.shard_device_secs, &other.shard_device_secs);
        merge_max(&mut self.shard_peak_queue, &other.shard_peak_queue);
        self.cache_hits += other.cache_hits;
        self.cache_tokens += other.cache_tokens;
        self.cache_bytes_saved += other.cache_bytes_saved;
        self.warm_hits += other.warm_hits;
        self.warm_tokens += other.warm_tokens;
        self.warm_bytes_saved += other.warm_bytes_saved;
        self.dequant_secs += other.dequant_secs;
        self.quant_secs += other.quant_secs;
        self.warm_admit_tokens += other.warm_admit_tokens;
        self.q4_dequant_secs += other.q4_dequant_secs;
        self.upload_secs += other.upload_secs;
        self.prefill_wall_secs += other.prefill_wall_secs;
        self.prefill_trace.add(&other.prefill_trace);
        self.decode_wall_secs += other.decode_wall_secs;
        self.decode_trace.add(&other.decode_trace);
        self.total_wall_secs += other.total_wall_secs;
        self.requests += other.requests;
        self.tokens_out += other.tokens_out;
        merge_add(&mut self.worker_busy_secs, &other.worker_busy_secs);
        merge_add(&mut self.worker_batches, &other.worker_batches);
        merge_add(&mut self.worker_transfer_secs, &other.worker_transfer_secs);
        merge_add(&mut self.worker_link_queued_secs, &other.worker_link_queued_secs);
        merge_max_f64(
            &mut self.worker_link_peak_backlog_secs,
            &other.worker_link_peak_backlog_secs,
        );
        self.request_latency.merge(&other.request_latency);
        self.retries += other.retries;
        self.retry_backoff_secs += other.retry_backoff_secs;
        self.checksum_failures += other.checksum_failures;
        self.recomputed_chunks += other.recomputed_chunks;
        self.recompute_fallback_secs += other.recompute_fallback_secs;
        self.requeued_requests += other.requeued_requests;
        self.degraded_tokens += other.degraded_tokens;
    }

    /// Simulated prefill seconds for the trace under an architecture.
    pub fn prefill_secs_on(&self, arch: &ArchSpec, dev: &DeviceProfile) -> f64 {
        arch.trace_secs(&self.prefill_trace, dev)
    }

    /// Simulated decode seconds for the trace under an architecture
    /// (decode-class bandwidth calibration).
    pub fn decode_secs_on(&self, arch: &ArchSpec, dev: &DeviceProfile) -> f64 {
        arch.trace_secs_decode(&self.decode_trace, dev)
    }

    /// Simulated KV-load seconds at architecture scale on a storage
    /// tier. DRAM-tier hits (hot or warm) never touched the device, so
    /// only the miss tokens are charged to it; warm-served tokens are
    /// charged the modeled q8 dequant pass instead — one byte per f16
    /// KV-byte pair, so half of [`ArchSpec::kv_bytes`] moves through the
    /// dequant bandwidth. Symmetrically, tokens this path quantized
    /// *into* the warm tier (`warm_admit_tokens`) are charged the
    /// quantize pass at the same scale — the warm tier's round trip is
    /// never half-priced. The q4 unpack clock (`q4_dequant_secs`, v4
    /// flash reads and q4 warm hits) is added as the store modeled it —
    /// it is priced on actual payload bytes at record time, not
    /// rescaled per token here.
    pub fn load_secs_on(&self, arch: &ArchSpec, storage: &StorageProfile) -> f64 {
        let miss_tokens =
            self.loaded_tokens.saturating_sub(self.cache_tokens + self.warm_tokens);
        storage.read_secs_batch(arch.kv_bytes(miss_tokens), self.load_reads)
            + crate::hwsim::q8_dequant_secs(arch.kv_bytes(self.warm_tokens) * 0.5)
            + crate::hwsim::q8_quant_secs(arch.kv_bytes(self.warm_admit_tokens) * 0.5)
            + self.q4_dequant_secs
    }

    /// Simulated host→device upload of the loaded KVs: PCIe wire time
    /// through the one [`Link::wire_secs`] definition (queueing on top
    /// of it belongs to actual links — the fleet's per-worker H2D
    /// links — not to this aggregate rollup).
    pub fn upload_secs_on(&self, arch: &ArchSpec, dev: &DeviceProfile) -> f64 {
        Link::wire_secs(dev.pcie_bw, 0.0, arch.kv_bytes(self.loaded_tokens) as usize)
    }

    /// Simulated end-to-end, serial composition (no overlap).
    pub fn total_secs_on(
        &self,
        arch: &ArchSpec,
        dev: &DeviceProfile,
        storage: &StorageProfile,
    ) -> f64 {
        self.load_secs_on(arch, storage)
            + self.upload_secs_on(arch, dev)
            + self.prefill_secs_on(arch, dev)
            + self.decode_secs_on(arch, dev)
    }

    /// Measured tokens/sec.
    pub fn throughput(&self) -> f64 {
        if self.total_wall_secs > 0.0 {
            self.tokens_out as f64 / self.total_wall_secs
        } else {
            0.0
        }
    }

    /// Exhaustive JSON of every field, in **sorted key order** (so two
    /// dumps diff cleanly line-to-line). This is the `--metrics-json`
    /// payload, and doubles as the merge guard's equality witness: a
    /// field missing here (or from [`add`]) trips
    /// `exhaustive_merge_guard` below, so neither can silently lag the
    /// struct. Keep all three in sync when adding a field.
    ///
    /// [`add`]: PhaseBreakdown::add
    pub fn to_json(&self) -> String {
        fn vec_u64(v: &[u64]) -> String {
            let rows: Vec<String> = v.iter().map(u64::to_string).collect();
            format!("[{}]", rows.join(","))
        }
        fn vec_f64(v: &[f64]) -> String {
            let rows: Vec<String> = v.iter().map(|x| format!("{x:.9}")).collect();
            format!("[{}]", rows.join(","))
        }
        format!(
            "{{\"cache_bytes_saved\":{},\"cache_hits\":{},\"cache_tokens\":{},\
             \"checksum_failures\":{},\"decode_trace\":{},\
             \"decode_wall_secs\":{:.9},\"degraded_tokens\":{},\
             \"dequant_secs\":{:.9},\"load_device_secs\":{:.9},\
             \"load_reads\":{},\"load_wall_secs\":{:.9},\
             \"loaded_bytes\":{},\"loaded_tokens\":{},\
             \"prefill_trace\":{},\"prefill_wall_secs\":{:.9},\
             \"q4_dequant_secs\":{:.9},\"quant_secs\":{:.9},\
             \"recompute_fallback_secs\":{:.9},\"recomputed_chunks\":{},\
             \"request_latency\":{},\"requests\":{},\
             \"requeued_requests\":{},\"retries\":{},\
             \"retrieve_secs\":{:.9},\"retry_backoff_secs\":{:.9},\
             \"shard_bytes\":{},\"shard_device_secs\":{},\
             \"shard_peak_queue\":{},\"shard_reads\":{},\
             \"tokens_out\":{},\"total_wall_secs\":{:.9},\
             \"upload_secs\":{:.9},\"warm_admit_tokens\":{},\
             \"warm_bytes_saved\":{},\"warm_hits\":{},\"warm_tokens\":{},\
             \"worker_batches\":{},\"worker_busy_secs\":{},\
             \"worker_link_peak_backlog_secs\":{},\
             \"worker_link_queued_secs\":{},\"worker_transfer_secs\":{}}}",
            self.cache_bytes_saved,
            self.cache_hits,
            self.cache_tokens,
            self.checksum_failures,
            self.decode_trace.to_json(),
            self.decode_wall_secs,
            self.degraded_tokens,
            self.dequant_secs,
            self.load_device_secs,
            self.load_reads,
            self.load_wall_secs,
            self.loaded_bytes,
            self.loaded_tokens,
            self.prefill_trace.to_json(),
            self.prefill_wall_secs,
            self.q4_dequant_secs,
            self.quant_secs,
            self.recompute_fallback_secs,
            self.recomputed_chunks,
            self.request_latency.to_json(),
            self.requests,
            self.requeued_requests,
            self.retries,
            self.retrieve_secs,
            self.retry_backoff_secs,
            vec_u64(&self.shard_bytes),
            vec_f64(&self.shard_device_secs),
            vec_u64(&self.shard_peak_queue),
            vec_u64(&self.shard_reads),
            self.tokens_out,
            self.total_wall_secs,
            self.upload_secs,
            self.warm_admit_tokens,
            self.warm_bytes_saved,
            self.warm_hits,
            self.warm_tokens,
            vec_u64(&self.worker_batches),
            vec_f64(&self.worker_busy_secs),
            vec_f64(&self.worker_link_peak_backlog_secs),
            vec_f64(&self.worker_link_queued_secs),
            vec_f64(&self.worker_transfer_secs),
        )
    }
}

/// The serving percentiles the fleet bench emits, in one copyable
/// bundle (nearest-rank, from [`Percentiles::summary`]).
#[derive(Debug, Clone, Copy, Default, PartialEq)]
pub struct LatencySummary {
    pub mean: f64,
    pub p50: f64,
    pub p95: f64,
    pub p99: f64,
}

/// Latency percentile helper for per-request distributions.
#[derive(Debug, Default, Clone)]
pub struct Percentiles {
    samples: Vec<f64>,
}

impl Percentiles {
    pub fn record(&mut self, v: f64) {
        self.samples.push(v);
    }

    /// Fold another distribution's samples into this one (the
    /// [`PhaseBreakdown::add`] merge).
    pub fn merge(&mut self, other: &Percentiles) {
        self.samples.extend_from_slice(&other.samples);
    }

    /// Nearest-rank pick from a pre-sorted sample slice — the one
    /// definition of the rule [`Percentiles::percentile`] and
    /// [`Percentiles::summary`] share.
    fn rank_pick(sorted: &[f64], p: f64) -> f64 {
        if sorted.is_empty() {
            return 0.0;
        }
        let rank = ((p / 100.0) * (sorted.len() as f64 - 1.0)).round() as usize;
        sorted[rank.min(sorted.len() - 1)]
    }

    /// The p50/p95/p99 bundle serving reports quote. One sort serves
    /// all three ranks.
    pub fn summary(&self) -> LatencySummary {
        let mut sorted = self.samples.clone();
        sorted.sort_by(|a, b| a.total_cmp(b));
        LatencySummary {
            mean: self.mean(),
            p50: Self::rank_pick(&sorted, 50.0),
            p95: Self::rank_pick(&sorted, 95.0),
            p99: Self::rank_pick(&sorted, 99.0),
        }
    }

    pub fn len(&self) -> usize {
        self.samples.len()
    }

    pub fn is_empty(&self) -> bool {
        self.samples.is_empty()
    }

    /// p in [0, 100]; nearest-rank.
    pub fn percentile(&self, p: f64) -> f64 {
        let mut sorted = self.samples.clone();
        sorted.sort_by(|a, b| a.total_cmp(b));
        Self::rank_pick(&sorted, p)
    }

    pub fn mean(&self) -> f64 {
        if self.samples.is_empty() {
            0.0
        } else {
            self.samples.iter().sum::<f64>() / self.samples.len() as f64
        }
    }

    /// Fold the per-sample distribution into the mergeable log-bucketed
    /// form ([`LogHistogram`]). Per-sample fidelity stays here; the
    /// histogram is what crosses file boundaries (trace documents,
    /// metrics dumps), where unbounded sample vectors don't belong.
    pub fn histogram(&self) -> LogHistogram {
        let mut h = LogHistogram::default();
        for &v in &self.samples {
            h.record(v);
        }
        h
    }

    /// Summary bundle plus the mergeable histogram — never the raw
    /// samples, which are unbounded.
    pub fn to_json(&self) -> String {
        let s = self.summary();
        format!(
            "{{\"count\":{},\"mean\":{:.9},\"p50\":{:.9},\"p95\":{:.9},\
             \"p99\":{:.9},\"histogram\":{}}}",
            self.len(),
            s.mean,
            s.p50,
            s.p95,
            s.p99,
            self.histogram().to_json()
        )
    }
}

/// Log-bucketed latency histogram with a fixed, universal bucket
/// geometry, so any two histograms merge bucket-for-bucket without
/// resampling — the property [`Percentiles`] (a raw sample vector)
/// lacks once distributions leave the process as JSON.
///
/// Geometry: bucket 0 holds everything at or below [`LogHistogram::LO`]
/// (1 µs — below the resolution of anything this testbed models);
/// bucket `i ≥ 1` holds `(LO·G^(i-1), LO·G^i]` with `G =`
/// [`LogHistogram::GROWTH`]. At 8% growth the relative quantile error
/// is bounded by one bucket width (~8%), and ~300 buckets span 1 µs to
/// over an hour; anything beyond clamps into the last bucket.
#[derive(Debug, Clone, Default, PartialEq)]
pub struct LogHistogram {
    /// Per-bucket counts, grown lazily to the highest occupied index.
    counts: Vec<u64>,
    count: u64,
    sum: f64,
    min: f64,
    max: f64,
}

impl LogHistogram {
    /// Lower edge of the geometry: 1 µs.
    pub const LO: f64 = 1e-6;
    /// Bucket growth ratio (8% relative quantile error bound).
    pub const GROWTH: f64 = 1.08;
    /// Bucket count cap: `LO · GROWTH^320` ≈ 4.8e4 s (~13 h).
    pub const MAX_BUCKETS: usize = 321;

    /// Bucket index for a value — the one place the geometry lives.
    fn bucket(v: f64) -> usize {
        if !(v > Self::LO) {
            return 0; // ≤ LO, zero, negative, and NaN all floor out
        }
        let idx = ((v / Self::LO).ln() / Self::GROWTH.ln()).ceil() as usize;
        idx.min(Self::MAX_BUCKETS - 1)
    }

    /// Upper edge of bucket `i` — the quantile representative, so
    /// reported percentiles err conservatively (never under-report).
    fn upper_edge(i: usize) -> f64 {
        if i == 0 {
            Self::LO
        } else {
            Self::LO * Self::GROWTH.powi(i as i32)
        }
    }

    pub fn record(&mut self, v: f64) {
        let i = Self::bucket(v);
        if self.counts.len() <= i {
            self.counts.resize(i + 1, 0);
        }
        self.counts[i] += 1;
        if self.count == 0 {
            self.min = v;
            self.max = v;
        } else {
            self.min = self.min.min(v);
            self.max = self.max.max(v);
        }
        self.count += 1;
        self.sum += v;
    }

    /// Fold another histogram in. Exact — both sides share the fixed
    /// geometry, so this is element-wise addition, and `merge(a, b)`
    /// reports identical quantiles to having recorded every sample
    /// into one histogram.
    pub fn merge(&mut self, other: &LogHistogram) {
        if other.count == 0 {
            return;
        }
        if self.counts.len() < other.counts.len() {
            self.counts.resize(other.counts.len(), 0);
        }
        for (x, &y) in self.counts.iter_mut().zip(&other.counts) {
            *x += y;
        }
        if self.count == 0 {
            self.min = other.min;
            self.max = other.max;
        } else {
            self.min = self.min.min(other.min);
            self.max = self.max.max(other.max);
        }
        self.count += other.count;
        self.sum += other.sum;
    }

    pub fn len(&self) -> usize {
        self.count as usize
    }

    pub fn is_empty(&self) -> bool {
        self.count == 0
    }

    pub fn mean(&self) -> f64 {
        if self.count == 0 {
            0.0
        } else {
            self.sum / self.count as f64
        }
    }

    /// Exact sum of recorded values (the histogram buckets quantize,
    /// the sum does not) — what a Prometheus summary's `_sum` reports.
    pub fn sum(&self) -> f64 {
        self.sum
    }

    pub fn min(&self) -> f64 {
        self.min
    }

    pub fn max(&self) -> f64 {
        self.max
    }

    /// p in [0, 100]; nearest-rank over buckets. Returns the matched
    /// bucket's upper edge clamped into `[min, max]`, so the answer is
    /// within one bucket width (~8%) of the sample-exact quantile and
    /// extreme ranks return the exact recorded extremes.
    pub fn percentile(&self, p: f64) -> f64 {
        if self.count == 0 {
            return 0.0;
        }
        let rank = ((p / 100.0) * self.count as f64).ceil().max(1.0) as u64;
        let mut seen = 0u64;
        for (i, &c) in self.counts.iter().enumerate() {
            seen += c;
            if seen >= rank {
                return Self::upper_edge(i).clamp(self.min, self.max);
            }
        }
        self.max
    }

    /// Sparse JSON: fixed geometry constants plus `index:count` pairs
    /// for occupied buckets only. Floats print at fixed precision so
    /// the same distribution always serializes to the same bytes.
    pub fn to_json(&self) -> String {
        use std::fmt::Write as _;
        let mut buckets = String::new();
        for (i, &c) in self.counts.iter().enumerate() {
            if c > 0 {
                if !buckets.is_empty() {
                    buckets.push(',');
                }
                let _ = write!(buckets, "\"{i}\":{c}");
            }
        }
        format!(
            "{{\"lo\":{:e},\"growth\":{},\"count\":{},\"sum\":{:.9},\
             \"min\":{:.9},\"max\":{:.9},\"buckets\":{{{}}}}}",
            Self::LO,
            Self::GROWTH,
            self.count,
            self.sum,
            self.min,
            self.max,
            buckets,
        )
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::hwsim::DeviceProfile;

    #[test]
    fn trace_records_and_adds() {
        let mut t = WorkTrace::default();
        t.record_step();
        t.record_elem(256, 256);
        t.record_elem(256, 512);
        assert_eq!(t.sum_s, 512.0);
        assert_eq!(t.sum_s_ctx, 256.0 * 256.0 + 256.0 * 512.0);
        assert_eq!(t.steps, 1.0);
        let mut u = WorkTrace::default();
        u.add(&t);
        u.add(&t);
        assert_eq!(u.sum_s, 1024.0);
    }

    #[test]
    fn add_accumulates_all_fields() {
        let mut a = PhaseBreakdown { retrieve_secs: 1.0, requests: 2, tokens_out: 10, ..Default::default() };
        let b = PhaseBreakdown {
            retrieve_secs: 2.0,
            requests: 3,
            tokens_out: 5,
            loaded_tokens: 7,
            cache_hits: 2,
            cache_tokens: 4,
            cache_bytes_saved: 99,
            ..Default::default()
        };
        a.add(&b);
        assert_eq!(a.retrieve_secs, 3.0);
        assert_eq!(a.requests, 5);
        assert_eq!(a.tokens_out, 15);
        assert_eq!(a.loaded_tokens, 7);
        assert_eq!(a.cache_hits, 2);
        assert_eq!(a.cache_tokens, 4);
        assert_eq!(a.cache_bytes_saved, 99);
    }

    #[test]
    fn add_accumulates_warm_tier_fields() {
        let mut a = PhaseBreakdown {
            warm_hits: 1,
            warm_tokens: 256,
            warm_bytes_saved: 10,
            dequant_secs: 0.5,
            quant_secs: 0.1,
            ..Default::default()
        };
        let b = PhaseBreakdown {
            warm_hits: 2,
            warm_tokens: 512,
            warm_bytes_saved: 30,
            dequant_secs: 0.25,
            quant_secs: 0.2,
            ..Default::default()
        };
        a.add(&b);
        assert_eq!(a.warm_hits, 3);
        assert_eq!(a.warm_tokens, 768);
        assert_eq!(a.warm_bytes_saved, 40);
        assert!((a.dequant_secs - 0.75).abs() < 1e-12);
        assert!((a.quant_secs - 0.3).abs() < 1e-12);
    }

    #[test]
    fn q4_dequant_accumulates_and_prices_the_load() {
        let mut a = PhaseBreakdown { q4_dequant_secs: 0.5, ..Default::default() };
        a.add(&PhaseBreakdown { q4_dequant_secs: 0.25, ..Default::default() });
        assert!((a.q4_dequant_secs - 0.75).abs() < 1e-12);
        // load_secs_on must carry the store-modeled q4 unpack verbatim:
        // with no tokens loaded at all, the charge is exactly that clock
        let arch = ArchSpec::llama_70b();
        let ssd = StorageProfile::ssd_9100pro();
        assert!((a.load_secs_on(&arch, &ssd) - 0.75).abs() < 1e-12);
        // and it stacks on top of a miss-token read charge
        let mut b = PhaseBreakdown { loaded_tokens: 4096, load_reads: 4, ..Default::default() };
        let base = b.load_secs_on(&arch, &ssd);
        b.q4_dequant_secs = 0.125;
        assert!((b.load_secs_on(&arch, &ssd) - base - 0.125).abs() < 1e-12);
    }

    #[test]
    fn add_accumulates_fault_recovery_fields() {
        let mut a = PhaseBreakdown {
            retries: 1,
            retry_backoff_secs: 0.004,
            checksum_failures: 1,
            recomputed_chunks: 2,
            recompute_fallback_secs: 0.5,
            requeued_requests: 1,
            degraded_tokens: 256,
            ..Default::default()
        };
        let b = PhaseBreakdown {
            retries: 3,
            retry_backoff_secs: 0.012,
            checksum_failures: 0,
            recomputed_chunks: 1,
            recompute_fallback_secs: 0.25,
            requeued_requests: 2,
            degraded_tokens: 512,
            ..Default::default()
        };
        a.add(&b);
        assert_eq!(a.retries, 4);
        assert!((a.retry_backoff_secs - 0.016).abs() < 1e-12);
        assert_eq!(a.checksum_failures, 1);
        assert_eq!(a.recomputed_chunks, 3);
        assert!((a.recompute_fallback_secs - 0.75).abs() < 1e-12);
        assert_eq!(a.requeued_requests, 3);
        assert_eq!(a.degraded_tokens, 768);
    }

    #[test]
    fn add_merges_worker_rollups_and_latency() {
        let mut lat_a = Percentiles::default();
        lat_a.record(0.010);
        lat_a.record(0.030);
        let mut a = PhaseBreakdown {
            worker_busy_secs: vec![1.0, 2.0],
            worker_batches: vec![1, 2],
            worker_transfer_secs: vec![0.125],
            worker_link_queued_secs: vec![0.01, 0.02],
            worker_link_peak_backlog_secs: vec![0.5, 0.1],
            request_latency: lat_a,
            ..Default::default()
        };
        let mut lat_b = Percentiles::default();
        lat_b.record(0.020);
        let b = PhaseBreakdown {
            worker_busy_secs: vec![0.5, 0.5, 3.0], // sparse worker 2 grows vecs
            worker_batches: vec![0, 1, 4],
            worker_transfer_secs: vec![0.25, 0.5],
            worker_link_queued_secs: vec![0.01],
            worker_link_peak_backlog_secs: vec![0.25, 0.75, 0.3],
            request_latency: lat_b,
            ..Default::default()
        };
        a.add(&b);
        assert_eq!(a.worker_busy_secs, vec![1.5, 2.5, 3.0]);
        assert_eq!(a.worker_batches, vec![1, 3, 4]);
        assert_eq!(a.worker_transfer_secs, vec![0.375, 0.5]);
        // queued secs are counters (sum); peak backlog is a gauge (max)
        assert_eq!(a.worker_link_queued_secs, vec![0.02, 0.02]);
        assert_eq!(a.worker_link_peak_backlog_secs, vec![0.5, 0.75, 0.3]);
        assert_eq!(a.request_latency.len(), 3);
        let s = a.request_latency.summary();
        assert_eq!(s.p50, 0.020);
        assert!((s.mean - 0.020).abs() < 1e-12);
        // merging into an empty breakdown grows everything
        let mut empty = PhaseBreakdown::default();
        empty.add(&a);
        assert_eq!(empty.worker_busy_secs, a.worker_busy_secs);
        assert_eq!(empty.request_latency.len(), 3);
    }

    #[test]
    fn latency_summary_is_ordered_and_deterministic() {
        let mut p = Percentiles::default();
        for i in (0..200).rev() {
            p.record(i as f64 / 1000.0);
        }
        let s = p.summary();
        assert!(s.p50 <= s.p95 && s.p95 <= s.p99, "{s:?}");
        assert_eq!(s, p.summary(), "same samples must summarize identically");
        // nearest-rank pins the exact values for a known distribution
        assert_eq!(s.p99, 0.197);
    }

    #[test]
    fn shard_rollup_merges_sums_and_peaks() {
        let mut a = PhaseBreakdown::default();
        a.record_shard_read(0, 100, 0.5);
        a.record_shard_read(2, 300, 1.5); // sparse shard index grows vecs
        a.shard_peak_queue = vec![2, 0, 1];
        assert_eq!(a.shard_reads, vec![1, 0, 1]);
        assert_eq!(a.shard_bytes, vec![100, 0, 300]);

        let mut b = PhaseBreakdown::default();
        b.record_shard_read(0, 50, 0.25);
        b.record_shard_read(1, 60, 0.25);
        b.shard_peak_queue = vec![1, 4];

        a.add(&b);
        assert_eq!(a.shard_reads, vec![2, 1, 1]);
        assert_eq!(a.shard_bytes, vec![150, 60, 300]);
        assert!((a.shard_device_secs[0] - 0.75).abs() < 1e-12);
        // gauges merge by max, counters by sum
        assert_eq!(a.shard_peak_queue, vec![2, 4, 1]);

        // merging into an empty breakdown grows the vectors
        let mut empty = PhaseBreakdown::default();
        empty.add(&a);
        assert_eq!(empty.shard_reads, a.shard_reads);
        assert_eq!(empty.shard_peak_queue, a.shard_peak_queue);
    }

    #[test]
    fn load_costing_discounts_hot_tier_hits() {
        let arch = crate::hwsim::standin::ArchSpec::llama_70b();
        let ssd = crate::hwsim::StorageProfile::ssd_9100pro();
        let mut b = PhaseBreakdown { loaded_tokens: 2048, load_reads: 2, ..Default::default() };
        let cold = b.load_secs_on(&arch, &ssd);
        // half the chunks now come from the hot tier
        b.cache_hits = 1;
        b.cache_tokens = 1024;
        b.load_reads = 1;
        let warm = b.load_secs_on(&arch, &ssd);
        assert!(warm < cold, "{warm} vs {cold}");
        // PCIe upload is unchanged: every spliced token still crosses
        assert_eq!(b.upload_secs_on(&arch, &crate::hwsim::DeviceProfile::h100()),
            PhaseBreakdown { loaded_tokens: 2048, ..Default::default() }
                .upload_secs_on(&arch, &crate::hwsim::DeviceProfile::h100()));
    }

    #[test]
    fn load_costing_charges_warm_hits_dequant_not_device() {
        let arch = crate::hwsim::standin::ArchSpec::llama_70b();
        let ssd = crate::hwsim::StorageProfile::ssd_9100pro();
        let cold = PhaseBreakdown { loaded_tokens: 2048, load_reads: 2, ..Default::default() };
        // the same tokens served from the warm tier: no device reads,
        // only the dequant pass
        let warm = PhaseBreakdown {
            loaded_tokens: 2048,
            warm_hits: 2,
            warm_tokens: 2048,
            ..Default::default()
        };
        // and from the hot tier: entirely free
        let hot = PhaseBreakdown {
            loaded_tokens: 2048,
            cache_hits: 2,
            cache_tokens: 2048,
            ..Default::default()
        };
        let (c, w, h) = (
            cold.load_secs_on(&arch, &ssd),
            warm.load_secs_on(&arch, &ssd),
            hot.load_secs_on(&arch, &ssd),
        );
        assert_eq!(h, 0.0);
        assert!(w > 0.0, "warm hits are not free");
        assert!(w < c, "dequant must undercut the device read: {w} vs {c}");
    }

    #[test]
    fn load_costing_charges_warm_admissions_symmetrically() {
        let arch = crate::hwsim::standin::ArchSpec::llama_70b();
        let ssd = crate::hwsim::StorageProfile::ssd_9100pro();
        // tokens served FROM warm pay dequant; the same token count
        // quantized INTO warm pays exactly the same modeled seconds
        let served = PhaseBreakdown {
            loaded_tokens: 1024,
            warm_hits: 1,
            warm_tokens: 1024,
            ..Default::default()
        };
        let admitted = PhaseBreakdown {
            loaded_tokens: 1024,
            load_reads: 1,
            warm_admit_tokens: 1024,
            ..Default::default()
        };
        let base =
            PhaseBreakdown { loaded_tokens: 1024, load_reads: 1, ..Default::default() };
        let quant_charge = admitted.load_secs_on(&arch, &ssd) - base.load_secs_on(&arch, &ssd);
        let dequant_charge = served.load_secs_on(&arch, &ssd);
        assert!(quant_charge > 0.0, "warm admission is not free at arch scale");
        assert!(
            (quant_charge - dequant_charge).abs() < 1e-12,
            "round trip must price symmetrically: {quant_charge} vs {dequant_charge}"
        );
    }

    #[test]
    fn standin_costing_recovers_paper_regime() {
        // a 2x1024-token MatKV request: load 2048 tokens, sub-prefill 20,
        // decode 20 — at 70B scale prefill-from-scratch must dwarf load.
        let mut matkv = PhaseBreakdown::default();
        matkv.loaded_tokens = 2048;
        matkv.load_reads = 2;
        matkv.prefill_trace.record_step();
        matkv.prefill_trace.record_elem(20, 2068);
        let mut vanilla_trace = WorkTrace::default();
        for i in 0..8 {
            vanilla_trace.record_step();
            vanilla_trace.record_elem(256, (i + 1) * 256);
        }
        let arch = crate::hwsim::standin::ArchSpec::llama_70b();
        let h100 = DeviceProfile::h100();
        let ssd = crate::hwsim::StorageProfile::raid0_4x9100();
        let matkv_path = matkv.load_secs_on(&arch, &ssd)
            + matkv.upload_secs_on(&arch, &h100)
            + matkv.prefill_secs_on(&arch, &h100);
        let vanilla_path = arch.trace_secs(&vanilla_trace, &h100);
        assert!(vanilla_path > 2.0 * matkv_path, "{vanilla_path} vs {matkv_path}");
    }

    #[test]
    fn percentiles_ordered() {
        let mut p = Percentiles::default();
        for i in 0..100 {
            p.record(i as f64);
        }
        assert!(p.percentile(50.0) >= 45.0 && p.percentile(50.0) <= 55.0);
        assert_eq!(p.percentile(100.0), 99.0);
        assert_eq!(p.percentile(0.0), 0.0);
        assert!((p.mean() - 49.5).abs() < 1e-9);
    }

    #[test]
    fn empty_percentiles_are_zero() {
        let p = Percentiles::default();
        assert_eq!(p.percentile(99.0), 0.0);
        assert_eq!(p.mean(), 0.0);
    }

    #[test]
    fn throughput() {
        let b = PhaseBreakdown { total_wall_secs: 2.0, tokens_out: 100, ..Default::default() };
        assert_eq!(b.throughput(), 50.0);
    }

    /// Every field, distinct and nonzero, with **no** `..Default::default()`:
    /// adding a field to [`PhaseBreakdown`] breaks this literal at compile
    /// time, forcing this test (and so [`PhaseBreakdown::add`] and
    /// [`PhaseBreakdown::to_json`]) to be revisited.
    fn filled_breakdown() -> PhaseBreakdown {
        let mut lat = Percentiles::default();
        lat.record(0.040);
        lat.record(0.020);
        PhaseBreakdown {
            retrieve_secs: 0.001,
            load_wall_secs: 0.002,
            load_device_secs: 0.003,
            loaded_bytes: 11,
            loaded_tokens: 12,
            load_reads: 13,
            shard_reads: vec![1, 2],
            shard_bytes: vec![100, 200],
            shard_device_secs: vec![0.25, 0.5],
            shard_peak_queue: vec![3, 1],
            cache_hits: 14,
            cache_tokens: 15,
            cache_bytes_saved: 16,
            warm_hits: 17,
            warm_tokens: 18,
            warm_bytes_saved: 19,
            dequant_secs: 0.004,
            quant_secs: 0.005,
            warm_admit_tokens: 20,
            q4_dequant_secs: 0.006,
            upload_secs: 0.007,
            prefill_wall_secs: 0.008,
            prefill_trace: WorkTrace { sum_s: 1.0, sum_s_ctx: 2.0, sum_ctx: 3.0, steps: 4.0 },
            decode_wall_secs: 0.009,
            decode_trace: WorkTrace { sum_s: 5.0, sum_s_ctx: 6.0, sum_ctx: 7.0, steps: 8.0 },
            total_wall_secs: 0.010,
            requests: 21,
            tokens_out: 22,
            worker_busy_secs: vec![0.75],
            worker_batches: vec![4],
            worker_transfer_secs: vec![0.125],
            worker_link_queued_secs: vec![0.0625],
            worker_link_peak_backlog_secs: vec![0.375],
            request_latency: lat,
            retries: 23,
            retry_backoff_secs: 0.011,
            checksum_failures: 24,
            recomputed_chunks: 25,
            recompute_fallback_secs: 0.012,
            requeued_requests: 26,
            degraded_tokens: 27,
        }
    }

    #[test]
    fn exhaustive_merge_guard() {
        let filled = filled_breakdown();
        // add-identity: merging the fully-populated breakdown into a
        // default one must reproduce it exactly. A field [`add`] fails
        // to carry stays at its default and diverges in the exhaustive
        // serialization (all values above are chosen nonzero and
        // distinct, so no omission can cancel out).
        let mut merged = PhaseBreakdown::default();
        merged.add(&filled);
        assert_eq!(merged.to_json(), filled.to_json());
        // double-add doubles counters but leaves gauges at their max
        let mut twice = PhaseBreakdown::default();
        twice.add(&filled);
        twice.add(&filled);
        assert_eq!(twice.requests, 42);
        assert!((twice.retrieve_secs - 0.002).abs() < 1e-12);
        assert_eq!(twice.shard_peak_queue, vec![3, 1]);
        assert_eq!(twice.worker_link_peak_backlog_secs, vec![0.375]);
        assert_eq!(twice.request_latency.len(), 4);
    }

    #[test]
    fn breakdown_json_is_exhaustive_and_deterministic() {
        let filled = filled_breakdown();
        let j = filled.to_json();
        assert_eq!(j, filled_breakdown().to_json());
        // spot-check shape: a scalar, a rollup vector, and both nested
        // structures made it into the document
        assert!(j.contains("\"degraded_tokens\":27"), "{j}");
        assert!(j.contains("\"shard_reads\":[1,2]"), "{j}");
        assert!(j.contains("\"prefill_trace\":{\"sum_s\":1"), "{j}");
        assert!(j.contains("\"request_latency\":{\"count\":2"), "{j}");
        assert!(j.contains("\"histogram\":{\"lo\":1e-6"), "{j}");
        // top-level keys are emitted in sorted order so dumps diff cleanly
        let mut depth = 0usize;
        let mut keys = Vec::new();
        let bytes = j.as_bytes();
        let mut i = 0;
        while i < bytes.len() {
            match bytes[i] {
                b'{' | b'[' => depth += 1,
                b'}' | b']' => depth -= 1,
                b'"' if depth == 1 => {
                    let end = j[i + 1..].find('"').unwrap() + i + 1;
                    if bytes.get(end + 1) == Some(&b':') {
                        keys.push(&j[i + 1..end]);
                    }
                    i = end;
                }
                _ => {}
            }
            i += 1;
        }
        let mut sorted = keys.clone();
        sorted.sort_unstable();
        assert_eq!(keys, sorted, "PhaseBreakdown::to_json keys must stay sorted");
    }

    #[test]
    fn log_histogram_percentiles_track_samples_within_bucket_width() {
        let mut h = LogHistogram::default();
        let mut p = Percentiles::default();
        for i in 1..=1000 {
            let v = i as f64 * 1e-3;
            h.record(v);
            p.record(v);
        }
        assert_eq!(h.len(), 1000);
        assert!((h.mean() - p.mean()).abs() < 1e-9, "sum is exact, not bucketed");
        for q in [10.0, 50.0, 90.0, 99.0] {
            let exact = p.percentile(q);
            let approx = h.percentile(q);
            assert!(
                approx >= exact * 0.999 && approx <= exact * LogHistogram::GROWTH * 1.001,
                "q{q}: {approx} vs exact {exact}"
            );
        }
        // extremes clamp to the recorded min/max, not bucket edges
        assert_eq!(h.percentile(100.0), 1.0);
        assert!(h.percentile(0.0) >= 1e-3 - 1e-12);
        assert_eq!(h.min(), 1e-3);
        assert_eq!(h.max(), 1.0);
    }

    #[test]
    fn log_histogram_merge_matches_single_recording() {
        let vals: Vec<f64> = (0..200).map(|i| 1e-5 * 1.07f64.powi(i % 37)).collect();
        let mut whole = LogHistogram::default();
        let mut a = LogHistogram::default();
        let mut b = LogHistogram::default();
        for (i, &v) in vals.iter().enumerate() {
            whole.record(v);
            if i % 2 == 0 {
                a.record(v);
            } else {
                b.record(v);
            }
        }
        a.merge(&b);
        // fixed geometry makes the merge exact bucket-for-bucket (the
        // float `sum` can differ by an ulp from a different addition
        // order, which the fixed-precision serialization absorbs)
        assert_eq!(a.to_json(), whole.to_json());
        for q in [1.0, 25.0, 50.0, 75.0, 99.0, 100.0] {
            assert_eq!(a.percentile(q), whole.percentile(q), "q{q}");
        }
        // merging an empty histogram is a no-op either way
        a.merge(&LogHistogram::default());
        assert_eq!(a.to_json(), whole.to_json());
        let mut e = LogHistogram::default();
        e.merge(&whole);
        assert_eq!(e, whole);
    }

    #[test]
    fn log_histogram_floors_tiny_values_and_clamps_huge_ones() {
        let mut h = LogHistogram::default();
        h.record(0.0);
        h.record(1e-9);
        h.record(1e12); // far past the last bucket edge
        assert_eq!(h.len(), 3);
        // sub-resolution values floor into bucket 0 and report at its
        // 1 µs edge — never above it
        assert!(h.percentile(1.0) <= LogHistogram::LO, "{}", h.percentile(1.0));
        assert_eq!(h.percentile(100.0), 1e12, "p-high clamps to recorded max");
        let empty = LogHistogram::default();
        assert_eq!(empty.percentile(99.0), 0.0);
        assert_eq!(empty.mean(), 0.0);
        assert!(empty.to_json().contains("\"count\":0"));
    }

    #[test]
    fn percentiles_histogram_bridge_preserves_the_distribution() {
        let mut p = Percentiles::default();
        for i in (0..200).rev() {
            p.record(0.001 + i as f64 / 1000.0);
        }
        let h = p.histogram();
        assert_eq!(h.len(), p.len());
        assert!((h.mean() - p.mean()).abs() < 1e-9);
        let (hp, pp) = (h.percentile(99.0), p.percentile(99.0));
        assert!(hp >= pp && hp <= pp * LogHistogram::GROWTH, "{hp} vs {pp}");
    }
}
