//! Baseline comparators and the output-fidelity metric (Table VI).
//!
//! With seeded (not pretrained) weights, QA F1 against gold answers is
//! meaningless; what Table VI actually asks is *how much does dropping
//! cross-document attention perturb the output?* We answer it exactly:
//! generate with Vanilla (full attention), MatKV (independent KVs) and
//! CacheBlend (partial recompute) from the *same* model and compare
//! outputs token-by-token — the paper's accuracy ordering
//! (Vanilla ≈ CacheBlend ≳ MatKV) should and does reproduce as fidelity.

use std::collections::HashMap;

use super::engine::{Response, ServeMode};

/// The paper's CacheBlend configuration: ~18% of retrieved KV recomputed.
/// With 1,024-token documents and a 256-token recompute step this is the
/// closest step-aligned fraction.
pub fn cacheblend_mode(doc_tokens: usize) -> ServeMode {
    let recompute = ((doc_tokens as f64 * 0.18).ceil() as usize).clamp(1, 256);
    ServeMode::CacheBlend { recompute_tokens: recompute }
}

/// Token-level F1 between two sequences (multiset overlap — the standard
/// SQuAD-style F1 applied to generated tokens).
pub fn token_f1(a: &[u32], b: &[u32]) -> f64 {
    if a.is_empty() && b.is_empty() {
        return 1.0;
    }
    if a.is_empty() || b.is_empty() {
        return 0.0;
    }
    let mut counts: HashMap<u32, i64> = HashMap::new();
    for &t in a {
        *counts.entry(t).or_default() += 1;
    }
    let mut common = 0i64;
    for &t in b {
        if let Some(c) = counts.get_mut(&t) {
            if *c > 0 {
                *c -= 1;
                common += 1;
            }
        }
    }
    if common == 0 {
        return 0.0;
    }
    let p = common as f64 / b.len() as f64;
    let r = common as f64 / a.len() as f64;
    2.0 * p * r / (p + r)
}

/// Exact-prefix length (how many leading tokens agree) — a stricter
/// fidelity signal than F1 for greedy decoding.
pub fn prefix_agreement(a: &[u32], b: &[u32]) -> usize {
    a.iter().zip(b).take_while(|(x, y)| x == y).count()
}

/// Mean token-F1 of paired responses (matched by request id).
pub fn mean_f1(reference: &[Response], candidate: &[Response]) -> f64 {
    fidelity(reference, candidate).mean_f1
}

/// Paired output-fidelity summary (the Table-VI harness in one struct):
/// responses are matched by request id and compared token-by-token. The
/// warm-tier bench uses this to price q8-served chunks against the pure
/// f32 path — same model, same requests, only the storage plane differs.
#[derive(Debug, Clone, Copy, Default, PartialEq)]
pub struct Fidelity {
    /// Response pairs that matched by request id.
    pub pairs: usize,
    /// Mean token-level F1 (multiset overlap).
    pub mean_f1: f64,
    /// Mean exact-prefix length in tokens — the stricter greedy-decoding
    /// signal: one early divergent token ends the prefix.
    pub mean_prefix: f64,
    /// Pairs whose outputs matched token-for-token.
    pub exact: usize,
}

/// Compute the paired fidelity summary (see [`Fidelity`]).
pub fn fidelity(reference: &[Response], candidate: &[Response]) -> Fidelity {
    let by_id: HashMap<u64, &Response> = reference.iter().map(|r| (r.request_id, r)).collect();
    let mut out = Fidelity::default();
    for c in candidate {
        if let Some(r) = by_id.get(&c.request_id) {
            out.pairs += 1;
            out.mean_f1 += token_f1(&r.tokens, &c.tokens);
            out.mean_prefix += prefix_agreement(&r.tokens, &c.tokens) as f64;
            out.exact += (r.tokens == c.tokens) as usize;
        }
    }
    if out.pairs > 0 {
        out.mean_f1 /= out.pairs as f64;
        out.mean_prefix /= out.pairs as f64;
    }
    out
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn f1_identical_is_one() {
        assert_eq!(token_f1(&[1, 2, 3], &[1, 2, 3]), 1.0);
    }

    #[test]
    fn f1_disjoint_is_zero() {
        assert_eq!(token_f1(&[1, 2], &[3, 4]), 0.0);
    }

    #[test]
    fn f1_partial_overlap() {
        // a = [1,2,3,4], b = [1,2] → p=1, r=0.5 → F1 = 2/3
        let f1 = token_f1(&[1, 2, 3, 4], &[1, 2]);
        assert!((f1 - 2.0 / 3.0).abs() < 1e-9);
    }

    #[test]
    fn f1_respects_multiplicity() {
        // b has 1 twice but a only once → only one counts
        let f1 = token_f1(&[1, 2], &[1, 1]);
        // common=1, p=0.5, r=0.5 → F1=0.5
        assert!((f1 - 0.5).abs() < 1e-9);
    }

    #[test]
    fn f1_empty_edge_cases() {
        assert_eq!(token_f1(&[], &[]), 1.0);
        assert_eq!(token_f1(&[1], &[]), 0.0);
    }

    #[test]
    fn prefix_agreement_counts() {
        assert_eq!(prefix_agreement(&[1, 2, 3], &[1, 2, 9]), 2);
        assert_eq!(prefix_agreement(&[], &[1]), 0);
    }

    #[test]
    fn fidelity_pairs_by_request_id() {
        let resp = |id: u64, tokens: Vec<u32>| Response {
            request_id: id,
            text: String::new(),
            tokens,
            retrieved: Vec::new(),
        };
        let reference = vec![resp(1, vec![1, 2, 3]), resp(2, vec![4, 5])];
        // candidate arrives reordered; id 9 has no reference pair
        let candidate = vec![resp(2, vec![4, 5]), resp(1, vec![1, 2, 9]), resp(9, vec![7])];
        let f = fidelity(&reference, &candidate);
        assert_eq!(f.pairs, 2);
        assert_eq!(f.exact, 1);
        // prefixes: id 2 → 2 tokens, id 1 → 2 tokens
        assert!((f.mean_prefix - 2.0).abs() < 1e-9);
        // f1: id 2 → 1.0, id 1 → 2/3
        assert!((f.mean_f1 - (1.0 + 2.0 / 3.0) / 2.0).abs() < 1e-9);
        assert_eq!(mean_f1(&reference, &candidate), f.mean_f1);
        assert_eq!(fidelity(&reference, &[]), Fidelity::default());
    }

    #[test]
    fn cacheblend_fraction() {
        match cacheblend_mode(1024) {
            ServeMode::CacheBlend { recompute_tokens } => {
                // 18% of 1024 = 185 (within one 256 step)
                assert_eq!(recompute_tokens, 185);
            }
            _ => panic!(),
        }
    }
}
