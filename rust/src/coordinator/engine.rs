//! The MatKV serve engine (Fig 3b) and its baselines.
//!
//! Three serve modes over identical retrieval and decode phases:
//!
//! * [`ServeMode::MatKv`] — load materialized KVs from flash, splice into
//!   the packed device state, sub-prefill only the query, decode.
//! * [`ServeMode::Vanilla`] — recompute every retrieved chunk's KV on the
//!   device with sequential positions and full cross-document attention
//!   (the paper's full-KV-compute baseline).
//! * [`ServeMode::CacheBlend`] — load KVs, then *recompute* the leading
//!   tokens of every non-first document in context (partial
//!   cross-attention repair, modelling CacheBlend's ~18% recompute).

use std::collections::HashMap;
use std::rc::Rc;
use std::sync::{Arc, RwLock};
use std::time::Instant;

use anyhow::{bail, Context, Result};

use super::metrics::PhaseBreakdown;
use crate::kvstore::{KvStore, TierMetrics};
use crate::manifest::{Manifest, ModelConfig};
use crate::runtime::session::StateBuf;
use crate::runtime::state::argmax;
use crate::runtime::{HostState, ModelSession};
use crate::tokenizer::{Tokenizer, PAD};
use crate::vectordb::{ChunkId, FlatIndex, HashEmbedder, VectorIndex};
use crate::workload::RagRequest;

/// Per-chunk metadata the coordinator keeps beside the vector index.
#[derive(Debug, Clone)]
pub struct ChunkMeta {
    /// Token ids of the chunk (Vanilla recompute needs them; MatKV only
    /// needs them at ingest).
    pub tokens: Vec<u32>,
    pub doc_id: u64,
}

/// Retrieval-side state, shared with the overlap loader thread.
pub struct Retrieval {
    pub tokenizer: Tokenizer,
    pub embedder: HashEmbedder,
    pub index: RwLock<FlatIndex>,
    pub meta: RwLock<HashMap<ChunkId, ChunkMeta>>,
}

impl Retrieval {
    /// Top-K chunk ids for a query string.
    pub fn retrieve(&self, query: &str, k: usize) -> Vec<ChunkId> {
        let q = self.embedder.embed(&self.tokenizer.encode(query));
        self.index.read().unwrap().search(&q, k).into_iter().map(|r| r.chunk_id).collect()
    }

    /// The retrieval stack [`Engine::new`] builds (corpus-seeded
    /// tokenizer, hash embedder, empty flat index + chunk meta) — the
    /// one constructor, shared with PJRT-free harnesses (scheduler
    /// tests, `fig_sched`) so they model the exact retrieval
    /// distribution the engine serves.
    pub fn for_corpus<'a>(
        texts: impl IntoIterator<Item = &'a str>,
        vocab: u32,
        embed_dim: usize,
    ) -> Retrieval {
        Retrieval {
            tokenizer: Tokenizer::from_corpus(texts, vocab),
            embedder: HashEmbedder::new(embed_dim, 0x9a7_f00d),
            index: RwLock::new(FlatIndex::new(embed_dim)),
            meta: RwLock::new(HashMap::new()),
        }
    }
}

/// Serving strategy.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum ServeMode {
    MatKv,
    Vanilla,
    /// Recompute the first `recompute_tokens` of each non-first document
    /// in context (must be a multiple of the chunk step).
    CacheBlend { recompute_tokens: usize },
}

/// Engine construction knobs.
#[derive(Debug, Clone)]
pub struct EngineOptions {
    /// Model config name (tiny/small/base).
    pub config: String,
    /// Query bucket (S=32 artifact).
    pub query_bucket: usize,
    /// Chunked-prefill step (S=256 artifact).
    pub chunk_step: usize,
    /// Serve-time padded context (the C of serve artifacts).
    pub serve_ctx: usize,
    /// Ingest-time padded context (compact C for materialization).
    pub ingest_ctx: usize,
    /// Embedding dimension of the vector DB.
    pub embed_dim: usize,
}

impl EngineOptions {
    pub fn for_config(m: &Manifest, name: &str) -> Result<Self> {
        let cfg = m.config(name)?;
        Ok(EngineOptions {
            config: name.to_string(),
            query_bucket: m.query_bucket,
            chunk_step: m.chunk_tokens,
            serve_ctx: cfg.max_ctx,
            ingest_ctx: cfg.ingest_ctx,
            embed_dim: 128,
        })
    }
}

/// One generated answer.
#[derive(Debug, Clone)]
pub struct Response {
    pub request_id: u64,
    pub tokens: Vec<u32>,
    pub text: String,
    pub retrieved: Vec<ChunkId>,
}

/// A batch staged by the (possibly remote) loader: everything the device
/// needs, all host memory, `Send`.
pub struct StagedBatch {
    pub bucket: usize,
    pub ids: Vec<u64>,
    pub output_tokens: Vec<usize>,
    pub retrieved: Vec<Vec<ChunkId>>,
    pub host_state: HostState,
    pub cache_len: Vec<i32>,
    pub query_tokens: Vec<i32>,
    pub qlen: Vec<i32>,
    /// Doc layout per element: (start_slot, n_tokens) per retrieved doc
    /// (CacheBlend's recompute targets).
    pub doc_slots: Vec<Vec<(usize, usize)>>,
    /// Partial metrics from the staging phase.
    pub metrics: PhaseBreakdown,
}

/// Loader-side context for staging batches off the device thread.
#[derive(Clone)]
pub struct LoaderCtx {
    pub retrieval: Arc<Retrieval>,
    pub kv: Arc<KvStore>,
    pub cfg: ModelConfig,
    pub opts: EngineOptions,
}

impl LoaderCtx {
    /// Batch buckets available (from the manifest artifacts).
    fn batch_bucket(&self, n: usize) -> Result<usize> {
        self.cfg.batch_bucket(n)
    }

    /// Stage a MatKV batch: retrieve, load KVs from the tiered store
    /// (DRAM hot tier, then the q8 warm tier, then flash), splice into a
    /// host state (Fig 3b steps 1-2). No device work.
    pub fn stage_matkv(&self, reqs: &[RagRequest]) -> Result<StagedBatch> {
        self.stage_matkv_with(reqs, None)
    }

    /// [`LoaderCtx::stage_matkv`] with the retrieval top-K already known
    /// (`retrieved[i]` pairs with `reqs[i]`): the scheduler pays for
    /// retrieval once at plan time, so staging a planned batch must not
    /// run the vector-DB search a second time.
    pub fn stage_matkv_with(
        &self,
        reqs: &[RagRequest],
        retrieved: Option<&[Vec<ChunkId>]>,
    ) -> Result<StagedBatch> {
        let bucket = self.batch_bucket(reqs.len())?;
        let mut staged = self.stage_common(reqs, bucket, retrieved)?;

        let t0 = Instant::now();
        // flatten (element, doc) pairs and load them all concurrently
        let flat: Vec<(usize, ChunkId)> = staged
            .retrieved
            .iter()
            .enumerate()
            .flat_map(|(b, ids)| ids.iter().map(move |&id| (b, id)))
            .collect();
        let ids: Vec<ChunkId> = flat.iter().map(|&(_, id)| id).collect();
        let loaded = self.kv.load_many(&ids)?;
        let expect_cfg = crate::kvstore::store::config_id(&self.cfg);
        for ((b, _), l) in flat.iter().zip(&loaded) {
            if l.chunk.config_id != expect_cfg {
                bail!(
                    "materialized KV was produced by a different model config \
                     ({:#x} != {:#x}) — re-ingest after changing configs",
                    l.chunk.config_id,
                    expect_cfg
                );
            }
            let slot = staged.cache_len[*b] as usize;
            staged.host_state.splice_chunk(*b, slot, &l.chunk)?;
            staged.doc_slots[*b].push((slot, l.chunk.seq_len as usize));
            staged.cache_len[*b] += l.chunk.seq_len as i32;
            staged.metrics.loaded_tokens += l.chunk.seq_len as usize;
            staged.metrics.quant_secs += l.quant_secs;
            // q4 unpack rides on both rungs (v4 flash reads and q4-mode
            // warm hits), so accumulate it outside the from_warm branch.
            staged.metrics.q4_dequant_secs += l.q4_dequant_secs;
            if l.quant_secs > 0.0 {
                // This load quantized its chunk into the warm tier:
                // the arch-scale costing charges the symmetric pass.
                staged.metrics.warm_admit_tokens += l.chunk.seq_len as usize;
            }
            staged.metrics.retries += l.retries;
            staged.metrics.retry_backoff_secs += l.retry_backoff_secs;
            staged.metrics.checksum_failures += l.checksum_failures;
            if l.recomputed {
                // Served by the Vanilla recompute safety net: no healthy
                // flash read backs these tokens.
                staged.metrics.recomputed_chunks += 1;
                staged.metrics.recompute_fallback_secs += l.recompute_secs;
                staged.metrics.degraded_tokens += l.chunk.seq_len as usize;
            }
            if l.from_warm {
                staged.metrics.warm_hits += 1;
                staged.metrics.warm_tokens += l.chunk.seq_len as usize;
                staged.metrics.warm_bytes_saved += l.file_bytes;
                staged.metrics.dequant_secs += l.dequant_secs;
            } else if l.from_cache {
                staged.metrics.cache_hits += 1;
                staged.metrics.cache_tokens += l.chunk.seq_len as usize;
                staged.metrics.cache_bytes_saved += l.file_bytes;
            } else {
                staged.metrics.load_device_secs += l.device_secs;
                staged.metrics.loaded_bytes += l.file_bytes;
                staged.metrics.load_reads += 1;
                staged.metrics.record_shard_read(l.shard, l.file_bytes, l.device_secs);
            }
        }
        staged.metrics.shard_peak_queue = self.kv.shard_peak_queues();
        staged.metrics.load_wall_secs = t0.elapsed().as_secs_f64();
        Ok(staged)
    }

    /// Stage a Vanilla batch: retrieval only (chunks will be recomputed
    /// on-device from their tokens).
    pub fn stage_vanilla(&self, reqs: &[RagRequest]) -> Result<StagedBatch> {
        self.stage_vanilla_with(reqs, None)
    }

    /// [`LoaderCtx::stage_vanilla`] with precomputed retrieval (see
    /// [`LoaderCtx::stage_matkv_with`]).
    pub fn stage_vanilla_with(
        &self,
        reqs: &[RagRequest],
        retrieved: Option<&[Vec<ChunkId>]>,
    ) -> Result<StagedBatch> {
        let bucket = self.batch_bucket(reqs.len())?;
        let mut staged = self.stage_common(reqs, bucket, retrieved)?;
        // record doc layout (slots assigned sequentially at prefill time)
        let meta = self.retrieval.meta.read().unwrap();
        for b in 0..staged.retrieved.len() {
            let mut slot = 0usize;
            for id in &staged.retrieved[b] {
                let m = meta.get(id).context("missing chunk meta")?;
                staged.doc_slots[b].push((slot, m.tokens.len()));
                slot += m.tokens.len();
            }
        }
        Ok(staged)
    }

    /// Shared staging: retrieval (or reuse of the scheduler's planned
    /// top-K), query tokenization, zero host state.
    fn stage_common(
        &self,
        reqs: &[RagRequest],
        bucket: usize,
        precomputed: Option<&[Vec<ChunkId>]>,
    ) -> Result<StagedBatch> {
        if reqs.is_empty() || reqs.len() > bucket {
            bail!("batch of {} vs bucket {bucket}", reqs.len());
        }
        let qb = self.opts.query_bucket;
        let mut metrics = PhaseBreakdown { requests: reqs.len(), ..Default::default() };

        let t0 = Instant::now();
        let retrieved: Vec<Vec<ChunkId>> = match precomputed {
            Some(r) => {
                anyhow::ensure!(
                    r.len() == reqs.len(),
                    "precomputed retrieval for {} requests but batch has {}",
                    r.len(),
                    reqs.len()
                );
                r.to_vec()
            }
            None => reqs.iter().map(|r| self.retrieval.retrieve(&r.query, r.top_k)).collect(),
        };
        metrics.retrieve_secs = t0.elapsed().as_secs_f64();

        let mut query_tokens = vec![PAD as i32; bucket * qb];
        let mut qlen = vec![1i32; bucket];
        for (b, r) in reqs.iter().enumerate() {
            let (ids, live) = self.retrieval.tokenizer.encode_block(&r.query, qb);
            for (i, id) in ids.iter().enumerate() {
                query_tokens[b * qb + i] = *id as i32;
            }
            qlen[b] = live.max(1) as i32;
        }

        Ok(StagedBatch {
            bucket,
            ids: reqs.iter().map(|r| r.id).collect(),
            output_tokens: reqs.iter().map(|r| r.output_tokens).collect(),
            retrieved,
            host_state: HostState::zeros(&self.cfg, bucket, self.opts.serve_ctx),
            cache_len: vec![0; bucket],
            query_tokens,
            qlen,
            doc_slots: vec![Vec::new(); bucket],
            metrics,
        })
    }
}

/// The serve engine: owns the device session plus shared retrieval/KV
/// state (the latter shareable with a loader thread via [`LoaderCtx`]).
pub struct Engine {
    pub session: ModelSession,
    pub retrieval: Arc<Retrieval>,
    pub kv: Arc<KvStore>,
    pub opts: EngineOptions,
    cfg: ModelConfig,
}

impl Engine {
    /// Build an engine. `corpus_texts` seeds the tokenizer vocabulary.
    pub fn new<'a>(
        manifest: &Manifest,
        opts: EngineOptions,
        kv: KvStore,
        corpus_texts: impl IntoIterator<Item = &'a str>,
    ) -> Result<Self> {
        let session = ModelSession::new(manifest, &opts.config)?;
        let cfg = session.config().clone();
        let retrieval =
            Arc::new(Retrieval::for_corpus(corpus_texts, cfg.vocab as u32, opts.embed_dim));
        Ok(Engine { session, retrieval, kv: Arc::new(kv), opts, cfg })
    }

    pub fn config(&self) -> &ModelConfig {
        &self.cfg
    }

    /// Context for staging work off-thread (overlap pipeline).
    pub fn loader_ctx(&self) -> LoaderCtx {
        LoaderCtx {
            retrieval: self.retrieval.clone(),
            kv: self.kv.clone(),
            cfg: self.cfg.clone(),
            opts: self.opts.clone(),
        }
    }

    /// Fresh zero state. NOT cached/shared: the AOT entries donate the
    /// state parameter (input_output_alias), so a state buffer must never
    /// be fed to step() twice.
    fn zero_state(&self, bucket: usize, ctx: usize) -> Result<Rc<StateBuf>> {
        Ok(Rc::new(self.session.zero_state(bucket, ctx)?))
    }

    /// Serve one batch end-to-end in the given mode.
    pub fn serve_batch(
        &self,
        reqs: &[RagRequest],
        mode: ServeMode,
    ) -> Result<(Vec<Response>, PhaseBreakdown)> {
        let ctx = self.loader_ctx();
        let staged = match mode {
            ServeMode::MatKv | ServeMode::CacheBlend { .. } => ctx.stage_matkv(reqs)?,
            ServeMode::Vanilla => ctx.stage_vanilla(reqs)?,
        };
        self.exec_staged(staged, mode)
    }

    /// Device half: upload/prefill/decode a staged batch.
    pub fn exec_staged(
        &self,
        staged: StagedBatch,
        mode: ServeMode,
    ) -> Result<(Vec<Response>, PhaseBreakdown)> {
        let total_t0 = Instant::now();
        let mut m = staged.metrics.clone();
        let bucket = staged.bucket;
        let ctx = self.opts.serve_ctx;
        let n = staged.ids.len();

        // ---- state setup -------------------------------------------------
        let t0 = Instant::now();
        let (mut state, mut cache_len): (Rc<StateBuf>, Vec<i32>) = match mode {
            ServeMode::MatKv | ServeMode::CacheBlend { .. } => {
                let st = self.session.upload_state(&staged.host_state)?;
                (Rc::new(st), staged.cache_len.clone())
            }
            ServeMode::Vanilla => (self.zero_state(bucket, ctx)?, vec![0; bucket]),
        };
        m.upload_secs = t0.elapsed().as_secs_f64();

        // ---- prefill -----------------------------------------------------
        let t0 = Instant::now();
        if mode == ServeMode::Vanilla {
            // chunked recompute of every retrieved document, sequential
            // positions, cross-document attention intact.
            let step = self.opts.chunk_step;
            let meta = self.retrieval.meta.read().unwrap();
            let mut doc_tokens: Vec<Vec<u32>> = vec![Vec::new(); bucket];
            for b in 0..n {
                for id in &staged.retrieved[b] {
                    doc_tokens[b].extend(&meta.get(id).context("chunk meta")?.tokens);
                }
            }
            drop(meta);
            // Guard the whole budget up front: recomputed docs + query +
            // decode all advance the same cache, and stepping past C
            // would silently attend garbage instead of failing.
            for b in 0..n {
                let need = doc_tokens[b].len()
                    + staged.qlen[b] as usize
                    + staged.output_tokens[b].saturating_sub(1);
                if need > ctx {
                    bail!(
                        "request {}: {} doc tokens + {} query + {} decode budget exceeds serve context {ctx}",
                        staged.ids[b],
                        doc_tokens[b].len(),
                        staged.qlen[b],
                        staged.output_tokens[b],
                    );
                }
            }
            let mut off = vec![0usize; bucket];
            loop {
                let mut any = false;
                let mut tokens = vec![PAD as i32; bucket * step];
                let mut qlen = vec![1i32; bucket];
                let mut adv = vec![0i32; bucket];
                for b in 0..bucket {
                    let rem = doc_tokens[b].len().saturating_sub(off[b]);
                    if rem == 0 {
                        continue;
                    }
                    any = true;
                    let take = rem.min(step);
                    for i in 0..take {
                        tokens[b * step + i] = doc_tokens[b][off[b] + i] as i32;
                    }
                    qlen[b] = take as i32;
                    adv[b] = take as i32;
                    m.prefill_trace.record_elem(take, cache_len[b] as usize + take);
                }
                if !any {
                    break;
                }
                m.prefill_trace.record_step();
                state = Rc::new(self.session.step(&tokens, &qlen, &cache_len, &state)?);
                for b in 0..bucket {
                    cache_len[b] += adv[b];
                    off[b] += adv[b] as usize;
                }
            }
        } else if let ServeMode::CacheBlend { recompute_tokens } = mode {
            // partial recompute: leading tokens of every non-first doc,
            // in-context (cross-attending everything before them).
            let step = self.opts.chunk_step;
            let meta = self.retrieval.meta.read().unwrap();
            for doc_i in 1..staged.doc_slots.iter().map(|d| d.len()).max().unwrap_or(0) {
                let mut tokens = vec![PAD as i32; bucket * step];
                let mut qlen = vec![1i32; bucket];
                let mut clen = vec![0i32; bucket];
                let mut any = false;
                for b in 0..n {
                    let Some(&(slot, len)) = staged.doc_slots[b].get(doc_i) else { continue };
                    let take = recompute_tokens.min(len).min(step);
                    if take == 0 {
                        continue;
                    }
                    let id = staged.retrieved[b][doc_i];
                    let toks = &meta.get(&id).context("chunk meta")?.tokens;
                    for i in 0..take {
                        tokens[b * step + i] = toks[i] as i32;
                    }
                    qlen[b] = take as i32;
                    clen[b] = slot as i32;
                    any = true;
                    m.prefill_trace.record_elem(take, slot + take);
                }
                if any {
                    m.prefill_trace.record_step();
                    state = Rc::new(self.session.step(&tokens, &qlen, &clen, &state)?);
                }
            }
        }

        // query sub-prefill (all modes). The splice/prefill paths only
        // guarantee the *documents* fit; the query must too, or this
        // step writes KV past C and attends garbage. (Decode, by
        // contrast, is allowed to run out of context — it breaks early
        // and tokens_out reports what was actually generated.)
        for b in 0..n {
            if (cache_len[b] + staged.qlen[b]) as usize > ctx {
                bail!(
                    "request {}: query of {} tokens does not fit after {} cached tokens \
                     (serve context {ctx})",
                    staged.ids[b],
                    staged.qlen[b],
                    cache_len[b],
                );
            }
        }
        for b in 0..n {
            m.prefill_trace
                .record_elem(staged.qlen[b] as usize, (cache_len[b] + staged.qlen[b]) as usize);
        }
        m.prefill_trace.record_step();
        state = Rc::new(self.session.step(&staged.query_tokens, &staged.qlen, &cache_len, &state)?);
        for b in 0..bucket {
            cache_len[b] += staged.qlen[b];
        }
        m.prefill_wall_secs = t0.elapsed().as_secs_f64();

        // ---- decode (greedy) ----------------------------------------------
        let t0 = Instant::now();
        let v = self.cfg.vocab;
        let max_out = staged.output_tokens.iter().copied().max().unwrap_or(0);
        let mut generated: Vec<Vec<u32>> = vec![Vec::new(); n];
        if max_out > 0 {
            let logits = self.session.read_logits(&state)?;
            let mut next: Vec<i32> =
                (0..bucket).map(|b| argmax(&logits[b * v..(b + 1) * v]) as i32).collect();
            for (b, g) in generated.iter_mut().enumerate() {
                g.push(next[b] as u32);
            }
            for _ in 1..max_out {
                if cache_len.iter().any(|&c| c as usize + 1 > ctx) {
                    break; // context exhausted
                }
                for b in 0..n {
                    m.decode_trace.record_elem(1, cache_len[b] as usize + 1);
                }
                m.decode_trace.record_step();
                state = Rc::new(self.session.step(&next, &vec![1i32; bucket], &cache_len, &state)?);
                for c in cache_len.iter_mut() {
                    *c += 1;
                }
                let logits = self.session.read_logits(&state)?;
                next = (0..bucket).map(|b| argmax(&logits[b * v..(b + 1) * v]) as i32).collect();
                for (b, g) in generated.iter_mut().enumerate() {
                    g.push(next[b] as u32);
                }
            }
        }
        m.decode_wall_secs = t0.elapsed().as_secs_f64();

        // ---- package -------------------------------------------------------
        let responses: Vec<Response> = (0..n)
            .map(|b| {
                let want = staged.output_tokens[b].min(generated[b].len());
                let tokens: Vec<u32> = generated[b][..want].to_vec();
                Response {
                    request_id: staged.ids[b],
                    text: self.retrieval.tokenizer.decode(&tokens),
                    tokens,
                    retrieved: staged.retrieved[b].clone(),
                }
            })
            .collect();
        // Count what was actually generated — decode can break early on
        // context exhaustion, and throughput must not be flattered by
        // the *requested* budget.
        m.tokens_out = responses.iter().map(|r| r.tokens.len()).sum();
        m.total_wall_secs = total_t0.elapsed().as_secs_f64();
        // One telemetry sample per executed batch and per tier: the
        // hit/miss/eviction time series the serve-time telemetry benches
        // plot (tier-labeled, so hot and warm stay distinguishable).
        if let Some(tier) = self.kv.hot_tier() {
            tier.sample();
        }
        if let Some(tier) = self.kv.warm_tier() {
            tier.sample();
        }
        // Unclocked batch mark (the engine runs on wall time): payload
        // only, so the deterministic trace sees the executed batch shape
        // but never a wall timestamp.
        self.kv.trace().mark("engine", "batch", &[
            ("bucket", crate::trace::Arg::U(bucket as u64)),
            ("n", crate::trace::Arg::U(n as u64)),
            ("tokens_out", crate::trace::Arg::U(m.tokens_out as u64)),
        ]);
        Ok((responses, m))
    }

    /// Serve a request list in fixed-size batches (no overlap). A thin
    /// wrapper over [`Scheduler::run`]: the offline FIFO schedule
    /// reproduces the historical `reqs.chunks(batch_size)` slicing
    /// exactly, so batch formation lives in one place.
    ///
    /// [`Scheduler::run`]: super::scheduler::Scheduler::run
    pub fn serve_all(
        &self,
        reqs: &[RagRequest],
        batch_size: usize,
        mode: ServeMode,
    ) -> Result<(Vec<Response>, PhaseBreakdown)> {
        let mut sched = super::scheduler::Scheduler::offline(self.loader_ctx(), batch_size);
        sched.enqueue_now(reqs.iter().cloned());
        let out = sched.run(self, mode, &super::scheduler::ExecOptions::sequential())?;
        Ok((out.responses, out.metrics))
    }
}
