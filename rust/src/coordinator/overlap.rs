//! The §III-C overlap pipeline: SSD KV loading for batch *n+1* proceeds
//! concurrently with device decode of batch *n*.
//!
//! A loader thread owns the host-only half of the serve path (retrieval,
//! throttled KV loads, state assembly — everything in [`LoaderCtx`]) and
//! feeds staged batches through a bounded channel to the executor thread,
//! which owns the PJRT session (device objects are not `Send`; they never
//! leave that thread). Channel capacity 1 gives classic double buffering:
//! at steady state the storage device and the compute device are both
//! busy, which is exactly the paper's Fig 4.
//!
//! Since the scheduler refactor the pipeline consumes a **planned
//! schedule** ([`PlannedBatch`]) rather than slicing the request list
//! itself: batch formation — including tier-affinity grouping and the
//! size-or-timeout release condition — happens once, in
//! [`super::scheduler::Scheduler`], and [`serve_overlapped_with`] is a
//! thin wrapper that plans a FIFO offline schedule and runs it here.
//!
//! The loader goes through the tiered store: DRAM hits — hot-tier f32
//! for free, q8 warm-tier at a modeled dequant cost — shave their
//! chunks' throttled device reads off the loader's critical path, which
//! shrinks `loader_busy_secs` and with it the only stage that can stall
//! the executor. Per-batch hit counts surface in the aggregated
//! [`PhaseBreakdown`] (`cache_hits`/`cache_bytes_saved` for hot,
//! `warm_hits`/`warm_bytes_saved`/`dequant_secs` for warm).
//!
//! **Retrieval-aware prefetch** ([`OverlapOptions::prefetch`]) adds a
//! third thread: the scheduler already knows every upcoming batch's
//! retrieval top-K (it scored them to form the schedule), so the
//! prefetcher reads those chunk sets straight from the plan — no
//! retrieval re-runs — a bounded lookahead ahead of the executor and
//! warms the hot tier via [`KvStore::prefetch_many`]'s protected
//! admission path. Chunks the prefetcher lands become tier hits when the
//! loader reaches that batch — device reads move off the loader's
//! critical path onto a thread whose time was previously spent blocked
//! on the staging channel. The lookahead is paced by executor progress
//! so prefetched chunks aren't evicted (by later prefetches) before
//! their batch needs them.
//!
//! [`LoaderCtx`]: super::engine::LoaderCtx
//! [`PlannedBatch`]: super::scheduler::PlannedBatch
//! [`KvStore::prefetch_many`]: crate::kvstore::KvStore::prefetch_many

use std::sync::atomic::{AtomicBool, AtomicUsize, Ordering};
use std::sync::mpsc;
use std::time::{Duration, Instant};

use anyhow::{Context, Result};

use super::engine::{Engine, Response, ServeMode, StagedBatch};
use super::metrics::PhaseBreakdown;
use super::scheduler::{ExecOptions, PlannedBatch, Scheduler};
use crate::workload::RagRequest;

/// Knobs for [`serve_overlapped_with`].
#[derive(Debug, Clone)]
pub struct OverlapOptions {
    /// Warm the DRAM tiers for upcoming batches from their retrieval
    /// top-K (requires the store to have a hot or warm tier; a no-op
    /// otherwise — see [`crate::kvstore::KvStore::prefetch_many`]).
    pub prefetch: bool,
    /// How many batches past the last *executed* one the prefetcher may
    /// run ahead (≥ 1). The loader itself pipelines up to 2 batches
    /// ahead of the executor (one staged in the channel, one staging),
    /// and the prefetcher never touches a batch the loader has claimed,
    /// so the default of 2 targets exactly the next batch the loader
    /// will stage. Larger values warm further ahead at the risk of
    /// later prefetches displacing earlier ones before use.
    pub lookahead: usize,
}

impl Default for OverlapOptions {
    fn default() -> Self {
        OverlapOptions { prefetch: false, lookahead: 2 }
    }
}

/// Timing summary of an overlapped run.
#[derive(Debug, Clone, Default)]
pub struct OverlapReport {
    /// Total wall time of the overlapped run.
    pub wall_secs: f64,
    /// Loader-thread busy time (staging, throttled loads).
    pub loader_busy_secs: f64,
    /// Executor-thread busy time (upload + prefill + decode).
    pub exec_busy_secs: f64,
    /// Executor time spent blocked waiting for the loader (pipeline
    /// bubble — ~0 when SSD bandwidth keeps up, the paper's claim).
    pub exec_stall_secs: f64,
    pub batches: usize,
    /// Prefetcher busy time (throttled tier warming); overlaps the
    /// executor, so it is not on the critical path.
    pub prefetch_busy_secs: f64,
    /// Chunks the prefetcher admitted to the hot tier.
    pub prefetch_warmed: usize,
    /// Prefetch requests that were already resident.
    pub prefetch_already_resident: usize,
    /// Prefetch requests missing/unreadable on flash (left to demand).
    pub prefetch_absent: usize,
    /// Prefetch admissions refused to protect demand-resident chunks.
    pub prefetch_rejected: usize,
    /// Simulated device seconds consumed by prefetch reads.
    pub prefetch_device_secs: f64,
}

impl OverlapReport {
    /// Fold another report's prefetch counters into this one. The
    /// single merge point for every `prefetch_*` field, so adding a
    /// counter to the struct can't silently drop it from the rollup.
    pub fn merge_prefetch(&mut self, totals: &OverlapReport) {
        self.prefetch_busy_secs += totals.prefetch_busy_secs;
        self.prefetch_warmed += totals.prefetch_warmed;
        self.prefetch_already_resident += totals.prefetch_already_resident;
        self.prefetch_absent += totals.prefetch_absent;
        self.prefetch_rejected += totals.prefetch_rejected;
        self.prefetch_device_secs += totals.prefetch_device_secs;
    }
}

/// Serve requests in fixed-size batches with load/decode overlap
/// (defaults: no prefetch). See [`serve_overlapped_with`].
pub fn serve_overlapped(
    engine: &Engine,
    reqs: &[RagRequest],
    batch_size: usize,
    mode: ServeMode,
) -> Result<(Vec<Response>, PhaseBreakdown, OverlapReport)> {
    serve_overlapped_with(engine, reqs, batch_size, mode, &OverlapOptions::default())
}

/// Serve requests in fixed-size batches with load/decode overlap and,
/// optionally, retrieval-aware hot-tier prefetch. A thin wrapper over
/// [`Scheduler::run`]: FIFO policy with offline arrivals reproduces the
/// historical `reqs.chunks(batch_size)` batching exactly.
///
/// MatKV only (Vanilla has no load phase to hide; the engine rejects it).
pub fn serve_overlapped_with(
    engine: &Engine,
    reqs: &[RagRequest],
    batch_size: usize,
    mode: ServeMode,
    opts: &OverlapOptions,
) -> Result<(Vec<Response>, PhaseBreakdown, OverlapReport)> {
    let mut sched = Scheduler::offline(engine.loader_ctx(), batch_size);
    sched.enqueue_now(reqs.iter().cloned());
    let out = sched.run(engine, mode, &ExecOptions::overlapped(opts.clone()))?;
    Ok((out.responses, out.metrics, out.overlap))
}

/// Drive a planned schedule through the loader/executor (and optional
/// prefetcher) threads. The scheduler calls this; everything below is
/// the §III-C machinery.
pub(crate) fn run_pipeline(
    engine: &Engine,
    batches: &[PlannedBatch],
    mode: ServeMode,
    opts: &OverlapOptions,
) -> Result<(Vec<Response>, PhaseBreakdown, OverlapReport)> {
    anyhow::ensure!(
        !matches!(mode, ServeMode::Vanilla),
        "overlap requires a load phase (MatKv or CacheBlend)"
    );
    let loader_ctx = engine.loader_ctx();
    let n_batches = batches.len();
    let (tx, rx) = mpsc::sync_channel::<Result<(StagedBatch, f64)>>(1);

    let wall_t0 = Instant::now();
    let mut report = OverlapReport { batches: n_batches, ..Default::default() };
    let mut responses = Vec::with_capacity(batches.iter().map(|b| b.reqs.len()).sum());
    let mut agg = PhaseBreakdown::default();

    // Prefetcher pacing: `executed` counts batches the executor has
    // finished, `claimed` counts batches the loader has *started*
    // staging (the prefetcher must never double-read a batch the loader
    // is already demand-loading — the tier would miss for both and the
    // same chunks would charge the shard throttles twice). A stop latch
    // set on executor exit (success or error) bounds the prefetcher.
    let executed = AtomicUsize::new(0);
    let claimed = AtomicUsize::new(0);
    let stop = AtomicBool::new(false);

    std::thread::scope(|scope| -> Result<()> {
        let prefetch_handle = if opts.prefetch {
            let kv = engine.kv.clone();
            let executed = &executed;
            let claimed = &claimed;
            let stop = &stop;
            let lookahead = opts.lookahead.max(1);
            Some(scope.spawn(move || {
                let mut totals = OverlapReport::default();
                // Batch 0 is claimed by the loader immediately.
                for (i, batch) in batches.iter().enumerate().skip(1) {
                    while i > executed.load(Ordering::Acquire).saturating_add(lookahead) {
                        if stop.load(Ordering::Acquire) {
                            return totals;
                        }
                        std::thread::sleep(Duration::from_micros(200));
                    }
                    if stop.load(Ordering::Acquire) {
                        return totals;
                    }
                    if i < claimed.load(Ordering::Acquire) {
                        continue; // loader already staging/staged it
                    }
                    // The scheduler planned this batch, so its top-K is
                    // already known — warm straight from the plan.
                    let ids = batch.chunk_ids();
                    if ids.is_empty() {
                        continue;
                    }
                    let t0 = Instant::now();
                    let rep = kv.prefetch_many(&ids);
                    totals.prefetch_busy_secs += t0.elapsed().as_secs_f64();
                    totals.prefetch_warmed += rep.warmed;
                    totals.prefetch_already_resident += rep.already_resident;
                    totals.prefetch_absent += rep.absent;
                    totals.prefetch_rejected += rep.rejected;
                    totals.prefetch_device_secs += rep.device_secs;
                }
                totals
            }))
        } else {
            None
        };

        {
            let claimed = &claimed;
            scope.spawn(move || {
                for (i, batch) in batches.iter().enumerate() {
                    claimed.store(i + 1, Ordering::Release);
                    let t0 = Instant::now();
                    // The plan's retrieval (when computed) is reused so
                    // the vector-DB search runs once per request.
                    let staged =
                        loader_ctx.stage_matkv_with(&batch.reqs, batch.planned_retrieval());
                    let busy = t0.elapsed().as_secs_f64();
                    // Unclocked (wall-clock thread): payload only, and
                    // the batch index keys the mark uniquely, so the
                    // export stays deterministic under any interleave.
                    loader_ctx.kv.trace().mark("pipeline", "staged", &[
                        ("batch", crate::trace::Arg::U(i as u64)),
                        ("n", crate::trace::Arg::U(batch.reqs.len() as u64)),
                    ]);
                    if tx.send(staged.map(|s| (s, busy))).is_err() {
                        return; // executor hung up (error path)
                    }
                }
            });
        }

        let mut run = || -> Result<()> {
            for i in 0..n_batches {
                let t0 = Instant::now();
                let (staged, loader_busy) = rx.recv().context("loader thread died")??;
                report.exec_stall_secs += t0.elapsed().as_secs_f64();
                report.loader_busy_secs += loader_busy;

                let t0 = Instant::now();
                let (r, m) = engine.exec_staged(staged, mode)?;
                report.exec_busy_secs += t0.elapsed().as_secs_f64();
                engine.kv.trace().mark("pipeline", "executed", &[
                    ("batch", crate::trace::Arg::U(i as u64)),
                    ("n", crate::trace::Arg::U(r.len() as u64)),
                ]);
                responses.extend(r);
                agg.add(&m);
                executed.store(i + 1, Ordering::Release);
            }
            Ok(())
        };
        let result = run();
        stop.store(true, Ordering::Release);
        // Unblock the loader before the scope joins it: on an executor
        // error it may be parked in `send` with a staged batch nobody
        // will receive — dropping the receiver turns that into a send
        // error and a clean loader exit (instead of a deadlocked join).
        drop(rx);
        if let Some(handle) = prefetch_handle {
            let totals = handle.join().map_err(|_| anyhow::anyhow!("prefetch thread panicked"))?;
            report.merge_prefetch(&totals);
        }
        result
    })?;

    report.wall_secs = wall_t0.elapsed().as_secs_f64();
    agg.total_wall_secs = report.wall_secs; // end-to-end, not sum of phases
    Ok((responses, agg, report))
}
