//! The §III-C overlap pipeline: SSD KV loading for batch *n+1* proceeds
//! concurrently with device decode of batch *n*.
//!
//! A loader thread owns the host-only half of the serve path (retrieval,
//! throttled KV loads, state assembly — everything in [`LoaderCtx`]) and
//! feeds staged batches through a bounded channel to the executor thread,
//! which owns the PJRT session (device objects are not `Send`; they never
//! leave that thread). Channel capacity 1 gives classic double buffering:
//! at steady state the storage device and the compute device are both
//! busy, which is exactly the paper's Fig 4.
//!
//! The loader goes through the tiered store: DRAM hot-tier hits shave
//! their chunks off the loader's critical path entirely (no throttled
//! device read), which shrinks `loader_busy_secs` and with it the only
//! stage that can stall the executor. Per-batch hit counts surface in
//! the aggregated [`PhaseBreakdown`] (`cache_hits`/`cache_bytes_saved`).

use std::sync::mpsc;
use std::time::Instant;

use anyhow::{Context, Result};

use super::engine::{Engine, Response, ServeMode, StagedBatch};
use super::metrics::PhaseBreakdown;
use crate::workload::RagRequest;

/// Timing summary of an overlapped run.
#[derive(Debug, Clone, Default)]
pub struct OverlapReport {
    /// Total wall time of the overlapped run.
    pub wall_secs: f64,
    /// Loader-thread busy time (staging, throttled loads).
    pub loader_busy_secs: f64,
    /// Executor-thread busy time (upload + prefill + decode).
    pub exec_busy_secs: f64,
    /// Executor time spent blocked waiting for the loader (pipeline
    /// bubble — ~0 when SSD bandwidth keeps up, the paper's claim).
    pub exec_stall_secs: f64,
    pub batches: usize,
}

/// Serve requests in fixed-size batches with load/decode overlap.
///
/// MatKV only (Vanilla has no load phase to hide; the engine rejects it).
pub fn serve_overlapped(
    engine: &Engine,
    reqs: &[RagRequest],
    batch_size: usize,
    mode: ServeMode,
) -> Result<(Vec<Response>, PhaseBreakdown, OverlapReport)> {
    anyhow::ensure!(
        !matches!(mode, ServeMode::Vanilla),
        "overlap requires a load phase (MatKv or CacheBlend)"
    );
    let ctx = engine.loader_ctx();
    let batches: Vec<Vec<RagRequest>> = reqs.chunks(batch_size).map(|c| c.to_vec()).collect();
    let n_batches = batches.len();
    let (tx, rx) = mpsc::sync_channel::<Result<(StagedBatch, f64)>>(1);

    let wall_t0 = Instant::now();
    let mut report = OverlapReport { batches: n_batches, ..Default::default() };
    let mut responses = Vec::with_capacity(reqs.len());
    let mut agg = PhaseBreakdown::default();

    std::thread::scope(|scope| -> Result<()> {
        scope.spawn(move || {
            for batch in batches {
                let t0 = Instant::now();
                let staged = ctx.stage_matkv(&batch);
                let busy = t0.elapsed().as_secs_f64();
                if tx.send(staged.map(|s| (s, busy))).is_err() {
                    return; // executor hung up (error path)
                }
            }
        });

        for _ in 0..n_batches {
            let t0 = Instant::now();
            let (staged, loader_busy) = rx.recv().context("loader thread died")??;
            report.exec_stall_secs += t0.elapsed().as_secs_f64();
            report.loader_busy_secs += loader_busy;

            let t0 = Instant::now();
            let (r, m) = engine.exec_staged(staged, mode)?;
            report.exec_busy_secs += t0.elapsed().as_secs_f64();
            responses.extend(r);
            agg.add(&m);
        }
        Ok(())
    })?;

    report.wall_secs = wall_t0.elapsed().as_secs_f64();
    agg.total_wall_secs = report.wall_secs; // end-to-end, not sum of phases
    Ok((responses, agg, report))
}
