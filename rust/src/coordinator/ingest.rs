//! Ingest pipeline (Fig 3a): document → vector-DB insert + device prefill
//! → KV materialization on flash (write-behind).
//!
//! Documents are prefilled in batches through the compact-context ingest
//! artifacts (C = 1024 instead of the serve C) in 256-token steps; the
//! finished cache region is extracted per document and written to the KV
//! store asynchronously while the next batch prefills — the ingest-side
//! analogue of the serve-side overlap.

use std::time::Instant;

use anyhow::{bail, Result};

use super::engine::{ChunkMeta, Engine};
use super::metrics::WorkTrace;
use crate::kvstore::store::config_id;
use crate::tokenizer::PAD;
use crate::vectordb::VectorIndex;
use crate::workload::Corpus;

/// Ingest statistics (paper Table "materialization cost" discussions).
#[derive(Debug, Clone, Default)]
pub struct IngestStats {
    pub docs: usize,
    pub tokens: usize,
    /// Measured device wall time spent prefilling.
    pub prefill_wall_secs: f64,
    /// Executed prefill work (cost under any arch via ArchSpec).
    pub prefill_trace: WorkTrace,
    /// Simulated storage seconds writing materialized KVs.
    pub write_device_secs: f64,
    /// Bytes materialized.
    pub materialized_bytes: usize,
}

/// Convenience alias so callers can use `Ingestor::ingest(...)`.
pub struct Ingestor;

impl Engine {
    /// Ingest a corpus: every document becomes one retrieval unit whose
    /// KV cache is materialized. `doc_tokens` must be a multiple of the
    /// chunk step and fit the ingest context.
    pub fn ingest_corpus(&self, corpus: &Corpus, doc_tokens: usize) -> Result<IngestStats> {
        let step = self.opts.chunk_step;
        let ingest_ctx = self.opts.ingest_ctx;
        if doc_tokens % step != 0 || doc_tokens > ingest_ctx {
            bail!("doc_tokens {doc_tokens} must be a multiple of {step} and <= {ingest_ctx}");
        }
        let cfg = self.config().clone();
        let cfg_id = config_id(&cfg);
        let bucket = 8.min(corpus.docs.len().next_power_of_two());
        let bucket = cfg.batch_bucket(bucket.min(8))?;
        let n_steps = doc_tokens / step;
        let mut stats = IngestStats::default();
        let mut pending = Vec::new();

        for docs in corpus.docs.chunks(bucket) {
            // tokenize + register in the vector DB
            let mut tok_rows: Vec<Vec<u32>> = Vec::with_capacity(docs.len());
            {
                let mut index = self.retrieval.index.write().unwrap();
                let mut meta = self.retrieval.meta.write().unwrap();
                for d in docs {
                    let (ids, _live) = self.retrieval.tokenizer.encode_block(&d.text, doc_tokens);
                    index.insert(d.id, self.retrieval.embedder.embed(&ids));
                    meta.insert(d.id, ChunkMeta { tokens: ids.clone(), doc_id: d.id });
                    tok_rows.push(ids);
                }
            }

            // chunked prefill on the device (compact ingest context)
            let t0 = Instant::now();
            let mut state = std::rc::Rc::new(self.session.zero_state(bucket, ingest_ctx)?);
            let mut cache_len = vec![0i32; bucket];
            for si in 0..n_steps {
                let mut tokens = vec![PAD as i32; bucket * step];
                let qlen = vec![step as i32; bucket];
                for (b, row) in tok_rows.iter().enumerate() {
                    for i in 0..step {
                        tokens[b * step + i] = row[si * step + i] as i32;
                    }
                }
                for _ in 0..docs.len() {
                    stats.prefill_trace.record_elem(step, (si + 1) * step);
                }
                stats.prefill_trace.record_step();
                state = std::rc::Rc::new(self.session.step(&tokens, &qlen, &cache_len, &state)?);
                for c in cache_len.iter_mut() {
                    *c += step as i32;
                }
            }
            // extract + write-behind
            let host = self.session.download_state(&state)?;
            stats.prefill_wall_secs += t0.elapsed().as_secs_f64();
            for (b, d) in docs.iter().enumerate() {
                let chunk = host.extract_chunk(cfg_id, b, 0, doc_tokens);
                stats.materialized_bytes += self.kv.encoded_bytes(&chunk);
                pending.push(self.kv.store_async(d.id, chunk));
            }
            stats.docs += docs.len();
            stats.tokens += docs.len() * doc_tokens;
        }

        // drain write-behind queue, collecting simulated device seconds
        stats.write_device_secs = self.kv.drain(pending)?;
        Ok(stats)
    }

    /// Delete a document everywhere (vector DB + materialized KV + meta).
    pub fn delete_doc(&self, id: u64) -> Result<bool> {
        let in_index = self.retrieval.index.write().unwrap().delete(id);
        self.retrieval.meta.write().unwrap().remove(&id);
        let on_disk = self.kv.delete(id)?;
        Ok(in_index || on_disk)
    }
}
