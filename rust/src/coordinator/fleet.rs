//! Heterogeneous device fleet: disaggregated prefill/decode serving
//! across simulated GPU workers.
//!
//! The paper's second headline optimization is that **low-end GPUs can
//! decode nearly as fast as an H100 once the materialized KVs sit in
//! device memory** — decode is dominated by per-element software
//! overhead plus weight streaming, where an RTX 4090 trails by ~1.1-2.7x,
//! versus ~7x at prefill (Fig 10, `DeviceProfile::rtx4090`). At serving
//! scale that asymmetry wants a *fleet*: one expensive prefill-class
//! card for the compute-bound work, several cheap decode-class cards
//! for the KV-resident mass, all sharing one request stream.
//!
//! This module is that executor. A [`Fleet`] wraps N workers — each a
//! calibrated [`DeviceProfile`] from the serving catalog
//! ([`crate::hwsim::SERVING_GPUS`]) with its own [`EnergyMeter`] — and
//! dispatches the scheduler's [`PlannedBatch`]es on the same
//! deterministic **virtual clock** the scheduler planned them on: a
//! batch becomes runnable at its `release_secs`, starts when its worker
//! frees up, and occupies the worker for a modeled per-batch cost
//! ([`FleetCostModel`]) instead of the old flat `service_estimate_secs`
//! knob. Everything is simulation — no wall-clock, no PJRT — so the
//! same trace plus the same fleet spec reproduces the same per-worker
//! assignment bit-for-bit.
//!
//! **Routing** is pluggable ([`Routing`]):
//!
//! * [`Routing::RoundRobin`] — the baseline: batch *i* to worker
//!   *i mod N*, blind to roles and residency.
//! * [`Routing::RoleAware`] — KV-resident batches (every chunk
//!   materialized on flash, DRAM-resident or not) go to **decode-class**
//!   workers; cache-miss/prefill-heavy batches (some chunk was never
//!   materialized and must be recomputed on-device) go to the
//!   **prefill-class** card. Within a role the batch takes the worker
//!   with the earliest modeled completion, ties to the lowest index.
//!
//! **Costing** a batch on a worker charges four phases:
//!
//! 1. *load* — storage reads for chunks absent from host DRAM (the
//!    [`ResidentSet`] snapshot the [`crate::kvstore::KvStore`] exports,
//!    evolved advisorily as batches execute), at the storage profile's
//!    batched-read cost over the chunk's file bytes
//!    ([`ArchSpec::kv_bytes`] is f16-scale, matching the v2 flash
//!    format and `PhaseBreakdown::load_secs_on`).
//! 2. *transfer* — the explicit host→device KV charge: every spliced
//!    chunk that is not already resident in **this worker's** device
//!    memory crosses PCIe at the worker's `pcie_bw`. A chunk loaded by
//!    a *different* worker is host-resident but still pays this — the
//!    disaggregation tax the routing policy exists to dodge. Per-worker
//!    residency is a byte-budgeted window of HBM minus resident
//!    weights. Since the interconnect refactor the upload is
//!    **chunk-granular on a per-worker [`Link`]**: each chunk reserves
//!    a queued slot on the worker's PCIe link starting when the storage
//!    load drains, so batch *n+1*'s upload overlaps batch *n*'s compute
//!    (double buffering) up to link saturation, and concurrent uploads
//!    queue behind each other instead of overlapping for free.
//!    [`Fleet::set_contention`] switches the queueing off for A/B runs
//!    (`benches/fig_bus.rs`): transfers still take their wire time, but
//!    the link grants horizon-free slots.
//! 3. *prefill* — query sub-prefill for everyone, plus chunked
//!    on-device recompute of unmaterialized chunks (the Vanilla-path
//!    cost), through the same [`ArchSpec`] roofline the benches use.
//! 4. *decode* — batched greedy decode to the longest output budget,
//!    with the calibrated per-element overhead that makes decode nearly
//!    class-blind.
//!
//! Energy integrates per worker ([`EnergyMeter::server_for`]): load
//! phases charge the storage delta, compute phases the GPU delta, and
//! end-of-run idle gaps the box's `host_idle_w` floor — which is what
//! makes the H100-alone baseline *lose* on tokens-per-joule to a mixed
//! fleet at equal offered load (`benches/fig_fleet.rs`): the big box
//! burns server-class watts on work a desktop-class box does almost as
//! fast.

use std::collections::{HashSet, VecDeque};
use std::sync::{Arc, Mutex};

use anyhow::{bail, Context, Result};

use super::metrics::{LatencySummary, Percentiles, PhaseBreakdown, WorkTrace};
use super::scheduler::{PlannedBatch, ServiceEstimator};
use crate::hwsim::{
    register_link_metrics, serving_profile, ArchSpec, DeviceProfile, EnergyMeter, FaultPlan, Link,
    LinkClock, LinkSnapshot, PhaseKind, StorageProfile, TrafficClass, SERVING_GPUS,
};
use crate::obs::{Gauge, Histogram, MetricsRegistry, Sampler};
use crate::kvstore::ResidentSet;
use crate::trace::{Arg, RequestPath, TraceBus};
use crate::vectordb::ChunkId;
use crate::workload::RagRequest;

/// A worker's role in role-aware routing. Assigned from relative
/// compute: the fleet's fastest class is prefill-capable, everything
/// else decodes. A homogeneous fleet is all [`Role::Prefill`] and
/// decode-class batches fall back to it.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum Role {
    /// High-end: takes the cache-miss/prefill-heavy batches.
    Prefill,
    /// Low-end: takes KV-resident batches (the Fig-10 premise).
    Decode,
}

impl Role {
    pub fn label(self) -> &'static str {
        match self {
            Role::Prefill => "prefill",
            Role::Decode => "decode",
        }
    }
}

/// Which worker a batch rides to.
#[derive(Debug, Clone, Copy, PartialEq, Eq, Default)]
pub enum Routing {
    /// Batch *i* → worker *i mod N*.
    #[default]
    RoundRobin,
    /// Resident batches → decode workers, miss/prefill-heavy batches →
    /// the prefill card; earliest modeled completion within the role.
    RoleAware,
}

impl Routing {
    pub fn parse(name: &str) -> Result<Routing> {
        Ok(match name {
            "rr" | "roundrobin" | "round-robin" => Routing::RoundRobin,
            "role" | "roleaware" | "role-aware" => Routing::RoleAware,
            other => bail!("unknown routing policy {other:?} (expected rr|role)"),
        })
    }

    pub fn label(self) -> &'static str {
        match self {
            Routing::RoundRobin => "rr",
            Routing::RoleAware => "role",
        }
    }
}

/// The device mix, e.g. parsed from `--fleet h100:1,rtx4090:3`.
#[derive(Debug, Clone)]
pub struct FleetSpec {
    pub workers: Vec<DeviceProfile>,
}

impl FleetSpec {
    /// Parse `name:count[,name:count...]` (count defaults to 1), names
    /// resolved through the serving catalog — the same
    /// [`crate::hwsim::GpuCatalogRow`] lookup `fig10_gpu_class` uses, so
    /// there is exactly one place a GPU class is defined.
    pub fn parse(spec: &str) -> Result<FleetSpec> {
        let mut workers = Vec::new();
        for part in spec.split(',').map(str::trim).filter(|p| !p.is_empty()) {
            let (name, count) = match part.split_once(':') {
                Some((n, c)) => (
                    n.trim(),
                    c.trim()
                        .parse::<usize>()
                        .with_context(|| format!("bad worker count in {part:?}"))?,
                ),
                None => (part, 1),
            };
            if count == 0 {
                bail!("fleet spec {part:?} asks for zero workers");
            }
            let profile = serving_profile(name).with_context(|| {
                let menu: Vec<&str> = SERVING_GPUS.iter().map(|r| r.name).collect();
                format!("unknown GPU class {name:?} (serving catalog: {menu:?})")
            })?;
            for _ in 0..count {
                workers.push(profile.clone());
            }
        }
        if workers.is_empty() {
            bail!("empty fleet spec {spec:?}");
        }
        Ok(FleetSpec { workers })
    }

    pub fn len(&self) -> usize {
        self.workers.len()
    }

    pub fn is_empty(&self) -> bool {
        self.workers.is_empty()
    }
}

/// Converts a planned batch into modeled phase costs on a device (the
/// per-batch replacement for the scheduler's flat service knob).
#[derive(Debug, Clone)]
pub struct FleetCostModel {
    /// Architecture the work is costed under (the stand-in scale, like
    /// every bench: [`ArchSpec::standin_for`]).
    pub arch: ArchSpec,
    /// Storage tier serving cache-miss chunk reads.
    pub storage: StorageProfile,
    /// Tokens per materialized chunk (the scenario's `doc_tokens`).
    pub chunk_tokens: usize,
    /// Modeled query length (tokens) per request.
    pub query_tokens: usize,
    /// Chunked-prefill step for on-device recompute of unmaterialized
    /// chunks (the engine's `chunk_step`).
    pub chunk_step: usize,
}

/// Modeled cost of one batch on one worker.
#[derive(Debug, Clone, Copy, Default)]
pub struct BatchCost {
    /// Storage-device seconds for chunks absent from host DRAM.
    pub load_secs: f64,
    /// Host→device KV upload seconds (chunks not on this worker).
    pub transfer_secs: f64,
    /// Prefill-class device seconds (query sub-prefill + recompute).
    pub prefill_secs: f64,
    /// Decode-class device seconds.
    pub decode_secs: f64,
    /// Storage reads issued (cache-miss chunks).
    pub miss_reads: usize,
    /// Bytes crossing PCIe.
    pub transfer_bytes: f64,
}

impl BatchCost {
    /// Device-busy seconds (everything but the storage load).
    pub fn exec_secs(&self) -> f64 {
        self.transfer_secs + self.prefill_secs + self.decode_secs
    }

    /// End-to-end worker occupancy (serial composition, like
    /// [`PhaseBreakdown::total_secs_on`]).
    pub fn total_secs(&self) -> f64 {
        self.load_secs + self.exec_secs()
    }
}

/// The device-independent half of a batch's cost: the work traces and
/// the deduplicated materialized chunk set. Built **once per batch**
/// ([`FleetCostModel::batch_work`]); pricing it on a candidate worker
/// ([`FleetCostModel::work_cost`]) is then only the residency walk plus
/// the roofline conversions — what role-aware routing iterates per
/// worker.
#[derive(Debug, Clone, Default)]
pub struct BatchWork {
    /// Query sub-prefill + chunked recompute of unmaterialized chunks.
    pub prefill: WorkTrace,
    /// Batched greedy decode to the longest output budget.
    pub decode: WorkTrace,
    /// Unique materialized chunk ids, first-seen order (duplicates
    /// within the batch collapse — `load_many` splice reuse).
    pub unique_chunks: Vec<ChunkId>,
    /// Total tokens that must be recomputed on-device (unmaterialized
    /// chunks, summed over elements).
    pub recompute_tokens: usize,
}

impl BatchWork {
    /// Is this batch prefill-heavy (some chunk must be recomputed)?
    /// The one classification source role-aware routing consults.
    pub fn needs_prefill(&self) -> bool {
        self.recompute_tokens > 0
    }
}

impl FleetCostModel {
    /// Bytes of one chunk's KV — the flash file size, the host→device
    /// transfer size, and the HBM-window charge alike.
    /// [`ArchSpec::kv_bytes_per_token`] is already f16-scale (the
    /// paper's measured KV sizes — what the v2 flash format stores), so
    /// one number serves all three: the same convention
    /// [`super::metrics::PhaseBreakdown::load_secs_on`] uses to charge
    /// miss tokens to a storage tier. The single definition, so a
    /// future format change can't update one accounting site and
    /// silently leave the others behind.
    pub fn chunk_kv_bytes(&self) -> f64 {
        self.arch.kv_bytes(self.chunk_tokens)
    }

    /// Build the device-independent work of one batch (`reqs` and
    /// `retrieved` paired like a [`PlannedBatch`]). `materialized` says
    /// whether a chunk exists on flash at all — unmaterialized chunks
    /// are recomputed on-device at the Vanilla-prefill cost.
    pub fn batch_work(
        &self,
        reqs: &[RagRequest],
        retrieved: &[Vec<ChunkId>],
        materialized: &dyn Fn(ChunkId) -> bool,
    ) -> BatchWork {
        let mut work = BatchWork::default();
        let mut seen: HashSet<ChunkId> = HashSet::new();

        // Per-element context split: spliced (materialized) tokens vs
        // tokens that must be recomputed on-device.
        let mut spliced: Vec<usize> = Vec::with_capacity(reqs.len());
        let mut recompute: Vec<usize> = Vec::with_capacity(reqs.len());
        for i in 0..reqs.len() {
            let ids: &[ChunkId] = retrieved.get(i).map(Vec::as_slice).unwrap_or(&[]);
            let (mut sp, mut rc) = (0usize, 0usize);
            for &id in ids {
                if materialized(id) {
                    sp += self.chunk_tokens;
                    if seen.insert(id) {
                        work.unique_chunks.push(id);
                    }
                } else {
                    rc += self.chunk_tokens;
                }
            }
            work.recompute_tokens += rc;
            spliced.push(sp);
            recompute.push(rc);
        }

        // Chunked recompute of unmaterialized docs, batch-synchronous
        // like the engine's Vanilla prefill: every element advances
        // together in `chunk_step` slices until the longest drains.
        let step = self.chunk_step.max(1);
        let max_rc = recompute.iter().copied().max().unwrap_or(0);
        let mut off = 0usize;
        while off < max_rc {
            work.prefill.record_step();
            for b in 0..reqs.len() {
                let rem = recompute[b].saturating_sub(off);
                if rem == 0 {
                    continue;
                }
                let take = rem.min(step);
                work.prefill.record_elem(take, spliced[b] + off + take);
            }
            off += step;
        }
        // Query sub-prefill: one step, every element.
        work.prefill.record_step();
        for b in 0..reqs.len() {
            let ctx = spliced[b] + recompute[b] + self.query_tokens;
            work.prefill.record_elem(self.query_tokens, ctx);
        }

        // Greedy decode to the longest output budget; the first token
        // falls out of the sub-prefill logits, like the engine.
        let max_out = reqs.iter().map(|r| r.output_tokens).max().unwrap_or(0);
        for s in 1..max_out {
            work.decode.record_step();
            for b in 0..reqs.len() {
                let ctx = spliced[b] + recompute[b] + self.query_tokens + s;
                work.decode.record_elem(1, ctx + 1);
            }
        }
        work
    }

    /// Price prepared [`BatchWork`] on `dev`. `host_resident` is the
    /// DRAM set (no storage read); `device_resident` is what already
    /// sits in this worker's HBM (no PCIe transfer either).
    pub fn work_cost(
        &self,
        work: &BatchWork,
        dev: &DeviceProfile,
        host_resident: &HashSet<ChunkId>,
        device_resident: &HashSet<ChunkId>,
    ) -> BatchCost {
        let mut cost = BatchCost::default();
        let mut miss_bytes = 0.0f64;
        for id in &work.unique_chunks {
            if !device_resident.contains(id) {
                cost.transfer_bytes += self.chunk_kv_bytes();
                if !host_resident.contains(id) {
                    miss_bytes += self.chunk_kv_bytes();
                    cost.miss_reads += 1;
                }
            }
        }
        cost.load_secs = self.storage.read_secs_batch(miss_bytes, cost.miss_reads);
        // Wire time via the one definition every transfer site shares;
        // queueing on top of it is the dispatcher's job (per-worker
        // H2D links), not the cost model's.
        cost.transfer_secs = Link::wire_secs(dev.pcie_bw, 0.0, cost.transfer_bytes as usize);
        cost.prefill_secs = self.arch.trace_secs(&work.prefill, dev);
        cost.decode_secs = self.arch.trace_secs_decode(&work.decode, dev);
        cost
    }

    /// [`FleetCostModel::batch_work`] + [`FleetCostModel::work_cost`]
    /// in one call — the convenience form tests and the service
    /// estimator use; the dispatcher builds the work once and prices it
    /// per candidate instead.
    pub fn batch_cost(
        &self,
        reqs: &[RagRequest],
        retrieved: &[Vec<ChunkId>],
        dev: &DeviceProfile,
        host_resident: &HashSet<ChunkId>,
        device_resident: &HashSet<ChunkId>,
        materialized: &dyn Fn(ChunkId) -> bool,
    ) -> BatchCost {
        let work = self.batch_work(reqs, retrieved, materialized);
        self.work_cost(&work, dev, host_resident, device_resident)
    }
}

/// One simulated worker: a device profile, its virtual-clock state, a
/// bounded device-resident KV window, and its own energy meter.
struct Worker {
    profile: DeviceProfile,
    role: Role,
    meter: EnergyMeter,
    /// This worker's host→device PCIe link on the dispatch virtual
    /// clock: every KV upload reserves queued slots here, sized from
    /// the profile's `pcie_bw` (latency folded into the batched wire
    /// time, so chunked slot sums equal the flat charge exactly).
    /// Arc'd so [`Fleet::register_metrics`] can hand the registry
    /// polled handles onto its stats.
    link: Arc<Link>,
    /// Virtual time this worker is next free.
    free_at: f64,
    busy_secs: f64,
    load_secs: f64,
    transfer_secs: f64,
    batches: u64,
    requests: usize,
    tokens_out: usize,
    /// Chunk ids resident in this worker's device memory (insertion-
    /// order window bounded by `kv_budget`; an approximation of the
    /// on-device cache, like the scheduler's recent-batch warm set).
    resident: HashSet<ChunkId>,
    /// Insertion order with each entry's admitted size, so eviction
    /// reclaims exactly what was charged even if chunk sizes vary.
    resident_order: VecDeque<(ChunkId, f64)>,
    resident_bytes: f64,
    kv_budget: f64,
}

impl Worker {
    fn new(profile: DeviceProfile, role: Role, model: &FleetCostModel) -> Worker {
        let weight_bytes = model.arch.param_count * model.arch.bytes_per_param;
        // HBM minus resident weights holds KV; floor at 10% so a model
        // larger than the card still leaves a (paged) working set.
        let kv_budget = (profile.hbm_bytes - weight_bytes).max(0.1 * profile.hbm_bytes);
        let link = Arc::new(Link::new(
            format!("{}-pcie", profile.name),
            profile.pcie_bw,
            0.0,
            LinkClock::Virtual,
        ));
        Worker {
            meter: EnergyMeter::server_for(profile.clone(), model.storage.clone()),
            profile,
            role,
            link,
            free_at: 0.0,
            busy_secs: 0.0,
            load_secs: 0.0,
            transfer_secs: 0.0,
            batches: 0,
            requests: 0,
            tokens_out: 0,
            resident: HashSet::new(),
            resident_order: VecDeque::new(),
            resident_bytes: 0.0,
            kv_budget,
        }
    }

    /// Clear all per-run state (see [`Fleet::dispatch`]'s independent-
    /// simulation contract).
    fn reset(&mut self) {
        self.meter.reset();
        self.link.reset();
        self.free_at = 0.0;
        self.busy_secs = 0.0;
        self.load_secs = 0.0;
        self.transfer_secs = 0.0;
        self.batches = 0;
        self.requests = 0;
        self.tokens_out = 0;
        self.resident.clear();
        self.resident_order.clear();
        self.resident_bytes = 0.0;
    }

    fn admit_resident(&mut self, id: ChunkId, chunk_bytes: f64) {
        if chunk_bytes > self.kv_budget || !self.resident.insert(id) {
            return;
        }
        self.resident_bytes += chunk_bytes;
        self.resident_order.push_back((id, chunk_bytes));
        while self.resident_bytes > self.kv_budget {
            match self.resident_order.pop_front() {
                Some((old, old_bytes)) => {
                    if self.resident.remove(&old) {
                        self.resident_bytes -= old_bytes;
                    }
                }
                None => break,
            }
        }
    }
}

/// Chunk-granular H2D upload: reserve `cost`'s transfer on `link` as
/// per-chunk slots starting at `load_done` — the double-buffered path;
/// the link may still be draining an earlier batch's upload, in which
/// case these chunks queue behind it. Returns the instant the last
/// byte lands (`load_done` when nothing transfers). The **one** upload
/// timeline: [`Fleet::dispatch`] plays it and the hand-computed
/// latency test mirrors it verbatim, so the two can't drift.
fn h2d_upload(link: &Link, load_done: f64, cost: &BatchCost, chunk_bytes: f64) -> f64 {
    h2d_upload_queued(link, load_done, cost, chunk_bytes).0
}

/// [`h2d_upload`] plus the sum of queued (not-on-the-wire) seconds its
/// slots spent waiting behind earlier traffic — the dispatch loop's
/// per-batch *bus* attribution component. Same timeline, same
/// reservations; `h2d_upload` delegates here so the two can't drift.
fn h2d_upload_queued(
    link: &Link,
    load_done: f64,
    cost: &BatchCost,
    chunk_bytes: f64,
) -> (f64, f64) {
    if cost.transfer_bytes <= 0.0 {
        return (load_done, 0.0);
    }
    let n = (cost.transfer_bytes / chunk_bytes.max(1.0)).round().max(1.0) as usize;
    let per_secs = cost.transfer_secs / n as f64;
    let per_bytes = (cost.transfer_bytes / n as f64) as usize;
    let total_bytes = cost.transfer_bytes as usize;
    let mut cursor = load_done;
    let mut queued = 0.0f64;
    for i in 0..n {
        // the last chunk carries the integer-division remainder, so the
        // byte counters stay exact
        let bytes = if i + 1 == n { total_bytes - (n - 1) * per_bytes } else { per_bytes };
        let slot = link.reserve_secs_at(cursor, per_secs, bytes, TrafficClass::H2D);
        queued += slot.queued_secs;
        cursor = slot.end;
    }
    (cursor, queued)
}

/// Per-worker slice of a [`FleetReport`].
#[derive(Debug, Clone)]
pub struct WorkerReport {
    pub name: String,
    pub role: Role,
    pub batches: u64,
    pub requests: usize,
    pub tokens_out: usize,
    /// Virtual seconds the worker was occupied (load + exec).
    pub busy_secs: f64,
    /// Storage-load share of `busy_secs`.
    pub load_secs: f64,
    /// Host→device KV transfer share of `busy_secs`.
    pub transfer_secs: f64,
    /// `busy_secs / makespan` (0 when nothing ran).
    pub utilization: f64,
    /// Whole-box energy over the run, kJ (busy + idle floor).
    pub energy_kj: f64,
    /// Telemetry of this worker's H2D PCIe link — busy/queued seconds,
    /// peak backlog, per-traffic-class bytes.
    pub link: LinkSnapshot,
}

/// Everything one dispatch pass produces.
#[derive(Debug, Clone)]
pub struct FleetReport {
    pub routing: Routing,
    /// Whether the per-worker PCIe links queued ([`Fleet::set_contention`]).
    pub contention: bool,
    pub workers: Vec<WorkerReport>,
    /// Worker index per batch, in release order — the dispatch decision
    /// trail (determinism tests compare it across runs).
    pub assignments: Vec<usize>,
    /// Batches classified prefill-heavy (some chunk unmaterialized).
    pub prefill_batches: usize,
    /// Batches whose chunks were all materialized (decode-class).
    pub decode_batches: usize,
    /// Virtual time the last worker went idle.
    pub makespan_secs: f64,
    pub requests: usize,
    pub tokens_out: usize,
    /// Whole-fleet energy (every box's busy + idle), kJ.
    pub total_kj: f64,
    /// The headline: generated tokens per joule across the fleet.
    pub tokens_per_joule: f64,
    /// Per-request arrival → batch-completion latency percentiles on
    /// the virtual clock.
    pub latency: LatencySummary,
    /// The same numbers in the shared metrics shape (per-worker rollups
    /// + the latency sample set), mergeable via [`PhaseBreakdown::add`].
    pub metrics: PhaseBreakdown,
}

impl FleetReport {
    /// Tokens per virtual second across the fleet.
    pub fn throughput(&self) -> f64 {
        if self.makespan_secs > 0.0 {
            self.tokens_out as f64 / self.makespan_secs
        } else {
            0.0
        }
    }

    /// Compact JSON object — the one serializer the fleet bench embeds,
    /// so the emitted document can't drift from the struct.
    pub fn to_json(&self) -> String {
        let workers: Vec<String> = self
            .workers
            .iter()
            .map(|w| {
                format!(
                    "{{\"name\":\"{}\",\"role\":\"{}\",\"batches\":{},\"requests\":{},\
                     \"tokens_out\":{},\"busy_secs\":{:.6},\"load_secs\":{:.6},\
                     \"transfer_secs\":{:.6},\"utilization\":{:.4},\"energy_kj\":{:.6},\
                     \"link\":{}}}",
                    w.name,
                    w.role.label(),
                    w.batches,
                    w.requests,
                    w.tokens_out,
                    w.busy_secs,
                    w.load_secs,
                    w.transfer_secs,
                    w.utilization,
                    w.energy_kj,
                    w.link.to_json()
                )
            })
            .collect();
        format!(
            "{{\"routing\":\"{}\",\"contention\":{},\"workers\":[{}],\"prefill_batches\":{},\
             \"decode_batches\":{},\"makespan_secs\":{:.6},\"requests\":{},\
             \"tokens_out\":{},\"tokens_per_sec\":{:.3},\"total_kj\":{:.6},\
             \"tokens_per_joule\":{:.6},\"requeued_requests\":{},\"recomputed_chunks\":{},\
             \"degraded_tokens\":{},\"recompute_fallback_secs\":{:.6},\
             \"latency\":{{\"mean\":{:.6},\"p50\":{:.6},\
             \"p95\":{:.6},\"p99\":{:.6}}}}}",
            self.routing.label(),
            self.contention,
            workers.join(","),
            self.prefill_batches,
            self.decode_batches,
            self.makespan_secs,
            self.requests,
            self.tokens_out,
            self.throughput(),
            self.total_kj,
            self.tokens_per_joule,
            self.metrics.requeued_requests,
            self.metrics.recomputed_chunks,
            self.metrics.degraded_tokens,
            self.metrics.recompute_fallback_secs,
            self.latency.mean,
            self.latency.p50,
            self.latency.p95,
            self.latency.p99,
        )
    }
}

/// The fleet: a worker pool plus a routing policy and cost model.
/// Build one, optionally [`Fleet::seed_resident`] from the store's
/// snapshot, then [`Fleet::dispatch`] a planned schedule.
pub struct Fleet {
    workers: Vec<Worker>,
    routing: Routing,
    model: FleetCostModel,
    /// Whether per-worker H2D links queue (the `--pcie-contention`
    /// knob). Off: uploads still take wire time, but concurrent
    /// transfers overlap freely — the pre-refactor optimism, kept as
    /// the A/B baseline `fig_bus` measures against.
    contention: bool,
    rr_next: usize,
    /// What [`Fleet::seed_resident`] accumulated: the host-DRAM state
    /// every dispatch starts from.
    seed: HashSet<ChunkId>,
    /// Advisory host-DRAM residency model during a dispatch: reset to
    /// `seed` at the top of every [`Fleet::dispatch`], then grown as
    /// batches load chunks (eviction is not simulated — same
    /// approximation as the scheduler's warm-set window).
    host_resident: HashSet<ChunkId>,
    /// Optional fault plan ([`Fleet::set_faults`]): worker crashes on
    /// the dispatch virtual clock. `None` (the default) is the exact
    /// pre-fault dispatch, bit for bit.
    faults: Option<Arc<FaultPlan>>,
    /// Chunks whose flash copy is unreachable (dead shard): they price
    /// as on-device recompute even though they were materialized
    /// ([`Fleet::set_lost_chunks`]).
    lost: Option<Arc<dyn Fn(ChunkId) -> bool + Send + Sync>>,
    /// Trace handle ([`Fleet::set_trace`]). Dispatch runs entirely on
    /// the virtual clock, so every emission here is *clocked* — real
    /// trace timestamps — and the per-request [`RequestPath`]
    /// attribution records land on the same bus.
    trace: TraceBus,
    /// Per-worker registry gauges, index-aligned with `workers`; empty
    /// until [`Fleet::register_metrics`].
    wmetrics: Vec<WorkerGauges>,
    /// Request-latency histogram instrument, when registered.
    latency_hist: Option<Histogram>,
    /// Shared registry sampler ([`Fleet::set_sampler`]): dispatch
    /// advances it to each batch completion and closes the tail at the
    /// fleet makespan, so every registered series gets samples on the
    /// dispatch virtual clock.
    sampler: Option<Arc<Mutex<Sampler>>>,
}

/// One worker's registry instruments: gauges tracking the dispatch-loop
/// counters (which reset per dispatch — a counter instrument would
/// misreport the second run).
struct WorkerGauges {
    busy: Gauge,
    batches: Gauge,
    requests: Gauge,
    tokens_out: Gauge,
    utilization: Gauge,
}

impl WorkerGauges {
    fn update(&self, w: &Worker, elapsed: f64) {
        self.busy.set(w.busy_secs);
        self.batches.set(w.batches as f64);
        self.requests.set(w.requests as f64);
        self.tokens_out.set(w.tokens_out as f64);
        self.utilization.set(if elapsed > 0.0 { w.busy_secs / elapsed } else { 0.0 });
    }
}

impl Fleet {
    /// Build workers from a spec. Role assignment: the fastest device
    /// class present is [`Role::Prefill`], everything slower decodes.
    pub fn new(spec: &FleetSpec, routing: Routing, model: FleetCostModel) -> Fleet {
        let max_flops =
            spec.workers.iter().map(|p| p.peak_flops).fold(0.0f64, f64::max);
        let workers = spec
            .workers
            .iter()
            .map(|p| {
                let role = if p.peak_flops >= 0.99 * max_flops {
                    Role::Prefill
                } else {
                    Role::Decode
                };
                Worker::new(p.clone(), role, &model)
            })
            .collect();
        Fleet {
            workers,
            routing,
            model,
            contention: true,
            rr_next: 0,
            seed: HashSet::new(),
            host_resident: HashSet::new(),
            faults: None,
            lost: None,
            trace: TraceBus::disabled(),
            wmetrics: Vec::new(),
            latency_hist: None,
            sampler: None,
        }
    }

    /// Register every worker's instruments into `reg` under
    /// `matkv.fleet.*{worker=<profile>:<index>}` plus each worker's H2D
    /// link under `matkv.link.*{worker=…}`, and one
    /// `matkv.fleet.request_latency_seconds` histogram. Worker labels
    /// are `<lowercased profile name>:<worker index>` (e.g.
    /// `rtx4090:1`) — stable across runs of the same spec. Call once
    /// per registry (duplicate ids fail loudly).
    pub fn register_metrics(&mut self, reg: &MetricsRegistry) -> Result<()> {
        self.wmetrics.clear();
        for (i, w) in self.workers.iter().enumerate() {
            let id = format!("{}:{}", w.profile.name.to_lowercase(), i);
            let labels = [("worker", id.as_str())];
            let busy = reg.gauge(
                "matkv.fleet.worker_busy_seconds",
                &labels,
                "virtual seconds this worker has been busy in the current dispatch",
            )?;
            let batches = reg.gauge(
                "matkv.fleet.worker_batches",
                &labels,
                "batches completed by this worker in the current dispatch",
            )?;
            let requests = reg.gauge(
                "matkv.fleet.worker_requests",
                &labels,
                "requests completed by this worker in the current dispatch",
            )?;
            let tokens_out = reg.gauge(
                "matkv.fleet.worker_tokens_out",
                &labels,
                "tokens generated by this worker in the current dispatch",
            )?;
            let utilization = reg.gauge(
                "matkv.fleet.worker_utilization",
                &labels,
                "worker busy seconds over elapsed virtual time",
            )?;
            register_link_metrics(reg, &w.link, &labels, false)?;
            self.wmetrics.push(WorkerGauges { busy, batches, requests, tokens_out, utilization });
        }
        self.latency_hist = Some(reg.histogram(
            "matkv.fleet.request_latency_seconds",
            &[],
            "virtual seconds from request arrival to batch completion",
        )?);
        Ok(())
    }

    /// Share the registry sampler: dispatch advances it to each batch
    /// completion time and finishes it at the fleet makespan.
    pub fn set_sampler(&mut self, sampler: Arc<Mutex<Sampler>>) {
        self.sampler = Some(sampler);
    }

    /// Attach a trace bus: per-batch load/upload/prefill/decode spans
    /// and completion instants on each worker's own track, per-slot
    /// reservation spans on each worker's H2D link track, and one
    /// [`RequestPath`] critical-path record per completed request.
    /// Call after [`Fleet::set_contention`]-style knobs; tracks are
    /// indexed (`worker0:H100`, …) because profile names repeat.
    pub fn set_trace(&mut self, trace: TraceBus) {
        for (i, w) in self.workers.iter().enumerate() {
            w.link.set_trace(trace.clone(), format!("link:worker{}:{}", i, w.profile.name));
        }
        self.trace = trace;
    }

    /// Install a fault plan: workers crash at their plan-scheduled
    /// virtual times and their in-flight batches are requeued onto the
    /// survivors with arrival times preserved. No plan → the exact
    /// pre-fault dispatch.
    pub fn set_faults(&mut self, plan: Arc<FaultPlan>) {
        self.faults = Some(plan);
    }

    /// Mark chunks whose flash copy is gone (e.g. a dead shard:
    /// `plan.shard_dead(kv.shard_index_of(id))`). Dispatch prices them
    /// as on-device recompute — the Vanilla safety net at fleet scale —
    /// and the extra prefill seconds land in
    /// `PhaseBreakdown::recompute_fallback_secs` at the assigned
    /// worker's rate.
    pub fn set_lost_chunks(&mut self, lost: Arc<dyn Fn(ChunkId) -> bool + Send + Sync>) {
        self.lost = Some(lost);
    }

    /// Toggle PCIe queueing on every worker's H2D link (default on).
    /// Off disables the links — reservations become horizon-free, so
    /// transfers keep their wire time but never wait behind each other.
    pub fn set_contention(&mut self, on: bool) {
        self.contention = on;
        for w in &self.workers {
            w.link.set_enabled(on);
        }
    }

    pub fn contention(&self) -> bool {
        self.contention
    }

    pub fn len(&self) -> usize {
        self.workers.len()
    }

    pub fn is_empty(&self) -> bool {
        self.workers.is_empty()
    }

    /// Roles in worker order (telemetry / tests).
    pub fn roles(&self) -> Vec<Role> {
        self.workers.iter().map(|w| w.role).collect()
    }

    /// Seed the host-DRAM residency model from the store's snapshot —
    /// what the routing's cost estimates treat as already loaded at the
    /// start of every dispatch. **Replaces** any previous seed: stale
    /// residency from an earlier snapshot must not union in.
    pub fn seed_resident(&mut self, snapshot: &ResidentSet) {
        self.seed.clear();
        self.seed.extend(snapshot.hot.iter().copied());
        self.seed.extend(snapshot.warm.iter().copied());
    }

    /// A [`ServiceEstimator`] for the scheduler that treats every chunk
    /// as flash-materialized — right when the whole corpus was ingested;
    /// use [`Fleet::service_estimator_with`] when some chunks are known
    /// to be missing, or prefill-heavy batches will be under-priced.
    pub fn service_estimator(&self) -> Arc<dyn ServiceEstimator> {
        self.service_estimator_with(Arc::new(|_| true))
    }

    /// A [`ServiceEstimator`] for the scheduler: the batch's cost on
    /// the fleet's fastest card with nothing DRAM/device-resident
    /// (pessimistic on residency), amortized over the worker count — so
    /// the planner's release clock drains at roughly the fleet's
    /// aggregate rate. `materialized` mirrors the dispatch-time
    /// predicate: an unmaterialized chunk prices as on-device recompute,
    /// so cache-miss batches occupy the modeled executor longer — the
    /// whole point of replacing the flat knob.
    pub fn service_estimator_with(
        &self,
        materialized: Arc<dyn Fn(ChunkId) -> bool + Send + Sync>,
    ) -> Arc<dyn ServiceEstimator> {
        let reference = self
            .workers
            .iter()
            .map(|w| &w.profile)
            .max_by(|a, b| a.peak_flops.total_cmp(&b.peak_flops))
            .expect("fleet has at least one worker")
            .clone();
        Arc::new(FleetServiceEstimator {
            model: self.model.clone(),
            reference,
            workers: self.workers.len(),
            materialized,
        })
    }

    /// Classify + route one batch (its device-independent work already
    /// prepared): the chosen worker index and its modeled cost there.
    /// `crash` is the per-worker crash time (None on a clean run):
    /// workers already crashed at the batch's release are excluded, so
    /// role-aware routing rebalances around a dead card and round-robin
    /// skips it.
    fn route(
        &self,
        batch: &PlannedBatch,
        work: &BatchWork,
        needs_prefill: bool,
        crash: &[Option<f64>],
    ) -> (usize, BatchCost) {
        let dead = |i: usize| crash[i].is_some_and(|t| t <= batch.release_secs);
        let alive: Vec<usize> = (0..self.workers.len()).filter(|&i| !dead(i)).collect();
        let pool: Vec<usize> = if alive.is_empty() {
            // Every worker is down. Real serving would page an operator;
            // the simulation warns loudly and keeps going (no request is
            // ever dropped), treating the fleet as restarted.
            eprintln!(
                "[fleet] WARNING: every worker has crashed by t={:.3}; \
                 dispatching on the full pool anyway",
                batch.release_secs
            );
            (0..self.workers.len()).collect()
        } else {
            alive
        };
        let cost_on = |i: usize| {
            self.model.work_cost(
                work,
                &self.workers[i].profile,
                &self.host_resident,
                &self.workers[i].resident,
            )
        };
        match self.routing {
            Routing::RoundRobin => {
                let i = pool[self.rr_next % pool.len()];
                (i, cost_on(i))
            }
            Routing::RoleAware => {
                let want = if needs_prefill { Role::Prefill } else { Role::Decode };
                let mut candidates: Vec<usize> =
                    pool.iter().copied().filter(|&i| self.workers[i].role == want).collect();
                if candidates.is_empty() {
                    // homogeneous fleet (or no surviving card of that
                    // class): every live worker is a candidate
                    candidates = pool;
                }
                let mut best: Option<(usize, BatchCost, f64)> = None;
                for i in candidates {
                    let cost = cost_on(i);
                    // Earliest finish on the pipelined timeline,
                    // including this worker's **link backlog**: the
                    // upload can't start before the storage load drains
                    // or the link's horizon clears, and compute waits
                    // on the later of the upload and the device — a
                    // wire-granular estimate of what dispatch plays out.
                    let transfer_start =
                        (batch.release_secs + cost.load_secs).max(self.workers[i].link.horizon());
                    let finish = (transfer_start + cost.transfer_secs)
                        .max(self.workers[i].free_at)
                        + cost.prefill_secs
                        + cost.decode_secs;
                    // strict < keeps ties on the lowest index: the
                    // dispatch is deterministic by construction
                    let better = match &best {
                        None => true,
                        Some((_, _, f)) => finish < *f,
                    };
                    if better {
                        best = Some((i, cost, finish));
                    }
                }
                let (i, cost, _) = best.expect("at least one candidate");
                (i, cost)
            }
        }
    }

    /// Dispatch a planned schedule across the fleet on the virtual
    /// clock. `materialized` answers whether a chunk exists on flash
    /// (callers snapshot `KvStore::contains` once — see the CLI);
    /// batches with unmaterialized chunks are prefill-heavy. The plan
    /// must carry its retrieval sets ([`Scheduler::plan_with_retrieval`]
    /// or an installed estimator) — without them every batch looks
    /// chunk-free and prices at decode-only. Each call is an
    /// independent simulation: all per-run worker state (clocks,
    /// counters, meters, device-resident windows) and the host-DRAM
    /// model reset to the seeded snapshot first, so dispatching two
    /// schedules through one fleet never bleeds state between runs.
    ///
    /// [`Scheduler::plan_with_retrieval`]: super::scheduler::Scheduler::plan_with_retrieval
    pub fn dispatch(
        &mut self,
        batches: &[PlannedBatch],
        materialized: &dyn Fn(ChunkId) -> bool,
    ) -> FleetReport {
        self.rr_next = 0;
        self.host_resident = self.seed.clone();
        for w in &mut self.workers {
            w.reset();
        }
        for (w, g) in self.workers.iter().zip(&self.wmetrics) {
            g.update(w, 0.0);
        }
        // Misuse check, loud in release builds too: a plan without its
        // retrieval sets prices every batch as chunk-free decode work —
        // plausible-looking, meaningless numbers.
        if batches.iter().any(|b| !b.reqs.is_empty() && b.retrieved.len() != b.reqs.len()) {
            eprintln!(
                "[fleet] WARNING: planned batches carry no retrieval sets; dispatch will \
                 price them as chunk-free decode work — plan with plan_with_retrieval() \
                 or install a service estimator"
            );
        }
        let chunk_bytes = self.model.chunk_kv_bytes();
        let mut assignments = Vec::with_capacity(batches.len());
        let mut latency = Percentiles::default();
        let mut prefill_batches = 0usize;
        let mut decode_batches = 0usize;

        // Fault wiring. On a clean run (no plan, no lost set) `mat`
        // delegates straight to `materialized` and `crash` is all-None,
        // so the loop below replays the pre-fault dispatch bit for bit.
        let crash: Vec<Option<f64>> = match &self.faults {
            Some(p) => (0..self.workers.len()).map(|i| p.worker_crash_at(i)).collect(),
            None => vec![None; self.workers.len()],
        };
        let lost = self.lost.clone();
        let is_lost = |id: ChunkId| lost.as_ref().is_some_and(|f| f(id));
        let mat = |id: ChunkId| materialized(id) && !is_lost(id);
        let mut requeued_requests = 0usize;
        let mut recomputed_chunks = 0usize;
        let mut recompute_fallback_secs = 0.0f64;
        let mut degraded_tokens = 0usize;

        // Requeues append behind the planned batches; a requeued batch
        // keeps its arrivals (latency stays honest about the crash) but
        // releases at the crash instant, when the loss is detectable.
        let mut queue: VecDeque<PlannedBatch> = batches.iter().cloned().collect();
        let mut popped = 0usize;
        while let Some(batch) = queue.pop_front() {
            // Device-independent work once per batch; classification
            // falls out of it (one materialized() walk), and candidates
            // only pay the residency walk + roofline conversion.
            let work = self.model.batch_work(&batch.reqs, &batch.retrieved, &mat);
            let needs_prefill = work.needs_prefill();
            // classify planned batches once; requeued copies (popped
            // past the original plan) are not double-counted
            if popped < batches.len() {
                if needs_prefill {
                    prefill_batches += 1;
                } else {
                    decode_batches += 1;
                }
            }
            popped += 1;
            let (wi, cost) = self.route(&batch, &work, needs_prefill, &crash);
            self.rr_next += 1;
            assignments.push(wi);

            let w = &mut self.workers[wi];
            // Pipelined timeline: the storage load drains from the
            // batch's release (host-side work — it never occupies the
            // device); the upload then crosses this worker's PCIe link
            // chunk-by-chunk, queueing behind any still-draining
            // earlier upload; compute starts once the device is free
            // AND the bytes have landed. Decode of batch *n* hides the
            // transfer of batch *n+1* up to link saturation.
            let load_done = batch.release_secs + cost.load_secs;
            let (transfer_done, bus_queued) =
                h2d_upload_queued(&w.link, load_done, &cost, chunk_bytes);
            let start = transfer_done.max(w.free_at);
            let done = start + cost.prefill_secs + cost.decode_secs;
            let track = self
                .trace
                .enabled()
                .then(|| format!("worker{}:{}", wi, w.profile.name));

            // Crash mid-dispatch: the worker dies before this batch
            // completes. It keeps whatever it burned up to the crash,
            // then the batch requeues onto the survivors.
            if let Some(t) = crash[wi] {
                if t > batch.release_secs && done > t {
                    let partial = (t - start).max(0.0);
                    w.free_at = t;
                    w.busy_secs += cost.load_secs + cost.transfer_secs + partial;
                    w.load_secs += cost.load_secs;
                    w.transfer_secs += cost.transfer_secs;
                    w.meter.record(PhaseKind::StorageIo, cost.load_secs);
                    w.meter.record(PhaseKind::GpuCompute, cost.transfer_secs + partial);
                    requeued_requests += batch.reqs.len();
                    if let Some(track) = &track {
                        self.trace.instant(
                            track,
                            "crash_requeue",
                            t,
                            &[("n", Arg::U(batch.reqs.len() as u64))],
                        );
                    }
                    let mut again = batch;
                    again.release_secs = t;
                    queue.push_back(again);
                    continue;
                }
            }
            w.free_at = done;
            w.busy_secs += cost.total_secs();
            w.load_secs += cost.load_secs;
            w.transfer_secs += cost.transfer_secs;
            w.batches += 1;
            w.requests += batch.reqs.len();
            w.tokens_out += batch.reqs.iter().map(|r| r.output_tokens).sum::<usize>();
            w.meter.record(PhaseKind::StorageIo, cost.load_secs);
            w.meter.record(PhaseKind::GpuCompute, cost.exec_secs());
            for &arrival in &batch.arrivals {
                latency.record(done - arrival);
                if let Some(h) = &self.latency_hist {
                    h.record(done - arrival);
                }
            }
            if let Some(g) = self.wmetrics.get(wi) {
                g.update(w, done);
            }
            if let Some(s) = &self.sampler {
                s.lock().unwrap().advance_to(done);
            }

            // Lost-chunk accounting: chunks that *were* materialized but
            // sit on dead storage were just recomputed on this worker.
            // The surcharge is exact — this batch's prefill minus what
            // it would have cost with those chunks loadable, priced on
            // the assigned device.
            let mut retry_surcharge = 0.0f64;
            if lost.is_some() {
                let mut lost_ids: HashSet<ChunkId> = HashSet::new();
                let mut lost_elems = 0usize;
                for ids in &batch.retrieved {
                    for &id in ids {
                        if materialized(id) && is_lost(id) {
                            lost_elems += 1;
                            lost_ids.insert(id);
                        }
                    }
                }
                if !lost_ids.is_empty() {
                    recomputed_chunks += lost_ids.len();
                    degraded_tokens += lost_elems * self.model.chunk_tokens;
                    let healthy =
                        self.model.batch_work(&batch.reqs, &batch.retrieved, materialized);
                    let healthy_prefill =
                        self.model.arch.trace_secs(&healthy.prefill, &self.workers[wi].profile);
                    retry_surcharge = (cost.prefill_secs - healthy_prefill).max(0.0);
                    recompute_fallback_secs += retry_surcharge;
                }
            }

            // Trace the batch's timeline on this worker's track and
            // record one critical-path attribution per request. The
            // components sum to `done - arrival` *algebraically*: queue
            // absorbs both the pre-release wait and the device-busy gap,
            // pcie is pure wire time (the queued share is `bus`), and
            // compute is exec minus the recompute surcharge.
            if let Some(track) = &track {
                let bi = Arg::U((popped - 1) as u64);
                if cost.load_secs > 0.0 {
                    self.trace.span(track, "load", batch.release_secs, cost.load_secs, &[
                        ("batch", bi.clone()),
                    ]);
                }
                if transfer_done > load_done {
                    self.trace.span(track, "upload", load_done, transfer_done - load_done, &[
                        ("batch", bi.clone()),
                        ("bytes", Arg::U(cost.transfer_bytes as u64)),
                        ("queued_secs", Arg::F(bus_queued)),
                    ]);
                }
                if cost.prefill_secs > 0.0 {
                    self.trace.span(track, "prefill", start, cost.prefill_secs, &[
                        ("batch", bi.clone()),
                    ]);
                }
                if cost.decode_secs > 0.0 {
                    self.trace.span(
                        track,
                        "decode",
                        start + cost.prefill_secs,
                        cost.decode_secs,
                        &[("batch", bi.clone())],
                    );
                }
                self.trace.instant(track, "done", done, &[
                    ("batch", bi),
                    ("n", Arg::U(batch.reqs.len() as u64)),
                ]);
                for (r, &arrival) in batch.reqs.iter().zip(&batch.arrivals) {
                    self.trace.request_path(RequestPath {
                        request_id: r.id,
                        worker: track.clone(),
                        arrival_secs: arrival,
                        done_secs: done,
                        queue_secs: (batch.release_secs - arrival) + (start - transfer_done),
                        storage_secs: cost.load_secs,
                        bus_secs: bus_queued,
                        pcie_secs: (transfer_done - load_done) - bus_queued,
                        compute_secs: (done - start) - retry_surcharge,
                        retry_secs: retry_surcharge,
                    });
                }
            }

            // Evolve both residency models: the batch's materialized
            // chunks are now in host DRAM and on this worker.
            for &id in &work.unique_chunks {
                self.workers[wi].admit_resident(id, chunk_bytes);
                self.host_resident.insert(id);
            }
        }

        let makespan = self.workers.iter().map(|w| w.free_at).fold(0.0f64, f64::max);
        for (w, g) in self.workers.iter().zip(&self.wmetrics) {
            g.update(w, makespan);
        }
        if let Some(s) = &self.sampler {
            s.lock().unwrap().finish(makespan);
        }
        let mut total_kj = 0.0;
        let mut workers = Vec::with_capacity(self.workers.len());
        let mut metrics = PhaseBreakdown::default();
        for w in &mut self.workers {
            // Close the integral: whatever the box wasn't computing, it
            // idled at its floor until the fleet drained.
            w.meter.record(PhaseKind::HostIdle, (makespan - w.busy_secs).max(0.0));
            let energy_kj = w.meter.system_report().total_kj;
            total_kj += energy_kj;
            let link = w.link.stats.snapshot();
            metrics.worker_busy_secs.push(w.busy_secs);
            metrics.worker_batches.push(w.batches);
            metrics.worker_transfer_secs.push(w.transfer_secs);
            metrics.worker_link_queued_secs.push(link.queued_secs);
            metrics.worker_link_peak_backlog_secs.push(link.peak_backlog_secs);
            workers.push(WorkerReport {
                name: w.profile.name.clone(),
                role: w.role,
                batches: w.batches,
                requests: w.requests,
                tokens_out: w.tokens_out,
                busy_secs: w.busy_secs,
                load_secs: w.load_secs,
                transfer_secs: w.transfer_secs,
                utilization: if makespan > 0.0 { w.busy_secs / makespan } else { 0.0 },
                energy_kj,
                link,
            });
        }
        let requests: usize = workers.iter().map(|w| w.requests).sum();
        let tokens_out: usize = workers.iter().map(|w| w.tokens_out).sum();
        metrics.requests = requests;
        metrics.tokens_out = tokens_out;
        metrics.request_latency = latency.clone();
        metrics.requeued_requests = requeued_requests;
        metrics.recomputed_chunks = recomputed_chunks;
        metrics.recompute_fallback_secs = recompute_fallback_secs;
        metrics.degraded_tokens = degraded_tokens;

        FleetReport {
            routing: self.routing,
            contention: self.contention,
            workers,
            assignments,
            prefill_batches,
            decode_batches,
            makespan_secs: makespan,
            requests,
            tokens_out,
            total_kj,
            tokens_per_joule: if total_kj > 0.0 {
                tokens_out as f64 / (total_kj * 1e3)
            } else {
                0.0
            },
            latency: latency.summary(),
            metrics,
        }
    }
}

/// The fleet's per-batch service model for the scheduler (see
/// [`Fleet::service_estimator_with`]).
struct FleetServiceEstimator {
    model: FleetCostModel,
    reference: DeviceProfile,
    workers: usize,
    materialized: Arc<dyn Fn(ChunkId) -> bool + Send + Sync>,
}

impl ServiceEstimator for FleetServiceEstimator {
    fn batch_secs(&self, reqs: &[RagRequest], retrieved: &[Vec<ChunkId>]) -> f64 {
        let none = HashSet::new();
        let cost = self.model.batch_cost(
            reqs,
            retrieved,
            &self.reference,
            &none,
            &none,
            &*self.materialized,
        );
        cost.total_secs() / self.workers.max(1) as f64
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn model() -> FleetCostModel {
        FleetCostModel {
            arch: ArchSpec::llama_70b(),
            storage: StorageProfile::ssd_9100pro(),
            chunk_tokens: 1024,
            query_tokens: 20,
            chunk_step: 256,
        }
    }

    fn req(id: u64, out: usize) -> RagRequest {
        RagRequest {
            id,
            query: format!("q{id}"),
            top_k: 2,
            output_tokens: out,
            topic: 0,
        }
    }

    /// A batch of `n` requests, each retrieving the same `ids`.
    fn batch(id0: u64, n: usize, ids: Vec<ChunkId>, release: f64) -> PlannedBatch {
        PlannedBatch {
            reqs: (0..n).map(|i| req(id0 + i as u64, 16)).collect(),
            retrieved: vec![ids; n],
            arrivals: vec![release; n],
            release_secs: release,
        }
    }

    fn all_materialized(_: ChunkId) -> bool {
        true
    }

    #[test]
    fn spec_parses_counts_and_rejects_junk() {
        let spec = FleetSpec::parse("h100:1,rtx4090:3").unwrap();
        assert_eq!(spec.len(), 4);
        assert_eq!(spec.workers[0].name, "H100");
        assert!(spec.workers[1..].iter().all(|p| p.name == "RTX4090"));
        // bare name = count 1; case-insensitive
        assert_eq!(FleetSpec::parse("RTX4090").unwrap().len(), 1);
        assert!(FleetSpec::parse("").is_err());
        assert!(FleetSpec::parse("h100:0").is_err());
        assert!(FleetSpec::parse("h100:x").is_err());
        let err = FleetSpec::parse("tpu:2").unwrap_err();
        assert!(format!("{err:#}").contains("unknown GPU class"), "{err:#}");
    }

    #[test]
    fn roles_follow_device_class() {
        let mixed = Fleet::new(
            &FleetSpec::parse("h100:1,rtx4090:2").unwrap(),
            Routing::RoleAware,
            model(),
        );
        assert_eq!(mixed.roles(), vec![Role::Prefill, Role::Decode, Role::Decode]);
        // homogeneous fleet: everyone is prefill-capable (decode-class
        // batches fall back to the whole pool)
        let homo =
            Fleet::new(&FleetSpec::parse("rtx4090:2").unwrap(), Routing::RoleAware, model());
        assert_eq!(homo.roles(), vec![Role::Prefill, Role::Prefill]);
    }

    #[test]
    fn round_robin_cycles_workers() {
        let spec = FleetSpec::parse("h100:1,rtx4090:1").unwrap();
        let mut fleet = Fleet::new(&spec, Routing::RoundRobin, model());
        let batches: Vec<PlannedBatch> =
            (0..4).map(|i| batch(10 * i, 2, vec![i, i + 100], 0.0)).collect();
        let rep = fleet.dispatch(&batches, &all_materialized);
        assert_eq!(rep.assignments, vec![0, 1, 0, 1]);
        assert_eq!(rep.workers[0].batches, 2);
        assert_eq!(rep.workers[1].batches, 2);
        assert_eq!(rep.requests, 8);
        assert_eq!(rep.tokens_out, 8 * 16);
    }

    #[test]
    fn role_aware_separates_prefill_from_decode_traffic() {
        let spec = FleetSpec::parse("h100:1,rtx4090:2").unwrap();
        let mut fleet = Fleet::new(&spec, Routing::RoleAware, model());
        // chunk 7 was never materialized → its batch is prefill-heavy
        let materialized = |id: ChunkId| id != 7;
        let batches = vec![
            batch(0, 4, vec![1, 2], 0.0),  // decode-class
            batch(10, 4, vec![7, 2], 0.0), // prefill-heavy
            batch(20, 4, vec![3, 4], 0.0), // decode-class
        ];
        let rep = fleet.dispatch(&batches, &materialized);
        assert_eq!(rep.prefill_batches, 1);
        assert_eq!(rep.decode_batches, 2);
        // the miss batch rode the H100; resident batches rode 4090s
        assert_eq!(rep.assignments[1], 0, "prefill-heavy batch must take the high-end card");
        assert_ne!(rep.assignments[0], 0);
        assert_ne!(rep.assignments[2], 0);
        // two decode batches at equal release spread across the two
        // 4090s (earliest-finish: the second would otherwise queue)
        assert_ne!(rep.assignments[0], rep.assignments[2]);
    }

    #[test]
    fn transfer_charged_when_chunks_loaded_by_another_worker() {
        // Same chunk set, two batches, two workers round-robin: worker 1
        // pays the PCIe transfer for chunks worker 0 loaded (they are
        // host-resident by then — no storage read — but not on worker
        // 1's device). A single-worker fleet pays neither on the repeat.
        let m = model();
        let ids = vec![1u64, 2];
        let mk = |r| batch(10 * r as u64, 2, ids.clone(), 0.0);

        let mut pair = Fleet::new(
            &FleetSpec::parse("rtx4090:2").unwrap(),
            Routing::RoundRobin,
            m.clone(),
        );
        let rep = pair.dispatch(&[mk(0), mk(1)], &all_materialized);
        assert_eq!(rep.assignments, vec![0, 1]);
        assert!(rep.workers[0].load_secs > 0.0, "first toucher reads the device");
        assert_eq!(rep.workers[1].load_secs, 0.0, "host-resident: no second read");
        assert!(
            rep.workers[1].transfer_secs > 0.0,
            "cross-worker reuse still crosses PCIe"
        );

        let pair_first_load = rep.workers[0].load_secs;
        let mut solo =
            Fleet::new(&FleetSpec::parse("rtx4090:1").unwrap(), Routing::RoundRobin, m);
        let rep = solo.dispatch(&[mk(0), mk(1)], &all_materialized);
        // batch 2 reuses the worker-resident chunks: no second load, and
        // only batch 1's transfer on the books
        let w = &rep.workers[0];
        assert_eq!(w.load_secs, pair_first_load, "repeat batch must not re-read");
        assert!(w.transfer_secs > 0.0);
        let one_batch_transfer =
            2.0 * m_transfer_bytes() / DeviceProfile::rtx4090().pcie_bw;
        assert!(
            (w.transfer_secs - one_batch_transfer).abs() < 1e-9,
            "repeat batch must not re-transfer: {} vs {}",
            w.transfer_secs,
            one_batch_transfer
        );
    }

    /// Bytes one of the test batches transfers (2 unique chunks).
    fn m_transfer_bytes() -> f64 {
        model().arch.kv_bytes(1024)
    }

    #[test]
    fn dispatch_is_deterministic() {
        // Same schedule + same spec → identical assignments, worker
        // stats and latency percentiles, run to run (the virtual clock
        // has no wall-clock anywhere).
        let batches: Vec<PlannedBatch> = (0..10)
            .map(|i| batch(10 * i, 3, vec![i % 4, 50 + i % 3], 0.01 * i as f64))
            .collect();
        let run = || {
            let mut fleet = Fleet::new(
                &FleetSpec::parse("h100:1,rtx4090:3").unwrap(),
                Routing::RoleAware,
                model(),
            );
            fleet.dispatch(&batches, &|id| id != 2)
        };
        let (a, b) = (run(), run());
        assert_eq!(a.assignments, b.assignments);
        assert_eq!(a.latency, b.latency);
        assert_eq!(a.makespan_secs, b.makespan_secs);
        assert_eq!(a.total_kj, b.total_kj);
        for (x, y) in a.workers.iter().zip(&b.workers) {
            assert_eq!(x.busy_secs, y.busy_secs);
            assert_eq!(x.batches, y.batches);
        }
        // ...and re-dispatching through the SAME fleet is an
        // independent simulation: no clock/energy/residency bleed.
        let mut reused = Fleet::new(
            &FleetSpec::parse("h100:1,rtx4090:3").unwrap(),
            Routing::RoleAware,
            model(),
        );
        let first = reused.dispatch(&batches, &|id| id != 2);
        let second = reused.dispatch(&batches, &|id| id != 2);
        assert_eq!(first.assignments, second.assignments);
        assert_eq!(first.total_kj, second.total_kj);
        assert_eq!(first.makespan_secs, second.makespan_secs);
        assert_eq!(first.latency, second.latency);
    }

    #[test]
    fn latency_percentiles_match_hand_computed_completions() {
        // One worker, two single-request batches with disjoint chunk
        // sets released at t=0, on the pipelined timeline: load from
        // release, chunked upload across the worker's PCIe link,
        // compute when both the device and the bytes are ready. The
        // mirror below replays the dispatcher's exact arithmetic —
        // same h2d_upload(), scratch link — so the expected
        // completions are bit-identical, not approximations.
        let m = model();
        let b1 = batch(0, 1, vec![1, 2], 0.0);
        let b2 = batch(10, 1, vec![3, 4], 0.0);
        let dev = DeviceProfile::h100();
        let none = HashSet::new();
        let c1 = m.batch_cost(&b1.reqs, &b1.retrieved, &dev, &none, &none, &all_materialized);
        // batch 2 prices with batch 1's chunks host-resident but its own
        // still cold — disjoint ids keep c2 independent of that state
        let host: HashSet<ChunkId> = [1, 2].into_iter().collect();
        let mut on_device: HashSet<ChunkId> = HashSet::new();
        on_device.extend([1u64, 2]);
        let c2 = m.batch_cost(&b2.reqs, &b2.retrieved, &dev, &host, &on_device, &all_materialized);

        let mirror = Link::new("mirror", dev.pcie_bw, 0.0, LinkClock::Virtual);
        let chunk = m.chunk_kv_bytes();
        let done1 = h2d_upload(&mirror, 0.0 + c1.load_secs, &c1, chunk).max(0.0)
            + c1.prefill_secs
            + c1.decode_secs;
        let done2 = h2d_upload(&mirror, 0.0 + c2.load_secs, &c2, chunk).max(done1)
            + c2.prefill_secs
            + c2.decode_secs;

        let mut fleet =
            Fleet::new(&FleetSpec::parse("h100:1").unwrap(), Routing::RoundRobin, m);
        let rep = fleet.dispatch(&[b1, b2], &all_materialized);
        let mut expect = Percentiles::default();
        expect.record(done1);
        expect.record(done2);
        assert_eq!(rep.latency, expect.summary());
        assert_eq!(rep.makespan_secs, done2);
        assert!(rep.latency.p50 <= rep.latency.p99);
        // batch 2's upload queued behind batch 1's on the single link
        assert!(rep.workers[0].link.queued_secs > 0.0, "second upload must queue");
        // the metrics shape carries the same samples + link gauges
        assert_eq!(rep.metrics.request_latency.summary(), rep.latency);
        assert_eq!(rep.metrics.worker_busy_secs, vec![rep.workers[0].busy_secs]);
        assert_eq!(
            rep.metrics.worker_link_queued_secs,
            vec![rep.workers[0].link.queued_secs]
        );
    }

    #[test]
    fn upload_overlaps_prior_compute_on_the_virtual_clock() {
        // Double buffering: batch 2's load+upload runs while the worker
        // is still computing batch 1, so the pipelined makespan beats
        // the serial sum of the two batch costs.
        let m = model();
        let b1 = batch(0, 4, vec![1, 2], 0.0);
        let b2 = batch(10, 4, vec![3, 4], 0.0);
        let dev = DeviceProfile::h100();
        let none = HashSet::new();
        let c1 = m
            .batch_cost(&b1.reqs, &b1.retrieved, &dev, &none, &none, &all_materialized)
            .total_secs();
        let c2 = m
            .batch_cost(&b2.reqs, &b2.retrieved, &dev, &none, &none, &all_materialized)
            .total_secs();
        let mut fleet =
            Fleet::new(&FleetSpec::parse("h100:1").unwrap(), Routing::RoundRobin, m);
        let rep = fleet.dispatch(&[b1, b2], &all_materialized);
        assert!(
            rep.makespan_secs < c1 + c2 - 1e-9,
            "batch 2's load+upload must hide under batch 1's compute: {} vs serial {}",
            rep.makespan_secs,
            c1 + c2
        );
        // the upload rode the link chunk-granularly: 2 chunks x 2 batches
        let link = &rep.workers[0].link;
        assert_eq!(link.reserves, 4);
        assert!(link.bytes_by_class[TrafficClass::H2D.index()] > 0);
        assert!(link.busy_secs > 0.0);
    }

    #[test]
    fn contention_off_grants_horizon_free_uploads() {
        // Transfer-dominant plan (32 cold chunks, 1 output token per
        // batch): with queueing on, consecutive uploads wait behind
        // each other and stretch the makespan; off, the same plan
        // finishes sooner and reports zero queued seconds — the A/B
        // fig_bus measures at scale.
        let mk = |id0: u64| PlannedBatch {
            reqs: vec![req(id0, 1)],
            retrieved: vec![(0..32u64).map(|i| id0 * 100 + i).collect()],
            arrivals: vec![0.0],
            release_secs: 0.0,
        };
        let batches: Vec<PlannedBatch> = (1..=4).map(mk).collect();
        let run = |on: bool| {
            let mut fleet =
                Fleet::new(&FleetSpec::parse("h100:1").unwrap(), Routing::RoundRobin, model());
            fleet.set_contention(on);
            fleet.dispatch(&batches, &all_materialized)
        };
        let (on, off) = (run(true), run(false));
        assert!(on.contention && !off.contention);
        assert!(on.workers[0].link.queued_secs > 0.0, "a 4-deep upload burst must queue");
        assert_eq!(off.workers[0].link.queued_secs, 0.0, "disabled link never queues");
        assert!(
            on.makespan_secs > off.makespan_secs + 1e-9,
            "queueing must stretch a transfer-bound makespan: {} vs {}",
            on.makespan_secs,
            off.makespan_secs
        );
        // wire time and work are identical either way — only the
        // queueing differs
        assert_eq!(on.workers[0].transfer_secs, off.workers[0].transfer_secs);
        assert_eq!(on.tokens_out, off.tokens_out);
        assert!(on.to_json().contains("\"contention\":true"));
        assert!(off.to_json().contains("\"contention\":false"));
    }

    #[test]
    fn mixed_fleet_beats_single_h100_on_tokens_per_joule() {
        // The fig_fleet acceptance shape at unit scale: same offered
        // load (12 decode-class batches of 8), a 1×H100+3×4090 fleet
        // under role-aware routing must generate strictly more tokens
        // per joule than routing everything to the H100 alone — decode
        // is nearly class-blind while the desktop boxes draw far less.
        let batches: Vec<PlannedBatch> = (0..12)
            .map(|i| batch(100 * i, 8, vec![2 * i, 2 * i + 1], 0.0))
            .collect();
        let mut single =
            Fleet::new(&FleetSpec::parse("h100:1").unwrap(), Routing::RoundRobin, model());
        let alone = single.dispatch(&batches, &all_materialized);
        let mut mixed = Fleet::new(
            &FleetSpec::parse("h100:1,rtx4090:3").unwrap(),
            Routing::RoleAware,
            model(),
        );
        let fleet = mixed.dispatch(&batches, &all_materialized);
        assert_eq!(alone.tokens_out, fleet.tokens_out, "equal offered load");
        assert!(
            fleet.tokens_per_joule > alone.tokens_per_joule,
            "mixed fleet must win: {} vs {} tok/J",
            fleet.tokens_per_joule,
            alone.tokens_per_joule
        );
        // and it finishes sooner (three decode lanes)
        assert!(fleet.makespan_secs < alone.makespan_secs);
        // per-worker utilization surfaces the disaggregation: the H100
        // idles while the 4090s decode
        assert_eq!(fleet.workers[0].batches, 0);
        assert!(fleet.workers[1..].iter().all(|w| w.batches > 0));
    }

    #[test]
    fn service_estimator_prices_batches_for_the_planner() {
        let fleet = Fleet::new(
            &FleetSpec::parse("h100:1,rtx4090:3").unwrap(),
            Routing::RoleAware,
            model(),
        );
        let est = fleet.service_estimator();
        let b = batch(0, 8, vec![1, 2], 0.0);
        let secs = est.batch_secs(&b.reqs, &b.retrieved);
        assert!(secs > 0.0);
        // amortized over the 4 workers: a quarter of the solo cost
        let solo = Fleet::new(&FleetSpec::parse("h100:1").unwrap(), Routing::RoundRobin, model())
            .service_estimator()
            .batch_secs(&b.reqs, &b.retrieved);
        assert!((solo / secs - 4.0).abs() < 1e-9, "{solo} vs {secs}");
        // a bigger batch costs more
        let big = batch(0, 8, vec![1, 2, 3, 4], 0.0);
        assert!(est.batch_secs(&big.reqs, &big.retrieved) > secs);
        // an unmaterialized chunk prices as on-device recompute: the
        // estimator must charge the cache-miss batch strictly more
        let est_miss = fleet.service_estimator_with(Arc::new(|id| id != 1));
        assert!(
            est_miss.batch_secs(&b.reqs, &b.retrieved) > secs,
            "prefill-heavy batches must out-price resident ones"
        );
    }

    #[test]
    fn worker_crash_requeues_in_flight_requests_onto_survivors() {
        // Worker 1 dies almost immediately: the two batches round-robin
        // would hand it are interrupted mid-dispatch and requeued onto
        // worker 0 with their arrival times intact — no request is lost.
        let plan = Arc::new(FaultPlan::parse("worker1:crash@0.0001").unwrap());
        let batches: Vec<PlannedBatch> =
            (0..4).map(|i| batch(10 * i, 2, vec![i, i + 100], 0.0)).collect();
        let mut fleet =
            Fleet::new(&FleetSpec::parse("rtx4090:2").unwrap(), Routing::RoundRobin, model());
        fleet.set_faults(plan);
        let rep = fleet.dispatch(&batches, &all_materialized);
        assert_eq!(rep.requests, 8, "every request must complete despite the crash");
        assert_eq!(rep.tokens_out, 8 * 16);
        assert_eq!(rep.metrics.request_latency.len(), 8, "one latency sample per request");
        assert!(rep.metrics.requeued_requests > 0, "crash must requeue in-flight work");
        assert_eq!(rep.workers[0].batches, 4, "the survivor absorbs everything");
        assert_eq!(rep.workers[1].batches, 0, "the dead card completes nothing");
        assert!(rep.to_json().contains("\"requeued_requests\":"));
    }

    #[test]
    fn faulted_dispatch_is_deterministic_and_reroutes_around_dead_storage() {
        // A decode card crashes mid-trace and chunk 3's shard is gone:
        // role-aware routing rebalances onto the survivors, the lost
        // chunk prices as on-device recompute (billed to the assigned
        // worker), and the whole faulted run replays bit-identically.
        let batches: Vec<PlannedBatch> = (0..8)
            .map(|i| batch(10 * i, 3, vec![i % 4, 50 + i % 3], 0.01 * i as f64))
            .collect();
        let run = || {
            let mut fleet = Fleet::new(
                &FleetSpec::parse("h100:1,rtx4090:2").unwrap(),
                Routing::RoleAware,
                model(),
            );
            fleet.set_faults(Arc::new(FaultPlan::parse("seed=3,worker2:crash@0.02").unwrap()));
            fleet.set_lost_chunks(Arc::new(|id| id == 3));
            fleet.dispatch(&batches, &all_materialized)
        };
        let (a, b) = (run(), run());
        assert_eq!(a.assignments, b.assignments);
        assert_eq!(a.latency, b.latency);
        assert_eq!(a.makespan_secs, b.makespan_secs);
        assert_eq!(a.total_kj, b.total_kj);
        // zero failed requests: all 24 planned requests completed
        assert_eq!(a.requests, 8 * 3);
        // the dead shard's chunk was recomputed — and billed — somewhere
        assert!(a.metrics.recomputed_chunks > 0);
        assert!(a.metrics.degraded_tokens > 0);
        assert!(a.metrics.recompute_fallback_secs > 0.0);
        // batches retrieving chunk 3 are prefill-heavy now → the H100
        assert!(a.prefill_batches > 0);
        assert!(a.to_json().contains("\"recomputed_chunks\":"));
    }

    #[test]
    fn fault_free_dispatch_is_unchanged_by_the_fault_plumbing() {
        // No plan installed: the queue-based loop must replay the
        // pre-fault dispatch exactly — zeroed recovery counters and the
        // same decision trail the clean determinism test pins.
        let batches: Vec<PlannedBatch> =
            (0..6).map(|i| batch(10 * i, 2, vec![i, i + 100], 0.005 * i as f64)).collect();
        let mut fleet = Fleet::new(
            &FleetSpec::parse("h100:1,rtx4090:3").unwrap(),
            Routing::RoleAware,
            model(),
        );
        let rep = fleet.dispatch(&batches, &all_materialized);
        assert_eq!(rep.assignments.len(), batches.len(), "no requeues on a clean run");
        assert_eq!(rep.metrics.requeued_requests, 0);
        assert_eq!(rep.metrics.recomputed_chunks, 0);
        assert_eq!(rep.metrics.degraded_tokens, 0);
        assert_eq!(rep.metrics.recompute_fallback_secs, 0.0);
        assert!(rep.to_json().contains("\"requeued_requests\":0"));
    }

    #[test]
    fn empty_dispatch_is_zeroes_not_nans() {
        let mut fleet =
            Fleet::new(&FleetSpec::parse("h100:1").unwrap(), Routing::RoleAware, model());
        let rep = fleet.dispatch(&[], &all_materialized);
        assert_eq!(rep.makespan_secs, 0.0);
        assert_eq!(rep.tokens_per_joule, 0.0);
        assert_eq!(rep.workers[0].utilization, 0.0);
        assert!(rep.to_json().contains("\"tokens_out\":0"));
    }

    /// The schedule the three tracing tests below share: enough batches
    /// to exercise load, queued uploads, prefill and decode on a mixed
    /// fleet, with one chunk unmaterialized so prefill-heavy routing
    /// fires too.
    fn trace_batches() -> Vec<PlannedBatch> {
        (0..10).map(|i| batch(10 * i, 3, vec![i % 4, 50 + i % 3], 0.01 * i as f64)).collect()
    }

    #[test]
    fn traced_dispatch_exports_byte_identically_across_runs() {
        // The tentpole's determinism contract at fleet scope: same
        // schedule + same spec ⇒ the exported trace is the same STRING,
        // not merely equivalent events.
        let batches = trace_batches();
        let run = || {
            let mut fleet = Fleet::new(
                &FleetSpec::parse("h100:1,rtx4090:3").unwrap(),
                Routing::RoleAware,
                model(),
            );
            let bus = TraceBus::recording();
            fleet.set_trace(bus.clone());
            fleet.dispatch(&batches, &|id| id != 2);
            bus.to_chrome_json()
        };
        let (a, b) = (run(), run());
        assert!(!a.is_empty());
        assert_eq!(a, b, "trace export must be byte-identical run to run");
        // worker tracks and link tracks both present and named
        assert!(a.contains("\"name\":\"thread_name\""));
        assert!(a.contains("worker0:H100"));
        assert!(a.contains("link:worker0:H100"));
        assert!(a.contains("\"name\":\"decode\""));
    }

    #[test]
    fn tracing_does_not_change_dispatch_results() {
        // Bit-identity pin: a recording bus must be write-only — the
        // dispatch decision trail and every reported number match the
        // untraced run exactly.
        let batches = trace_batches();
        let untraced = {
            let mut fleet = Fleet::new(
                &FleetSpec::parse("h100:1,rtx4090:3").unwrap(),
                Routing::RoleAware,
                model(),
            );
            fleet.dispatch(&batches, &|id| id != 2)
        };
        let traced = {
            let mut fleet = Fleet::new(
                &FleetSpec::parse("h100:1,rtx4090:3").unwrap(),
                Routing::RoleAware,
                model(),
            );
            fleet.set_trace(TraceBus::recording());
            fleet.dispatch(&batches, &|id| id != 2)
        };
        assert_eq!(untraced.assignments, traced.assignments);
        assert_eq!(untraced.latency, traced.latency);
        assert_eq!(untraced.makespan_secs, traced.makespan_secs);
        assert_eq!(untraced.total_kj, traced.total_kj);
        assert_eq!(untraced.to_json(), traced.to_json());
    }

    #[test]
    fn attribution_components_sum_to_request_latency() {
        // Acceptance criterion: every traced request's critical-path
        // components sum to its end-to-end latency within 1e-6 s — on a
        // clean run AND under faults (crash requeue + lost chunks),
        // where the queue and retry components do the absorbing.
        let batches = trace_batches();
        let bus = TraceBus::recording();
        let mut fleet = Fleet::new(
            &FleetSpec::parse("h100:1,rtx4090:3").unwrap(),
            Routing::RoleAware,
            model(),
        );
        fleet.set_trace(bus.clone());
        let rep = fleet.dispatch(&batches, &|id| id != 2);
        let paths = bus.paths();
        assert_eq!(paths.len(), rep.requests, "one attribution record per request");
        assert!(bus.max_attribution_err() < 1e-6, "err {}", bus.max_attribution_err());
        for p in &paths {
            assert!(p.latency_secs() > 0.0);
            assert!(p.queue_secs >= -1e-9 && p.storage_secs >= 0.0 && p.compute_secs >= 0.0);
        }

        // Faulted: a crashed decode card and a dead-storage chunk.
        let bus = TraceBus::recording();
        let mut fleet = Fleet::new(
            &FleetSpec::parse("h100:1,rtx4090:3").unwrap(),
            Routing::RoleAware,
            model(),
        );
        fleet.set_faults(Arc::new(FaultPlan::parse("worker3:crash@0.02").unwrap()));
        fleet.set_lost_chunks(Arc::new(|id| id == 1));
        fleet.set_trace(bus.clone());
        let rep = fleet.dispatch(&batches, &|id| id != 2);
        assert!(rep.metrics.recomputed_chunks > 0, "lost chunk must recompute");
        assert_eq!(bus.paths().len(), rep.requests);
        assert!(bus.max_attribution_err() < 1e-6, "err {}", bus.max_attribution_err());
        let retried: f64 = bus.paths().iter().map(|p| p.retry_secs).sum();
        assert!(retried > 0.0, "recompute surcharge must land in the retry component");
    }
}
