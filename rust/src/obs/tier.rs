//! Tier telemetry on the shared observability machinery.
//!
//! [`CacheSample`]/[`series_to_json`] started life inside
//! `kvstore/cache.rs` as the repo's only (hand-rolled) time series, and
//! the warm tier carried a copy-pasted sampling path of its own. They
//! now live here: one sample shape, one bounded series buffer
//! ([`TierSeries`]), and one sampling + registration path
//! ([`TierMetrics`]) that both DRAM tiers share. `kvstore` re-exports
//! the names, so existing consumers (`fig_tier_hit`, `fig_sched`,
//! `fig_shard_scale`, `fig_warm_tier` JSON embeds) keep compiling and
//! keep their byte-exact JSON shape.

use std::sync::{Arc, Mutex};

use anyhow::Result;

use super::registry::MetricsRegistry;
use crate::kvstore::cache::{CacheStats, TierKind};

/// One cumulative telemetry snapshot of a DRAM tier. Producers
/// (benches, the overlap pipeline) call [`TierMetrics::sample`] once
/// per batch / access window; consumers diff consecutive samples to get
/// the per-batch rates the hit-ratio-vs-offered-load curves need.
#[derive(Debug, Clone, Copy, Default, PartialEq)]
pub struct CacheSample {
    /// Which tier recorded this sample (`"hot"` for pre-warm consumers).
    pub tier: TierKind,
    pub hits: u64,
    pub misses: u64,
    pub insertions: u64,
    pub evictions: u64,
    pub prefetch_inserts: u64,
    pub prefetch_hits: u64,
    pub prefetch_rejected: u64,
    /// Modeled seconds spent dequantizing q8 hits (warm tier only; the
    /// hot tier serves f32 and leaves this 0).
    pub dequant_secs: f64,
    /// Modeled seconds spent quantizing chunks *into* the q8 tier
    /// (demotions and direct admissions; symmetric to `dequant_secs`).
    pub quant_secs: f64,
    /// Seconds this tier's quant/dequant transfers spent queued behind
    /// other traffic on the shared host bus
    /// ([`crate::hwsim::Link`]) — 0 for tiers not wired to a bus.
    pub link_queued_secs: f64,
    pub resident_bytes: u64,
    pub resident_chunks: u64,
}

impl CacheSample {
    /// Compact JSON object — the one serializer for the telemetry
    /// series, so benches embedding it in `--json` output can't drift
    /// from the struct's fields. The field order is pinned by
    /// downstream consumers; new telemetry goes through the registry,
    /// not through this shape.
    pub fn to_json(&self) -> String {
        format!(
            "{{\"tier\":\"{}\",\"hits\":{},\"misses\":{},\"insertions\":{},\"evictions\":{},\
             \"prefetch_inserts\":{},\"prefetch_hits\":{},\"prefetch_rejected\":{},\
             \"dequant_secs\":{:.6},\"quant_secs\":{:.6},\"link_queued_secs\":{:.6},\
             \"resident_bytes\":{},\"resident_chunks\":{}}}",
            self.tier.label(),
            self.hits,
            self.misses,
            self.insertions,
            self.evictions,
            self.prefetch_inserts,
            self.prefetch_hits,
            self.prefetch_rejected,
            self.dequant_secs,
            self.quant_secs,
            self.link_queued_secs,
            self.resident_bytes,
            self.resident_chunks
        )
    }
}

/// JSON array of [`CacheSample::to_json`] objects.
pub fn series_to_json(series: &[CacheSample]) -> String {
    let body: Vec<String> = series.iter().map(CacheSample::to_json).collect();
    format!("[{}]", body.join(","))
}

/// Series entries kept before sampling quietly stops (a run that never
/// drains would otherwise grow the series without bound).
const SAMPLE_CAP: usize = 16_384;

/// The bounded tier-telemetry buffer [`CacheStats`] embeds — the one
/// copy of the machinery both tiers used to duplicate.
#[derive(Debug, Default)]
pub struct TierSeries {
    samples: Mutex<Vec<CacheSample>>,
}

impl TierSeries {
    /// Append a snapshot (no-op past the cap).
    pub fn record(&self, sample: CacheSample) {
        let mut s = self.samples.lock().unwrap();
        if s.len() < SAMPLE_CAP {
            s.push(sample);
        }
    }

    pub fn samples(&self) -> Vec<CacheSample> {
        self.samples.lock().unwrap().clone()
    }

    pub fn len(&self) -> usize {
        self.samples.lock().unwrap().len()
    }
}

/// What a byte-budgeted tier exposes to the shared telemetry path: its
/// counters and its residency under the tier's own lock discipline.
/// `sample` is the provided, tier-agnostic sampling path that replaced
/// the per-tier copies.
pub trait TierMetrics {
    fn tier_stats(&self) -> &CacheStats;

    /// Current `(resident_bytes, resident_chunks)` — one lock
    /// acquisition, the implementor owns the discipline.
    fn residency(&self) -> (usize, usize);

    /// Append one cumulative snapshot to the tier's telemetry series.
    fn sample(&self) {
        let (bytes, chunks) = self.residency();
        self.tier_stats().record_sample(bytes, chunks);
    }
}

/// Register every tier counter/gauge into `reg` under
/// `matkv.tier.*{tier=<label>}` as polled bridges over the existing
/// atomics — the hot path pays nothing it wasn't already paying. One
/// registration path for both tiers (hot f32 and warm q8/q4), including
/// the counters the pinned [`CacheSample`] shape can't carry
/// (`admission_rejected`, the q4 clocks).
pub fn register_tier<T>(reg: &MetricsRegistry, tier: Arc<T>) -> Result<()>
where
    T: TierMetrics + Send + Sync + 'static,
{
    use std::sync::atomic::Ordering::Relaxed;
    let label = tier.tier_stats().tier.label();
    let labels = [("tier", label)];
    macro_rules! poll_counter {
        ($name:expr, $help:expr, |$t:ident| $body:expr) => {{
            let t = Arc::clone(&tier);
            reg.counter_fn($name, &labels, $help, move || {
                let $t = t.tier_stats();
                $body
            })?;
        }};
    }
    poll_counter!("matkv.tier.hits", "demand hits served by this tier", |s| {
        s.hits.load(Relaxed) as f64
    });
    poll_counter!("matkv.tier.misses", "demand lookups this tier missed", |s| {
        s.misses.load(Relaxed) as f64
    });
    poll_counter!("matkv.tier.insertions", "chunks admitted", |s| {
        s.insertions.load(Relaxed) as f64
    });
    poll_counter!("matkv.tier.evictions", "chunks evicted", |s| {
        s.evictions.load(Relaxed) as f64
    });
    poll_counter!("matkv.tier.bytes_saved", "device bytes avoided by hits", |s| {
        s.bytes_saved.load(Relaxed) as f64
    });
    poll_counter!("matkv.tier.prefetch_inserts", "prefetch-path admissions", |s| {
        s.prefetch_inserts.load(Relaxed) as f64
    });
    poll_counter!("matkv.tier.prefetch_hits", "demand hits on prefetched entries", |s| {
        s.prefetch_hits.load(Relaxed) as f64
    });
    poll_counter!("matkv.tier.prefetch_rejected", "prefetch admissions dropped", |s| {
        s.prefetch_rejected.load(Relaxed) as f64
    });
    poll_counter!(
        "matkv.tier.admission_rejected",
        "demand admissions refused by the frequency gate",
        |s| s.admission_rejected.load(Relaxed) as f64
    );
    poll_counter!("matkv.tier.dequant_seconds", "modeled q8 dequant seconds", |s| {
        s.dequant_secs()
    });
    poll_counter!("matkv.tier.quant_seconds", "modeled q8 quant seconds", |s| s.quant_secs());
    poll_counter!("matkv.tier.q4_dequant_seconds", "modeled q4 dequant seconds", |s| {
        s.q4_dequant_secs()
    });
    poll_counter!("matkv.tier.q4_quant_seconds", "modeled q4 quant seconds", |s| {
        s.q4_quant_secs()
    });
    poll_counter!(
        "matkv.tier.link_queued_seconds",
        "host-bus queueing absorbed by tier traffic",
        |s| s.link_queued_secs()
    );
    {
        let t = Arc::clone(&tier);
        reg.gauge_fn("matkv.tier.resident_bytes", &labels, "bytes resident", move || {
            t.residency().0 as f64
        })?;
    }
    {
        let t = Arc::clone(&tier);
        reg.gauge_fn("matkv.tier.resident_chunks", &labels, "chunks resident", move || {
            t.residency().1 as f64
        })?;
    }
    Ok(())
}

#[cfg(test)]
mod tests {
    use super::*;
    use std::sync::atomic::Ordering::Relaxed;

    struct FakeTier {
        stats: CacheStats,
        bytes: usize,
        chunks: usize,
    }

    impl TierMetrics for FakeTier {
        fn tier_stats(&self) -> &CacheStats {
            &self.stats
        }
        fn residency(&self) -> (usize, usize) {
            (self.bytes, self.chunks)
        }
    }

    #[test]
    fn shared_sample_path_records_residency() {
        let t = FakeTier { stats: CacheStats::for_tier(TierKind::Warm), bytes: 640, chunks: 2 };
        t.stats.hits.fetch_add(3, Relaxed);
        t.sample();
        let s = t.stats.series();
        assert_eq!(s.len(), 1);
        assert_eq!(s[0].tier, TierKind::Warm);
        assert_eq!(s[0].hits, 3);
        assert_eq!(s[0].resident_bytes, 640);
        assert_eq!(s[0].resident_chunks, 2);
    }

    #[test]
    fn register_tier_exposes_the_pinned_gap_counters() {
        let reg = MetricsRegistry::new();
        let t = Arc::new(FakeTier {
            stats: CacheStats::for_tier(TierKind::Hot),
            bytes: 1024,
            chunks: 1,
        });
        t.stats.admission_rejected.fetch_add(9, Relaxed);
        register_tier(&reg, Arc::clone(&t)).unwrap();
        let vals: std::collections::BTreeMap<String, f64> =
            reg.sampled_values().into_iter().collect();
        assert_eq!(vals["matkv.tier.admission_rejected{tier=hot}"], 9.0);
        assert_eq!(vals["matkv.tier.resident_bytes{tier=hot}"], 1024.0);
        // the same tier registering twice collides loudly
        assert!(register_tier(&reg, t).is_err());
    }

    #[test]
    fn series_buffer_caps() {
        let s = TierSeries::default();
        for _ in 0..(SAMPLE_CAP + 10) {
            s.record(CacheSample::default());
        }
        assert_eq!(s.len(), SAMPLE_CAP);
    }
}
