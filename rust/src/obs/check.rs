//! The bench-regression matrix: normalized metrics per `fig_*` bench,
//! committed baselines with direction-aware tolerance bands, and the
//! comparison that turns "a number moved" into a named, explainable
//! CI failure.
//!
//! Every smoke bench emits a JSON document; [`normalize`] flattens the
//! document into `metric name → value` and attaches a *default band*
//! per metric (which direction is a regression, and how much slack).
//! `rust/testdata/baselines/<bench>.json` holds the committed bands;
//! `bench_check --all` re-runs [`compare`] against the current smoke
//! output and fails with one line per violated band.
//!
//! The committed seed baselines deliberately use only **invariant**
//! directions (`above` / `below` / `exact`) — the properties the CI
//! python asserts already promise (v4 moves strictly fewer flash bytes
//! than v3, zero failed requests under faults, nonzero link queueing at
//! high load, deterministic traces). Measured `higher`/`lower` bands
//! (throughput may not drop, queued-seconds may not grow) come from a
//! real run via `bench_check --bless`, which rewrites the baselines
//! from the machine's own smoke output — see the README's
//! baseline-update workflow.

use std::collections::BTreeMap;
use std::fmt::Write as _;

use anyhow::{bail, Context, Result};

use crate::util::json::Json;

/// Which way a metric is allowed to move before it counts as a
/// regression.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum Direction {
    /// Bigger is better (throughput-like): regression when the current
    /// value falls below `value·(1−rel_tol) − abs_tol`.
    Higher,
    /// Smaller is better (queued-seconds-like): regression when the
    /// current value rises above `value·(1+rel_tol) + abs_tol`.
    Lower,
    /// Invariant strict floor: the current value must be `> value`.
    Above,
    /// Invariant strict ceiling: the current value must be `< value`.
    Below,
    /// Invariant equality within `abs_tol` (flags, determinism bits).
    Exact,
}

impl Direction {
    pub fn label(self) -> &'static str {
        match self {
            Direction::Higher => "higher",
            Direction::Lower => "lower",
            Direction::Above => "above",
            Direction::Below => "below",
            Direction::Exact => "exact",
        }
    }

    pub fn parse(s: &str) -> Result<Direction> {
        Ok(match s {
            "higher" => Direction::Higher,
            "lower" => Direction::Lower,
            "above" => Direction::Above,
            "below" => Direction::Below,
            "exact" => Direction::Exact,
            other => bail!("unknown direction {other:?}"),
        })
    }
}

/// One metric's tolerance band.
#[derive(Debug, Clone, Copy, PartialEq)]
pub struct Band {
    pub value: f64,
    pub direction: Direction,
    pub rel_tol: f64,
    pub abs_tol: f64,
}

impl Band {
    /// Does `current` violate this band? Returns the regression message
    /// (without the metric name) or `None` when it passes.
    pub fn check(&self, current: f64) -> Option<String> {
        let v = self.value;
        match self.direction {
            Direction::Higher => {
                let floor = v * (1.0 - self.rel_tol) - self.abs_tol;
                (current < floor).then(|| {
                    format!(
                        "{current} < floor {floor} (baseline {v}, rel_tol {}, abs_tol {}, \
                         direction=higher)",
                        self.rel_tol, self.abs_tol
                    )
                })
            }
            Direction::Lower => {
                let ceil = v * (1.0 + self.rel_tol) + self.abs_tol;
                (current > ceil).then(|| {
                    format!(
                        "{current} > ceiling {ceil} (baseline {v}, rel_tol {}, abs_tol {}, \
                         direction=lower)",
                        self.rel_tol, self.abs_tol
                    )
                })
            }
            Direction::Above => {
                (!(current > v)).then(|| format!("{current} !> {v} (direction=above)"))
            }
            Direction::Below => {
                (!(current < v)).then(|| format!("{current} !< {v} (direction=below)"))
            }
            Direction::Exact => ((current - v).abs() > self.abs_tol).then(|| {
                format!("{current} != {v} (abs_tol {}, direction=exact)", self.abs_tol)
            }),
        }
    }

    /// A value that satisfies the band (self-test scaffolding).
    pub fn satisfying_value(&self) -> f64 {
        let v = self.value;
        let step = v.abs() * 0.5 + 1.0;
        match self.direction {
            Direction::Higher | Direction::Lower | Direction::Exact => v,
            Direction::Above => v + step,
            Direction::Below => v - step,
        }
    }

    /// A value that violates the band (self-test scaffolding).
    pub fn violating_value(&self) -> f64 {
        let v = self.value;
        let step = v.abs() * 0.5 + 1.0;
        match self.direction {
            Direction::Higher => v * (1.0 - self.rel_tol) - self.abs_tol - step,
            Direction::Lower => v * (1.0 + self.rel_tol) + self.abs_tol + step,
            Direction::Above => v,
            Direction::Below => v,
            Direction::Exact => v + self.abs_tol + step,
        }
    }

    fn to_json(self) -> String {
        format!(
            "{{\"value\":{:.9},\"direction\":\"{}\",\"rel_tol\":{:.9},\"abs_tol\":{:.9}}}",
            self.value,
            self.direction.label(),
            self.rel_tol,
            self.abs_tol
        )
    }

    fn parse(j: &Json) -> Result<Band> {
        Ok(Band {
            value: j.req("value")?.as_f64().context("value not numeric")?,
            direction: Direction::parse(
                j.req("direction")?.as_str().context("direction not a string")?,
            )?,
            rel_tol: j.get("rel_tol").and_then(Json::as_f64).unwrap_or(0.0),
            abs_tol: j.get("abs_tol").and_then(Json::as_f64).unwrap_or(0.0),
        })
    }
}

/// A committed baseline: one bench's metric bands.
#[derive(Debug, Clone, PartialEq)]
pub struct Baseline {
    pub bench: String,
    pub metrics: BTreeMap<String, Band>,
}

/// Version of the baseline file format.
pub const BASELINE_VERSION: u32 = 1;

impl Baseline {
    pub fn parse(text: &str) -> Result<Baseline> {
        let doc = Json::parse(text).context("baseline is not valid JSON")?;
        let version = doc.req("version")?.as_usize().context("version not numeric")?;
        if version != BASELINE_VERSION as usize {
            bail!("baseline version {version} unsupported (want {BASELINE_VERSION})");
        }
        let bench = doc.req("bench")?.as_str().context("bench not a string")?.to_string();
        let mut metrics = BTreeMap::new();
        for (name, band) in doc.req("metrics")?.as_obj().context("metrics not an object")? {
            metrics.insert(
                name.clone(),
                Band::parse(band).with_context(|| format!("metric {name:?}"))?,
            );
        }
        Ok(Baseline { bench, metrics })
    }

    /// Deterministic serialization (sorted metric names).
    pub fn to_json(&self) -> String {
        let mut out = format!(
            "{{\"version\":{BASELINE_VERSION},\"bench\":\"{}\",\"metrics\":{{",
            self.bench
        );
        for (i, (name, band)) in self.metrics.iter().enumerate() {
            if i > 0 {
                out.push(',');
            }
            let _ = write!(out, "\"{name}\":{}", band.to_json());
        }
        out.push_str("}}");
        out
    }
}

/// One named regression.
#[derive(Debug, Clone)]
pub struct Diff {
    pub metric: String,
    pub message: String,
}

/// Compare a bench's current normalized metrics against its baseline.
/// Every baseline band must find its metric and pass it; extra current
/// metrics (new telemetry not yet blessed) are ignored.
pub fn compare(baseline: &Baseline, current: &BTreeMap<String, f64>) -> Vec<Diff> {
    let mut diffs = Vec::new();
    for (name, band) in &baseline.metrics {
        match current.get(name) {
            None => diffs.push(Diff {
                metric: name.clone(),
                message: "metric missing from bench output".to_string(),
            }),
            Some(&cur) => {
                if let Some(msg) = band.check(cur) {
                    diffs.push(Diff { metric: name.clone(), message: msg });
                }
            }
        }
    }
    diffs
}

/// One normalized metric: the current measurement plus the band
/// `--bless` would commit for it.
#[derive(Debug, Clone)]
pub struct NormMetric {
    pub name: String,
    pub current: f64,
    pub bless: Band,
}

fn invariant(name: &str, current: f64, direction: Direction, bound: f64) -> NormMetric {
    NormMetric {
        name: name.to_string(),
        current,
        bless: Band { value: bound, direction, rel_tol: 0.0, abs_tol: 0.0 },
    }
}

/// `current` must stay strictly above `bound` (usually 0).
fn above(name: &str, current: f64, bound: f64) -> NormMetric {
    invariant(name, current, Direction::Above, bound)
}

/// `current` must stay strictly below `bound`.
fn below(name: &str, current: f64, bound: f64) -> NormMetric {
    invariant(name, current, Direction::Below, bound)
}

/// `current` must equal `expect` exactly (flags, counts pinned to 0).
fn exact(name: &str, current: f64, expect: f64) -> NormMetric {
    invariant(name, current, Direction::Exact, expect)
}

/// `current` may never fall below `floor` (a non-strict invariant —
/// `higher` with zero tolerance around the floor).
fn at_least(name: &str, current: f64, floor: f64) -> NormMetric {
    NormMetric {
        name: name.to_string(),
        current,
        bless: Band { value: floor, direction: Direction::Higher, rel_tol: 0.0, abs_tol: 0.0 },
    }
}

/// Measured metric where smaller is better; blessing pins the current
/// value with `rel_tol` headroom.
fn lower(name: &str, current: f64, rel_tol: f64) -> NormMetric {
    NormMetric {
        name: name.to_string(),
        current,
        bless: Band { value: current, direction: Direction::Lower, rel_tol, abs_tol: 0.0 },
    }
}

/// Measured metric where bigger is better.
fn higher(name: &str, current: f64, rel_tol: f64) -> NormMetric {
    NormMetric {
        name: name.to_string(),
        current,
        bless: Band { value: current, direction: Direction::Higher, rel_tol, abs_tol: 0.0 },
    }
}

fn num(doc: &Json, key: &str) -> Result<f64> {
    match doc.req(key)? {
        Json::Num(n) => Ok(*n),
        Json::Bool(b) => Ok(if *b { 1.0 } else { 0.0 }),
        other => bail!("key {key:?} is not numeric: {other:?}"),
    }
}

fn arr_len(doc: &Json, key: &str) -> Result<f64> {
    Ok(doc.req(key)?.as_arr().with_context(|| format!("key {key:?} is not an array"))?.len()
        as f64)
}

/// Every regression-gated bench and the smoke JSON file CI writes for
/// it (`fig_cool_tier` → `cool_smoke.json` is the one irregular name).
pub const BENCHES: &[(&str, &str)] = &[
    ("fig_shard_scale", "shard_scale_smoke.json"),
    ("fig_sched", "sched_smoke.json"),
    ("fig_tier_hit", "tier_hit_smoke.json"),
    ("fig_warm_tier", "warm_tier_smoke.json"),
    ("fig_fleet", "fleet_smoke.json"),
    ("fig_bus", "bus_smoke.json"),
    ("fig_fault", "fault_smoke.json"),
    ("fig_cool_tier", "cool_smoke.json"),
    ("fig_trace", "trace_smoke.json"),
];

/// Flatten one bench's smoke JSON into the regression-matrix metrics,
/// each with its default band. Fails loudly on a missing key — a bench
/// that stops emitting a gated metric *is* a regression.
pub fn normalize(bench: &str, doc: &Json) -> Result<Vec<NormMetric>> {
    let mut m = Vec::new();
    match bench {
        "fig_shard_scale" => {
            m.push(above("chunks", num(doc, "chunks")?, 0.0));
            m.push(above("scale_rows", arr_len(doc, "scale_rows")?, 0.0));
            let p = doc.req("prefetch")?;
            m.push(above("prefetch.demand_wall_secs", num(p, "demand_wall_secs")?, 0.0));
            m.push(above("prefetch.prefetch_wall_secs", num(p, "prefetch_wall_secs")?, 0.0));
            m.push(at_least("prefetch.warmed", num(p, "warmed")?, 0.0));
        }
        "fig_sched" => {
            m.push(above("requests", num(doc, "requests")?, 0.0));
            m.push(above("policies", arr_len(doc, "policies")?, 0.0));
            m.push(at_least("affinity_hit_gain", num(doc, "affinity_hit_gain")?, 0.0));
            m.push(at_least("affinity_read_saving", num(doc, "affinity_read_saving")?, 0.0));
            for p in doc.req("policies")?.as_arr().context("policies not an array")? {
                let name = p.req("policy")?.as_str().context("policy not a string")?;
                m.push(lower(
                    &format!("{name}.mean_wait_ms"),
                    num(p, "mean_wait_ms")?,
                    0.25,
                ));
                m.push(lower(&format!("{name}.device_secs"), num(p, "device_secs")?, 0.25));
                m.push(higher(&format!("{name}.cache_hits"), num(p, "cache_hits")?, 0.25));
            }
        }
        "fig_tier_hit" => {
            m.push(above("chunks", num(doc, "chunks")?, 0.0));
            m.push(above("accesses", num(doc, "accesses")?, 0.0));
            m.push(above("cells", arr_len(doc, "cells")?, 0.0));
        }
        "fig_warm_tier" => {
            m.push(above("chunks", num(doc, "chunks")?, 0.0));
            m.push(above("splits", arr_len(doc, "splits")?, 0.0));
            m.push(above("total_budget_bytes", num(doc, "total_budget_bytes")?, 0.0));
        }
        "fig_fleet" => {
            m.push(above("requests", num(doc, "requests")?, 0.0));
            m.push(above("batches", num(doc, "batches")?, 0.0));
            m.push(above("configs", arr_len(doc, "configs")?, 0.0));
            // ROADMAP claim: the role-aware mixed fleet strictly beats a
            // single H100 on tokens/joule.
            m.push(above(
                "role_tpj_gain_vs_single",
                num(doc, "role_tpj_gain_vs_single")?,
                0.0,
            ));
        }
        "fig_bus" => {
            m.push(above("rates", arr_len(doc, "rates")?, 0.0));
            // CI already asserts this one: contention must bite.
            m.push(above(
                "high_load_queued_secs_on",
                num(doc, "high_load_queued_secs_on")?,
                0.0,
            ));
            m.push(higher("high_load_tps_gap", num(doc, "high_load_tps_gap")?, 0.25));
            m.push(higher("high_load_p99_gap", num(doc, "high_load_p99_gap")?, 0.25));
        }
        "fig_fault" => {
            m.push(exact("failed_requests", num(doc, "failed_requests")?, 0.0));
            m.push(above("recomputed_chunks", num(doc, "recomputed_chunks")?, 0.0));
            m.push(at_least("requeued_requests", num(doc, "requeued_requests")?, 0.0));
            m.push(exact("clean_bit_identical", num(doc, "clean_bit_identical")?, 1.0));
        }
        "fig_cool_tier" => {
            let v3 = doc.req("formats")?.req("v3")?;
            let v4 = doc.req("formats")?.req("v4")?;
            let flash_ratio = num(v4, "flash_bytes")? / num(v3, "flash_bytes")?;
            let device_ratio = num(v4, "device_secs")? / num(v3, "device_secs")?;
            m.push(below("v4_flash_bytes_over_v3", flash_ratio, 1.0));
            m.push(below("v4_device_secs_over_v3", device_ratio, 1.0));
            m.push(above("v4_q4_dequant_secs", num(v4, "q4_dequant_secs")?, 0.0));
            let mut lru = None;
            let mut tinylfu = None;
            for row in doc.req("scan")?.as_arr().context("scan not an array")? {
                match row.req("policy")?.as_str() {
                    Some("lru") => lru = Some(num(row, "demand_hits")?),
                    Some("tinylfu") => tinylfu = Some(num(row, "demand_hits")?),
                    _ => {}
                }
            }
            let (lru, tinylfu) = (
                lru.context("scan has no lru row")?,
                tinylfu.context("scan has no tinylfu row")?,
            );
            m.push(above("tinylfu_demand_hit_gain", tinylfu - lru, 0.0));
        }
        "fig_trace" => {
            m.push(exact("deterministic", num(doc, "deterministic")?, 1.0));
            m.push(exact("series_deterministic", num(doc, "series_deterministic")?, 1.0));
            m.push(above("spans", num(doc, "spans")?, 0.0));
            m.push(above("sched_events", num(doc, "sched_events")?, 0.0));
            m.push(above("paths", num(doc, "paths")?, 0.0));
            // the CI attribution bound: components sum within 1e-6 s
            m.push(NormMetric {
                name: "max_attribution_err_secs".to_string(),
                current: num(doc, "max_attribution_err_secs")?,
                bless: Band {
                    value: 0.0,
                    direction: Direction::Lower,
                    rel_tol: 0.0,
                    abs_tol: 1e-6,
                },
            });
        }
        other => bail!("unknown bench {other:?} (known: {:?})", BENCHES),
    }
    Ok(m)
}

/// Build a baseline from normalized metrics (the `--bless` writer).
pub fn bless(bench: &str, norms: &[NormMetric]) -> Baseline {
    Baseline {
        bench: bench.to_string(),
        metrics: norms.iter().map(|n| (n.name.clone(), n.bless)).collect(),
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn band(value: f64, direction: Direction, rel: f64, abs: f64) -> Band {
        Band { value, direction, rel_tol: rel, abs_tol: abs }
    }

    #[test]
    fn direction_rules() {
        // higher: throughput may not drop below the tolerance floor
        let b = band(100.0, Direction::Higher, 0.1, 0.0);
        assert!(b.check(95.0).is_none());
        assert!(b.check(90.0).is_none(), "exactly at the floor passes");
        assert!(b.check(89.0).is_some());
        // lower: queued-seconds may not grow beyond tolerance
        let b = band(2.0, Direction::Lower, 0.25, 0.0);
        assert!(b.check(2.5).is_none());
        assert!(b.check(2.6).is_some());
        // above / below are strict
        assert!(band(0.0, Direction::Above, 0.0, 0.0).check(0.0).is_some());
        assert!(band(0.0, Direction::Above, 0.0, 0.0).check(1e-9).is_none());
        assert!(band(1.0, Direction::Below, 0.0, 0.0).check(1.0).is_some());
        assert!(band(1.0, Direction::Below, 0.0, 0.0).check(0.99).is_none());
        // exact within abs_tol
        assert!(band(1.0, Direction::Exact, 0.0, 0.0).check(1.0).is_none());
        assert!(band(1.0, Direction::Exact, 0.0, 0.0).check(1.1).is_some());
        assert!(band(0.0, Direction::Lower, 0.0, 1e-6).check(5e-7).is_none());
        assert!(band(0.0, Direction::Lower, 0.0, 1e-6).check(2e-6).is_some());
    }

    #[test]
    fn satisfying_and_violating_values_do_what_they_say() {
        for dir in
            [Direction::Higher, Direction::Lower, Direction::Above, Direction::Below, Direction::Exact]
        {
            for value in [0.0, 1.0, 2.5e6, 1e-6] {
                let b = band(value, dir, 0.25, 0.0);
                assert!(
                    b.check(b.satisfying_value()).is_none(),
                    "{dir:?} value {value}: satisfying value failed its own band"
                );
                assert!(
                    b.check(b.violating_value()).is_some(),
                    "{dir:?} value {value}: violating value passed its own band"
                );
            }
        }
    }

    #[test]
    fn baseline_roundtrips() {
        let mut metrics = BTreeMap::new();
        metrics.insert("tps".to_string(), band(120.5, Direction::Higher, 0.1, 0.0));
        metrics.insert("queued_secs".to_string(), band(0.2, Direction::Lower, 0.25, 0.001));
        metrics.insert("failed".to_string(), band(0.0, Direction::Exact, 0.0, 0.0));
        let b = Baseline { bench: "fig_x".to_string(), metrics };
        let parsed = Baseline::parse(&b.to_json()).unwrap();
        assert_eq!(parsed, b);
        assert_eq!(parsed.to_json(), b.to_json(), "serialization is deterministic");
    }

    #[test]
    fn perturbed_metric_fails_with_the_right_named_diff() {
        let mut metrics = BTreeMap::new();
        metrics.insert("throughput_tps".to_string(), band(100.0, Direction::Higher, 0.1, 0.0));
        metrics.insert("queued_secs".to_string(), band(1.0, Direction::Lower, 0.25, 0.0));
        metrics.insert("failed_requests".to_string(), band(0.0, Direction::Exact, 0.0, 0.0));
        let baseline = Baseline { bench: "fig_x".to_string(), metrics };

        let mut current: BTreeMap<String, f64> =
            baseline.metrics.iter().map(|(k, b)| (k.clone(), b.satisfying_value())).collect();
        assert!(compare(&baseline, &current).is_empty(), "clean run must pass");

        // deliberately perturb exactly one metric the wrong way
        current.insert("queued_secs".to_string(), 2.0);
        let diffs = compare(&baseline, &current);
        assert_eq!(diffs.len(), 1);
        assert_eq!(diffs[0].metric, "queued_secs");
        assert!(diffs[0].message.contains("direction=lower"), "{}", diffs[0].message);

        // and a missing metric is itself a named failure
        current.remove("queued_secs");
        current.insert("failed_requests".to_string(), 0.0);
        let diffs = compare(&baseline, &current);
        assert_eq!(diffs.len(), 1);
        assert_eq!(diffs[0].metric, "queued_secs");
        assert!(diffs[0].message.contains("missing"), "{}", diffs[0].message);
    }

    #[test]
    fn normalize_cool_tier_extracts_the_invariants() {
        let doc = Json::parse(
            r#"{"bench":"fig_cool_tier","formats":{
                "v3":{"reads":10,"flash_bytes":4000,"device_secs":0.4,"q4_dequant_secs":0.0},
                "v4":{"reads":10,"flash_bytes":1000,"device_secs":0.1,"q4_dequant_secs":0.02}},
               "scan":[{"policy":"lru","demand_hits":5},{"policy":"tinylfu","demand_hits":9}]}"#,
        )
        .unwrap();
        let norms = normalize("fig_cool_tier", &doc).unwrap();
        let by_name: BTreeMap<String, f64> =
            norms.iter().map(|n| (n.name.clone(), n.current)).collect();
        assert_eq!(by_name["v4_flash_bytes_over_v3"], 0.25);
        assert_eq!(by_name["tinylfu_demand_hit_gain"], 4.0);
        let blessed = bless("fig_cool_tier", &norms);
        assert!(compare(&blessed, &by_name).is_empty());
    }

    #[test]
    fn normalize_trace_pins_determinism_and_attribution() {
        let doc = Json::parse(
            r#"{"deterministic":true,"series_deterministic":true,"spans":120,
                "sched_events":30,"paths":16,"max_attribution_err_secs":2.0e-9}"#,
        )
        .unwrap();
        let norms = normalize("fig_trace", &doc).unwrap();
        let by_name: BTreeMap<String, f64> =
            norms.iter().map(|n| (n.name.clone(), n.current)).collect();
        let blessed = bless("fig_trace", &norms);
        assert!(compare(&blessed, &by_name).is_empty());
        // a nondeterministic trace fails by name
        let mut bad = by_name.clone();
        bad.insert("deterministic".to_string(), 0.0);
        let diffs = compare(&blessed, &bad);
        assert_eq!(diffs.len(), 1);
        assert_eq!(diffs[0].metric, "deterministic");
        // attribution error beyond 1e-6 fails by name
        let mut bad = by_name;
        bad.insert("max_attribution_err_secs".to_string(), 5e-6);
        let diffs = compare(&blessed, &bad);
        assert_eq!(diffs.len(), 1);
        assert_eq!(diffs[0].metric, "max_attribution_err_secs");
    }

    #[test]
    fn unknown_bench_errors() {
        assert!(normalize("fig_nope", &Json::parse("{}").unwrap()).is_err());
    }
}
