//! The unified metrics registry: every subsystem's counters behind one
//! typed, named surface.
//!
//! PR 9 gave the testbed *events* (spans on the virtual clocks); this
//! module gives it *aggregates*. Before it, counters were scattered —
//! `CacheStats`, `ShardStats`, `LinkStats`, `PhaseBreakdown`, the fleet
//! report — each with its own ad-hoc JSON shape, and nothing could
//! enumerate "everything the system measures" in one pass. The registry
//! fixes the enumeration problem without touching the hot paths: the
//! existing relaxed-atomic fields stay exactly where they are, and
//! subsystems register either *owned* instruments (a [`Counter`] /
//! [`Gauge`] handle the subsystem bumps directly) or *polled* bridges
//! (a closure over an `Arc` that reads the pre-existing atomics at
//! snapshot time, costing the hot path nothing at all).
//!
//! Naming schema (enforced by [`MetricsRegistry`]):
//!
//! * dotted lowercase metric names — `matkv.tier.hits`,
//!   `matkv.link.queued_seconds` — segments of `[a-z0-9_]`;
//! * `key=value` labels for the instance dimension — `tier=hot`,
//!   `shard=3`, `worker=rtx4090:1`, `class=h2d` — canonicalized by
//!   sorting on the key, so `[a=1, b=2]` and `[b=2, a=1]` name the
//!   same series;
//! * seconds-valued counters end in `_seconds`, byte-valued ones in
//!   `_bytes` (mirrored from the Prometheus conventions).
//!
//! Registering the same fully-qualified id twice errors loudly instead
//! of silently aliasing two subsystems onto one counter.
//!
//! Exports are deterministic by construction: iteration order is the
//! `BTreeMap` order of canonical ids and every float prints at fixed
//! precision, so two runs of the same seed+config produce byte-identical
//! dumps — the same guarantee the PR-9 trace export makes.

use std::collections::BTreeMap;
use std::fmt::Write as _;
use std::sync::atomic::{AtomicU64, Ordering};
use std::sync::{Arc, Mutex};

use anyhow::{bail, Result};

use crate::coordinator::metrics::LogHistogram;

/// A monotone event counter. Cloning shares the underlying cell, so a
/// subsystem keeps one handle and the registry another. `inc`/`add` are
/// one relaxed atomic RMW — the same cost as the raw `AtomicU64` fields
/// the rest of the codebase already pays (`hotpath_micro` pins this).
#[derive(Clone, Debug, Default)]
pub struct Counter(Arc<AtomicU64>);

impl Counter {
    #[inline]
    pub fn inc(&self) {
        self.0.fetch_add(1, Ordering::Relaxed);
    }

    #[inline]
    pub fn add(&self, n: u64) {
        self.0.fetch_add(n, Ordering::Relaxed);
    }

    pub fn get(&self) -> u64 {
        self.0.load(Ordering::Relaxed)
    }
}

/// A point-in-time value (queue depth, residency bytes, utilization).
/// Stored as `f64` bits in an atomic; `set` is one relaxed store.
#[derive(Clone, Debug, Default)]
pub struct Gauge(Arc<AtomicU64>);

impl Gauge {
    #[inline]
    pub fn set(&self, v: f64) {
        self.0.store(v.to_bits(), Ordering::Relaxed);
    }

    pub fn get(&self) -> f64 {
        f64::from_bits(self.0.load(Ordering::Relaxed))
    }
}

/// A first-class distribution instrument: the PR-9 [`LogHistogram`]
/// (fixed universal bucket geometry, exact merges) behind a shared
/// handle. Not on any hot path — recorded per request, not per byte.
#[derive(Clone, Debug, Default)]
pub struct Histogram(Arc<Mutex<LogHistogram>>);

impl Histogram {
    pub fn record(&self, v: f64) {
        self.0.lock().unwrap().record(v);
    }

    pub fn snapshot(&self) -> LogHistogram {
        self.0.lock().unwrap().clone()
    }
}

/// Where a metric's current value comes from at snapshot time.
enum Source {
    Counter(Counter),
    Gauge(Gauge),
    /// Bridge over a pre-existing atomic (or any computed value) with
    /// counter semantics: cumulative and non-decreasing.
    CounterPoll(Arc<dyn Fn() -> f64 + Send + Sync>),
    /// Bridge with gauge semantics: a point-in-time level.
    GaugePoll(Arc<dyn Fn() -> f64 + Send + Sync>),
    Hist(Histogram),
}

struct Metric {
    /// Dotted metric name (label-free part of the id).
    name: String,
    /// Canonicalized (key-sorted) labels.
    labels: Vec<(String, String)>,
    help: String,
    source: Source,
}

/// The process-wide metric namespace: canonical id → instrument.
/// Construct one per run ([`MetricsRegistry::new`] returns an `Arc` —
/// samplers and subsystems share it), register every subsystem into it,
/// then export with [`MetricsRegistry::to_prometheus`] or sample it on
/// the virtual clock with [`super::Sampler`].
#[derive(Default)]
pub struct MetricsRegistry {
    metrics: Mutex<BTreeMap<String, Metric>>,
}

/// Format one sampled value deterministically: integers print bare
/// (`42`, not `42.000000000`), everything else at fixed `{:.9}`.
pub(crate) fn fmt_value(v: f64) -> String {
    if v.is_finite() && v == v.trunc() && v.abs() < 9.0e15 {
        format!("{}", v as i64)
    } else {
        format!("{v:.9}")
    }
}

fn valid_name(name: &str) -> bool {
    !name.is_empty()
        && name.starts_with(|c: char| c.is_ascii_lowercase())
        && !name.ends_with('.')
        && !name.contains("..")
        && name.chars().all(|c| c.is_ascii_lowercase() || c.is_ascii_digit() || c == '_' || c == '.')
}

fn valid_label_key(k: &str) -> bool {
    !k.is_empty()
        && k.starts_with(|c: char| c.is_ascii_lowercase())
        && k.chars().all(|c| c.is_ascii_lowercase() || c.is_ascii_digit() || c == '_')
}

fn valid_label_value(v: &str) -> bool {
    !v.is_empty() && v.chars().all(|c| c.is_ascii_graphic() && !"\"{},=".contains(c))
}

/// Canonical id: `name` alone, or `name{k=v,...}` with labels sorted by
/// key. The id is both the registry key and the series name in sampler
/// JSON, so canonicalization is what makes label order irrelevant.
fn canonical_id(name: &str, labels: &[(String, String)]) -> String {
    if labels.is_empty() {
        return name.to_string();
    }
    let body: Vec<String> = labels.iter().map(|(k, v)| format!("{k}={v}")).collect();
    format!("{name}{{{}}}", body.join(","))
}

impl MetricsRegistry {
    pub fn new() -> Arc<MetricsRegistry> {
        Arc::new(MetricsRegistry::default())
    }

    fn register(
        &self,
        name: &str,
        labels: &[(&str, &str)],
        help: &str,
        source: Source,
    ) -> Result<()> {
        if !valid_name(name) {
            bail!("invalid metric name {name:?}: want dotted lowercase [a-z0-9_.]");
        }
        let mut canon: Vec<(String, String)> = Vec::with_capacity(labels.len());
        for (k, v) in labels {
            if !valid_label_key(k) {
                bail!("invalid label key {k:?} on metric {name:?}");
            }
            if !valid_label_value(v) {
                bail!("invalid label value {v:?} for {k}= on metric {name:?}");
            }
            canon.push((k.to_string(), v.to_string()));
        }
        canon.sort();
        if canon.windows(2).any(|w| w[0].0 == w[1].0) {
            bail!("duplicate label key on metric {name:?}");
        }
        let id = canonical_id(name, &canon);
        let mut m = self.metrics.lock().unwrap();
        if m.contains_key(&id) {
            bail!("metric {id} already registered");
        }
        m.insert(
            id,
            Metric { name: name.to_string(), labels: canon, help: help.to_string(), source },
        );
        Ok(())
    }

    /// Register an owned counter and return the shared handle.
    pub fn counter(&self, name: &str, labels: &[(&str, &str)], help: &str) -> Result<Counter> {
        let c = Counter::default();
        self.register(name, labels, help, Source::Counter(c.clone()))?;
        Ok(c)
    }

    /// Register an owned gauge and return the shared handle.
    pub fn gauge(&self, name: &str, labels: &[(&str, &str)], help: &str) -> Result<Gauge> {
        let g = Gauge::default();
        self.register(name, labels, help, Source::Gauge(g.clone()))?;
        Ok(g)
    }

    /// Register a [`LogHistogram`] instrument and return the handle.
    pub fn histogram(&self, name: &str, labels: &[(&str, &str)], help: &str) -> Result<Histogram> {
        let h = Histogram::default();
        self.register(name, labels, help, Source::Hist(h.clone()))?;
        Ok(h)
    }

    /// Register a polled counter: `f` is called at snapshot/export time
    /// and must return a cumulative, non-decreasing value. This is the
    /// zero-hot-path-cost bridge onto the pre-existing atomic fields.
    pub fn counter_fn(
        &self,
        name: &str,
        labels: &[(&str, &str)],
        help: &str,
        f: impl Fn() -> f64 + Send + Sync + 'static,
    ) -> Result<()> {
        self.register(name, labels, help, Source::CounterPoll(Arc::new(f)))
    }

    /// Register a polled gauge: `f` returns the current level.
    pub fn gauge_fn(
        &self,
        name: &str,
        labels: &[(&str, &str)],
        help: &str,
        f: impl Fn() -> f64 + Send + Sync + 'static,
    ) -> Result<()> {
        self.register(name, labels, help, Source::GaugePoll(Arc::new(f)))
    }

    /// Whether `name` + `labels` (any order) is already registered.
    pub fn contains(&self, name: &str, labels: &[(&str, &str)]) -> bool {
        let mut canon: Vec<(String, String)> =
            labels.iter().map(|(k, v)| (k.to_string(), v.to_string())).collect();
        canon.sort();
        self.metrics.lock().unwrap().contains_key(&canonical_id(name, &canon))
    }

    pub fn len(&self) -> usize {
        self.metrics.lock().unwrap().len()
    }

    pub fn is_empty(&self) -> bool {
        self.len() == 0
    }

    /// Current scalar value of every non-histogram metric, in canonical
    /// id order — what [`super::Sampler`] appends to its series each
    /// tick. Histograms are excluded: a distribution has no single
    /// sample value (they export through the Prometheus dump instead).
    pub fn sampled_values(&self) -> Vec<(String, f64)> {
        let m = self.metrics.lock().unwrap();
        let mut out = Vec::with_capacity(m.len());
        for (id, metric) in m.iter() {
            let v = match &metric.source {
                Source::Counter(c) => c.get() as f64,
                Source::Gauge(g) => g.get(),
                Source::CounterPoll(f) | Source::GaugePoll(f) => f(),
                Source::Hist(_) => continue,
            };
            out.push((id.clone(), v));
        }
        out
    }

    /// Prometheus text-format dump. Families sort by canonical id (so
    /// every line of a family is contiguous), dots mangle to underscores
    /// per the exposition format, histograms render as summaries
    /// (`quantile=` series plus `_sum`/`_count`), and all values format
    /// through one fixed-precision rule — byte-identical across runs of
    /// the same seed+config.
    pub fn to_prometheus(&self) -> String {
        let m = self.metrics.lock().unwrap();
        let mut out = String::new();
        let mut last_family = String::new();
        for metric in m.values() {
            let family = metric.name.replace('.', "_");
            if family != last_family {
                if !metric.help.is_empty() {
                    let _ = writeln!(out, "# HELP {family} {}", metric.help);
                }
                let kind = match &metric.source {
                    Source::Counter(_) | Source::CounterPoll(_) => "counter",
                    Source::Gauge(_) | Source::GaugePoll(_) => "gauge",
                    Source::Hist(_) => "summary",
                };
                let _ = writeln!(out, "# TYPE {family} {kind}");
                last_family = family.clone();
            }
            match &metric.source {
                Source::Hist(h) => {
                    let hist = h.snapshot();
                    for (q, p) in [("0.5", 50.0), ("0.95", 95.0), ("0.99", 99.0)] {
                        let mut labels = metric.labels.clone();
                        labels.push(("quantile".to_string(), q.to_string()));
                        let _ = writeln!(
                            out,
                            "{family}{} {}",
                            prom_labels(&labels),
                            fmt_value(hist.percentile(p))
                        );
                    }
                    let l = prom_labels(&metric.labels);
                    let _ = writeln!(out, "{family}_sum{l} {}", fmt_value(hist.sum()));
                    let _ = writeln!(out, "{family}_count{l} {}", hist.len());
                }
                src => {
                    let v = match src {
                        Source::Counter(c) => c.get() as f64,
                        Source::Gauge(g) => g.get(),
                        Source::CounterPoll(f) | Source::GaugePoll(f) => f(),
                        Source::Hist(_) => unreachable!("handled above"),
                    };
                    let _ = writeln!(
                        out,
                        "{family}{} {}",
                        prom_labels(&metric.labels),
                        fmt_value(v)
                    );
                }
            }
        }
        out
    }
}

/// `{k="v",...}` in canonical (sorted) order; empty string for none.
fn prom_labels(labels: &[(String, String)]) -> String {
    if labels.is_empty() {
        return String::new();
    }
    let body: Vec<String> = labels
        .iter()
        .map(|(k, v)| format!("{k}=\"{}\"", v.replace('\\', "\\\\").replace('"', "\\\"")))
        .collect();
    format!("{{{}}}", body.join(","))
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn duplicate_registration_errors_loudly() {
        let reg = MetricsRegistry::new();
        reg.counter("matkv.test.hits", &[("tier", "hot")], "hits").unwrap();
        let err = reg.counter("matkv.test.hits", &[("tier", "hot")], "hits").unwrap_err();
        assert!(format!("{err:#}").contains("already registered"), "{err:#}");
        // same name, different labels: a new series, not a duplicate
        reg.counter("matkv.test.hits", &[("tier", "warm")], "hits").unwrap();
        assert_eq!(reg.len(), 2);
    }

    #[test]
    fn label_order_is_canonicalized() {
        let reg = MetricsRegistry::new();
        reg.counter("matkv.test.bytes", &[("shard", "0"), ("class", "h2d")], "").unwrap();
        // the same series under reversed label order collides
        let err =
            reg.counter("matkv.test.bytes", &[("class", "h2d"), ("shard", "0")], "").unwrap_err();
        assert!(format!("{err:#}").contains("already registered"), "{err:#}");
        assert!(reg.contains("matkv.test.bytes", &[("class", "h2d"), ("shard", "0")]));
        // and the canonical id sorts the keys
        let ids: Vec<String> = reg.sampled_values().into_iter().map(|(id, _)| id).collect();
        assert_eq!(ids, vec!["matkv.test.bytes{class=h2d,shard=0}".to_string()]);
    }

    #[test]
    fn invalid_names_and_labels_are_rejected() {
        let reg = MetricsRegistry::new();
        assert!(reg.counter("Bad.Name", &[], "").is_err());
        assert!(reg.counter("trailing.", &[], "").is_err());
        assert!(reg.counter("double..dot", &[], "").is_err());
        assert!(reg.counter("ok.name", &[("BadKey", "v")], "").is_err());
        assert!(reg.counter("ok.name", &[("k", "bad\"value")], "").is_err());
        assert!(reg.counter("ok.name", &[("k", "v"), ("k", "w")], "").is_err());
        assert!(reg.counter("ok.name", &[("k", "rtx4090:1")], "").is_ok());
    }

    #[test]
    fn prometheus_dump_is_deterministic_and_typed() {
        let dump = |seed: u64| {
            let reg = MetricsRegistry::new();
            let c = reg.counter("matkv.t.hits", &[("tier", "hot")], "tier hits").unwrap();
            let g = reg.gauge("matkv.t.depth", &[], "queue depth").unwrap();
            let h = reg.histogram("matkv.t.latency_seconds", &[("worker", "h100:0")], "").unwrap();
            reg.counter_fn("matkv.t.polled", &[], "bridge", move || (seed * 2) as f64).unwrap();
            c.add(seed);
            g.set(seed as f64 + 0.5);
            h.record(0.001);
            h.record(0.004);
            reg.to_prometheus()
        };
        let a = dump(7);
        assert_eq!(a, dump(7), "same inputs must export byte-identical text");
        assert_ne!(a, dump(8));
        assert!(a.contains("# TYPE matkv_t_hits counter"), "{a}");
        assert!(a.contains("matkv_t_hits{tier=\"hot\"} 7"), "{a}");
        assert!(a.contains("# TYPE matkv_t_depth gauge"), "{a}");
        assert!(a.contains("matkv_t_depth 7.500000000"), "{a}");
        assert!(a.contains("# TYPE matkv_t_latency_seconds summary"), "{a}");
        assert!(a.contains("matkv_t_latency_seconds{worker=\"h100:0\",quantile=\"0.5\"}"), "{a}");
        assert!(a.contains("matkv_t_latency_seconds_count{worker=\"h100:0\"} 2"), "{a}");
        assert!(a.contains("matkv_t_polled 14"), "{a}");
    }

    #[test]
    fn integer_values_print_bare() {
        assert_eq!(fmt_value(42.0), "42");
        assert_eq!(fmt_value(0.0), "0");
        assert_eq!(fmt_value(268435456.0), "268435456");
        assert_eq!(fmt_value(0.5), "0.500000000");
        assert_eq!(fmt_value(1e18), format!("{:.9}", 1e18));
    }
}
