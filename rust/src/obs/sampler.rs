//! The virtual-clock time-series sampler: aligned series over the
//! whole [`MetricsRegistry`](super::MetricsRegistry).
//!
//! Bottleneck attribution needs *utilization over time*, not point
//! totals — a link that queued 4 s total looks identical whether it
//! queued steadily or all at once, and only the series tells the
//! difference. The sampler snapshots every registered counter/gauge at
//! a fixed **virtual** period: the driving clock is the scheduler's
//! release clock and the fleet's dispatch clock (the same deterministic
//! timeline the traces run on), never wall time, so two runs of the
//! same seed+config produce byte-identical series JSON.
//!
//! Period semantics: tick boundaries sit at `0, p, 2p, …` on the
//! virtual timeline. Instrumented loops call
//! [`Sampler::advance_to`]`(t)` as their clock passes `t`; each
//! boundary fires the first time *any* caller's clock reaches it, and
//! counter values are read as of that call — the simulation may have
//! already scored work "later" than the boundary within the same loop
//! iteration, which is the usual discretization of sampling a
//! simulator, and is deterministic because the loop order is.
//! [`Sampler::finish`] records one final (possibly off-period) sample
//! at the end of a run so the last partial period is not lost.
//!
//! Metrics registered after sampling started are backfilled with zeros
//! so every series stays aligned to the shared time axis.

use std::collections::BTreeMap;
use std::fmt::Write as _;
use std::sync::Arc;

use super::registry::{fmt_value, MetricsRegistry};

/// Ticks kept before sampling quietly stops — the same bound the tier
/// telemetry series uses, so a run that never drains cannot grow the
/// series without bound.
pub const MAX_SAMPLES: usize = 16_384;

/// Version of the JSON series document [`Sampler::to_json`] emits.
/// Bump when the shape changes; `bench_check` and figure consumers key
/// on it.
pub const SERIES_VERSION: u32 = 1;

/// Snapshots a [`MetricsRegistry`] at a fixed virtual period into
/// aligned time series. Not `Clone`: one sampler owns one time axis.
pub struct Sampler {
    registry: Arc<MetricsRegistry>,
    period: f64,
    /// Next tick boundary on the virtual timeline.
    next_t: f64,
    times: Vec<f64>,
    series: BTreeMap<String, Vec<f64>>,
}

impl Sampler {
    /// A sampler ticking every `period_secs` of virtual time, with the
    /// first boundary at t = 0 (an all-baseline anchor row). Periods
    /// at or below zero clamp to 1 ms.
    pub fn new(registry: Arc<MetricsRegistry>, period_secs: f64) -> Sampler {
        Sampler {
            registry,
            period: if period_secs > 0.0 { period_secs } else { 1e-3 },
            next_t: 0.0,
            times: Vec::new(),
            series: BTreeMap::new(),
        }
    }

    pub fn period_secs(&self) -> f64 {
        self.period
    }

    /// Samples recorded so far.
    pub fn len(&self) -> usize {
        self.times.len()
    }

    pub fn is_empty(&self) -> bool {
        self.times.is_empty()
    }

    /// The caller's virtual clock has reached `t`: fire every tick
    /// boundary at or before it. Monotone and idempotent — calls with
    /// an earlier `t` (another worker's clock running behind) are
    /// no-ops, so interleaved clocks can all drive one sampler.
    pub fn advance_to(&mut self, t: f64) {
        while self.next_t <= t + 1e-12 && self.times.len() < MAX_SAMPLES {
            let tick = self.next_t;
            self.next_t += self.period;
            self.tick(tick);
        }
    }

    /// End of run: advance through `t`, then record one final sample at
    /// `t` itself if it sits past the last boundary — the tail partial
    /// period would otherwise vanish from every series.
    pub fn finish(&mut self, t: f64) {
        self.advance_to(t);
        if self.times.len() < MAX_SAMPLES && self.times.last().is_none_or(|&last| t > last) {
            self.tick(t);
            self.next_t = self.next_t.max(t + self.period);
        }
    }

    fn tick(&mut self, t: f64) {
        self.times.push(t);
        let n = self.times.len();
        for (id, v) in self.registry.sampled_values() {
            let s = self.series.entry(id).or_default();
            if s.len() < n - 1 {
                // registered after earlier ticks: backfill to stay aligned
                s.resize(n - 1, 0.0);
            }
            s.push(v);
        }
    }

    /// The versioned series document: shared time axis plus one aligned
    /// value array per canonical metric id, in sorted id order.
    /// Deterministic bytes for deterministic values.
    pub fn to_json(&self) -> String {
        let n = self.times.len();
        let mut out = String::new();
        let _ = write!(
            out,
            "{{\"version\":{SERIES_VERSION},\"period_secs\":{:.6},\"samples\":{n},\"times\":[",
            self.period
        );
        for (i, t) in self.times.iter().enumerate() {
            if i > 0 {
                out.push(',');
            }
            let _ = write!(out, "{t:.6}");
        }
        out.push_str("],\"series\":{");
        let mut first = true;
        for (id, vals) in &self.series {
            if vals.len() != n {
                // registered after the last tick: nothing aligned to emit
                continue;
            }
            if !first {
                out.push(',');
            }
            first = false;
            let _ = write!(out, "\"{id}\":[");
            for (i, v) in vals.iter().enumerate() {
                if i > 0 {
                    out.push(',');
                }
                out.push_str(&fmt_value(*v));
            }
            out.push(']');
        }
        out.push_str("}}");
        out
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn samples_on_period_boundaries_only() {
        let reg = MetricsRegistry::new();
        let c = reg.counter("matkv.s.events", &[], "").unwrap();
        let mut s = Sampler::new(reg, 1.0);
        c.add(5);
        s.advance_to(0.25); // fires the t=0 anchor only
        assert_eq!(s.len(), 1);
        c.add(5);
        s.advance_to(2.5); // fires t=1 and t=2
        assert_eq!(s.len(), 3);
        s.advance_to(2.5); // idempotent
        s.advance_to(1.0); // monotone: late clocks are no-ops
        assert_eq!(s.len(), 3);
        let doc = s.to_json();
        assert!(doc.contains("\"times\":[0.000000,1.000000,2.000000]"), "{doc}");
        assert!(doc.contains("\"matkv.s.events\":[5,10,10]"), "{doc}");
    }

    #[test]
    fn series_json_is_byte_identical_across_runs() {
        let run = || {
            let reg = MetricsRegistry::new();
            let c = reg.counter("matkv.s.reads", &[("shard", "0")], "").unwrap();
            let g = reg.gauge("matkv.s.depth", &[], "").unwrap();
            let mut s = Sampler::new(reg, 0.5);
            for i in 0..20 {
                c.add(i % 3);
                g.set(i as f64 * 0.25);
                s.advance_to(i as f64 * 0.3);
            }
            s.finish(6.1);
            s.to_json()
        };
        let a = run();
        assert_eq!(a, run(), "same scripted run must serialize byte-identically");
        assert!(a.starts_with("{\"version\":1,\"period_secs\":0.500000"), "{a}");
    }

    #[test]
    fn late_registration_backfills_zeros() {
        let reg = MetricsRegistry::new();
        let c = reg.counter("matkv.s.early", &[], "").unwrap();
        let mut s = Sampler::new(reg.clone(), 1.0);
        c.inc();
        s.advance_to(1.0); // t=0, t=1
        let late = reg.counter("matkv.s.late", &[], "").unwrap();
        late.add(7);
        s.advance_to(2.0);
        let doc = s.to_json();
        assert!(doc.contains("\"matkv.s.early\":[1,1,1]"), "{doc}");
        assert!(doc.contains("\"matkv.s.late\":[0,0,7]"), "{doc}");
    }

    #[test]
    fn finish_records_the_tail_sample() {
        let reg = MetricsRegistry::new();
        let c = reg.counter("matkv.s.tail", &[], "").unwrap();
        let mut s = Sampler::new(reg, 10.0);
        c.add(1);
        s.finish(3.5);
        assert_eq!(s.len(), 2, "t=0 anchor plus the off-period tail");
        let doc = s.to_json();
        assert!(doc.contains("\"times\":[0.000000,3.500000]"), "{doc}");
        // finishing twice at the same time does not duplicate the tail
        let mut s2 = Sampler::new(MetricsRegistry::new(), 10.0);
        s2.finish(3.5);
        s2.finish(3.5);
        assert_eq!(s2.len(), 2);
    }

    #[test]
    fn sampling_stops_at_the_cap() {
        let reg = MetricsRegistry::new();
        reg.counter("matkv.s.capped", &[], "").unwrap();
        let mut s = Sampler::new(reg, 0.001);
        s.advance_to(1e9);
        assert_eq!(s.len(), MAX_SAMPLES);
        s.finish(2e9);
        assert_eq!(s.len(), MAX_SAMPLES);
    }
}
