//! Unified observability: one metrics registry, one virtual-clock
//! sampler, one regression matrix.
//!
//! Before this module, counters lived scattered across `CacheStats`,
//! `ShardStats`, `LinkStats`, `PhaseBreakdown`, and `FleetReport` with
//! ad-hoc JSON shapes, and the only time series was a hand-rolled pair
//! in `kvstore/cache.rs`. Everything now registers into a
//! [`MetricsRegistry`] under stable dotted names with `key=value`
//! labels (`matkv.tier.hits{tier=hot}`,
//! `matkv.link.queued_seconds{link=hostbus}`,
//! `matkv.worker.busy_seconds{worker=rtx4090:1}`), a [`Sampler`]
//! driven by the scheduler/fleet **virtual** clock snapshots the
//! registry into aligned time series, and both exports — the
//! Prometheus text dump and the versioned series JSON — are
//! byte-identical across runs of the same seed+config, the same
//! guarantee the trace layer makes.
//!
//! [`check`] turns those exports into a regression gate: normalized
//! per-bench metrics, committed baselines with direction-aware
//! tolerance bands, and named diffs when a number moves the wrong way
//! (`cargo bench --bench bench_check -- --all`).

pub mod check;
pub mod registry;
pub mod sampler;
pub mod tier;

pub use check::{
    bless, compare, normalize, Band, Baseline, Diff, Direction, NormMetric, BASELINE_VERSION,
    BENCHES,
};
pub use registry::{Counter, Gauge, Histogram, MetricsRegistry};
pub use sampler::{Sampler, MAX_SAMPLES, SERIES_VERSION};
pub use tier::{register_tier, series_to_json, CacheSample, TierMetrics, TierSeries};
