//! Synthetic document corpus with topical structure.
//!
//! Each document belongs to a topic; its text mixes topic-specific words
//! (which make retrieval meaningful: a query about topic t embeds close
//! to topic-t documents) with common filler words. Token counts per
//! document are exact, which is all the paper's measurements consume.

use super::rng::Rng;

/// One synthetic document.
#[derive(Debug, Clone)]
pub struct Document {
    pub id: u64,
    pub topic: usize,
    pub text: String,
    pub n_words: usize,
}

/// A generated corpus.
#[derive(Debug, Clone)]
pub struct Corpus {
    pub docs: Vec<Document>,
    pub n_topics: usize,
}

const COMMON: &[&str] = &[
    "the", "a", "of", "and", "to", "in", "is", "was", "for", "with", "on", "as", "by", "at",
    "from", "that", "this", "which", "were", "are", "be", "has", "had", "its", "their",
];

fn topic_word(topic: usize, i: usize) -> String {
    format!("t{topic}w{i}")
}

impl Corpus {
    /// Generate `n_docs` documents of ~`words_per_doc` words across
    /// `n_topics` topics. Word counts are exact.
    pub fn generate(n_docs: usize, words_per_doc: usize, n_topics: usize, seed: u64) -> Self {
        let mut rng = Rng::new(seed);
        let mut docs = Vec::with_capacity(n_docs);
        for id in 0..n_docs {
            let topic = id % n_topics;
            let mut words = Vec::with_capacity(words_per_doc);
            for w in 0..words_per_doc {
                // ~40% topical, 60% filler — enough signal for retrieval
                if w % 5 < 2 {
                    words.push(topic_word(topic, rng.below(30)));
                } else {
                    words.push(rng.pick(COMMON).to_string());
                }
            }
            docs.push(Document {
                id: id as u64,
                topic,
                text: words.join(" "),
                n_words: words_per_doc,
            });
        }
        Corpus { docs, n_topics }
    }

    /// A natural query about `topic`: a few of its characteristic words.
    pub fn query_for_topic(&self, topic: usize, n_words: usize, rng: &mut Rng) -> String {
        (0..n_words)
            .map(|_| {
                if rng.f64() < 0.7 {
                    topic_word(topic, rng.below(30))
                } else {
                    rng.pick(COMMON).to_string()
                }
            })
            .collect::<Vec<_>>()
            .join(" ")
    }

    /// All document texts (tokenizer-vocabulary building).
    pub fn texts(&self) -> impl Iterator<Item = &str> {
        self.docs.iter().map(|d| d.text.as_str())
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::tokenizer::Tokenizer;
    use crate::vectordb::{FlatIndex, HashEmbedder, VectorIndex};

    #[test]
    fn exact_word_counts() {
        let c = Corpus::generate(10, 64, 3, 1);
        for d in &c.docs {
            assert_eq!(d.text.split_whitespace().count(), 64);
        }
    }

    #[test]
    fn deterministic() {
        let a = Corpus::generate(5, 32, 2, 9);
        let b = Corpus::generate(5, 32, 2, 9);
        assert_eq!(a.docs[3].text, b.docs[3].text);
    }

    #[test]
    fn retrieval_finds_topical_documents() {
        // End-to-end sanity of the whole retrieval substrate: corpus →
        // tokenizer → embedder → index → query lands on the right topic.
        let c = Corpus::generate(40, 128, 8, 4);
        let tok = Tokenizer::from_corpus(c.texts(), 2048);
        let emb = HashEmbedder::new(128, 11);
        let mut ix = FlatIndex::new(128);
        for d in &c.docs {
            ix.insert(d.id, emb.embed(&tok.encode(&d.text)));
        }
        let mut rng = Rng::new(5);
        let mut correct = 0;
        for topic in 0..8 {
            let q = c.query_for_topic(topic, 12, &mut rng);
            let hits = ix.search(&emb.embed(&tok.encode(&q)), 3);
            let hit_topics: Vec<usize> =
                hits.iter().map(|h| c.docs[h.chunk_id as usize].topic).collect();
            if hit_topics.contains(&topic) {
                correct += 1;
            }
        }
        assert!(correct >= 7, "retrieval precision too low: {correct}/8");
    }
}
