//! Serving-request generation: the TurboRAG-profile workload used by the
//! paper's §V-B experiments (2×1,024-token chunks + ~20-token query +
//! 20-token answer per request), with all knobs exposed for the parameter
//! sweeps of Figs 6/8/9.

use super::corpus::Corpus;
use super::rng::Rng;
use super::zipf::Zipf;

/// One serving request as the coordinator consumes it.
#[derive(Debug, Clone)]
pub struct RagRequest {
    pub id: u64,
    pub query: String,
    /// Number of document chunks to retrieve (top-k).
    pub top_k: usize,
    /// Decode length (answer tokens to generate).
    pub output_tokens: usize,
    /// Topic the query is about (ground truth for retrieval checks).
    pub topic: usize,
}

/// Workload profile matching the paper's TurboRAG samples.
#[derive(Debug, Clone, Copy)]
pub struct TurboRagProfile {
    /// Retrieved chunks per request (paper default: 2).
    pub top_k: usize,
    /// Mean query length in tokens (paper: 17.67 ≈ 20).
    pub query_tokens: f64,
    /// Answer tokens generated (paper: 20).
    pub output_tokens: usize,
}

impl Default for TurboRagProfile {
    fn default() -> Self {
        TurboRagProfile { top_k: 2, query_tokens: 20.0, output_tokens: 20 }
    }
}

/// Deterministic request stream with Zipf-skewed topic popularity.
pub struct RequestGen {
    profile: TurboRagProfile,
    zipf: Zipf,
    rng: Rng,
    next_id: u64,
}

impl RequestGen {
    pub fn new(profile: TurboRagProfile, n_topics: usize, skew: f64, seed: u64) -> Self {
        RequestGen { profile, zipf: Zipf::new(n_topics, skew), rng: Rng::new(seed), next_id: 0 }
    }

    /// Generate the next request over `corpus`.
    pub fn next(&mut self, corpus: &Corpus) -> RagRequest {
        let topic = self.zipf.sample(&mut self.rng);
        let qlen = self.rng.length_around(self.profile.query_tokens, 4, 31);
        let query = corpus.query_for_topic(topic, qlen, &mut self.rng);
        let id = self.next_id;
        self.next_id += 1;
        RagRequest {
            id,
            query,
            top_k: self.profile.top_k,
            output_tokens: self.profile.output_tokens,
            topic,
        }
    }

    pub fn take(&mut self, corpus: &Corpus, n: usize) -> Vec<RagRequest> {
        (0..n).map(|_| self.next(corpus)).collect()
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn stream_is_deterministic() {
        let corpus = Corpus::generate(20, 64, 5, 1);
        let mut a = RequestGen::new(TurboRagProfile::default(), 5, 1.0, 7);
        let mut b = RequestGen::new(TurboRagProfile::default(), 5, 1.0, 7);
        for _ in 0..20 {
            let (x, y) = (a.next(&corpus), b.next(&corpus));
            assert_eq!(x.query, y.query);
            assert_eq!(x.topic, y.topic);
        }
    }

    #[test]
    fn ids_monotonic_and_lengths_bounded() {
        let corpus = Corpus::generate(20, 64, 5, 1);
        let mut g = RequestGen::new(TurboRagProfile::default(), 5, 1.0, 3);
        let reqs = g.take(&corpus, 50);
        for (i, r) in reqs.iter().enumerate() {
            assert_eq!(r.id, i as u64);
            let n = r.query.split_whitespace().count();
            assert!((4..32).contains(&n), "{n}");
        }
    }

    #[test]
    fn topics_skewed() {
        let corpus = Corpus::generate(100, 32, 100, 1);
        let mut g = RequestGen::new(TurboRagProfile::default(), 100, 1.1, 5);
        let reqs = g.take(&corpus, 2000);
        let hot = reqs.iter().filter(|r| r.topic == 0).count();
        let cold = reqs.iter().filter(|r| r.topic == 99).count();
        assert!(hot > cold * 3, "hot={hot} cold={cold}");
    }
}
