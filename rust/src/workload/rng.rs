//! Small deterministic PRNG (xoshiro256**, seeded via splitmix64).
//! Dependency-free so workloads replay bit-identically across platforms.

use crate::vectordb::embed::splitmix64;

#[derive(Debug, Clone)]
pub struct Rng {
    s: [u64; 4],
}

impl Rng {
    pub fn new(seed: u64) -> Self {
        let mut s = [0u64; 4];
        let mut x = seed;
        for slot in &mut s {
            x = splitmix64(x);
            *slot = x;
        }
        Rng { s }
    }

    #[inline]
    pub fn next_u64(&mut self) -> u64 {
        let r = self.s[1].wrapping_mul(5).rotate_left(7).wrapping_mul(9);
        let t = self.s[1] << 17;
        self.s[2] ^= self.s[0];
        self.s[3] ^= self.s[1];
        self.s[1] ^= self.s[2];
        self.s[0] ^= self.s[3];
        self.s[2] ^= t;
        self.s[3] = self.s[3].rotate_left(45);
        r
    }

    /// Uniform in [0, n).
    #[inline]
    pub fn below(&mut self, n: usize) -> usize {
        (self.next_u64() % n as u64) as usize
    }

    /// Uniform f64 in [0, 1).
    #[inline]
    pub fn f64(&mut self) -> f64 {
        (self.next_u64() >> 11) as f64 * (1.0 / (1u64 << 53) as f64)
    }

    /// Approximately Poisson-shaped positive integer with the given mean
    /// (clamped geometric mixture — good enough for length sampling).
    pub fn length_around(&mut self, mean: f64, min: usize, max: usize) -> usize {
        let jitter = 0.5 + self.f64(); // [0.5, 1.5)
        ((mean * jitter).round() as usize).clamp(min, max)
    }

    /// Pick one element of a slice.
    pub fn pick<'a, T>(&mut self, xs: &'a [T]) -> &'a T {
        &xs[self.below(xs.len())]
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn deterministic() {
        let mut a = Rng::new(7);
        let mut b = Rng::new(7);
        for _ in 0..100 {
            assert_eq!(a.next_u64(), b.next_u64());
        }
    }

    #[test]
    fn f64_in_unit_interval() {
        let mut r = Rng::new(3);
        for _ in 0..1000 {
            let x = r.f64();
            assert!((0.0..1.0).contains(&x));
        }
    }

    #[test]
    fn below_bounds() {
        let mut r = Rng::new(9);
        for _ in 0..1000 {
            assert!(r.below(17) < 17);
        }
    }

    #[test]
    fn length_mean_roughly_right() {
        let mut r = Rng::new(5);
        let n = 5000;
        let sum: usize = (0..n).map(|_| r.length_around(20.0, 1, 100)).sum();
        let mean = sum as f64 / n as f64;
        assert!((15.0..25.0).contains(&mean), "{mean}");
    }
}
