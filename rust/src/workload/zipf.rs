//! Zipf-distributed sampling over ranked items.
//!
//! Drives the Fig-2 experiment: document popularity in real RAG traces is
//! highly skewed ("a small fraction of documents accounts for the
//! majority of retrieval requests" — paper §II-C quoting RAGCache), which
//! a Zipf(s≈1) rank distribution reproduces.

use super::rng::Rng;

/// Precomputed-CDF Zipf sampler over ranks `0..n`.
#[derive(Debug, Clone)]
pub struct Zipf {
    cdf: Vec<f64>,
}

impl Zipf {
    /// `s` is the skew exponent (s=0 → uniform; s≈1 → web-like skew).
    pub fn new(n: usize, s: f64) -> Self {
        assert!(n > 0);
        let mut cdf = Vec::with_capacity(n);
        let mut acc = 0f64;
        for rank in 1..=n {
            acc += 1.0 / (rank as f64).powf(s);
            cdf.push(acc);
        }
        let total = acc;
        for c in &mut cdf {
            *c /= total;
        }
        Zipf { cdf }
    }

    /// Sample a rank in `0..n` (rank 0 = most popular).
    pub fn sample(&self, rng: &mut Rng) -> usize {
        let u = rng.f64();
        self.cdf.partition_point(|&c| c < u).min(self.cdf.len() - 1)
    }

    pub fn n(&self) -> usize {
        self.cdf.len()
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn rank0_most_popular() {
        let z = Zipf::new(1000, 1.0);
        let mut rng = Rng::new(1);
        let mut counts = vec![0usize; 1000];
        for _ in 0..20_000 {
            counts[z.sample(&mut rng)] += 1;
        }
        assert!(counts[0] > counts[10]);
        assert!(counts[0] > counts[999] * 10);
    }

    #[test]
    fn uniform_when_s_zero() {
        let z = Zipf::new(100, 0.0);
        let mut rng = Rng::new(2);
        let mut counts = vec![0usize; 100];
        for _ in 0..100_000 {
            counts[z.sample(&mut rng)] += 1;
        }
        let (min, max) = (counts.iter().min().unwrap(), counts.iter().max().unwrap());
        assert!((*max as f64) < (*min as f64) * 1.6, "{min} {max}");
    }

    #[test]
    fn skew_produces_fig2_shape() {
        // Paper Fig 2 (scaled): with ~9 chunks per query over a 9M corpus
        // and 1M queries, >10% of chunks are accessed 2+ times. Our scaled
        // version must show the same heavy repeat mass.
        let n = 10_000;
        let z = Zipf::new(n, 0.9);
        let mut rng = Rng::new(3);
        let mut counts = vec![0u32; n];
        for _ in 0..10_000 {
            for _ in 0..10 {
                counts[z.sample(&mut rng)] += 1;
            }
        }
        let repeated = counts.iter().filter(|&&c| c >= 2).count();
        assert!(repeated as f64 > 0.05 * n as f64, "{repeated}");
    }

    #[test]
    fn prop_samples_in_range() {
        let mut meta = Rng::new(1234);
        for _ in 0..50 {
            let n = 1 + meta.below(499);
            let s = meta.f64() * 2.0;
            let z = Zipf::new(n, s);
            let mut rng = Rng::new(meta.next_u64());
            for _ in 0..50 {
                assert!(z.sample(&mut rng) < n);
            }
        }
    }
}
