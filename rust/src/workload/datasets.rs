//! Dataset profiles for Table I and the QA-style fidelity experiments.
//!
//! Table I of the paper reports average query/answer token counts for four
//! public RAG benchmarks. We generate synthetic datasets whose length
//! distributions match those means, and the `paper_tables` bench
//! re-measures them — closing the loop between profile and generator.

use super::corpus::Corpus;
use super::rng::Rng;

/// Length profile of one RAG QA dataset (Table I row).
#[derive(Debug, Clone, Copy)]
pub struct DatasetProfile {
    pub name: &'static str,
    pub avg_query_tokens: f64,
    pub avg_answer_tokens: f64,
    /// Documents retrieved per question (top-k in the paper's eval).
    pub top_k: usize,
    /// Multi-hop datasets need evidence combined across documents.
    pub multi_hop: bool,
}

/// The four Table-I datasets.
pub const TABLE1_DATASETS: &[DatasetProfile] = &[
    DatasetProfile { name: "CRAG", avg_query_tokens: 15.56, avg_answer_tokens: 11.17, top_k: 5, multi_hop: false },
    DatasetProfile { name: "TriviaQA", avg_query_tokens: 18.16, avg_answer_tokens: 4.05, top_k: 5, multi_hop: false },
    DatasetProfile { name: "GoogleNQ", avg_query_tokens: 10.09, avg_answer_tokens: 5.77, top_k: 5, multi_hop: false },
    DatasetProfile { name: "HotpotQA", avg_query_tokens: 23.11, avg_answer_tokens: 3.53, top_k: 5, multi_hop: true },
];

/// One synthetic QA item.
#[derive(Debug, Clone)]
pub struct QaItem {
    pub query: String,
    pub answer_len: usize,
    /// Topic(s) whose documents contain the evidence.
    pub evidence_topics: Vec<usize>,
}

/// Generate `n` QA items following a dataset profile over a corpus.
pub fn generate_qa(
    profile: &DatasetProfile,
    corpus: &Corpus,
    n: usize,
    seed: u64,
) -> Vec<QaItem> {
    let mut rng = Rng::new(seed);
    (0..n)
        .map(|_| {
            let n_topics = if profile.multi_hop { 2 } else { 1 };
            let topics: Vec<usize> =
                (0..n_topics).map(|_| rng.below(corpus.n_topics)).collect();
            let qlen = rng.length_around(profile.avg_query_tokens, 3, 64);
            // split query words across evidence topics (multi-hop questions
            // mention entities from both documents)
            let per_topic = qlen / topics.len();
            let mut words = Vec::new();
            for &t in &topics {
                words.push(corpus.query_for_topic(t, per_topic.max(1), &mut rng));
            }
            QaItem {
                query: words.join(" "),
                answer_len: rng.length_around(profile.avg_answer_tokens, 1, 32),
                evidence_topics: topics,
            }
        })
        .collect()
}

/// Measured means of a generated dataset (Table I regeneration).
pub fn measure_means(items: &[QaItem]) -> (f64, f64) {
    let q: usize = items.iter().map(|i| i.query.split_whitespace().count()).sum();
    let a: usize = items.iter().map(|i| i.answer_len).sum();
    (q as f64 / items.len() as f64, a as f64 / items.len() as f64)
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn generated_means_match_profiles() {
        let corpus = Corpus::generate(50, 64, 10, 1);
        for p in TABLE1_DATASETS {
            let items = generate_qa(p, &corpus, 2000, 7);
            let (q, a) = measure_means(&items);
            assert!((q - p.avg_query_tokens).abs() / p.avg_query_tokens < 0.25,
                    "{}: query mean {q} vs {}", p.name, p.avg_query_tokens);
            assert!((a - p.avg_answer_tokens).abs() / p.avg_answer_tokens.max(2.0) < 0.4,
                    "{}: answer mean {a} vs {}", p.name, p.avg_answer_tokens);
        }
    }

    #[test]
    fn multi_hop_has_two_evidence_topics() {
        let corpus = Corpus::generate(50, 64, 10, 1);
        let hotpot = &TABLE1_DATASETS[3];
        assert!(hotpot.multi_hop);
        let items = generate_qa(hotpot, &corpus, 10, 3);
        assert!(items.iter().all(|i| i.evidence_topics.len() == 2));
    }
}
