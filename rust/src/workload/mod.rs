//! Synthetic RAG workload generation (DESIGN.md "Substitutions": stands in
//! for TurboRAG samples, LongBench QA sets and the deep1B access trace —
//! every figure depends only on token counts, chunk sizes and access skew,
//! all controlled parameters here).

pub mod arrivals;
pub mod corpus;
pub mod datasets;
pub mod requests;
pub mod rng;
pub mod zipf;

pub use arrivals::{ArrivalGen, TimedRequest};
pub use corpus::{Corpus, Document};
pub use datasets::{DatasetProfile, TABLE1_DATASETS};
pub use requests::{RagRequest, RequestGen, TurboRagProfile};
pub use rng::Rng;
pub use zipf::Zipf;
