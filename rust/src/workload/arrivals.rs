//! Simulated arrival traces for online serving: Poisson arrival times
//! over the Zipf-skewed TurboRAG request stream.
//!
//! The serving scheduler ([`crate::coordinator::Scheduler`]) runs on
//! *virtual* time — batches are released when a size-or-timeout condition
//! fires against these arrival stamps, never against wall-clock sleeps —
//! so a trace generated here replays bit-identically across runs and
//! policies. Inter-arrival gaps are exponential (`-ln(1-u)/rate`, the
//! Poisson process of open-loop load generators), while topic popularity
//! keeps the Zipf skew of [`RequestGen`]: the combination is the
//! "many users hammering a popular corpus" shape that tier-aware batch
//! formation exists to exploit.

use super::corpus::Corpus;
use super::requests::{RagRequest, RequestGen, TurboRagProfile};
use super::rng::Rng;

/// A serving request stamped with its simulated arrival time.
#[derive(Debug, Clone)]
pub struct TimedRequest {
    pub req: RagRequest,
    /// Seconds since trace start on the virtual clock (nondecreasing).
    pub arrival_secs: f64,
}

/// Deterministic Poisson/Zipf arrival-trace generator: exponential
/// inter-arrival gaps at `rate` requests/second over [`RequestGen`]'s
/// Zipf-skewed topic stream. `rate <= 0` degenerates to the offline
/// trace (every request arrives at t = 0), which is how the batch-replay
/// wrappers feed the scheduler.
pub struct ArrivalGen {
    reqs: RequestGen,
    rng: Rng,
    rate: f64,
    t: f64,
}

impl ArrivalGen {
    pub fn new(
        profile: TurboRagProfile,
        n_topics: usize,
        skew: f64,
        rate: f64,
        seed: u64,
    ) -> Self {
        ArrivalGen {
            reqs: RequestGen::new(profile, n_topics, skew, seed),
            // Independent stream so arrival jitter never perturbs the
            // request content (same seed → same queries at any rate).
            rng: Rng::new(seed ^ 0xa11_ca11),
            rate,
            t: 0.0,
        }
    }

    /// Generate the next request and advance the virtual clock.
    pub fn next(&mut self, corpus: &Corpus) -> TimedRequest {
        if self.rate > 0.0 {
            let u = self.rng.f64();
            self.t += -(1.0 - u).ln() / self.rate;
        }
        TimedRequest { req: self.reqs.next(corpus), arrival_secs: self.t }
    }

    pub fn take(&mut self, corpus: &Corpus, n: usize) -> Vec<TimedRequest> {
        (0..n).map(|_| self.next(corpus)).collect()
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn corpus() -> Corpus {
        Corpus::generate(20, 64, 5, 1)
    }

    #[test]
    fn trace_is_deterministic() {
        let c = corpus();
        let mut a = ArrivalGen::new(TurboRagProfile::default(), 5, 1.0, 50.0, 9);
        let mut b = ArrivalGen::new(TurboRagProfile::default(), 5, 1.0, 50.0, 9);
        for _ in 0..50 {
            let (x, y) = (a.next(&c), b.next(&c));
            assert_eq!(x.arrival_secs, y.arrival_secs);
            assert_eq!(x.req.query, y.req.query);
            assert_eq!(x.req.topic, y.req.topic);
        }
    }

    #[test]
    fn arrivals_monotone_with_poisson_mean() {
        let c = corpus();
        let rate = 100.0;
        let n = 4000;
        let mut gen = ArrivalGen::new(TurboRagProfile::default(), 5, 1.0, rate, 3);
        let trace = gen.take(&c, n);
        let mut prev = 0.0;
        for t in &trace {
            assert!(t.arrival_secs >= prev, "arrivals must be nondecreasing");
            prev = t.arrival_secs;
        }
        // mean inter-arrival of an exponential at rate r is 1/r
        let mean_gap = trace.last().unwrap().arrival_secs / n as f64;
        assert!(
            (mean_gap - 1.0 / rate).abs() < 0.15 / rate,
            "mean gap {mean_gap} vs expected {}",
            1.0 / rate
        );
    }

    #[test]
    fn zero_rate_is_offline() {
        let c = corpus();
        let mut gen = ArrivalGen::new(TurboRagProfile::default(), 5, 1.0, 0.0, 3);
        assert!(gen.take(&c, 20).iter().all(|t| t.arrival_secs == 0.0));
    }

    #[test]
    fn rate_does_not_change_request_content() {
        let c = corpus();
        let mut slow = ArrivalGen::new(TurboRagProfile::default(), 5, 1.0, 1.0, 7);
        let mut fast = ArrivalGen::new(TurboRagProfile::default(), 5, 1.0, 1000.0, 7);
        for _ in 0..30 {
            let (a, b) = (slow.next(&c), fast.next(&c));
            assert_eq!(a.req.query, b.req.query);
            assert_eq!(a.req.id, b.req.id);
        }
    }
}
