//! Vector indexes: exact flat scan and IVF approximate search.

use std::collections::HashMap;

use super::embed::{dot, l2_normalize, splitmix64};
use super::ChunkId;

/// One search hit.
#[derive(Debug, Clone, PartialEq)]
pub struct SearchResult {
    pub chunk_id: ChunkId,
    pub score: f32,
}

/// Common interface of the flat and IVF indexes.
pub trait VectorIndex: Send {
    /// Insert (or replace) a chunk embedding.
    fn insert(&mut self, id: ChunkId, embedding: Vec<f32>);
    /// Remove a chunk (its materialized KV is deleted alongside — see
    /// `coordinator::ingest::delete`). Returns true if present.
    fn delete(&mut self, id: ChunkId) -> bool;
    /// Exact or approximate top-k by cosine similarity.
    fn search(&self, query: &[f32], k: usize) -> Vec<SearchResult>;
    fn len(&self) -> usize;
    fn is_empty(&self) -> bool {
        self.len() == 0
    }
}

/// Exact brute-force index: contiguous embedding matrix + id column.
///
/// Deleted slots are swap-removed so the scan stays dense; at the scales
/// of every experiment but Fig 2 this is both the fastest and the ground
/// truth for recall checks.
#[derive(Debug, Default)]
pub struct FlatIndex {
    dim: usize,
    ids: Vec<ChunkId>,
    data: Vec<f32>, // row-major [len, dim]
    pos: HashMap<ChunkId, usize>,
}

impl FlatIndex {
    pub fn new(dim: usize) -> Self {
        FlatIndex { dim, ids: Vec::new(), data: Vec::new(), pos: HashMap::new() }
    }

    fn row(&self, i: usize) -> &[f32] {
        &self.data[i * self.dim..(i + 1) * self.dim]
    }
}

impl VectorIndex for FlatIndex {
    fn insert(&mut self, id: ChunkId, mut embedding: Vec<f32>) {
        assert_eq!(embedding.len(), self.dim, "embedding dim mismatch");
        l2_normalize(&mut embedding);
        if let Some(&i) = self.pos.get(&id) {
            self.data[i * self.dim..(i + 1) * self.dim].copy_from_slice(&embedding);
            return;
        }
        self.pos.insert(id, self.ids.len());
        self.ids.push(id);
        self.data.extend_from_slice(&embedding);
    }

    fn delete(&mut self, id: ChunkId) -> bool {
        let Some(i) = self.pos.remove(&id) else { return false };
        let last = self.ids.len() - 1;
        if i != last {
            let moved = self.ids[last];
            self.ids.swap(i, last);
            let (head, tail) = self.data.split_at_mut(last * self.dim);
            head[i * self.dim..(i + 1) * self.dim].copy_from_slice(&tail[..self.dim]);
            self.pos.insert(moved, i);
        }
        self.ids.pop();
        self.data.truncate(last * self.dim);
        true
    }

    fn search(&self, query: &[f32], k: usize) -> Vec<SearchResult> {
        assert_eq!(query.len(), self.dim);
        let mut top: Vec<SearchResult> = Vec::with_capacity(k + 1);
        for i in 0..self.ids.len() {
            let score = dot(query, self.row(i));
            if top.len() < k || score > top.last().map(|r| r.score).unwrap_or(f32::MIN) {
                let at = top.partition_point(|r| r.score >= score);
                top.insert(at, SearchResult { chunk_id: self.ids[i], score });
                top.truncate(k);
            }
        }
        top
    }

    fn len(&self) -> usize {
        self.ids.len()
    }
}

/// IVF (inverted-file) approximate index.
///
/// A k-means coarse quantizer over a training sample partitions vectors
/// into `nlist` cells; a query scans only the `nprobe` nearest cells.
/// This is the same structure FAISS/ChromaDB use for million-scale
/// corpora (the Fig 2 experiment runs 900K chunks / 100K queries).
pub struct IvfIndex {
    dim: usize,
    nlist: usize,
    pub nprobe: usize,
    centroids: Vec<f32>, // [nlist, dim]
    lists: Vec<Vec<(ChunkId, Vec<f32>)>>,
    whereabouts: HashMap<ChunkId, usize>,
    trained: bool,
    seed: u64,
}

impl IvfIndex {
    pub fn new(dim: usize, nlist: usize, nprobe: usize, seed: u64) -> Self {
        IvfIndex {
            dim,
            nlist: nlist.max(1),
            nprobe: nprobe.clamp(1, nlist.max(1)),
            centroids: Vec::new(),
            lists: vec![Vec::new(); nlist.max(1)],
            whereabouts: HashMap::new(),
            trained: false,
            seed,
        }
    }

    /// K-means (few iterations of Lloyd's) over a sample of vectors.
    pub fn train(&mut self, sample: &[Vec<f32>], iters: usize) {
        assert!(!sample.is_empty());
        // init: pseudo-random distinct picks
        self.centroids = Vec::with_capacity(self.nlist * self.dim);
        for i in 0..self.nlist {
            let idx = (splitmix64(self.seed ^ i as u64) % sample.len() as u64) as usize;
            self.centroids.extend_from_slice(&sample[idx]);
        }
        for _ in 0..iters {
            let mut sums = vec![0f32; self.nlist * self.dim];
            let mut counts = vec![0usize; self.nlist];
            for v in sample {
                let c = self.nearest_centroid(v);
                counts[c] += 1;
                for (s, x) in sums[c * self.dim..(c + 1) * self.dim].iter_mut().zip(v) {
                    *s += x;
                }
            }
            for c in 0..self.nlist {
                if counts[c] > 0 {
                    let row = &mut sums[c * self.dim..(c + 1) * self.dim];
                    l2_normalize(row);
                    self.centroids[c * self.dim..(c + 1) * self.dim].copy_from_slice(row);
                }
            }
        }
        self.trained = true;
    }

    fn nearest_centroid(&self, v: &[f32]) -> usize {
        let mut best = 0;
        let mut best_score = f32::MIN;
        for c in 0..self.nlist {
            let score = dot(v, &self.centroids[c * self.dim..(c + 1) * self.dim]);
            if score > best_score {
                best_score = score;
                best = c;
            }
        }
        best
    }

    fn probe_order(&self, v: &[f32]) -> Vec<usize> {
        let mut scored: Vec<(usize, f32)> = (0..self.nlist)
            .map(|c| (c, dot(v, &self.centroids[c * self.dim..(c + 1) * self.dim])))
            .collect();
        scored.sort_by(|a, b| b.1.total_cmp(&a.1));
        scored.into_iter().map(|(c, _)| c).collect()
    }
}

impl VectorIndex for IvfIndex {
    fn insert(&mut self, id: ChunkId, mut embedding: Vec<f32>) {
        assert!(self.trained, "IvfIndex::train before insert");
        assert_eq!(embedding.len(), self.dim);
        l2_normalize(&mut embedding);
        if self.whereabouts.contains_key(&id) {
            self.delete(id);
        }
        let c = self.nearest_centroid(&embedding);
        self.lists[c].push((id, embedding));
        self.whereabouts.insert(id, c);
    }

    fn delete(&mut self, id: ChunkId) -> bool {
        let Some(c) = self.whereabouts.remove(&id) else { return false };
        let list = &mut self.lists[c];
        if let Some(i) = list.iter().position(|(x, _)| *x == id) {
            list.swap_remove(i);
            return true;
        }
        false
    }

    fn search(&self, query: &[f32], k: usize) -> Vec<SearchResult> {
        let mut top: Vec<SearchResult> = Vec::with_capacity(k + 1);
        for &c in self.probe_order(query).iter().take(self.nprobe) {
            for (id, v) in &self.lists[c] {
                let score = dot(query, v);
                if top.len() < k || score > top.last().map(|r| r.score).unwrap_or(f32::MIN) {
                    let at = top.partition_point(|r| r.score >= score);
                    top.insert(at, SearchResult { chunk_id: *id, score });
                    top.truncate(k);
                }
            }
        }
        top
    }

    fn len(&self) -> usize {
        self.whereabouts.len()
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::vectordb::HashEmbedder;

    fn emb(dim: usize, seed: u64) -> Vec<f32> {
        let mut v: Vec<f32> = (0..dim)
            .map(|i| (splitmix64(seed ^ i as u64) as f32 / u64::MAX as f32) - 0.5)
            .collect();
        l2_normalize(&mut v);
        v
    }

    #[test]
    fn flat_exact_top1_is_self() {
        let mut ix = FlatIndex::new(16);
        for i in 0..100u64 {
            ix.insert(i, emb(16, i));
        }
        for i in (0..100u64).step_by(17) {
            let hits = ix.search(&emb(16, i), 3);
            assert_eq!(hits[0].chunk_id, i);
            assert!(hits[0].score > 0.999);
        }
    }

    #[test]
    fn flat_delete_swaps_correctly() {
        let mut ix = FlatIndex::new(8);
        for i in 0..10u64 {
            ix.insert(i, emb(8, i));
        }
        assert!(ix.delete(3));
        assert!(!ix.delete(3));
        assert_eq!(ix.len(), 9);
        // remaining entries still searchable
        for i in [0u64, 9, 5] {
            assert_eq!(ix.search(&emb(8, i), 1)[0].chunk_id, i);
        }
        // deleted entry no longer returned
        assert!(ix.search(&emb(8, 3), 10).iter().all(|r| r.chunk_id != 3));
    }

    #[test]
    fn flat_insert_replaces() {
        let mut ix = FlatIndex::new(8);
        ix.insert(1, emb(8, 1));
        ix.insert(1, emb(8, 99));
        assert_eq!(ix.len(), 1);
        assert_eq!(ix.search(&emb(8, 99), 1)[0].chunk_id, 1);
    }

    #[test]
    fn flat_search_returns_sorted_k() {
        let mut ix = FlatIndex::new(8);
        for i in 0..50u64 {
            ix.insert(i, emb(8, i));
        }
        let hits = ix.search(&emb(8, 7), 10);
        assert_eq!(hits.len(), 10);
        for w in hits.windows(2) {
            assert!(w[0].score >= w[1].score);
        }
    }

    #[test]
    fn ivf_recall_against_flat() {
        let e = HashEmbedder::new(32, 3);
        let docs: Vec<Vec<u32>> = (0..500u32)
            .map(|i| (0..20).map(|j| i / 10 + j * 31).collect())
            .collect();
        let embs: Vec<Vec<f32>> = docs.iter().map(|d| e.embed(d)).collect();
        let mut flat = FlatIndex::new(32);
        let mut ivf = IvfIndex::new(32, 16, 6, 9);
        ivf.train(&embs, 5);
        for (i, v) in embs.iter().enumerate() {
            flat.insert(i as u64, v.clone());
            ivf.insert(i as u64, v.clone());
        }
        // recall@10 of IVF vs exact should be high with nprobe=6/16
        let mut hits = 0;
        let mut total = 0;
        for q in (0..500).step_by(29) {
            let truth: Vec<u64> =
                flat.search(&embs[q], 10).into_iter().map(|r| r.chunk_id).collect();
            let approx: Vec<u64> =
                ivf.search(&embs[q], 10).into_iter().map(|r| r.chunk_id).collect();
            total += truth.len();
            hits += truth.iter().filter(|t| approx.contains(t)).count();
        }
        let recall = hits as f64 / total as f64;
        assert!(recall > 0.6, "ivf recall too low: {recall}");
    }

    #[test]
    fn ivf_delete() {
        let mut ivf = IvfIndex::new(8, 4, 4, 1);
        let sample: Vec<Vec<f32>> = (0..20u64).map(|i| emb(8, i)).collect();
        ivf.train(&sample, 3);
        for (i, v) in sample.iter().enumerate() {
            ivf.insert(i as u64, v.clone());
        }
        assert!(ivf.delete(5));
        assert_eq!(ivf.len(), 19);
        assert!(ivf.search(&emb(8, 5), 20).iter().all(|r| r.chunk_id != 5));
    }

    #[test]
    fn prop_flat_len_tracks_inserts_deletes() {
        // randomized insert/delete interleavings vs a HashSet model
        let mut rng = crate::workload::Rng::new(99);
        for _case in 0..50 {
            let mut ix = FlatIndex::new(8);
            let mut reference = std::collections::HashSet::new();
            let ops = 1 + rng.below(59);
            for _ in 0..ops {
                let id = rng.below(20) as u64;
                if rng.f64() < 0.5 {
                    ix.insert(id, emb(8, id));
                    reference.insert(id);
                } else {
                    let was = ix.delete(id);
                    assert_eq!(was, reference.remove(&id));
                }
                assert_eq!(ix.len(), reference.len());
            }
            for id in reference {
                assert_eq!(ix.search(&emb(8, id), 1)[0].chunk_id, id);
            }
        }
    }
}
