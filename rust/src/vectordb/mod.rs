//! In-process vector database substrate (the paper uses ChromaDB).
//!
//! Stores one embedding per document chunk keyed by `chunk_id`, and
//! answers top-K cosine queries. Two index implementations:
//!
//! * [`FlatIndex`] — exact brute-force scan (default; matches ChromaDB's
//!   behaviour at our scales and is the ground truth for IVF recall).
//! * [`IvfIndex`] — inverted-file approximate index (k-means coarse
//!   quantizer, `nprobe` lists searched) for the Fig 2 experiment's
//!   900K-chunk scale.
//!
//! Embeddings come from [`embed::HashEmbedder`], a deterministic hashed
//! bag-of-tokens projection standing in for all-MiniLM-L6-v2 (DESIGN.md
//! "Substitutions": retrieval semantics, not embedding quality, is what
//! MatKV exercises).

pub mod embed;
pub mod store;

pub use embed::HashEmbedder;
pub use store::{FlatIndex, IvfIndex, SearchResult, VectorIndex};

/// Identifier of a document chunk; also names its materialized KV file.
pub type ChunkId = u64;
