//! Deterministic hashed bag-of-tokens embedder.
//!
//! Each token id is hashed (splitmix64) to a fixed pseudo-random unit
//! direction in `dim` dimensions; a text's embedding is the L2-normalized
//! sum of its token directions. Texts sharing many tokens embed close in
//! cosine distance — exactly the property the RAG retrieval path needs —
//! while remaining fully deterministic and offline.

/// splitmix64: cheap, high-quality 64-bit mixer.
#[inline]
pub fn splitmix64(mut x: u64) -> u64 {
    x = x.wrapping_add(0x9e3779b97f4a7c15);
    x = (x ^ (x >> 30)).wrapping_mul(0xbf58476d1ce4e5b9);
    x = (x ^ (x >> 27)).wrapping_mul(0x94d049bb133111eb);
    x ^ (x >> 31)
}

/// Hashed bag-of-tokens embedder (stand-in for all-MiniLM-L6-v2).
#[derive(Debug, Clone)]
pub struct HashEmbedder {
    dim: usize,
    seed: u64,
}

impl HashEmbedder {
    pub fn new(dim: usize, seed: u64) -> Self {
        assert!(dim >= 8, "embedding dim too small");
        HashEmbedder { dim, seed }
    }

    pub fn dim(&self) -> usize {
        self.dim
    }

    /// Pseudo-random direction for one token (unnormalized, ±1 entries).
    fn token_direction(&self, token: u32, out: &mut [f32]) {
        let mut h = splitmix64(self.seed ^ (token as u64).wrapping_mul(0x9e3779b97f4a7c15));
        let mut bits = 0u64;
        let mut remaining = 0;
        for slot in out.iter_mut() {
            if remaining == 0 {
                h = splitmix64(h);
                bits = h;
                remaining = 64;
            }
            *slot += if bits & 1 == 1 { 1.0 } else { -1.0 };
            bits >>= 1;
            remaining -= 1;
        }
    }

    /// Embed a token sequence: normalized sum of token directions.
    pub fn embed(&self, tokens: &[u32]) -> Vec<f32> {
        let mut v = vec![0f32; self.dim];
        for &t in tokens {
            self.token_direction(t, &mut v);
        }
        l2_normalize(&mut v);
        v
    }
}

/// Normalize in place (zero vectors become the unit e0 direction so that
/// downstream cosine math never sees NaN).
pub fn l2_normalize(v: &mut [f32]) {
    let norm: f32 = v.iter().map(|x| x * x).sum::<f32>().sqrt();
    if norm > 1e-12 {
        for x in v.iter_mut() {
            *x /= norm;
        }
    } else if let Some(first) = v.first_mut() {
        *first = 1.0;
    }
}

/// Cosine similarity of two L2-normalized vectors (= dot product).
#[inline]
pub fn dot(a: &[f32], b: &[f32]) -> f32 {
    debug_assert_eq!(a.len(), b.len());
    // 4-way unrolled accumulation: the hot loop of FlatIndex::search.
    let mut acc = [0f32; 4];
    let chunks = a.len() / 4;
    for i in 0..chunks {
        let j = i * 4;
        acc[0] += a[j] * b[j];
        acc[1] += a[j + 1] * b[j + 1];
        acc[2] += a[j + 2] * b[j + 2];
        acc[3] += a[j + 3] * b[j + 3];
    }
    let mut s = acc[0] + acc[1] + acc[2] + acc[3];
    for j in chunks * 4..a.len() {
        s += a[j] * b[j];
    }
    s
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn deterministic() {
        let e = HashEmbedder::new(64, 7);
        assert_eq!(e.embed(&[1, 2, 3]), e.embed(&[1, 2, 3]));
    }

    #[test]
    fn normalized() {
        let e = HashEmbedder::new(64, 7);
        let v = e.embed(&[5, 9, 200, 3]);
        let n: f32 = v.iter().map(|x| x * x).sum();
        assert!((n - 1.0).abs() < 1e-5, "{n}");
    }

    #[test]
    fn shared_tokens_embed_closer() {
        let e = HashEmbedder::new(128, 7);
        let a = e.embed(&[1, 2, 3, 4, 5, 6, 7, 8]);
        let b = e.embed(&[1, 2, 3, 4, 5, 6, 9, 10]); // 6/8 shared
        let c = e.embed(&[100, 101, 102, 103, 104, 105, 106, 107]); // disjoint
        assert!(dot(&a, &b) > dot(&a, &c) + 0.2);
    }

    #[test]
    fn empty_tokens_is_unit_vector() {
        let e = HashEmbedder::new(16, 7);
        let v = e.embed(&[]);
        assert_eq!(v[0], 1.0);
    }

    #[test]
    fn prop_order_invariant_and_unit_norm() {
        let e = HashEmbedder::new(32, 42);
        let mut rng = crate::workload::Rng::new(17);
        for _ in 0..100 {
            let n = 1 + rng.below(29);
            let mut ts: Vec<u32> = (0..n).map(|_| rng.below(1000) as u32).collect();
            let a = e.embed(&ts);
            assert!((dot(&a, &a) - 1.0).abs() < 1e-4);
            ts.reverse();
            let b = e.embed(&ts);
            for (x, y) in a.iter().zip(&b) {
                assert!((x - y).abs() < 1e-5);
            }
        }
    }
}
