//! Stand-in architectures: costing executed work traces at paper scale.
//!
//! Our executed configs (tiny/small/base) are scaled ~1000x below the
//! paper's LLaMA models, and FLOPs shrink quadratically with width while
//! KV bytes shrink linearly — so *directly* converting our FLOPs/bytes to
//! H100 time would misplace every compute-vs-IO crossover. Instead the
//! engine records an architecture-independent **work trace** (how many
//! live tokens were appended against how much live context, and how many
//! device invocations ran — see [`crate::coordinator::metrics::WorkTrace`]),
//! and the benches cost that *same trace* under the real architecture
//! each config stands in for (DESIGN.md "Substitutions"):
//!
//!   tiny → LLaMA 3.2 3B, small → LLaMA 3.1 8B, base → LLaMA 3.1 70B
//!   (4-bit weights, as in the paper's H100 setup).

use super::profiles::DeviceProfile;
use super::roofline::PhaseCost;
use crate::coordinator::metrics::WorkTrace;
use crate::manifest::ModelConfig;

/// Transformer architecture description sufficient for roofline costing.
#[derive(Debug, Clone)]
pub struct ArchSpec {
    pub name: String,
    pub n_layers: usize,
    pub d_model: usize,
    pub n_heads: usize,
    pub n_kv_heads: usize,
    pub head_dim: usize,
    pub d_ff: usize,
    pub vocab: usize,
    pub param_count: f64,
    /// Bytes per weight streamed from HBM (2 = f16, 0.5 = 4-bit).
    pub bytes_per_param: f64,
    /// Bytes of KV cache per token (storage + HBM traffic).
    pub kv_bytes_per_token: f64,
    /// Per-batch-element software overhead of one decode step, seconds.
    /// Calibrated from the paper's own measurements: Fig 5 (batch 1)
    /// implies ~65 ms/step for the 4-bit 70B while Table IV (batch 8)
    /// implies ~450 ms/step — jointly a ~15 ms roofline term plus ~50 ms
    /// *per element* (HF transformers' dynamic-cache concat + bnb 4-bit
    /// dequant are per-element costs). f16 models are far cheaper.
    pub decode_elem_overhead_s: f64,
}

impl ArchSpec {
    /// LLaMA 3.2 3B (f16) — the paper's small model.
    pub fn llama_3b() -> Self {
        ArchSpec {
            name: "LLaMA-3.2-3B".into(),
            n_layers: 28,
            d_model: 3072,
            n_heads: 24,
            n_kv_heads: 8,
            head_dim: 128,
            d_ff: 8192,
            vocab: 128_256,
            param_count: 3.2e9,
            bytes_per_param: 2.0,
            kv_bytes_per_token: 28.0 * 2.0 * 8.0 * 128.0 * 2.0, // 114 KB (f16)
            decode_elem_overhead_s: 0.003,
        }
    }

    /// LLaMA 3.1 8B (f16).
    pub fn llama_8b() -> Self {
        ArchSpec {
            name: "LLaMA-3.1-8B".into(),
            n_layers: 32,
            d_model: 4096,
            n_heads: 32,
            n_kv_heads: 8,
            head_dim: 128,
            d_ff: 14_336,
            vocab: 128_256,
            param_count: 8.0e9,
            bytes_per_param: 2.0,
            kv_bytes_per_token: 32.0 * 2.0 * 8.0 * 128.0 * 2.0, // 131 KB
            decode_elem_overhead_s: 0.005,
        }
    }

    /// LLaMA 3.1 70B, 4-bit quantized (the paper's single-H100 setup).
    /// KV bytes calibrated to the paper's anchor (250 MB / 1,024 tokens).
    pub fn llama_70b() -> Self {
        ArchSpec {
            name: "LLaMA-3.1-70B-4bit".into(),
            n_layers: 80,
            d_model: 8192,
            n_heads: 64,
            n_kv_heads: 8,
            head_dim: 128,
            d_ff: 28_672,
            vocab: 128_256,
            param_count: 70.0e9,
            bytes_per_param: 0.5,
            kv_bytes_per_token: 250e6 / 1024.0, // 244 KB (paper §II-C)
            decode_elem_overhead_s: 0.05, // bnb-4bit per-element decode cost
        }
    }

    /// The paper model each executed config stands in for.
    pub fn standin_for(config_name: &str) -> Self {
        match config_name {
            "tiny" => Self::llama_3b(),
            "small" => Self::llama_8b(),
            _ => Self::llama_70b(),
        }
    }

    /// Cost this architecture at our own (executed) scale.
    pub fn from_config(cfg: &ModelConfig) -> Self {
        ArchSpec {
            name: cfg.name.clone(),
            n_layers: cfg.n_layers,
            d_model: cfg.d_model,
            n_heads: cfg.n_heads,
            n_kv_heads: cfg.n_kv_heads,
            head_dim: cfg.head_dim,
            d_ff: cfg.d_ff,
            vocab: cfg.vocab,
            param_count: cfg.param_count as f64,
            bytes_per_param: 4.0, // f32 artifacts
            kv_bytes_per_token: cfg.kv_bytes_per_token as f64,
            decode_elem_overhead_s: 0.0, // our rust stack has no per-elem cost
        }
    }

    /// FLOPs per appended live token, excluding attention-context terms.
    fn flops_per_token(&self) -> f64 {
        let d = self.d_model as f64;
        let hd = (self.n_heads * self.head_dim) as f64;
        let hkv = (self.n_kv_heads * self.head_dim) as f64;
        let f = self.d_ff as f64;
        self.n_layers as f64 * 2.0 * (d * hd * 2.0 + d * hkv * 2.0 + 3.0 * d * f)
            + 2.0 * d * self.vocab as f64
    }

    /// FLOPs per (token x live-context) unit of attention.
    fn attn_flops_per_token_ctx(&self) -> f64 {
        self.n_layers as f64 * 2.0 * 2.0 * (self.n_heads * self.head_dim) as f64
    }

    /// Roofline cost of an executed work trace under this architecture.
    pub fn trace_cost(&self, t: &WorkTrace) -> PhaseCost {
        PhaseCost {
            flops: self.flops_per_token() * t.sum_s
                + self.attn_flops_per_token_ctx() * t.sum_s_ctx,
            hbm_bytes: t.steps * self.param_count * self.bytes_per_param
                + t.sum_ctx * self.kv_bytes_per_token
                + t.sum_s * self.d_model as f64 * 4.0 * 8.0, // activations
            pcie_bytes: 0.0,
        }
    }

    /// Seconds of device time for a prefill-class trace.
    pub fn trace_secs(&self, t: &WorkTrace, dev: &DeviceProfile) -> f64 {
        self.trace_cost(t).secs_on(dev)
    }

    /// Seconds of device time for a decode-class trace: bandwidth
    /// roofline plus the calibrated per-element software overhead
    /// (sum_s counts element-steps for S=1 decode traces).
    pub fn trace_secs_decode(&self, t: &WorkTrace, dev: &DeviceProfile) -> f64 {
        self.trace_cost(t).secs_on_decode(dev) + self.decode_elem_overhead_s * t.sum_s
    }

    /// Materialized KV bytes for a token count at this scale.
    pub fn kv_bytes(&self, tokens: usize) -> f64 {
        tokens as f64 * self.kv_bytes_per_token
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::coordinator::metrics::WorkTrace;
    use crate::hwsim::DeviceProfile;

    fn prefill_trace(tokens: usize) -> WorkTrace {
        // one 1,024-token chunk prefilled in four 256 steps, batch 1
        let mut t = WorkTrace::default();
        let step = 256;
        for i in 0..(tokens / step) {
            t.record_step();
            t.record_elem(step, (i + 1) * step);
        }
        t
    }

    #[test]
    fn paper_anchor_70b_prefill_time() {
        // §II-C: prefilling 1,024 tokens of LLaMA-70B on an H100 takes
        // ~500 ms. Our roofline with the stand-in spec must land in the
        // right regime (same order of magnitude).
        let arch = ArchSpec::llama_70b();
        let secs = arch.trace_secs(&prefill_trace(1024), &DeviceProfile::h100());
        assert!((0.1..1.5).contains(&secs), "70B prefill {secs}s");
    }

    #[test]
    fn paper_anchor_70b_kv_size() {
        let arch = ArchSpec::llama_70b();
        let mb = arch.kv_bytes(1024) / 1e6;
        assert!((200.0..300.0).contains(&mb), "{mb} MB");
    }

    #[test]
    fn prefill_compute_dominates_load_at_70b() {
        // the inequality the whole paper rests on
        let arch = ArchSpec::llama_70b();
        let prefill = arch.trace_secs(&prefill_trace(1024), &DeviceProfile::h100());
        let load = crate::hwsim::StorageProfile::ssd_9100pro()
            .read_secs(arch.kv_bytes(1024) as usize);
        assert!(prefill > 5.0 * load, "prefill {prefill} vs load {load}");
    }

    #[test]
    fn benefit_grows_with_model_size() {
        // Fig 9's shape: prefill/load ratio widens from 3B to 70B
        let h100 = DeviceProfile::h100();
        let ssd = crate::hwsim::StorageProfile::raid0_4x9100();
        let ratio = |arch: &ArchSpec| {
            arch.trace_secs(&prefill_trace(1024), &h100)
                / ssd.read_secs(arch.kv_bytes(1024) as usize)
        };
        let r3 = ratio(&ArchSpec::llama_3b());
        let r70 = ratio(&ArchSpec::llama_70b());
        assert!(r70 > r3, "3B {r3} vs 70B {r70}");
    }

    #[test]
    fn decode_memory_bound_on_both_gpus() {
        // a decode trace: 20 steps, batch 8, ctx ~2100
        let mut t = WorkTrace::default();
        for _ in 0..20 {
            t.record_step();
            for _ in 0..8 {
                t.record_elem(1, 2100);
            }
        }
        let arch = ArchSpec::llama_70b();
        let cost = arch.trace_cost(&t);
        let h100 = DeviceProfile::h100();
        assert!(
            cost.hbm_bytes / (h100.hbm_bw * h100.membw_util)
                > cost.flops / (h100.peak_flops * h100.mfu)
        );
    }
}
