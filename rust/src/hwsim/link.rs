//! Contended-link model: the one queued-reservation primitive every
//! simulated transfer in this repo goes through.
//!
//! MatKV's overlap claim (decode batch *n* while loading batch *n+1*'s
//! KVs) only holds if the host→device path can absorb the traffic, and
//! the KV-offloading bottleneck literature (PAPERS.md) argues PCIe — not
//! flash — is where serving saturates first. Before this module, only
//! the flash shards modeled contention (a sleep-based
//! [`DeviceThrottle`]); PCIe was a flat `bytes / pcie_bw` charge that
//! could never queue. [`Link`] generalizes the throttle's
//! reserve-a-slot-after-`busy_until` core so flash reads, H2D demand
//! loads, prefetch, warm→hot promotion and hot→warm demotion all
//! contend for bandwidth the same way — and exposes the backlog / peak
//! queue / per-traffic-class gauges the serve reports print.
//!
//! A link is (bandwidth, latency) plus a single `busy_until` horizon.
//! [`Link::reserve`] computes the transfer's wire time, claims the slot
//! `[max(now, busy_until), +duration)`, advances the horizon, and
//! returns the [`Slot`] — the queued wait is `start - now`. Three clock
//! modes cover every caller:
//!
//! * [`LinkClock::Sleep`] — wall clock, and the caller is slept until
//!   the slot ends (the flash shards' behavior, where simulated device
//!   time must show up as real wall time for the overlap benches).
//! * [`LinkClock::Account`] — wall clock for slot placement, no sleep:
//!   pure accounting for host-side buses whose cost is already charged
//!   elsewhere (the q8 quant/dequant bus).
//! * [`LinkClock::Virtual`] — the caller supplies `now` (the fleet
//!   dispatcher's deterministic virtual clock); backlog gauges read
//!   against the last supplied instant, so telemetry is reproducible in
//!   tests (no wall-clock `Instant` leaks into assertions).
//!
//! [`DeviceThrottle`]: crate::kvstore::DeviceThrottle

use std::sync::atomic::{AtomicBool, AtomicU64, Ordering};
use std::sync::Mutex;
use std::time::{Duration, Instant};

use crate::trace::{Arg, TraceBus};

/// How a [`Link`] obtains "now" and whether reservations block.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum LinkClock {
    /// Wall clock; `reserve` sleeps the caller until its slot ends.
    Sleep,
    /// Wall clock for placement; `reserve` returns immediately.
    Account,
    /// Caller-supplied clock (`reserve_at`); fully deterministic.
    Virtual,
}

/// What a reservation's bytes were moved *for* — the per-class byte
/// counters let one bus report how much of its traffic was demand
/// misses vs. speculative prefetch vs. tier promotions.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum TrafficClass {
    /// Demand miss reads (a batch is waiting on these bytes).
    Demand,
    /// Speculative reads issued by the overlap prefetcher.
    Prefetch,
    /// Warm→hot promotion (q8 dequant feeding the f32 tier).
    Promotion,
    /// Hot→warm demotion (f32 eviction quantizing into q8).
    Demotion,
    /// Host→device KV upload ahead of prefill/decode.
    H2D,
    /// Store writes (ingest / materialization).
    Write,
}

impl TrafficClass {
    /// Every class, in [`TrafficClass::index`] order.
    pub const ALL: [TrafficClass; 6] = [
        TrafficClass::Demand,
        TrafficClass::Prefetch,
        TrafficClass::Promotion,
        TrafficClass::Demotion,
        TrafficClass::H2D,
        TrafficClass::Write,
    ];

    /// Stable slot into [`LinkStats`]' per-class byte counters.
    pub fn index(self) -> usize {
        self as usize
    }

    /// The label emitted into telemetry JSON.
    pub fn label(self) -> &'static str {
        match self {
            TrafficClass::Demand => "demand",
            TrafficClass::Prefetch => "prefetch",
            TrafficClass::Promotion => "promotion",
            TrafficClass::Demotion => "demotion",
            TrafficClass::H2D => "h2d",
            TrafficClass::Write => "write",
        }
    }
}

/// One granted reservation: the half-open interval `[start, end)` in
/// link-clock seconds, plus how long the caller waited behind earlier
/// traffic (`start - now` at reserve time).
#[derive(Debug, Clone, Copy, PartialEq)]
pub struct Slot {
    pub start: f64,
    pub end: f64,
    pub queued_secs: f64,
}

impl Slot {
    /// Seconds of link time this reservation occupies.
    pub fn duration(&self) -> f64 {
        self.end - self.start
    }
}

/// Cumulative per-link counters (relaxed atomics, nano-granular like
/// the cache tiers' quant clocks, so tiny unit-test transfers still
/// register).
#[derive(Debug, Default)]
pub struct LinkStats {
    busy_ns: AtomicU64,
    queued_ns: AtomicU64,
    peak_backlog_ns: AtomicU64,
    reserves: AtomicU64,
    bytes: [AtomicU64; TrafficClass::ALL.len()],
}

impl LinkStats {
    /// Seconds the link spent moving bytes.
    pub fn busy_secs(&self) -> f64 {
        self.busy_ns.load(Ordering::Relaxed) as f64 / 1e9
    }

    /// Seconds reservations spent waiting behind earlier traffic — the
    /// contention signal (`0` means the link never queued).
    pub fn queued_secs(&self) -> f64 {
        self.queued_ns.load(Ordering::Relaxed) as f64 / 1e9
    }

    /// High-water mark of the backlog any single reservation saw ahead
    /// of its own completion (`end - now`).
    pub fn peak_backlog_secs(&self) -> f64 {
        self.peak_backlog_ns.load(Ordering::Relaxed) as f64 / 1e9
    }

    /// Number of reservations granted.
    pub fn reserves(&self) -> u64 {
        self.reserves.load(Ordering::Relaxed)
    }

    /// Bytes moved for one traffic class.
    pub fn bytes_for(&self, class: TrafficClass) -> u64 {
        self.bytes[class.index()].load(Ordering::Relaxed)
    }

    /// Bytes moved across all classes.
    pub fn total_bytes(&self) -> u64 {
        self.bytes.iter().map(|b| b.load(Ordering::Relaxed)).sum()
    }

    fn record(&self, busy: f64, queued: f64, backlog: f64, bytes: usize, class: TrafficClass) {
        self.busy_ns.fetch_add((busy * 1e9) as u64, Ordering::Relaxed);
        if queued > 0.0 {
            self.queued_ns.fetch_add((queued * 1e9) as u64, Ordering::Relaxed);
        }
        self.peak_backlog_ns.fetch_max((backlog * 1e9) as u64, Ordering::Relaxed);
        self.reserves.fetch_add(1, Ordering::Relaxed);
        self.bytes[class.index()].fetch_add(bytes as u64, Ordering::Relaxed);
    }

    fn count_bypass(&self, bytes: usize, class: TrafficClass) {
        self.reserves.fetch_add(1, Ordering::Relaxed);
        self.bytes[class.index()].fetch_add(bytes as u64, Ordering::Relaxed);
    }

    fn reset(&self) {
        self.busy_ns.store(0, Ordering::Relaxed);
        self.queued_ns.store(0, Ordering::Relaxed);
        self.peak_backlog_ns.store(0, Ordering::Relaxed);
        self.reserves.store(0, Ordering::Relaxed);
        for b in &self.bytes {
            b.store(0, Ordering::Relaxed);
        }
    }

    /// Plain-data copy for JSON emission.
    pub fn snapshot(&self) -> LinkSnapshot {
        let mut bytes = [0u64; TrafficClass::ALL.len()];
        for (dst, src) in bytes.iter_mut().zip(&self.bytes) {
            *dst = src.load(Ordering::Relaxed);
        }
        LinkSnapshot {
            busy_secs: self.busy_secs(),
            queued_secs: self.queued_secs(),
            peak_backlog_secs: self.peak_backlog_secs(),
            reserves: self.reserves(),
            bytes_by_class: bytes,
        }
    }
}

/// Point-in-time copy of [`LinkStats`], serializable.
#[derive(Debug, Clone, Copy, Default, PartialEq)]
pub struct LinkSnapshot {
    pub busy_secs: f64,
    pub queued_secs: f64,
    pub peak_backlog_secs: f64,
    pub reserves: u64,
    pub bytes_by_class: [u64; TrafficClass::ALL.len()],
}

impl LinkSnapshot {
    /// Compact JSON object — the one serializer for per-link telemetry.
    pub fn to_json(&self) -> String {
        let bytes: Vec<String> = TrafficClass::ALL
            .iter()
            .map(|c| format!("\"{}\":{}", c.label(), self.bytes_by_class[c.index()]))
            .collect();
        format!(
            "{{\"busy_secs\":{:.6},\"queued_secs\":{:.6},\"peak_backlog_secs\":{:.6},\
             \"reserves\":{},\"bytes\":{{{}}}}}",
            self.busy_secs,
            self.queued_secs,
            self.peak_backlog_secs,
            self.reserves,
            bytes.join(",")
        )
    }
}

#[derive(Debug, Default)]
struct LinkState {
    /// When the link drains, in link-clock seconds (0 = idle since birth).
    busy_until: f64,
    /// Latest `now` any reservation supplied (virtual-clock backlog anchor).
    last_now: f64,
}

/// A contended, bandwidth/latency-parameterized transfer resource.
///
/// All times are f64 seconds on the link's own clock: wall modes anchor
/// at construction (`birth`), virtual mode is whatever the caller's
/// scheduler says. Reservations serialize through one mutex-guarded
/// horizon, exactly like [`DeviceThrottle`]'s `busy_until` — this type
/// *is* that core, extracted.
///
/// [`DeviceThrottle`]: crate::kvstore::DeviceThrottle
#[derive(Debug)]
pub struct Link {
    name: String,
    bandwidth: f64,
    latency_s: f64,
    clock: LinkClock,
    enabled: AtomicBool,
    birth: Instant,
    state: Mutex<LinkState>,
    /// Tracing gate, checked with one relaxed load in [`Link::admit`]
    /// before the trace mutex is ever touched — the untraced hot path
    /// pays a single branch.
    trace_on: AtomicBool,
    /// Trace handle plus the track this link records under. Link
    /// *names* repeat (every shard of one profile shares one), so the
    /// caller — who knows the topology — names the track.
    trace: Mutex<Option<(TraceBus, String)>>,
    pub stats: LinkStats,
}

impl Link {
    pub fn new(name: impl Into<String>, bandwidth: f64, latency_s: f64, clock: LinkClock) -> Self {
        Link {
            name: name.into(),
            bandwidth,
            latency_s,
            clock,
            enabled: AtomicBool::new(true),
            birth: Instant::now(),
            state: Mutex::new(LinkState::default()),
            trace_on: AtomicBool::new(false),
            trace: Mutex::new(None),
            stats: LinkStats::default(),
        }
    }

    /// Wire this link to a trace bus under an explicit `track` name.
    /// Interior-mutable — links are shared behind `Arc` by the time the
    /// CLI knows whether tracing is on. A disabled bus un-wires.
    pub fn set_trace(&self, trace: TraceBus, track: impl Into<String>) {
        self.trace_on.store(trace.enabled(), Ordering::Relaxed);
        *self.trace.lock().unwrap() =
            if trace.enabled() { Some((trace, track.into())) } else { None };
    }

    /// Record one granted reservation. Virtual-clock slots carry their
    /// real (deterministic) timestamps and queued split; wall-clock
    /// modes record the modeled duration and bytes only — wall times
    /// would break the exporter's byte-identity contract (see
    /// [`crate::trace`]).
    fn trace_slot(&self, slot: &Slot, bytes: usize, class: TrafficClass) {
        if !self.trace_on.load(Ordering::Relaxed) {
            return;
        }
        let guard = self.trace.lock().unwrap();
        let Some((bus, track)) = guard.as_ref() else { return };
        match self.clock {
            LinkClock::Virtual => bus.span(
                track,
                class.label(),
                slot.start,
                slot.duration(),
                &[
                    ("bytes", Arg::U(bytes as u64)),
                    ("queued_secs", Arg::F(slot.queued_secs)),
                ],
            ),
            _ => bus.event(
                track,
                class.label(),
                slot.duration(),
                &[("bytes", Arg::U(bytes as u64))],
            ),
        }
    }

    pub fn name(&self) -> &str {
        &self.name
    }

    pub fn bandwidth(&self) -> f64 {
        self.bandwidth
    }

    pub fn clock(&self) -> LinkClock {
        self.clock
    }

    /// Whether reservations queue (disabled links grant instant,
    /// horizon-free slots — the `--pcie-contention off` / unthrottled
    /// degenerate mode).
    pub fn is_enabled(&self) -> bool {
        self.enabled.load(Ordering::Relaxed)
    }

    pub fn set_enabled(&self, on: bool) {
        self.enabled.store(on, Ordering::Relaxed);
    }

    /// **The** definition of transfer wire time in this repo:
    /// `latency + bytes / bandwidth` (0 for empty transfers). Every
    /// path that used to flat-charge `bytes / pcie_bw` now routes
    /// through this, so the formula can't fork per call site.
    pub fn wire_secs(bandwidth: f64, latency_s: f64, bytes: usize) -> f64 {
        if bytes == 0 {
            return 0.0;
        }
        latency_s + bytes as f64 / bandwidth
    }

    /// Wire time of `bytes` on *this* link.
    pub fn duration_secs(&self, bytes: usize) -> f64 {
        Self::wire_secs(self.bandwidth, self.latency_s, bytes)
    }

    fn wall_now(&self) -> f64 {
        self.birth.elapsed().as_secs_f64()
    }

    /// Reserve a slot for `bytes` at the link clock's current instant
    /// (wall modes; [`LinkClock::Sleep`] blocks until the slot ends).
    pub fn reserve(&self, bytes: usize, class: TrafficClass) -> Slot {
        let now = self.wall_now();
        self.admit(now, self.duration_secs(bytes), bytes, class)
    }

    /// Reserve a slot for `bytes` at virtual instant `now`.
    pub fn reserve_at(&self, now: f64, bytes: usize, class: TrafficClass) -> Slot {
        self.admit(now, self.duration_secs(bytes), bytes, class)
    }

    /// Reserve a caller-priced slot (duration computed outside — e.g. a
    /// storage profile's asymmetric read/write bandwidth, or a quant
    /// pass whose cost is compute-, not wire-, bound). `bytes` only
    /// feeds the traffic-class byte counters.
    pub fn reserve_secs(&self, secs: f64, bytes: usize, class: TrafficClass) -> Slot {
        let now = self.wall_now();
        self.admit(now, secs, bytes, class)
    }

    /// [`Link::reserve_secs`] at virtual instant `now`.
    pub fn reserve_secs_at(&self, now: f64, secs: f64, bytes: usize, class: TrafficClass) -> Slot {
        self.admit(now, secs, bytes, class)
    }

    fn admit(&self, now: f64, secs: f64, bytes: usize, class: TrafficClass) -> Slot {
        let secs = if secs.is_finite() { secs.max(0.0) } else { 0.0 };
        if bytes == 0 && secs == 0.0 {
            // Zero-byte transfer: nothing moves, nothing queues, no
            // stats — a pure no-op by contract.
            return Slot { start: now, end: now, queued_secs: 0.0 };
        }
        if !self.is_enabled() {
            // Disabled: the transfer still "takes" its wire time for
            // the caller's own accounting, but never occupies the
            // horizon — concurrent transfers overlap freely.
            self.stats.count_bypass(bytes, class);
            let slot = Slot { start: now, end: now + secs, queued_secs: 0.0 };
            self.trace_slot(&slot, bytes, class);
            return slot;
        }
        let (start, end) = {
            let mut st = self.state.lock().unwrap();
            st.last_now = st.last_now.max(now);
            let start = st.busy_until.max(now);
            let end = start + secs;
            st.busy_until = end;
            (start, end)
        };
        let queued = start - now;
        self.stats.record(secs, queued, end - now, bytes, class);
        let slot = Slot { start, end, queued_secs: queued };
        self.trace_slot(&slot, bytes, class);
        if self.clock == LinkClock::Sleep {
            let wall = self.wall_now();
            if end > wall {
                std::thread::sleep(Duration::from_secs_f64(end - wall));
            }
        }
        slot
    }

    /// Seconds until the link drains, measured on the link's own clock:
    /// wall for [`LinkClock::Sleep`]/[`LinkClock::Account`], the last
    /// reservation's supplied instant for [`LinkClock::Virtual`] — so
    /// virtual-clock gauges are reproducible (no `Instant::now` in the
    /// reading).
    pub fn backlog_secs(&self) -> f64 {
        let st = self.state.lock().unwrap();
        let now = match self.clock {
            LinkClock::Virtual => st.last_now,
            _ => self.wall_now(),
        };
        (st.busy_until - now).max(0.0)
    }

    /// Raw drain instant in link-clock seconds (0 = never reserved).
    /// Route estimators fold this into earliest-finish scoring.
    pub fn horizon(&self) -> f64 {
        self.state.lock().unwrap().busy_until
    }

    /// Clear the horizon *and* the stats — a fresh link, as required by
    /// deterministic re-dispatch (two runs of the same plan must see
    /// identical queues).
    pub fn reset(&self) {
        let mut st = self.state.lock().unwrap();
        st.busy_until = 0.0;
        st.last_now = 0.0;
        self.stats.reset();
    }
}

/// Register one link's counters/gauges into a
/// [`MetricsRegistry`](crate::obs::MetricsRegistry) under
/// `matkv.link.*` with the caller's labels (`link=hostbus`,
/// `shard=3`, `worker=rtx4090:1`, …) — polled bridges over the
/// existing relaxed atomics, so the reserve hot path is untouched.
/// With `classes` set, per-traffic-class byte counters are added under
/// an extra `class=<label>` label.
pub fn register_link_metrics(
    reg: &crate::obs::MetricsRegistry,
    link: &std::sync::Arc<Link>,
    labels: &[(&str, &str)],
    classes: bool,
) -> anyhow::Result<()> {
    macro_rules! poll {
        ($method:ident, $name:expr, $help:expr, |$s:ident| $body:expr) => {{
            let l = std::sync::Arc::clone(link);
            reg.$method($name, labels, $help, move || {
                let $s = &l.stats;
                $body
            })?;
        }};
    }
    poll!(counter_fn, "matkv.link.busy_seconds", "seconds spent moving bytes", |s| {
        s.busy_secs()
    });
    poll!(
        counter_fn,
        "matkv.link.queued_seconds",
        "seconds reservations waited behind earlier traffic",
        |s| s.queued_secs()
    );
    poll!(counter_fn, "matkv.link.reserves", "reservations granted", |s| {
        s.reserves() as f64
    });
    poll!(
        gauge_fn,
        "matkv.link.peak_backlog_seconds",
        "high-water backlog any reservation saw",
        |s| s.peak_backlog_secs()
    );
    {
        let l = std::sync::Arc::clone(link);
        reg.gauge_fn(
            "matkv.link.backlog_seconds",
            labels,
            "seconds until the link drains (link-clock)",
            move || l.backlog_secs(),
        )?;
    }
    if classes {
        for class in TrafficClass::ALL {
            let mut with_class: Vec<(&str, &str)> = labels.to_vec();
            with_class.push(("class", class.label()));
            let l = std::sync::Arc::clone(link);
            reg.counter_fn(
                "matkv.link.bytes",
                &with_class,
                "bytes moved, by traffic class",
                move || l.stats.bytes_for(class) as f64,
            )?;
        }
    }
    Ok(())
}

#[cfg(test)]
mod tests {
    use super::*;
    use std::sync::Arc;

    fn vlink(bw: f64) -> Link {
        Link::new("test", bw, 0.0, LinkClock::Virtual)
    }

    #[test]
    fn zero_byte_transfer_is_a_noop() {
        let link = vlink(100e6);
        let slot = link.reserve_at(5.0, 0, TrafficClass::H2D);
        assert_eq!(slot, Slot { start: 5.0, end: 5.0, queued_secs: 0.0 });
        assert_eq!(link.horizon(), 0.0, "horizon untouched");
        assert_eq!(link.stats.reserves(), 0);
        assert_eq!(link.stats.busy_secs(), 0.0);
        assert_eq!(link.stats.total_bytes(), 0);
    }

    #[test]
    fn disabled_link_degenerates_to_noop() {
        let link = vlink(100e6);
        link.set_enabled(false);
        let a = link.reserve_at(0.0, 10 << 20, TrafficClass::H2D);
        let b = link.reserve_at(0.0, 10 << 20, TrafficClass::H2D);
        // Both transfers start immediately — no queueing, horizon-free.
        assert_eq!(a.start, 0.0);
        assert_eq!(b.start, 0.0);
        assert_eq!(a.queued_secs, 0.0);
        assert_eq!(b.queued_secs, 0.0);
        assert!((a.duration() - 0.1048576).abs() < 1e-9, "wire time still charged");
        assert_eq!(link.horizon(), 0.0);
        assert_eq!(link.stats.queued_secs(), 0.0);
        assert_eq!(link.stats.busy_secs(), 0.0);
        // Byte accounting survives the bypass (traffic reports stay whole).
        assert_eq!(link.stats.bytes_for(TrafficClass::H2D), 2 * (10 << 20) as u64);
        // Re-enabling makes the same reservation queue again.
        link.set_enabled(true);
        link.reserve_at(0.0, 10 << 20, TrafficClass::H2D);
        assert!(link.horizon() > 0.0);
    }

    #[test]
    fn concurrent_reserves_serialize_in_slot_order() {
        // Account mode: wall-clock placement, no sleeping — the test
        // finishes instantly while the slots still serialize.
        let link = Arc::new(Link::new("bus", 100e6, 0.0, LinkClock::Account));
        let handles: Vec<_> = (0..4)
            .map(|_| {
                let l = link.clone();
                std::thread::spawn(move || l.reserve(10 << 20, TrafficClass::Demand))
            })
            .collect();
        let mut slots: Vec<Slot> = handles.into_iter().map(|h| h.join().unwrap()).collect();
        slots.sort_by(|a, b| a.start.partial_cmp(&b.start).unwrap());
        for pair in slots.windows(2) {
            assert!(
                pair[1].start >= pair[0].end - 1e-9,
                "slots overlap: {:?} then {:?}",
                pair[0],
                pair[1]
            );
        }
        let per = 0.1048576; // 10 MiB at 100 MB/s
        assert!((link.stats.busy_secs() - 4.0 * per).abs() < 1e-6);
        assert!(link.stats.queued_secs() > 0.0, "a 4-deep burst must queue");
        assert!(link.stats.peak_backlog_secs() > 3.0 * per);
        assert_eq!(link.stats.reserves(), 4);
    }

    #[test]
    fn backlog_gauge_is_monotone_across_a_burst_then_drains() {
        let link = vlink(100e6);
        let mut last = link.backlog_secs();
        assert_eq!(last, 0.0);
        // A burst at one virtual instant: each reservation deepens the
        // backlog by exactly its duration.
        for _ in 0..5 {
            link.reserve_at(0.0, 10 << 20, TrafficClass::Demand);
            let b = link.backlog_secs();
            assert!(b > last, "backlog must grow across a burst: {b} vs {last}");
            assert!((b - last - 0.1048576).abs() < 1e-9);
            last = b;
        }
        // Advancing the virtual clock past the horizon drains the gauge
        // deterministically — no wall-clock Instant involved.
        link.reserve_at(1e6, 0, TrafficClass::Demand); // zero-byte noop
        assert_eq!(link.backlog_secs(), last, "noop must not move the anchor");
        // A real reservation far in the virtual future drains the gauge
        // deterministically down to its own (1-byte) duration.
        link.reserve_at(1e6, 1, TrafficClass::Demand);
        assert!(link.backlog_secs() < 1e-7, "horizon long past: gauge drains");
    }

    #[test]
    fn chained_virtual_reservations_are_deterministic() {
        let total: usize = 8 << 20;
        let chunks = 7;
        let run = || {
            let link = vlink(55e9);
            let mut cursor = 0.25;
            for i in 0..chunks {
                let bytes = if i + 1 == chunks { total - (chunks - 1) * (total / chunks) } else { total / chunks };
                cursor = link.reserve_at(cursor, bytes, TrafficClass::H2D).end;
            }
            (cursor, link.stats.busy_secs())
        };
        let (end_a, busy_a) = run();
        let (end_b, busy_b) = run();
        assert_eq!(end_a, end_b, "virtual chains must be bit-identical");
        assert_eq!(busy_a, busy_b);
        let wire = Link::wire_secs(55e9, 0.0, total);
        assert!((end_a - 0.25 - wire).abs() < 1e-9, "chunked sum ≈ single wire time");
    }

    #[test]
    fn traced_reservations_land_on_the_named_track() {
        let link = vlink(100e6);
        let bus = TraceBus::recording();
        link.set_trace(bus.clone(), "link:test0");
        link.reserve_at(0.0, 10 << 20, TrafficClass::H2D);
        link.reserve_at(0.0, 10 << 20, TrafficClass::Demand);
        assert_eq!(bus.len(), 2);
        // zero-byte no-op reservations emit nothing
        link.reserve_at(5.0, 0, TrafficClass::H2D);
        assert_eq!(bus.len(), 2);
        let doc = bus.to_chrome_json();
        assert!(doc.contains("link:test0"), "{doc}");
        assert!(doc.contains("\"name\":\"h2d\""), "{doc}");
        // the demand slot queued behind the h2d slot for its wire time
        assert!(doc.contains("\"queued_secs\":0.104857600"), "{doc}");
        // an un-wired link records nothing; wiring a disabled bus un-wires
        let quiet = vlink(100e6);
        quiet.reserve_at(0.0, 1024, TrafficClass::H2D);
        link.set_trace(TraceBus::disabled(), "link:test0");
        link.reserve_at(9.0, 1024, TrafficClass::H2D);
        assert_eq!(bus.len(), 2);
    }

    #[test]
    fn latency_is_charged_once_per_reservation() {
        let link = Link::new("lat", 100e6, 0.005, LinkClock::Virtual);
        let slot = link.reserve_at(0.0, 10 << 20, TrafficClass::Demand);
        assert!((slot.duration() - (0.005 + 0.1048576)).abs() < 1e-9);
        // Zero bytes: no latency either — wire_secs(_, _, 0) == 0.
        assert_eq!(Link::wire_secs(100e6, 0.005, 0), 0.0);
    }
}
