//! The paper's economic analysis: Eq. 1 / the **ten-day rule** (a
//! five-minute-rule analogue for materialized KV caches), per-access cost
//! comparison, and the Fig-1 trend table.

use super::profiles::{DeviceProfile, StorageProfile, CATALOG_GPUS, CATALOG_SSDS};
use crate::manifest::ModelConfig;

/// Default hardware amortization horizon (both GPU and SSD), seconds.
/// Three years is the conventional datacenter depreciation window.
pub const AMORTIZATION_SECS: f64 = 3.0 * 365.0 * 24.0 * 3600.0;

/// Inputs and result of the break-even analysis for one (GPU, SSD,
/// model, chunk) combination.
#[derive(Debug, Clone)]
pub struct TenDayRule {
    pub gpu: DeviceProfile,
    pub ssd: StorageProfile,
    /// Seconds of GPU time to prefill one chunk.
    pub prefill_secs: f64,
    /// Materialized KV bytes of one chunk.
    pub kv_bytes: usize,
    /// Amortization horizon in seconds.
    pub horizon_secs: f64,
}

impl TenDayRule {
    /// Paper anchor (§II-C): LLaMA-70B, 1,024-token chunk on H100
    /// (500 ms prefill, 250 MB KV) vs a Samsung 9100 Pro.
    pub fn paper_anchor() -> Self {
        TenDayRule {
            gpu: DeviceProfile::h100(),
            ssd: StorageProfile::ssd_9100pro(),
            prefill_secs: 0.5,
            kv_bytes: 250 << 20,
            horizon_secs: AMORTIZATION_SECS,
        }
    }

    /// Build from one of our model configs + measured/simulated prefill time.
    pub fn for_config(
        cfg: &ModelConfig,
        chunk_tokens: usize,
        prefill_secs: f64,
        gpu: DeviceProfile,
        ssd: StorageProfile,
    ) -> Self {
        TenDayRule {
            gpu,
            ssd,
            prefill_secs,
            kv_bytes: cfg.kv_bytes(chunk_tokens),
            horizon_secs: AMORTIZATION_SECS,
        }
    }

    /// Dollar cost of recomputing the chunk's KV once on the GPU
    /// (amortized capital cost of the GPU-seconds used).
    pub fn recompute_cost_usd(&self) -> f64 {
        self.prefill_secs * self.gpu.price_usd / self.horizon_secs
    }

    /// Dollar cost of *holding* the chunk's KV on flash for the horizon.
    pub fn storage_cost_usd(&self) -> f64 {
        self.kv_bytes as f64 * self.ssd.usd_per_byte
    }

    /// Break-even access interval (seconds): if the chunk is retrieved at
    /// least once every T seconds, materializing beats recomputation.
    ///
    /// Derivation (Gray & Putzolu's five-minute-rule argument, Eq. 1 of
    /// the paper): accesses over the horizon = horizon/T; recompute total
    /// = (horizon/T) * recompute_cost; storage total = storage_cost;
    /// equate and solve for T.
    pub fn break_even_secs(&self) -> f64 {
        self.horizon_secs * self.recompute_cost_usd() / self.storage_cost_usd()
    }

    pub fn break_even_days(&self) -> f64 {
        self.break_even_secs() / 86_400.0
    }

    /// Cost ratio at a given access interval (recompute / materialize);
    /// > 1 means MatKV wins. The paper's "100x at one access per hour".
    pub fn cost_ratio_at_interval(&self, interval_secs: f64) -> f64 {
        let accesses = self.horizon_secs / interval_secs;
        accesses * self.recompute_cost_usd() / self.storage_cost_usd()
    }

    /// Latency ratio per retrieval: GPU recompute time / SSD load time.
    pub fn latency_ratio(&self) -> f64 {
        self.prefill_secs / self.ssd.read_secs(self.kv_bytes)
    }
}

/// Convenience wrapper: break-even interval in seconds.
pub fn break_even_interval_secs(rule: &TenDayRule) -> f64 {
    rule.break_even_secs()
}

/// One computed row of the Fig-1 trend (value metrics per dollar).
#[derive(Debug, Clone)]
pub struct TrendRow {
    pub year: u32,
    pub gpu: &'static str,
    pub gpu_tflops_per_kusd: f64,
    pub ssd: &'static str,
    pub ssd_gbps_per_kusd_tb: f64,
    pub ssd_gb_per_usd: f64,
}

/// Regenerate the Fig-1 series from the hardware catalog.
pub fn fig1_trend() -> Vec<TrendRow> {
    CATALOG_GPUS
        .iter()
        .zip(CATALOG_SSDS)
        .map(|(g, s)| TrendRow {
            year: g.year,
            gpu: g.name,
            gpu_tflops_per_kusd: g.tflops_f16 / (g.price_usd / 1e3),
            ssd: s.name,
            ssd_gbps_per_kusd_tb: s.read_gbps / s.usd_per_gb,
            ssd_gb_per_usd: 1.0 / s.usd_per_gb,
        })
        .collect()
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn paper_anchor_is_about_ten_days() {
        // §II-C: "storing KV caches in SSDs is more cost-effective than GPU
        // recomputation if a given document is accessed at least once every
        // 10 days"
        let days = TenDayRule::paper_anchor().break_even_days();
        assert!((5.0..20.0).contains(&days), "break-even {days} days");
    }

    #[test]
    fn hourly_access_is_orders_of_magnitude_cheaper() {
        // §II-C: "retrieved once per hour, MatKV is 100x more cost-efficient"
        let r = TenDayRule::paper_anchor().cost_ratio_at_interval(3600.0);
        assert!(r > 50.0, "cost ratio {r}");
    }

    #[test]
    fn latency_ratio_at_least_2x() {
        // §II-C: 500ms recompute vs <20ms load → well above the paper's 2x
        // end-to-end claim (decode dominates end-to-end).
        let r = TenDayRule::paper_anchor().latency_ratio();
        assert!(r > 10.0, "latency ratio {r}");
    }

    #[test]
    fn rarely_accessed_chunks_favor_recompute() {
        let rule = TenDayRule::paper_anchor();
        // accessed once a year → materialization loses
        assert!(rule.cost_ratio_at_interval(365.0 * 86400.0) < 1.0);
        // accessed daily → materialization wins
        assert!(rule.cost_ratio_at_interval(86400.0) > 1.0);
    }

    #[test]
    fn fig1_trend_ssd_value_outpaces_gpu() {
        let rows = fig1_trend();
        let first = rows.first().unwrap();
        let last = rows.last().unwrap();
        let gpu_gain = last.gpu_tflops_per_kusd / first.gpu_tflops_per_kusd;
        let ssd_gain = last.ssd_gb_per_usd / first.ssd_gb_per_usd;
        assert!(ssd_gain > gpu_gain, "ssd {ssd_gain} vs gpu {gpu_gain}");
    }
}
