//! Roofline phase-time model: converts a phase's FLOPs and byte movement
//! into simulated time on a [`DeviceProfile`].

use super::profiles::DeviceProfile;
use crate::manifest::ModelConfig;

/// Cost of one executed phase (an append call, a KV load, ...).
#[derive(Debug, Clone, Copy, Default, PartialEq)]
pub struct PhaseCost {
    /// Floating-point operations performed on the device.
    pub flops: f64,
    /// Bytes streamed through device memory (weights + KV + activations).
    pub hbm_bytes: f64,
    /// Bytes crossing host<->device (KV uploads, logits downloads).
    pub pcie_bytes: f64,
}

impl PhaseCost {
    /// Simulated execution time on `dev` for prefill-class work (large
    /// fused ops — use the prefill bandwidth utilization): roofline max
    /// of compute, memory and interconnect times.
    pub fn secs_on(&self, dev: &DeviceProfile) -> f64 {
        self.secs_with(dev, dev.prefill_membw_util)
    }

    /// Simulated execution time for decode-class work (one token per
    /// invocation; bandwidth utilization calibrated to the paper's stack).
    pub fn secs_on_decode(&self, dev: &DeviceProfile) -> f64 {
        self.secs_with(dev, dev.membw_util)
    }

    fn secs_with(&self, dev: &DeviceProfile, membw_util: f64) -> f64 {
        let t_flops = self.flops / (dev.peak_flops * dev.mfu);
        let t_mem = self.hbm_bytes / (dev.hbm_bw * membw_util);
        let t_pcie = self.pcie_bytes / dev.pcie_bw;
        t_flops.max(t_mem).max(t_pcie)
    }

    pub fn add(&mut self, other: PhaseCost) {
        self.flops += other.flops;
        self.hbm_bytes += other.hbm_bytes;
        self.pcie_bytes += other.pcie_bytes;
    }
}

/// Cost of one `append` entry invocation (B elements, S live tokens each,
/// ctx live cache slots) — the prefill/sub-prefill/decode building block.
pub fn append_cost(cfg: &ModelConfig, batch: usize, s_live: usize, ctx_live: usize) -> PhaseCost {
    let param_bytes = (cfg.param_count * 4) as f64;
    let kv_touched = (batch * ctx_live * cfg.kv_bytes_per_token) as f64;
    let act_bytes = (batch * s_live * cfg.d_model * 4 * 8) as f64; // rough activations
    PhaseCost {
        flops: batch as f64 * cfg.append_flops(s_live, ctx_live),
        hbm_bytes: param_bytes + kv_touched + act_bytes,
        pcie_bytes: 0.0,
    }
}

/// Cost of uploading loaded KV bytes into device memory.
pub fn kv_upload_cost(bytes: usize) -> PhaseCost {
    PhaseCost { flops: 0.0, hbm_bytes: bytes as f64, pcie_bytes: bytes as f64 }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::hwsim::profiles::DeviceProfile;
    use crate::manifest::Manifest;

    fn base() -> ModelConfig {
        // Roofline math only needs config dims — golden metadata
        // suffices when the real artifacts aren't built.
        Manifest::load_or_golden().unwrap().config("base").unwrap().clone()
    }

    #[test]
    fn prefill_is_compute_bound_decode_is_memory_bound() {
        let cfg = base();
        let h100 = DeviceProfile::h100();
        let prefill = append_cost(&cfg, 1, 1024, 1024);
        let decode = append_cost(&cfg, 1, 1, 2048);
        // prefill: flops term dominates (at prefill-class bandwidth)
        assert!(
            prefill.flops / (h100.peak_flops * h100.mfu)
                > prefill.hbm_bytes / (h100.hbm_bw * h100.prefill_membw_util)
        );
        // decode: memory term dominates
        assert!(
            decode.hbm_bytes / (h100.hbm_bw * h100.membw_util)
                > decode.flops / (h100.peak_flops * h100.mfu)
        );
    }

    #[test]
    fn h100_beats_4090_more_at_prefill_than_decode() {
        // Fig 10's premise: decode is much less sensitive to GPU class.
        let cfg = base();
        let h100 = DeviceProfile::h100();
        let r4090 = DeviceProfile::rtx4090();
        let prefill = append_cost(&cfg, 1, 1024, 1024);
        let decode = append_cost(&cfg, 1, 1, 2048);
        let prefill_ratio = prefill.secs_on(&r4090) / prefill.secs_on(&h100);
        let decode_ratio = decode.secs_on(&r4090) / decode.secs_on(&h100);
        assert!(prefill_ratio > decode_ratio, "{prefill_ratio} {decode_ratio}");
    }

    #[test]
    fn cost_add_accumulates() {
        let mut a = PhaseCost { flops: 1.0, hbm_bytes: 2.0, pcie_bytes: 3.0 };
        a.add(PhaseCost { flops: 10.0, hbm_bytes: 20.0, pcie_bytes: 30.0 });
        assert_eq!(a, PhaseCost { flops: 11.0, hbm_bytes: 22.0, pcie_bytes: 33.0 });
    }
}
