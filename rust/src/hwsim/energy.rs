//! Energy integration (Tables IV & V): power × phase-time accounting over
//! the simulated H100 server.
//!
//! The paper measures whole-server draw via IPMI and GPU draw via
//! nvidia-smi while the workload runs. We reproduce the same integrals by
//! attributing each pipeline phase to the components it keeps active:
//! system idle floor + GPU delta when computing + SSD delta when reading,
//! with overlapped phases charging both simultaneously (which is why
//! overlapped MatKV shows *higher peak* but *lower total* — Table IV).

use super::profiles::{DeviceProfile, StorageProfile};

/// What a span of wall-time was spent doing.
#[derive(Debug, Clone, Copy, PartialEq, Eq, Hash)]
pub enum PhaseKind {
    /// GPU busy (prefill or decode compute).
    GpuCompute,
    /// Storage busy (KV load/store), GPU idle.
    StorageIo,
    /// GPU decode overlapped with storage prefetch (MatKV w/ overlap).
    Overlapped,
    /// Neither busy (queueing, host work).
    HostIdle,
}

/// One recorded phase.
#[derive(Debug, Clone, Copy)]
pub struct Phase {
    pub kind: PhaseKind,
    pub secs: f64,
}

/// Accumulates phases and integrates energy for a server configuration.
#[derive(Debug, Clone)]
pub struct EnergyMeter {
    /// Whole-server idle floor, watts (paper: 550W for the H100 box).
    pub system_idle_w: f64,
    pub gpu: DeviceProfile,
    pub storage: StorageProfile,
    phases: Vec<Phase>,
}

/// Summary mirroring the columns of Tables IV/V.
#[derive(Debug, Clone, Copy)]
pub struct EnergyReport {
    pub peak_w: f64,
    pub avg_w: f64,
    pub time_s: f64,
    pub total_kj: f64,
}

impl EnergyMeter {
    pub fn h100_server(storage: StorageProfile) -> Self {
        Self::server_for(DeviceProfile::h100(), storage)
    }

    /// Meter for a server anchored by `gpu`: the idle floor comes from
    /// the profile's `host_idle_w` (550 W for the paper's H100 box,
    /// desktop-class for a 4090). The fleet simulator builds one of
    /// these per worker so each box integrates its own draw.
    pub fn server_for(gpu: DeviceProfile, storage: StorageProfile) -> Self {
        EnergyMeter { system_idle_w: gpu.host_idle_w, gpu, storage, phases: Vec::new() }
    }

    pub fn new(system_idle_w: f64, gpu: DeviceProfile, storage: StorageProfile) -> Self {
        EnergyMeter { system_idle_w, gpu, storage, phases: Vec::new() }
    }

    pub fn record(&mut self, kind: PhaseKind, secs: f64) {
        if secs > 0.0 {
            self.phases.push(Phase { kind, secs });
        }
    }

    /// Instantaneous whole-server draw during a phase kind.
    fn system_watts(&self, kind: PhaseKind) -> f64 {
        let gpu_delta = self.gpu.power_active - self.gpu.power_idle;
        let ssd_delta = self.storage.power_active - self.storage.power_idle;
        match kind {
            PhaseKind::GpuCompute => self.system_idle_w + gpu_delta,
            PhaseKind::StorageIo => self.system_idle_w + ssd_delta,
            PhaseKind::Overlapped => self.system_idle_w + gpu_delta + ssd_delta,
            PhaseKind::HostIdle => self.system_idle_w,
        }
    }

    /// GPU-only draw during a phase kind (Table V).
    fn gpu_watts(&self, kind: PhaseKind) -> f64 {
        match kind {
            PhaseKind::GpuCompute | PhaseKind::Overlapped => self.gpu.power_active,
            _ => self.gpu.power_idle,
        }
    }

    fn report(&self, watts_of: impl Fn(PhaseKind) -> f64) -> EnergyReport {
        let mut peak = 0f64;
        let mut joules = 0f64;
        let mut time = 0f64;
        for p in &self.phases {
            let w = watts_of(p.kind);
            peak = peak.max(w);
            joules += w * p.secs;
            time += p.secs;
        }
        EnergyReport {
            peak_w: peak,
            avg_w: if time > 0.0 { joules / time } else { 0.0 },
            time_s: time,
            total_kj: joules / 1e3,
        }
    }

    /// Whole-server report (Table IV).
    pub fn system_report(&self) -> EnergyReport {
        self.report(|k| self.system_watts(k))
    }

    /// GPU-only report (Table V).
    pub fn gpu_report(&self) -> EnergyReport {
        self.report(|k| self.gpu_watts(k))
    }

    pub fn reset(&mut self) {
        self.phases.clear();
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn meter() -> EnergyMeter {
        EnergyMeter::h100_server(StorageProfile::raid0_4x9100())
    }

    #[test]
    fn overlap_saves_energy_vs_serial() {
        // Same work split: 10s GPU + 4s SSD. Serial = 14s; overlapped = 10s
        // (IO hidden under compute). Overlap must consume fewer joules.
        let mut serial = meter();
        serial.record(PhaseKind::GpuCompute, 10.0);
        serial.record(PhaseKind::StorageIo, 4.0);
        let mut overlap = meter();
        overlap.record(PhaseKind::Overlapped, 4.0);
        overlap.record(PhaseKind::GpuCompute, 6.0);
        let s = serial.system_report();
        let o = overlap.system_report();
        assert!(o.total_kj < s.total_kj, "{o:?} {s:?}");
        assert!(o.time_s < s.time_s);
        // ... at a higher instantaneous peak (Table IV shape)
        assert!(o.peak_w > s.peak_w);
    }

    #[test]
    fn gpu_report_ignores_storage_phases() {
        let mut m = meter();
        m.record(PhaseKind::StorageIo, 100.0);
        let g = m.gpu_report();
        assert_eq!(g.peak_w, m.gpu.power_idle);
    }

    #[test]
    fn empty_meter_is_zero() {
        let m = meter();
        let r = m.system_report();
        assert_eq!(r.time_s, 0.0);
        assert_eq!(r.total_kj, 0.0);
    }

    #[test]
    fn server_for_uses_the_profile_idle_floor() {
        let h100 = EnergyMeter::server_for(DeviceProfile::h100(), StorageProfile::ssd_pm9a3());
        assert_eq!(h100.system_idle_w, DeviceProfile::h100().host_idle_w);
        // a 4090 box: same work, far fewer joules at idle and at load —
        // the arithmetic the fleet's tokens-per-joule claim rests on
        let mut desktop =
            EnergyMeter::server_for(DeviceProfile::rtx4090(), StorageProfile::ssd_pm9a3());
        let mut server = EnergyMeter::h100_server(StorageProfile::ssd_pm9a3());
        for m in [&mut desktop, &mut server] {
            m.record(PhaseKind::GpuCompute, 2.0);
            m.record(PhaseKind::HostIdle, 1.0);
        }
        assert!(desktop.system_report().total_kj < server.system_report().total_kj);
    }

    #[test]
    fn integral_matches_hand_computation() {
        let mut m = meter();
        m.record(PhaseKind::GpuCompute, 2.0);
        m.record(PhaseKind::HostIdle, 1.0);
        let r = m.system_report();
        let expect = (550.0 + 300.0) * 2.0 + 550.0 * 1.0;
        assert!((r.total_kj * 1e3 - expect).abs() < 1e-9);
        assert_eq!(r.time_s, 3.0);
    }
}
