//! Calibrated device/storage profiles and the Fig-1 hardware catalog.
//!
//! Sources: the paper's §II-C numbers (H100 $50K / 350W cap / ~500 ms to
//! prefill 1,024 tokens of LLaMA-70B; Samsung 9100 Pro $400/4TB, 14.7
//! GB/s, 7W active) plus public spec sheets for the catalog trend.

/// A GPU-class compute device for the roofline model.
#[derive(Debug, Clone, PartialEq)]
pub struct DeviceProfile {
    pub name: String,
    /// Peak dense f16/bf16 FLOP/s.
    pub peak_flops: f64,
    /// HBM bandwidth, bytes/s.
    pub hbm_bw: f64,
    /// Host<->device interconnect bandwidth, bytes/s (PCIe for both GPUs).
    pub pcie_bw: f64,
    /// Achievable fraction of peak FLOPs in prefill-like GEMMs (MFU).
    pub mfu: f64,
    /// Achievable HBM fraction during prefill (large fused ops).
    pub prefill_membw_util: f64,
    /// Achievable HBM fraction during decode (launch-latency-bound in the
    /// paper's HF-transformers stack — calibrated from Table IV).
    pub membw_util: f64,
    /// Active power draw at full load, watts.
    pub power_active: f64,
    /// Idle power draw, watts.
    pub power_idle: f64,
    /// Whole-server idle floor of the box this device anchors, watts
    /// (the H100 lives in a dual-socket server — paper: 550 W — while a
    /// 4090 sits in a desktop-class chassis). Drives the per-worker
    /// [`super::EnergyMeter`]s of the fleet simulator.
    pub host_idle_w: f64,
    /// Device memory capacity, bytes. Bounds the fleet workers'
    /// device-resident KV model: weights stay pinned, the remainder
    /// holds loaded KV chunks.
    pub hbm_bytes: f64,
    /// Street price, dollars.
    pub price_usd: f64,
}

/// A storage device (or tier) for KV materialization.
#[derive(Debug, Clone, PartialEq)]
pub struct StorageProfile {
    pub name: String,
    /// Sequential read bandwidth, bytes/s. `f64::INFINITY` = unthrottled
    /// (the DRAM tier of Table III).
    pub read_bw: f64,
    /// Sequential write bandwidth, bytes/s.
    pub write_bw: f64,
    /// Per-request base latency, seconds.
    pub latency_s: f64,
    /// Active power, watts.
    pub power_active: f64,
    /// Idle power, watts.
    pub power_idle: f64,
    /// Price per byte, dollars.
    pub usd_per_byte: f64,
}

impl DeviceProfile {
    /// Calibrated to the paper's measured HF-transformers stack, not the
    /// theoretical card: §II-C's anchor (1,024-token 70B prefill in 500 ms)
    /// implies mfu = 2*70e9*1024 / (989e12 * 0.5s) ≈ 0.29. Decode is the
    /// roofline here plus a per-ELEMENT software overhead that lives in
    /// `ArchSpec::decode_elem_overhead_s` (reconciling Fig 5's 65 ms/step
    /// at batch 1 with Table IV's ~450 ms/step at batch 8). Using the
    /// measured stack keeps every prefill/decode share, crossover and
    /// overlap benefit at the paper's proportions.
    pub fn h100() -> Self {
        DeviceProfile {
            name: "H100".into(),
            peak_flops: 989e12, // dense bf16, no sparsity
            hbm_bw: 3.35e12,
            pcie_bw: 55e9, // PCIe gen5 x16 measured
            mfu: 0.29,     // paper anchor: 500 ms / 1,024 tokens of 70B
            prefill_membw_util: 0.55,
            membw_util: 0.7, // weight streaming; per-element software
                             // overhead lives in ArchSpec (calibration note
                             // there reconciles Fig 5 with Table IV)
            power_active: 350.0, // paper: power cap reached in all configs
            power_idle: 50.0,
            host_idle_w: 550.0, // paper: the H100 server's IPMI idle floor
            hbm_bytes: 80e9,
            price_usd: 50_000.0,
        }
    }

    /// Same HF-transformers-stack calibration as [`DeviceProfile::h100`];
    /// the paper's Fig 10 premise — decode barely slower on the low-end
    /// card — emerges because decode is dominated by per-element software
    /// overhead plus weight streaming, where the 4090 is only ~2.7x
    /// behind (0.6 TB/s effective vs 2.3 TB/s), vs ~7x behind at prefill.
    pub fn rtx4090() -> Self {
        DeviceProfile {
            name: "RTX4090".into(),
            peak_flops: 165e12, // dense fp16 tensor
            hbm_bw: 1.01e12,
            pcie_bw: 25e9, // PCIe gen4 x16
            mfu: 0.25,
            prefill_membw_util: 0.5,
            membw_util: 0.6,
            power_active: 320.0,
            power_idle: 20.0,
            host_idle_w: 120.0, // desktop-class chassis (the Fig-10 box)
            hbm_bytes: 24e9,
            price_usd: 1_600.0,
        }
    }

    /// The CPU host running PJRT in this testbed (used when reporting
    /// measured wall-clock next to simulated device time).
    pub fn cpu_host() -> Self {
        DeviceProfile {
            name: "cpu-host".into(),
            peak_flops: 1.0e12,
            hbm_bw: 40e9,
            pcie_bw: 40e9,
            mfu: 0.3,
            prefill_membw_util: 0.5,
            membw_util: 0.5,
            power_active: 180.0,
            power_idle: 90.0,
            host_idle_w: 150.0,
            hbm_bytes: 64e9,
            price_usd: 5_000.0,
        }
    }
}

impl StorageProfile {
    /// Samsung 9100 Pro (PCIe 5.0, 4TB): the paper's headline SSD.
    pub fn ssd_9100pro() -> Self {
        StorageProfile {
            name: "9100Pro".into(),
            read_bw: 14.7e9,
            write_bw: 13.3e9,
            latency_s: 60e-6,
            power_active: 7.0,
            power_idle: 0.5,
            usd_per_byte: 400.0 / 4e12, // $0.1/GB
        }
    }

    /// Four 9100 Pros in software RAID-0 (paper's H100 server config).
    pub fn raid0_4x9100() -> Self {
        StorageProfile {
            name: "RAID0-4x9100".into(),
            // paper quotes 58.8 GB/s theoretical; their measured Table III
            // load times correspond to ~30 GB/s effective — we use measured.
            read_bw: 30e9,
            write_bw: 26e9,
            latency_s: 80e-6,
            power_active: 30.0,
            power_idle: 2.0,
            usd_per_byte: 1600.0 / 16e12,
        }
    }

    /// Samsung PM9A3 (the RTX 4090 box in Fig 10).
    pub fn ssd_pm9a3() -> Self {
        StorageProfile {
            name: "PM9A3".into(),
            read_bw: 6.5e9,
            write_bw: 3.5e9,
            latency_s: 90e-6,
            power_active: 8.0,
            power_idle: 1.0,
            usd_per_byte: 250.0 / 1e12,
        }
    }

    /// DRAM tier of Table III (KVs preloaded in page cache; only the
    /// aio copy to the device remains).
    pub fn dram() -> Self {
        StorageProfile {
            name: "DRAM".into(),
            read_bw: f64::INFINITY,
            write_bw: f64::INFINITY,
            latency_s: 5e-6,
            power_active: 90.0,
            power_idle: 90.0,
            usd_per_byte: 2000.0 / 256e9, // server DDR5 $/byte
        }
    }

    /// Seconds to read `bytes` from this tier.
    pub fn read_secs(&self, bytes: usize) -> f64 {
        self.read_secs_batch(bytes as f64, 1)
    }

    /// Seconds to service `reads` read requests totalling `bytes` bytes
    /// (per-request latency paid once per read, bandwidth shared). Used
    /// by the serve-path costing, where hot-tier hits reduce `reads`.
    pub fn read_secs_batch(&self, bytes: f64, reads: usize) -> f64 {
        self.latency_s * reads as f64
            + if self.read_bw.is_finite() { bytes / self.read_bw } else { 0.0 }
    }

    /// Seconds to write `bytes` to this tier.
    pub fn write_secs(&self, bytes: usize) -> f64 {
        self.latency_s + if self.write_bw.is_finite() { bytes as f64 / self.write_bw } else { 0.0 }
    }
}

/// Modeled host-side throughput of the q8 → f32 dequantization pass the
/// warm tier pays on every hit, in **q8 payload bytes per second**.
///
/// Dequant is one scale-multiply per element over data that just came
/// out of DRAM — memory-bound, not compute-bound — so the model is a
/// single effective-bandwidth constant: roughly half of one server DDR5
/// channel's ~50 GB/s stream rate, accounting for the read-q8 +
/// write-f32 traffic (1 byte in, 4 bytes out per element, amortized
/// against the streamed read that dominates). The point of the model is
/// the *ordering* it preserves: a warm hit (dequant at tens of GB/s) is
/// far cheaper than a flash read (14.7 GB/s on the headline SSD plus
/// per-request latency) and far dearer than a hot hit (free) — exactly
/// the three-rung hierarchy the warm tier buys.
pub const Q8_DEQUANT_BYTES_PER_SEC: f64 = 24e9;

/// Modeled seconds to dequantize `q8_bytes` of warm-tier payload back to
/// f32 (see [`Q8_DEQUANT_BYTES_PER_SEC`]).
pub fn q8_dequant_secs(q8_bytes: f64) -> f64 {
    q8_bytes / Q8_DEQUANT_BYTES_PER_SEC
}

/// Modeled host-side throughput of the f32 → q8 quantization pass paid
/// when a chunk *enters* the warm tier (demote-on-evict, a direct q8
/// admission, or a prefetch parked there), in q8 payload bytes/second.
///
/// Quantization is the mirror image of the dequant pass — one
/// scale-multiply per element over streamed planes, with the wide side
/// of the traffic (4 f32 bytes per element) on the read instead of the
/// write — so it is memory-bound at the same effective bandwidth and
/// shares the dequant constant. Demotion and promotion therefore charge
/// **symmetrically** in simulated time, which keeps the warm tier's
/// modeled round trip (quantize in, dequantize out) honest instead of
/// letting demotions look free.
pub const Q8_QUANT_BYTES_PER_SEC: f64 = Q8_DEQUANT_BYTES_PER_SEC;

/// Modeled seconds to quantize a chunk whose q8 payload is `q8_bytes`
/// (see [`Q8_QUANT_BYTES_PER_SEC`]).
pub fn q8_quant_secs(q8_bytes: f64) -> f64 {
    q8_bytes / Q8_QUANT_BYTES_PER_SEC
}

/// Modeled host-side throughput of the q4 → f32 dequantization pass the
/// cool paths pay (a v4 flash load, or a warm hit in `--warm-mode q4`),
/// in **q4 payload bytes per second**.
///
/// Still memory-bound, but each packed byte now expands to *two*
/// elements (nibble unpack + sign-extend + scale-multiply each, 8 f32
/// output bytes per input byte), so the effective input-byte bandwidth
/// sits below the q8 constant: per *element* the two codecs are
/// comparable, per *payload byte* q4 does twice the work. The ordering
/// the model must preserve is unchanged — dequant is far cheaper than
/// the flash read it replaces bytes of, and far dearer than a hot hit —
/// which is exactly the trade the v4 format prices: half the device
/// bytes of v2/v3, bought with this pass on every load.
pub const Q4_DEQUANT_BYTES_PER_SEC: f64 = 16e9;

/// Modeled seconds to dequantize `q4_bytes` of packed q4 payload back to
/// f32 (see [`Q4_DEQUANT_BYTES_PER_SEC`]).
pub fn q4_dequant_secs(q4_bytes: f64) -> f64 {
    q4_bytes / Q4_DEQUANT_BYTES_PER_SEC
}

/// Modeled host-side throughput of the f32 → q4 quantization pass paid
/// when a chunk is packed for a cool path (a v4 flash write, or entry
/// into a q4-mode warm tier), in q4 payload bytes/second. Symmetric
/// with [`Q4_DEQUANT_BYTES_PER_SEC`] for the same reason the q8 pair is
/// symmetric: the mirrored pass streams the same bytes the other way.
pub const Q4_QUANT_BYTES_PER_SEC: f64 = Q4_DEQUANT_BYTES_PER_SEC;

/// Modeled seconds to quantize a chunk whose q4 payload is `q4_bytes`
/// (see [`Q4_QUANT_BYTES_PER_SEC`]).
pub fn q4_quant_secs(q4_bytes: f64) -> f64 {
    q4_bytes / Q4_QUANT_BYTES_PER_SEC
}

/// One row of a GPU catalog: the Fig-1 cost/performance trend
/// ([`CATALOG_GPUS`]) and the serving simulator's device menu
/// ([`SERVING_GPUS`]) share this shape.
#[derive(Debug, Clone)]
pub struct GpuCatalogRow {
    pub year: u32,
    pub name: &'static str,
    pub tflops_f16: f64,
    pub price_usd: f64,
    pub tdp_w: f64,
}

impl GpuCatalogRow {
    /// The calibrated [`DeviceProfile`] for this row, when the serving
    /// simulator has one. `None` for trend-only rows (V100/A100/H200):
    /// they have no measured-stack calibration to run a fleet on.
    pub fn device_profile(&self) -> Option<DeviceProfile> {
        match self.name {
            "H100" => Some(DeviceProfile::h100()),
            "RTX4090" => Some(DeviceProfile::rtx4090()),
            _ => None,
        }
    }
}

/// The serving simulator's device menu: every GPU class a fleet worker
/// can wrap, with the *paper-config* price/power (the trend catalog
/// above carries launch specs instead — the H100 rows differ on
/// purpose). `fig10_gpu_class`, the fleet spec parser and the CLI all
/// resolve device names here, so there is exactly one place a GPU class
/// is defined; a unit test pins each row to its calibrated profile so
/// the two can never drift apart.
pub const SERVING_GPUS: &[GpuCatalogRow] = &[
    GpuCatalogRow { year: 2022, name: "H100", tflops_f16: 989.0, price_usd: 50_000.0, tdp_w: 350.0 },
    GpuCatalogRow { year: 2022, name: "RTX4090", tflops_f16: 165.0, price_usd: 1_600.0, tdp_w: 320.0 },
];

/// Look up a serving-catalog row by (case-insensitive) device name.
pub fn gpu_by_name(name: &str) -> Option<&'static GpuCatalogRow> {
    SERVING_GPUS.iter().find(|r| r.name.eq_ignore_ascii_case(name))
}

/// The calibrated serving profile for a device name, via the catalog
/// (the one constructor fleet specs and benches share).
pub fn serving_profile(name: &str) -> Option<DeviceProfile> {
    gpu_by_name(name).and_then(GpuCatalogRow::device_profile)
}

/// GPU generations 2017-2024 (dense f16 TFLOPs, launch street price).
pub const CATALOG_GPUS: &[GpuCatalogRow] = &[
    GpuCatalogRow { year: 2017, name: "V100", tflops_f16: 125.0, price_usd: 10_000.0, tdp_w: 300.0 },
    GpuCatalogRow { year: 2020, name: "A100", tflops_f16: 312.0, price_usd: 12_500.0, tdp_w: 400.0 },
    GpuCatalogRow { year: 2022, name: "H100", tflops_f16: 989.0, price_usd: 30_000.0, tdp_w: 700.0 },
    GpuCatalogRow { year: 2024, name: "H200", tflops_f16: 989.0, price_usd: 35_000.0, tdp_w: 700.0 },
];

/// One row of the SSD side of Fig 1.
#[derive(Debug, Clone)]
pub struct SsdCatalogRow {
    pub year: u32,
    pub name: &'static str,
    pub read_gbps: f64,
    pub usd_per_gb: f64,
    pub active_w: f64,
}

/// Consumer NVMe generations 2017-2024.
pub const CATALOG_SSDS: &[SsdCatalogRow] = &[
    SsdCatalogRow { year: 2017, name: "960Pro", read_gbps: 3.5, usd_per_gb: 0.62, active_w: 5.3 },
    SsdCatalogRow { year: 2020, name: "980Pro", read_gbps: 7.0, usd_per_gb: 0.23, active_w: 6.2 },
    SsdCatalogRow { year: 2022, name: "990Pro", read_gbps: 7.45, usd_per_gb: 0.17, active_w: 6.5 },
    SsdCatalogRow { year: 2024, name: "9100Pro", read_gbps: 14.7, usd_per_gb: 0.10, active_w: 7.0 },
];

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn paper_anchor_9100pro_read_250mb_under_20ms() {
        // §II-C: "a commodity SSD ... can read the same 250MB KV cache in
        // under 20 milliseconds"
        let t = StorageProfile::ssd_9100pro().read_secs(250 << 20);
        assert!(t < 0.020, "got {t}");
    }

    #[test]
    fn dram_faster_than_raid_faster_than_single() {
        let b = 250 << 20;
        let dram = StorageProfile::dram().read_secs(b);
        let raid = StorageProfile::raid0_4x9100().read_secs(b);
        let single = StorageProfile::ssd_9100pro().read_secs(b);
        assert!(dram < raid && raid < single, "{dram} {raid} {single}");
    }

    #[test]
    fn catalog_trends_match_paper_claims() {
        // §II-C: SSD bandwidth up ~30x... (paper exaggerates; our catalog
        // shows >4x 2017->2024 bandwidth and >6x $/GB improvement) while
        // GPU flops/$ improves more slowly than SSD bytes/$.
        let g0 = &CATALOG_GPUS[0];
        let g1 = CATALOG_GPUS.last().unwrap();
        let s0 = &CATALOG_SSDS[0];
        let s1 = CATALOG_SSDS.last().unwrap();
        let gpu_value_gain = (g1.tflops_f16 / g1.price_usd) / (g0.tflops_f16 / g0.price_usd);
        let ssd_value_gain = s0.usd_per_gb / s1.usd_per_gb;
        assert!(ssd_value_gain > gpu_value_gain, "{ssd_value_gain} <= {gpu_value_gain}");
    }

    #[test]
    fn infinite_bw_tier_is_latency_only() {
        let d = StorageProfile::dram();
        assert_eq!(d.read_secs(1 << 30), d.latency_s);
    }

    #[test]
    fn dequant_sits_between_hot_and_flash() {
        // The hierarchy ordering the warm tier relies on: serving a chunk
        // by dequantizing its q8 copy must beat re-reading it from flash
        // (q8 is a quarter of the f32 bytes AND moves at DRAM-class
        // speed), while remaining nonzero (warm hits are not free).
        let f32_bytes = 8 << 20; // one decoded chunk
        let q8 = q8_dequant_secs(f32_bytes as f64 / 4.0);
        let flash = StorageProfile::ssd_9100pro().read_secs(f32_bytes / 2); // f16 file
        assert!(q8 > 0.0);
        assert!(q8 < flash, "dequant {q8} must undercut the flash read {flash}");
    }

    #[test]
    fn quant_charges_symmetrically_to_dequant() {
        // The warm tier's modeled round trip: parking a chunk (quantize)
        // costs exactly what serving it back (dequantize) does — and
        // both stay far cheaper than the flash read they stand in for.
        let q8_bytes = 2e6;
        assert_eq!(q8_quant_secs(q8_bytes), q8_dequant_secs(q8_bytes));
        assert!(q8_quant_secs(q8_bytes) > 0.0);
        let flash = StorageProfile::ssd_9100pro().read_secs(4 * q8_bytes as usize / 2);
        assert!(q8_quant_secs(q8_bytes) < flash);
    }

    #[test]
    fn q4_dequant_sits_between_hot_and_flash() {
        // The cool-path ordering: serving a chunk by unpacking its q4
        // copy must beat re-reading even the *halved* v4 file from
        // flash, while remaining nonzero (the trade is priced).
        let f32_bytes = 8 << 20; // one decoded chunk
        let q4 = q4_dequant_secs(f32_bytes as f64 / 8.0);
        let v4_flash = StorageProfile::ssd_9100pro().read_secs(f32_bytes / 8); // q4 file
        assert!(q4 > 0.0);
        assert!(q4 < v4_flash, "q4 dequant {q4} must undercut the v4 flash read {v4_flash}");
        // and per payload byte q4 is the slower pass (two elements per byte)
        assert!(Q4_DEQUANT_BYTES_PER_SEC < Q8_DEQUANT_BYTES_PER_SEC);
    }

    #[test]
    fn q4_quant_charges_symmetrically_to_dequant() {
        let q4_bytes = 1e6;
        assert_eq!(q4_quant_secs(q4_bytes), q4_dequant_secs(q4_bytes));
        assert!(q4_quant_secs(q4_bytes) > 0.0);
        let flash = StorageProfile::ssd_9100pro().read_secs(8 * q4_bytes as usize / 2);
        assert!(q4_quant_secs(q4_bytes) < flash);
    }

    #[test]
    fn serving_catalog_resolves_calibrated_profiles() {
        // Case-insensitive name → catalog row → calibrated profile; the
        // row's price/power must match the profile bit-for-bit so the
        // catalog can never drift from the calibration it names.
        for row in SERVING_GPUS {
            let p = row.device_profile().expect("every serving row has a profile");
            assert_eq!(p.name, row.name);
            assert_eq!(p.price_usd, row.price_usd, "{} price drifted", row.name);
            assert_eq!(p.power_active, row.tdp_w, "{} power drifted", row.name);
            assert_eq!(p.peak_flops, row.tflops_f16 * 1e12, "{} flops drifted", row.name);
        }
        assert_eq!(serving_profile("h100").unwrap(), DeviceProfile::h100());
        assert_eq!(serving_profile("RTX4090").unwrap(), DeviceProfile::rtx4090());
        assert_eq!(serving_profile("rtx4090").unwrap().name, "RTX4090");
        assert!(serving_profile("TPUv9").is_none());
        // trend-only rows exist in the Fig-1 catalog but not the menu
        assert!(gpu_by_name("V100").is_none());
    }

    #[test]
    fn host_idle_floors_follow_server_class() {
        // The fleet's energy story rests on this ordering: the H100 box
        // idles at server-class wattage, the 4090 at desktop-class.
        let h = DeviceProfile::h100();
        let r = DeviceProfile::rtx4090();
        assert!(h.host_idle_w > 3.0 * r.host_idle_w, "{} vs {}", h.host_idle_w, r.host_idle_w);
        assert!(h.hbm_bytes > r.hbm_bytes);
    }

    #[test]
    fn batched_reads_pay_latency_per_request() {
        let s = StorageProfile::ssd_9100pro();
        let one = s.read_secs_batch(1e9, 1);
        let four = s.read_secs_batch(1e9, 4);
        assert!((four - one - 3.0 * s.latency_s).abs() < 1e-12);
        assert_eq!(s.read_secs_batch(0.0, 0), 0.0);
    }
}
