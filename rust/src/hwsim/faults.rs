//! Deterministic fault injection: a seeded, virtual-clock schedule of
//! failures the recovery machinery is graded against.
//!
//! MatKV trades GPU recompute for a dependency on storage and
//! interconnect staying healthy. A [`FaultPlan`] makes that dependency
//! testable: it is a *plan*, not a random process — every injected
//! event is pinned to a deterministic coordinate, so the same plan
//! against the same trace replays bit-for-bit (mirroring the fleet's
//! virtual-clock determinism guarantees):
//!
//! * **Shard events** key on the shard's *read sequence number* — the
//!   flash shards run on wall-clock sleep links, so "the 6th read on
//!   shard 0" is the reproducible coordinate, not a wall instant.
//!   Retries advance the sequence, which is exactly what lets a
//!   windowed stall heal under retry-with-backoff while a permanent
//!   death falls through to the recompute ladder.
//! * **Worker events** key on the fleet dispatcher's virtual clock —
//!   "worker 1 crashes at t = 0.25s" lands between the same two batch
//!   completions every run.
//! * **Corruption** flips one payload bit chosen by a splitmix64 hash
//!   of `(plan seed, shard, read seq)`: silent on the device, caught by
//!   the v3 record checksum.
//!
//! Spec grammar (the CLI's `--faults`), comma-separated events:
//!
//! ```text
//! seed=N                     reseed the corruption hash (default 0x5eed)
//! shardS:slowFx@A..B         reads A..B on shard S take Fx device time
//! shardS:stall@A..B          reads A..B on shard S error, then heal
//! shardS:die@A               shard S dead from read A on (permanent)
//! shardS:corrupt@A           read A on shard S returns one flipped bit
//! shardS:wfail@A..B          writes A..B on shard S error
//! workerW:crash@T            fleet worker W goes offline at virtual T secs
//! ```
//!
//! `@A` with no `..B` means the single-event window `A..A+1`. Example:
//! `--faults "shard0:die@6,worker1:crash@0.25,shard1:corrupt@3"`.

use std::collections::HashMap;
use std::sync::Mutex;

use anyhow::{bail, Context, Result};

/// One injectable failure. Shard windows are half-open `[from, to)`
/// over that shard's 0-based read (or write) sequence.
#[derive(Debug, Clone, PartialEq)]
pub enum FaultEvent {
    /// Reads in the window take `factor`× the modeled device time.
    ShardSlow { shard: usize, factor: f64, from: u64, to: u64 },
    /// Reads in the window error (a timeout), then the shard heals.
    ShardStall { shard: usize, from: u64, to: u64 },
    /// Every read from sequence `from` on errors — the shard is gone.
    ShardDie { shard: usize, from: u64 },
    /// Read `read` silently returns a buffer with one flipped payload
    /// bit (the file on disk stays intact — it is the *transfer* that
    /// lied, which is what the record checksum exists to catch).
    ShardCorrupt { shard: usize, read: u64 },
    /// Writes in the window error (surfaced as `write_errors`).
    ShardWriteFail { shard: usize, from: u64, to: u64 },
    /// Fleet worker `worker` goes offline at virtual second `at`.
    WorkerCrash { worker: usize, at: f64 },
}

/// The injection decision for one shard read, returned by
/// [`FaultPlan::on_read`]. Fields compose: a read can be both slowed
/// and corrupted (fail wins over corrupt — an errored read returns no
/// buffer to flip).
#[derive(Debug, Clone, Copy, PartialEq)]
pub struct ReadFault {
    /// Multiplier on the modeled device seconds (1.0 = untouched).
    pub slow_factor: f64,
    /// `Some(reason)`: the read errors instead of returning bytes.
    pub fail: Option<&'static str>,
    /// `Some(hash)`: flip one payload bit derived from this value.
    pub corrupt: Option<u64>,
}

impl ReadFault {
    const CLEAN: ReadFault = ReadFault { slow_factor: 1.0, fail: None, corrupt: None };

    /// True when this read is delivered untouched.
    pub fn is_clean(&self) -> bool {
        *self == Self::CLEAN
    }
}

/// A deterministic failure schedule shared (via `Arc`) by the store's
/// shards and the fleet dispatcher. Interior per-shard sequence
/// counters make it injectable behind `Arc` without plumbing `&mut`
/// through the read path.
#[derive(Debug)]
pub struct FaultPlan {
    seed: u64,
    events: Vec<FaultEvent>,
    /// Per-shard read/write sequence counters (keyed `shard`).
    reads: Mutex<HashMap<usize, u64>>,
    writes: Mutex<HashMap<usize, u64>>,
}

impl FaultPlan {
    pub fn new(seed: u64, events: Vec<FaultEvent>) -> Self {
        FaultPlan {
            seed,
            events,
            reads: Mutex::new(HashMap::new()),
            writes: Mutex::new(HashMap::new()),
        }
    }

    /// Parse the `--faults` spec grammar (module docs). Empty specs and
    /// plans with zero events are rejected — a no-op plan is almost
    /// certainly a typo, and `--faults` absent is the no-op spelling.
    pub fn parse(spec: &str) -> Result<FaultPlan> {
        let mut seed = 0x5eed_u64;
        let mut events = Vec::new();
        for item in spec.split(',').map(str::trim).filter(|s| !s.is_empty()) {
            if let Some(s) = item.strip_prefix("seed=") {
                seed = s.parse().with_context(|| format!("bad fault seed {s:?}"))?;
                continue;
            }
            let (target, action) = item
                .split_once(':')
                .with_context(|| format!("fault event {item:?} missing ':'"))?;
            if let Some(w) = target.strip_prefix("worker") {
                let worker: usize =
                    w.parse().with_context(|| format!("bad worker index in {item:?}"))?;
                let at = action
                    .strip_prefix("crash@")
                    .with_context(|| format!("worker fault {item:?} must be crash@T"))?;
                let at: f64 = at.parse().with_context(|| format!("bad crash time in {item:?}"))?;
                if !at.is_finite() || at < 0.0 {
                    bail!("crash time must be finite and >= 0 in {item:?}");
                }
                events.push(FaultEvent::WorkerCrash { worker, at });
                continue;
            }
            let shard: usize = target
                .strip_prefix("shard")
                .with_context(|| format!("fault target {target:?} must be shardN or workerN"))?
                .parse()
                .with_context(|| format!("bad shard index in {item:?}"))?;
            let (verb, arg) = action
                .split_once('@')
                .with_context(|| format!("shard fault {item:?} missing '@'"))?;
            events.push(if let Some(f) = verb.strip_prefix("slow") {
                let factor: f64 = f
                    .strip_suffix('x')
                    .with_context(|| format!("slow factor in {item:?} must end in 'x'"))?
                    .parse()
                    .with_context(|| format!("bad slow factor in {item:?}"))?;
                if !factor.is_finite() || factor < 1.0 {
                    bail!("slow factor must be >= 1 in {item:?}");
                }
                let (from, to) = parse_window(arg, item)?;
                FaultEvent::ShardSlow { shard, factor, from, to }
            } else {
                match verb {
                    "stall" => {
                        let (from, to) = parse_window(arg, item)?;
                        FaultEvent::ShardStall { shard, from, to }
                    }
                    "die" => FaultEvent::ShardDie {
                        shard,
                        from: arg.parse().with_context(|| format!("bad die point in {item:?}"))?,
                    },
                    "corrupt" => FaultEvent::ShardCorrupt {
                        shard,
                        read: arg
                            .parse()
                            .with_context(|| format!("bad corrupt point in {item:?}"))?,
                    },
                    "wfail" => {
                        let (from, to) = parse_window(arg, item)?;
                        FaultEvent::ShardWriteFail { shard, from, to }
                    }
                    other => bail!("unknown shard fault {other:?} in {item:?}"),
                }
            });
        }
        if events.is_empty() {
            bail!("fault spec {spec:?} names no events");
        }
        Ok(FaultPlan::new(seed, events))
    }

    pub fn events(&self) -> &[FaultEvent] {
        &self.events
    }

    /// Advance shard `shard`'s read sequence and fold every matching
    /// event into one injection decision. Called once per read
    /// *attempt* — retries advance the sequence, so windowed faults
    /// heal under backoff while permanent ones don't.
    pub fn on_read(&self, shard: usize) -> ReadFault {
        let seq = {
            let mut reads = self.reads.lock().unwrap();
            let c = reads.entry(shard).or_insert(0);
            let seq = *c;
            *c += 1;
            seq
        };
        let mut fault = ReadFault::CLEAN;
        for ev in &self.events {
            match *ev {
                FaultEvent::ShardSlow { shard: s, factor, from, to }
                    if s == shard && (from..to).contains(&seq) =>
                {
                    fault.slow_factor *= factor;
                }
                FaultEvent::ShardStall { shard: s, from, to }
                    if s == shard && (from..to).contains(&seq) =>
                {
                    fault.fail = Some("injected stall");
                }
                FaultEvent::ShardDie { shard: s, from } if s == shard && seq >= from => {
                    fault.fail = Some("shard dead");
                }
                FaultEvent::ShardCorrupt { shard: s, read } if s == shard && seq == read => {
                    fault.corrupt =
                        Some(splitmix64(self.seed ^ ((shard as u64) << 32) ^ seq));
                }
                _ => {}
            }
        }
        fault
    }

    /// Advance shard `shard`'s write sequence; `Some(reason)` fails it.
    pub fn on_write(&self, shard: usize) -> Option<&'static str> {
        let seq = {
            let mut writes = self.writes.lock().unwrap();
            let c = writes.entry(shard).or_insert(0);
            let seq = *c;
            *c += 1;
            seq
        };
        self.events.iter().find_map(|ev| match *ev {
            FaultEvent::ShardWriteFail { shard: s, from, to }
                if s == shard && (from..to).contains(&seq) =>
            {
                Some("injected write failure")
            }
            _ => None,
        })
    }

    /// Earliest virtual second at which fleet worker `worker` crashes.
    pub fn worker_crash_at(&self, worker: usize) -> Option<f64> {
        self.events
            .iter()
            .filter_map(|ev| match *ev {
                FaultEvent::WorkerCrash { worker: w, at } if w == worker => Some(at),
                _ => None,
            })
            .fold(None, |acc: Option<f64>, at| Some(acc.map_or(at, |a| a.min(at))))
    }

    /// Whether the plan kills shard `shard` permanently (a
    /// [`FaultEvent::ShardDie`] exists). The fleet prices chunks placed
    /// on such a shard as Vanilla recompute at the serving worker.
    pub fn shard_dead(&self, shard: usize) -> bool {
        self.events
            .iter()
            .any(|ev| matches!(*ev, FaultEvent::ShardDie { shard: s, .. } if s == shard))
    }

    /// Reset the per-shard sequence counters (fresh replay of the same
    /// plan — what the determinism tests lean on).
    pub fn reset(&self) {
        self.reads.lock().unwrap().clear();
        self.writes.lock().unwrap().clear();
    }
}

/// `A` or `A..B` → half-open `[A, B)` (single point = width-1 window).
fn parse_window(arg: &str, item: &str) -> Result<(u64, u64)> {
    let (a, b) = match arg.split_once("..") {
        Some((a, b)) => (
            a.parse::<u64>().with_context(|| format!("bad window start in {item:?}"))?,
            b.parse::<u64>().with_context(|| format!("bad window end in {item:?}"))?,
        ),
        None => {
            let a: u64 = arg.parse().with_context(|| format!("bad window in {item:?}"))?;
            (a, a + 1)
        }
    };
    if b <= a {
        bail!("empty fault window in {item:?}");
    }
    Ok((a, b))
}

/// The same splitmix64 the shard router uses — one hash family for
/// every deterministic decision in the repo.
fn splitmix64(mut x: u64) -> u64 {
    x = x.wrapping_add(0x9e37_79b9_7f4a_7c15);
    x = (x ^ (x >> 30)).wrapping_mul(0xbf58_476d_1ce4_e5b9);
    x = (x ^ (x >> 27)).wrapping_mul(0x94d0_49bb_1331_11eb);
    x ^ (x >> 31)
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn parses_every_event_kind() {
        let plan = FaultPlan::parse(
            "seed=7, shard0:slow2.5x@4..12, shard1:stall@5, shard0:die@6, \
             shard2:corrupt@3, shard1:wfail@0..2, worker1:crash@0.25",
        )
        .unwrap();
        assert_eq!(plan.seed, 7);
        assert_eq!(plan.events.len(), 6);
        assert_eq!(
            plan.events[0],
            FaultEvent::ShardSlow { shard: 0, factor: 2.5, from: 4, to: 12 }
        );
        assert_eq!(plan.events[1], FaultEvent::ShardStall { shard: 1, from: 5, to: 6 });
        assert_eq!(plan.events[2], FaultEvent::ShardDie { shard: 0, from: 6 });
        assert_eq!(plan.events[3], FaultEvent::ShardCorrupt { shard: 2, read: 3 });
        assert_eq!(plan.events[4], FaultEvent::ShardWriteFail { shard: 1, from: 0, to: 2 });
        assert_eq!(plan.events[5], FaultEvent::WorkerCrash { worker: 1, at: 0.25 });
        assert_eq!(plan.worker_crash_at(1), Some(0.25));
        assert_eq!(plan.worker_crash_at(0), None);
        assert!(plan.shard_dead(0));
        assert!(!plan.shard_dead(1));
    }

    #[test]
    fn rejects_malformed_specs() {
        for bad in [
            "",
            "shard0",
            "shard0:die",
            "shardX:die@1",
            "worker0:die@1",
            "shard0:slow0.5x@0..4", // speedup is not a fault
            "shard0:stall@4..4",    // empty window
            "shard0:frob@1",
            "seed=banana",
            "worker0:crash@-1",
        ] {
            assert!(FaultPlan::parse(bad).is_err(), "spec {bad:?} must be rejected");
        }
    }

    #[test]
    fn read_faults_follow_the_sequence_windows() {
        let plan =
            FaultPlan::parse("shard0:slow3x@1..3, shard0:stall@2, shard0:corrupt@4").unwrap();
        // seq 0 clean; 1 slow; 2 slow+stall (fail set); 3 clean; 4 corrupt
        assert!(plan.on_read(0).is_clean());
        let f1 = plan.on_read(0);
        assert_eq!(f1.slow_factor, 3.0);
        assert!(f1.fail.is_none());
        let f2 = plan.on_read(0);
        assert_eq!(f2.slow_factor, 3.0);
        assert!(f2.fail.is_some());
        assert!(plan.on_read(0).is_clean());
        assert!(plan.on_read(0).corrupt.is_some());
        // other shards never see shard 0's events
        for _ in 0..8 {
            assert!(plan.on_read(1).is_clean());
        }
    }

    #[test]
    fn die_is_permanent_stall_heals() {
        let plan = FaultPlan::parse("shard0:stall@0..2, shard1:die@1").unwrap();
        assert!(plan.on_read(0).fail.is_some());
        assert!(plan.on_read(0).fail.is_some());
        assert!(plan.on_read(0).fail.is_none(), "stall window must heal");
        assert!(plan.on_read(1).fail.is_none());
        for _ in 0..4 {
            assert!(plan.on_read(1).fail.is_some(), "death must be permanent");
        }
    }

    #[test]
    fn write_faults_fail_their_window_only() {
        let plan = FaultPlan::parse("shard0:wfail@1..2").unwrap();
        assert!(plan.on_write(0).is_none());
        assert!(plan.on_write(0).is_some());
        assert!(plan.on_write(0).is_none());
        assert!(plan.on_write(1).is_none());
    }

    #[test]
    fn same_plan_replays_bit_identically() {
        let spec = "seed=9, shard0:corrupt@1, shard0:slow2x@0..3, shard1:stall@1..2";
        let (a, b) = (FaultPlan::parse(spec).unwrap(), FaultPlan::parse(spec).unwrap());
        let run = |p: &FaultPlan| -> Vec<ReadFault> {
            (0..6).flat_map(|_| [p.on_read(0), p.on_read(1)]).collect()
        };
        let first = run(&a);
        assert_eq!(first, run(&b), "two parses of one spec must inject identically");
        a.reset();
        assert_eq!(first, run(&a), "reset must replay the schedule from the top");
    }
}
