//! Symmetric per-plane q8 and q4 codecs for the DRAM warm tier and the
//! v4 flash format.
//!
//! The warm tier ([`super::WarmTier`]) holds chunks evicted from the f32
//! hot tier at ~4x fewer resident bytes: each K/V element is stored as a
//! signed 8-bit integer with one f32 scale per **layer×head plane**
//! (`seq_len × head_dim` elements). Per-plane scaling matters because KV
//! magnitudes vary strongly across layers and heads — a global scale
//! would let one loud attention head destroy every quiet one's
//! precision; per-plane, each head's error is bounded by *its own*
//! dynamic range.
//!
//! Both codecs are symmetric (no zero-point): `scale = max|x| / Q`,
//! `q = round(x / scale)`, `x̂ = q · scale`, with `Q = 127` for q8 and
//! `Q = 7` for q4. Rounding to nearest gives the error bounds the
//! property tests pin:
//!
//! ```text
//! |x − x̂| ≤ scale / 2 = max|x| / 254      (q8, per plane)
//! |x − x̂| ≤ scale / 2 = max|x| / 14       (q4, per plane)
//! ```
//!
//! The q4 codec packs **two signed 4-bit values per byte** (range
//! −7..=7, two's-complement nibbles, low nibble first; each plane packs
//! independently so an odd `plane_len` pads its last nibble) — half the
//! q8 payload again, at a 18x looser error bound. It backs the cool
//! paths: the `--warm-mode q4` DRAM tier and the v4 on-disk format
//! ([`super::store::KvFormat::V4`]), where the saved flash bytes are
//! bought with a modeled dequant pass per load.
//!
//! An all-zero plane encodes with scale 0 and decodes exactly. Encode
//! and decode are single memory-bound passes; the modeled serve-time
//! costs of the decode passes live in
//! [`crate::hwsim::profiles::q8_dequant_secs`] and
//! [`crate::hwsim::profiles::q4_dequant_secs`].

use super::store::KvChunk;

/// A [`KvChunk`] with its K/V planes quantized to q8 (one f32 scale per
/// layer×head plane). Header fields mirror the source chunk so
/// dequantization can rebuild it exactly shaped.
#[derive(Debug, Clone, PartialEq)]
pub struct QuantChunk {
    pub config_id: u32,
    pub n_layers: u32,
    pub n_kv_heads: u32,
    pub seq_len: u32,
    pub head_dim: u32,
    /// One scale per layer×head plane of K (`n_layers * n_kv_heads`).
    pub k_scales: Vec<f32>,
    /// One scale per layer×head plane of V.
    pub v_scales: Vec<f32>,
    /// Quantized K plane, same element order as `KvChunk::k`.
    pub k_q: Vec<i8>,
    /// Quantized V plane, same element order as `KvChunk::v`.
    pub v_q: Vec<i8>,
}

impl QuantChunk {
    /// Elements in one layer×head plane.
    pub fn plane_len(&self) -> usize {
        self.seq_len as usize * self.head_dim as usize
    }

    /// Number of layer×head planes per tensor (= scales per tensor).
    pub fn n_planes(&self) -> usize {
        self.n_layers as usize * self.n_kv_heads as usize
    }

    /// Total K+V elements.
    pub fn total_elems(&self) -> usize {
        self.k_q.len() + self.v_q.len()
    }

    /// Bytes the q8 payload occupies (what a dequant pass must touch):
    /// quantized elements plus the per-plane scales.
    pub fn q8_bytes(&self) -> usize {
        self.total_elems() + 4 * (self.k_scales.len() + self.v_scales.len())
    }

    /// Resident bytes when held by the DRAM warm tier — the ~4x
    /// advantage over [`KvChunk::dram_bytes`] that lets the warm tier
    /// keep more chunks off the simulated flash at equal DRAM budget.
    pub fn dram_bytes(&self) -> usize {
        std::mem::size_of::<QuantChunk>() + self.q8_bytes()
    }

    /// Resident bytes the *dequantized* f32 chunk would occupy
    /// ([`KvChunk::dram_bytes`] of the reconstruction) — what a
    /// promotion into the hot tier would charge. The warm tier uses
    /// this to refuse promote-out of chunks the hot tier could never
    /// admit, which would otherwise evict themselves on every hit.
    pub fn f32_dram_bytes(&self) -> usize {
        std::mem::size_of::<KvChunk>() + 4 * self.total_elems()
    }
}

/// Worst-case absolute reconstruction error of a plane encoded with
/// `scale` (round-to-nearest: half a quantization step).
pub fn max_abs_error(scale: f32) -> f32 {
    scale * 0.5
}

fn quantize_planes(src: &[f32], plane_len: usize) -> (Vec<f32>, Vec<i8>) {
    let mut scales = Vec::with_capacity(if plane_len > 0 { src.len() / plane_len } else { 0 });
    let mut q = Vec::with_capacity(src.len());
    if plane_len == 0 {
        return (scales, q);
    }
    for plane in src.chunks(plane_len) {
        let max_abs = plane.iter().fold(0.0f32, |m, &x| m.max(x.abs()));
        let scale = if max_abs > 0.0 { max_abs / 127.0 } else { 0.0 };
        scales.push(scale);
        if scale == 0.0 {
            q.extend(std::iter::repeat(0i8).take(plane.len()));
        } else {
            q.extend(plane.iter().map(|&x| (x / scale).round().clamp(-127.0, 127.0) as i8));
        }
    }
    (scales, q)
}

fn dequantize_planes(scales: &[f32], q: &[i8], plane_len: usize) -> Vec<f32> {
    let mut out = Vec::with_capacity(q.len());
    if plane_len == 0 {
        return out;
    }
    for (plane, &scale) in q.chunks(plane_len).zip(scales) {
        out.extend(plane.iter().map(|&v| v as f32 * scale));
    }
    out
}

/// Quantize a chunk's K/V planes to q8 (one scale per layer×head plane).
pub fn quantize(chunk: &KvChunk) -> QuantChunk {
    let plane_len = chunk.seq_len as usize * chunk.head_dim as usize;
    let (k_scales, k_q) = quantize_planes(&chunk.k, plane_len);
    let (v_scales, v_q) = quantize_planes(&chunk.v, plane_len);
    QuantChunk {
        config_id: chunk.config_id,
        n_layers: chunk.n_layers,
        n_kv_heads: chunk.n_kv_heads,
        seq_len: chunk.seq_len,
        head_dim: chunk.head_dim,
        k_scales,
        v_scales,
        k_q,
        v_q,
    }
}

/// Reconstruct the f32 chunk a warm hit serves (lossy: see the module
/// error bound).
pub fn dequantize(q: &QuantChunk) -> KvChunk {
    let plane_len = q.plane_len();
    KvChunk {
        config_id: q.config_id,
        n_layers: q.n_layers,
        n_kv_heads: q.n_kv_heads,
        seq_len: q.seq_len,
        head_dim: q.head_dim,
        k: dequantize_planes(&q.k_scales, &q.k_q, plane_len),
        v: dequantize_planes(&q.v_scales, &q.v_q, plane_len),
    }
}

/// A [`KvChunk`] with its K/V planes quantized to q4: two
/// two's-complement nibbles per byte, one f32 scale per layer×head
/// plane. Half the q8 payload; the error bound is max|plane|/14.
#[derive(Debug, Clone, PartialEq)]
pub struct Q4Chunk {
    pub config_id: u32,
    pub n_layers: u32,
    pub n_kv_heads: u32,
    pub seq_len: u32,
    pub head_dim: u32,
    /// One scale per layer×head plane of K (`n_layers * n_kv_heads`).
    pub k_scales: Vec<f32>,
    /// One scale per layer×head plane of V.
    pub v_scales: Vec<f32>,
    /// Packed K nibbles, plane-major: each plane occupies
    /// `ceil(plane_len / 2)` bytes, low nibble first.
    pub k_q: Vec<u8>,
    /// Packed V nibbles, same layout as `k_q`.
    pub v_q: Vec<u8>,
}

impl Q4Chunk {
    /// Elements in one layer×head plane.
    pub fn plane_len(&self) -> usize {
        self.seq_len as usize * self.head_dim as usize
    }

    /// Number of layer×head planes per tensor (= scales per tensor).
    pub fn n_planes(&self) -> usize {
        self.n_layers as usize * self.n_kv_heads as usize
    }

    /// Total K+V *elements* (not bytes) the planes decode to.
    pub fn total_elems(&self) -> usize {
        2 * self.n_planes() * self.plane_len()
    }

    /// Bytes the q4 payload occupies (what a dequant pass must touch):
    /// packed nibbles plus the per-plane scales.
    pub fn q4_bytes(&self) -> usize {
        self.k_q.len() + self.v_q.len() + 4 * (self.k_scales.len() + self.v_scales.len())
    }

    /// Resident bytes when held by the DRAM warm tier in q4 mode — the
    /// ~8x advantage over [`KvChunk::dram_bytes`].
    pub fn dram_bytes(&self) -> usize {
        std::mem::size_of::<Q4Chunk>() + self.q4_bytes()
    }

    /// Resident bytes the *dequantized* f32 chunk would occupy — what a
    /// promotion into the hot tier would charge (see
    /// [`QuantChunk::f32_dram_bytes`]).
    pub fn f32_dram_bytes(&self) -> usize {
        std::mem::size_of::<KvChunk>() + 4 * self.total_elems()
    }
}

/// Bytes one q4-packed plane of `plane_len` elements occupies.
pub fn q4_plane_bytes(plane_len: usize) -> usize {
    plane_len.div_ceil(2)
}

fn quantize_planes_q4(src: &[f32], plane_len: usize) -> (Vec<f32>, Vec<u8>) {
    let mut scales = Vec::with_capacity(if plane_len > 0 { src.len() / plane_len } else { 0 });
    let mut q = Vec::new();
    if plane_len == 0 {
        return (scales, q);
    }
    q.reserve(src.len().div_ceil(plane_len) * q4_plane_bytes(plane_len));
    for plane in src.chunks(plane_len) {
        let max_abs = plane.iter().fold(0.0f32, |m, &x| m.max(x.abs()));
        let scale = if max_abs > 0.0 { max_abs / 7.0 } else { 0.0 };
        scales.push(scale);
        let quant = |x: f32| -> u8 {
            if scale == 0.0 {
                0
            } else {
                ((x / scale).round().clamp(-7.0, 7.0) as i8 as u8) & 0x0f
            }
        };
        for pair in plane.chunks(2) {
            let lo = quant(pair[0]);
            let hi = if pair.len() == 2 { quant(pair[1]) } else { 0 };
            q.push(lo | (hi << 4));
        }
    }
    (scales, q)
}

#[inline]
fn nibble_to_i8(n: u8) -> i8 {
    // sign-extend the low 4 bits (two's complement)
    ((n << 4) as i8) >> 4
}

fn dequantize_planes_q4(scales: &[f32], q: &[u8], plane_len: usize) -> Vec<f32> {
    let mut out = Vec::with_capacity(scales.len() * plane_len);
    if plane_len == 0 {
        return out;
    }
    let packed = q4_plane_bytes(plane_len);
    for (plane, &scale) in q.chunks(packed).zip(scales) {
        let mut left = plane_len;
        for &b in plane {
            out.push(nibble_to_i8(b & 0x0f) as f32 * scale);
            left -= 1;
            if left == 0 {
                break; // odd plane_len: the high nibble of the last byte is padding
            }
            out.push(nibble_to_i8(b >> 4) as f32 * scale);
            left -= 1;
        }
    }
    out
}

/// Quantize a chunk's K/V planes to q4 (one scale per layer×head plane,
/// two values per byte).
pub fn quantize_q4(chunk: &KvChunk) -> Q4Chunk {
    let plane_len = chunk.seq_len as usize * chunk.head_dim as usize;
    let (k_scales, k_q) = quantize_planes_q4(&chunk.k, plane_len);
    let (v_scales, v_q) = quantize_planes_q4(&chunk.v, plane_len);
    Q4Chunk {
        config_id: chunk.config_id,
        n_layers: chunk.n_layers,
        n_kv_heads: chunk.n_kv_heads,
        seq_len: chunk.seq_len,
        head_dim: chunk.head_dim,
        k_scales,
        v_scales,
        k_q,
        v_q,
    }
}

/// Reconstruct the f32 chunk a q4 cool-path load serves (lossy: see the
/// module error bound).
pub fn dequantize_q4(q: &Q4Chunk) -> KvChunk {
    let plane_len = q.plane_len();
    KvChunk {
        config_id: q.config_id,
        n_layers: q.n_layers,
        n_kv_heads: q.n_kv_heads,
        seq_len: q.seq_len,
        head_dim: q.head_dim,
        k: dequantize_planes_q4(&q.k_scales, &q.k_q, plane_len),
        v: dequantize_planes_q4(&q.v_scales, &q.v_q, plane_len),
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn chunk_with<F: FnMut(usize) -> f32, G: FnMut(usize) -> f32>(
        n_layers: u32,
        n_kv_heads: u32,
        seq: u32,
        head_dim: u32,
        k_of: F,
        v_of: G,
    ) -> KvChunk {
        let plane = (n_layers * n_kv_heads * seq * head_dim) as usize;
        KvChunk {
            config_id: 7,
            n_layers,
            n_kv_heads,
            seq_len: seq,
            head_dim,
            k: (0..plane).map(k_of).collect(),
            v: (0..plane).map(v_of).collect(),
        }
    }

    /// Tiny deterministic pseudo-random stream (no external crates).
    fn lcg(seed: u64) -> impl FnMut() -> f32 {
        let mut s = seed.wrapping_mul(6364136223846793005).wrapping_add(1);
        move || {
            s = s.wrapping_mul(6364136223846793005).wrapping_add(1442695040888963407);
            // uniform in [-1, 1), then stretched by a per-draw magnitude
            let u = ((s >> 40) as f64 / (1u64 << 24) as f64) * 2.0 - 1.0;
            let mag = 1.0 + ((s >> 16) & 0xff) as f64 / 16.0;
            (u * mag) as f32
        }
    }

    #[test]
    fn roundtrip_error_bounded_per_plane() {
        // Property: for random payloads, every reconstructed element is
        // within max|plane| / 254 of the original — the module's bound.
        for seed in 1..=8u64 {
            let mut rnd = lcg(seed);
            let c = chunk_with(3, 2, 16, 8, |_| rnd(), |_| 0.0);
            let mut rnd2 = lcg(seed ^ 0xdead);
            let c = KvChunk { v: c.k.iter().map(|_| rnd2()).collect(), ..c };
            let q = quantize(&c);
            let back = dequantize(&q);
            assert_eq!(back.plane_elems(), c.plane_elems());
            let plane_len = q.plane_len();
            for (src, dst, scales) in
                [(&c.k, &back.k, &q.k_scales), (&c.v, &back.v, &q.v_scales)]
            {
                for (p, (orig, rec)) in
                    src.chunks(plane_len).zip(dst.chunks(plane_len)).enumerate()
                {
                    let bound = max_abs_error(scales[p]) + 1e-7;
                    for (a, b) in orig.iter().zip(rec) {
                        assert!(
                            (a - b).abs() <= bound,
                            "seed {seed} plane {p}: {a} vs {b} (bound {bound})"
                        );
                    }
                    // and the bound itself is max|plane|/254
                    let max_abs = orig.iter().fold(0.0f32, |m, &x| m.max(x.abs()));
                    assert!(max_abs_error(scales[p]) <= max_abs / 254.0 + 1e-7);
                }
            }
        }
    }

    #[test]
    fn per_plane_scales_isolate_loud_heads() {
        // One loud plane must not destroy a quiet plane's precision: the
        // quiet plane's error stays bounded by ITS max, not the loud one's.
        let plane_len = 16 * 8;
        let c = chunk_with(
            2,
            1,
            16,
            8,
            |i| if i < plane_len { 1000.0 } else { 0.001 * ((i % 7) as f32 - 3.0) },
            |_| 1.0,
        );
        let q = quantize(&c);
        let back = dequantize(&q);
        for (a, b) in c.k[plane_len..].iter().zip(&back.k[plane_len..]) {
            assert!((a - b).abs() <= 0.003 / 254.0 + 1e-9, "{a} vs {b}");
        }
    }

    #[test]
    fn zero_planes_and_exact_grid_values_roundtrip_exactly() {
        // All-zero planes encode with scale 0 and decode exactly; values
        // already on the q8 grid (integers with a ±127 in every plane, so
        // scale = 1) survive exactly too.
        let c = chunk_with(
            1,
            2,
            4,
            4,
            |_| 0.0,
            |i| if i % 16 == 0 { 127.0 } else { (i % 255) as f32 - 127.0 },
        );
        let q = quantize(&c);
        assert!(q.k_scales.iter().all(|&s| s == 0.0));
        let back = dequantize(&q);
        assert_eq!(back.k, c.k);
        assert_eq!(back.v, c.v, "on-grid integers must be exact");
        // negatives preserved
        assert!(back.v[1] < 0.0);
    }

    #[test]
    fn q8_is_about_a_quarter_of_f32_residency() {
        let c = chunk_with(4, 4, 64, 16, |i| (i as f32).sin(), |i| (i as f32).cos());
        let q = quantize(&c);
        let ratio = q.dram_bytes() as f64 / c.dram_bytes() as f64;
        assert!(ratio < 0.30, "q8/f32 residency ratio {ratio}");
        assert_eq!(q.total_elems(), 2 * c.plane_elems());
        assert_eq!(q.n_planes(), 16);
        assert_eq!(q.k_scales.len(), 16);
    }

    #[test]
    fn shapes_survive_roundtrip() {
        let c = chunk_with(2, 3, 8, 4, |i| i as f32, |i| -(i as f32));
        let q = quantize(&c);
        let back = dequantize(&q);
        assert_eq!(
            (back.config_id, back.n_layers, back.n_kv_heads, back.seq_len, back.head_dim),
            (c.config_id, c.n_layers, c.n_kv_heads, c.seq_len, c.head_dim)
        );
        assert_eq!(back.k.len(), c.k.len());
        assert_eq!(back.v.len(), c.v.len());
    }

    // ---- q4 ------------------------------------------------------------

    #[test]
    fn q4_roundtrip_error_bounded_per_plane() {
        // Property: for random payloads, every reconstructed element is
        // within max|plane| / 14 of the original — the q4 bound.
        for seed in 1..=8u64 {
            let mut rnd = lcg(seed);
            let c = chunk_with(3, 2, 16, 8, |_| rnd(), |_| 0.0);
            let mut rnd2 = lcg(seed ^ 0xbeef);
            let c = KvChunk { v: c.k.iter().map(|_| rnd2()).collect(), ..c };
            let q = quantize_q4(&c);
            let back = dequantize_q4(&q);
            assert_eq!(back.plane_elems(), c.plane_elems());
            let plane_len = q.plane_len();
            for (src, dst, scales) in
                [(&c.k, &back.k, &q.k_scales), (&c.v, &back.v, &q.v_scales)]
            {
                for (p, (orig, rec)) in
                    src.chunks(plane_len).zip(dst.chunks(plane_len)).enumerate()
                {
                    let bound = max_abs_error(scales[p]) + 1e-7;
                    for (a, b) in orig.iter().zip(rec) {
                        assert!(
                            (a - b).abs() <= bound,
                            "seed {seed} plane {p}: {a} vs {b} (bound {bound})"
                        );
                    }
                    // and the bound itself is max|plane|/14
                    let max_abs = orig.iter().fold(0.0f32, |m, &x| m.max(x.abs()));
                    assert!(max_abs_error(scales[p]) <= max_abs / 14.0 + 1e-7);
                }
            }
        }
    }

    #[test]
    fn q4_per_plane_scales_isolate_loud_heads() {
        // Same isolation property as q8: the quiet plane's error is
        // bounded by ITS dynamic range, not the loud plane's.
        let plane_len = 16 * 8;
        let c = chunk_with(
            2,
            1,
            16,
            8,
            |i| if i < plane_len { 1000.0 } else { 0.001 * ((i % 7) as f32 - 3.0) },
            |_| 1.0,
        );
        let q = quantize_q4(&c);
        let back = dequantize_q4(&q);
        for (a, b) in c.k[plane_len..].iter().zip(&back.k[plane_len..]) {
            assert!((a - b).abs() <= 0.003 / 14.0 + 1e-9, "{a} vs {b}");
        }
    }

    #[test]
    fn q4_zero_planes_and_on_grid_values_roundtrip_exactly() {
        // All-zero planes encode with scale 0; values on the q4 grid
        // (integers −7..=7 with a ±7 in every plane, so scale = 1)
        // survive exactly, negatives included.
        let c = chunk_with(
            1,
            2,
            4,
            4,
            |_| 0.0,
            |i| if i % 16 == 0 { 7.0 } else { (i % 15) as f32 - 7.0 },
        );
        let q = quantize_q4(&c);
        assert!(q.k_scales.iter().all(|&s| s == 0.0));
        let back = dequantize_q4(&q);
        assert_eq!(back.k, c.k);
        assert_eq!(back.v, c.v, "on-grid integers must be exact");
        assert!(back.v[1] < 0.0);
    }

    #[test]
    fn q4_odd_plane_len_pads_the_last_nibble() {
        // plane_len = 3*3 = 9 (odd): each plane packs to 5 bytes, the
        // high nibble of the last byte is padding, and the roundtrip
        // still reconstructs exactly plane_len elements per plane.
        // every 9-element plane leads with a 7 so its scale is exactly 1
        let c = chunk_with(
            2,
            2,
            3,
            3,
            |i| if i % 9 == 0 { 7.0 } else { ((i % 15) as f32) - 7.0 },
            |i| (i % 8) as f32,
        );
        let q = quantize_q4(&c);
        assert_eq!(q.plane_len(), 9);
        assert_eq!(q.k_q.len(), q.n_planes() * q4_plane_bytes(9));
        assert_eq!(q4_plane_bytes(9), 5);
        let back = dequantize_q4(&q);
        assert_eq!(back.k.len(), c.k.len());
        assert_eq!(back.v.len(), c.v.len());
        assert_eq!(back.k, c.k, "on-grid odd-plane payload must be exact");
    }

    #[test]
    fn q4_is_about_an_eighth_of_f32_residency_and_half_of_q8() {
        let c = chunk_with(4, 4, 64, 16, |i| (i as f32).sin(), |i| (i as f32).cos());
        let q8 = quantize(&c);
        let q4 = quantize_q4(&c);
        let ratio = q4.dram_bytes() as f64 / c.dram_bytes() as f64;
        assert!(ratio < 0.16, "q4/f32 residency ratio {ratio}");
        assert!(
            (q4.q4_bytes() as f64) < 0.55 * q8.q8_bytes() as f64,
            "q4 payload {} vs q8 {}",
            q4.q4_bytes(),
            q8.q8_bytes()
        );
        assert_eq!(q4.total_elems(), 2 * c.plane_elems());
        assert_eq!(q4.n_planes(), 16);
        assert_eq!(q4.k_scales.len(), 16);
    }

    #[test]
    fn q4_shapes_survive_roundtrip() {
        let c = chunk_with(2, 3, 8, 4, |i| i as f32, |i| -(i as f32));
        let q = quantize_q4(&c);
        let back = dequantize_q4(&q);
        assert_eq!(
            (back.config_id, back.n_layers, back.n_kv_heads, back.seq_len, back.head_dim),
            (c.config_id, c.n_layers, c.n_kv_heads, c.seq_len, c.head_dim)
        );
        assert_eq!(back.k.len(), c.k.len());
        assert_eq!(back.v.len(), c.v.len());
    }
}
