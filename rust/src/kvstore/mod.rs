//! Materialized-KV store: the storage half of MatKV, now a three-level
//! hierarchy.
//!
//! Each document chunk's precomputed KV cache is one file
//! (`<dir>/<chunk_id>.kv`) holding a fixed header plus contiguous
//! `[n_layers, n_kv_heads, seq, head_dim]` K then V planes — f32 in the
//! v1 format, f16 in the v2 format (halving both flash bytes and
//! simulated device-read time), and f16 plus a payload checksum in the
//! (default) v3 format, verified on every read so corrupted flash is
//! caught and retried instead of decoded. The layout matches what the
//! rust runtime splices into the packed device state, so a load is:
//! (simulated) flash read → decode → bounce buffer → one
//! `buffer_from_host` upload.
//!
//! In front of the flash tier sits an optional byte-budgeted **DRAM hot
//! tier** ([`HotTier`], [`KvStore::set_hot_tier`]): an LRU of decoded
//! chunks that serves the popular mass of Fig 2's Zipf-skewed access
//! distribution at memory speed, with hit/miss/eviction stats surfaced
//! through [`CacheStats`] and per-batch through
//! [`crate::coordinator::metrics::PhaseBreakdown`].
//!
//! Between the hot tier and flash sits an optional **warm tier**
//! ([`WarmTier`], [`KvStore::set_warm_tier`]): hot-tier budget evictions
//! demote into it as symmetric per-plane q8 ([`quant`], ~4x fewer
//! resident bytes) or — under [`WarmMode::Q4`] — q4 (~8x), and warm hits
//! dequantize at a modeled cost ([`crate::hwsim::profiles::q8_dequant_secs`]
//! / [`crate::hwsim::profiles::q4_dequant_secs`]) and promote back to
//! hot. At equal total DRAM budget the hot+warm split keeps strictly
//! more chunks off the device than hot alone; the fidelity price of
//! serving dequantized planes is measured by `benches/fig_warm_tier.rs`.
//! One rung cooler, the **v4 flash format** stores the same q4 planes
//! on disk (~4x fewer flash bytes than v1, half of v2/v3), trading a
//! per-load dequant charge for device-read time, and the hot tier's
//! eviction choice can be gated by a TinyLFU frequency sketch
//! ([`AdmissionPolicy::TinyLfu`]) so one sequential scan cannot flush
//! the resident hot set.
//! The lookup ladder in [`KvStore::load_many`] is hot → warm → flash;
//! under an installed [`crate::hwsim::FaultPlan`] failed flash reads
//! extend it with bounded retry/backoff and a Vanilla-recompute safety
//! net, so a dead or corrupting shard degrades service instead of
//! failing it.
//!
//! Real SSD hardware is replaced by a [`DeviceThrottle`] (DESIGN.md
//! "Substitutions"): reads/writes go through the filesystem (page cache —
//! effectively DRAM speed) and then *wall-clock delay* is injected to
//! match a [`StorageProfile`]'s bandwidth/latency, serialized across
//! concurrent requests exactly like a shared device. Table III (single
//! SSD vs RAID-0 vs DRAM) falls out of swapping profiles; hot-tier hits
//! bypass the throttle entirely.
//!
//! Below the store sits the **shard layer** ([`Shard`],
//! [`KvStore::open_sharded`]): chunk ids hash across N shard
//! directories, each with its own throttle, modeling a JBOD of
//! independent devices — `load_many` misses to different shards overlap
//! in simulated device time, so aggregate load bandwidth scales with the
//! shard count. [`KvStore::prefetch_many`] warms the hot tier ahead of
//! demand time through a protected admission path (prefetches can never
//! evict demand-resident chunks).
//!
//! [`StorageProfile`]: crate::hwsim::StorageProfile

pub mod cache;
pub mod quant;
pub mod shard;
pub mod store;
pub mod throttle;
pub mod warm;

pub use cache::{
    series_to_json, AdmissionPolicy, CacheSample, CacheStats, DemoteSink, HotTier, Probe,
    TierKind, TierMetrics,
};
pub use quant::{dequantize, dequantize_q4, quantize, quantize_q4, Q4Chunk, QuantChunk};
pub use shard::{route, Shard, ShardStats};
pub use store::{
    KvChunk, KvFormat, KvStore, Loaded, PrefetchReport, ResidentSet, ShardedKvStore, StoreStats,
};
pub use throttle::DeviceThrottle;
pub use warm::{WarmMode, WarmPayload, WarmProbe, WarmTier};
