//! Materialized-KV store: the flash-storage half of MatKV.
//!
//! Each document chunk's precomputed KV cache is one file
//! (`<dir>/<chunk_id>.kv`) holding a fixed header plus contiguous f32
//! `[n_layers, n_kv_heads, seq, head_dim]` K then V planes — the exact
//! layout the rust runtime splices into the packed device state, so a
//! load is: (simulated) flash read → bounce buffer → one
//! `buffer_from_host` upload.
//!
//! Real SSD hardware is replaced by a [`DeviceThrottle`] (DESIGN.md
//! "Substitutions"): reads/writes go through the filesystem (page cache —
//! effectively DRAM speed) and then *wall-clock delay* is injected to
//! match a [`StorageProfile`]'s bandwidth/latency, serialized across
//! concurrent requests exactly like a shared device. Table III (single
//! SSD vs RAID-0 vs DRAM) falls out of swapping profiles.

pub mod store;
pub mod throttle;

pub use store::{KvChunk, KvStore, StoreStats};
pub use throttle::DeviceThrottle;
