//! Shared-device bandwidth/latency simulation.
//!
//! Since the interconnect refactor this is a thin wrapper over
//! [`Link`] in [`LinkClock::Sleep`] mode: the queued-reservation core
//! (`busy_until` slotting, backlog gauge, per-class byte counters) was
//! extracted into the generic link model so flash shards, the host q8
//! bus, and the fleet's H2D PCIe links all account time identically.
//! What remains here is the storage-profile pricing (asymmetric
//! read/write bandwidth) and the already-spent credit for real
//! filesystem I/O.

use std::time::Duration;

use crate::hwsim::{Link, LinkClock, StorageProfile, TrafficClass};

/// Serializes simulated transfer time across concurrent users of one
/// storage device, like a real SSD's single internal bus.
///
/// Each transfer computes its device time from the profile, reserves a
/// slot `[start, start+t)` on the underlying [`Link`], and sleeps the
/// caller until the slot ends (minus however long the real filesystem
/// I/O already took). With an unthrottled profile (DRAM tier) this
/// degenerates to a no-op.
#[derive(Debug)]
pub struct DeviceThrottle {
    profile: StorageProfile,
    link: Link,
    /// Disable sleeping entirely (pure-functional tests).
    pub enabled: bool,
}

impl DeviceThrottle {
    pub fn new(profile: StorageProfile) -> Self {
        Self::with_enabled(profile, true)
    }

    /// A throttle with sleeping pre-configured (sharded stores rebuild
    /// one throttle per shard when swapping profiles; see
    /// [`crate::kvstore::Shard`]).
    pub fn with_enabled(profile: StorageProfile, enabled: bool) -> Self {
        let link = Link::new(profile.name.clone(), profile.read_bw, profile.latency_s, LinkClock::Sleep);
        DeviceThrottle { profile, link, enabled }
    }

    pub fn profile(&self) -> &StorageProfile {
        &self.profile
    }

    /// The underlying contended link — queue/busy/byte telemetry for
    /// the per-shard serve report.
    pub fn link(&self) -> &Link {
        &self.link
    }

    /// Seconds until this device would be idle (0 when idle now) — a
    /// cheap backlog gauge for shard telemetry, read off the link's own
    /// clock (see [`Link::backlog_secs`]).
    pub fn backlog_secs(&self) -> f64 {
        self.link.backlog_secs()
    }

    /// Charge a read of `bytes`; returns the simulated device seconds.
    /// `already_spent` is the real I/O time already consumed (subtracted
    /// from the injected sleep so total wall time matches the profile).
    pub fn charge_read(&self, bytes: usize, already_spent: Duration) -> f64 {
        self.charge_read_as(bytes, already_spent, TrafficClass::Demand)
    }

    /// [`DeviceThrottle::charge_read`] with an explicit traffic class,
    /// so demand misses and speculative prefetches stay separable in
    /// the link's byte counters.
    pub fn charge_read_as(&self, bytes: usize, already_spent: Duration, class: TrafficClass) -> f64 {
        self.charge(self.profile.read_secs(bytes), bytes, already_spent, class)
    }

    /// Charge a write of `bytes`; returns the simulated device seconds.
    pub fn charge_write(&self, bytes: usize, already_spent: Duration) -> f64 {
        self.charge(self.profile.write_secs(bytes), bytes, already_spent, TrafficClass::Write)
    }

    fn charge(&self, device_secs: f64, bytes: usize, already_spent: Duration, class: TrafficClass) -> f64 {
        if !self.enabled || !device_secs.is_finite() {
            return device_secs;
        }
        let secs = (device_secs - already_spent.as_secs_f64()).max(0.0);
        self.link.reserve_secs(secs, bytes, class);
        device_secs
    }

    /// Charge `secs` of pure occupancy (no bytes move): fault-injected
    /// slowdowns and retry backoffs hold the device exactly like a
    /// transfer would — queueing behind (and delaying) real traffic,
    /// sleeping on the wall-clock link. Returns the modeled seconds,
    /// which the caller still accounts when the throttle is disabled
    /// (pure-functional tests keep deterministic telemetry without the
    /// sleep).
    pub fn charge_penalty(&self, secs: f64, class: TrafficClass) -> f64 {
        if !secs.is_finite() || secs <= 0.0 {
            return 0.0;
        }
        if self.enabled {
            self.link.reserve_secs(secs, 0, class);
        }
        secs
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use std::sync::Arc;
    use std::time::Instant;

    fn slow_profile(bw: f64) -> StorageProfile {
        StorageProfile {
            name: "test".into(),
            read_bw: bw,
            write_bw: bw,
            latency_s: 0.0,
            power_active: 1.0,
            power_idle: 0.0,
            usd_per_byte: 1e-9,
        }
    }

    #[test]
    fn read_takes_simulated_time() {
        let t = DeviceThrottle::new(slow_profile(100e6)); // 100 MB/s
        let start = Instant::now();
        let secs = t.charge_read(10 << 20, Duration::ZERO); // 10 MB → 100ms
        assert!((secs - 0.1048).abs() < 0.01, "{secs}");
        assert!(start.elapsed().as_secs_f64() >= 0.09);
    }

    #[test]
    fn concurrent_reads_serialize() {
        // Two 5MB reads at 100MB/s on one device take ~100ms total, not 50.
        let t = Arc::new(DeviceThrottle::new(slow_profile(100e6)));
        let start = Instant::now();
        let handles: Vec<_> = (0..2)
            .map(|_| {
                let t = t.clone();
                std::thread::spawn(move || t.charge_read(5 << 20, Duration::ZERO))
            })
            .collect();
        for h in handles {
            h.join().unwrap();
        }
        let elapsed = start.elapsed().as_secs_f64();
        assert!(elapsed >= 0.09, "reads overlapped: {elapsed}");
    }

    #[test]
    fn disabled_throttle_is_instant() {
        let mut t = DeviceThrottle::new(slow_profile(1.0)); // absurdly slow
        t.enabled = false;
        let start = Instant::now();
        t.charge_read(1 << 30, Duration::ZERO);
        assert!(start.elapsed().as_millis() < 50);
        let t2 = DeviceThrottle::with_enabled(slow_profile(1.0), false);
        assert!(!t2.enabled);
        t2.charge_read(1 << 30, Duration::ZERO);
        assert!(start.elapsed().as_millis() < 100);
    }

    #[test]
    fn backlog_reflects_reserved_time() {
        let t = DeviceThrottle::new(slow_profile(100e6));
        assert_eq!(t.backlog_secs(), 0.0);
        // claim the time was already spent: reserves the slot, no sleep
        t.charge_read(10 << 20, Duration::from_secs(10));
        // the reservation window has already passed (already_spent >
        // device time), so backlog is back to ~0
        assert!(t.backlog_secs() < 0.2, "{}", t.backlog_secs());
    }

    #[test]
    fn already_spent_is_credited() {
        let t = DeviceThrottle::new(slow_profile(100e6));
        let start = Instant::now();
        // claim we already spent 95ms of the ~105ms budget
        t.charge_read(10 << 20, Duration::from_millis(95));
        assert!(start.elapsed().as_millis() < 60);
    }

    #[test]
    fn infinite_bw_profile_never_sleeps() {
        let t = DeviceThrottle::new(crate::hwsim::StorageProfile::dram());
        let start = Instant::now();
        for _ in 0..100 {
            t.charge_read(1 << 30, Duration::ZERO);
        }
        assert!(start.elapsed().as_millis() < 100);
    }

    #[test]
    fn penalty_occupies_the_device_but_moves_no_bytes() {
        let t = DeviceThrottle::new(slow_profile(100e6));
        let start = Instant::now();
        assert_eq!(t.charge_penalty(0.05, TrafficClass::Demand), 0.05);
        assert!(start.elapsed().as_secs_f64() >= 0.04, "penalty must sleep the device");
        assert_eq!(t.link().stats.total_bytes(), 0);
        assert!(t.link().stats.busy_secs() >= 0.049);
        // disabled: modeled seconds still returned, nothing reserved
        let off = DeviceThrottle::with_enabled(slow_profile(100e6), false);
        let start = Instant::now();
        assert_eq!(off.charge_penalty(5.0, TrafficClass::Demand), 5.0);
        assert!(start.elapsed().as_millis() < 100, "disabled penalty must not sleep");
        assert_eq!(off.link().stats.reserves(), 0);
        // degenerate inputs are no-ops
        assert_eq!(t.charge_penalty(0.0, TrafficClass::Demand), 0.0);
        assert_eq!(t.charge_penalty(f64::NAN, TrafficClass::Demand), 0.0);
    }

    #[test]
    fn traffic_classes_split_the_byte_counters() {
        let t = DeviceThrottle::new(slow_profile(f64::INFINITY));
        t.charge_read_as(1024, Duration::ZERO, TrafficClass::Prefetch);
        t.charge_read(512, Duration::ZERO);
        t.charge_write(256, Duration::ZERO);
        let stats = &t.link().stats;
        assert_eq!(stats.bytes_for(TrafficClass::Prefetch), 1024);
        assert_eq!(stats.bytes_for(TrafficClass::Demand), 512);
        assert_eq!(stats.bytes_for(TrafficClass::Write), 256);
    }
}
