//! Shared-device bandwidth/latency simulation.

use std::sync::Mutex;
use std::time::{Duration, Instant};

use crate::hwsim::StorageProfile;

/// Serializes simulated transfer time across concurrent users of one
/// storage device, like a real SSD's single internal bus.
///
/// Each transfer computes its device time from the profile, reserves a
/// slot `[start, start+t)` after the device's current `busy_until`, and
/// sleeps the caller until the slot ends (minus however long the real
/// filesystem I/O already took). With an unthrottled profile (DRAM tier)
/// this degenerates to a no-op.
#[derive(Debug)]
pub struct DeviceThrottle {
    profile: StorageProfile,
    busy_until: Mutex<Option<Instant>>,
    /// Disable sleeping entirely (pure-functional tests).
    pub enabled: bool,
}

impl DeviceThrottle {
    pub fn new(profile: StorageProfile) -> Self {
        DeviceThrottle { profile, busy_until: Mutex::new(None), enabled: true }
    }

    /// A throttle with sleeping pre-configured (sharded stores rebuild
    /// one throttle per shard when swapping profiles; see
    /// [`crate::kvstore::Shard`]).
    pub fn with_enabled(profile: StorageProfile, enabled: bool) -> Self {
        DeviceThrottle { profile, busy_until: Mutex::new(None), enabled }
    }

    pub fn profile(&self) -> &StorageProfile {
        &self.profile
    }

    /// Seconds until this device would be idle (0 when idle now) — a
    /// cheap backlog gauge for shard telemetry.
    pub fn backlog_secs(&self) -> f64 {
        let now = Instant::now();
        match *self.busy_until.lock().unwrap() {
            Some(b) if b > now => (b - now).as_secs_f64(),
            _ => 0.0,
        }
    }

    fn reserve(&self, device_secs: f64) -> Instant {
        let now = Instant::now();
        let mut busy = self.busy_until.lock().unwrap();
        let start = busy.filter(|b| *b > now).unwrap_or(now);
        let end = start + Duration::from_secs_f64(device_secs);
        *busy = Some(end);
        end
    }

    /// Charge a read of `bytes`; returns the simulated device seconds.
    /// `already_spent` is the real I/O time already consumed (subtracted
    /// from the injected sleep so total wall time matches the profile).
    pub fn charge_read(&self, bytes: usize, already_spent: Duration) -> f64 {
        self.charge(self.profile.read_secs(bytes), already_spent)
    }

    /// Charge a write of `bytes`; returns the simulated device seconds.
    pub fn charge_write(&self, bytes: usize, already_spent: Duration) -> f64 {
        self.charge(self.profile.write_secs(bytes), already_spent)
    }

    fn charge(&self, device_secs: f64, already_spent: Duration) -> f64 {
        if !self.enabled || !device_secs.is_finite() {
            return device_secs;
        }
        let end = self.reserve((device_secs - already_spent.as_secs_f64()).max(0.0));
        let now = Instant::now();
        if end > now {
            std::thread::sleep(end - now);
        }
        device_secs
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use std::sync::Arc;

    fn slow_profile(bw: f64) -> StorageProfile {
        StorageProfile {
            name: "test".into(),
            read_bw: bw,
            write_bw: bw,
            latency_s: 0.0,
            power_active: 1.0,
            power_idle: 0.0,
            usd_per_byte: 1e-9,
        }
    }

    #[test]
    fn read_takes_simulated_time() {
        let t = DeviceThrottle::new(slow_profile(100e6)); // 100 MB/s
        let start = Instant::now();
        let secs = t.charge_read(10 << 20, Duration::ZERO); // 10 MB → 100ms
        assert!((secs - 0.1048).abs() < 0.01, "{secs}");
        assert!(start.elapsed().as_secs_f64() >= 0.09);
    }

    #[test]
    fn concurrent_reads_serialize() {
        // Two 5MB reads at 100MB/s on one device take ~100ms total, not 50.
        let t = Arc::new(DeviceThrottle::new(slow_profile(100e6)));
        let start = Instant::now();
        let handles: Vec<_> = (0..2)
            .map(|_| {
                let t = t.clone();
                std::thread::spawn(move || t.charge_read(5 << 20, Duration::ZERO))
            })
            .collect();
        for h in handles {
            h.join().unwrap();
        }
        let elapsed = start.elapsed().as_secs_f64();
        assert!(elapsed >= 0.09, "reads overlapped: {elapsed}");
    }

    #[test]
    fn disabled_throttle_is_instant() {
        let mut t = DeviceThrottle::new(slow_profile(1.0)); // absurdly slow
        t.enabled = false;
        let start = Instant::now();
        t.charge_read(1 << 30, Duration::ZERO);
        assert!(start.elapsed().as_millis() < 50);
        let t2 = DeviceThrottle::with_enabled(slow_profile(1.0), false);
        assert!(!t2.enabled);
        t2.charge_read(1 << 30, Duration::ZERO);
        assert!(start.elapsed().as_millis() < 100);
    }

    #[test]
    fn backlog_reflects_reserved_time() {
        let t = DeviceThrottle::new(slow_profile(100e6));
        assert_eq!(t.backlog_secs(), 0.0);
        // claim the time was already spent: reserves the slot, no sleep
        t.charge_read(10 << 20, Duration::from_secs(10));
        // the reservation window has already passed (already_spent >
        // device time), so backlog is back to ~0
        assert!(t.backlog_secs() < 0.2, "{}", t.backlog_secs());
    }

    #[test]
    fn already_spent_is_credited() {
        let t = DeviceThrottle::new(slow_profile(100e6));
        let start = Instant::now();
        // claim we already spent 95ms of the ~105ms budget
        t.charge_read(10 << 20, Duration::from_millis(95));
        assert!(start.elapsed().as_millis() < 60);
    }

    #[test]
    fn infinite_bw_profile_never_sleeps() {
        let t = DeviceThrottle::new(crate::hwsim::StorageProfile::dram());
        let start = Instant::now();
        for _ in 0..100 {
            t.charge_read(1 << 30, Duration::ZERO);
        }
        assert!(start.elapsed().as_millis() < 100);
    }
}
