//! Shard layer of the materialized-KV store: one directory + one
//! [`DeviceThrottle`] per simulated storage device.
//!
//! A [`super::KvStore`] is a *set* of shards (a JBOD of independent
//! SSDs): every shard charges its own throttle, and misses to different
//! shards genuinely overlap in simulated device time — this is how
//! `load_many` bandwidth scales past a single bus. New chunks are
//! *placed* by cumulative bytes (the store's persisted placement map,
//! see [`super::KvStore::shard_index_of`]), so one large-chunk-heavy
//! shard can't serialize the fan-out; [`route`] remains the pure
//! (id, shard count) fallback hash for ids no placement record covers.
//! The shard count itself is pinned by a marker file the store writes
//! next to the shards (see [`super::KvStore::open_sharded`]).

use std::path::{Path, PathBuf};
use std::sync::atomic::{AtomicU64, Ordering};
use std::sync::{Arc, Mutex};
use std::time::Instant;

use anyhow::{Context, Result};

use super::throttle::DeviceThrottle;
use crate::hwsim::{FaultPlan, Link, StorageProfile, TrafficClass};
use crate::trace::{Arg, TraceBus};
use crate::vectordb::ChunkId;

/// Per-device cumulative counters plus live/peak queue-depth gauges
/// (relaxed atomics, mirroring [`super::StoreStats`] at device scope).
#[derive(Debug, Default)]
pub struct ShardStats {
    pub reads: AtomicU64,
    pub writes: AtomicU64,
    pub deletes: AtomicU64,
    pub bytes_read: AtomicU64,
    pub bytes_written: AtomicU64,
    /// Simulated device seconds spent in reads, stored as microseconds
    /// (atomics have no f64).
    pub read_device_us: AtomicU64,
    /// Simulated device seconds spent in writes, as microseconds.
    pub write_device_us: AtomicU64,
    /// Reads in flight against this device right now (queued on the
    /// throttle or mid-filesystem-read).
    pub queue_depth: AtomicU64,
    /// High-water mark of `queue_depth`.
    pub peak_queue_depth: AtomicU64,
    /// Writes that errored (filesystem failure or injected fault) —
    /// async store errors surface here instead of vanishing into a
    /// skipped stats bump.
    pub write_errors: AtomicU64,
}

impl ShardStats {
    pub fn read_device_secs(&self) -> f64 {
        self.read_device_us.load(Ordering::Relaxed) as f64 / 1e6
    }

    pub fn write_device_secs(&self) -> f64 {
        self.write_device_us.load(Ordering::Relaxed) as f64 / 1e6
    }

    fn enter_queue(&self) {
        let depth = self.queue_depth.fetch_add(1, Ordering::Relaxed) + 1;
        self.peak_queue_depth.fetch_max(depth, Ordering::Relaxed);
    }

    fn exit_queue(&self) {
        self.queue_depth.fetch_sub(1, Ordering::Relaxed);
    }

    fn count_read(&self, bytes: usize, device_secs: f64) {
        self.reads.fetch_add(1, Ordering::Relaxed);
        self.bytes_read.fetch_add(bytes as u64, Ordering::Relaxed);
        if device_secs.is_finite() && device_secs > 0.0 {
            self.read_device_us.fetch_add((device_secs * 1e6) as u64, Ordering::Relaxed);
        }
    }

    fn count_write(&self, bytes: usize, device_secs: f64) {
        self.writes.fetch_add(1, Ordering::Relaxed);
        self.bytes_written.fetch_add(bytes as u64, Ordering::Relaxed);
        if device_secs.is_finite() && device_secs > 0.0 {
            self.write_device_us.fetch_add((device_secs * 1e6) as u64, Ordering::Relaxed);
        }
    }
}

/// Stable *fallback* shard routing: a splitmix64 finalizer over the
/// chunk id, reduced mod the shard count. Purely deterministic — same
/// (id, count) always maps to the same shard, across reopens and
/// processes — and well-mixed even for the sequential ids the ingest
/// pipeline assigns. Count-balancing only: the store's byte-balanced
/// placement map supersedes this for every chunk it has a record for,
/// and legacy layouts written before the map existed still resolve
/// here.
pub fn route(id: ChunkId, n_shards: usize) -> usize {
    debug_assert!(n_shards > 0);
    let mut z = id.wrapping_add(0x9e37_79b9_7f4a_7c15);
    z = (z ^ (z >> 30)).wrapping_mul(0xbf58_476d_1ce4_e5b9);
    z = (z ^ (z >> 27)).wrapping_mul(0x94d0_49bb_1331_11eb);
    z ^= z >> 31;
    (z % n_shards as u64) as usize
}

/// One simulated storage device: a directory of `.kv` files behind its
/// own [`DeviceThrottle`], with per-device [`ShardStats`].
///
/// Shards hold only raw file bytes — encode/decode and the hot tier
/// live in [`super::KvStore`], which owns the shard set.
#[derive(Debug)]
pub struct Shard {
    index: usize,
    dir: PathBuf,
    throttle: Arc<DeviceThrottle>,
    /// Injected failure schedule; `None` (the default) is the fast
    /// clean path — reads and writes behave exactly as before faults
    /// existed.
    faults: Mutex<Option<Arc<FaultPlan>>>,
    /// Trace handle, post-construction like `faults` (the store wires
    /// it after the shard set exists). Disabled by default; the device
    /// link carries its own copy with an explicit per-shard track name
    /// because profile names repeat across shards.
    trace: Mutex<TraceBus>,
    pub stats: Arc<ShardStats>,
}

impl Shard {
    pub(crate) fn open(index: usize, dir: impl AsRef<Path>, profile: StorageProfile) -> Result<Self> {
        let dir = dir.as_ref().to_path_buf();
        std::fs::create_dir_all(&dir).with_context(|| format!("creating shard dir {dir:?}"))?;
        Ok(Shard {
            index,
            dir,
            throttle: Arc::new(DeviceThrottle::new(profile)),
            faults: Mutex::new(None),
            trace: Mutex::new(TraceBus::disabled()),
            stats: Arc::new(ShardStats::default()),
        })
    }

    /// A copy of this shard driving a different (or disabled) simulated
    /// device; cumulative [`ShardStats`] and the fault plan carry over.
    /// In-flight I/O keeps the old throttle, exactly like the pre-shard
    /// store's profile swap.
    pub(crate) fn with_profile(&self, profile: StorageProfile, enabled: bool) -> Shard {
        let shard = Shard {
            index: self.index,
            dir: self.dir.clone(),
            throttle: Arc::new(DeviceThrottle::with_enabled(profile, enabled)),
            faults: Mutex::new(self.faults.lock().unwrap().clone()),
            trace: Mutex::new(TraceBus::disabled()),
            stats: self.stats.clone(),
        };
        // The fresh throttle owns a fresh, untraced link — rewire it
        // (and the shard handle) so a profile swap can't silence an
        // already-attached trace.
        shard.set_trace(self.trace.lock().unwrap().clone());
        shard
    }

    /// Install (or clear) the shared fault plan.
    pub fn set_faults(&self, plan: Option<Arc<FaultPlan>>) {
        *self.faults.lock().unwrap() = plan;
    }

    /// Attach a trace bus: shard-level read events plus this device
    /// link's reservations, on tracks named by shard index (profile
    /// names repeat across a JBOD of identical devices).
    pub fn set_trace(&self, trace: TraceBus) {
        self.throttle.link().set_trace(
            trace.clone(),
            format!("link:shard{}:{}", self.index, self.throttle.profile().name),
        );
        *self.trace.lock().unwrap() = trace;
    }

    fn fault_plan(&self) -> Option<Arc<FaultPlan>> {
        self.faults.lock().unwrap().clone()
    }

    pub fn index(&self) -> usize {
        self.index
    }

    pub fn dir(&self) -> &Path {
        &self.dir
    }

    pub fn profile(&self) -> &StorageProfile {
        self.throttle.profile()
    }

    /// Seconds until this shard's simulated device would be idle (0 when
    /// idle now) — the backlog gauge the per-shard serve report prints.
    pub fn backlog_secs(&self) -> f64 {
        self.throttle.backlog_secs()
    }

    /// The device's contended link — queued/busy seconds and per-class
    /// (demand vs. prefetch) byte counters for the serve report.
    pub fn link(&self) -> &Link {
        self.throttle.link()
    }

    pub(crate) fn path_of(&self, id: ChunkId) -> PathBuf {
        self.dir.join(format!("{id:016x}.kv"))
    }

    pub(crate) fn contains(&self, id: ChunkId) -> bool {
        self.path_of(id).exists()
    }

    /// Read a chunk's raw file bytes, throttled to this shard's device.
    /// `class` tags the transfer in the link's byte counters (demand
    /// miss vs. speculative prefetch). Returns the bytes plus the
    /// simulated device seconds charged.
    ///
    /// With a fault plan installed this is the injection choke point:
    /// the plan is consulted once per read *attempt* (retries advance
    /// the shard's fault sequence), and may slow the read, fail it, or
    /// flip one payload bit in the returned buffer — the file on disk
    /// is never touched, so the recompute safety net always has intact
    /// bytes to fall back on.
    pub(crate) fn read(&self, id: ChunkId, class: TrafficClass) -> Result<(Vec<u8>, f64)> {
        let fault = self.fault_plan().map(|p| p.on_read(self.index));
        if let Some(reason) = fault.as_ref().and_then(|f| f.fail) {
            return Err(anyhow::anyhow!("shard {}: {reason} reading KV {id:016x}", self.index));
        }
        let path = self.path_of(id);
        self.stats.enter_queue();
        let result = (|| {
            let start = Instant::now();
            let mut data =
                std::fs::read(&path).with_context(|| format!("loading KV {path:?}"))?;
            let mut device_secs =
                self.throttle.charge_read_as(data.len(), start.elapsed(), class);
            if let Some(f) = &fault {
                if f.slow_factor > 1.0 {
                    // The extra latency occupies the device like any
                    // other transfer (queues behind it, sleeps on a
                    // wall-clock link).
                    device_secs +=
                        self.throttle.charge_penalty((f.slow_factor - 1.0) * device_secs, class);
                }
                if let Some(h) = f.corrupt {
                    // One bit in the back half of the record — always
                    // payload, never the header, so the lie is silent
                    // until the checksum looks.
                    let lo = data.len() / 2;
                    if lo < data.len() {
                        data[lo + (h as usize % (data.len() - lo))] ^= 1 << ((h >> 32) % 8);
                    }
                }
            }
            Ok((data, device_secs))
        })();
        self.stats.exit_queue();
        if let Ok((data, device_secs)) = &result {
            self.stats.count_read(data.len(), *device_secs);
            let bus = self.trace.lock().unwrap().clone();
            if bus.enabled() {
                // Unclocked: shard reads run on wall/sleep clocks, so
                // only the modeled duration and payload are recorded.
                bus.event(
                    &format!("shard{}", self.index),
                    "read",
                    *device_secs,
                    &[("id", Arg::U(id)), ("bytes", Arg::U(data.len() as u64))],
                );
            }
        }
        result
    }

    /// Charge a retry-backoff wait against this shard's device link so
    /// recovery costs simulated time (sleeps on a wall-clock link,
    /// no-op accounting when the throttle is disabled). Returns the
    /// modeled seconds.
    pub(crate) fn charge_backoff(&self, secs: f64) -> f64 {
        self.throttle.charge_penalty(secs, TrafficClass::Demand)
    }

    /// Write a chunk's encoded bytes, throttled; returns simulated
    /// device seconds. Stats count only successful writes; failures
    /// (filesystem or injected) bump `write_errors`.
    pub(crate) fn write(&self, id: ChunkId, buf: &[u8]) -> Result<f64> {
        if let Some(reason) = self.fault_plan().and_then(|p| p.on_write(self.index)) {
            self.stats.write_errors.fetch_add(1, Ordering::Relaxed);
            return Err(anyhow::anyhow!("shard {}: {reason} writing KV {id:016x}", self.index));
        }
        let path = self.path_of(id);
        let start = Instant::now();
        if let Err(e) = std::fs::write(&path, buf) {
            self.stats.write_errors.fetch_add(1, Ordering::Relaxed);
            return Err(anyhow::Error::new(e).context(format!("writing KV {path:?}")));
        }
        let device_secs = self.throttle.charge_write(buf.len(), start.elapsed());
        self.stats.count_write(buf.len(), device_secs);
        Ok(device_secs)
    }

    /// Unlink a chunk's file; `Ok(false)` when it was not present.
    pub(crate) fn delete(&self, id: ChunkId) -> Result<bool> {
        match std::fs::remove_file(self.path_of(id)) {
            Ok(()) => {
                self.stats.deletes.fetch_add(1, Ordering::Relaxed);
                Ok(true)
            }
            Err(e) if e.kind() == std::io::ErrorKind::NotFound => Ok(false),
            Err(e) => Err(e.into()),
        }
    }

    /// Number of `.kv` files resident in this shard.
    pub(crate) fn len(&self) -> Result<usize> {
        Ok(std::fs::read_dir(&self.dir)?
            .filter_map(|e| e.ok())
            .filter(|e| e.path().extension().is_some_and(|x| x == "kv"))
            .count())
    }

    /// Total bytes of `.kv` files in this shard.
    pub(crate) fn bytes_on_disk(&self) -> Result<u64> {
        let mut total = 0;
        for e in std::fs::read_dir(&self.dir)? {
            let e = e?;
            if e.path().extension().is_some_and(|x| x == "kv") {
                total += e.metadata()?.len();
            }
        }
        Ok(total)
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn routing_is_deterministic_and_in_range() {
        for n in [1usize, 2, 3, 4, 8, 16] {
            for id in 0..1000u64 {
                let s = route(id, n);
                assert!(s < n);
                assert_eq!(s, route(id, n), "routing must be a pure function");
            }
        }
    }

    #[test]
    fn routing_spreads_sequential_ids() {
        // Ingest assigns sequential doc ids; the mix must still spread
        // them: no shard may take more than twice its fair share.
        let n = 4usize;
        let mut counts = [0usize; 4];
        for id in 0..1024u64 {
            counts[route(id, n)] += 1;
        }
        for (i, &c) in counts.iter().enumerate() {
            assert!(c > 1024 / n / 2 && c < 1024 / n * 2, "shard {i}: {c}/1024");
        }
    }

    #[test]
    fn single_shard_routes_everything_to_zero() {
        for id in [0u64, 1, u64::MAX, 0xdead_beef] {
            assert_eq!(route(id, 1), 0);
        }
    }

    #[test]
    fn shard_read_write_roundtrip_counts_stats() {
        let dir = crate::util::tempdir::TempDir::new("matkv-shard-test").unwrap();
        let shard = Shard::open(0, dir.path(), StorageProfile::dram()).unwrap();
        let payload = vec![7u8; 1024];
        shard.write(42, &payload).unwrap();
        let (back, _secs) = shard.read(42, TrafficClass::Demand).unwrap();
        assert_eq!(back, payload);
        assert_eq!(shard.stats.reads.load(Ordering::Relaxed), 1);
        assert_eq!(shard.stats.writes.load(Ordering::Relaxed), 1);
        assert_eq!(shard.stats.bytes_read.load(Ordering::Relaxed), 1024);
        assert_eq!(shard.stats.bytes_written.load(Ordering::Relaxed), 1024);
        assert_eq!(shard.stats.queue_depth.load(Ordering::Relaxed), 0);
        assert!(shard.stats.peak_queue_depth.load(Ordering::Relaxed) >= 1);
        assert!(shard.delete(42).unwrap());
        assert!(!shard.delete(42).unwrap());
        assert!(shard.read(42, TrafficClass::Demand).is_err());
        assert_eq!(shard.stats.reads.load(Ordering::Relaxed), 1, "failed read not counted");
    }

    #[test]
    fn with_profile_keeps_stats_and_dir() {
        let dir = crate::util::tempdir::TempDir::new("matkv-shard-prof").unwrap();
        let shard = Shard::open(3, dir.path(), StorageProfile::dram()).unwrap();
        shard.write(1, &[0u8; 64]).unwrap();
        let swapped = shard.with_profile(StorageProfile::ssd_9100pro(), false);
        assert_eq!(swapped.index(), 3);
        assert_eq!(swapped.profile().name, "9100Pro");
        assert_eq!(swapped.stats.writes.load(Ordering::Relaxed), 1, "stats must carry over");
        assert_eq!(swapped.len().unwrap(), 1);
    }

    #[test]
    fn injected_read_faults_stall_then_heal_and_corrupt_in_memory_only() {
        let dir = crate::util::tempdir::TempDir::new("matkv-shard-fault").unwrap();
        let shard = Shard::open(0, dir.path(), StorageProfile::dram()).unwrap();
        let payload: Vec<u8> = (0..255u8).collect();
        shard.write(9, &payload).unwrap();
        shard.set_faults(Some(Arc::new(
            FaultPlan::parse("shard0:stall@0..2, shard0:corrupt@2").unwrap(),
        )));
        // reads 0 and 1 error (no file touched, stats uncounted)...
        assert!(shard.read(9, TrafficClass::Demand).is_err());
        assert!(shard.read(9, TrafficClass::Demand).is_err());
        assert_eq!(shard.stats.reads.load(Ordering::Relaxed), 1, "faulted reads not counted");
        // ...read 2 heals but returns exactly one flipped bit...
        let (bad, _) = shard.read(9, TrafficClass::Demand).unwrap();
        assert_ne!(bad, payload, "corrupt read must differ");
        let flipped: u32 =
            bad.iter().zip(&payload).map(|(a, b)| (a ^ b).count_ones()).sum();
        assert_eq!(flipped, 1, "exactly one bit flips");
        // ...and the file itself stayed intact: read 3 is clean.
        let (good, _) = shard.read(9, TrafficClass::Demand).unwrap();
        assert_eq!(good, payload);
        // clearing the plan restores the unfaulted path
        shard.set_faults(None);
        assert_eq!(shard.read(9, TrafficClass::Demand).unwrap().0, payload);
    }

    #[test]
    fn injected_write_failure_counts_write_errors() {
        let dir = crate::util::tempdir::TempDir::new("matkv-shard-wfail").unwrap();
        let shard = Shard::open(0, dir.path(), StorageProfile::dram()).unwrap();
        shard.set_faults(Some(Arc::new(FaultPlan::parse("shard0:wfail@0").unwrap())));
        assert!(shard.write(1, &[1u8; 64]).is_err());
        assert_eq!(shard.stats.write_errors.load(Ordering::Relaxed), 1);
        assert_eq!(shard.stats.writes.load(Ordering::Relaxed), 0, "failed write not counted");
        assert!(!shard.contains(1), "failed write must not leave a file");
        // next write (past the window) lands
        shard.write(1, &[1u8; 64]).unwrap();
        assert_eq!(shard.stats.writes.load(Ordering::Relaxed), 1);
        assert_eq!(shard.stats.write_errors.load(Ordering::Relaxed), 1);
    }

    #[test]
    fn with_profile_carries_the_fault_plan() {
        let dir = crate::util::tempdir::TempDir::new("matkv-shard-fault-prof").unwrap();
        let shard = Shard::open(0, dir.path(), StorageProfile::dram()).unwrap();
        shard.write(2, &[3u8; 32]).unwrap();
        shard.set_faults(Some(Arc::new(FaultPlan::parse("shard0:die@0").unwrap())));
        let swapped = shard.with_profile(StorageProfile::dram(), false);
        assert!(
            swapped.read(2, TrafficClass::Demand).is_err(),
            "profile swap must not drop the fault plan"
        );
    }

    #[test]
    fn concurrent_reads_track_peak_queue_depth() {
        let dir = crate::util::tempdir::TempDir::new("matkv-shard-queue").unwrap();
        let shard = Arc::new(Shard::open(0, dir.path(), StorageProfile::dram()).unwrap());
        for id in 0..8u64 {
            shard.write(id, &vec![id as u8; 4096]).unwrap();
        }
        let handles: Vec<_> = (0..8u64)
            .map(|id| {
                let s = shard.clone();
                std::thread::spawn(move || s.read(id, TrafficClass::Demand).unwrap())
            })
            .collect();
        for h in handles {
            h.join().unwrap();
        }
        assert_eq!(shard.stats.reads.load(Ordering::Relaxed), 8);
        assert_eq!(shard.stats.queue_depth.load(Ordering::Relaxed), 0, "gauge must drain");
        assert!(shard.stats.peak_queue_depth.load(Ordering::Relaxed) >= 1);
    }
}
