//! Byte-budgeted DRAM hot tier in front of the flash-backed [`KvStore`].
//!
//! Fig 2's access distribution is heavily skewed: a small set of popular
//! chunks absorbs most retrievals. Keeping exactly that set resident in
//! DRAM turns the serve hot path's dominant cost — bytes moved from the
//! storage device per request — into a memory reference for the popular
//! mass, while the flash tier keeps the corpus-sized tail cheap. This is
//! the first rung of the storage hierarchy ("LLM in a flash" /
//! kv-cache-tier style): DRAM (hot) over flash (capacity).
//!
//! The tier is an LRU over decoded [`KvChunk`]s, budgeted in *resident
//! bytes* ([`KvChunk::dram_bytes`], f32 planes — decode cost is paid once
//! at fill time, hits hand out `Arc` clones with zero copies). It is
//! `Sync`: the overlap pipeline's loader thread and any number of
//! concurrent `load_many` workers share one tier through the store's
//! `Arc`.
//!
//! [`KvStore`]: super::KvStore

use std::collections::{BTreeMap, HashMap};
use std::sync::atomic::{AtomicU64, Ordering};
use std::sync::{Arc, Mutex};

use super::store::KvChunk;
use crate::vectordb::ChunkId;

/// Cumulative hit/miss/eviction counters (relaxed atomics, like
/// [`super::StoreStats`]).
#[derive(Debug, Default)]
pub struct CacheStats {
    pub hits: AtomicU64,
    pub misses: AtomicU64,
    pub insertions: AtomicU64,
    pub evictions: AtomicU64,
    /// On-disk bytes that hits avoided reading from the device.
    pub bytes_saved: AtomicU64,
}

impl CacheStats {
    /// Hits / (hits + misses); 0 when the tier was never consulted.
    pub fn hit_ratio(&self) -> f64 {
        let h = self.hits.load(Ordering::Relaxed) as f64;
        let m = self.misses.load(Ordering::Relaxed) as f64;
        if h + m > 0.0 {
            h / (h + m)
        } else {
            0.0
        }
    }
}

/// Outcome of a [`HotTier::probe`].
pub enum Probe {
    /// Resident: the chunk plus the on-disk bytes the hit avoided.
    Hit(Arc<KvChunk>, usize),
    /// Not resident: the id's current invalidation generation (to pass
    /// to [`HotTier::insert_at`] after the device read).
    Miss(u64),
}

struct Entry {
    chunk: Arc<KvChunk>,
    /// Size of the backing file (what a miss would have read).
    file_bytes: usize,
    /// Resident bytes charged against the budget.
    cost: usize,
    /// Recency stamp; key into `Lru::order`.
    tick: u64,
}

#[derive(Default)]
struct Lru {
    map: HashMap<ChunkId, Entry>,
    /// tick → id, oldest first (ticks are unique: one logical clock).
    order: BTreeMap<u64, ChunkId>,
    /// Per-id invalidation generation (bumped by [`HotTier::invalidate`];
    /// a missing entry means generation 0). Lets loaders detect that a
    /// write/delete raced *their* chunk's file read without suppressing
    /// admission of unrelated chunks (see [`HotTier::insert_at`]). Tiny:
    /// two u64 per ever-invalidated id, vs megabytes per cached chunk.
    gens: HashMap<ChunkId, u64>,
    bytes: usize,
    clock: u64,
}

/// The DRAM hot tier: an LRU map `ChunkId → Arc<KvChunk>` holding at
/// most `budget` resident bytes.
pub struct HotTier {
    budget: usize,
    lru: Mutex<Lru>,
    pub stats: CacheStats,
}

impl HotTier {
    pub fn new(budget_bytes: usize) -> Self {
        HotTier {
            budget: budget_bytes,
            lru: Mutex::new(Lru::default()),
            stats: CacheStats::default(),
        }
    }

    pub fn budget(&self) -> usize {
        self.budget
    }

    /// Resident bytes currently held.
    pub fn bytes(&self) -> usize {
        self.lru.lock().unwrap().bytes
    }

    /// Number of resident chunks.
    pub fn len(&self) -> usize {
        self.lru.lock().unwrap().map.len()
    }

    pub fn is_empty(&self) -> bool {
        self.len() == 0
    }

    /// Look up a chunk. A hit promotes it to most-recently-used and
    /// returns the chunk plus the file bytes the hit avoided reading.
    pub fn get(&self, id: ChunkId) -> Option<(Arc<KvChunk>, usize)> {
        match self.probe(id) {
            Probe::Hit(chunk, file_bytes) => Some((chunk, file_bytes)),
            Probe::Miss(_) => None,
        }
    }

    /// Single-lock lookup for the load path: a hit promotes the entry
    /// and returns it; a miss also reports the id's current invalidation
    /// generation, so the caller can admit the upcoming device read via
    /// [`HotTier::insert_at`] without re-taking the lock.
    pub fn probe(&self, id: ChunkId) -> Probe {
        let mut guard = self.lru.lock().unwrap();
        let lru = &mut *guard;
        lru.clock += 1;
        let tick = lru.clock;
        let gen = lru.gens.get(&id).copied().unwrap_or(0);
        let Some(e) = lru.map.get_mut(&id) else {
            self.stats.misses.fetch_add(1, Ordering::Relaxed);
            return Probe::Miss(gen);
        };
        let old_tick = std::mem::replace(&mut e.tick, tick);
        let chunk = e.chunk.clone();
        let file_bytes = e.file_bytes;
        lru.order.remove(&old_tick);
        lru.order.insert(tick, id);
        self.stats.hits.fetch_add(1, Ordering::Relaxed);
        self.stats.bytes_saved.fetch_add(file_bytes as u64, Ordering::Relaxed);
        Probe::Hit(chunk, file_bytes)
    }

    /// Current invalidation generation of `id`. Loaders capture it
    /// *before* reading the backing file and pass it to
    /// [`HotTier::insert_at`] so a read that raced a re-materialization
    /// of the same chunk can never populate the tier with superseded
    /// bytes.
    pub fn generation(&self, id: ChunkId) -> u64 {
        self.lru.lock().unwrap().gens.get(&id).copied().unwrap_or(0)
    }

    /// Drop `id` and advance its generation. Writers call this on both
    /// sides of the file write (and deleters around the unlink): the
    /// generation bump rejects in-flight stale inserts of this id, and
    /// the remove cleans up any that slipped in under the old one.
    pub fn invalidate(&self, id: ChunkId) {
        let mut guard = self.lru.lock().unwrap();
        let lru = &mut *guard;
        *lru.gens.entry(id).or_insert(0) += 1;
        if let Some(e) = lru.map.remove(&id) {
            lru.order.remove(&e.tick);
            lru.bytes -= e.cost;
        }
    }

    /// Insert (or refresh) a chunk, then evict least-recently-used
    /// entries until the tier is back under budget. `file_bytes` is the
    /// on-disk size recorded for hit accounting; the budget is charged
    /// at DRAM footprint. A chunk larger than the whole budget is not
    /// admitted (it would evict everything for a single resident).
    pub fn insert(&self, id: ChunkId, chunk: Arc<KvChunk>, file_bytes: usize) {
        let gen = self.generation(id);
        self.insert_at(id, chunk, file_bytes, gen);
    }

    /// [`HotTier::insert`] guarded by the id's invalidation generation:
    /// if this chunk was invalidated since `seen_gen` was captured, the
    /// loaded bytes may be stale and are not admitted. Invalidations of
    /// *other* ids don't interfere.
    pub fn insert_at(&self, id: ChunkId, chunk: Arc<KvChunk>, file_bytes: usize, seen_gen: u64) {
        let cost = chunk.dram_bytes();
        if cost > self.budget {
            return;
        }
        let mut guard = self.lru.lock().unwrap();
        let lru = &mut *guard;
        if lru.gens.get(&id).copied().unwrap_or(0) != seen_gen {
            return; // a write/delete raced this load; don't cache stale bytes
        }
        lru.clock += 1;
        let tick = lru.clock;
        if let Some(old) = lru.map.remove(&id) {
            lru.order.remove(&old.tick);
            lru.bytes -= old.cost;
        }
        lru.bytes += cost;
        lru.map.insert(id, Entry { chunk, file_bytes, cost, tick });
        lru.order.insert(tick, id);
        self.stats.insertions.fetch_add(1, Ordering::Relaxed);
        while lru.bytes > self.budget {
            let Some((&oldest, &evict)) = lru.order.iter().next() else { break };
            lru.order.remove(&oldest);
            if let Some(e) = lru.map.remove(&evict) {
                lru.bytes -= e.cost;
                self.stats.evictions.fetch_add(1, Ordering::Relaxed);
            }
        }
    }

}

#[cfg(test)]
mod tests {
    use super::*;

    fn chunk(seed: u32) -> Arc<KvChunk> {
        let plane = 2 * 2 * 8 * 4;
        Arc::new(KvChunk {
            config_id: 1,
            n_layers: 2,
            n_kv_heads: 2,
            seq_len: 8,
            head_dim: 4,
            k: (0..plane).map(|i| (i + seed) as f32).collect(),
            v: (0..plane).map(|i| -((i + seed) as f32)).collect(),
        })
    }

    fn cost() -> usize {
        chunk(0).dram_bytes()
    }

    #[test]
    fn lru_eviction_order() {
        let tier = HotTier::new(2 * cost());
        tier.insert(1, chunk(1), 100);
        tier.insert(2, chunk(2), 100);
        assert!(tier.get(1).is_some()); // promote 1 → LRU victim is 2
        tier.insert(3, chunk(3), 100);
        assert_eq!(tier.len(), 2);
        assert!(tier.get(2).is_none(), "LRU entry must be the one evicted");
        assert!(tier.get(1).is_some());
        assert!(tier.get(3).is_some());
        assert_eq!(tier.stats.evictions.load(Ordering::Relaxed), 1);
    }

    #[test]
    fn byte_budget_enforced() {
        let budget = 2 * cost() + cost() / 2;
        let tier = HotTier::new(budget);
        for i in 0..5 {
            tier.insert(i, chunk(i as u32), 100);
            assert!(tier.bytes() <= budget, "over budget after insert {i}");
        }
        assert_eq!(tier.len(), 2);
        assert_eq!(tier.bytes(), 2 * cost());
    }

    #[test]
    fn oversize_chunk_not_admitted() {
        let tier = HotTier::new(cost() - 1);
        tier.insert(1, chunk(1), 100);
        assert_eq!(tier.len(), 0);
        assert_eq!(tier.bytes(), 0);
    }

    #[test]
    fn hit_miss_stats() {
        let tier = HotTier::new(4 * cost());
        assert!(tier.get(7).is_none());
        tier.insert(7, chunk(7), 640);
        let (c, fb) = tier.get(7).unwrap();
        assert_eq!(c.k, chunk(7).k);
        assert_eq!(fb, 640);
        tier.get(7).unwrap();
        assert_eq!(tier.stats.hits.load(Ordering::Relaxed), 2);
        assert_eq!(tier.stats.misses.load(Ordering::Relaxed), 1);
        assert_eq!(tier.stats.bytes_saved.load(Ordering::Relaxed), 2 * 640);
        assert!((tier.stats.hit_ratio() - 2.0 / 3.0).abs() < 1e-9);
    }

    #[test]
    fn reinsert_replaces_without_double_charge() {
        let tier = HotTier::new(4 * cost());
        tier.insert(1, chunk(1), 100);
        tier.insert(1, chunk(9), 100);
        assert_eq!(tier.len(), 1);
        assert_eq!(tier.bytes(), cost());
        assert_eq!(tier.get(1).unwrap().0.k, chunk(9).k, "stale chunk survived reinsert");
    }

    #[test]
    fn generation_guard_rejects_stale_insert() {
        let tier = HotTier::new(4 * cost());
        // loader captured the generation, then a writer invalidated: the
        // loader's (possibly stale) chunk must not be admitted.
        let seen = tier.generation(9);
        tier.invalidate(9);
        tier.insert_at(9, chunk(9), 100, seen);
        assert_eq!(tier.len(), 0);
        assert!(tier.get(9).is_none());
        // a load that starts after the invalidation is admitted
        tier.insert_at(9, chunk(9), 100, tier.generation(9));
        assert!(tier.get(9).is_some());
        // invalidating one id never suppresses admission of another
        let other = tier.generation(8);
        tier.invalidate(9);
        tier.insert_at(8, chunk(8), 100, other);
        assert!(tier.get(8).is_some(), "unrelated invalidation blocked admission");
    }

    #[test]
    fn invalidate_drops_entry() {
        let tier = HotTier::new(4 * cost());
        tier.insert(1, chunk(1), 100);
        tier.invalidate(1);
        assert_eq!(tier.len(), 0);
        assert_eq!(tier.bytes(), 0);
        assert!(tier.get(1).is_none());
        tier.invalidate(1); // idempotent on absent entries
    }
}
