//! Byte-budgeted DRAM hot tier in front of the flash-backed [`KvStore`].
//!
//! Fig 2's access distribution is heavily skewed: a small set of popular
//! chunks absorbs most retrievals. Keeping exactly that set resident in
//! DRAM turns the serve hot path's dominant cost — bytes moved from the
//! storage device per request — into a memory reference for the popular
//! mass, while the flash tier keeps the corpus-sized tail cheap. This is
//! the first rung of the storage hierarchy ("LLM in a flash" /
//! kv-cache-tier style): DRAM (hot) over flash (capacity).
//!
//! The tier is an LRU over decoded [`KvChunk`]s, budgeted in *resident
//! bytes* ([`KvChunk::dram_bytes`], f32 planes — decode cost is paid once
//! at fill time, hits hand out `Arc` clones with zero copies). It is
//! `Sync`: the overlap pipeline's loader thread and any number of
//! concurrent `load_many` workers share one tier through the store's
//! `Arc`.
//!
//! Admission is pluggable ([`AdmissionPolicy`]): the default admits
//! every miss LRU-style; `TinyLfu` consults a compact frequency sketch
//! ([TinyLFU](https://arxiv.org/abs/1512.00727)-style count-min counters
//! with periodic halving) and refuses candidates whose estimated access
//! frequency does not beat the would-be LRU victim's — so one
//! sequential scan of cold chunks can no longer flush the resident hot
//! set.
//!
//! [`KvStore`]: super::KvStore

use std::collections::{BTreeMap, HashMap};
use std::sync::atomic::{AtomicU64, Ordering};
use std::sync::{Arc, Mutex, RwLock};

use super::store::KvChunk;
use crate::trace::{Arg, TraceBus};
use crate::vectordb::ChunkId;

/// Which DRAM tier a stats object / telemetry sample belongs to, so the
/// hot (f32) and warm (q8, [`super::WarmTier`]) series stay
/// distinguishable once both land in one bench JSON document. Existing
/// consumers keep working: the default is `Hot`, which serializes to the
/// `"hot"` label every pre-warm-tier sample implicitly had.
#[derive(Debug, Clone, Copy, Default, PartialEq, Eq)]
pub enum TierKind {
    #[default]
    Hot,
    Warm,
}

impl TierKind {
    /// The label emitted into telemetry JSON.
    pub fn label(self) -> &'static str {
        match self {
            TierKind::Hot => "hot",
            TierKind::Warm => "warm",
        }
    }
}

// The sample shape and series machinery moved to [`crate::obs::tier`]
// (PR 10); these re-exports are the compatibility shim — every
// pre-registry consumer imported them from `kvstore`.
pub use crate::obs::tier::{series_to_json, CacheSample, TierMetrics, TierSeries};

/// Cumulative hit/miss/eviction counters (relaxed atomics, like
/// [`super::StoreStats`]).
#[derive(Debug, Default)]
pub struct CacheStats {
    /// Which DRAM tier these counters belong to (hot f32 / warm q8).
    pub tier: TierKind,
    pub hits: AtomicU64,
    pub misses: AtomicU64,
    pub insertions: AtomicU64,
    pub evictions: AtomicU64,
    /// On-disk bytes that hits avoided reading from the device.
    pub bytes_saved: AtomicU64,
    /// Chunks admitted through the prefetch path ([`HotTier::insert_prefetch`]).
    pub prefetch_inserts: AtomicU64,
    /// Demand hits served by a still-unread prefetched entry — the reads
    /// the prefetcher converted from device time into tier hits.
    pub prefetch_hits: AtomicU64,
    /// Prefetch admissions dropped to protect demand-resident chunks.
    pub prefetch_rejected: AtomicU64,
    /// Demand admissions refused by the TinyLFU frequency gate (the
    /// candidate's sketch estimate did not beat the LRU victim's).
    /// Always 0 under [`AdmissionPolicy::Lru`]. Deliberately *not* part
    /// of [`CacheSample`]: the telemetry JSON shape is pinned by
    /// downstream consumers; benches that A/B admission policies read
    /// this counter directly.
    pub admission_rejected: AtomicU64,
    /// Modeled dequant nanoseconds charged to q8 hits (warm tier; the
    /// nano granularity keeps the counter an integer atomic — like the
    /// shard stats' device clocks — while staying nonzero even for the
    /// tiny chunks unit tests dequantize).
    pub dequant_ns: AtomicU64,
    /// Modeled quantization nanoseconds charged to chunks entering the
    /// q8 tier — demote-on-evict, direct q8 admissions, and prefetches
    /// parked in warm. The symmetric twin of `dequant_ns`.
    pub quant_ns: AtomicU64,
    /// Modeled dequant nanoseconds charged to **q4** hits (warm tier in
    /// q4 mode). Kept apart from `dequant_ns` so fig JSONs can
    /// attribute the deeper-compression trade to its own clock; not
    /// part of [`CacheSample`] (that JSON shape is pinned).
    pub q4_dequant_ns: AtomicU64,
    /// Modeled quantization nanoseconds charged to chunks entering the
    /// tier in **q4** mode — the symmetric twin of `q4_dequant_ns`.
    pub q4_quant_ns: AtomicU64,
    /// Nanoseconds this tier's quant/dequant transfers spent *queued*
    /// on the shared host bus ([`crate::hwsim::Link`]) — contention
    /// telemetry on top of the modeled charge, not an extra charge.
    pub link_queued_ns: AtomicU64,
    /// Sampled cumulative snapshots ([`CacheStats::record_sample`]) —
    /// the shared bounded buffer from [`crate::obs::tier`].
    series: TierSeries,
}

impl CacheStats {
    /// Stats tagged for a specific tier (the default is [`TierKind::Hot`]).
    pub fn for_tier(tier: TierKind) -> Self {
        CacheStats { tier, ..CacheStats::default() }
    }

    /// Charge modeled dequantization time to this tier's clock.
    pub fn add_dequant_secs(&self, secs: f64) {
        self.dequant_ns.fetch_add((secs * 1e9) as u64, Ordering::Relaxed);
    }

    /// Total modeled dequantization seconds charged so far.
    pub fn dequant_secs(&self) -> f64 {
        self.dequant_ns.load(Ordering::Relaxed) as f64 / 1e9
    }

    /// Charge modeled quantization time (chunk entering the q8 tier).
    pub fn add_quant_secs(&self, secs: f64) {
        self.quant_ns.fetch_add((secs * 1e9) as u64, Ordering::Relaxed);
    }

    /// Total modeled quantization seconds charged so far.
    pub fn quant_secs(&self) -> f64 {
        self.quant_ns.load(Ordering::Relaxed) as f64 / 1e9
    }

    /// Charge modeled q4 dequantization time (q4-mode warm hits).
    pub fn add_q4_dequant_secs(&self, secs: f64) {
        self.q4_dequant_ns.fetch_add((secs * 1e9) as u64, Ordering::Relaxed);
    }

    /// Total modeled q4 dequantization seconds charged so far.
    pub fn q4_dequant_secs(&self) -> f64 {
        self.q4_dequant_ns.load(Ordering::Relaxed) as f64 / 1e9
    }

    /// Charge modeled q4 quantization time (chunk entering a q4 tier).
    pub fn add_q4_quant_secs(&self, secs: f64) {
        self.q4_quant_ns.fetch_add((secs * 1e9) as u64, Ordering::Relaxed);
    }

    /// Total modeled q4 quantization seconds charged so far.
    pub fn q4_quant_secs(&self) -> f64 {
        self.q4_quant_ns.load(Ordering::Relaxed) as f64 / 1e9
    }

    /// Record host-bus queueing delay a quant/dequant transfer saw.
    pub fn add_link_queued_secs(&self, secs: f64) {
        self.link_queued_ns.fetch_add((secs * 1e9) as u64, Ordering::Relaxed);
    }

    /// Total host-bus queueing seconds this tier's traffic absorbed.
    pub fn link_queued_secs(&self) -> f64 {
        self.link_queued_ns.load(Ordering::Relaxed) as f64 / 1e9
    }

    /// Hits / (hits + misses); 0 when the tier was never consulted.
    pub fn hit_ratio(&self) -> f64 {
        let h = self.hits.load(Ordering::Relaxed) as f64;
        let m = self.misses.load(Ordering::Relaxed) as f64;
        if h + m > 0.0 {
            h / (h + m)
        } else {
            0.0
        }
    }

    /// Cumulative snapshot of the counters (residency supplied by the
    /// caller, which owns the LRU lock discipline).
    pub fn snapshot(&self, resident_bytes: usize, resident_chunks: usize) -> CacheSample {
        CacheSample {
            tier: self.tier,
            dequant_secs: self.dequant_secs(),
            quant_secs: self.quant_secs(),
            link_queued_secs: self.link_queued_secs(),
            hits: self.hits.load(Ordering::Relaxed),
            misses: self.misses.load(Ordering::Relaxed),
            insertions: self.insertions.load(Ordering::Relaxed),
            evictions: self.evictions.load(Ordering::Relaxed),
            prefetch_inserts: self.prefetch_inserts.load(Ordering::Relaxed),
            prefetch_hits: self.prefetch_hits.load(Ordering::Relaxed),
            prefetch_rejected: self.prefetch_rejected.load(Ordering::Relaxed),
            resident_bytes: resident_bytes as u64,
            resident_chunks: resident_chunks as u64,
        }
    }

    /// Append a snapshot to the telemetry series (no-op past the
    /// buffer's cap).
    pub fn record_sample(&self, resident_bytes: usize, resident_chunks: usize) {
        self.series.record(self.snapshot(resident_bytes, resident_chunks));
    }

    /// The sampled telemetry series recorded so far.
    pub fn series(&self) -> Vec<CacheSample> {
        self.series.samples()
    }

    /// Exhaustive point-in-time JSON of every counter, in sorted key
    /// order — the `--metrics-json` "tiers" entry. Unlike the pinned
    /// [`CacheSample`] shape, this carries the full set, including
    /// `admission_rejected` and the q4 clocks.
    pub fn to_full_json(&self, resident_bytes: usize, resident_chunks: usize) -> String {
        format!(
            "{{\"admission_rejected\":{},\"bytes_saved\":{},\"dequant_secs\":{:.9},\
             \"evictions\":{},\"hits\":{},\"insertions\":{},\"link_queued_secs\":{:.9},\
             \"misses\":{},\"prefetch_hits\":{},\"prefetch_inserts\":{},\
             \"prefetch_rejected\":{},\"q4_dequant_secs\":{:.9},\"q4_quant_secs\":{:.9},\
             \"quant_secs\":{:.9},\"resident_bytes\":{},\"resident_chunks\":{},\
             \"tier\":\"{}\"}}",
            self.admission_rejected.load(Ordering::Relaxed),
            self.bytes_saved.load(Ordering::Relaxed),
            self.dequant_secs(),
            self.evictions.load(Ordering::Relaxed),
            self.hits.load(Ordering::Relaxed),
            self.insertions.load(Ordering::Relaxed),
            self.link_queued_secs(),
            self.misses.load(Ordering::Relaxed),
            self.prefetch_hits.load(Ordering::Relaxed),
            self.prefetch_inserts.load(Ordering::Relaxed),
            self.prefetch_rejected.load(Ordering::Relaxed),
            self.q4_dequant_secs(),
            self.q4_quant_secs(),
            self.quant_secs(),
            resident_bytes,
            resident_chunks,
            self.tier.label(),
        )
    }
}

/// How the hot tier decides whether a demand miss may displace a
/// resident chunk (see [`HotTier::set_admission`]).
#[derive(Debug, Clone, Copy, Default, PartialEq, Eq)]
pub enum AdmissionPolicy {
    /// Admit every miss; recency alone picks victims. The historical
    /// behavior and the default — existing callers are bit-identical.
    #[default]
    Lru,
    /// Frequency-gated admission: a miss that would evict the LRU
    /// victim is admitted only when its frequency-sketch estimate
    /// strictly beats the victim's, so a one-pass scan (every candidate
    /// seen once) cannot displace the repeatedly-hit resident set.
    TinyLfu,
}

impl AdmissionPolicy {
    pub fn label(self) -> &'static str {
        match self {
            AdmissionPolicy::Lru => "lru",
            AdmissionPolicy::TinyLfu => "tinylfu",
        }
    }
}

/// Counters in the TinyLFU frequency sketch. Power of two (the lane
/// hash masks into it); at one byte per counter the whole sketch is
/// 16 KiB — noise next to the megabyte-scale chunks whose admission it
/// arbitrates.
const SKETCH_COUNTERS: usize = 16_384;

/// Hash lanes per id. The estimate is the minimum over the lanes, so a
/// colliding increment in one lane never inflates it alone.
const SKETCH_LANES: u64 = 4;

/// Compact access-frequency sketch backing [`AdmissionPolicy::TinyLfu`]:
/// count-min over [`SKETCH_LANES`] lanes of saturating `u8` counters.
/// Every recorded access bumps one counter per lane; once the total
/// number of recordings reaches [`SKETCH_COUNTERS`] all counters are
/// halved ("aging"), so the estimate tracks *recent* popularity and a
/// formerly-hot id decays instead of squatting on its history.
struct FreqSketch {
    counters: Vec<u8>,
    /// Recordings since the last halving pass.
    ops: u64,
}

impl Default for FreqSketch {
    fn default() -> Self {
        FreqSketch { counters: vec![0; SKETCH_COUNTERS], ops: 0 }
    }
}

impl FreqSketch {
    /// Lane `lane`'s counter index for `id`: a splitmix64-style avalanche
    /// over the id, salted per lane. Deterministic (no per-process seed)
    /// so sketch-dependent tests and traces replay exactly.
    fn index(id: ChunkId, lane: u64) -> usize {
        let mut x = id ^ lane.wrapping_mul(0x9e37_79b9_7f4a_7c15);
        x ^= x >> 30;
        x = x.wrapping_mul(0xbf58_476d_1ce4_e5b9);
        x ^= x >> 27;
        x = x.wrapping_mul(0x94d0_49bb_1331_11eb);
        x ^= x >> 31;
        (x as usize) & (SKETCH_COUNTERS - 1)
    }

    /// Record one access of `id` (called on every probe, hit or miss).
    fn record(&mut self, id: ChunkId) {
        for lane in 0..SKETCH_LANES {
            let c = &mut self.counters[Self::index(id, lane)];
            *c = c.saturating_add(1);
        }
        self.ops += 1;
        if self.ops >= SKETCH_COUNTERS as u64 {
            self.ops = 0;
            for c in self.counters.iter_mut() {
                *c >>= 1;
            }
        }
    }

    /// Estimated recent access count of `id` (min over the lanes; an
    /// upper bound on the true count, never an undercount).
    fn estimate(&self, id: ChunkId) -> u8 {
        (0..SKETCH_LANES).map(|lane| self.counters[Self::index(id, lane)]).min().unwrap_or(0)
    }
}

/// Outcome of a [`HotTier::probe`].
pub enum Probe {
    /// Resident: the chunk plus the on-disk bytes the hit avoided.
    Hit(Arc<KvChunk>, usize),
    /// Not resident: the id's current invalidation generation (to pass
    /// to [`HotTier::insert_at`] after the device read).
    Miss(u64),
}

struct Entry {
    chunk: Arc<KvChunk>,
    /// Size of the backing file (what a miss would have read).
    file_bytes: usize,
    /// Resident bytes charged against the budget.
    cost: usize,
    /// Recency stamp; key into `Lru::order`.
    tick: u64,
    /// Admitted by the prefetch path and not yet demand-hit. Prefetch
    /// evictions may only reclaim these — never a chunk some in-flight
    /// batch demand-loaded — and the first demand hit promotes the entry
    /// to demand status.
    prefetched: bool,
}

#[derive(Default)]
struct Lru {
    map: HashMap<ChunkId, Entry>,
    /// tick → id, oldest first (ticks are unique: one logical clock).
    order: BTreeMap<u64, ChunkId>,
    /// Per-id invalidation generation (bumped by [`HotTier::invalidate`];
    /// a missing entry means generation 0). Lets loaders detect that a
    /// write/delete raced *their* chunk's file read without suppressing
    /// admission of unrelated chunks (see [`HotTier::insert_at`]). Tiny:
    /// two u64 per ever-invalidated id, vs megabytes per cached chunk.
    gens: HashMap<ChunkId, u64>,
    bytes: usize,
    clock: u64,
    /// Demand-miss admission policy (see [`AdmissionPolicy`]).
    policy: AdmissionPolicy,
    /// Access-frequency sketch feeding the TinyLFU gate. Lives under
    /// the LRU mutex — probes already hold it, so recording adds no
    /// locking — and is only consulted when `policy` is `TinyLfu`.
    sketch: FreqSketch,
}

/// Receiver for chunks the hot tier evicts under *budget pressure* —
/// the hook the q8 warm tier ([`super::WarmTier`]) hangs demotion on.
///
/// Demotion is split in two so the expensive half (quantization) stays
/// **off** the hot tier's LRU lock:
///
/// * [`DemoteSink::prepare`] runs *inside* the hot lock's critical
///   section, at the moment of eviction, and snapshots the sink-side
///   invalidation generation. A writer invalidating `id` takes the hot
///   lock first and the warm tier second, so any invalidation that had
///   not completed by prepare-time is ordered *after* it — and will
///   either bump the generation (refusing the admission) or sweep the
///   admitted entry. Implementations must not call back into the hot
///   tier (lock order is strictly hot → warm).
/// * [`DemoteSink::demote`] runs *after* the hot lock is released, does
///   the O(plane) quantize + admit work, and is guarded by the prepared
///   generation — concurrent probes of the hot tier never serialize
///   behind a demotion's encode pass.
///
/// Only budget evictions demote. Invalidations drop the entry outright
/// (the bytes are superseded), and a same-id reinsert replaces in place.
pub trait DemoteSink: Send + Sync {
    /// Snapshot the sink's invalidation generation for `id`. Called
    /// under the hot LRU lock at eviction time; must be cheap.
    fn prepare(&self, id: ChunkId) -> u64;

    /// Offer an evicted chunk to the next tier down, guarded by the
    /// generation [`DemoteSink::prepare`] captured. `prefetched` is the
    /// entry's admission class at eviction time (a still-unread prefetch
    /// keeps that status through the demote→promote cycle).
    fn demote(
        &self,
        id: ChunkId,
        chunk: &Arc<KvChunk>,
        file_bytes: usize,
        prefetched: bool,
        seen_gen: u64,
    );
}

/// The DRAM hot tier: an LRU map `ChunkId → Arc<KvChunk>` holding at
/// most `budget` resident bytes.
pub struct HotTier {
    budget: usize,
    lru: Mutex<Lru>,
    /// Where budget evictions demote to (the warm tier), if anywhere.
    sink: RwLock<Option<Arc<dyn DemoteSink>>>,
    /// Trace handle (disabled by default; the store wires it). Only the
    /// *mutation* paths emit — probes stay untouched so the hot path
    /// costs nothing extra.
    trace: Mutex<TraceBus>,
    pub stats: CacheStats,
}

impl HotTier {
    pub fn new(budget_bytes: usize) -> Self {
        HotTier {
            budget: budget_bytes,
            lru: Mutex::new(Lru::default()),
            sink: RwLock::new(None),
            trace: Mutex::new(TraceBus::disabled()),
            stats: CacheStats::default(),
        }
    }

    /// Attach a trace bus; eviction and admission-rejection marks land
    /// on the `tier:hot` track.
    pub fn set_trace(&self, trace: TraceBus) {
        *self.trace.lock().unwrap() = trace;
    }

    /// Install (or clear) the demotion sink budget evictions feed. The
    /// store wires this to its warm tier; see [`DemoteSink`] for the
    /// locking contract.
    pub fn set_demote_sink(&self, sink: Option<Arc<dyn DemoteSink>>) {
        *self.sink.write().unwrap() = sink;
    }

    /// Select the demand-miss admission policy. Default is
    /// [`AdmissionPolicy::Lru`] (every miss admitted — the historical
    /// behavior, bit-identical); [`AdmissionPolicy::TinyLfu`] turns on
    /// the frequency gate in [`HotTier::insert_at`]. Takes `&self` so
    /// the knob works after the tier is shared behind an `Arc`.
    pub fn set_admission(&self, policy: AdmissionPolicy) {
        self.lru.lock().unwrap().policy = policy;
    }

    /// The currently selected admission policy.
    pub fn admission(&self) -> AdmissionPolicy {
        self.lru.lock().unwrap().policy
    }

    pub fn budget(&self) -> usize {
        self.budget
    }

    /// Resident bytes currently held.
    pub fn bytes(&self) -> usize {
        self.lru.lock().unwrap().bytes
    }

    /// Number of resident chunks.
    pub fn len(&self) -> usize {
        self.lru.lock().unwrap().map.len()
    }

    pub fn is_empty(&self) -> bool {
        self.len() == 0
    }

    /// Look up a chunk. A hit promotes it to most-recently-used and
    /// returns the chunk plus the file bytes the hit avoided reading.
    pub fn get(&self, id: ChunkId) -> Option<(Arc<KvChunk>, usize)> {
        match self.probe(id) {
            Probe::Hit(chunk, file_bytes) => Some((chunk, file_bytes)),
            Probe::Miss(_) => None,
        }
    }

    /// Single-lock lookup for the load path: a hit promotes the entry
    /// and returns it; a miss also reports the id's current invalidation
    /// generation, so the caller can admit the upcoming device read via
    /// [`HotTier::insert_at`] without re-taking the lock.
    pub fn probe(&self, id: ChunkId) -> Probe {
        let mut guard = self.lru.lock().unwrap();
        let lru = &mut *guard;
        lru.clock += 1;
        let tick = lru.clock;
        if lru.policy == AdmissionPolicy::TinyLfu {
            // Every demand access — hit or miss — feeds the frequency
            // sketch; the later insert_at of this same miss consults it.
            lru.sketch.record(id);
        }
        let gen = lru.gens.get(&id).copied().unwrap_or(0);
        let Some(e) = lru.map.get_mut(&id) else {
            self.stats.misses.fetch_add(1, Ordering::Relaxed);
            return Probe::Miss(gen);
        };
        let old_tick = std::mem::replace(&mut e.tick, tick);
        let chunk = e.chunk.clone();
        let file_bytes = e.file_bytes;
        if std::mem::take(&mut e.prefetched) {
            self.stats.prefetch_hits.fetch_add(1, Ordering::Relaxed);
        }
        lru.order.remove(&old_tick);
        lru.order.insert(tick, id);
        self.stats.hits.fetch_add(1, Ordering::Relaxed);
        self.stats.bytes_saved.fetch_add(file_bytes as u64, Ordering::Relaxed);
        Probe::Hit(chunk, file_bytes)
    }

    /// Residency check with no side effects: no stat bump, no LRU
    /// promotion. The prefetcher uses this to skip chunks that are
    /// already warm without distorting the demand hit/miss counters.
    pub fn contains(&self, id: ChunkId) -> bool {
        self.lru.lock().unwrap().map.contains_key(&id)
    }

    /// Snapshot of every resident chunk id (demand and prefetched alike),
    /// with no stat bumps and no LRU promotion. The scheduler's
    /// tier-affinity policy scores queued requests against this set; it
    /// is advisory — residency can change the moment the lock drops — so
    /// consumers treat it as a hint, never a guarantee.
    pub fn resident_ids(&self) -> Vec<ChunkId> {
        self.lru.lock().unwrap().map.keys().copied().collect()
    }

    /// Current invalidation generation of `id`. Loaders capture it
    /// *before* reading the backing file and pass it to
    /// [`HotTier::insert_at`] so a read that raced a re-materialization
    /// of the same chunk can never populate the tier with superseded
    /// bytes.
    pub fn generation(&self, id: ChunkId) -> u64 {
        self.lru.lock().unwrap().gens.get(&id).copied().unwrap_or(0)
    }

    /// Drop `id` and advance its generation. Writers call this on both
    /// sides of the file write (and deleters around the unlink): the
    /// generation bump rejects in-flight stale inserts of this id, and
    /// the remove cleans up any that slipped in under the old one.
    pub fn invalidate(&self, id: ChunkId) {
        let mut guard = self.lru.lock().unwrap();
        let lru = &mut *guard;
        *lru.gens.entry(id).or_insert(0) += 1;
        if let Some(e) = lru.map.remove(&id) {
            lru.order.remove(&e.tick);
            lru.bytes -= e.cost;
        }
    }

    /// Insert (or refresh) a chunk, then evict least-recently-used
    /// entries until the tier is back under budget. `file_bytes` is the
    /// on-disk size recorded for hit accounting; the budget is charged
    /// at DRAM footprint. A chunk larger than the whole budget is not
    /// admitted (it would evict everything for a single resident).
    pub fn insert(&self, id: ChunkId, chunk: Arc<KvChunk>, file_bytes: usize) {
        let gen = self.generation(id);
        self.insert_at(id, chunk, file_bytes, gen);
    }

    /// [`HotTier::insert`] guarded by the id's invalidation generation:
    /// if this chunk was invalidated since `seen_gen` was captured, the
    /// loaded bytes may be stale and are not admitted. Invalidations of
    /// *other* ids don't interfere.
    pub fn insert_at(&self, id: ChunkId, chunk: Arc<KvChunk>, file_bytes: usize, seen_gen: u64) {
        let cost = chunk.dram_bytes();
        if cost > self.budget {
            return;
        }
        let sink = self.sink.read().unwrap().clone();
        let bus = self.trace.lock().unwrap().clone();
        let mut guard = self.lru.lock().unwrap();
        let lru = &mut *guard;
        if lru.gens.get(&id).copied().unwrap_or(0) != seen_gen {
            return; // a write/delete raced this load; don't cache stale bytes
        }
        // TinyLFU frequency gate: when admitting `id` would force a
        // budget eviction, the candidate must *strictly* beat the LRU
        // victim's sketch estimate. A scan item probed once (estimate 1)
        // loses to any repeatedly-hit resident, so sequential sweeps
        // read through the tier instead of flushing it. Gated on the
        // first victim only — the standard TinyLFU approximation.
        if lru.policy == AdmissionPolicy::TinyLfu {
            let freed = lru.map.get(&id).map_or(0, |old| old.cost);
            if lru.bytes - freed + cost > self.budget {
                let victim = lru.order.iter().find(|&(_, &vid)| vid != id).map(|(_, &vid)| vid);
                if let Some(victim) = victim {
                    if lru.sketch.estimate(id) <= lru.sketch.estimate(victim) {
                        self.stats.admission_rejected.fetch_add(1, Ordering::Relaxed);
                        drop(guard);
                        bus.mark("tier:hot", "admit_reject", &[("id", Arg::U(id))]);
                        return;
                    }
                }
            }
        }
        lru.clock += 1;
        let tick = lru.clock;
        if let Some(old) = lru.map.remove(&id) {
            // superseded in place: the old bytes are NOT demoted
            lru.order.remove(&old.tick);
            lru.bytes -= old.cost;
        }
        lru.bytes += cost;
        lru.map.insert(id, Entry { chunk, file_bytes, cost, tick, prefetched: false });
        lru.order.insert(tick, id);
        self.stats.insertions.fetch_add(1, Ordering::Relaxed);
        // Evict under the lock, but defer the sink's quantize/admit work
        // until after it drops (see the DemoteSink contract): only the
        // cheap generation snapshot happens in the critical section.
        let mut demotions: Vec<(ChunkId, Arc<KvChunk>, usize, bool, u64)> = Vec::new();
        let mut evicted: Vec<(ChunkId, usize)> = Vec::new();
        while lru.bytes > self.budget {
            let Some((&oldest, &evict)) = lru.order.iter().next() else { break };
            lru.order.remove(&oldest);
            if let Some(e) = lru.map.remove(&evict) {
                lru.bytes -= e.cost;
                self.stats.evictions.fetch_add(1, Ordering::Relaxed);
                if bus.enabled() {
                    evicted.push((evict, e.cost));
                }
                if let Some(sink) = &sink {
                    let gen = sink.prepare(evict);
                    demotions.push((evict, e.chunk, e.file_bytes, e.prefetched, gen));
                }
            }
        }
        drop(guard);
        // Trace marks only after the LRU lock drops, like the sink work.
        for (evict, cost) in evicted {
            bus.mark(
                "tier:hot",
                "evict",
                &[("id", Arg::U(evict)), ("bytes", Arg::U(cost as u64))],
            );
        }
        if let Some(sink) = &sink {
            for (evict, chunk, file_bytes, prefetched, gen) in demotions {
                sink.demote(evict, &chunk, file_bytes, prefetched, gen);
            }
        }
    }

    /// Dedicated prefetch admission, generation-guarded like
    /// [`HotTier::insert_at`]. The crucial difference from the demand
    /// path: making room for a prefetched chunk may evict only *other
    /// not-yet-used prefetched* entries — never a chunk a demand load
    /// admitted (those may belong to an in-flight batch, and trading a
    /// certain hit for a speculative one is strictly worse). When the
    /// protected mass leaves no room, the prefetch is dropped instead.
    ///
    /// Returns `true` when `id` is resident after the call (admitted now,
    /// or already resident from an earlier load).
    pub fn insert_prefetch(
        &self,
        id: ChunkId,
        chunk: Arc<KvChunk>,
        file_bytes: usize,
        seen_gen: u64,
    ) -> bool {
        let cost = chunk.dram_bytes();
        if cost > self.budget {
            self.stats.prefetch_rejected.fetch_add(1, Ordering::Relaxed);
            return false;
        }
        let sink = self.sink.read().unwrap().clone();
        let mut guard = self.lru.lock().unwrap();
        let lru = &mut *guard;
        if lru.gens.get(&id).copied().unwrap_or(0) != seen_gen {
            self.stats.prefetch_rejected.fetch_add(1, Ordering::Relaxed);
            return false; // superseded while the prefetch read was in flight
        }
        if lru.map.contains_key(&id) {
            return true; // already warm (demand or earlier prefetch); keep as-is
        }
        // Admit only if the budget can be met by reclaiming prefetched
        // entries: walk victims oldest-first, counting reclaimable bytes.
        let mut demotions: Vec<(ChunkId, Arc<KvChunk>, usize, bool, u64)> = Vec::new();
        let need = (lru.bytes + cost).saturating_sub(self.budget);
        if need > 0 {
            let mut reclaimable = 0usize;
            let mut victims: Vec<(u64, ChunkId)> = Vec::new();
            for (&tick, &vid) in lru.order.iter() {
                if reclaimable >= need {
                    break;
                }
                if let Some(e) = lru.map.get(&vid) {
                    if e.prefetched {
                        reclaimable += e.cost;
                        victims.push((tick, vid));
                    }
                }
            }
            if reclaimable < need {
                self.stats.prefetch_rejected.fetch_add(1, Ordering::Relaxed);
                return false; // would have to evict demand-resident chunks
            }
            for (tick, vid) in victims {
                lru.order.remove(&tick);
                if let Some(e) = lru.map.remove(&vid) {
                    lru.bytes -= e.cost;
                    self.stats.evictions.fetch_add(1, Ordering::Relaxed);
                    if let Some(sink) = &sink {
                        let gen = sink.prepare(vid);
                        demotions.push((vid, e.chunk, e.file_bytes, e.prefetched, gen));
                    }
                }
            }
        }
        lru.clock += 1;
        let tick = lru.clock;
        lru.bytes += cost;
        lru.map.insert(id, Entry { chunk, file_bytes, cost, tick, prefetched: true });
        lru.order.insert(tick, id);
        self.stats.insertions.fetch_add(1, Ordering::Relaxed);
        self.stats.prefetch_inserts.fetch_add(1, Ordering::Relaxed);
        // Quantize/admit demoted victims only after the lock drops (see
        // the DemoteSink contract).
        drop(guard);
        if let Some(sink) = &sink {
            for (vid, chunk, file_bytes, prefetched, gen) in demotions {
                sink.demote(vid, &chunk, file_bytes, prefetched, gen);
            }
        }
        true
    }
}

impl TierMetrics for HotTier {
    fn tier_stats(&self) -> &CacheStats {
        &self.stats
    }

    fn residency(&self) -> (usize, usize) {
        let lru = self.lru.lock().unwrap();
        (lru.bytes, lru.map.len())
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn chunk(seed: u32) -> Arc<KvChunk> {
        let plane = 2 * 2 * 8 * 4;
        Arc::new(KvChunk {
            config_id: 1,
            n_layers: 2,
            n_kv_heads: 2,
            seq_len: 8,
            head_dim: 4,
            k: (0..plane).map(|i| (i + seed) as f32).collect(),
            v: (0..plane).map(|i| -((i + seed) as f32)).collect(),
        })
    }

    fn cost() -> usize {
        chunk(0).dram_bytes()
    }

    #[test]
    fn lru_eviction_order() {
        let tier = HotTier::new(2 * cost());
        tier.insert(1, chunk(1), 100);
        tier.insert(2, chunk(2), 100);
        assert!(tier.get(1).is_some()); // promote 1 → LRU victim is 2
        tier.insert(3, chunk(3), 100);
        assert_eq!(tier.len(), 2);
        assert!(tier.get(2).is_none(), "LRU entry must be the one evicted");
        assert!(tier.get(1).is_some());
        assert!(tier.get(3).is_some());
        assert_eq!(tier.stats.evictions.load(Ordering::Relaxed), 1);
    }

    #[test]
    fn byte_budget_enforced() {
        let budget = 2 * cost() + cost() / 2;
        let tier = HotTier::new(budget);
        for i in 0..5 {
            tier.insert(i, chunk(i as u32), 100);
            assert!(tier.bytes() <= budget, "over budget after insert {i}");
        }
        assert_eq!(tier.len(), 2);
        assert_eq!(tier.bytes(), 2 * cost());
    }

    #[test]
    fn oversize_chunk_not_admitted() {
        let tier = HotTier::new(cost() - 1);
        tier.insert(1, chunk(1), 100);
        assert_eq!(tier.len(), 0);
        assert_eq!(tier.bytes(), 0);
    }

    #[test]
    fn hit_miss_stats() {
        let tier = HotTier::new(4 * cost());
        assert!(tier.get(7).is_none());
        tier.insert(7, chunk(7), 640);
        let (c, fb) = tier.get(7).unwrap();
        assert_eq!(c.k, chunk(7).k);
        assert_eq!(fb, 640);
        tier.get(7).unwrap();
        assert_eq!(tier.stats.hits.load(Ordering::Relaxed), 2);
        assert_eq!(tier.stats.misses.load(Ordering::Relaxed), 1);
        assert_eq!(tier.stats.bytes_saved.load(Ordering::Relaxed), 2 * 640);
        assert!((tier.stats.hit_ratio() - 2.0 / 3.0).abs() < 1e-9);
    }

    #[test]
    fn reinsert_replaces_without_double_charge() {
        let tier = HotTier::new(4 * cost());
        tier.insert(1, chunk(1), 100);
        tier.insert(1, chunk(9), 100);
        assert_eq!(tier.len(), 1);
        assert_eq!(tier.bytes(), cost());
        assert_eq!(tier.get(1).unwrap().0.k, chunk(9).k, "stale chunk survived reinsert");
    }

    #[test]
    fn generation_guard_rejects_stale_insert() {
        let tier = HotTier::new(4 * cost());
        // loader captured the generation, then a writer invalidated: the
        // loader's (possibly stale) chunk must not be admitted.
        let seen = tier.generation(9);
        tier.invalidate(9);
        tier.insert_at(9, chunk(9), 100, seen);
        assert_eq!(tier.len(), 0);
        assert!(tier.get(9).is_none());
        // a load that starts after the invalidation is admitted
        tier.insert_at(9, chunk(9), 100, tier.generation(9));
        assert!(tier.get(9).is_some());
        // invalidating one id never suppresses admission of another
        let other = tier.generation(8);
        tier.invalidate(9);
        tier.insert_at(8, chunk(8), 100, other);
        assert!(tier.get(8).is_some(), "unrelated invalidation blocked admission");
    }

    #[test]
    fn prefetch_cannot_evict_demand_entries() {
        let tier = HotTier::new(2 * cost());
        tier.insert(1, chunk(1), 100);
        tier.insert(2, chunk(2), 100); // budget full of demand entries
        let admitted = tier.insert_prefetch(3, chunk(3), 100, tier.generation(3));
        assert!(!admitted, "prefetch displaced a demand-resident chunk");
        assert!(tier.contains(1) && tier.contains(2));
        assert!(!tier.contains(3));
        assert_eq!(tier.stats.prefetch_rejected.load(Ordering::Relaxed), 1);
        // demand inserts still evict normally
        tier.insert(4, chunk(4), 100);
        assert!(tier.contains(4));
    }

    #[test]
    fn prefetch_evicts_only_other_prefetched_entries() {
        let tier = HotTier::new(2 * cost());
        tier.insert(1, chunk(1), 100); // demand
        assert!(tier.insert_prefetch(2, chunk(2), 100, tier.generation(2)));
        // tier full: one demand + one prefetched. A new prefetch must
        // reclaim the prefetched entry and leave the demand one alone.
        assert!(tier.insert_prefetch(3, chunk(3), 100, tier.generation(3)));
        assert!(tier.contains(1), "demand entry evicted by prefetch");
        assert!(!tier.contains(2));
        assert!(tier.contains(3));
        assert_eq!(tier.stats.evictions.load(Ordering::Relaxed), 1);
    }

    #[test]
    fn demand_hit_promotes_prefetched_entry() {
        let tier = HotTier::new(2 * cost());
        assert!(tier.insert_prefetch(1, chunk(1), 100, tier.generation(1)));
        assert!(tier.get(1).is_some()); // demand hit: promote to demand status
        assert_eq!(tier.stats.prefetch_hits.load(Ordering::Relaxed), 1);
        // promoted entries are now protected from prefetch eviction: a
        // full tier reclaims the unread prefetched entry, never id 1.
        assert!(tier.insert_prefetch(2, chunk(2), 100, tier.generation(2)));
        assert!(tier.insert_prefetch(3, chunk(3), 100, tier.generation(3)));
        assert!(tier.contains(1), "promoted entry evicted by prefetch");
        assert!(!tier.contains(2));
        assert!(tier.contains(3));
        // a second hit is a plain hit, not another prefetch hit
        tier.get(1).unwrap();
        assert_eq!(tier.stats.prefetch_hits.load(Ordering::Relaxed), 1);
    }

    #[test]
    fn prefetch_generation_guard_rejects_stale() {
        let tier = HotTier::new(4 * cost());
        let seen = tier.generation(9);
        tier.invalidate(9); // a delete/write superseded the prefetch read
        assert!(!tier.insert_prefetch(9, chunk(9), 100, seen));
        assert!(!tier.contains(9));
        assert!(tier.insert_prefetch(9, chunk(9), 100, tier.generation(9)));
        assert!(tier.contains(9));
    }

    #[test]
    fn prefetch_already_resident_is_noop_success() {
        let tier = HotTier::new(4 * cost());
        tier.insert(1, chunk(1), 100);
        assert!(tier.insert_prefetch(1, chunk(2), 100, tier.generation(1)));
        // the demand copy survives untouched (no downgrade to prefetched)
        assert_eq!(tier.get(1).unwrap().0.k, chunk(1).k);
        assert_eq!(tier.stats.prefetch_hits.load(Ordering::Relaxed), 0);
        assert_eq!(tier.stats.prefetch_inserts.load(Ordering::Relaxed), 0);
    }

    #[test]
    fn contains_has_no_side_effects() {
        let tier = HotTier::new(4 * cost());
        assert!(!tier.contains(5));
        tier.insert(5, chunk(5), 100);
        assert!(tier.contains(5));
        assert_eq!(tier.stats.hits.load(Ordering::Relaxed), 0);
        assert_eq!(tier.stats.misses.load(Ordering::Relaxed), 0);
    }

    #[test]
    fn resident_ids_snapshots_without_side_effects() {
        let tier = HotTier::new(4 * cost());
        assert!(tier.resident_ids().is_empty());
        tier.insert(1, chunk(1), 100);
        tier.insert_prefetch(2, chunk(2), 100, tier.generation(2));
        let mut ids = tier.resident_ids();
        ids.sort_unstable();
        assert_eq!(ids, vec![1, 2], "demand and prefetched entries both resident");
        assert_eq!(tier.stats.hits.load(Ordering::Relaxed), 0);
        assert_eq!(tier.stats.misses.load(Ordering::Relaxed), 0);
        tier.invalidate(1);
        assert_eq!(tier.resident_ids(), vec![2]);
    }

    #[test]
    fn telemetry_series_samples_cumulative_counters() {
        let tier = HotTier::new(4 * cost());
        tier.sample(); // empty tier
        tier.insert(1, chunk(1), 100);
        tier.get(1).unwrap();
        tier.sample();
        tier.get(1).unwrap();
        assert!(tier.get(2).is_none()); // miss
        tier.sample();
        let series = tier.stats.series();
        assert_eq!(series.len(), 3);
        assert_eq!(series[0], CacheSample::default());
        assert_eq!(series[1].hits, 1);
        assert_eq!(series[1].insertions, 1);
        assert_eq!(series[1].resident_chunks, 1);
        assert_eq!(series[1].resident_bytes, cost() as u64);
        assert_eq!(series[2].hits, 2);
        assert_eq!(series[2].misses, 1);
        // per-window rates fall out of diffing consecutive samples
        assert_eq!(series[2].hits - series[1].hits, 1);
    }

    #[test]
    fn sample_carries_tier_label_and_defaults_hot() {
        let tier = HotTier::new(4 * cost());
        tier.sample();
        let s = tier.stats.series()[0];
        assert_eq!(s.tier, TierKind::Hot);
        assert_eq!(s.dequant_secs, 0.0);
        assert!(s.to_json().contains("\"tier\":\"hot\""));
        // warm-tagged stats serialize distinguishably
        let warm = CacheStats::for_tier(TierKind::Warm);
        warm.add_dequant_secs(0.25);
        warm.add_quant_secs(0.125);
        let snap = warm.snapshot(0, 0);
        assert_eq!(snap.tier, TierKind::Warm);
        assert!((snap.dequant_secs - 0.25).abs() < 1e-6);
        assert!((snap.quant_secs - 0.125).abs() < 1e-6);
        assert!(snap.to_json().contains("\"tier\":\"warm\""));
        assert!(snap.to_json().contains("\"quant_secs\":0.125"));
    }

    #[test]
    fn demote_sink_sees_budget_evictions_only() {
        struct Recorder(Mutex<Vec<(ChunkId, bool)>>);
        impl DemoteSink for Recorder {
            fn prepare(&self, _id: ChunkId) -> u64 {
                0
            }
            fn demote(
                &self,
                id: ChunkId,
                _c: &Arc<KvChunk>,
                _fb: usize,
                prefetched: bool,
                _seen_gen: u64,
            ) {
                self.0.lock().unwrap().push((id, prefetched));
            }
        }
        let tier = HotTier::new(2 * cost());
        let rec = Arc::new(Recorder(Mutex::new(Vec::new())));
        tier.set_demote_sink(Some(rec.clone() as Arc<dyn DemoteSink>));
        tier.insert(1, chunk(1), 100);
        tier.insert(1, chunk(9), 100); // same-id reinsert: superseded, not demoted
        tier.invalidate(1); // invalidation: stale, not demoted
        assert!(rec.0.lock().unwrap().is_empty());

        tier.insert(2, chunk(2), 100);
        tier.insert(3, chunk(3), 100);
        tier.insert(4, chunk(4), 100); // budget eviction of LRU id 2
        assert_eq!(rec.0.lock().unwrap().as_slice(), &[(2, false)]);

        // a prefetch evicting a prefetched entry demotes it with its class
        let tier = HotTier::new(2 * cost());
        let rec = Arc::new(Recorder(Mutex::new(Vec::new())));
        tier.set_demote_sink(Some(rec.clone() as Arc<dyn DemoteSink>));
        tier.insert(10, chunk(10), 100);
        assert!(tier.insert_prefetch(11, chunk(11), 100, tier.generation(11)));
        assert!(tier.insert_prefetch(12, chunk(12), 100, tier.generation(12)));
        assert_eq!(rec.0.lock().unwrap().as_slice(), &[(11, true)]);
    }

    #[test]
    fn invalidate_drops_entry() {
        let tier = HotTier::new(4 * cost());
        tier.insert(1, chunk(1), 100);
        tier.invalidate(1);
        assert_eq!(tier.len(), 0);
        assert_eq!(tier.bytes(), 0);
        assert!(tier.get(1).is_none());
        tier.invalidate(1); // idempotent on absent entries
    }

    /// Replay the demand path the store drives: probe (records
    /// frequency, counts the miss), then insert the loaded chunk.
    fn miss_and_insert(tier: &HotTier, id: ChunkId) {
        match tier.probe(id) {
            Probe::Miss(gen) => tier.insert_at(id, chunk(id as u32), 100, gen),
            Probe::Hit(..) => {}
        }
    }

    #[test]
    fn tinylfu_scan_cannot_flush_the_hot_set() {
        let tier = HotTier::new(2 * cost());
        tier.set_admission(AdmissionPolicy::TinyLfu);
        assert_eq!(tier.admission(), AdmissionPolicy::TinyLfu);
        // build frequency: ids 1 and 2 probed repeatedly
        miss_and_insert(&tier, 1);
        miss_and_insert(&tier, 2);
        for _ in 0..3 {
            tier.get(1).unwrap();
            tier.get(2).unwrap();
        }
        // one sequential scan: each cold id seen exactly once
        for id in 100..108 {
            miss_and_insert(&tier, id);
        }
        assert!(tier.contains(1) && tier.contains(2), "scan flushed the resident hot set");
        assert_eq!(tier.len(), 2);
        assert_eq!(tier.stats.admission_rejected.load(Ordering::Relaxed), 8);
        // the residents still serve as hits after the scan
        assert!(tier.get(1).is_some() && tier.get(2).is_some());
    }

    #[test]
    fn lru_default_is_flushed_by_the_same_scan() {
        // The A/B control for the test above: identical trace, default
        // policy — recency-only admission lets the scan displace both
        // frequently-hit residents.
        let tier = HotTier::new(2 * cost());
        assert_eq!(tier.admission(), AdmissionPolicy::Lru);
        miss_and_insert(&tier, 1);
        miss_and_insert(&tier, 2);
        for _ in 0..3 {
            tier.get(1).unwrap();
            tier.get(2).unwrap();
        }
        for id in 100..108 {
            miss_and_insert(&tier, id);
        }
        assert!(!tier.contains(1) && !tier.contains(2));
        assert_eq!(tier.stats.admission_rejected.load(Ordering::Relaxed), 0);
    }

    #[test]
    fn tinylfu_admits_candidate_that_out_frequents_the_victim() {
        let tier = HotTier::new(cost()); // one slot: every admission evicts
        tier.set_admission(AdmissionPolicy::TinyLfu);
        miss_and_insert(&tier, 1);
        tier.get(1).unwrap();
        tier.get(1).unwrap(); // estimate(1) = 3
        // two probes of 5 (estimate 2) lose to the resident...
        assert!(tier.get(5).is_none());
        miss_and_insert(&tier, 5);
        assert!(tier.contains(1) && !tier.contains(5));
        // ...but further demand keeps raising the estimate until it
        // strictly beats the victim's, and the candidate displaces it.
        assert!(tier.get(5).is_none());
        miss_and_insert(&tier, 5); // estimate(5) = 4 > 3
        assert!(tier.contains(5), "out-frequented victim kept its slot");
        assert!(!tier.contains(1));
    }

    #[test]
    fn tinylfu_never_gates_admissions_that_fit_without_eviction() {
        let tier = HotTier::new(4 * cost());
        tier.set_admission(AdmissionPolicy::TinyLfu);
        // cold-start fills (no victim to defend) always admit
        for id in 1..=4 {
            miss_and_insert(&tier, id);
        }
        assert_eq!(tier.len(), 4);
        assert_eq!(tier.stats.admission_rejected.load(Ordering::Relaxed), 0);
        // same-id refresh replaces in place: no eviction, no gate
        tier.insert(1, chunk(9), 100);
        assert_eq!(tier.get(1).unwrap().0.k, chunk(9).k);
    }

    #[test]
    fn sketch_halving_ages_out_stale_frequency() {
        let tier = HotTier::new(cost()); // one slot
        tier.set_admission(AdmissionPolicy::TinyLfu);
        for _ in 0..64 {
            tier.probe(1); // old hotness: estimate(1) = 64
        }
        miss_and_insert(&tier, 1);
        for _ in 0..8 {
            tier.probe(5);
        }
        miss_and_insert(&tier, 5);
        assert!(tier.contains(1) && !tier.contains(5), "fresh trickle beat stale hotness too early");
        // a long stream of unrelated traffic crosses the halving
        // threshold twice: estimate(1) decays 64 → 16 without id 1 ever
        // being touched again
        for _ in 0..(2 * SKETCH_COUNTERS as u64) {
            tier.probe(2);
        }
        // now a moderately demanded candidate (20 recent accesses > 16
        // decayed ones) wins the slot
        for _ in 0..20 {
            tier.probe(5);
        }
        miss_and_insert(&tier, 5);
        assert!(tier.contains(5), "aged-out resident still defending its slot");
        assert!(!tier.contains(1));
    }
}
