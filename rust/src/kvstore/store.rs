//! File-backed materialized-KV store with write-behind and throttled loads.

use std::path::{Path, PathBuf};
use std::sync::atomic::{AtomicU64, Ordering};
use std::sync::Arc;
use std::time::Instant;

use anyhow::{bail, Context, Result};

use super::throttle::DeviceThrottle;
use crate::util::aio::{IoPool, Pending};
use crate::hwsim::StorageProfile;
use crate::manifest::ModelConfig;
use crate::vectordb::ChunkId;

const MAGIC: u32 = 0x4d41_544b; // "MATK"
const VERSION: u32 = 1;
const HEADER_BYTES: usize = 8 * 4;

/// One chunk's materialized KV tensors (host side).
///
/// `k`/`v` are `[n_layers, n_kv_heads, seq_len, head_dim]` f32,
/// row-major — the per-batch-element slice of the packed device cache, so
/// assembly into a serve-time cache is pure memcpy.
#[derive(Debug, Clone, PartialEq)]
pub struct KvChunk {
    pub config_id: u32,
    pub n_layers: u32,
    pub n_kv_heads: u32,
    pub seq_len: u32,
    pub head_dim: u32,
    pub k: Vec<f32>,
    pub v: Vec<f32>,
}

impl KvChunk {
    pub fn plane_elems(&self) -> usize {
        (self.n_layers * self.n_kv_heads * self.seq_len * self.head_dim) as usize
    }

    pub fn total_bytes(&self) -> usize {
        HEADER_BYTES + 8 * self.plane_elems()
    }

    fn validate(&self) -> Result<()> {
        if self.k.len() != self.plane_elems() || self.v.len() != self.plane_elems() {
            bail!(
                "KvChunk plane size mismatch: k={} v={} expect={}",
                self.k.len(),
                self.v.len(),
                self.plane_elems()
            );
        }
        Ok(())
    }
}

/// Stable id for a model config (validated on load so a store produced by
/// one model is never spliced into another).
pub fn config_id(cfg: &ModelConfig) -> u32 {
    let mut h: u32 = 2166136261;
    for b in cfg.name.bytes() {
        h = (h ^ b as u32).wrapping_mul(16777619);
    }
    h ^= (cfg.n_layers as u32) << 24 ^ (cfg.n_kv_heads as u32) << 16 ^ cfg.head_dim as u32;
    h
}

/// Cumulative I/O counters.
#[derive(Debug, Default)]
pub struct StoreStats {
    pub reads: AtomicU64,
    pub writes: AtomicU64,
    pub bytes_read: AtomicU64,
    pub bytes_written: AtomicU64,
    pub deletes: AtomicU64,
}

/// The store: one directory per (deployment, model config).
pub struct KvStore {
    dir: PathBuf,
    throttle: Arc<DeviceThrottle>,
    pool: IoPool,
    pub stats: StoreStats,
}

/// Result of a load: the chunk plus its simulated device time.
#[derive(Debug)]
pub struct Loaded {
    pub chunk: KvChunk,
    pub device_secs: f64,
}

impl KvStore {
    /// Open (creating if needed) a store under `dir`, timed as `profile`.
    pub fn open(dir: impl AsRef<Path>, profile: StorageProfile) -> Result<Self> {
        let dir = dir.as_ref().to_path_buf();
        std::fs::create_dir_all(&dir).with_context(|| format!("creating {dir:?}"))?;
        Ok(KvStore {
            dir,
            throttle: Arc::new(DeviceThrottle::new(profile)),
            pool: IoPool::new(4),
            stats: StoreStats::default(),
        })
    }

    /// Swap the simulated storage device (Table III sweeps this).
    pub fn set_profile(&mut self, profile: StorageProfile) {
        self.throttle = Arc::new(DeviceThrottle::new(profile));
    }

    /// Disable wall-clock throttling (pure-functional tests).
    pub fn disable_throttle(&mut self) {
        let profile = self.throttle.profile().clone();
        let mut t = DeviceThrottle::new(profile);
        t.enabled = false;
        self.throttle = Arc::new(t);
    }

    pub fn profile(&self) -> &StorageProfile {
        self.throttle.profile()
    }

    fn path_of(&self, id: ChunkId) -> PathBuf {
        self.dir.join(format!("{id:016x}.kv"))
    }

    pub fn contains(&self, id: ChunkId) -> bool {
        self.path_of(id).exists()
    }

    fn encode(chunk: &KvChunk) -> Vec<u8> {
        let plane = chunk.plane_elems();
        let mut buf = Vec::with_capacity(HEADER_BYTES + 8 * plane);
        for word in [
            MAGIC,
            VERSION,
            chunk.config_id,
            chunk.n_layers,
            chunk.n_kv_heads,
            chunk.seq_len,
            chunk.head_dim,
            0, // reserved
        ] {
            buf.extend_from_slice(&word.to_le_bytes());
        }
        for plane_data in [&chunk.k, &chunk.v] {
            // safety: f32 slice → bytes (LE on all supported targets)
            let bytes = unsafe {
                std::slice::from_raw_parts(plane_data.as_ptr() as *const u8, plane_data.len() * 4)
            };
            buf.extend_from_slice(bytes);
        }
        buf
    }

    fn decode(data: &[u8]) -> Result<KvChunk> {
        if data.len() < HEADER_BYTES {
            bail!("KV file truncated: {} bytes", data.len());
        }
        let word = |i: usize| u32::from_le_bytes(data[i * 4..i * 4 + 4].try_into().unwrap());
        if word(0) != MAGIC {
            bail!("bad KV magic {:#x}", word(0));
        }
        if word(1) != VERSION {
            bail!("bad KV version {}", word(1));
        }
        let chunk = KvChunk {
            config_id: word(2),
            n_layers: word(3),
            n_kv_heads: word(4),
            seq_len: word(5),
            head_dim: word(6),
            k: Vec::new(),
            v: Vec::new(),
        };
        let plane = chunk.plane_elems();
        if data.len() != HEADER_BYTES + 8 * plane {
            bail!("KV file size mismatch: {} vs {}", data.len(), HEADER_BYTES + 8 * plane);
        }
        let floats = |off: usize, n: usize| -> Vec<f32> {
            let mut out = vec![0f32; n];
            let src = &data[off..off + 4 * n];
            // safety: copying LE bytes into f32s
            unsafe {
                std::ptr::copy_nonoverlapping(src.as_ptr(), out.as_mut_ptr() as *mut u8, 4 * n);
            }
            out
        };
        Ok(KvChunk {
            k: floats(HEADER_BYTES, plane),
            v: floats(HEADER_BYTES + 4 * plane, plane),
            ..chunk
        })
    }

    /// Synchronous materialization (throttled to the device profile).
    pub fn store_sync(&self, id: ChunkId, chunk: &KvChunk) -> Result<f64> {
        chunk.validate()?;
        let buf = Self::encode(chunk);
        let start = Instant::now();
        std::fs::write(self.path_of(id), &buf)?;
        let secs = self.throttle.charge_write(buf.len(), start.elapsed());
        self.stats.writes.fetch_add(1, Ordering::Relaxed);
        self.stats.bytes_written.fetch_add(buf.len() as u64, Ordering::Relaxed);
        Ok(secs)
    }

    /// Write-behind materialization: returns immediately, the write runs
    /// on the store's I/O pool (the role DeepNVMe's async_io plays in the
    /// paper's prototype). Wait on the handle (or [`KvStore::drain`]) to
    /// observe errors and the simulated device seconds.
    pub fn store_async(&self, id: ChunkId, chunk: KvChunk) -> Pending<Result<f64>> {
        chunk.validate().expect("invalid chunk");
        let path = self.path_of(id);
        let throttle = self.throttle.clone();
        let buf = Self::encode(&chunk);
        self.stats.writes.fetch_add(1, Ordering::Relaxed);
        self.stats.bytes_written.fetch_add(buf.len() as u64, Ordering::Relaxed);
        self.pool.submit(move || {
            let start = Instant::now();
            std::fs::write(&path, &buf)?;
            Ok(throttle.charge_write(buf.len(), start.elapsed()))
        })
    }

    /// Block until previously spawned async writes have finished; returns
    /// the total simulated device-write seconds.
    pub fn drain(&self, handles: Vec<Pending<Result<f64>>>) -> Result<f64> {
        let mut total = 0.0;
        for h in handles {
            total += h.wait()?;
        }
        Ok(total)
    }

    /// Load one chunk (throttled). Returns the chunk and device seconds.
    pub fn load(&self, id: ChunkId) -> Result<Loaded> {
        let path = self.path_of(id);
        let start = Instant::now();
        let data = std::fs::read(&path).with_context(|| format!("loading KV {path:?}"))?;
        let device_secs = self.throttle.charge_read(data.len(), start.elapsed());
        self.stats.reads.fetch_add(1, Ordering::Relaxed);
        self.stats.bytes_read.fetch_add(data.len() as u64, Ordering::Relaxed);
        Ok(Loaded { chunk: Self::decode(&data)?, device_secs })
    }

    /// Load many chunks concurrently (they still serialize on the
    /// simulated device, like real parallel reads of one SSD).
    pub fn load_many(&self, ids: &[ChunkId]) -> Result<Vec<Loaded>> {
        let handles: Vec<Pending<Result<(Vec<u8>, f64)>>> = ids
            .iter()
            .map(|&id| {
                let path = self.path_of(id);
                let throttle = self.throttle.clone();
                self.pool.submit(move || {
                    let start = Instant::now();
                    let data = std::fs::read(&path)
                        .with_context(|| format!("loading KV {path:?}"))?;
                    let device_secs = throttle.charge_read(data.len(), start.elapsed());
                    Ok((data, device_secs))
                })
            })
            .collect();
        let mut out = Vec::with_capacity(ids.len());
        for h in handles {
            let (data, device_secs) = h.wait()?;
            self.stats.reads.fetch_add(1, Ordering::Relaxed);
            self.stats.bytes_read.fetch_add(data.len() as u64, Ordering::Relaxed);
            out.push(Loaded { chunk: Self::decode(&data)?, device_secs });
        }
        Ok(out)
    }

    /// Delete a chunk's materialized KV (vector-DB delete path).
    pub fn delete(&self, id: ChunkId) -> Result<bool> {
        match std::fs::remove_file(self.path_of(id)) {
            Ok(()) => {
                self.stats.deletes.fetch_add(1, Ordering::Relaxed);
                Ok(true)
            }
            Err(e) if e.kind() == std::io::ErrorKind::NotFound => Ok(false),
            Err(e) => Err(e.into()),
        }
    }

    /// Number of materialized chunks on disk.
    pub fn len(&self) -> Result<usize> {
        Ok(std::fs::read_dir(&self.dir)?
            .filter_map(|e| e.ok())
            .filter(|e| e.path().extension().is_some_and(|x| x == "kv"))
            .count())
    }

    pub fn is_empty(&self) -> Result<bool> {
        Ok(self.len()? == 0)
    }

    /// Total bytes of materialized KV on disk (TCO accounting).
    pub fn bytes_on_disk(&self) -> Result<u64> {
        let mut total = 0;
        for e in std::fs::read_dir(&self.dir)? {
            let e = e?;
            if e.path().extension().is_some_and(|x| x == "kv") {
                total += e.metadata()?.len();
            }
        }
        Ok(total)
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn chunk(seed: u32, seq: u32) -> KvChunk {
        let plane = (2 * 2 * seq * 4) as usize;
        KvChunk {
            config_id: 0xabcd,
            n_layers: 2,
            n_kv_heads: 2,
            seq_len: seq,
            head_dim: 4,
            k: (0..plane).map(|i| (i as f32) + seed as f32).collect(),
            v: (0..plane).map(|i| -(i as f32) - seed as f32).collect(),
        }
    }

    fn store() -> (crate::util::tempdir::TempDir, KvStore) {
        let dir = crate::util::tempdir::TempDir::new("matkv-kvstore-test").unwrap();
        let mut s = KvStore::open(dir.path(), StorageProfile::dram()).unwrap();
        s.disable_throttle();
        (dir, s)
    }

    #[test]
    fn roundtrip() {
        let (_d, s) = store();
        let c = chunk(7, 16);
        s.store_sync(42, &c).unwrap();
        let loaded = s.load(42).unwrap();
        assert_eq!(loaded.chunk, c);
    }

    #[test]
    fn async_write_behind_roundtrip() {
        let (_d, s) = store();
        let c = chunk(9, 8);
        let h = s.store_async(7, c.clone());
        s.drain(vec![h]).unwrap();
        assert_eq!(s.load(7).unwrap().chunk, c);
    }

    #[test]
    fn load_many_preserves_order() {
        let (_d, s) = store();
        for i in 0..5u64 {
            s.store_sync(i, &chunk(i as u32, 8)).unwrap();
        }
        let loaded = s.load_many(&[3, 1, 4]).unwrap();
        assert_eq!(loaded[0].chunk.k[0], chunk(3, 8).k[0]);
        assert_eq!(loaded[1].chunk.k[0], chunk(1, 8).k[0]);
        assert_eq!(loaded[2].chunk.k[0], chunk(4, 8).k[0]);
    }

    #[test]
    fn delete_and_contains() {
        let (_d, s) = store();
        s.store_sync(1, &chunk(1, 8)).unwrap();
        assert!(s.contains(1));
        assert!(s.delete(1).unwrap());
        assert!(!s.contains(1));
        assert!(!s.delete(1).unwrap());
        assert!(s.load(1).is_err());
    }

    #[test]
    fn corrupt_file_rejected() {
        let (_d, s) = store();
        s.store_sync(5, &chunk(5, 8)).unwrap();
        // truncate
        let path = s.path_of(5);
        let data = std::fs::read(&path).unwrap();
        std::fs::write(&path, &data[..data.len() / 2]).unwrap();
        assert!(s.load(5).is_err());
        // bad magic
        let mut bad = data.clone();
        bad[0] ^= 0xff;
        std::fs::write(&path, &bad).unwrap();
        assert!(s.load(5).is_err());
    }

    #[test]
    fn stats_accumulate() {
        let (_d, s) = store();
        let c = chunk(1, 8);
        s.store_sync(1, &c).unwrap();
        s.load(1).unwrap();
        s.load(1).unwrap();
        assert_eq!(s.stats.reads.load(Ordering::Relaxed), 2);
        assert_eq!(s.stats.writes.load(Ordering::Relaxed), 1);
        assert_eq!(s.stats.bytes_read.load(Ordering::Relaxed), 2 * c.total_bytes() as u64);
        assert_eq!(s.len().unwrap(), 1);
        assert_eq!(s.bytes_on_disk().unwrap(), c.total_bytes() as u64);
    }

    #[test]
    fn throttled_load_is_slower() {
        let dir = crate::util::tempdir::TempDir::new("matkv-kvstore-thr").unwrap();
        let slow = StorageProfile {
            name: "slow".into(),
            read_bw: 50e6,
            write_bw: 1e12,
            latency_s: 0.0,
            power_active: 1.0,
            power_idle: 0.0,
            usd_per_byte: 0.0,
        };
        let s = KvStore::open(dir.path(), slow).unwrap();
        let c = chunk(1, 256); // 2*2*256*4 *2 planes *4B = 64KB
        s.store_sync(1, &c).unwrap();
        let loaded = s.load(1).unwrap();
        let expect = c.total_bytes() as f64 / 50e6;
        assert!((loaded.device_secs - expect).abs() / expect < 0.3);
    }

    #[test]
    fn size_validation() {
        let mut c = chunk(1, 8);
        c.k.pop();
        let (_d, s) = store();
        assert!(s.store_sync(1, &c).is_err());
    }
}
