//! File-backed materialized-KV store with write-behind, throttled loads,
//! an optional DRAM hot tier ([`HotTier`]), and a sharded flash layer
//! ([`super::Shard`]) so aggregate load bandwidth scales past one bus.
//!
//! Four on-disk formats share one header layout (8 little-endian u32
//! words: magic, version, config id, layers, kv-heads, seq, head dim,
//! reserved/checksum):
//!
//! * **v1** — K/V planes as f32 (the original format; still loads).
//! * **v2** — K/V planes as f16: half the flash bytes, half the
//!   simulated device-read seconds for the same chunk.
//! * **v3** — f16 planes like v2, plus an FNV-1a checksum of the
//!   payload in the (previously reserved) eighth header word, verified
//!   on every read — same file size and device timing as v2, but a
//!   silently corrupted read is detected instead of served. The
//!   default write format; decode dispatches on the version word, so
//!   stores holding a mix of v1–v4 files serve all transparently.
//! * **v4** — the q4 **cool format**: per-plane f32 scales plus packed
//!   4-bit planes ([`quant::Q4Chunk`]), with the v3 checksum. ~4x fewer
//!   flash bytes than v1 and about half of v2/v3, which is the paper's
//!   compute-for-bytes trade one level deeper: the device read is
//!   priced at the smaller byte count and every load is charged a
//!   modeled q4→f32 dequant pass ([`Loaded::q4_dequant_secs`]) —
//!   the saved flash seconds are bought, not free.

use std::collections::{HashMap, HashSet};
use std::io::Write as _;
use std::path::{Path, PathBuf};
use std::sync::atomic::{AtomicU64, Ordering};
use std::sync::{Arc, Mutex};

use anyhow::{bail, Context, Result};

use super::cache::{HotTier, Probe};
use super::quant;
use super::shard::{route, Shard};
use super::warm::{WarmMode, WarmProbe, WarmTier};
use crate::hwsim::profiles::Q8_DEQUANT_BYTES_PER_SEC;
use crate::hwsim::{FaultPlan, Link, LinkClock, StorageProfile, TrafficClass};
use crate::manifest::ModelConfig;
use crate::trace::{Arg, TraceBus};
use crate::util::aio::{IoPool, Pending};
use crate::util::half::{f16_bits_to_f32, f32_to_f16_bits};
use crate::vectordb::ChunkId;

const MAGIC: u32 = 0x4d41_544b; // "MATK"
const HEADER_BYTES: usize = 8 * 4;

/// On-disk plane encoding. The header's version word selects the
/// decoder; the store's configured format selects the encoder.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum KvFormat {
    /// f32 planes (version word 1).
    V1,
    /// f16 planes (version word 2) — half the bytes of v1.
    V2,
    /// f16 planes + payload checksum in the reserved header word
    /// (version word 3) — same bytes and timing as v2.
    V3,
    /// q4 planes (per-plane f32 scales + two packed elements per byte)
    /// with the v3 checksum (version word 4) — about half the bytes of
    /// v2/v3, paid for with a modeled dequant pass on every load.
    V4,
}

/// Newest version word this reader decodes. A file declaring a higher
/// version was written by a newer matkv and is rejected with a
/// forward-compat message, not a generic decode bail.
const NEWEST_KV_VERSION: u32 = 4;

impl KvFormat {
    pub fn version(self) -> u32 {
        match self {
            KvFormat::V1 => 1,
            KvFormat::V2 => 2,
            KvFormat::V3 => 3,
            KvFormat::V4 => 4,
        }
    }

    /// Bytes per stored K/V element for the flat formats; `None` for
    /// v4, which packs two elements per byte plus per-plane scales (its
    /// sizing goes through [`KvChunk::file_bytes`] and the decoder's v4
    /// arm instead).
    pub fn elem_bytes(self) -> Option<usize> {
        match self {
            KvFormat::V1 => Some(4),
            KvFormat::V2 | KvFormat::V3 => Some(2),
            KvFormat::V4 => None,
        }
    }

    /// Does this format carry the payload checksum in the reserved
    /// header word?
    fn checksummed(self) -> bool {
        matches!(self, KvFormat::V3 | KvFormat::V4)
    }
}

/// FNV-1a over the payload (everything after the header) — the v3
/// record's corruption check. Not cryptographic; any single-bit flip
/// (what the fault injector models) is always detected because each
/// step `h → (h ^ b) * PRIME` is injective in `h`.
fn fnv1a32(data: &[u8]) -> u32 {
    let mut h: u32 = 2166136261;
    for &b in data {
        h = (h ^ b as u32).wrapping_mul(16777619);
    }
    h
}

/// One chunk's materialized KV tensors (host side).
///
/// `k`/`v` are `[n_layers, n_kv_heads, seq_len, head_dim]` f32,
/// row-major — the per-batch-element slice of the packed device cache, so
/// assembly into a serve-time cache is pure memcpy. In-memory planes are
/// always f32 regardless of the on-disk format.
#[derive(Debug, Clone, PartialEq)]
pub struct KvChunk {
    pub config_id: u32,
    pub n_layers: u32,
    pub n_kv_heads: u32,
    pub seq_len: u32,
    pub head_dim: u32,
    pub k: Vec<f32>,
    pub v: Vec<f32>,
}

impl KvChunk {
    pub fn plane_elems(&self) -> usize {
        self.n_layers as usize
            * self.n_kv_heads as usize
            * self.seq_len as usize
            * self.head_dim as usize
    }

    /// In-memory (f32 planes) footprint — also the v1 file size.
    pub fn total_bytes(&self) -> usize {
        HEADER_BYTES + 8 * self.plane_elems()
    }

    /// Resident bytes when held by the DRAM hot tier.
    pub fn dram_bytes(&self) -> usize {
        std::mem::size_of::<KvChunk>() + 8 * self.plane_elems()
    }

    /// Layer×head planes per tensor (the per-plane-scale count of the
    /// quantized formats).
    pub fn n_planes(&self) -> usize {
        self.n_layers as usize * self.n_kv_heads as usize
    }

    /// Elements in one layer×head plane.
    pub fn plane_len(&self) -> usize {
        self.seq_len as usize * self.head_dim as usize
    }

    /// On-disk size when encoded as `format`.
    pub fn file_bytes(&self, format: KvFormat) -> usize {
        match format.elem_bytes() {
            Some(eb) => HEADER_BYTES + 2 * eb * self.plane_elems(),
            // v4: per-plane f32 scales + packed nibbles, K and V.
            None => {
                HEADER_BYTES
                    + 2 * (4 * self.n_planes()
                        + self.n_planes() * quant::q4_plane_bytes(self.plane_len()))
            }
        }
    }

    fn validate(&self) -> Result<()> {
        if self.k.len() != self.plane_elems() || self.v.len() != self.plane_elems() {
            bail!(
                "KvChunk plane size mismatch: k={} v={} expect={}",
                self.k.len(),
                self.v.len(),
                self.plane_elems()
            );
        }
        Ok(())
    }
}

/// Stable id for a model config (validated on load so a store produced by
/// one model is never spliced into another).
pub fn config_id(cfg: &ModelConfig) -> u32 {
    let mut h: u32 = 2166136261;
    for b in cfg.name.bytes() {
        h = (h ^ b as u32).wrapping_mul(16777619);
    }
    h ^= (cfg.n_layers as u32) << 24 ^ (cfg.n_kv_heads as u32) << 16 ^ cfg.head_dim as u32;
    h
}

/// Cumulative I/O counters (device reads/writes; hot-tier hits never
/// touch these — see [`super::CacheStats`]).
#[derive(Debug, Default)]
pub struct StoreStats {
    pub reads: AtomicU64,
    pub writes: AtomicU64,
    pub bytes_read: AtomicU64,
    pub bytes_written: AtomicU64,
    pub deletes: AtomicU64,
}

/// The store: a set of shard directories under one root (one per
/// simulated device), fronted by an optional byte-budgeted DRAM hot
/// tier. [`KvStore::open`] gives the classic single-device store; with
/// [`KvStore::open_sharded`] it models a JBOD of independent SSDs.
pub struct KvStore {
    root: PathBuf,
    /// One per simulated device; new chunks are byte-balance-placed
    /// across them ([`KvStore::shard_index_of`]). Always non-empty.
    shards: Vec<Arc<Shard>>,
    /// Persisted byte-balanced placement: id → shard, plus cumulative
    /// placed bytes per shard (the argmin weights). Ids without a
    /// record fall back to [`route`].
    placement: Mutex<PlacementState>,
    pool: IoPool,
    format: KvFormat,
    hot: Option<Arc<HotTier>>,
    /// q8 warm tier between the hot tier and flash (hot-tier budget
    /// evictions demote here; warm hits dequantize and promote back).
    warm: Option<Arc<WarmTier>>,
    /// The shared host-side bus all DRAM-tier quant traffic crosses:
    /// warm→hot promotions (dequant) and hot→warm demotions (quant)
    /// contend here in [`LinkClock::Account`] mode — the charge
    /// magnitudes are unchanged, the bus adds the queueing telemetry.
    bus: Arc<Link>,
    /// Active fault plan, if any. `None` keeps the exact pre-fault
    /// miss path in `load_many` (no retry ladder, no extra probes), so
    /// a store without `--faults` is bit-identical to one built before
    /// the fault layer existed.
    faults: Option<Arc<FaultPlan>>,
    /// Bounded retries per failed shard read (fault plans only).
    max_retries: usize,
    /// Base of the exponential retry backoff, charged on the shard's
    /// link clock so waiting costs simulated time.
    retry_backoff_secs: f64,
    /// Modeled Vanilla-recompute seconds per chunk token — the last
    /// rung of the degradation ladder. 0 prices recompute as free; the
    /// fleet layer re-prices it per worker either way.
    recompute_secs_per_token: f64,
    /// Trace handle ([`crate::trace::TraceBus`]); disabled by default.
    /// [`KvStore::set_trace`] fans it out to the shards, the host bus,
    /// and both DRAM tiers, and the engine/overlap layers reach it via
    /// [`KvStore::trace`] — so `LoaderCtx` needs no extra field.
    trace: TraceBus,
    pub stats: Arc<StoreStats>,
}

/// Alias naming the JBOD-configured form of [`KvStore`]: since the shard
/// refactor every store *is* a shard set (a 1-shard set behaves exactly
/// like the original single-device store, down to the directory layout).
pub type ShardedKvStore = KvStore;

/// Shard-count pin, written into the store root so a directory laid out
/// as N shards is never reopened (and silently mis-routed) as M.
const SHARD_MARKER: &str = "SHARDS";

/// Append-only placement log in the store root: one `id shard bytes`
/// line per first-time placement, replayed on open so byte-balanced
/// placement survives reopens exactly like hash routing did.
const PLACEMENT_LOG: &str = "PLACEMENT";

/// In-memory form of the placement log. Append-only by design: deletes
/// keep their records (and their byte weights — conservative for the
/// ingest-dominated workloads the store models), and re-stores of a
/// placed id reuse the original shard, so each id appears at most once.
#[derive(Debug, Default)]
struct PlacementState {
    map: HashMap<ChunkId, usize>,
    /// Cumulative placed bytes per shard — the argmin weights.
    shard_bytes: Vec<u64>,
}

/// Result of a load: the chunk plus where it came from and what it cost.
#[derive(Debug)]
pub struct Loaded {
    pub chunk: Arc<KvChunk>,
    /// Simulated storage-device seconds (0 for DRAM-tier hits).
    pub device_secs: f64,
    /// Size of the chunk's on-disk file (for a hit: the read it avoided).
    pub file_bytes: usize,
    /// Served without a device read: a DRAM tier hit (hot or warm), or a
    /// reuse of an identical id earlier in the same `load_many` call.
    pub from_cache: bool,
    /// Served by the quantized warm tier: no device read, but the
    /// planes were dequantized (lossy within the codec's error bound)
    /// and the load was charged `dequant_secs` (q8 mode) or
    /// `q4_dequant_secs` (q4 mode) of modeled time.
    pub from_warm: bool,
    /// Modeled q8→f32 dequantization seconds (q8 warm hits only; 0
    /// elsewhere, including for in-call duplicates of a warm hit — the
    /// dequantized chunk is shared, not re-decoded).
    pub dequant_secs: f64,
    /// Modeled q4→f32 dequantization seconds: charged on every v4 flash
    /// load (the priced half of the v4 byte saving) and on warm hits in
    /// q4 mode. Kept distinct from `dequant_secs` so the fig JSONs can
    /// attribute the cool-path trade.
    pub q4_dequant_secs: f64,
    /// Modeled f32→q8 quantization seconds this load paid admitting its
    /// chunk into the warm tier (warm-only stores and chunks oversize
    /// for the hot tier; 0 elsewhere — demote-on-evict quantization is
    /// charged to the *evicting* tier's [`super::CacheStats`], not to
    /// the load that triggered it).
    pub quant_secs: f64,
    /// Index of the shard this chunk routes to (for a hit: the device
    /// read the hit avoided).
    pub shard: usize,
    /// Shard-read retries this load needed (fault plans only; 0 on the
    /// clean path).
    pub retries: usize,
    /// Simulated seconds spent in retry backoff, already charged on
    /// the shard's link clock.
    pub retry_backoff_secs: f64,
    /// Reads whose v3 payload checksum rejected corrupted bytes.
    pub checksum_failures: usize,
    /// Served by the Vanilla recompute safety net: every flash rung of
    /// the ladder failed, so the chunk's tokens were re-prefilled
    /// (`recompute_secs` of modeled time) instead of loaded.
    pub recomputed: bool,
    /// Modeled recompute seconds (see [`KvStore::set_recompute_model`]).
    pub recompute_secs: f64,
}

impl Loaded {
    /// A clean (non-degraded) load outcome — every field the fault
    /// layer owns at its zero.
    fn clean(
        chunk: Arc<KvChunk>,
        device_secs: f64,
        file_bytes: usize,
        from_cache: bool,
        from_warm: bool,
        dequant_secs: f64,
        quant_secs: f64,
        shard: usize,
    ) -> Self {
        Loaded {
            chunk,
            device_secs,
            file_bytes,
            from_cache,
            from_warm,
            dequant_secs,
            q4_dequant_secs: 0.0,
            quant_secs,
            shard,
            retries: 0,
            retry_backoff_secs: 0.0,
            checksum_failures: 0,
            recomputed: false,
            recompute_secs: 0.0,
        }
    }
}

/// Point-in-time snapshot of DRAM residency, split by tier — the
/// routing input of the fleet dispatcher
/// ([`crate::coordinator::fleet::Fleet`]): a chunk in either set can be
/// served without a storage-device read (warm residents additionally
/// owe a dequant pass), so batches made of resident chunks are safe to
/// route to low-end decode workers. Like [`KvStore::resident_ids`] this
/// is advisory — residency can change the moment the snapshot returns.
#[derive(Debug, Clone, Default)]
pub struct ResidentSet {
    /// Ids resident in the f32 hot tier.
    pub hot: HashSet<ChunkId>,
    /// Ids resident in the q8 warm tier.
    pub warm: HashSet<ChunkId>,
}

impl ResidentSet {
    /// Is `id` resident in either DRAM tier?
    pub fn contains(&self, id: ChunkId) -> bool {
        self.hot.contains(&id) || self.warm.contains(&id)
    }

    /// Total resident ids (a promote in flight can briefly double-list
    /// an id; the union collapses it).
    pub fn len(&self) -> usize {
        if self.warm.is_empty() {
            self.hot.len()
        } else {
            self.hot.union(&self.warm).count()
        }
    }

    pub fn is_empty(&self) -> bool {
        self.hot.is_empty() && self.warm.is_empty()
    }
}

/// Outcome of a [`KvStore::prefetch_many`] pass. Prefetch is strictly
/// best-effort: unreadable chunks degrade to a later demand miss and
/// admission can be refused to protect demand-resident chunks, so the
/// report carries counts, never errors.
#[derive(Debug, Clone, Default, PartialEq)]
pub struct PrefetchReport {
    /// Ids requested (after in-call dedup).
    pub requested: usize,
    /// Already resident in the hot tier — nothing to do.
    pub already_resident: usize,
    /// Read from flash and admitted to a DRAM tier: the hot tier, or —
    /// when its protected admission refused the chunk, in a warm-only
    /// store, or for a chunk oversize for hot — parked as q8 in the
    /// warm tier (demote-on-prefetch-reject).
    pub warmed: usize,
    /// Missing/unreadable on flash — left for the demand path to surface.
    pub absent: usize,
    /// Read but not admitted (admission guard or superseded mid-flight).
    pub rejected: usize,
    /// Simulated device seconds the prefetch reads consumed.
    pub device_secs: f64,
}

impl KvStore {
    /// Open (creating if needed) a single-device store under `dir`,
    /// timed as `profile`. Writes default to the v2 (f16) format; no
    /// hot tier. Layout-compatible with pre-shard stores: chunk files
    /// live directly under `dir`.
    pub fn open(dir: impl AsRef<Path>, profile: StorageProfile) -> Result<Self> {
        Self::open_sharded(dir, profile, 1)
    }

    /// Open a store of `n_shards` independent simulated devices (a
    /// JBOD): chunk ids hash across shard directories, each shard
    /// charges its own [`super::DeviceThrottle`], and `load_many`
    /// misses to different shards overlap in simulated device time.
    ///
    /// `n_shards == 1` keeps files directly under `dir` (the original
    /// layout); more shards use `dir/shard-NN/`. The count is pinned by
    /// a marker file: reopening with a different count fails loudly
    /// instead of silently routing ids to the wrong directories.
    pub fn open_sharded(
        dir: impl AsRef<Path>,
        profile: StorageProfile,
        n_shards: usize,
    ) -> Result<Self> {
        if n_shards == 0 {
            bail!("a KvStore needs at least one shard");
        }
        let root = dir.as_ref().to_path_buf();
        std::fs::create_dir_all(&root).with_context(|| format!("creating {root:?}"))?;
        let marker = root.join(SHARD_MARKER);
        match std::fs::read_to_string(&marker) {
            Ok(text) => {
                let pinned: usize = text
                    .trim()
                    .parse()
                    .with_context(|| format!("corrupt shard marker {marker:?}: {text:?}"))?;
                if pinned != n_shards {
                    bail!(
                        "store at {root:?} is laid out as {pinned} shard(s); reopening with \
                         {n_shards} would mis-route chunk ids"
                    );
                }
            }
            Err(e) if e.kind() == std::io::ErrorKind::NotFound => {
                if n_shards > 1 && Self::has_loose_chunks(&root)? {
                    bail!(
                        "store at {root:?} holds a single-shard layout (chunk files in the \
                         root); cannot reopen it with {n_shards} shards"
                    );
                }
                std::fs::write(&marker, format!("{n_shards}\n"))
                    .with_context(|| format!("writing shard marker {marker:?}"))?;
            }
            Err(e) => return Err(e).with_context(|| format!("reading shard marker {marker:?}")),
        }
        let shards = (0..n_shards)
            .map(|i| {
                let sdir = if n_shards == 1 {
                    root.clone()
                } else {
                    root.join(format!("shard-{i:02}"))
                };
                Shard::open(i, sdir, profile.clone()).map(Arc::new)
            })
            .collect::<Result<Vec<_>>>()?;
        let placement = Self::replay_placement(&root, n_shards)?;
        Ok(KvStore {
            root,
            shards,
            placement: Mutex::new(placement),
            // Enough workers that every simulated device can have I/O in
            // flight at once, bounded so huge JBODs don't spawn armies.
            pool: IoPool::new((2 * n_shards).clamp(4, 16)),
            format: KvFormat::V3,
            hot: None,
            warm: None,
            bus: Arc::new(Link::new(
                "host-bus",
                Q8_DEQUANT_BYTES_PER_SEC,
                0.0,
                LinkClock::Account,
            )),
            faults: None,
            max_retries: 3,
            retry_backoff_secs: 0.002,
            recompute_secs_per_token: 0.0,
            trace: TraceBus::disabled(),
            stats: Arc::new(StoreStats::default()),
        })
    }

    /// Wire the whole storage stack to a trace bus: per-chunk tier
    /// outcomes at store level, per-shard device-link reservations, the
    /// shared host bus, and both DRAM tiers' eviction traffic. Call
    /// after the tier/profile setters — replacing a tier or profile
    /// builds untraced components.
    pub fn set_trace(&mut self, trace: TraceBus) {
        self.bus.set_trace(trace.clone(), "link:host-bus");
        for shard in &self.shards {
            shard.set_trace(trace.clone());
        }
        if let Some(hot) = &self.hot {
            hot.set_trace(trace.clone());
        }
        if let Some(warm) = &self.warm {
            warm.set_trace(trace.clone());
        }
        self.trace = trace;
    }

    /// The store's trace handle (disabled unless [`KvStore::set_trace`]
    /// wired a recording bus) — how the engine and overlap layers reach
    /// the one shared bus.
    pub fn trace(&self) -> &TraceBus {
        &self.trace
    }

    /// Install (or clear) a deterministic fault plan. The plan is
    /// propagated to every shard (injection happens at the device) and
    /// arms the recovery ladder in [`KvStore::load_many`]; clearing it
    /// restores the exact pre-fault code path.
    pub fn set_faults(&mut self, plan: Option<Arc<FaultPlan>>) {
        for shard in &self.shards {
            shard.set_faults(plan.clone());
        }
        self.faults = plan;
    }

    /// The active fault plan, if any.
    pub fn faults(&self) -> Option<&Arc<FaultPlan>> {
        self.faults.as_ref()
    }

    /// Retry policy for failed shard reads under a fault plan: up to
    /// `max_retries` re-reads, the n-th preceded by a backoff of
    /// `backoff_secs * 2^n` charged on the shard's link clock.
    pub fn set_retry_policy(&mut self, max_retries: usize, backoff_secs: f64) {
        self.max_retries = max_retries;
        self.retry_backoff_secs = backoff_secs.max(0.0);
    }

    /// Price the recompute safety net: modeled seconds of Vanilla
    /// prefill per token of a chunk that had to be recomputed because
    /// every other rung of the degradation ladder failed.
    pub fn set_recompute_model(&mut self, secs_per_token: f64) {
        self.recompute_secs_per_token = secs_per_token.max(0.0);
    }

    /// Rebuild the placement map from the append-only log (absent for
    /// fresh or pre-placement stores: every id then resolves through
    /// the [`route`] fallback, which is exactly where the legacy layout
    /// put its files).
    fn replay_placement(root: &Path, n_shards: usize) -> Result<PlacementState> {
        let mut state =
            PlacementState { map: HashMap::new(), shard_bytes: vec![0; n_shards] };
        let path = root.join(PLACEMENT_LOG);
        let text = match std::fs::read_to_string(&path) {
            Ok(t) => t,
            Err(e) if e.kind() == std::io::ErrorKind::NotFound => return Ok(state),
            Err(e) => return Err(e).with_context(|| format!("reading placement log {path:?}")),
        };
        let lines: Vec<&str> = text.lines().collect();
        for (i, line) in lines.iter().enumerate() {
            // A malformed FINAL record is a torn append — the crash the
            // fault plans simulate — and means clean EOF: the id falls
            // back to route(). Malformed records anywhere earlier are
            // not a crash artifact (appends are ordered), so the log is
            // corrupt and replaying the rest would mis-route silently.
            let parsed = {
                let mut it = line.split_whitespace();
                match (it.next(), it.next(), it.next()) {
                    (Some(a), Some(b), Some(c)) => {
                        match (a.parse::<ChunkId>(), b.parse::<usize>(), c.parse::<u64>()) {
                            (Ok(id), Ok(shard), Ok(bytes)) => Some((id, shard, bytes)),
                            _ => None,
                        }
                    }
                    _ => None,
                }
            };
            let Some((id, shard, bytes)) = parsed else {
                if i + 1 == lines.len() {
                    break; // torn trailing record: clean EOF
                }
                bail!(
                    "placement log {path:?} line {} is corrupt (not a trailing \
                     torn write): {line:?}",
                    i + 1
                );
            };
            if shard >= n_shards {
                bail!(
                    "placement log {path:?} names shard {shard} but the store has \
                     {n_shards}; the layout is corrupt"
                );
            }
            if state.map.insert(id, shard).is_none() {
                state.shard_bytes[shard] += bytes;
            }
        }
        Ok(state)
    }

    fn has_loose_chunks(root: &Path) -> Result<bool> {
        Ok(std::fs::read_dir(root)?
            .filter_map(|e| e.ok())
            .any(|e| e.path().extension().is_some_and(|x| x == "kv")))
    }

    /// Swap the simulated storage device on every shard (Table III
    /// sweeps this). Cumulative per-shard stats carry over.
    pub fn set_profile(&mut self, profile: StorageProfile) {
        self.shards =
            self.shards.iter().map(|s| Arc::new(s.with_profile(profile.clone(), true))).collect();
    }

    /// Disable wall-clock throttling on every shard (pure-functional
    /// tests; simulated device seconds are still computed).
    pub fn disable_throttle(&mut self) {
        self.shards = self
            .shards
            .iter()
            .map(|s| Arc::new(s.with_profile(s.profile().clone(), false)))
            .collect();
    }

    /// Profile of the simulated devices (uniform across shards).
    pub fn profile(&self) -> &StorageProfile {
        self.shards[0].profile()
    }

    /// Root directory of the store (shard dirs live under it).
    pub fn root(&self) -> &Path {
        &self.root
    }

    pub fn n_shards(&self) -> usize {
        self.shards.len()
    }

    /// Width of the store's I/O pool (scales with the shard count so
    /// every simulated device can have reads in flight at once).
    pub fn io_threads(&self) -> usize {
        self.pool.threads()
    }

    /// The shard set (telemetry: per-device stats, dirs).
    pub fn shards(&self) -> &[Arc<Shard>] {
        &self.shards
    }

    /// Which shard `id` routes to (stable across reopens): the
    /// byte-balanced placement record when one exists, else the
    /// [`route`] hash (legacy layouts and never-stored ids).
    pub fn shard_index_of(&self, id: ChunkId) -> usize {
        let pl = self.placement.lock().unwrap();
        pl.map.get(&id).copied().unwrap_or_else(|| route(id, self.shards.len()))
    }

    /// Choose (and persist) the shard a new chunk of `bytes` lands on:
    /// the shard with the least cumulative placed bytes, ties to the
    /// lowest index — so equal-size chunks round-robin and a run of
    /// large chunks can't pile onto one device and serialize the
    /// `load_many` fan-out the way count-balanced hashing could.
    /// Re-stores of an already-placed id keep their shard.
    fn place_shard(&self, id: ChunkId, bytes: usize) -> Result<usize> {
        let mut pl = self.placement.lock().unwrap();
        if let Some(&s) = pl.map.get(&id) {
            return Ok(s);
        }
        let mut best = 0;
        for (i, &b) in pl.shard_bytes.iter().enumerate() {
            if b < pl.shard_bytes[best] {
                best = i;
            }
        }
        // Log before mutating: if the append fails, the in-memory state
        // still matches what a reopen would replay.
        let path = self.root.join(PLACEMENT_LOG);
        let mut file = std::fs::OpenOptions::new()
            .create(true)
            .append(true)
            .open(&path)
            .with_context(|| format!("opening placement log {path:?}"))?;
        writeln!(file, "{id} {best} {bytes}")
            .with_context(|| format!("appending placement log {path:?}"))?;
        pl.map.insert(id, best);
        pl.shard_bytes[best] += bytes as u64;
        Ok(best)
    }

    /// Cumulative placed bytes per shard (the placement balancer's
    /// weights — telemetry for the serve report and skew tests).
    pub fn shard_placed_bytes(&self) -> Vec<u64> {
        self.placement.lock().unwrap().shard_bytes.clone()
    }

    /// The shared host-side quant/dequant bus: warm→hot promotion and
    /// hot→warm demotion traffic contends here (see [`Link`]).
    pub fn bus(&self) -> &Arc<Link> {
        &self.bus
    }

    /// Register the whole storage hierarchy into a metrics registry:
    /// store-level counters (`matkv.store.*`), the host bus
    /// (`matkv.link.*{link=hostbus}`, with per-traffic-class bytes),
    /// every shard (`matkv.shard.*{shard=i}`), and whichever DRAM tiers
    /// are enabled (`matkv.tier.*{tier=hot|warm}`). Polled bridges over
    /// the existing relaxed atomics — the load/store hot paths are
    /// untouched. Call once per registry; a second call on the same
    /// registry fails loudly on the first duplicate id.
    pub fn register_metrics(&self, reg: &crate::obs::MetricsRegistry) -> Result<()> {
        macro_rules! store_counter {
            ($name:expr, $help:expr, $field:ident) => {{
                let s = Arc::clone(&self.stats);
                reg.counter_fn($name, &[], $help, move || {
                    s.$field.load(Ordering::Relaxed) as f64
                })?;
            }};
        }
        store_counter!("matkv.store.reads", "chunk loads issued to the store", reads);
        store_counter!("matkv.store.writes", "chunk stores issued", writes);
        store_counter!("matkv.store.bytes_read", "flash bytes read", bytes_read);
        store_counter!("matkv.store.bytes_written", "flash bytes written", bytes_written);
        store_counter!("matkv.store.deletes", "chunk deletions", deletes);

        crate::hwsim::register_link_metrics(reg, &self.bus, &[("link", "hostbus")], true)?;

        for (i, shard) in self.shards.iter().enumerate() {
            let idx = i.to_string();
            let labels = [("shard", idx.as_str())];
            macro_rules! shard_counter {
                ($name:expr, $help:expr, |$s:ident| $body:expr) => {{
                    let s = Arc::clone(&shard.stats);
                    reg.counter_fn($name, &labels, $help, move || {
                        let $s = &s;
                        $body
                    })?;
                }};
            }
            shard_counter!("matkv.shard.reads", "device reads", |s| {
                s.reads.load(Ordering::Relaxed) as f64
            });
            shard_counter!("matkv.shard.writes", "device writes", |s| {
                s.writes.load(Ordering::Relaxed) as f64
            });
            shard_counter!("matkv.shard.deletes", "device deletes", |s| {
                s.deletes.load(Ordering::Relaxed) as f64
            });
            shard_counter!("matkv.shard.bytes_read", "device bytes read", |s| {
                s.bytes_read.load(Ordering::Relaxed) as f64
            });
            shard_counter!("matkv.shard.bytes_written", "device bytes written", |s| {
                s.bytes_written.load(Ordering::Relaxed) as f64
            });
            shard_counter!(
                "matkv.shard.device_read_seconds",
                "simulated device seconds in reads",
                |s| s.read_device_secs()
            );
            shard_counter!(
                "matkv.shard.device_write_seconds",
                "simulated device seconds in writes",
                |s| s.write_device_secs()
            );
            shard_counter!("matkv.shard.write_errors", "failed writes", |s| {
                s.write_errors.load(Ordering::Relaxed) as f64
            });
            {
                let s = Arc::clone(&shard.stats);
                reg.gauge_fn("matkv.shard.queue_depth", &labels, "reads in flight", move || {
                    s.queue_depth.load(Ordering::Relaxed) as f64
                })?;
            }
            {
                let s = Arc::clone(&shard.stats);
                reg.gauge_fn(
                    "matkv.shard.peak_queue_depth",
                    &labels,
                    "high-water mark of reads in flight",
                    move || s.peak_queue_depth.load(Ordering::Relaxed) as f64,
                )?;
            }
        }

        if let Some(hot) = &self.hot {
            crate::obs::register_tier(reg, Arc::clone(hot))?;
        }
        if let Some(warm) = &self.warm {
            crate::obs::register_tier(reg, Arc::clone(warm))?;
        }
        Ok(())
    }

    fn shard_of(&self, id: ChunkId) -> &Arc<Shard> {
        &self.shards[self.shard_index_of(id)]
    }

    /// Per-shard peak read queue depth (cumulative high-water marks).
    pub fn shard_peak_queues(&self) -> Vec<u64> {
        self.shards
            .iter()
            .map(|s| s.stats.peak_queue_depth.load(Ordering::Relaxed))
            .collect()
    }

    /// Select the on-disk format for subsequent writes (loads always
    /// accept both).
    pub fn set_format(&mut self, format: KvFormat) {
        self.format = format;
    }

    pub fn format(&self) -> KvFormat {
        self.format
    }

    /// Enable a DRAM hot tier of `budget_bytes` resident bytes
    /// (0 disables). Replacing the tier drops its contents.
    pub fn set_hot_tier(&mut self, budget_bytes: usize) {
        self.hot =
            if budget_bytes > 0 { Some(Arc::new(HotTier::new(budget_bytes))) } else { None };
        self.wire_demote();
    }

    /// Enable a q8 **warm tier** of `budget_bytes` resident bytes behind
    /// the hot tier (0 disables; replacing drops contents). With a hot
    /// tier present, budget evictions *demote* into the warm tier
    /// instead of dropping, and warm hits dequantize + promote back
    /// (exclusive placement). Without one, the warm tier is the
    /// first-level cache: misses admit quantized copies directly.
    pub fn set_warm_tier(&mut self, budget_bytes: usize) {
        self.warm = if budget_bytes > 0 {
            let mut warm = WarmTier::new(budget_bytes);
            // Quantize traffic entering the tier (demotions, direct
            // admissions, prefetch parks) contends on the host bus.
            warm.set_bus(self.bus.clone());
            Some(Arc::new(warm))
        } else {
            None
        };
        self.wire_demote();
    }

    /// Select the warm tier's codec for future admissions
    /// (`--warm-mode q8|q4`; see [`WarmMode`]). No-op without a warm
    /// tier; call after [`KvStore::set_warm_tier`] — replacing the tier
    /// resets the mode to the q8 default.
    pub fn set_warm_mode(&self, mode: WarmMode) {
        if let Some(warm) = &self.warm {
            warm.set_mode(mode);
        }
    }

    /// Select the hot tier's demand-admission policy
    /// (`--admission lru|tinylfu`; see
    /// [`super::cache::AdmissionPolicy`]). No-op without a hot tier;
    /// call after [`KvStore::set_hot_tier`] — replacing the tier resets
    /// the policy to the LRU default.
    pub fn set_admission(&self, policy: super::cache::AdmissionPolicy) {
        if let Some(hot) = &self.hot {
            hot.set_admission(policy);
        }
    }

    /// Point the hot tier's budget evictions at the warm tier (or back
    /// at the void). Called whenever either tier is replaced, so the
    /// demote path survives any `set_hot_tier`/`set_warm_tier` order.
    fn wire_demote(&self) {
        if let Some(hot) = &self.hot {
            hot.set_demote_sink(
                self.warm.as_ref().map(|w| w.clone() as Arc<dyn super::cache::DemoteSink>),
            );
        }
    }

    pub fn hot_tier(&self) -> Option<&HotTier> {
        self.hot.as_deref()
    }

    pub fn warm_tier(&self) -> Option<&WarmTier> {
        self.warm.as_deref()
    }

    /// Snapshot of every DRAM-resident chunk id — the union of the hot
    /// and warm tiers (either may be absent). The serving scheduler's
    /// tier-affinity policy scores queued requests by overlap of their
    /// retrieval top-K with this set — advisory only, residency can
    /// change as soon as the snapshot is taken (see
    /// [`HotTier::resident_ids`]). Policies that price the dequant cost
    /// use the per-tier snapshots ([`KvStore::hot_resident_ids`] /
    /// [`KvStore::warm_resident_ids`]) instead.
    pub fn resident_ids(&self) -> Vec<ChunkId> {
        let mut ids = self.hot_resident_ids();
        ids.extend(self.warm_resident_ids());
        ids.sort_unstable();
        ids.dedup(); // a promote in flight can briefly double-list an id
        ids
    }

    /// Resident ids of the hot (f32) tier only.
    pub fn hot_resident_ids(&self) -> Vec<ChunkId> {
        self.hot.as_deref().map(HotTier::resident_ids).unwrap_or_default()
    }

    /// Resident ids of the q8 warm tier only — served without a device
    /// read but at a dequant cost, which tier-affinity scoring discounts.
    pub fn warm_resident_ids(&self) -> Vec<ChunkId> {
        self.warm.as_deref().map(WarmTier::resident_ids).unwrap_or_default()
    }

    /// Per-tier residency snapshot (see [`ResidentSet`]) — what the
    /// fleet's routing policy consumes to tell KV-resident batches from
    /// cache-miss ones.
    pub fn resident_set(&self) -> ResidentSet {
        ResidentSet {
            hot: self.hot_resident_ids().into_iter().collect(),
            warm: self.warm_resident_ids().into_iter().collect(),
        }
    }

    /// On-disk size of `chunk` in the store's current write format.
    pub fn encoded_bytes(&self, chunk: &KvChunk) -> usize {
        chunk.file_bytes(self.format)
    }

    fn path_of(&self, id: ChunkId) -> PathBuf {
        self.shard_of(id).path_of(id)
    }

    pub fn contains(&self, id: ChunkId) -> bool {
        self.shard_of(id).contains(id)
    }

    fn encode(chunk: &KvChunk, format: KvFormat) -> Vec<u8> {
        let mut buf = Vec::with_capacity(chunk.file_bytes(format));
        for word in [
            MAGIC,
            format.version(),
            chunk.config_id,
            chunk.n_layers,
            chunk.n_kv_heads,
            chunk.seq_len,
            chunk.head_dim,
            0, // reserved
        ] {
            buf.extend_from_slice(&word.to_le_bytes());
        }
        match format {
            KvFormat::V1 | KvFormat::V2 | KvFormat::V3 => {
                for plane_data in [&chunk.k, &chunk.v] {
                    match format {
                        KvFormat::V1 => {
                            for &x in plane_data.iter() {
                                buf.extend_from_slice(&x.to_le_bytes());
                            }
                        }
                        _ => {
                            for &x in plane_data.iter() {
                                buf.extend_from_slice(&f32_to_f16_bits(x).to_le_bytes());
                            }
                        }
                    }
                }
            }
            KvFormat::V4 => {
                // Per tensor: the per-plane f32 scales, then the packed
                // nibble planes (each plane starts on a byte boundary).
                let q = quant::quantize_q4(chunk);
                for (scales, packed) in [(&q.k_scales, &q.k_q), (&q.v_scales, &q.v_q)] {
                    for &s in scales.iter() {
                        buf.extend_from_slice(&s.to_le_bytes());
                    }
                    buf.extend_from_slice(packed);
                }
            }
        }
        if format.checksummed() {
            // Patch the payload checksum into the reserved header word.
            let sum = fnv1a32(&buf[HEADER_BYTES..]);
            buf[28..32].copy_from_slice(&sum.to_le_bytes());
        }
        buf
    }

    /// Decode a record, also reporting which on-disk format it carried
    /// (the load path prices a v4 record's dequant pass from this).
    fn decode_versioned(data: &[u8]) -> Result<(KvChunk, KvFormat)> {
        if data.len() < HEADER_BYTES {
            bail!("KV file truncated: {} bytes", data.len());
        }
        let word = |i: usize| u32::from_le_bytes(data[i * 4..i * 4 + 4].try_into().unwrap());
        if word(0) != MAGIC {
            bail!("bad KV magic {:#x}", word(0));
        }
        let format = match word(1) {
            1 => KvFormat::V1,
            2 => KvFormat::V2,
            3 => KvFormat::V3,
            4 => KvFormat::V4,
            v if v > NEWEST_KV_VERSION => bail!(
                "KV format {v} from a newer writer: this reader decodes up to \
                 v{NEWEST_KV_VERSION} — upgrade matkv (or re-materialize with --kv-format)"
            ),
            v => bail!("unsupported KV version {v}"),
        };
        // Header dimensions are untrusted: all size math is checked so a
        // corrupt/adversarial header can never wrap and pass the size
        // check (u32 products overflow u32 and even u64 at the extremes).
        let plane_u64 = [word(3), word(4), word(5), word(6)]
            .into_iter()
            .try_fold(1u64, |acc, w| acc.checked_mul(w as u64))
            .context("KV header dimensions overflow")?;
        let n_planes_u64 = (word(3) as u64)
            .checked_mul(word(4) as u64)
            .context("KV header dimensions overflow")?;
        let expected = match format.elem_bytes() {
            Some(eb) => plane_u64
                .checked_mul(2 * eb as u64)
                .and_then(|b| b.checked_add(HEADER_BYTES as u64))
                .context("KV header dimensions overflow")?,
            None => {
                // v4: scales + packed nibbles per tensor. plane_len =
                // seq * head_dim; packed = ceil(plane_len / 2) per plane.
                let plane_len = (word(5) as u64)
                    .checked_mul(word(6) as u64)
                    .context("KV header dimensions overflow")?;
                let per_tensor = n_planes_u64
                    .checked_mul(4 + plane_len.div_ceil(2))
                    .context("KV header dimensions overflow")?;
                per_tensor
                    .checked_mul(2)
                    .and_then(|b| b.checked_add(HEADER_BYTES as u64))
                    .context("KV header dimensions overflow")?
            }
        };
        if data.len() as u64 != expected {
            bail!("KV file size mismatch: {} vs {expected}", data.len());
        }
        // Size checks can't see a bit flip; the v3/v4 payload checksum can.
        if format.checksummed() && fnv1a32(&data[HEADER_BYTES..]) != word(7) {
            bail!("KV checksum mismatch: the payload was corrupted");
        }
        let plane = plane_u64 as usize; // fits: expected == data.len()
        let chunk = match format.elem_bytes() {
            Some(eb) => {
                let floats = |idx: usize| -> Vec<f32> {
                    let off = HEADER_BYTES + idx * plane * eb;
                    let src = &data[off..off + plane * eb];
                    match format {
                        KvFormat::V1 => src
                            .chunks_exact(4)
                            .map(|b| f32::from_le_bytes(b.try_into().unwrap()))
                            .collect(),
                        _ => src
                            .chunks_exact(2)
                            .map(|b| f16_bits_to_f32(u16::from_le_bytes(b.try_into().unwrap())))
                            .collect(),
                    }
                };
                KvChunk {
                    config_id: word(2),
                    n_layers: word(3),
                    n_kv_heads: word(4),
                    seq_len: word(5),
                    head_dim: word(6),
                    k: floats(0),
                    v: floats(1),
                }
            }
            None => {
                let n_planes = n_planes_u64 as usize;
                let plane_len = word(5) as usize * word(6) as usize;
                let packed = quant::q4_plane_bytes(plane_len);
                let per_tensor = 4 * n_planes + n_planes * packed;
                let tensor = |idx: usize| -> (Vec<f32>, Vec<u8>) {
                    let off = HEADER_BYTES + idx * per_tensor;
                    let scales = data[off..off + 4 * n_planes]
                        .chunks_exact(4)
                        .map(|b| f32::from_le_bytes(b.try_into().unwrap()))
                        .collect();
                    let q = data[off + 4 * n_planes..off + per_tensor].to_vec();
                    (scales, q)
                };
                let (k_scales, k_q) = tensor(0);
                let (v_scales, v_q) = tensor(1);
                quant::dequantize_q4(&quant::Q4Chunk {
                    config_id: word(2),
                    n_layers: word(3),
                    n_kv_heads: word(4),
                    seq_len: word(5),
                    head_dim: word(6),
                    k_scales,
                    v_scales,
                    k_q,
                    v_q,
                })
            }
        };
        Ok((chunk, format))
    }

    fn decode(data: &[u8]) -> Result<KvChunk> {
        Self::decode_versioned(data).map(|(chunk, _)| chunk)
    }

    /// Modeled q4→f32 dequant seconds a freshly read record owes: the
    /// v4 payload priced through
    /// [`crate::hwsim::profiles::q4_dequant_secs`], 0 for the flat
    /// formats (their decode is part of the ordinary load path).
    fn q4_decode_price(format: KvFormat, file_len: usize) -> f64 {
        match format {
            KvFormat::V4 => {
                crate::hwsim::profiles::q4_dequant_secs((file_len - HEADER_BYTES) as f64)
            }
            _ => 0.0,
        }
    }

    /// Invalidate `id` in every DRAM tier, **hot first**: the hot-side
    /// invalidation serializes behind any in-flight demotion of this id
    /// (both hold the hot LRU lock), so the warm-side sweep that follows
    /// always sees — and removes — whatever that demotion parked.
    fn invalidate_tiers(&self, id: ChunkId) {
        if let Some(hot) = &self.hot {
            hot.invalidate(id);
        }
        if let Some(warm) = &self.warm {
            warm.invalidate(id);
        }
    }

    /// Synchronous materialization (throttled to the device profile).
    ///
    /// The DRAM tiers are invalidated on *both* sides of the write: the
    /// first pass drops resident copies, the second (generation bump)
    /// rejects any concurrent load that read the superseded file while
    /// the write was in flight — no tier ever serves a stale KV.
    pub fn store_sync(&self, id: ChunkId, chunk: &KvChunk) -> Result<f64> {
        chunk.validate()?;
        self.invalidate_tiers(id);
        let buf = Self::encode(chunk, self.format);
        let secs = self.shards[self.place_shard(id, buf.len())?].write(id, &buf)?;
        self.invalidate_tiers(id);
        self.stats.writes.fetch_add(1, Ordering::Relaxed);
        self.stats.bytes_written.fetch_add(buf.len() as u64, Ordering::Relaxed);
        Ok(secs)
    }

    /// Write-behind materialization: returns immediately, the write runs
    /// on the store's I/O pool (the role DeepNVMe's async_io plays in the
    /// paper's prototype). Wait on the handle (or [`KvStore::drain`]) to
    /// observe errors and the simulated device seconds. Invalid chunks
    /// and I/O failures surface as `Err` through the handle — never a
    /// panic — and failed writes are not counted in [`StoreStats`].
    pub fn store_async(&self, id: ChunkId, chunk: KvChunk) -> Pending<Result<f64>> {
        if let Err(e) = chunk.validate() {
            return self.pool.submit(move || Err(e));
        }
        self.invalidate_tiers(id);
        let buf = Self::encode(&chunk, self.format);
        // Placement is decided (and logged) at submission time, so the
        // order writes were issued in — not pool scheduling — fixes the
        // balancer's byte weights deterministically.
        let shard = match self.place_shard(id, buf.len()) {
            Ok(idx) => self.shards[idx].clone(),
            Err(e) => return self.pool.submit(move || Err(e)),
        };
        let stats = self.stats.clone();
        let hot = self.hot.clone();
        let warm = self.warm.clone();
        self.pool.submit(move || {
            let secs = shard.write(id, &buf)?;
            // Second invalidation once the write landed: a load that
            // raced the write and read the old bytes can no longer keep
            // or re-admit them, in either tier (see store_sync).
            if let Some(hot) = &hot {
                hot.invalidate(id);
            }
            if let Some(warm) = &warm {
                warm.invalidate(id);
            }
            // Accounting happens only once the write actually landed.
            stats.writes.fetch_add(1, Ordering::Relaxed);
            stats.bytes_written.fetch_add(buf.len() as u64, Ordering::Relaxed);
            Ok(secs)
        })
    }

    /// Block until previously spawned async writes have finished; returns
    /// the total simulated device-write seconds.
    pub fn drain(&self, handles: Vec<Pending<Result<f64>>>) -> Result<f64> {
        let mut total = 0.0;
        for h in handles {
            total += h.wait()?;
        }
        Ok(total)
    }

    /// Load one chunk: hot tier first (free), then the q8 warm tier
    /// (dequant cost), then the throttled device.
    pub fn load(&self, id: ChunkId) -> Result<Loaded> {
        let mut loaded = self.load_many(std::slice::from_ref(&id))?;
        Ok(loaded.pop().expect("load_many returns one Loaded per id"))
    }

    /// Serve a warm-tier hit: dequantize the payload with whichever
    /// codec it was packed with, charge the modeled dequant cost — the
    /// q8 charge on `Loaded::dequant_secs`, the q4 charge on the
    /// separate [`Loaded::q4_dequant_secs`] clock so fig JSONs can
    /// attribute the deeper-compression trade — and, when a hot tier
    /// exists, promote the f32 chunk back into it (the quantized copy
    /// was already taken out of the warm tier, so placement stays
    /// exclusive). `hot_gen` is the generation the hot probe reported; a
    /// write/delete that raced the promote bounces off the hot tier's
    /// guard exactly like a raced device read would.
    fn serve_warm_hit(
        &self,
        id: ChunkId,
        payload: &super::warm::WarmPayload,
        file_bytes: usize,
        hot_gen: u64,
        shard: usize,
    ) -> Loaded {
        let chunk = Arc::new(payload.dequantize());
        let dequant_secs = payload.dequant_secs();
        let is_q4 = payload.mode() == WarmMode::Q4;
        // The dequant pass crosses the shared host bus: same charge
        // magnitude, but concurrent promotions/demotions queue behind
        // each other and the wait lands in the tier's link telemetry.
        let slot =
            self.bus.reserve_secs(dequant_secs, payload.quantized_bytes(), TrafficClass::Promotion);
        if let Some(warm) = &self.warm {
            if is_q4 {
                warm.stats.add_q4_dequant_secs(dequant_secs);
            } else {
                warm.stats.add_dequant_secs(dequant_secs);
            }
            warm.stats.add_link_queued_secs(slot.queued_secs);
        }
        if let Some(hot) = &self.hot {
            hot.insert_at(id, chunk.clone(), file_bytes, hot_gen);
        }
        let mut l = Loaded::clean(
            chunk,
            0.0,
            file_bytes,
            true,
            true,
            if is_q4 { 0.0 } else { dequant_secs },
            0.0,
            shard,
        );
        if is_q4 {
            l.q4_dequant_secs = dequant_secs;
        }
        l
    }

    /// Load many chunks concurrently. The lookup ladder per id is
    /// **hot → warm → flash**: hot-tier hits are answered inline for
    /// free; warm-tier hits dequantize (modeled cost, no device read)
    /// and promote back to hot; remaining misses fan out across the
    /// shard set through the I/O pool — reads against the *same* shard
    /// still serialize on that device's throttle (like real parallel
    /// reads of one SSD), but misses routed to different shards overlap
    /// in simulated device time, which is where the JBOD's aggregate
    /// bandwidth comes from. Output order matches `ids`.
    ///
    /// Repeated ids within one call collapse to a single device read:
    /// two batch elements splicing the same chunk share one file, so the
    /// duplicates are answered from the first occurrence (`from_cache`,
    /// zero device seconds) — the splice-reuse half of batcher/tier
    /// co-design, which is what makes grouping chunk-sharing requests
    /// into one batch pay off.
    pub fn load_many(&self, ids: &[ChunkId]) -> Result<Vec<Loaded>> {
        enum Slot {
            Hit(Loaded),
            /// A device read plus the id's invalidation generations in
            /// both DRAM tiers, captured before the read could start: if
            /// a write/delete races this load, the stale bytes are not
            /// cached in either tier.
            Miss { hot_gen: u64, warm_gen: u64, shard: usize, read: Pending<Result<(Vec<u8>, f64)>> },
            /// Same id appeared earlier in this call (at the given output
            /// index): reuse that slot's outcome instead of re-reading.
            Dup(usize),
        }
        let mut first_at: std::collections::HashMap<ChunkId, usize> = std::collections::HashMap::new();
        let slots: Vec<Slot> = ids
            .iter()
            .enumerate()
            .map(|(i, &id)| {
                if let Some(&j) = first_at.get(&id) {
                    return Slot::Dup(j);
                }
                first_at.insert(id, i);
                let shard_idx = self.shard_index_of(id);
                let mut hot_gen = 0;
                if let Some(hot) = &self.hot {
                    match hot.probe(id) {
                        Probe::Hit(chunk, file_bytes) => {
                            return Slot::Hit(Loaded::clean(
                                chunk, 0.0, file_bytes, true, false, 0.0, 0.0, shard_idx,
                            ));
                        }
                        Probe::Miss(g) => hot_gen = g,
                    }
                }
                let mut warm_gen = 0;
                if let Some(warm) = &self.warm {
                    // With a hot tier that can admit the chunk, a warm
                    // hit promotes (take); otherwise — warm-only store,
                    // or a chunk oversize for the hot tier — it stays
                    // put and is touched MRU.
                    match warm.probe(id, self.hot.as_ref().map(|h| h.budget())) {
                        WarmProbe::Hit { payload, file_bytes, .. } => {
                            return Slot::Hit(self.serve_warm_hit(
                                id, &payload, file_bytes, hot_gen, shard_idx,
                            ));
                        }
                        WarmProbe::Miss(g) => warm_gen = g,
                    }
                }
                let shard = self.shards[shard_idx].clone();
                Slot::Miss {
                    hot_gen,
                    warm_gen,
                    shard: shard_idx,
                    read: self.pool.submit(move || shard.read(id, TrafficClass::Demand)),
                }
            })
            .collect();
        let mut out: Vec<Loaded> = Vec::with_capacity(ids.len());
        for (slot, &id) in slots.into_iter().zip(ids) {
            match slot {
                Slot::Hit(l) => out.push(l),
                Slot::Miss { hot_gen, warm_gen, shard: shard_idx, read } => {
                    if self.faults.is_none() {
                        // No fault plan: the exact pre-fault path — any
                        // read or decode error propagates immediately,
                        // with no extra probes or stat bumps.
                        let (data, device_secs) = read.wait()?;
                        self.stats.reads.fetch_add(1, Ordering::Relaxed);
                        self.stats.bytes_read.fetch_add(data.len() as u64, Ordering::Relaxed);
                        let (chunk, fmt) = Self::decode_versioned(&data)?;
                        let chunk = Arc::new(chunk);
                        let quant_secs =
                            self.admit_miss(id, &chunk, data.len(), hot_gen, warm_gen);
                        let mut l = Loaded::clean(
                            chunk, device_secs, data.len(), false, false, 0.0, quant_secs,
                            shard_idx,
                        );
                        l.q4_dequant_secs = Self::q4_decode_price(fmt, data.len());
                        out.push(l);
                    } else {
                        out.push(self.recover_miss(id, hot_gen, warm_gen, shard_idx, read)?);
                    }
                }
                Slot::Dup(j) => {
                    // `j` indexes a strictly earlier slot, so `out[j]` is
                    // already resolved; no device charge for the reuse —
                    // and no second dequant either, the Arc is shared.
                    let (chunk, file_bytes, shard) = {
                        let first = &out[j];
                        (first.chunk.clone(), first.file_bytes, first.shard)
                    };
                    out.push(Loaded::clean(chunk, 0.0, file_bytes, true, false, 0.0, 0.0, shard));
                }
            }
        }
        if self.trace.enabled() {
            // One unclocked event per chunk outcome, named by ladder
            // rung (precedence mirrors the degradation order). Modeled
            // durations only — the store runs on wall clocks, so a real
            // timestamp here would break trace byte-identity.
            for (l, &id) in out.iter().zip(ids) {
                let name = if l.recomputed {
                    "recompute"
                } else if l.retries > 0 {
                    "flash_retry"
                } else if l.from_warm {
                    "warm_hit"
                } else if l.from_cache {
                    "hot_hit"
                } else {
                    "flash_read"
                };
                let dur = l.device_secs
                    + l.dequant_secs
                    + l.q4_dequant_secs
                    + l.recompute_secs
                    + l.retry_backoff_secs;
                self.trace.event(
                    "store",
                    name,
                    dur,
                    &[
                        ("id", Arg::U(id)),
                        ("shard", Arg::U(l.shard as u64)),
                        ("bytes", Arg::U(l.file_bytes as u64)),
                    ],
                );
            }
        }
        Ok(out)
    }

    /// Admit a freshly materialized chunk into the DRAM hierarchy,
    /// generation-guarded against writes/deletes that raced the load:
    /// the hot tier when it fits (overflow demotes through the eviction
    /// sink), else the warm tier quantized — no hot tier, a chunk the
    /// hot tier could never admit, or a recompute-fallback result all
    /// take that arm. Returns the modeled quantize seconds this load
    /// was charged (0 when the hot tier took it or no tier exists).
    fn admit_miss(
        &self,
        id: ChunkId,
        chunk: &Arc<KvChunk>,
        file_bytes: usize,
        hot_gen: u64,
        warm_gen: u64,
    ) -> f64 {
        match &self.hot {
            Some(hot) if chunk.dram_bytes() <= hot.budget() => {
                hot.insert_at(id, chunk.clone(), file_bytes, hot_gen);
                0.0
            }
            _ => match &self.warm {
                Some(warm) => warm.quantize_admit(id, chunk, file_bytes, false, warm_gen).1,
                None => 0.0,
            },
        }
    }

    /// Resolve a `load_many` miss under an active fault plan: the
    /// degradation ladder.
    ///
    /// 1. **Flash, retried** — up to `max_retries` re-reads of the
    ///    shard, the n-th after an exponential backoff of
    ///    `retry_backoff_secs * 2^n` charged on the shard's link clock
    ///    (waiting out a stall costs simulated time and delays queued
    ///    traffic). Corrupted payloads are caught by the v3 checksum
    ///    and count as failures, never served.
    /// 2. **Hot / warm re-probe** — a concurrent load or prefetch may
    ///    have made the chunk DRAM-resident while we were retrying.
    /// 3. **Vanilla recompute** — the safety net: the chunk's tokens
    ///    are re-prefilled instead of loaded, at
    ///    `seq_len * recompute_secs_per_token` modeled seconds and zero
    ///    device time. The store models the recompute result by
    ///    decoding the intact on-disk bytes directly (fault injection
    ///    corrupts the read path, never the file), which also means a
    ///    chunk that was genuinely deleted still errors — recompute
    ///    recovers *lost reads*, not lost data sources.
    fn recover_miss(
        &self,
        id: ChunkId,
        hot_gen: u64,
        warm_gen: u64,
        shard_idx: usize,
        read: Pending<Result<(Vec<u8>, f64)>>,
    ) -> Result<Loaded> {
        let shard = &self.shards[shard_idx];
        let mut retries = 0usize;
        let mut backoff_spent = 0.0f64;
        let mut checksum_failures = 0usize;
        let mut result = read.wait();
        let last_err = loop {
            let err = match result {
                Ok((data, device_secs)) => {
                    self.stats.reads.fetch_add(1, Ordering::Relaxed);
                    self.stats.bytes_read.fetch_add(data.len() as u64, Ordering::Relaxed);
                    match Self::decode_versioned(&data) {
                        Ok((chunk, fmt)) => {
                            let chunk = Arc::new(chunk);
                            let quant_secs =
                                self.admit_miss(id, &chunk, data.len(), hot_gen, warm_gen);
                            let mut l = Loaded::clean(
                                chunk, device_secs, data.len(), false, false, 0.0, quant_secs,
                                shard_idx,
                            );
                            l.q4_dequant_secs = Self::q4_decode_price(fmt, data.len());
                            l.retries = retries;
                            l.retry_backoff_secs = backoff_spent;
                            l.checksum_failures = checksum_failures;
                            return Ok(l);
                        }
                        Err(e) => {
                            if e.to_string().contains("checksum mismatch") {
                                checksum_failures += 1;
                            }
                            e
                        }
                    }
                }
                Err(e) => e,
            };
            if retries >= self.max_retries {
                break err;
            }
            // Exponential backoff before the next attempt, charged as
            // pure occupancy on this shard's link.
            let backoff = self.retry_backoff_secs * (1u64 << retries.min(32)) as f64;
            backoff_spent += shard.charge_backoff(backoff);
            retries += 1;
            result = shard.read(id, TrafficClass::Demand);
        };
        // Rung 2: the chunk may have gone DRAM-resident while we
        // retried (a concurrent load, prefetch, or re-materialization).
        if let Some(hot) = &self.hot {
            if let Probe::Hit(chunk, file_bytes) = hot.probe(id) {
                let mut l = Loaded::clean(chunk, 0.0, file_bytes, true, false, 0.0, 0.0, shard_idx);
                l.retries = retries;
                l.retry_backoff_secs = backoff_spent;
                l.checksum_failures = checksum_failures;
                return Ok(l);
            }
        }
        if let Some(warm) = &self.warm {
            let hot_gen = self.hot.as_ref().map(|h| h.generation(id)).unwrap_or(0);
            if let WarmProbe::Hit { payload, file_bytes, .. } =
                warm.probe(id, self.hot.as_ref().map(|h| h.budget()))
            {
                let mut l = self.serve_warm_hit(id, &payload, file_bytes, hot_gen, shard_idx);
                l.retries = retries;
                l.retry_backoff_secs = backoff_spent;
                l.checksum_failures = checksum_failures;
                return Ok(l);
            }
        }
        // Rung 3: Vanilla recompute for just this chunk.
        if let Ok(data) = std::fs::read(shard.path_of(id)) {
            if let Ok(chunk) = Self::decode(&data) {
                let chunk = Arc::new(chunk);
                let recompute_secs = chunk.seq_len as f64 * self.recompute_secs_per_token;
                let quant_secs = self.admit_miss(id, &chunk, data.len(), hot_gen, warm_gen);
                let mut l = Loaded::clean(
                    chunk, 0.0, data.len(), false, false, 0.0, quant_secs, shard_idx,
                );
                // No q4 price on the recompute rung: the chunk is modeled
                // as re-prefilled on device, not unpacked from flash.
                l.retries = retries;
                l.retry_backoff_secs = backoff_spent;
                l.checksum_failures = checksum_failures;
                l.recomputed = true;
                l.recompute_secs = recompute_secs;
                return Ok(l);
            }
        }
        Err(last_err.context(format!(
            "chunk {id:016x} unrecoverable: {retries} retries and the recompute \
             fallback all failed"
        )))
    }

    /// Warm the DRAM hierarchy for `ids` ahead of demand time (the
    /// overlap pipeline calls this with batch *n+1*'s retrieval top-K
    /// while batch *n* decodes). Reads fan out across shards like
    /// `load_many` misses; a chunk already resident in *either* DRAM
    /// tier is left where it is. With a hot tier, admission goes through
    /// the *protected* prefetch path ([`HotTier::insert_prefetch`]): a
    /// prefetch can never evict a chunk a demand load admitted, and a
    /// chunk that is missing or superseded mid-flight degrades to a
    /// later demand miss instead of an error. In a warm-only store — or
    /// for a chunk too large for the hot tier to ever admit — the read
    /// is admitted quantized (gen-guarded; plain LRU — the warm tier
    /// has no protection classes to defend). No DRAM tier → no-op.
    pub fn prefetch_many(&self, ids: &[ChunkId]) -> PrefetchReport {
        let hot = self.hot.clone();
        let warm = self.warm.clone();
        if hot.is_none() && warm.is_none() {
            return PrefetchReport::default();
        }
        let mut report = PrefetchReport::default();
        let mut seen = std::collections::HashSet::new();
        let mut pending: Vec<(ChunkId, u64, u64, Pending<Result<(Vec<u8>, f64)>>)> = Vec::new();
        for &id in ids {
            if !seen.insert(id) {
                continue;
            }
            report.requested += 1;
            if hot.as_ref().is_some_and(|h| h.contains(id))
                || warm.as_ref().is_some_and(|w| w.contains(id))
            {
                report.already_resident += 1;
                continue;
            }
            // Capture both tiers' generations before the read: which
            // tier admits is only known once the chunk's size is.
            let hot_gen = hot.as_ref().map(|h| h.generation(id)).unwrap_or(0);
            let warm_gen = warm.as_ref().map(|w| w.generation(id)).unwrap_or(0);
            let shard = self.shard_of(id).clone();
            pending.push((
                id,
                hot_gen,
                warm_gen,
                self.pool.submit(move || shard.read(id, TrafficClass::Prefetch)),
            ));
        }
        for (id, hot_gen, warm_gen, h) in pending {
            let (data, device_secs) = match h.wait() {
                Ok(r) => r,
                Err(_) => {
                    // Missing (or unreadable) on flash: the demand path
                    // owns surfacing that, a prefetch just skips it.
                    report.absent += 1;
                    continue;
                }
            };
            report.device_secs += device_secs;
            self.stats.reads.fetch_add(1, Ordering::Relaxed);
            self.stats.bytes_read.fetch_add(data.len() as u64, Ordering::Relaxed);
            let chunk = match Self::decode(&data) {
                Ok(c) => Arc::new(c),
                Err(_) => {
                    report.absent += 1;
                    continue;
                }
            };
            // Park `chunk` in `w` via the tier's one quantize+charge+
            // admit entry point — the warm-side admission every non-hot
            // prefetch outcome funnels through.
            let admit_warm = |w: &Arc<WarmTier>, chunk: &Arc<KvChunk>| {
                w.quantize_admit(id, chunk, data.len(), true, warm_gen).0
            };
            let admitted = match (&hot, &warm) {
                // A chunk the hot tier could never admit goes straight
                // to the warm tier (quantized) instead of being dropped.
                (Some(h), Some(w)) if chunk.dram_bytes() > h.budget() => admit_warm(w, &chunk),
                (Some(hot), w) => {
                    // Demote-on-prefetch-reject: when the protected
                    // admission path refuses the chunk (the hot tier is
                    // full of demand residents a prefetch must not
                    // displace), park the q8 copy in the warm tier —
                    // generation-guarded via the warm generation
                    // captured before the read — instead of discarding
                    // a device read the demand path will just repeat.
                    hot.insert_prefetch(id, chunk.clone(), data.len(), hot_gen)
                        || w.as_ref().is_some_and(|w| admit_warm(w, &chunk))
                }
                (None, Some(w)) => admit_warm(w, &chunk),
                (None, None) => unreachable!("early return above"),
            };
            if admitted {
                report.warmed += 1;
            } else {
                report.rejected += 1;
            }
        }
        report
    }

    /// Delete a chunk's materialized KV (vector-DB delete path). Like
    /// the write paths, the DRAM tiers are invalidated around the unlink
    /// so a racing load can't resurrect the deleted chunk in DRAM.
    pub fn delete(&self, id: ChunkId) -> Result<bool> {
        self.invalidate_tiers(id);
        let deleted = self.shard_of(id).delete(id)?;
        if deleted {
            self.invalidate_tiers(id);
            self.stats.deletes.fetch_add(1, Ordering::Relaxed);
        }
        Ok(deleted)
    }

    /// Number of materialized chunks on disk (all shards).
    pub fn len(&self) -> Result<usize> {
        let mut total = 0;
        for shard in &self.shards {
            total += shard.len()?;
        }
        Ok(total)
    }

    pub fn is_empty(&self) -> Result<bool> {
        Ok(self.len()? == 0)
    }

    /// Total bytes of materialized KV on disk, all shards (TCO
    /// accounting).
    pub fn bytes_on_disk(&self) -> Result<u64> {
        let mut total = 0;
        for shard in &self.shards {
            total += shard.bytes_on_disk()?;
        }
        Ok(total)
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::workload::{Rng, Zipf};

    fn chunk(seed: u32, seq: u32) -> KvChunk {
        let plane = (2 * 2 * seq * 4) as usize;
        KvChunk {
            config_id: 0xabcd,
            n_layers: 2,
            n_kv_heads: 2,
            seq_len: seq,
            head_dim: 4,
            // Integer payloads (<= 2048) survive the f16 format exactly.
            k: (0..plane).map(|i| (i as f32) + seed as f32).collect(),
            v: (0..plane).map(|i| -(i as f32) - seed as f32).collect(),
        }
    }

    fn store() -> (crate::util::tempdir::TempDir, KvStore) {
        let dir = crate::util::tempdir::TempDir::new("matkv-kvstore-test").unwrap();
        let mut s = KvStore::open(dir.path(), StorageProfile::dram()).unwrap();
        s.disable_throttle();
        (dir, s)
    }

    #[test]
    fn roundtrip() {
        let (_d, s) = store();
        let c = chunk(7, 16);
        s.store_sync(42, &c).unwrap();
        let loaded = s.load(42).unwrap();
        assert_eq!(*loaded.chunk, c);
        assert!(!loaded.from_cache);
        assert_eq!(loaded.file_bytes, s.encoded_bytes(&c));
    }

    #[test]
    fn async_write_behind_roundtrip() {
        let (_d, s) = store();
        let c = chunk(9, 8);
        let h = s.store_async(7, c.clone());
        s.drain(vec![h]).unwrap();
        assert_eq!(*s.load(7).unwrap().chunk, c);
    }

    #[test]
    fn load_many_preserves_order() {
        let (_d, s) = store();
        for i in 0..5u64 {
            s.store_sync(i, &chunk(i as u32, 8)).unwrap();
        }
        let loaded = s.load_many(&[3, 1, 4]).unwrap();
        assert_eq!(loaded[0].chunk.k[0], chunk(3, 8).k[0]);
        assert_eq!(loaded[1].chunk.k[0], chunk(1, 8).k[0]);
        assert_eq!(loaded[2].chunk.k[0], chunk(4, 8).k[0]);
    }

    #[test]
    fn load_many_dedups_repeated_ids_in_one_call() {
        // No hot tier: the dedup is batch-local, so repeated ids still
        // cost exactly one device read and the duplicates report
        // `from_cache` with zero device seconds.
        let (_d, s) = store();
        s.store_sync(1, &chunk(1, 8)).unwrap();
        s.store_sync(2, &chunk(2, 8)).unwrap();
        let loaded = s.load_many(&[1, 2, 1, 1]).unwrap();
        assert!(!loaded[0].from_cache && !loaded[1].from_cache);
        assert!(loaded[2].from_cache && loaded[3].from_cache);
        assert_eq!(loaded[2].device_secs, 0.0);
        assert_eq!(loaded[2].file_bytes, loaded[0].file_bytes);
        assert_eq!(loaded[2].shard, loaded[0].shard);
        assert_eq!(*loaded[2].chunk, *loaded[0].chunk);
        assert_eq!(s.stats.reads.load(Ordering::Relaxed), 2, "one read per unique id");
        // a later, separate call still misses (nothing was cached)
        assert!(!s.load(1).unwrap().from_cache);
    }

    #[test]
    fn load_many_dedup_of_a_tier_hit_stays_a_hit() {
        let (_d, s) = tiered_store(64 << 20);
        s.store_sync(3, &chunk(3, 8)).unwrap();
        s.load(3).unwrap(); // warm it
        let loaded = s.load_many(&[3, 3]).unwrap();
        assert!(loaded.iter().all(|l| l.from_cache));
        assert_eq!(s.stats.reads.load(Ordering::Relaxed), 1, "only the warming read");
        // the duplicate must not double-bump the tier's hit counter
        let tier = s.hot_tier().unwrap();
        assert_eq!(tier.stats.hits.load(Ordering::Relaxed), 1);
    }

    #[test]
    fn store_resident_ids_tracks_tier() {
        let (_d, s) = tiered_store(64 << 20);
        assert!(s.resident_ids().is_empty());
        s.store_sync(1, &chunk(1, 8)).unwrap();
        s.store_sync(2, &chunk(2, 8)).unwrap();
        s.load_many(&[1, 2]).unwrap();
        let mut ids = s.resident_ids();
        ids.sort_unstable();
        assert_eq!(ids, vec![1, 2]);
        // without a tier the snapshot is empty, never an error
        let (_d2, plain) = store();
        plain.store_sync(1, &chunk(1, 8)).unwrap();
        plain.load(1).unwrap();
        assert!(plain.resident_ids().is_empty());
    }

    #[test]
    fn delete_and_contains() {
        let (_d, s) = store();
        s.store_sync(1, &chunk(1, 8)).unwrap();
        assert!(s.contains(1));
        assert!(s.delete(1).unwrap());
        assert!(!s.contains(1));
        assert!(!s.delete(1).unwrap());
        assert!(s.load(1).is_err());
    }

    #[test]
    fn corrupt_file_rejected() {
        let (_d, s) = store();
        s.store_sync(5, &chunk(5, 8)).unwrap();
        // truncate
        let path = s.path_of(5);
        let data = std::fs::read(&path).unwrap();
        std::fs::write(&path, &data[..data.len() / 2]).unwrap();
        assert!(s.load(5).is_err());
        // bad magic
        let mut bad = data.clone();
        bad[0] ^= 0xff;
        std::fs::write(&path, &bad).unwrap();
        assert!(s.load(5).is_err());
        // unknown version
        let mut bad = data.clone();
        bad[4] = 9;
        std::fs::write(&path, &bad).unwrap();
        assert!(s.load(5).is_err());
    }

    #[test]
    fn corrupt_header_rejected_without_overflow() {
        // Adversarial dims whose u32 product wraps to 0: a 32-byte file
        // would pass an unchecked size check while claiming 2^16 layers.
        let (_d, s) = store();
        let mut buf = Vec::new();
        for word in [MAGIC, 1u32, 0xabcd, 0x1_0000, 0x1_0000, 1, 1, 0] {
            buf.extend_from_slice(&word.to_le_bytes());
        }
        std::fs::write(s.path_of(66), &buf).unwrap();
        let err = s.load(66).unwrap_err();
        let msg = err.to_string();
        assert!(msg.contains("mismatch") || msg.contains("overflow"), "{msg}");

        // Dims that overflow even u64 must hit the checked-math bail.
        let mut buf = Vec::new();
        for word in [MAGIC, 2u32, 0xabcd, u32::MAX, u32::MAX, u32::MAX, u32::MAX, 0] {
            buf.extend_from_slice(&word.to_le_bytes());
        }
        std::fs::write(s.path_of(67), &buf).unwrap();
        let err = s.load(67).unwrap_err();
        assert!(err.to_string().contains("overflow"), "{err}");
    }

    #[test]
    fn store_async_invalid_chunk_errors_not_panics() {
        let (_d, s) = store();
        let mut c = chunk(1, 8);
        c.k.pop(); // plane mismatch
        let h = s.store_async(3, c);
        assert!(h.wait().is_err());
        assert!(!s.contains(3));
        assert_eq!(s.stats.writes.load(Ordering::Relaxed), 0);
    }

    #[test]
    fn failed_async_write_not_counted() {
        let dir = crate::util::tempdir::TempDir::new("matkv-kvstore-fail").unwrap();
        let sub = dir.path().join("kv");
        let mut s = KvStore::open(&sub, StorageProfile::dram()).unwrap();
        s.disable_throttle();
        std::fs::remove_dir_all(&sub).unwrap(); // make every write fail
        let h = s.store_async(1, chunk(1, 8));
        assert!(h.wait().is_err());
        assert_eq!(s.stats.writes.load(Ordering::Relaxed), 0);
        assert_eq!(s.stats.bytes_written.load(Ordering::Relaxed), 0);
    }

    #[test]
    fn v1_files_still_load() {
        let dir = crate::util::tempdir::TempDir::new("matkv-kvstore-v1").unwrap();
        let mut writer = KvStore::open(dir.path(), StorageProfile::dram()).unwrap();
        writer.disable_throttle();
        writer.set_format(KvFormat::V1);
        // fractional payload: would NOT survive f16, so exact equality
        // proves the v1 decode path ran losslessly.
        let mut c = chunk(3, 8);
        for x in c.k.iter_mut().chain(c.v.iter_mut()) {
            *x += 0.123_456_7;
        }
        writer.store_sync(11, &c).unwrap();
        assert_eq!(writer.encoded_bytes(&c), c.total_bytes());

        let mut reader = KvStore::open(dir.path(), StorageProfile::dram()).unwrap();
        reader.disable_throttle();
        assert_eq!(reader.format(), KvFormat::V3); // default is v3...
        assert_eq!(*reader.load(11).unwrap().chunk, c); // ...yet v1 loads
    }

    #[test]
    fn v2_files_still_load_under_v3_default() {
        let dir = crate::util::tempdir::TempDir::new("matkv-kvstore-v2").unwrap();
        let mut writer = KvStore::open(dir.path(), StorageProfile::dram()).unwrap();
        writer.disable_throttle();
        writer.set_format(KvFormat::V2);
        let c = chunk(6, 8);
        writer.store_sync(12, &c).unwrap();

        let mut reader = KvStore::open(dir.path(), StorageProfile::dram()).unwrap();
        reader.disable_throttle();
        assert_eq!(reader.format(), KvFormat::V3);
        // the v2 record has no checksum (reserved word is 0) and must
        // load without one being demanded
        assert_eq!(*reader.load(12).unwrap().chunk, c);
    }

    #[test]
    fn v3_checksum_same_bytes_as_v2_and_detects_corruption() {
        let (_d, s) = store();
        let c = chunk(4, 16);
        assert_eq!(s.format(), KvFormat::V3);
        // the checksum lives in the reserved header word: file size
        // (and so device timing) is identical to v2
        assert_eq!(s.encoded_bytes(&c), c.file_bytes(KvFormat::V2));
        s.store_sync(9, &c).unwrap();
        assert_eq!(*s.load(9).unwrap().chunk, c);
        // flip one payload bit on disk: the size check can't see it,
        // the checksum must
        let path = s.path_of(9);
        let mut data = std::fs::read(&path).unwrap();
        let mid = HEADER_BYTES + (data.len() - HEADER_BYTES) / 2;
        data[mid] ^= 1;
        std::fs::write(&path, &data).unwrap();
        let err = s.load(9).unwrap_err();
        assert!(err.to_string().contains("checksum"), "{err}");
        // a flipped checksum word itself is caught too
        let mut data = std::fs::read(&path).unwrap();
        data[mid] ^= 1; // restore the payload
        data[28] ^= 0x40; // corrupt the stored checksum
        std::fs::write(&path, &data).unwrap();
        assert!(s.load(9).is_err());
    }

    // --- v4 / q4 cool path ----------------------------------------------

    #[test]
    fn v4_files_quarter_of_v1_and_half_of_v3() {
        let c = chunk(1, 32);
        let v1 = KvStore::encode(&c, KvFormat::V1).len();
        let v3 = KvStore::encode(&c, KvFormat::V3).len();
        let v4 = KvStore::encode(&c, KvFormat::V4).len();
        assert_eq!(v4, c.file_bytes(KvFormat::V4));
        assert!((v4 as f64) < 0.3 * v1 as f64, "v4/v1 = {}", v4 as f64 / v1 as f64);
        assert!((v4 as f64) < 0.6 * v3 as f64, "v4/v3 = {}", v4 as f64 / v3 as f64);
    }

    #[test]
    fn v4_roundtrip_and_checksum_detects_corruption() {
        let (_d, mut s) = store();
        s.set_format(KvFormat::V4);
        // constant planes at multiples of 127 are on the q4 grid
        // (q = ±7), so the round trip is exact
        let c = flat_chunk(254.0, 16);
        s.store_sync(9, &c).unwrap();
        assert_eq!(*s.load(9).unwrap().chunk, c);
        // v4 carries the v3 FNV-1a checksum: a payload bit flip that
        // the size check can't see must still be rejected
        let path = s.path_of(9);
        let mut data = std::fs::read(&path).unwrap();
        let mid = HEADER_BYTES + (data.len() - HEADER_BYTES) / 2;
        data[mid] ^= 1;
        std::fs::write(&path, &data).unwrap();
        let err = s.load(9).unwrap_err();
        assert!(err.to_string().contains("checksum"), "{err}");
    }

    #[test]
    fn v1_v2_v3_files_still_load_under_v4_writer() {
        // One directory, four formats: a store switched to v4 writes
        // must keep decoding every older record transparently.
        let (_d, mut s) = store();
        s.set_format(KvFormat::V1);
        s.store_sync(1, &chunk(1, 8)).unwrap();
        s.set_format(KvFormat::V2);
        s.store_sync(2, &chunk(2, 8)).unwrap();
        s.set_format(KvFormat::V3);
        s.store_sync(3, &chunk(3, 8)).unwrap();
        s.set_format(KvFormat::V4);
        s.store_sync(4, &flat_chunk(127.0, 8)).unwrap();
        assert_eq!(*s.load(1).unwrap().chunk, chunk(1, 8));
        assert_eq!(*s.load(2).unwrap().chunk, chunk(2, 8));
        assert_eq!(*s.load(3).unwrap().chunk, chunk(3, 8));
        assert_eq!(*s.load(4).unwrap().chunk, flat_chunk(127.0, 8));
        // only the v4 record pays the modeled q4 unpack
        assert_eq!(s.load(3).unwrap().q4_dequant_secs, 0.0);
        assert!(s.load(4).unwrap().q4_dequant_secs > 0.0);
    }

    #[test]
    fn future_format_version_names_the_newer_writer() {
        // A hand-built v9 header must produce the "newer writer"
        // diagnosis, not a generic decode bail: the operator's fix
        // (upgrade, or re-materialize) is different from corruption's.
        let (_d, s) = store();
        let mut buf = Vec::new();
        for word in [MAGIC, 9u32, 0xabcd, 2, 2, 8, 4, 0] {
            buf.extend_from_slice(&word.to_le_bytes());
        }
        std::fs::write(s.path_of(77), &buf).unwrap();
        let err = s.load(77).unwrap_err();
        let msg = format!("{err:#}");
        assert!(msg.contains("format 9 from a newer writer"), "{msg}");
        assert!(msg.contains("up to v4"), "{msg}");
    }

    #[test]
    fn v4_load_prices_smaller_read_and_charges_dequant() {
        // The tentpole's trade, end to end at the store: the same chunk
        // served from a v4 file moves strictly fewer device bytes (and
        // seconds) than from v3, and pays a nonzero modeled q4 dequant
        // on every flash load — priced, not free. A hot-tier hit
        // afterwards pays neither.
        let c = flat_chunk(127.0, 64);
        let dir3 = crate::util::tempdir::TempDir::new("matkv-cool-v3").unwrap();
        let mut s3 = KvStore::open(dir3.path(), StorageProfile::ssd_9100pro()).unwrap();
        s3.disable_throttle();
        s3.store_sync(1, &c).unwrap();
        let l3 = s3.load(1).unwrap();

        let dir4 = crate::util::tempdir::TempDir::new("matkv-cool-v4").unwrap();
        let mut s4 = KvStore::open(dir4.path(), StorageProfile::ssd_9100pro()).unwrap();
        s4.disable_throttle();
        s4.set_format(KvFormat::V4);
        s4.set_hot_tier(64 << 20);
        s4.store_sync(1, &c).unwrap();
        let l4 = s4.load(1).unwrap();

        assert!(l4.file_bytes < l3.file_bytes, "{} !< {}", l4.file_bytes, l3.file_bytes);
        assert!(l4.device_secs < l3.device_secs, "{} !< {}", l4.device_secs, l3.device_secs);
        assert!(l4.q4_dequant_secs > 0.0, "v4 flash load must charge the unpack");
        assert_eq!(l3.q4_dequant_secs, 0.0, "v3 loads must not");
        assert_eq!(*l4.chunk, c);
        let hit = s4.load(1).unwrap();
        assert!(hit.from_cache);
        assert_eq!(hit.q4_dequant_secs, 0.0, "hot hits are unpacked already");
    }

    #[test]
    fn q4_warm_demote_promote_preserves_prefetch_semantics() {
        // Satellite: the demote→promote cycle of the q8 suite, run
        // through a q4-mode warm tier — protection semantics identical,
        // costs on the q4 clock.
        let (_d, s) = warm_store(f32_cost(), 64 << 20);
        s.set_warm_mode(WarmMode::Q4);
        for i in 1..=3u64 {
            s.store_sync(i, &flat_chunk(127.0 * i as f32, 8)).unwrap();
        }
        assert_eq!(s.prefetch_many(&[1]).warmed, 1);
        assert_eq!(s.prefetch_many(&[2]).warmed, 1); // evicts prefetched 1 → warm (q4)
        let warm = s.warm_tier().unwrap();
        assert!(warm.contains(1), "prefetched eviction demotes like any other");
        assert!(warm.stats.q4_quant_secs() > 0.0, "q4 demotion must charge the q4 clock");
        assert_eq!(warm.stats.quant_secs(), 0.0);

        // demand load of 1: a q4 warm hit that still counts as a
        // prefetch conversion, promotes as a demand entry, and carries
        // its dequant charge on Loaded.q4_dequant_secs
        let l = s.load(1).unwrap();
        assert!(l.from_warm);
        assert_eq!(*l.chunk, flat_chunk(127.0, 8), "on-grid planes survive q4 exactly");
        assert!(l.q4_dequant_secs > 0.0);
        assert_eq!(l.dequant_secs, 0.0, "q4 hits must not bill the q8 clock");
        assert_eq!(warm.stats.prefetch_hits.load(Ordering::Relaxed), 1);
        assert!(s.hot_tier().unwrap().contains(1));

        // as a demand resident, 1 is protected from prefetch eviction —
        // the refused prefetch parks in the (q4) warm tier instead
        let rep = s.prefetch_many(&[3]);
        assert_eq!(rep.warmed, 1, "refused hot admission must park in warm: {rep:?}");
        assert_eq!(rep.rejected, 0);
        assert!(s.hot_tier().unwrap().contains(1));
        assert!(warm.contains(3));
    }

    #[test]
    fn store_knobs_reach_the_tiers() {
        let (_d, s) = warm_store(f32_cost(), 64 << 20);
        assert_eq!(s.warm_tier().unwrap().mode(), WarmMode::Q8);
        s.set_warm_mode(WarmMode::Q4);
        assert_eq!(s.warm_tier().unwrap().mode(), WarmMode::Q4);
        assert_eq!(s.hot_tier().unwrap().admission(), super::super::cache::AdmissionPolicy::Lru);
        s.set_admission(super::super::cache::AdmissionPolicy::TinyLfu);
        assert_eq!(
            s.hot_tier().unwrap().admission(),
            super::super::cache::AdmissionPolicy::TinyLfu
        );
        // both knobs are no-ops on stores without the tier
        let (_d2, plain) = store();
        plain.set_warm_mode(WarmMode::Q4);
        plain.set_admission(super::super::cache::AdmissionPolicy::TinyLfu);
    }

    #[test]
    fn v2_files_half_the_bytes() {
        let c = chunk(1, 32);
        let v1 = KvStore::encode(&c, KvFormat::V1).len();
        let v2 = KvStore::encode(&c, KvFormat::V2).len();
        assert_eq!(v1, c.total_bytes());
        assert_eq!(v2, c.file_bytes(KvFormat::V2));
        let ratio = v2 as f64 / v1 as f64;
        assert!(ratio < 0.55, "v2/v1 = {ratio}");

        let (_d, s) = store();
        s.store_sync(1, &c).unwrap();
        assert_eq!(s.bytes_on_disk().unwrap(), v2 as u64);
    }

    #[test]
    fn v2_quantization_error_bounded() {
        let (_d, s) = store();
        let mut c = chunk(0, 8);
        for (i, x) in c.k.iter_mut().enumerate() {
            *x = (i as f32 + 0.321).sin() * 3.7;
        }
        s.store_sync(8, &c).unwrap();
        let loaded = s.load(8).unwrap();
        for (a, b) in c.k.iter().zip(&loaded.chunk.k) {
            assert!((a - b).abs() <= a.abs() / 1024.0 + 1e-6, "{a} vs {b}");
        }
    }

    #[test]
    fn stats_accumulate() {
        let (_d, s) = store();
        let c = chunk(1, 8);
        let file = s.encoded_bytes(&c) as u64;
        s.store_sync(1, &c).unwrap();
        s.load(1).unwrap();
        s.load(1).unwrap();
        assert_eq!(s.stats.reads.load(Ordering::Relaxed), 2);
        assert_eq!(s.stats.writes.load(Ordering::Relaxed), 1);
        assert_eq!(s.stats.bytes_read.load(Ordering::Relaxed), 2 * file);
        assert_eq!(s.len().unwrap(), 1);
        assert_eq!(s.bytes_on_disk().unwrap(), file);
    }

    #[test]
    fn throttled_load_is_slower() {
        let dir = crate::util::tempdir::TempDir::new("matkv-kvstore-thr").unwrap();
        let slow = StorageProfile {
            name: "slow".into(),
            read_bw: 50e6,
            write_bw: 1e12,
            latency_s: 0.0,
            power_active: 1.0,
            power_idle: 0.0,
            usd_per_byte: 0.0,
        };
        let s = KvStore::open(dir.path(), slow).unwrap();
        let c = chunk(1, 256);
        s.store_sync(1, &c).unwrap();
        let loaded = s.load(1).unwrap();
        let expect = s.encoded_bytes(&c) as f64 / 50e6;
        assert!((loaded.device_secs - expect).abs() / expect < 0.3);
    }

    #[test]
    fn size_validation() {
        let mut c = chunk(1, 8);
        c.k.pop();
        let (_d, s) = store();
        assert!(s.store_sync(1, &c).is_err());
    }

    // --- hot tier -------------------------------------------------------

    fn tiered_store(budget: usize) -> (crate::util::tempdir::TempDir, KvStore) {
        let dir = crate::util::tempdir::TempDir::new("matkv-kvstore-tier").unwrap();
        let mut s = KvStore::open(dir.path(), StorageProfile::ssd_9100pro()).unwrap();
        s.disable_throttle(); // device_secs still computed, just no sleep
        s.set_hot_tier(budget);
        (dir, s)
    }

    #[test]
    fn hot_tier_hit_skips_device() {
        let (_d, s) = tiered_store(64 << 20);
        let c = chunk(2, 16);
        s.store_sync(5, &c).unwrap();
        let cold = s.load(5).unwrap();
        assert!(!cold.from_cache);
        assert!(cold.device_secs > 0.0);
        let warm = s.load(5).unwrap();
        assert!(warm.from_cache);
        assert_eq!(warm.device_secs, 0.0);
        assert_eq!(*warm.chunk, *cold.chunk);
        // only the miss touched the device
        assert_eq!(s.stats.reads.load(Ordering::Relaxed), 1);
        let tier = s.hot_tier().unwrap();
        assert_eq!(tier.stats.hits.load(Ordering::Relaxed), 1);
        assert_eq!(tier.stats.bytes_saved.load(Ordering::Relaxed), cold.file_bytes as u64);
    }

    #[test]
    fn load_many_mixes_hits_and_misses_in_order() {
        let (_d, s) = tiered_store(64 << 20);
        for i in 0..4u64 {
            s.store_sync(i, &chunk(i as u32, 8)).unwrap();
        }
        s.load(1).unwrap(); // warm id 1
        let loaded = s.load_many(&[0, 1, 2]).unwrap();
        assert!(!loaded[0].from_cache);
        assert!(loaded[1].from_cache);
        assert!(!loaded[2].from_cache);
        for (l, want) in loaded.iter().zip([0u32, 1, 2]) {
            assert_eq!(l.chunk.k[0], chunk(want, 8).k[0]);
        }
        // a second pass is all hits
        assert!(s.load_many(&[0, 1, 2]).unwrap().iter().all(|l| l.from_cache));
    }

    #[test]
    fn writes_and_deletes_invalidate_hot_tier() {
        let (_d, s) = tiered_store(64 << 20);
        s.store_sync(1, &chunk(1, 8)).unwrap();
        s.load(1).unwrap();
        assert!(s.load(1).unwrap().from_cache);
        // re-materialize: the next load must see the new payload
        s.store_sync(1, &chunk(50, 8)).unwrap();
        let l = s.load(1).unwrap();
        assert!(!l.from_cache);
        assert_eq!(l.chunk.k[0], 50.0);
        // delete: no stale hit either
        s.delete(1).unwrap();
        assert!(s.load(1).is_err());
    }

    // --- warm tier ------------------------------------------------------

    /// A chunk with constant planes. Use multiples of 127 for `val`:
    /// the q8 scale is then an exact small integer (max/127), the code
    /// is exactly ±127, and the round trip is bit-exact — so identity
    /// asserts stay valid through the warm tier. (An arbitrary constant
    /// is NOT safe: fl(127 · fl(x/127)) can land one ulp off x.)
    fn flat_chunk(val: f32, seq: u32) -> KvChunk {
        let plane = (2 * 2 * seq * 4) as usize;
        KvChunk {
            config_id: 0xabcd,
            n_layers: 2,
            n_kv_heads: 2,
            seq_len: seq,
            head_dim: 4,
            k: vec![val; plane],
            v: vec![-val; plane],
        }
    }

    fn warm_store(hot_budget: usize, warm_budget: usize) -> (crate::util::tempdir::TempDir, KvStore) {
        let dir = crate::util::tempdir::TempDir::new("matkv-kvstore-warm").unwrap();
        let mut s = KvStore::open(dir.path(), StorageProfile::ssd_9100pro()).unwrap();
        s.disable_throttle();
        s.set_hot_tier(hot_budget);
        s.set_warm_tier(warm_budget);
        (dir, s)
    }

    fn f32_cost() -> usize {
        flat_chunk(0.0, 8).dram_bytes()
    }

    #[test]
    fn hot_eviction_demotes_to_warm_and_promotes_back() {
        let (_d, s) = warm_store(2 * f32_cost(), 64 << 20);
        for i in 1..=3u64 {
            s.store_sync(i, &flat_chunk(127.0 * i as f32, 8)).unwrap();
        }
        s.load(1).unwrap();
        s.load(2).unwrap();
        s.load(3).unwrap(); // hot full → LRU id 1 demotes into warm
        let warm = s.warm_tier().unwrap();
        assert!(warm.contains(1), "eviction must demote, not drop");
        assert!(s.hot_tier().unwrap().contains(2) && s.hot_tier().unwrap().contains(3));
        assert_eq!(s.stats.reads.load(Ordering::Relaxed), 3);

        // warm hit: dequant + promote back to hot, exclusive placement
        let l = s.load(1).unwrap();
        assert!(l.from_cache && l.from_warm);
        assert_eq!(l.device_secs, 0.0);
        assert!(l.dequant_secs > 0.0, "warm hits charge modeled dequant time");
        assert_eq!(*l.chunk, flat_chunk(127.0, 8), "on-grid planes survive q8 exactly");
        assert_eq!(s.stats.reads.load(Ordering::Relaxed), 3, "no device read for a warm hit");
        assert!(!warm.contains(1), "promote must remove the q8 copy");
        assert!(s.hot_tier().unwrap().contains(1));
        assert!(warm.contains(2), "promote overflowed id 2 into the warm tier");
        assert_eq!(warm.stats.hits.load(Ordering::Relaxed), 1);
        assert!(warm.stats.dequant_secs() > 0.0);
        // a hot hit afterwards costs nothing further
        let l = s.load(1).unwrap();
        assert!(l.from_cache && !l.from_warm);
        assert_eq!(l.dequant_secs, 0.0);
    }

    #[test]
    fn warm_only_store_serves_q8_hits_in_place() {
        let (_d, s) = warm_store(0, 64 << 20);
        assert!(s.hot_tier().is_none());
        s.store_sync(1, &flat_chunk(508.0, 8)).unwrap();
        let cold = s.load(1).unwrap();
        assert!(!cold.from_cache && !cold.from_warm);
        let warm_hit = s.load(1).unwrap();
        assert!(warm_hit.from_cache && warm_hit.from_warm);
        assert!(warm_hit.dequant_secs > 0.0);
        assert_eq!(*warm_hit.chunk, flat_chunk(508.0, 8));
        // no hot tier to promote into: the q8 copy stays put
        assert!(s.warm_tier().unwrap().contains(1));
        assert!(s.load(1).unwrap().from_warm);
        assert_eq!(s.stats.reads.load(Ordering::Relaxed), 1, "one cold read total");
    }

    #[test]
    fn invalidate_between_demote_and_promote_serves_fresh_bytes() {
        // The generation-guard race the warm tier must survive (mirrors
        // the hot tier's insert_at race tests): a chunk demoted into the
        // warm tier is re-materialized before it is promoted back — the
        // store must serve the NEW payload from flash, never the stale
        // q8 copy.
        let (_d, s) = warm_store(2 * f32_cost(), 64 << 20);
        for i in 1..=3u64 {
            s.store_sync(i, &flat_chunk(i as f32, 8)).unwrap();
            s.load(i).unwrap();
        }
        assert!(s.warm_tier().unwrap().contains(1), "id 1 demoted");
        // re-materialize id 1 between its demotion and any promotion
        s.store_sync(1, &flat_chunk(50.0, 8)).unwrap();
        assert!(!s.warm_tier().unwrap().contains(1), "write must sweep the warm copy");
        let l = s.load(1).unwrap();
        assert!(!l.from_cache && !l.from_warm, "stale warm copy served after rewrite");
        assert_eq!(l.chunk.k[0], 50.0);
        // deletes sweep the warm tier too
        s.load(2).unwrap(); // ensure 2 is somewhere in DRAM
        s.delete(2).unwrap();
        assert!(!s.warm_tier().unwrap().contains(2));
        assert!(s.load(2).is_err());
    }

    #[test]
    fn demote_promote_cycle_preserves_prefetch_semantics() {
        // One-chunk hot tier + warm tier: a prefetched-but-unread chunk
        // is demoted by a later prefetch, keeps its class in the warm
        // tier, converts to a demand entry on promote — and is then
        // protected from prefetch eviction like any demand resident.
        let (_d, s) = warm_store(f32_cost(), 64 << 20);
        for i in 1..=3u64 {
            s.store_sync(i, &flat_chunk(i as f32, 8)).unwrap();
        }
        assert_eq!(s.prefetch_many(&[1]).warmed, 1);
        assert_eq!(s.prefetch_many(&[2]).warmed, 1); // evicts prefetched 1 → warm
        let warm = s.warm_tier().unwrap();
        assert!(warm.contains(1), "prefetched eviction demotes like any other");

        // demand load of 1: a warm hit that still counts as a prefetch
        // conversion, then promotes as a demand entry (evicting 2).
        let l = s.load(1).unwrap();
        assert!(l.from_warm);
        assert_eq!(warm.stats.prefetch_hits.load(Ordering::Relaxed), 1);
        assert!(s.hot_tier().unwrap().contains(1));

        // as a demand resident, 1 is now protected from prefetch
        // eviction — the refused prefetch parks in the warm tier
        // instead of dropping (demote-on-prefetch-reject)
        let rep = s.prefetch_many(&[3]);
        assert_eq!(rep.warmed, 1, "refused hot admission must park in warm: {rep:?}");
        assert_eq!(rep.rejected, 0);
        assert!(s.hot_tier().unwrap().contains(1));
        assert!(warm.contains(3));
    }

    #[test]
    fn prefetch_reject_demotes_into_warm() {
        // Satellite: a hot tier full of demand residents refuses the
        // prefetch admission (protection semantics unchanged — the
        // hot-tier stats still record the refusal), but the chunk parks
        // in the warm tier instead of wasting the device read, and the
        // demand load then serves from DRAM.
        let (_d, s) = warm_store(f32_cost(), 64 << 20);
        s.store_sync(1, &flat_chunk(127.0, 8)).unwrap();
        s.store_sync(2, &flat_chunk(254.0, 8)).unwrap();
        s.load(1).unwrap(); // demand-resident, fills the whole hot budget
        let rep = s.prefetch_many(&[2]);
        assert_eq!(rep.warmed, 1, "{rep:?}");
        assert_eq!(rep.rejected, 0);
        assert!(s.hot_tier().unwrap().contains(1), "demand resident displaced");
        assert!(!s.hot_tier().unwrap().contains(2));
        assert!(s.warm_tier().unwrap().contains(2));
        assert_eq!(
            s.hot_tier().unwrap().stats.prefetch_rejected.load(Ordering::Relaxed),
            1,
            "the hot-side refusal is still recorded"
        );
        // quantize-on-demote charged in simulated time (satellite 2)
        assert!(s.warm_tier().unwrap().stats.quant_secs() > 0.0);
        // the demand load is a warm hit: no second device read
        let reads = s.stats.reads.load(Ordering::Relaxed);
        let l = s.load(2).unwrap();
        assert!(l.from_warm, "parked prefetch must serve the demand load");
        assert_eq!(*l.chunk, flat_chunk(254.0, 8));
        assert_eq!(s.stats.reads.load(Ordering::Relaxed), reads);
        // prefetch class survived the park: the hit converts it
        assert_eq!(s.warm_tier().unwrap().stats.prefetch_hits.load(Ordering::Relaxed), 1);
    }

    #[test]
    fn prefetch_reject_without_warm_still_drops() {
        // No warm tier: the pre-satellite behavior is unchanged.
        let dir = crate::util::tempdir::TempDir::new("matkv-kvstore-rejdrop").unwrap();
        let mut s = KvStore::open(dir.path(), StorageProfile::ssd_9100pro()).unwrap();
        s.disable_throttle();
        s.set_hot_tier(f32_cost());
        s.store_sync(1, &flat_chunk(127.0, 8)).unwrap();
        s.store_sync(2, &flat_chunk(254.0, 8)).unwrap();
        s.load(1).unwrap();
        let rep = s.prefetch_many(&[2]);
        assert_eq!(rep.rejected, 1, "{rep:?}");
        assert_eq!(rep.warmed, 0);
        assert!(!s.load(2).unwrap().from_cache);
    }

    #[test]
    fn rejected_prefetch_park_is_generation_guarded() {
        // A delete landing while the to-be-parked chunk's read was in
        // flight must bounce the warm admission — same guard as any
        // other warm-side park.
        let (_d, s) = warm_store(f32_cost(), 64 << 20);
        s.store_sync(1, &flat_chunk(127.0, 8)).unwrap();
        s.store_sync(2, &flat_chunk(254.0, 8)).unwrap();
        s.load(1).unwrap();
        s.prefetch_many(&[2]);
        assert!(s.warm_tier().unwrap().contains(2));
        s.delete(2).unwrap();
        assert!(!s.warm_tier().unwrap().contains(2), "delete must sweep the parked copy");
        assert!(s.load(2).is_err());
    }

    #[test]
    fn warm_only_miss_charges_quantize_on_the_load() {
        // Direct q8 admission (warm-only store): the cold load pays the
        // modeled quantize pass, carried on Loaded and mirrored in the
        // tier's CacheStats; the warm hit afterwards pays dequant only.
        let (_d, s) = warm_store(0, 64 << 20);
        s.store_sync(1, &flat_chunk(508.0, 8)).unwrap();
        let cold = s.load(1).unwrap();
        assert!(cold.quant_secs > 0.0, "cold admit must charge quantize");
        assert_eq!(cold.dequant_secs, 0.0);
        let warm = s.warm_tier().unwrap();
        // the tier's clock is nanosecond-granular, so allow one tick
        assert!((warm.stats.quant_secs() - cold.quant_secs).abs() <= 2e-9);
        let hit = s.load(1).unwrap();
        assert_eq!(hit.quant_secs, 0.0);
        assert!(hit.dequant_secs > 0.0);
        // symmetric charge: same q8 payload in, same payload out
        assert!((cold.quant_secs - hit.dequant_secs).abs() < 1e-9);
    }

    #[test]
    fn resident_set_splits_tiers() {
        let (_d, s) = warm_store(2 * f32_cost(), 64 << 20);
        assert!(s.resident_set().is_empty());
        for i in 1..=3u64 {
            s.store_sync(i, &flat_chunk(i as f32, 8)).unwrap();
            s.load(i).unwrap();
        }
        // hot: {2, 3}, warm: {1} (same shape as resident_ids_union test)
        let snap = s.resident_set();
        assert!(snap.hot.contains(&2) && snap.hot.contains(&3));
        assert!(snap.warm.contains(&1));
        assert!(snap.contains(1) && snap.contains(2) && snap.contains(3));
        assert!(!snap.contains(9));
        assert_eq!(snap.len(), 3);
        // the snapshot is a copy: later loads don't mutate it
        s.load(1).unwrap();
        assert!(snap.warm.contains(&1));
    }

    #[test]
    fn prefetch_counts_warm_residents_and_warms_warm_only_stores() {
        // Warm-resident chunks are DRAM-resident: prefetch leaves them be.
        let (_d, s) = warm_store(2 * f32_cost(), 64 << 20);
        for i in 1..=3u64 {
            s.store_sync(i, &flat_chunk(i as f32, 8)).unwrap();
            s.load(i).unwrap();
        }
        assert!(s.warm_tier().unwrap().contains(1));
        let rep = s.prefetch_many(&[1, 2, 3]);
        assert_eq!(rep.already_resident, 3, "{rep:?}");
        assert_eq!(rep.warmed, 0);

        // Warm-only store: prefetch admits quantized copies directly.
        let (_d2, s2) = warm_store(0, 64 << 20);
        for i in 1..=2u64 {
            s2.store_sync(i, &flat_chunk(i as f32, 8)).unwrap();
        }
        let rep = s2.prefetch_many(&[1, 2, 9]);
        assert_eq!(rep.warmed, 2);
        assert_eq!(rep.absent, 1);
        assert!(rep.device_secs > 0.0);
        let warm = s2.warm_tier().unwrap();
        assert_eq!(warm.stats.prefetch_inserts.load(Ordering::Relaxed), 2);
        let l = s2.load(1).unwrap();
        assert!(l.from_warm, "prefetched q8 copy must serve the demand load");
        assert_eq!(warm.stats.prefetch_hits.load(Ordering::Relaxed), 1);
    }

    #[test]
    fn chunk_too_large_for_hot_tier_still_lands_in_warm() {
        // A hot tier smaller than one chunk can never admit anything —
        // so the warm tier must catch the miss directly (both demand
        // and prefetch paths), or --warm-tier-bytes would be silently
        // dead in that configuration.
        let (_d, s) = warm_store(f32_cost() / 2, 64 << 20);
        s.store_sync(1, &flat_chunk(127.0, 8)).unwrap();
        s.store_sync(2, &flat_chunk(254.0, 8)).unwrap();
        // demand path
        assert!(!s.load(1).unwrap().from_cache);
        assert_eq!(s.hot_tier().unwrap().len(), 0);
        assert!(s.warm_tier().unwrap().contains(1), "oversize miss must park in warm");
        let l = s.load(1).unwrap();
        assert!(l.from_warm);
        assert_eq!(*l.chunk, flat_chunk(127.0, 8));
        // no promote was attempted (the hot tier could never admit it),
        // so the q8 copy stays resident and keeps serving
        assert!(s.warm_tier().unwrap().contains(1), "hit must not evict itself");
        assert!(s.load(1).unwrap().from_warm);
        assert_eq!(s.stats.reads.load(Ordering::Relaxed), 1, "exactly one cold read");
        // prefetch path
        let rep = s.prefetch_many(&[2]);
        assert_eq!(rep.warmed, 1, "{rep:?}");
        assert!(s.warm_tier().unwrap().contains(2));
        assert!(s.load(2).unwrap().from_warm);
    }

    #[test]
    fn resident_ids_union_both_tiers() {
        let (_d, s) = warm_store(2 * f32_cost(), 64 << 20);
        for i in 1..=3u64 {
            s.store_sync(i, &flat_chunk(i as f32, 8)).unwrap();
            s.load(i).unwrap();
        }
        // hot: {2, 3}, warm: {1}
        let mut hot_ids = s.hot_resident_ids();
        hot_ids.sort_unstable();
        assert_eq!(hot_ids, vec![2, 3]);
        assert_eq!(s.warm_resident_ids(), vec![1]);
        assert_eq!(s.resident_ids(), vec![1, 2, 3], "union, sorted");
    }

    #[test]
    fn equal_dram_budget_split_beats_hot_only() {
        // The tentpole's acceptance shape at unit scale: at EQUAL total
        // DRAM bytes, hot+warm holds strictly more chunks (q8 is ~4x
        // denser), so a Zipf replay serves strictly more loads from DRAM
        // and issues strictly fewer device reads than hot-only.
        let n = 64usize;
        let total = 12 * f32_cost();
        let mut results = Vec::new();
        for (hot, warm) in [(total, 0), (total / 2, total - total / 2)] {
            let (_d, s) = warm_store(hot, warm);
            for i in 0..n as u64 {
                s.store_sync(i, &flat_chunk(i as f32, 8)).unwrap();
            }
            let zipf = Zipf::new(n, 1.0);
            let mut rng = Rng::new(99);
            let mut dram_served = 0u64;
            for _ in 0..1500 {
                let l = s.load(zipf.sample(&mut rng) as u64).unwrap();
                dram_served += l.from_cache as u64;
            }
            results.push((s.stats.reads.load(Ordering::Relaxed), dram_served));
        }
        let (hot_only_reads, hot_only_dram) = results[0];
        let (split_reads, split_dram) = results[1];
        assert!(
            split_reads < hot_only_reads,
            "split must read the device strictly less: {split_reads} vs {hot_only_reads}"
        );
        assert!(
            split_dram > hot_only_dram,
            "split must serve strictly more from DRAM: {split_dram} vs {hot_only_dram}"
        );
    }

    // --- sharding -------------------------------------------------------

    fn sharded_store(n: usize) -> (crate::util::tempdir::TempDir, KvStore) {
        let dir = crate::util::tempdir::TempDir::new("matkv-kvstore-shard").unwrap();
        let mut s = KvStore::open_sharded(dir.path(), StorageProfile::dram(), n).unwrap();
        s.disable_throttle();
        (dir, s)
    }

    #[test]
    fn sharded_roundtrip_spreads_files() {
        let (_d, s) = sharded_store(4);
        assert_eq!(s.n_shards(), 4);
        for i in 0..32u64 {
            s.store_sync(i, &chunk(i as u32, 8)).unwrap();
        }
        assert_eq!(s.len().unwrap(), 32);
        // every shard got some of the corpus
        for shard in s.shards() {
            assert!(shard.len().unwrap() > 0, "shard {} empty", shard.index());
        }
        let loaded = s.load_many(&(0..32u64).collect::<Vec<_>>()).unwrap();
        for (i, l) in loaded.iter().enumerate() {
            assert_eq!(l.chunk.k[0], chunk(i as u32, 8).k[0]);
            assert_eq!(l.shard, s.shard_index_of(i as u64));
        }
        // per-shard read counters sum to the store aggregate
        let shard_reads: u64 =
            s.shards().iter().map(|sh| sh.stats.reads.load(Ordering::Relaxed)).sum();
        assert_eq!(shard_reads, s.stats.reads.load(Ordering::Relaxed));
        assert_eq!(shard_reads, 32);
    }

    #[test]
    fn shard_routing_stable_across_reopen() {
        // Satellite regression: same id → same shard directory, before
        // and after reopen — and the single-id `load` goes through the
        // same routing path as `load_many`.
        let dir = crate::util::tempdir::TempDir::new("matkv-kvstore-reopen").unwrap();
        let placed: Vec<(u64, usize, PathBuf)> = {
            let mut s =
                KvStore::open_sharded(dir.path(), StorageProfile::dram(), 4).unwrap();
            s.disable_throttle();
            (0..16u64)
                .map(|i| {
                    s.store_sync(i, &chunk(i as u32, 8)).unwrap();
                    let idx = s.shard_index_of(i);
                    let path = s.shards()[idx].dir().join(format!("{i:016x}.kv"));
                    assert!(path.exists(), "chunk {i} not in its routed shard dir");
                    (i, idx, path)
                })
                .collect()
        };
        let mut s = KvStore::open_sharded(dir.path(), StorageProfile::dram(), 4).unwrap();
        s.disable_throttle();
        for (id, idx, path) in placed {
            assert_eq!(s.shard_index_of(id), idx, "routing moved for id {id} across reopen");
            assert!(path.exists());
            assert!(s.contains(id));
            // single-id load: same shard-routing path as load_many
            let l = s.load(id).unwrap();
            assert_eq!(l.shard, idx);
            assert_eq!(l.chunk.k[0], chunk(id as u32, 8).k[0]);
        }
    }

    #[test]
    fn shard_count_mismatch_rejected() {
        let dir = crate::util::tempdir::TempDir::new("matkv-kvstore-marker").unwrap();
        {
            KvStore::open_sharded(dir.path(), StorageProfile::dram(), 4).unwrap();
        }
        let err = KvStore::open_sharded(dir.path(), StorageProfile::dram(), 2).unwrap_err();
        assert!(err.to_string().contains("4 shard"), "{err}");
        // the pinned count still opens
        KvStore::open_sharded(dir.path(), StorageProfile::dram(), 4).unwrap();
    }

    #[test]
    fn single_shard_layout_not_reopenable_sharded() {
        // A PR-1-era store (chunk files directly in the root, no marker)
        // must not be silently re-sharded.
        let dir = crate::util::tempdir::TempDir::new("matkv-kvstore-loose").unwrap();
        {
            let s = KvStore::open(dir.path(), StorageProfile::dram()).unwrap();
            s.store_sync(1, &chunk(1, 8)).unwrap();
        }
        std::fs::remove_file(dir.path().join(SHARD_MARKER)).unwrap(); // simulate pre-marker store
        let err = KvStore::open_sharded(dir.path(), StorageProfile::dram(), 4).unwrap_err();
        assert!(err.to_string().contains("single-shard"), "{err}");
        // ...but keeps opening fine as the single device it is
        let s = KvStore::open(dir.path(), StorageProfile::dram()).unwrap();
        assert_eq!(*s.load(1).unwrap().chunk, chunk(1, 8));
    }

    #[test]
    fn zero_shards_rejected() {
        let dir = crate::util::tempdir::TempDir::new("matkv-kvstore-zero").unwrap();
        assert!(KvStore::open_sharded(dir.path(), StorageProfile::dram(), 0).is_err());
    }

    #[test]
    fn sharded_misses_overlap_in_wall_time() {
        // The tentpole's point: equal total bytes, 4 devices ≫ 1 device.
        // 16 chunks × ~10ms each: serial ≈ 160ms, 4-way JBOD ≈ 40ms+imbalance.
        let chunk_secs = 0.010;
        let c = chunk(1, 64);
        let file_bytes = c.file_bytes(KvFormat::V2) as f64;
        let profile = StorageProfile {
            name: "sim-slow".into(),
            read_bw: file_bytes / chunk_secs,
            write_bw: 1e12,
            latency_s: 0.0,
            power_active: 1.0,
            power_idle: 0.0,
            usd_per_byte: 0.0,
        };
        let ids: Vec<ChunkId> = (0..16u64).collect();
        let mut elapsed = Vec::new();
        for n in [1usize, 4] {
            let dir = crate::util::tempdir::TempDir::new("matkv-kvstore-jbod").unwrap();
            let mut s = KvStore::open_sharded(dir.path(), profile.clone(), n).unwrap();
            s.disable_throttle();
            for &i in &ids {
                s.store_sync(i, &chunk(i as u32, 64)).unwrap();
            }
            s.set_profile(profile.clone()); // re-enable throttling for the reads
            let t0 = std::time::Instant::now();
            let loaded = s.load_many(&ids).unwrap();
            elapsed.push(t0.elapsed().as_secs_f64());
            // simulated per-read device seconds are the same either way —
            // sharding buys *overlap*, not faster single reads
            for l in &loaded {
                assert!((l.device_secs - chunk_secs).abs() / chunk_secs < 0.5, "{}", l.device_secs);
            }
        }
        // Smell-test bound only: ideal is ~2.7x (16 ids route 6/4/4/2),
        // but CI schedulers add noise to sleep-based overlap, so the
        // rigorous scaling sweep lives in benches/fig_shard_scale.rs.
        let speedup = elapsed[0] / elapsed[1];
        assert!(speedup > 1.5, "4-shard JBOD only {speedup:.2}x over 1 shard ({elapsed:?})");
    }

    #[test]
    fn placement_balances_bytes_not_counts() {
        // Satellite: a 16x size spread across the corpus. Greedy argmin
        // placement bounds the cumulative byte skew by one max-size
        // file — count-balanced hashing has no such bound and can stack
        // the large chunks on one device.
        let (_d, s) = sharded_store(4);
        let seqs = [8u32, 128, 8, 8, 128, 8, 128, 128, 8, 64, 32, 8, 128, 16, 8, 128];
        let mut max_file = 0u64;
        for (i, &seq) in seqs.iter().enumerate() {
            let c = chunk(i as u32, seq);
            max_file = max_file.max(s.encoded_bytes(&c) as u64);
            s.store_sync(i as u64, &c).unwrap();
        }
        let placed = s.shard_placed_bytes();
        let (lo, hi) = (*placed.iter().min().unwrap(), *placed.iter().max().unwrap());
        assert!(hi - lo <= max_file, "byte skew {} exceeds one max file {max_file}", hi - lo);
        // the balancer's weights are the on-disk reality, not a model
        for (sh, &want) in s.shards().iter().zip(&placed) {
            assert_eq!(sh.bytes_on_disk().unwrap(), want);
        }
    }

    #[test]
    fn unplaced_ids_fall_back_to_hash_routing() {
        let (_d, s) = sharded_store(4);
        // never-stored ids resolve exactly where the legacy hash put them
        for id in [7u64, 1 << 40, u64::MAX] {
            assert_eq!(s.shard_index_of(id), route(id, 4));
        }
        // a placed id resolves through the map, and the file is there
        s.store_sync(7, &chunk(7, 8)).unwrap();
        let idx = s.shard_index_of(7);
        assert!(s.shards()[idx].dir().join(format!("{:016x}.kv", 7u64)).exists());
        // re-storing keeps the shard (no file orphaned in another dir)
        s.store_sync(7, &chunk(8, 8)).unwrap();
        assert_eq!(s.shard_index_of(7), idx);
        assert_eq!(s.len().unwrap(), 1);
    }

    #[test]
    fn byte_balanced_placement_survives_reopen() {
        let dir = crate::util::tempdir::TempDir::new("matkv-kvstore-placelog").unwrap();
        let (placed, weights) = {
            let mut s = KvStore::open_sharded(dir.path(), StorageProfile::dram(), 4).unwrap();
            s.disable_throttle();
            for i in 0..12u64 {
                s.store_sync(i, &chunk(i as u32, if i % 3 == 0 { 128 } else { 8 })).unwrap();
            }
            let placed: Vec<(u64, usize)> = (0..12u64).map(|i| (i, s.shard_index_of(i))).collect();
            (placed, s.shard_placed_bytes())
        };
        let mut s = KvStore::open_sharded(dir.path(), StorageProfile::dram(), 4).unwrap();
        s.disable_throttle();
        for &(id, idx) in &placed {
            assert_eq!(s.shard_index_of(id), idx, "placement moved for id {id} across reopen");
            assert_eq!(s.load(id).unwrap().shard, idx);
        }
        assert_eq!(s.shard_placed_bytes(), weights, "argmin weights must replay exactly");
    }

    #[test]
    fn warm_quant_traffic_contends_on_the_host_bus() {
        let (_d, s) = warm_store(2 * f32_cost(), 64 << 20);
        for i in 1..=3u64 {
            s.store_sync(i, &flat_chunk(127.0 * i as f32, 8)).unwrap();
            s.load(i).unwrap(); // third load demotes id 1 into warm
        }
        let bus = s.bus();
        assert!(bus.stats.bytes_for(TrafficClass::Demotion) > 0, "demote must cross the bus");
        assert!(bus.stats.busy_secs() > 0.0);
        let before = bus.stats.bytes_for(TrafficClass::Promotion);
        let l = s.load(1).unwrap(); // warm hit: dequant + promote
        assert!(l.from_warm);
        assert!(bus.stats.bytes_for(TrafficClass::Promotion) > before);
        // the bus adds contention telemetry only — charge magnitudes on
        // the Loaded/CacheStats side are exactly the modeled quant costs
        let warm = s.warm_tier().unwrap();
        assert!((l.dequant_secs - warm.stats.dequant_secs()).abs() < 2e-9);
        assert!(warm.stats.link_queued_secs() >= 0.0, "queued gauge wired, never negative");
    }

    #[test]
    fn shard_links_split_demand_and_prefetch_bytes() {
        // Throttle left ENABLED (DRAM profile: no sleeping) so reads
        // reach the shard links and tag their traffic class.
        let dir = crate::util::tempdir::TempDir::new("matkv-kvstore-class").unwrap();
        let mut s = KvStore::open(dir.path(), StorageProfile::dram()).unwrap();
        s.set_hot_tier(64 << 20);
        s.store_sync(1, &chunk(1, 8)).unwrap();
        s.store_sync(2, &chunk(2, 8)).unwrap();
        assert_eq!(s.prefetch_many(&[1]).warmed, 1);
        s.load(2).unwrap();
        let sum = |class: TrafficClass| -> u64 {
            s.shards().iter().map(|sh| sh.link().stats.bytes_for(class)).sum()
        };
        let file = s.encoded_bytes(&chunk(1, 8)) as u64;
        assert_eq!(sum(TrafficClass::Prefetch), file);
        assert_eq!(sum(TrafficClass::Demand), file);
        assert_eq!(sum(TrafficClass::Write), 2 * file);
    }

    // --- prefetch -------------------------------------------------------

    #[test]
    fn prefetch_warms_tier_then_demand_hits() {
        let (_d, s) = tiered_store(64 << 20);
        for i in 0..4u64 {
            s.store_sync(i, &chunk(i as u32, 8)).unwrap();
        }
        let report = s.prefetch_many(&[0, 1, 2, 2]); // dup collapses
        assert_eq!(report.requested, 3);
        assert_eq!(report.warmed, 3);
        assert_eq!(report.absent, 0);
        assert!(report.device_secs > 0.0, "prefetch reads must charge the device");
        // demand loads of the warmed ids are pure tier hits
        let loaded = s.load_many(&[0, 1, 2]).unwrap();
        assert!(loaded.iter().all(|l| l.from_cache));
        let tier = s.hot_tier().unwrap();
        assert_eq!(tier.stats.prefetch_hits.load(Ordering::Relaxed), 3);
        // id 3 was never prefetched: still a device miss
        assert!(!s.load(3).unwrap().from_cache);
        // second prefetch of the same ids is a no-op
        let again = s.prefetch_many(&[0, 1, 2]);
        assert_eq!(again.already_resident, 3);
        assert_eq!(again.warmed, 0);
    }

    #[test]
    fn prefetch_missing_chunk_degrades_to_miss() {
        let (_d, s) = tiered_store(64 << 20);
        s.store_sync(1, &chunk(1, 8)).unwrap();
        let report = s.prefetch_many(&[1, 99]); // 99 was never materialized
        assert_eq!(report.warmed, 1);
        assert_eq!(report.absent, 1);
        // the demand path still owns the error for the missing chunk
        assert!(s.load(99).is_err());
        assert!(s.load(1).unwrap().from_cache);
    }

    #[test]
    fn prefetched_then_deleted_not_served_stale() {
        let (_d, s) = tiered_store(64 << 20);
        s.store_sync(7, &chunk(7, 8)).unwrap();
        assert_eq!(s.prefetch_many(&[7]).warmed, 1);
        s.delete(7).unwrap();
        // neither the tier nor the store may serve the deleted chunk
        assert!(!s.hot_tier().unwrap().contains(7));
        assert!(s.load(7).is_err());
        // and a re-materialization serves the *new* payload
        s.store_sync(7, &chunk(70, 8)).unwrap();
        assert_eq!(s.prefetch_many(&[7]).warmed, 1);
        let l = s.load(7).unwrap();
        assert!(l.from_cache);
        assert_eq!(l.chunk.k[0], chunk(70, 8).k[0]);
    }

    #[test]
    fn prefetch_without_tier_is_noop() {
        let (_d, s) = store();
        s.store_sync(1, &chunk(1, 8)).unwrap();
        let report = s.prefetch_many(&[1]);
        assert_eq!(report, PrefetchReport::default());
        assert_eq!(s.stats.reads.load(Ordering::Relaxed), 0);
    }

    #[test]
    fn top_decile_tier_absorbs_zipf_mass() {
        // Acceptance shape: a hot tier holding ~10% of the corpus under
        // Zipf(1.0) access serves a large fraction of loads from DRAM
        // and strictly beats the cold store on simulated device time.
        let n = 100u64;
        let per_chunk = chunk(0, 8).dram_bytes();
        let (_d, hot) = tiered_store(10 * per_chunk);
        let (_d2, cold) = {
            let dir = crate::util::tempdir::TempDir::new("matkv-kvstore-cold").unwrap();
            let mut s = KvStore::open(dir.path(), StorageProfile::ssd_9100pro()).unwrap();
            s.disable_throttle();
            (dir, s)
        };
        for i in 0..n {
            hot.store_sync(i, &chunk(i as u32, 8)).unwrap();
            cold.store_sync(i, &chunk(i as u32, 8)).unwrap();
        }
        let zipf = Zipf::new(n as usize, 1.0);
        let mut rng = Rng::new(42);
        let ids: Vec<u64> = (0..2000).map(|_| zipf.sample(&mut rng) as u64).collect();
        let (mut hot_secs, mut cold_secs, mut hits) = (0.0, 0.0, 0u64);
        for &id in &ids {
            let l = hot.load(id).unwrap();
            hot_secs += l.device_secs;
            hits += l.from_cache as u64;
            cold_secs += cold.load(id).unwrap().device_secs;
        }
        let ratio = hits as f64 / ids.len() as f64;
        assert!(ratio > 0.3, "hit ratio {ratio}");
        assert!(hot_secs < cold_secs, "{hot_secs} vs {cold_secs}");
    }

    // --- fault recovery & crash consistency -----------------------------

    fn faulted_store(n_shards: usize, spec: &str) -> (crate::util::tempdir::TempDir, KvStore) {
        let dir = crate::util::tempdir::TempDir::new("matkv-kvstore-fault").unwrap();
        let mut s = KvStore::open_sharded(dir.path(), StorageProfile::dram(), n_shards).unwrap();
        s.disable_throttle();
        for i in 0..6u64 {
            s.store_sync(i, &chunk(i as u32, 8)).unwrap();
        }
        s.set_faults(Some(Arc::new(FaultPlan::parse(spec).unwrap())));
        (dir, s)
    }

    #[test]
    fn stalled_shard_retries_with_deterministic_backoff() {
        let run = || {
            let (_d, mut s) = faulted_store(2, "seed=7,shard0:stall@0..2");
            s.set_retry_policy(3, 0.004);
            let loaded = s.load_many(&[0, 1]).unwrap();
            loaded
                .iter()
                .map(|l| (l.retries, l.retry_backoff_secs.to_bits(), l.checksum_failures, l.recomputed))
                .collect::<Vec<_>>()
        };
        let a = run();
        // equal-size chunks round-robin across 2 shards: id 0 is on the
        // stalled shard 0, id 1 on the healthy shard 1
        assert_eq!(a[0].0, 2, "two stalled reads, then the heal: {a:?}");
        assert_eq!(a[0].1, (0.004f64 + 0.008).to_bits(), "1x, 2x exponential schedule");
        assert!(!a[0].3, "a healed retry must not fall through to recompute");
        assert_eq!(a[1], (0, 0.0f64.to_bits(), 0, false), "healthy shard untouched");
        // same seed + same plan ⇒ bit-identical retry schedule (the
        // fleet-dispatch mirror of this lives in coordinator::scheduler)
        assert_eq!(a, run());
    }

    #[test]
    fn corrupted_read_caught_by_checksum_and_retried() {
        let (_d, mut s) = faulted_store(1, "shard0:corrupt@0");
        s.set_retry_policy(2, 0.001);
        let l = s.load(2).unwrap();
        assert_eq!(l.checksum_failures, 1, "the v3 checksum must catch the bit flip");
        assert_eq!(l.retries, 1);
        assert!(!l.recomputed);
        assert_eq!(*l.chunk, chunk(2, 8), "served planes are the intact ones");
        // only the in-flight buffer was corrupted, never the file
        assert_eq!(*s.load(3).unwrap().chunk, chunk(3, 8));
    }

    #[test]
    fn dead_shard_degrades_to_recompute_fallback() {
        let (_d, mut s) = faulted_store(2, "shard0:die@0");
        s.set_retry_policy(2, 0.001);
        s.set_recompute_model(1e-4);
        let loaded = s.load_many(&[0, 1]).unwrap();
        let l = &loaded[0]; // id 0 routes to the dead shard 0
        assert!(l.recomputed, "dead shard must fall through to recompute: {l:?}");
        assert_eq!(l.retries, 2, "bounded retries are spent first");
        assert!((l.recompute_secs - 8.0 * 1e-4).abs() < 1e-12, "{}", l.recompute_secs);
        assert_eq!(l.device_secs, 0.0, "recompute never touches the device");
        assert_eq!(*l.chunk, chunk(0, 8), "the safety net serves the true KV");
        assert!(!loaded[1].recomputed, "shard 1 is healthy");
        // the dead shard's reads fail pre-queue: only shard 1 counted
        assert_eq!(s.stats.reads.load(Ordering::Relaxed), 1);
    }

    #[test]
    fn retry_knobs_without_a_plan_change_nothing() {
        // `--faults` off must be bit-identical to the pre-fault store,
        // whatever the retry knobs say (the bench pins the end-to-end
        // half of this; here the unit half).
        let (_d, mut s) = store();
        s.set_retry_policy(5, 0.5);
        s.set_recompute_model(1.0);
        s.store_sync(1, &chunk(1, 8)).unwrap();
        let l = s.load(1).unwrap();
        assert_eq!((l.retries, l.checksum_failures), (0, 0));
        assert_eq!(l.retry_backoff_secs, 0.0);
        assert!(!l.recomputed);
        assert_eq!(l.recompute_secs, 0.0);
        assert_eq!(s.stats.reads.load(Ordering::Relaxed), 1);
        assert!(s.faults().is_none());
    }

    #[test]
    fn stale_recompute_result_never_admitted_to_dram_tiers() {
        // The failover race: a chunk is re-materialized while its
        // recompute-fallback (or any fault-delayed miss) is in flight.
        // The fallback captured its tier generations before the original
        // read started; admission must bounce and the next load must
        // serve the new payload. Hot arm first:
        let (_d, s) = tiered_store(64 << 20);
        s.store_sync(1, &flat_chunk(127.0, 8)).unwrap();
        let hot_gen = s.hot_tier().unwrap().generation(1);
        let stale = Arc::new(flat_chunk(127.0, 8));
        s.store_sync(1, &flat_chunk(254.0, 8)).unwrap(); // invalidation lands mid-flight
        s.admit_miss(1, &stale, stale.file_bytes(KvFormat::V3), hot_gen, 0);
        assert!(!s.hot_tier().unwrap().contains(1), "stale hot admission must bounce");
        let l = s.load(1).unwrap();
        assert!(!l.from_cache);
        assert_eq!(l.chunk.k[0], 254.0, "fresh bytes win");

        // Warm arm (warm-only store takes the quantize_admit path):
        let (_d2, s) = warm_store(0, 64 << 20);
        s.store_sync(1, &flat_chunk(127.0, 8)).unwrap();
        let warm_gen = s.warm_tier().unwrap().generation(1);
        let stale = Arc::new(flat_chunk(127.0, 8));
        s.store_sync(1, &flat_chunk(254.0, 8)).unwrap();
        s.admit_miss(1, &stale, stale.file_bytes(KvFormat::V3), 0, warm_gen);
        assert!(!s.warm_tier().unwrap().contains(1), "stale warm admission must bounce");
        let l = s.load(1).unwrap();
        assert!(!l.from_cache && !l.from_warm);
        assert_eq!(l.chunk.k[0], 254.0);
    }

    #[test]
    fn torn_placement_tail_is_clean_eof() {
        let dir = crate::util::tempdir::TempDir::new("matkv-kvstore-torn").unwrap();
        {
            let mut s = KvStore::open_sharded(dir.path(), StorageProfile::dram(), 4).unwrap();
            s.disable_throttle();
            for i in 0..8u64 {
                s.store_sync(i, &chunk(i as u32, 8)).unwrap();
            }
        }
        let path = dir.path().join(PLACEMENT_LOG);
        let clean = std::fs::read_to_string(&path).unwrap();
        // a crash mid-append leaves a partial final record
        std::fs::write(&path, format!("{clean}99 1")).unwrap();
        let mut s = KvStore::open_sharded(dir.path(), StorageProfile::dram(), 4).unwrap();
        s.disable_throttle();
        // every complete record still replays and serves
        for i in 0..8u64 {
            assert_eq!(*s.load(i).unwrap().chunk, chunk(i as u32, 8));
        }
        // the torn id simply falls back to hash routing
        assert_eq!(s.shard_index_of(99), route(99, 4));
    }

    #[test]
    fn corrupt_mid_log_placement_record_rejected() {
        let dir = crate::util::tempdir::TempDir::new("matkv-kvstore-midrot").unwrap();
        {
            let mut s = KvStore::open_sharded(dir.path(), StorageProfile::dram(), 4).unwrap();
            s.disable_throttle();
            for i in 0..8u64 {
                s.store_sync(i, &chunk(i as u32, 8)).unwrap();
            }
        }
        let path = dir.path().join(PLACEMENT_LOG);
        let clean = std::fs::read_to_string(&path).unwrap();
        let mut lines: Vec<&str> = clean.lines().collect();
        // bit rot in the middle of the log is NOT a torn append —
        // replaying past it would silently mis-route every later id
        lines[2] = "zz zz";
        std::fs::write(&path, format!("{}\n", lines.join("\n"))).unwrap();
        let err = KvStore::open_sharded(dir.path(), StorageProfile::dram(), 4).unwrap_err();
        assert!(err.to_string().contains("corrupt"), "{err}");
    }
}
