//! File-backed materialized-KV store with write-behind, throttled loads,
//! and an optional DRAM hot tier ([`HotTier`]).
//!
//! Two on-disk formats share one header layout (8 little-endian u32
//! words: magic, version, config id, layers, kv-heads, seq, head dim,
//! reserved):
//!
//! * **v1** — K/V planes as f32 (the original format; still loads).
//! * **v2** — K/V planes as f16: half the flash bytes, half the
//!   simulated device-read seconds for the same chunk. The default
//!   write format; decode dispatches on the version word, so stores
//!   holding a mix of v1 and v2 files serve both transparently.

use std::path::{Path, PathBuf};
use std::sync::atomic::{AtomicU64, Ordering};
use std::sync::Arc;
use std::time::Instant;

use anyhow::{bail, Context, Result};

use super::cache::{HotTier, Probe};
use super::throttle::DeviceThrottle;
use crate::hwsim::StorageProfile;
use crate::manifest::ModelConfig;
use crate::util::aio::{IoPool, Pending};
use crate::util::half::{f16_bits_to_f32, f32_to_f16_bits};
use crate::vectordb::ChunkId;

const MAGIC: u32 = 0x4d41_544b; // "MATK"
const HEADER_BYTES: usize = 8 * 4;

/// On-disk plane encoding. The header's version word selects the
/// decoder; the store's configured format selects the encoder.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum KvFormat {
    /// f32 planes (version word 1).
    V1,
    /// f16 planes (version word 2) — half the bytes of v1.
    V2,
}

impl KvFormat {
    pub fn version(self) -> u32 {
        match self {
            KvFormat::V1 => 1,
            KvFormat::V2 => 2,
        }
    }

    /// Bytes per stored K/V element.
    pub fn elem_bytes(self) -> usize {
        match self {
            KvFormat::V1 => 4,
            KvFormat::V2 => 2,
        }
    }
}

/// One chunk's materialized KV tensors (host side).
///
/// `k`/`v` are `[n_layers, n_kv_heads, seq_len, head_dim]` f32,
/// row-major — the per-batch-element slice of the packed device cache, so
/// assembly into a serve-time cache is pure memcpy. In-memory planes are
/// always f32 regardless of the on-disk format.
#[derive(Debug, Clone, PartialEq)]
pub struct KvChunk {
    pub config_id: u32,
    pub n_layers: u32,
    pub n_kv_heads: u32,
    pub seq_len: u32,
    pub head_dim: u32,
    pub k: Vec<f32>,
    pub v: Vec<f32>,
}

impl KvChunk {
    pub fn plane_elems(&self) -> usize {
        self.n_layers as usize
            * self.n_kv_heads as usize
            * self.seq_len as usize
            * self.head_dim as usize
    }

    /// In-memory (f32 planes) footprint — also the v1 file size.
    pub fn total_bytes(&self) -> usize {
        HEADER_BYTES + 8 * self.plane_elems()
    }

    /// Resident bytes when held by the DRAM hot tier.
    pub fn dram_bytes(&self) -> usize {
        std::mem::size_of::<KvChunk>() + 8 * self.plane_elems()
    }

    /// On-disk size when encoded as `format`.
    pub fn file_bytes(&self, format: KvFormat) -> usize {
        HEADER_BYTES + 2 * format.elem_bytes() * self.plane_elems()
    }

    fn validate(&self) -> Result<()> {
        if self.k.len() != self.plane_elems() || self.v.len() != self.plane_elems() {
            bail!(
                "KvChunk plane size mismatch: k={} v={} expect={}",
                self.k.len(),
                self.v.len(),
                self.plane_elems()
            );
        }
        Ok(())
    }
}

/// Stable id for a model config (validated on load so a store produced by
/// one model is never spliced into another).
pub fn config_id(cfg: &ModelConfig) -> u32 {
    let mut h: u32 = 2166136261;
    for b in cfg.name.bytes() {
        h = (h ^ b as u32).wrapping_mul(16777619);
    }
    h ^= (cfg.n_layers as u32) << 24 ^ (cfg.n_kv_heads as u32) << 16 ^ cfg.head_dim as u32;
    h
}

/// Cumulative I/O counters (device reads/writes; hot-tier hits never
/// touch these — see [`super::CacheStats`]).
#[derive(Debug, Default)]
pub struct StoreStats {
    pub reads: AtomicU64,
    pub writes: AtomicU64,
    pub bytes_read: AtomicU64,
    pub bytes_written: AtomicU64,
    pub deletes: AtomicU64,
}

/// The store: one directory per (deployment, model config), fronted by
/// an optional byte-budgeted DRAM hot tier.
pub struct KvStore {
    dir: PathBuf,
    throttle: Arc<DeviceThrottle>,
    pool: IoPool,
    format: KvFormat,
    hot: Option<Arc<HotTier>>,
    pub stats: Arc<StoreStats>,
}

/// Result of a load: the chunk plus where it came from and what it cost.
#[derive(Debug)]
pub struct Loaded {
    pub chunk: Arc<KvChunk>,
    /// Simulated storage-device seconds (0 for hot-tier hits).
    pub device_secs: f64,
    /// Size of the chunk's on-disk file (for a hit: the read it avoided).
    pub file_bytes: usize,
    /// Served from the DRAM hot tier, no device read issued.
    pub from_cache: bool,
}

impl KvStore {
    /// Open (creating if needed) a store under `dir`, timed as `profile`.
    /// Writes default to the v2 (f16) format; no hot tier.
    pub fn open(dir: impl AsRef<Path>, profile: StorageProfile) -> Result<Self> {
        let dir = dir.as_ref().to_path_buf();
        std::fs::create_dir_all(&dir).with_context(|| format!("creating {dir:?}"))?;
        Ok(KvStore {
            dir,
            throttle: Arc::new(DeviceThrottle::new(profile)),
            pool: IoPool::new(4),
            format: KvFormat::V2,
            hot: None,
            stats: Arc::new(StoreStats::default()),
        })
    }

    /// Swap the simulated storage device (Table III sweeps this).
    pub fn set_profile(&mut self, profile: StorageProfile) {
        self.throttle = Arc::new(DeviceThrottle::new(profile));
    }

    /// Disable wall-clock throttling (pure-functional tests).
    pub fn disable_throttle(&mut self) {
        let profile = self.throttle.profile().clone();
        let mut t = DeviceThrottle::new(profile);
        t.enabled = false;
        self.throttle = Arc::new(t);
    }

    pub fn profile(&self) -> &StorageProfile {
        self.throttle.profile()
    }

    /// Select the on-disk format for subsequent writes (loads always
    /// accept both).
    pub fn set_format(&mut self, format: KvFormat) {
        self.format = format;
    }

    pub fn format(&self) -> KvFormat {
        self.format
    }

    /// Enable a DRAM hot tier of `budget_bytes` resident bytes
    /// (0 disables). Replacing the tier drops its contents.
    pub fn set_hot_tier(&mut self, budget_bytes: usize) {
        self.hot =
            if budget_bytes > 0 { Some(Arc::new(HotTier::new(budget_bytes))) } else { None };
    }

    pub fn hot_tier(&self) -> Option<&HotTier> {
        self.hot.as_deref()
    }

    /// On-disk size of `chunk` in the store's current write format.
    pub fn encoded_bytes(&self, chunk: &KvChunk) -> usize {
        chunk.file_bytes(self.format)
    }

    fn path_of(&self, id: ChunkId) -> PathBuf {
        self.dir.join(format!("{id:016x}.kv"))
    }

    pub fn contains(&self, id: ChunkId) -> bool {
        self.path_of(id).exists()
    }

    fn encode(chunk: &KvChunk, format: KvFormat) -> Vec<u8> {
        let plane = chunk.plane_elems();
        let mut buf = Vec::with_capacity(HEADER_BYTES + 2 * format.elem_bytes() * plane);
        for word in [
            MAGIC,
            format.version(),
            chunk.config_id,
            chunk.n_layers,
            chunk.n_kv_heads,
            chunk.seq_len,
            chunk.head_dim,
            0, // reserved
        ] {
            buf.extend_from_slice(&word.to_le_bytes());
        }
        for plane_data in [&chunk.k, &chunk.v] {
            match format {
                KvFormat::V1 => {
                    for &x in plane_data.iter() {
                        buf.extend_from_slice(&x.to_le_bytes());
                    }
                }
                KvFormat::V2 => {
                    for &x in plane_data.iter() {
                        buf.extend_from_slice(&f32_to_f16_bits(x).to_le_bytes());
                    }
                }
            }
        }
        buf
    }

    fn decode(data: &[u8]) -> Result<KvChunk> {
        if data.len() < HEADER_BYTES {
            bail!("KV file truncated: {} bytes", data.len());
        }
        let word = |i: usize| u32::from_le_bytes(data[i * 4..i * 4 + 4].try_into().unwrap());
        if word(0) != MAGIC {
            bail!("bad KV magic {:#x}", word(0));
        }
        let format = match word(1) {
            1 => KvFormat::V1,
            2 => KvFormat::V2,
            v => bail!("unsupported KV version {v}"),
        };
        // Header dimensions are untrusted: all size math is checked so a
        // corrupt/adversarial header can never wrap and pass the size
        // check (u32 products overflow u32 and even u64 at the extremes).
        let plane_u64 = [word(3), word(4), word(5), word(6)]
            .into_iter()
            .try_fold(1u64, |acc, w| acc.checked_mul(w as u64))
            .context("KV header dimensions overflow")?;
        let elem_bytes = format.elem_bytes() as u64;
        let expected = plane_u64
            .checked_mul(2 * elem_bytes)
            .and_then(|b| b.checked_add(HEADER_BYTES as u64))
            .context("KV header dimensions overflow")?;
        if data.len() as u64 != expected {
            bail!("KV file size mismatch: {} vs {expected}", data.len());
        }
        let plane = plane_u64 as usize; // fits: expected == data.len()
        let floats = |idx: usize| -> Vec<f32> {
            let off = HEADER_BYTES + idx * plane * elem_bytes as usize;
            let src = &data[off..off + plane * elem_bytes as usize];
            match format {
                KvFormat::V1 => src
                    .chunks_exact(4)
                    .map(|b| f32::from_le_bytes(b.try_into().unwrap()))
                    .collect(),
                KvFormat::V2 => src
                    .chunks_exact(2)
                    .map(|b| f16_bits_to_f32(u16::from_le_bytes(b.try_into().unwrap())))
                    .collect(),
            }
        };
        Ok(KvChunk {
            config_id: word(2),
            n_layers: word(3),
            n_kv_heads: word(4),
            seq_len: word(5),
            head_dim: word(6),
            k: floats(0),
            v: floats(1),
        })
    }

    /// Synchronous materialization (throttled to the device profile).
    ///
    /// The hot tier is invalidated on *both* sides of the write: the
    /// first pass drops the resident copy, the second (generation bump)
    /// rejects any concurrent load that read the superseded file while
    /// the write was in flight — the tier never serves a stale KV.
    pub fn store_sync(&self, id: ChunkId, chunk: &KvChunk) -> Result<f64> {
        chunk.validate()?;
        if let Some(hot) = &self.hot {
            hot.invalidate(id);
        }
        let buf = Self::encode(chunk, self.format);
        let start = Instant::now();
        std::fs::write(self.path_of(id), &buf)?;
        let secs = self.throttle.charge_write(buf.len(), start.elapsed());
        if let Some(hot) = &self.hot {
            hot.invalidate(id);
        }
        self.stats.writes.fetch_add(1, Ordering::Relaxed);
        self.stats.bytes_written.fetch_add(buf.len() as u64, Ordering::Relaxed);
        Ok(secs)
    }

    /// Write-behind materialization: returns immediately, the write runs
    /// on the store's I/O pool (the role DeepNVMe's async_io plays in the
    /// paper's prototype). Wait on the handle (or [`KvStore::drain`]) to
    /// observe errors and the simulated device seconds. Invalid chunks
    /// and I/O failures surface as `Err` through the handle — never a
    /// panic — and failed writes are not counted in [`StoreStats`].
    pub fn store_async(&self, id: ChunkId, chunk: KvChunk) -> Pending<Result<f64>> {
        if let Err(e) = chunk.validate() {
            return self.pool.submit(move || Err(e));
        }
        if let Some(hot) = &self.hot {
            hot.invalidate(id);
        }
        let path = self.path_of(id);
        let throttle = self.throttle.clone();
        let stats = self.stats.clone();
        let hot = self.hot.clone();
        let buf = Self::encode(&chunk, self.format);
        self.pool.submit(move || {
            let start = Instant::now();
            std::fs::write(&path, &buf)?;
            let secs = throttle.charge_write(buf.len(), start.elapsed());
            // Second invalidation once the write landed: a load that
            // raced the write and read the old bytes can no longer keep
            // or re-admit them (see store_sync).
            if let Some(hot) = &hot {
                hot.invalidate(id);
            }
            // Accounting happens only once the write actually landed.
            stats.writes.fetch_add(1, Ordering::Relaxed);
            stats.bytes_written.fetch_add(buf.len() as u64, Ordering::Relaxed);
            Ok(secs)
        })
    }

    /// Block until previously spawned async writes have finished; returns
    /// the total simulated device-write seconds.
    pub fn drain(&self, handles: Vec<Pending<Result<f64>>>) -> Result<f64> {
        let mut total = 0.0;
        for h in handles {
            total += h.wait()?;
        }
        Ok(total)
    }

    /// Load one chunk: hot tier first (free), then the throttled device.
    pub fn load(&self, id: ChunkId) -> Result<Loaded> {
        let mut loaded = self.load_many(std::slice::from_ref(&id))?;
        Ok(loaded.pop().expect("load_many returns one Loaded per id"))
    }

    /// Load many chunks concurrently. Hot-tier hits are answered inline;
    /// misses go through the I/O pool (and still serialize on the
    /// simulated device, like real parallel reads of one SSD). Output
    /// order matches `ids`.
    pub fn load_many(&self, ids: &[ChunkId]) -> Result<Vec<Loaded>> {
        enum Slot {
            Hit(Loaded),
            /// A device read plus the id's invalidation generation,
            /// captured before the read could start: if a write/delete
            /// races this load, the stale bytes are not cached.
            Miss(u64, Pending<Result<(Vec<u8>, f64)>>),
        }
        let slots: Vec<Slot> = ids
            .iter()
            .map(|&id| {
                let mut gen = 0;
                if let Some(hot) = &self.hot {
                    match hot.probe(id) {
                        Probe::Hit(chunk, file_bytes) => {
                            return Slot::Hit(Loaded {
                                chunk,
                                device_secs: 0.0,
                                file_bytes,
                                from_cache: true,
                            });
                        }
                        Probe::Miss(g) => gen = g,
                    }
                }
                let path = self.path_of(id);
                let throttle = self.throttle.clone();
                Slot::Miss(
                    gen,
                    self.pool.submit(move || {
                        let start = Instant::now();
                        let data =
                            std::fs::read(&path).with_context(|| format!("loading KV {path:?}"))?;
                        let device_secs = throttle.charge_read(data.len(), start.elapsed());
                        Ok((data, device_secs))
                    }),
                )
            })
            .collect();
        let mut out = Vec::with_capacity(ids.len());
        for (slot, &id) in slots.into_iter().zip(ids) {
            match slot {
                Slot::Hit(l) => out.push(l),
                Slot::Miss(gen, h) => {
                    let (data, device_secs) = h.wait()?;
                    self.stats.reads.fetch_add(1, Ordering::Relaxed);
                    self.stats.bytes_read.fetch_add(data.len() as u64, Ordering::Relaxed);
                    let chunk = Arc::new(Self::decode(&data)?);
                    if let Some(hot) = &self.hot {
                        hot.insert_at(id, chunk.clone(), data.len(), gen);
                    }
                    out.push(Loaded {
                        chunk,
                        device_secs,
                        file_bytes: data.len(),
                        from_cache: false,
                    });
                }
            }
        }
        Ok(out)
    }

    /// Delete a chunk's materialized KV (vector-DB delete path). Like
    /// the write paths, the hot tier is invalidated around the unlink so
    /// a racing load can't resurrect the deleted chunk in DRAM.
    pub fn delete(&self, id: ChunkId) -> Result<bool> {
        if let Some(hot) = &self.hot {
            hot.invalidate(id);
        }
        match std::fs::remove_file(self.path_of(id)) {
            Ok(()) => {
                if let Some(hot) = &self.hot {
                    hot.invalidate(id);
                }
                self.stats.deletes.fetch_add(1, Ordering::Relaxed);
                Ok(true)
            }
            Err(e) if e.kind() == std::io::ErrorKind::NotFound => Ok(false),
            Err(e) => Err(e.into()),
        }
    }

    /// Number of materialized chunks on disk.
    pub fn len(&self) -> Result<usize> {
        Ok(std::fs::read_dir(&self.dir)?
            .filter_map(|e| e.ok())
            .filter(|e| e.path().extension().is_some_and(|x| x == "kv"))
            .count())
    }

    pub fn is_empty(&self) -> Result<bool> {
        Ok(self.len()? == 0)
    }

    /// Total bytes of materialized KV on disk (TCO accounting).
    pub fn bytes_on_disk(&self) -> Result<u64> {
        let mut total = 0;
        for e in std::fs::read_dir(&self.dir)? {
            let e = e?;
            if e.path().extension().is_some_and(|x| x == "kv") {
                total += e.metadata()?.len();
            }
        }
        Ok(total)
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::workload::{Rng, Zipf};

    fn chunk(seed: u32, seq: u32) -> KvChunk {
        let plane = (2 * 2 * seq * 4) as usize;
        KvChunk {
            config_id: 0xabcd,
            n_layers: 2,
            n_kv_heads: 2,
            seq_len: seq,
            head_dim: 4,
            // Integer payloads (<= 2048) survive the f16 format exactly.
            k: (0..plane).map(|i| (i as f32) + seed as f32).collect(),
            v: (0..plane).map(|i| -(i as f32) - seed as f32).collect(),
        }
    }

    fn store() -> (crate::util::tempdir::TempDir, KvStore) {
        let dir = crate::util::tempdir::TempDir::new("matkv-kvstore-test").unwrap();
        let mut s = KvStore::open(dir.path(), StorageProfile::dram()).unwrap();
        s.disable_throttle();
        (dir, s)
    }

    #[test]
    fn roundtrip() {
        let (_d, s) = store();
        let c = chunk(7, 16);
        s.store_sync(42, &c).unwrap();
        let loaded = s.load(42).unwrap();
        assert_eq!(*loaded.chunk, c);
        assert!(!loaded.from_cache);
        assert_eq!(loaded.file_bytes, s.encoded_bytes(&c));
    }

    #[test]
    fn async_write_behind_roundtrip() {
        let (_d, s) = store();
        let c = chunk(9, 8);
        let h = s.store_async(7, c.clone());
        s.drain(vec![h]).unwrap();
        assert_eq!(*s.load(7).unwrap().chunk, c);
    }

    #[test]
    fn load_many_preserves_order() {
        let (_d, s) = store();
        for i in 0..5u64 {
            s.store_sync(i, &chunk(i as u32, 8)).unwrap();
        }
        let loaded = s.load_many(&[3, 1, 4]).unwrap();
        assert_eq!(loaded[0].chunk.k[0], chunk(3, 8).k[0]);
        assert_eq!(loaded[1].chunk.k[0], chunk(1, 8).k[0]);
        assert_eq!(loaded[2].chunk.k[0], chunk(4, 8).k[0]);
    }

    #[test]
    fn delete_and_contains() {
        let (_d, s) = store();
        s.store_sync(1, &chunk(1, 8)).unwrap();
        assert!(s.contains(1));
        assert!(s.delete(1).unwrap());
        assert!(!s.contains(1));
        assert!(!s.delete(1).unwrap());
        assert!(s.load(1).is_err());
    }

    #[test]
    fn corrupt_file_rejected() {
        let (_d, s) = store();
        s.store_sync(5, &chunk(5, 8)).unwrap();
        // truncate
        let path = s.path_of(5);
        let data = std::fs::read(&path).unwrap();
        std::fs::write(&path, &data[..data.len() / 2]).unwrap();
        assert!(s.load(5).is_err());
        // bad magic
        let mut bad = data.clone();
        bad[0] ^= 0xff;
        std::fs::write(&path, &bad).unwrap();
        assert!(s.load(5).is_err());
        // unknown version
        let mut bad = data.clone();
        bad[4] = 9;
        std::fs::write(&path, &bad).unwrap();
        assert!(s.load(5).is_err());
    }

    #[test]
    fn corrupt_header_rejected_without_overflow() {
        // Adversarial dims whose u32 product wraps to 0: a 32-byte file
        // would pass an unchecked size check while claiming 2^16 layers.
        let (_d, s) = store();
        let mut buf = Vec::new();
        for word in [MAGIC, 1u32, 0xabcd, 0x1_0000, 0x1_0000, 1, 1, 0] {
            buf.extend_from_slice(&word.to_le_bytes());
        }
        std::fs::write(s.path_of(66), &buf).unwrap();
        let err = s.load(66).unwrap_err();
        let msg = err.to_string();
        assert!(msg.contains("mismatch") || msg.contains("overflow"), "{msg}");

        // Dims that overflow even u64 must hit the checked-math bail.
        let mut buf = Vec::new();
        for word in [MAGIC, 2u32, 0xabcd, u32::MAX, u32::MAX, u32::MAX, u32::MAX, 0] {
            buf.extend_from_slice(&word.to_le_bytes());
        }
        std::fs::write(s.path_of(67), &buf).unwrap();
        let err = s.load(67).unwrap_err();
        assert!(err.to_string().contains("overflow"), "{err}");
    }

    #[test]
    fn store_async_invalid_chunk_errors_not_panics() {
        let (_d, s) = store();
        let mut c = chunk(1, 8);
        c.k.pop(); // plane mismatch
        let h = s.store_async(3, c);
        assert!(h.wait().is_err());
        assert!(!s.contains(3));
        assert_eq!(s.stats.writes.load(Ordering::Relaxed), 0);
    }

    #[test]
    fn failed_async_write_not_counted() {
        let dir = crate::util::tempdir::TempDir::new("matkv-kvstore-fail").unwrap();
        let sub = dir.path().join("kv");
        let mut s = KvStore::open(&sub, StorageProfile::dram()).unwrap();
        s.disable_throttle();
        std::fs::remove_dir_all(&sub).unwrap(); // make every write fail
        let h = s.store_async(1, chunk(1, 8));
        assert!(h.wait().is_err());
        assert_eq!(s.stats.writes.load(Ordering::Relaxed), 0);
        assert_eq!(s.stats.bytes_written.load(Ordering::Relaxed), 0);
    }

    #[test]
    fn v1_files_still_load() {
        let dir = crate::util::tempdir::TempDir::new("matkv-kvstore-v1").unwrap();
        let mut writer = KvStore::open(dir.path(), StorageProfile::dram()).unwrap();
        writer.disable_throttle();
        writer.set_format(KvFormat::V1);
        // fractional payload: would NOT survive f16, so exact equality
        // proves the v1 decode path ran losslessly.
        let mut c = chunk(3, 8);
        for x in c.k.iter_mut().chain(c.v.iter_mut()) {
            *x += 0.123_456_7;
        }
        writer.store_sync(11, &c).unwrap();
        assert_eq!(writer.encoded_bytes(&c), c.total_bytes());

        let mut reader = KvStore::open(dir.path(), StorageProfile::dram()).unwrap();
        reader.disable_throttle();
        assert_eq!(reader.format(), KvFormat::V2); // default is v2...
        assert_eq!(*reader.load(11).unwrap().chunk, c); // ...yet v1 loads
    }

    #[test]
    fn v2_files_half_the_bytes() {
        let c = chunk(1, 32);
        let v1 = KvStore::encode(&c, KvFormat::V1).len();
        let v2 = KvStore::encode(&c, KvFormat::V2).len();
        assert_eq!(v1, c.total_bytes());
        assert_eq!(v2, c.file_bytes(KvFormat::V2));
        let ratio = v2 as f64 / v1 as f64;
        assert!(ratio < 0.55, "v2/v1 = {ratio}");

        let (_d, s) = store();
        s.store_sync(1, &c).unwrap();
        assert_eq!(s.bytes_on_disk().unwrap(), v2 as u64);
    }

    #[test]
    fn v2_quantization_error_bounded() {
        let (_d, s) = store();
        let mut c = chunk(0, 8);
        for (i, x) in c.k.iter_mut().enumerate() {
            *x = (i as f32 + 0.321).sin() * 3.7;
        }
        s.store_sync(8, &c).unwrap();
        let loaded = s.load(8).unwrap();
        for (a, b) in c.k.iter().zip(&loaded.chunk.k) {
            assert!((a - b).abs() <= a.abs() / 1024.0 + 1e-6, "{a} vs {b}");
        }
    }

    #[test]
    fn stats_accumulate() {
        let (_d, s) = store();
        let c = chunk(1, 8);
        let file = s.encoded_bytes(&c) as u64;
        s.store_sync(1, &c).unwrap();
        s.load(1).unwrap();
        s.load(1).unwrap();
        assert_eq!(s.stats.reads.load(Ordering::Relaxed), 2);
        assert_eq!(s.stats.writes.load(Ordering::Relaxed), 1);
        assert_eq!(s.stats.bytes_read.load(Ordering::Relaxed), 2 * file);
        assert_eq!(s.len().unwrap(), 1);
        assert_eq!(s.bytes_on_disk().unwrap(), file);
    }

    #[test]
    fn throttled_load_is_slower() {
        let dir = crate::util::tempdir::TempDir::new("matkv-kvstore-thr").unwrap();
        let slow = StorageProfile {
            name: "slow".into(),
            read_bw: 50e6,
            write_bw: 1e12,
            latency_s: 0.0,
            power_active: 1.0,
            power_idle: 0.0,
            usd_per_byte: 0.0,
        };
        let s = KvStore::open(dir.path(), slow).unwrap();
        let c = chunk(1, 256);
        s.store_sync(1, &c).unwrap();
        let loaded = s.load(1).unwrap();
        let expect = s.encoded_bytes(&c) as f64 / 50e6;
        assert!((loaded.device_secs - expect).abs() / expect < 0.3);
    }

    #[test]
    fn size_validation() {
        let mut c = chunk(1, 8);
        c.k.pop();
        let (_d, s) = store();
        assert!(s.store_sync(1, &c).is_err());
    }

    // --- hot tier -------------------------------------------------------

    fn tiered_store(budget: usize) -> (crate::util::tempdir::TempDir, KvStore) {
        let dir = crate::util::tempdir::TempDir::new("matkv-kvstore-tier").unwrap();
        let mut s = KvStore::open(dir.path(), StorageProfile::ssd_9100pro()).unwrap();
        s.disable_throttle(); // device_secs still computed, just no sleep
        s.set_hot_tier(budget);
        (dir, s)
    }

    #[test]
    fn hot_tier_hit_skips_device() {
        let (_d, s) = tiered_store(64 << 20);
        let c = chunk(2, 16);
        s.store_sync(5, &c).unwrap();
        let cold = s.load(5).unwrap();
        assert!(!cold.from_cache);
        assert!(cold.device_secs > 0.0);
        let warm = s.load(5).unwrap();
        assert!(warm.from_cache);
        assert_eq!(warm.device_secs, 0.0);
        assert_eq!(*warm.chunk, *cold.chunk);
        // only the miss touched the device
        assert_eq!(s.stats.reads.load(Ordering::Relaxed), 1);
        let tier = s.hot_tier().unwrap();
        assert_eq!(tier.stats.hits.load(Ordering::Relaxed), 1);
        assert_eq!(tier.stats.bytes_saved.load(Ordering::Relaxed), cold.file_bytes as u64);
    }

    #[test]
    fn load_many_mixes_hits_and_misses_in_order() {
        let (_d, s) = tiered_store(64 << 20);
        for i in 0..4u64 {
            s.store_sync(i, &chunk(i as u32, 8)).unwrap();
        }
        s.load(1).unwrap(); // warm id 1
        let loaded = s.load_many(&[0, 1, 2]).unwrap();
        assert!(!loaded[0].from_cache);
        assert!(loaded[1].from_cache);
        assert!(!loaded[2].from_cache);
        for (l, want) in loaded.iter().zip([0u32, 1, 2]) {
            assert_eq!(l.chunk.k[0], chunk(want, 8).k[0]);
        }
        // a second pass is all hits
        assert!(s.load_many(&[0, 1, 2]).unwrap().iter().all(|l| l.from_cache));
    }

    #[test]
    fn writes_and_deletes_invalidate_hot_tier() {
        let (_d, s) = tiered_store(64 << 20);
        s.store_sync(1, &chunk(1, 8)).unwrap();
        s.load(1).unwrap();
        assert!(s.load(1).unwrap().from_cache);
        // re-materialize: the next load must see the new payload
        s.store_sync(1, &chunk(50, 8)).unwrap();
        let l = s.load(1).unwrap();
        assert!(!l.from_cache);
        assert_eq!(l.chunk.k[0], 50.0);
        // delete: no stale hit either
        s.delete(1).unwrap();
        assert!(s.load(1).is_err());
    }

    #[test]
    fn top_decile_tier_absorbs_zipf_mass() {
        // Acceptance shape: a hot tier holding ~10% of the corpus under
        // Zipf(1.0) access serves a large fraction of loads from DRAM
        // and strictly beats the cold store on simulated device time.
        let n = 100u64;
        let per_chunk = chunk(0, 8).dram_bytes();
        let (_d, hot) = tiered_store(10 * per_chunk);
        let (_d2, cold) = {
            let dir = crate::util::tempdir::TempDir::new("matkv-kvstore-cold").unwrap();
            let mut s = KvStore::open(dir.path(), StorageProfile::ssd_9100pro()).unwrap();
            s.disable_throttle();
            (dir, s)
        };
        for i in 0..n {
            hot.store_sync(i, &chunk(i as u32, 8)).unwrap();
            cold.store_sync(i, &chunk(i as u32, 8)).unwrap();
        }
        let zipf = Zipf::new(n as usize, 1.0);
        let mut rng = Rng::new(42);
        let ids: Vec<u64> = (0..2000).map(|_| zipf.sample(&mut rng) as u64).collect();
        let (mut hot_secs, mut cold_secs, mut hits) = (0.0, 0.0, 0u64);
        for &id in &ids {
            let l = hot.load(id).unwrap();
            hot_secs += l.device_secs;
            hits += l.from_cache as u64;
            cold_secs += cold.load(id).unwrap().device_secs;
        }
        let ratio = hits as f64 / ids.len() as f64;
        assert!(ratio > 0.3, "hit ratio {ratio}");
        assert!(hot_secs < cold_secs, "{hot_secs} vs {cold_secs}");
    }
}
