//! The DRAM **warm tier**: a byte-budgeted LRU of quantized chunks
//! between the f32 hot tier and the simulated flash.
//!
//! MatKV's core trade — recompute vs. storage — recurs *inside* DRAM: a
//! q8 plane ([`super::quant`]) costs ~4x fewer resident bytes than the
//! hot tier's f32 copy, so at equal total DRAM budget a hot+warm
//! hierarchy keeps strictly more chunks off the flash device than hot
//! alone ("LLM in a flash" / kv-cache-tier style). The price is paid in
//! compute and fidelity instead of bytes: a warm hit must dequantize
//! (charged a modeled cost, [`crate::hwsim::profiles::q8_dequant_secs`])
//! and serves planes with bounded quantization error (measured by the
//! table-VI fidelity harness, `benches/fig_warm_tier.rs`).
//!
//! The codec is selectable ([`WarmMode`], `--warm-mode q8|q4`): q4 mode
//! packs ~8x fewer resident bytes than f32 — twice the reach of q8 per
//! DRAM dollar — at a coarser error bound (max|plane|/14 vs /254) and a
//! slower modeled dequant pass per payload byte
//! ([`crate::hwsim::profiles::q4_dequant_secs`]). The mode picks the
//! codec for *future* admissions; entries already resident keep the
//! codec they were quantized with ([`WarmPayload`] carries it per
//! entry), so a mid-run switch never reinterprets parked bytes.
//!
//! Placement in the hierarchy is **exclusive**: chunks enter the warm
//! tier by *demotion* — the hot tier's budget evictions land here via
//! [`DemoteSink`] instead of being dropped — and leave it by *promotion*:
//! a warm hit on a store with a hot tier dequantizes, removes the q8
//! copy, and re-admits the f32 chunk to the hot tier, so no chunk is
//! double-resident. Without a hot tier (warm-only stores) the tier acts
//! as the first-level cache: misses admit quantized copies directly and
//! hits serve in place.
//!
//! Invalidation reuses the hot tier's generation-guard scheme
//! ([`WarmTier::generation`] / [`WarmTier::admit`] with a seen
//! generation). Demotions are guarded too: the generation is snapshotted
//! *inside* the hot tier's eviction critical section
//! ([`DemoteSink::prepare`]) — where every writer's hot-then-warm
//! invalidation order pins it fresh — while the O(plane) quantize+admit
//! runs after the hot lock is released, so demotion cost never
//! serializes the serve path's hot-tier probes.
//!
//! [`DemoteSink`]: super::cache::DemoteSink

use std::collections::{BTreeMap, HashMap};
use std::sync::atomic::{AtomicBool, Ordering};
use std::sync::{Arc, Mutex};

use super::cache::{CacheStats, DemoteSink, TierKind, TierMetrics};
use super::quant::{self, Q4Chunk, QuantChunk};
use super::store::KvChunk;
use crate::hwsim::{Link, TrafficClass};
use crate::trace::{Arg, TraceBus};
use crate::vectordb::ChunkId;

/// Which codec the warm tier quantizes *new* admissions with
/// (`--warm-mode q8|q4`). Resident entries keep their own codec.
#[derive(Debug, Clone, Copy, Default, PartialEq, Eq)]
pub enum WarmMode {
    /// ~4x fewer resident bytes than f32; error ≤ max|plane|/254.
    #[default]
    Q8,
    /// ~8x fewer resident bytes than f32; error ≤ max|plane|/14 and a
    /// slower modeled dequant per payload byte — the cool-path dial
    /// turned one level further.
    Q4,
}

impl WarmMode {
    /// CLI / report label (`"q8"` / `"q4"`).
    pub fn label(self) -> &'static str {
        match self {
            WarmMode::Q8 => "q8",
            WarmMode::Q4 => "q4",
        }
    }
}

/// A resident warm entry's quantized planes, tagged with the codec that
/// produced them. Cloning is cheap (`Arc` payloads).
#[derive(Clone)]
pub enum WarmPayload {
    Q8(Arc<QuantChunk>),
    Q4(Arc<Q4Chunk>),
}

impl WarmPayload {
    /// Which codec these planes are packed with.
    pub fn mode(&self) -> WarmMode {
        match self {
            WarmPayload::Q8(_) => WarmMode::Q8,
            WarmPayload::Q4(_) => WarmMode::Q4,
        }
    }

    /// Resident DRAM bytes charged against the tier budget.
    pub fn resident_bytes(&self) -> usize {
        match self {
            WarmPayload::Q8(q) => q.dram_bytes(),
            WarmPayload::Q4(q) => q.dram_bytes(),
        }
    }

    /// Packed payload bytes (scales + quantized planes) — what a
    /// promote's dequant pass moves across the host bus, and the byte
    /// count its modeled cost is priced on.
    pub fn quantized_bytes(&self) -> usize {
        match self {
            WarmPayload::Q8(q) => q.q8_bytes(),
            WarmPayload::Q4(q) => q.q4_bytes(),
        }
    }

    /// DRAM footprint of the reconstructed f32 chunk (the promote-to-hot
    /// admission cost).
    pub fn f32_dram_bytes(&self) -> usize {
        match self {
            WarmPayload::Q8(q) => q.f32_dram_bytes(),
            WarmPayload::Q4(q) => q.f32_dram_bytes(),
        }
    }

    /// Reconstruct the f32 chunk (the real compute a hit performs).
    pub fn dequantize(&self) -> KvChunk {
        match self {
            WarmPayload::Q8(q) => quant::dequantize(q),
            WarmPayload::Q4(q) => quant::dequantize_q4(q),
        }
    }

    /// Modeled seconds a hit on this payload pays to dequantize it —
    /// priced per *payload* byte by the matching profile constant, so
    /// the q4 codec's fewer bytes and slower per-byte unpack both show.
    pub fn dequant_secs(&self) -> f64 {
        match self {
            WarmPayload::Q8(q) => crate::hwsim::profiles::q8_dequant_secs(q.q8_bytes() as f64),
            WarmPayload::Q4(q) => crate::hwsim::profiles::q4_dequant_secs(q.q4_bytes() as f64),
        }
    }
}

struct WarmEntry {
    payload: WarmPayload,
    /// Size of the backing flash file (what a hit avoids reading).
    file_bytes: usize,
    /// Resident quantized bytes charged against the budget.
    cost: usize,
    /// Recency stamp; key into `WarmLru::order`.
    tick: u64,
    /// Admission class carried over from the hot tier: a still-unread
    /// prefetched chunk keeps that status through demotion, so the first
    /// demand hit — wherever it lands — still counts as a prefetch
    /// conversion in the stats.
    prefetched: bool,
}

#[derive(Default)]
struct WarmLru {
    map: HashMap<ChunkId, WarmEntry>,
    /// tick → id, oldest first (ticks unique: one logical clock).
    order: BTreeMap<u64, ChunkId>,
    /// Per-id invalidation generation (same scheme as the hot tier).
    gens: HashMap<ChunkId, u64>,
    bytes: usize,
    clock: u64,
}

/// Outcome of a [`WarmTier::probe`].
pub enum WarmProbe {
    /// Resident: the quantized planes (codec-tagged), the flash bytes
    /// the hit avoided, and whether the entry was admitted by a
    /// prefetch and never read.
    Hit { payload: WarmPayload, file_bytes: usize, prefetched: bool },
    /// Not resident: the id's current invalidation generation (to pass
    /// back to [`WarmTier::admit`] after a device read).
    Miss(u64),
}

/// The quantized warm tier: an LRU map `ChunkId → WarmPayload` holding
/// at most `budget` resident bytes. Unlike the hot tier there are no
/// protection classes — the warm tier is a victim cache, and everything
/// in it is already one demotion away from free.
pub struct WarmTier {
    budget: usize,
    lru: Mutex<WarmLru>,
    /// Shared host-side bus quantize traffic crosses on its way into
    /// the tier ([`TrafficClass::Demotion`]); `None` (standalone tiers,
    /// unit tests) keeps the pre-interconnect accounting exactly.
    bus: Option<Arc<Link>>,
    /// Codec for future admissions ([`WarmMode`]); atomic so the
    /// `--warm-mode` knob works after the tier is shared via `Arc`.
    q4_mode: AtomicBool,
    /// Trace handle (disabled by default; the store wires it). Only the
    /// admission/eviction paths emit — probes stay untouched.
    trace: Mutex<TraceBus>,
    pub stats: CacheStats,
}

impl WarmTier {
    pub fn new(budget_bytes: usize) -> Self {
        WarmTier {
            budget: budget_bytes,
            lru: Mutex::new(WarmLru::default()),
            bus: None,
            q4_mode: AtomicBool::new(false),
            trace: Mutex::new(TraceBus::disabled()),
            stats: CacheStats::for_tier(TierKind::Warm),
        }
    }

    /// Attach a trace bus; quantize-admission and eviction marks land
    /// on the `tier:warm` track.
    pub fn set_trace(&self, trace: TraceBus) {
        *self.trace.lock().unwrap() = trace;
    }

    /// Select the codec for future admissions (`--warm-mode q8|q4`).
    /// Entries already resident keep the codec they were packed with.
    pub fn set_mode(&self, mode: WarmMode) {
        self.q4_mode.store(mode == WarmMode::Q4, Ordering::Relaxed);
    }

    /// The codec new admissions will be quantized with.
    pub fn mode(&self) -> WarmMode {
        if self.q4_mode.load(Ordering::Relaxed) {
            WarmMode::Q4
        } else {
            WarmMode::Q8
        }
    }

    /// Wire the tier to the store's shared host bus: every quantize
    /// pass then reserves its modeled seconds there, so demotions
    /// contend with promotions (and each other) instead of being free
    /// of queueing. Charge magnitudes are unchanged — the bus only adds
    /// the queued-time telemetry ([`CacheStats::link_queued_secs`]).
    pub fn set_bus(&mut self, bus: Arc<Link>) {
        self.bus = Some(bus);
    }

    pub fn budget(&self) -> usize {
        self.budget
    }

    /// Resident q8 bytes currently held.
    pub fn bytes(&self) -> usize {
        self.lru.lock().unwrap().bytes
    }

    /// Number of resident chunks.
    pub fn len(&self) -> usize {
        self.lru.lock().unwrap().map.len()
    }

    pub fn is_empty(&self) -> bool {
        self.len() == 0
    }

    /// Residency check with no side effects (no stat bump, no LRU
    /// promotion) — the prefetcher's "is it already in DRAM?" test.
    pub fn contains(&self, id: ChunkId) -> bool {
        self.lru.lock().unwrap().map.contains_key(&id)
    }

    /// Snapshot of resident chunk ids, no side effects. The scheduler's
    /// tier-affinity policy scores these at a discount against hot
    /// residents: a warm hit still avoids the device read but pays the
    /// dequant pass.
    pub fn resident_ids(&self) -> Vec<ChunkId> {
        self.lru.lock().unwrap().map.keys().copied().collect()
    }

    /// Current invalidation generation of `id` (see
    /// [`super::HotTier::generation`] — same contract).
    pub fn generation(&self, id: ChunkId) -> u64 {
        self.lru.lock().unwrap().gens.get(&id).copied().unwrap_or(0)
    }

    /// Drop `id` and advance its generation. Writers/deleters call this
    /// on both sides of the file mutation, after the hot tier's
    /// invalidation (lock order hot → warm keeps demotions safe).
    pub fn invalidate(&self, id: ChunkId) {
        let mut guard = self.lru.lock().unwrap();
        let lru = &mut *guard;
        *lru.gens.entry(id).or_insert(0) += 1;
        if let Some(e) = lru.map.remove(&id) {
            lru.order.remove(&e.tick);
            lru.bytes -= e.cost;
        }
    }

    /// Look up a chunk. A hit bumps the hit/bytes-saved counters and
    /// either **takes** the entry out of the tier — the promote-to-hot
    /// path: the caller re-admits the dequantized f32 chunk to the hot
    /// tier, keeping placement exclusive — or touches it to
    /// most-recently-used in place. `promote_budget` is the hot tier's
    /// byte budget (`None` in warm-only stores): the entry is taken
    /// only when its *reconstructed f32* footprint fits, so a chunk the
    /// hot tier could never admit keeps serving from the warm tier
    /// instead of evicting itself on every hit. A miss reports the id's
    /// invalidation generation for a later gen-guarded
    /// [`WarmTier::admit`].
    pub fn probe(&self, id: ChunkId, promote_budget: Option<usize>) -> WarmProbe {
        let mut guard = self.lru.lock().unwrap();
        let lru = &mut *guard;
        let gen = lru.gens.get(&id).copied().unwrap_or(0);
        let Some(entry) = lru.map.get(&id) else {
            self.stats.misses.fetch_add(1, Ordering::Relaxed);
            return WarmProbe::Miss(gen);
        };
        let take = promote_budget.is_some_and(|b| entry.payload.f32_dram_bytes() <= b);
        self.stats.hits.fetch_add(1, Ordering::Relaxed);
        if take {
            let e = lru.map.remove(&id).expect("presence checked");
            lru.order.remove(&e.tick);
            lru.bytes -= e.cost;
            self.stats.bytes_saved.fetch_add(e.file_bytes as u64, Ordering::Relaxed);
            if e.prefetched {
                self.stats.prefetch_hits.fetch_add(1, Ordering::Relaxed);
            }
            WarmProbe::Hit { payload: e.payload, file_bytes: e.file_bytes, prefetched: e.prefetched }
        } else {
            lru.clock += 1;
            let tick = lru.clock;
            let e = lru.map.get_mut(&id).expect("presence checked");
            let old_tick = std::mem::replace(&mut e.tick, tick);
            let was_prefetched = std::mem::take(&mut e.prefetched);
            let (payload, file_bytes) = (e.payload.clone(), e.file_bytes);
            lru.order.remove(&old_tick);
            lru.order.insert(tick, id);
            self.stats.bytes_saved.fetch_add(file_bytes as u64, Ordering::Relaxed);
            if was_prefetched {
                self.stats.prefetch_hits.fetch_add(1, Ordering::Relaxed);
            }
            WarmProbe::Hit { payload, file_bytes, prefetched: was_prefetched }
        }
    }

    /// Quantize `chunk` with the current [`WarmMode`] codec, charge the
    /// modeled quantize pass (symmetric to the dequant a later hit
    /// pays) to this tier's clock, and admit the quantized copy
    /// (gen-guarded like [`WarmTier::admit`]). The **one entry point**
    /// for f32 chunks entering the tier — demotions, direct admissions
    /// on the load path, and prefetch parks — so the cost accounting
    /// can never diverge between them. Returns whether `id` is resident
    /// after the call, plus the charged quantize seconds. The q8 charge
    /// lands on the tier's `quant` clock, the q4 charge on its separate
    /// `q4_quant` clock, so fig JSONs can attribute each codec's cost.
    pub fn quantize_admit(
        &self,
        id: ChunkId,
        chunk: &KvChunk,
        file_bytes: usize,
        prefetched: bool,
        seen_gen: u64,
    ) -> (bool, f64) {
        let (payload, payload_bytes, quant_secs) = match self.mode() {
            WarmMode::Q8 => {
                let q = Arc::new(quant::quantize(chunk));
                let bytes = q.q8_bytes();
                let secs = crate::hwsim::profiles::q8_quant_secs(bytes as f64);
                self.stats.add_quant_secs(secs);
                (WarmPayload::Q8(q), bytes, secs)
            }
            WarmMode::Q4 => {
                let q = Arc::new(quant::quantize_q4(chunk));
                let bytes = q.q4_bytes();
                let secs = crate::hwsim::profiles::q4_quant_secs(bytes as f64);
                self.stats.add_q4_quant_secs(secs);
                (WarmPayload::Q4(q), bytes, secs)
            }
        };
        if let Some(bus) = &self.bus {
            let slot = bus.reserve_secs(quant_secs, payload_bytes, TrafficClass::Demotion);
            self.stats.add_link_queued_secs(slot.queued_secs);
        }
        let admitted = self.admit(id, payload, file_bytes, prefetched, seen_gen);
        if admitted {
            let bus = self.trace.lock().unwrap().clone();
            bus.event(
                "tier:warm",
                "demote_admit",
                quant_secs,
                &[("id", Arg::U(id)), ("bytes", Arg::U(payload_bytes as u64))],
            );
        }
        (admitted, quant_secs)
    }

    /// Admit a quantized chunk, evicting least-recently-used entries
    /// until the tier is back under budget (evicted q8 copies are
    /// dropped — this is the last DRAM rung; the flash file remains).
    ///
    /// `seen_gen` is the hot-tier-style generation guard: pass the
    /// generation captured *before* the bytes were obtained (before the
    /// device read for misses/prefetches, at eviction time — via
    /// [`DemoteSink::prepare`] — for demotions), and an admission raced
    /// by an invalidation is refused instead of parking stale bytes.
    ///
    /// Returns `true` when `id` is resident after the call.
    pub fn admit(
        &self,
        id: ChunkId,
        payload: WarmPayload,
        file_bytes: usize,
        prefetched: bool,
        seen_gen: u64,
    ) -> bool {
        let cost = payload.resident_bytes();
        if cost > self.budget {
            if prefetched {
                self.stats.prefetch_rejected.fetch_add(1, Ordering::Relaxed);
            }
            return false;
        }
        let mut guard = self.lru.lock().unwrap();
        let lru = &mut *guard;
        if lru.gens.get(&id).copied().unwrap_or(0) != seen_gen {
            if prefetched {
                self.stats.prefetch_rejected.fetch_add(1, Ordering::Relaxed);
            }
            return false; // superseded while the bytes were in flight
        }
        lru.clock += 1;
        let tick = lru.clock;
        if let Some(old) = lru.map.remove(&id) {
            lru.order.remove(&old.tick);
            lru.bytes -= old.cost;
        }
        lru.bytes += cost;
        lru.map.insert(id, WarmEntry { payload, file_bytes, cost, tick, prefetched });
        lru.order.insert(tick, id);
        self.stats.insertions.fetch_add(1, Ordering::Relaxed);
        if prefetched {
            self.stats.prefetch_inserts.fetch_add(1, Ordering::Relaxed);
        }
        let mut evicted: Vec<(ChunkId, usize)> = Vec::new();
        while lru.bytes > self.budget {
            let Some((&oldest, &evict)) = lru.order.iter().next() else { break };
            lru.order.remove(&oldest);
            if let Some(e) = lru.map.remove(&evict) {
                lru.bytes -= e.cost;
                self.stats.evictions.fetch_add(1, Ordering::Relaxed);
                evicted.push((evict, e.cost));
            }
        }
        drop(guard);
        if !evicted.is_empty() {
            let bus = self.trace.lock().unwrap().clone();
            for (evict, cost) in evicted {
                bus.mark(
                    "tier:warm",
                    "evict",
                    &[("id", Arg::U(evict)), ("bytes", Arg::U(cost as u64))],
                );
            }
        }
        true
    }
}

impl DemoteSink for WarmTier {
    /// Generation snapshot taken inside the hot tier's eviction critical
    /// section: any writer invalidation not complete by now is ordered
    /// after it (writers sweep hot-then-warm), so it will either bump
    /// this generation — refusing the admission below — or remove the
    /// admitted entry. Cheap by contract: one map lookup.
    fn prepare(&self, id: ChunkId) -> u64 {
        self.generation(id)
    }

    /// Hot-tier budget evictions land here *after* the hot lock is
    /// released: the O(plane), memory-bound quantize pass never
    /// serializes concurrent hot-tier probes. Guarded by the generation
    /// [`DemoteSink::prepare`] captured at eviction time. Goes through
    /// [`WarmTier::quantize_admit`], so demotion charges the simulated
    /// quantize pass exactly like every other entry into the tier.
    fn demote(
        &self,
        id: ChunkId,
        chunk: &Arc<KvChunk>,
        file_bytes: usize,
        prefetched: bool,
        seen_gen: u64,
    ) {
        self.quantize_admit(id, chunk, file_bytes, prefetched, seen_gen);
    }
}

impl TierMetrics for WarmTier {
    fn tier_stats(&self) -> &CacheStats {
        &self.stats
    }

    fn residency(&self) -> (usize, usize) {
        let lru = self.lru.lock().unwrap();
        (lru.bytes, lru.map.len())
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn qchunk(seed: u32) -> WarmPayload {
        let plane = 2 * 2 * 8 * 4;
        let c = KvChunk {
            config_id: 1,
            n_layers: 2,
            n_kv_heads: 2,
            seq_len: 8,
            head_dim: 4,
            k: (0..plane).map(|i| (i + seed as usize) as f32).collect(),
            v: (0..plane).map(|i| -((i + seed as usize) as f32)).collect(),
        };
        WarmPayload::Q8(Arc::new(quant::quantize(&c)))
    }

    fn cost() -> usize {
        qchunk(0).resident_bytes()
    }

    /// Admit with a freshly captured generation (the common happy path).
    fn admit_now(tier: &WarmTier, id: ChunkId, seed: u32, prefetched: bool) -> bool {
        tier.admit(id, qchunk(seed), 100, prefetched, tier.generation(id))
    }

    #[test]
    fn lru_eviction_order_and_budget() {
        let tier = WarmTier::new(2 * cost());
        assert!(admit_now(&tier, 1, 1, false));
        assert!(admit_now(&tier, 2, 2, false));
        // touch 1 → LRU victim is 2
        assert!(matches!(tier.probe(1, None), WarmProbe::Hit { .. }));
        assert!(admit_now(&tier, 3, 3, false));
        assert_eq!(tier.len(), 2);
        assert!(tier.contains(1) && tier.contains(3));
        assert!(!tier.contains(2), "LRU entry must be the one evicted");
        assert_eq!(tier.stats.evictions.load(Ordering::Relaxed), 1);
        assert!(tier.bytes() <= tier.budget());
    }

    #[test]
    fn take_removes_touch_keeps() {
        let tier = WarmTier::new(4 * cost());
        tier.admit(5, qchunk(5), 640, false, tier.generation(5));
        match tier.probe(5, None) {
            WarmProbe::Hit { file_bytes, .. } => assert_eq!(file_bytes, 640),
            WarmProbe::Miss(_) => panic!("touch lost the entry"),
        }
        assert!(tier.contains(5));
        assert!(matches!(tier.probe(5, Some(usize::MAX)), WarmProbe::Hit { .. }));
        assert!(!tier.contains(5), "take must remove (promote-out)");
        assert_eq!(tier.bytes(), 0);
        assert_eq!(tier.stats.hits.load(Ordering::Relaxed), 2);
        assert_eq!(tier.stats.bytes_saved.load(Ordering::Relaxed), 2 * 640);
        // and the next probe is a miss
        assert!(matches!(tier.probe(5, Some(usize::MAX)), WarmProbe::Miss(_)));
        assert_eq!(tier.stats.misses.load(Ordering::Relaxed), 1);
    }

    #[test]
    fn generation_guard_rejects_stale_admission() {
        // Mirrors the hot tier's insert_at race test: gen captured, then
        // an invalidation lands, then the stale admission must bounce.
        let tier = WarmTier::new(4 * cost());
        let seen = tier.generation(9);
        tier.invalidate(9);
        assert!(!tier.admit(9, qchunk(9), 100, false, seen));
        assert!(!tier.contains(9));
        // a fresh capture admits
        assert!(tier.admit(9, qchunk(9), 100, false, tier.generation(9)));
        assert!(tier.contains(9));
        // unrelated invalidations never suppress admission
        let other = tier.generation(8);
        tier.invalidate(9);
        assert!(tier.admit(8, qchunk(8), 100, false, other));
        assert!(tier.contains(8));
    }

    #[test]
    fn demotion_is_guarded_by_the_prepared_generation() {
        // prepare() snapshots the generation at (simulated) eviction
        // time; an invalidation landing between prepare and demote must
        // refuse the admission — the demoted bytes are superseded.
        let tier = WarmTier::new(64 << 20);
        let chunk = kvchunk(127.0);
        let gen = tier.prepare(3);
        tier.demote(3, &chunk, 100, false, gen);
        assert!(tier.contains(3), "unraced demotion must land");

        let gen = tier.prepare(4);
        tier.invalidate(4); // writer swept between eviction and admit
        tier.demote(4, &chunk, 100, false, gen);
        assert!(!tier.contains(4), "stale demotion admitted after invalidate");
    }

    #[test]
    fn prefetched_class_survives_until_first_hit() {
        let tier = WarmTier::new(4 * cost());
        admit_now(&tier, 1, 1, true);
        assert_eq!(tier.stats.prefetch_inserts.load(Ordering::Relaxed), 1);
        match tier.probe(1, None) {
            WarmProbe::Hit { prefetched, .. } => assert!(prefetched),
            WarmProbe::Miss(_) => panic!(),
        }
        assert_eq!(tier.stats.prefetch_hits.load(Ordering::Relaxed), 1);
        // the first hit consumed the class: a second hit is plain
        match tier.probe(1, None) {
            WarmProbe::Hit { prefetched, .. } => assert!(!prefetched),
            WarmProbe::Miss(_) => panic!(),
        }
        assert_eq!(tier.stats.prefetch_hits.load(Ordering::Relaxed), 1);
    }

    #[test]
    fn oversize_chunk_not_admitted() {
        let tier = WarmTier::new(cost() - 1);
        assert!(!admit_now(&tier, 1, 1, false));
        assert_eq!(tier.len(), 0);
        assert!(!admit_now(&tier, 2, 2, true));
        assert_eq!(tier.stats.prefetch_rejected.load(Ordering::Relaxed), 1);
    }

    #[test]
    fn invalidate_drops_and_is_idempotent() {
        let tier = WarmTier::new(4 * cost());
        admit_now(&tier, 1, 1, false);
        tier.invalidate(1);
        assert_eq!(tier.len(), 0);
        assert_eq!(tier.bytes(), 0);
        assert!(matches!(tier.probe(1, None), WarmProbe::Miss(_)));
        tier.invalidate(1); // absent: no panic
    }

    /// A real (unquantized) chunk with constant planes at multiples of
    /// 127: the q8 scale is an exact integer, so the round trip is
    /// bit-exact and equality asserts hold.
    fn kvchunk(val: f32) -> Arc<KvChunk> {
        let plane = 2 * 2 * 8 * 4;
        Arc::new(KvChunk {
            config_id: 1,
            n_layers: 2,
            n_kv_heads: 2,
            seq_len: 8,
            head_dim: 4,
            k: vec![val; plane],
            v: vec![-2.0 * val; plane],
        })
    }

    #[test]
    fn demote_sink_quantizes_and_admits() {
        let tier = WarmTier::new(64 << 20);
        let chunk = kvchunk(127.0);
        tier.demote(7, &chunk, 512, false, tier.prepare(7));
        assert!(tier.contains(7));
        // quantize-on-demote is charged in simulated time, symmetric to
        // the dequant a promotion would pay on the same q8 payload
        let quant = tier.stats.quant_secs();
        assert!(quant > 0.0, "demotion must charge the quantize pass");
        match tier.probe(7, Some(usize::MAX)) {
            WarmProbe::Hit { payload, file_bytes, .. } => {
                assert_eq!(file_bytes, 512);
                assert_eq!(payload.mode(), WarmMode::Q8, "default mode must stay q8");
                let back = payload.dequantize();
                assert_eq!(back.k, chunk.k);
                assert_eq!(back.v, chunk.v);
            }
            WarmProbe::Miss(_) => panic!(),
        }
    }

    #[test]
    fn q4_mode_packs_tighter_and_charges_its_own_clock() {
        let tier = WarmTier::new(64 << 20);
        assert_eq!(tier.mode(), WarmMode::Q8);
        tier.set_mode(WarmMode::Q4);
        assert_eq!(tier.mode(), WarmMode::Q4);
        // constant planes quantize exactly in q4 too (q = ±7 on grid)
        let chunk = kvchunk(127.0);
        tier.demote(7, &chunk, 512, false, tier.prepare(7));
        assert!(tier.contains(7));
        // the quantize pass lands on the q4 clock, not the q8 one
        assert!(tier.stats.q4_quant_secs() > 0.0, "q4 admission must charge the q4 quant clock");
        assert_eq!(tier.stats.quant_secs(), 0.0);
        match tier.probe(7, Some(usize::MAX)) {
            WarmProbe::Hit { payload, file_bytes, .. } => {
                assert_eq!(file_bytes, 512);
                assert_eq!(payload.mode(), WarmMode::Q4);
                assert!(payload.dequant_secs() > 0.0);
                let back = payload.dequantize();
                assert_eq!(back.k, chunk.k);
                assert_eq!(back.v, chunk.v);
            }
            WarmProbe::Miss(_) => panic!(),
        }
    }

    #[test]
    fn q4_mode_halves_residency_versus_q8() {
        // Equal chunks, both codecs: the q4 copy must charge roughly
        // half the q8 copy's resident bytes against the budget — the
        // whole point of the cooler rung. Planes big enough that
        // struct-header overhead doesn't blur the ratio.
        let plane = 2 * 2 * 128 * 4;
        let chunk = KvChunk {
            config_id: 1,
            n_layers: 2,
            n_kv_heads: 2,
            seq_len: 128,
            head_dim: 4,
            k: vec![127.0; plane],
            v: vec![-254.0; plane],
        };
        let q8 = WarmPayload::Q8(Arc::new(quant::quantize(&chunk)));
        let q4 = WarmPayload::Q4(Arc::new(quant::quantize_q4(&chunk)));
        assert!(
            (q4.resident_bytes() as f64) < 0.6 * q8.resident_bytes() as f64,
            "q4 residency {} not about half of q8's {}",
            q4.resident_bytes(),
            q8.resident_bytes()
        );
        assert_eq!(q4.f32_dram_bytes(), q8.f32_dram_bytes());
    }

    #[test]
    fn mode_switch_leaves_resident_entries_on_their_codec() {
        let tier = WarmTier::new(64 << 20);
        let chunk = kvchunk(127.0);
        tier.demote(1, &chunk, 100, false, tier.prepare(1));
        tier.set_mode(WarmMode::Q4);
        tier.demote(2, &chunk, 100, false, tier.prepare(2));
        match tier.probe(1, None) {
            WarmProbe::Hit { payload, .. } => assert_eq!(payload.mode(), WarmMode::Q8),
            WarmProbe::Miss(_) => panic!(),
        }
        match tier.probe(2, None) {
            WarmProbe::Hit { payload, .. } => assert_eq!(payload.mode(), WarmMode::Q4),
            WarmProbe::Miss(_) => panic!(),
        }
    }

    #[test]
    fn resident_ids_snapshot_without_side_effects() {
        let tier = WarmTier::new(4 * cost());
        admit_now(&tier, 1, 1, false);
        admit_now(&tier, 2, 2, true);
        let mut ids = tier.resident_ids();
        ids.sort_unstable();
        assert_eq!(ids, vec![1, 2]);
        assert_eq!(tier.stats.hits.load(Ordering::Relaxed), 0);
        assert_eq!(tier.stats.misses.load(Ordering::Relaxed), 0);
    }
}
