//! Deterministic tracing subsystem — the serving stack's flight
//! recorder.
//!
//! Every aggregate the simulator reports ([`PhaseBreakdown`] counters,
//! per-link telemetry) answers *how much*; this module answers *when*
//! and *why*: a [`TraceBus`] collects typed spans and instant events
//! from every layer — request lifecycle on the scheduler's virtual
//! clock, per-chunk tier outcomes in the store, link reservations with
//! their queued-vs-wire split, per-worker load/upload/prefill/decode
//! windows in the fleet — and exports them as Chrome trace-event JSON
//! that Perfetto / `chrome://tracing` loads directly, plus a
//! per-request **critical-path attribution** report (each request's
//! latency decomposed into queue / storage / bus / PCIe / compute /
//! retry seconds that sum to its end-to-end latency exactly).
//!
//! Design constraints, in order:
//!
//! 1. **Zero behavior change.** The handle is an `Option<Arc<..>>`;
//!    disabled it records nothing, allocates nothing, and every
//!    instrumented path is pinned bit-identical to the pre-trace code
//!    by the existing replay tests. Callers that must build args or
//!    track names check [`TraceBus::enabled`] first, so the disabled
//!    path is one branch.
//! 2. **Byte-identical exports.** Two runs with the same seed + config
//!    must produce the same file. Events from *virtual-clock* contexts
//!    (scheduler, fleet dispatch, Virtual-clock links) carry their real
//!    timestamps. Events from *wall-clock* contexts (store tier
//!    outcomes, Sleep/Account links, the overlap pipeline) are
//!    recorded **unclocked** — deterministic payload only, no wall
//!    timestamps — and the exporter lays each unclocked track out
//!    sequentially (cursor += duration) after sorting its events by
//!    their serialized body, so thread interleaving can never reorder
//!    the file. Timestamps are monotone per track by construction
//!    either way.
//! 3. **Cheap when recording.** One mutex push per event; formatting
//!    happens once, at export.
//!
//! [`PhaseBreakdown`]: crate::coordinator::PhaseBreakdown

use std::collections::BTreeMap;
use std::fmt::Write as _;
use std::sync::{Arc, Mutex};

use crate::coordinator::metrics::LogHistogram;

/// One argument value on a trace event. Floats format at fixed
/// precision so the exported bytes are stable.
#[derive(Debug, Clone, PartialEq)]
pub enum Arg {
    U(u64),
    F(f64),
    S(String),
}

impl Arg {
    fn write_json(&self, out: &mut String) {
        match self {
            Arg::U(v) => {
                let _ = write!(out, "{v}");
            }
            Arg::F(v) => {
                let _ = write!(out, "{v:.9}");
            }
            Arg::S(v) => {
                out.push('"');
                escape_into(v, out);
                out.push('"');
            }
        }
    }
}

/// Minimal JSON string escaping (track/event names are code-controlled;
/// this keeps user-ish strings like queries safe anyway).
fn escape_into(s: &str, out: &mut String) {
    for c in s.chars() {
        match c {
            '"' => out.push_str("\\\""),
            '\\' => out.push_str("\\\\"),
            '\n' => out.push_str("\\n"),
            '\r' => out.push_str("\\r"),
            '\t' => out.push_str("\\t"),
            c if (c as u32) < 0x20 => {
                let _ = write!(out, "\\u{:04x}", c as u32);
            }
            c => out.push(c),
        }
    }
}

/// One recorded event. `start` is `Some(virtual_secs)` for clocked
/// events; `None` marks an unclocked event whose timestamp the exporter
/// synthesizes (sequential layout per track).
#[derive(Debug, Clone)]
struct TraceEvent {
    track: String,
    name: &'static str,
    start: Option<f64>,
    dur_secs: f64,
    instant: bool,
    args: Vec<(&'static str, Arg)>,
}

impl TraceEvent {
    /// The event body without any timestamp — the exporter's
    /// deterministic sort key for unclocked events, and the tail of the
    /// emitted JSON either way.
    fn body(&self) -> String {
        let mut out = String::with_capacity(64);
        let _ = write!(out, "\"name\":\"{}\"", self.name);
        if self.instant {
            out.push_str(",\"ph\":\"i\",\"s\":\"t\"");
        } else {
            let _ = write!(out, ",\"ph\":\"X\",\"dur\":{:.3}", self.dur_secs * 1e6);
        }
        if !self.args.is_empty() {
            out.push_str(",\"args\":{");
            for (i, (k, v)) in self.args.iter().enumerate() {
                if i > 0 {
                    out.push(',');
                }
                let _ = write!(out, "\"{k}\":");
                v.write_json(&mut out);
            }
            out.push('}');
        }
        out
    }
}

/// A traced request's end-to-end latency, decomposed along its critical
/// path. The six components are constructed from the dispatch
/// timeline's own arithmetic, so they sum to `done - arrival` exactly
/// (modulo float rounding — see [`RequestPath::sum_abs_err`]).
#[derive(Debug, Clone, PartialEq)]
pub struct RequestPath {
    pub request_id: u64,
    /// Worker track name the request executed on.
    pub worker: String,
    pub arrival_secs: f64,
    pub done_secs: f64,
    /// Waiting: in the scheduler queue before release, plus at the
    /// device behind an earlier batch's compute.
    pub queue_secs: f64,
    /// Storage-tier load (flash read / dequant path, host side).
    pub storage_secs: f64,
    /// Seconds the H2D upload spent *queued* behind earlier traffic on
    /// the worker's PCIe link — the contention share.
    pub bus_secs: f64,
    /// H2D wire time (the upload's un-queued share).
    pub pcie_secs: f64,
    /// Prefill + decode on the device.
    pub compute_secs: f64,
    /// Degradation surcharge: recompute of lost chunks, retry backoff.
    pub retry_secs: f64,
}

impl RequestPath {
    pub fn latency_secs(&self) -> f64 {
        self.done_secs - self.arrival_secs
    }

    /// Sum of the six attributed components.
    pub fn components_sum(&self) -> f64 {
        self.queue_secs
            + self.storage_secs
            + self.bus_secs
            + self.pcie_secs
            + self.compute_secs
            + self.retry_secs
    }

    /// |components − latency| — the acceptance criterion is < 1e-6 s.
    pub fn sum_abs_err(&self) -> f64 {
        (self.components_sum() - self.latency_secs()).abs()
    }

    /// The component carrying the largest share — what the waterfall
    /// calls the bottleneck.
    pub fn dominant(&self) -> (&'static str, f64) {
        let parts = [
            ("queue", self.queue_secs),
            ("storage", self.storage_secs),
            ("bus", self.bus_secs),
            ("pcie", self.pcie_secs),
            ("compute", self.compute_secs),
            ("retry", self.retry_secs),
        ];
        let mut best = parts[0];
        for p in &parts[1..] {
            if p.1 > best.1 {
                best = *p;
            }
        }
        best
    }

    pub fn to_json(&self) -> String {
        let mut out = String::with_capacity(256);
        let _ = write!(
            out,
            "{{\"request\":{},\"worker\":\"{}\",\"arrival_secs\":{:.9},\
             \"done_secs\":{:.9},\"latency_secs\":{:.9},\"queue_secs\":{:.9},\
             \"storage_secs\":{:.9},\"bus_secs\":{:.9},\"pcie_secs\":{:.9},\
             \"compute_secs\":{:.9},\"retry_secs\":{:.9},\"dominant\":\"{}\"}}",
            self.request_id,
            self.worker,
            self.arrival_secs,
            self.done_secs,
            self.latency_secs(),
            self.queue_secs,
            self.storage_secs,
            self.bus_secs,
            self.pcie_secs,
            self.compute_secs,
            self.retry_secs,
            self.dominant().0,
        );
        out
    }
}

#[derive(Debug, Default)]
struct TraceInner {
    events: Mutex<Vec<TraceEvent>>,
    paths: Mutex<Vec<RequestPath>>,
}

/// The recording handle every layer holds. Cloning shares the buffer
/// (`Option<Arc>`); the disabled bus is a no-op whose record methods
/// cost one branch.
#[derive(Debug, Clone, Default)]
pub struct TraceBus {
    inner: Option<Arc<TraceInner>>,
}

impl TraceBus {
    /// A recording bus.
    pub fn recording() -> TraceBus {
        TraceBus { inner: Some(Arc::new(TraceInner::default())) }
    }

    /// The no-op bus (what every subsystem starts with).
    pub fn disabled() -> TraceBus {
        TraceBus { inner: None }
    }

    /// Whether events are being kept. Call this before building track
    /// names or args on hot paths.
    #[inline]
    pub fn enabled(&self) -> bool {
        self.inner.is_some()
    }

    fn push(&self, ev: TraceEvent) {
        if let Some(inner) = &self.inner {
            inner.events.lock().unwrap().push(ev);
        }
    }

    /// Clocked span: `start` is on the deterministic virtual clock.
    pub fn span(
        &self,
        track: &str,
        name: &'static str,
        start_secs: f64,
        dur_secs: f64,
        args: &[(&'static str, Arg)],
    ) {
        if !self.enabled() {
            return;
        }
        self.push(TraceEvent {
            track: track.to_string(),
            name,
            start: Some(start_secs),
            dur_secs,
            instant: false,
            args: args.to_vec(),
        });
    }

    /// Clocked instant event.
    pub fn instant(
        &self,
        track: &str,
        name: &'static str,
        ts_secs: f64,
        args: &[(&'static str, Arg)],
    ) {
        if !self.enabled() {
            return;
        }
        self.push(TraceEvent {
            track: track.to_string(),
            name,
            start: Some(ts_secs),
            dur_secs: 0.0,
            instant: true,
            args: args.to_vec(),
        });
    }

    /// Unclocked span: wall-clock context, deterministic payload only.
    /// The exporter lays these out sequentially per track.
    pub fn event(
        &self,
        track: &str,
        name: &'static str,
        dur_secs: f64,
        args: &[(&'static str, Arg)],
    ) {
        if !self.enabled() {
            return;
        }
        self.push(TraceEvent {
            track: track.to_string(),
            name,
            start: None,
            dur_secs,
            instant: false,
            args: args.to_vec(),
        });
    }

    /// Unclocked instant event.
    pub fn mark(&self, track: &str, name: &'static str, args: &[(&'static str, Arg)]) {
        if !self.enabled() {
            return;
        }
        self.push(TraceEvent {
            track: track.to_string(),
            name,
            start: None,
            dur_secs: 0.0,
            instant: true,
            args: args.to_vec(),
        });
    }

    /// Record one request's critical-path decomposition (the fleet
    /// dispatcher, once per completed request).
    pub fn request_path(&self, path: RequestPath) {
        if let Some(inner) = &self.inner {
            inner.paths.lock().unwrap().push(path);
        }
    }

    /// Events recorded so far (0 when disabled).
    pub fn len(&self) -> usize {
        match &self.inner {
            Some(inner) => inner.events.lock().unwrap().len(),
            None => 0,
        }
    }

    pub fn is_empty(&self) -> bool {
        self.len() == 0
    }

    /// Snapshot of the attribution records, sorted by request id (then
    /// arrival, for requeue-style duplicates) — deterministic.
    pub fn paths(&self) -> Vec<RequestPath> {
        let mut v = match &self.inner {
            Some(inner) => inner.paths.lock().unwrap().clone(),
            None => Vec::new(),
        };
        v.sort_by(|a, b| {
            a.request_id
                .cmp(&b.request_id)
                .then(a.arrival_secs.total_cmp(&b.arrival_secs))
        });
        v
    }

    /// Largest attribution error across recorded requests (0 if none).
    pub fn max_attribution_err(&self) -> f64 {
        self.paths().iter().map(|p| p.sum_abs_err()).fold(0.0, f64::max)
    }

    /// Export everything as Chrome trace-event JSON (Perfetto /
    /// `chrome://tracing` load it directly). The layout is fully
    /// deterministic:
    ///
    /// * tracks sort by name and become `tid` 1..N (named via `"M"`
    ///   metadata rows);
    /// * clocked events sort by (start, body) within their track;
    /// * unclocked events sort by their serialized body, then lay out
    ///   sequentially (`ts = cursor; cursor += dur`) — so wall-clock
    ///   thread interleaving never changes a byte of the file, and
    ///   timestamps are monotone per track.
    ///
    /// The attribution report and merged latency histograms ride in a
    /// top-level `"matkv"` object Perfetto ignores.
    pub fn to_chrome_json(&self) -> String {
        let events = match &self.inner {
            Some(inner) => inner.events.lock().unwrap().clone(),
            None => Vec::new(),
        };
        let mut tracks: BTreeMap<String, Vec<TraceEvent>> = BTreeMap::new();
        for e in events {
            tracks.entry(e.track.clone()).or_default().push(e);
        }

        let mut rows: Vec<String> = Vec::new();
        // Metadata first: one thread_name row per track, in tid order.
        for (tid, name) in tracks.keys().enumerate() {
            let mut esc = String::new();
            escape_into(name, &mut esc);
            rows.push(format!(
                "{{\"ph\":\"M\",\"pid\":1,\"tid\":{},\"name\":\"thread_name\",\
                 \"args\":{{\"name\":\"{}\"}}}}",
                tid + 1,
                esc
            ));
        }
        for (tid, (_, evs)) in tracks.into_iter().enumerate() {
            let tid = tid + 1;
            let mut clocked: Vec<(f64, String)> = Vec::new();
            let mut unclocked: Vec<(f64, String)> = Vec::new();
            for e in evs {
                match e.start {
                    Some(s) => clocked.push((s, e.body())),
                    None => unclocked.push((e.dur_secs, e.body())),
                }
            }
            clocked.sort_by(|a, b| a.0.total_cmp(&b.0).then(a.1.cmp(&b.1)));
            for (start, body) in clocked {
                rows.push(format!(
                    "{{\"pid\":1,\"tid\":{tid},\"ts\":{:.3},{body}}}",
                    start * 1e6
                ));
            }
            unclocked.sort_by(|a, b| a.1.cmp(&b.1).then(a.0.total_cmp(&b.0)));
            let mut cursor = 0.0f64;
            for (dur, body) in unclocked {
                rows.push(format!(
                    "{{\"pid\":1,\"tid\":{tid},\"ts\":{:.3},{body}}}",
                    cursor * 1e6
                ));
                cursor += dur;
            }
        }

        let paths = self.paths();
        let path_rows: Vec<String> = paths.iter().map(RequestPath::to_json).collect();
        // Mergeable latency distributions: one log-bucketed histogram
        // per worker, folded into the fleet-wide histogram via
        // LogHistogram::merge — no per-sample storage in the document.
        let mut by_worker: BTreeMap<String, LogHistogram> = BTreeMap::new();
        for p in &paths {
            by_worker.entry(p.worker.clone()).or_default().record(p.latency_secs());
        }
        let mut fleet = LogHistogram::default();
        for h in by_worker.values() {
            fleet.merge(h);
        }
        let worker_rows: Vec<String> = by_worker
            .iter()
            .map(|(w, h)| {
                let mut esc = String::new();
                escape_into(w, &mut esc);
                format!("\"{}\":{}", esc, h.to_json())
            })
            .collect();

        format!(
            "{{\"displayTimeUnit\":\"ms\",\"traceEvents\":[{}],\
             \"matkv\":{{\"events\":{},\"critical_path\":[{}],\
             \"max_attribution_err_secs\":{:.12},\
             \"latency_histograms\":{{\"fleet\":{},\"workers\":{{{}}}}}}}}}",
            rows.join(",\n"),
            rows.len(),
            path_rows.join(",\n"),
            self.max_attribution_err(),
            fleet.to_json(),
            worker_rows.join(","),
        )
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn path(id: u64, q: f64, s: f64, b: f64, p: f64, c: f64, r: f64) -> RequestPath {
        RequestPath {
            request_id: id,
            worker: "worker0:H100".into(),
            arrival_secs: 0.0,
            done_secs: q + s + b + p + c + r,
            queue_secs: q,
            storage_secs: s,
            bus_secs: b,
            pcie_secs: p,
            compute_secs: c,
            retry_secs: r,
        }
    }

    #[test]
    fn disabled_bus_records_nothing_and_exports_empty() {
        let bus = TraceBus::disabled();
        assert!(!bus.enabled());
        bus.span("t", "x", 0.0, 1.0, &[]);
        bus.mark("t", "y", &[("k", Arg::U(1))]);
        bus.request_path(path(1, 0.1, 0.0, 0.0, 0.0, 0.0, 0.0));
        assert_eq!(bus.len(), 0);
        assert!(bus.paths().is_empty());
        let doc = bus.to_chrome_json();
        assert!(doc.contains("\"traceEvents\":[]"), "{doc}");
    }

    #[test]
    fn export_is_independent_of_unclocked_insertion_order() {
        // Simulates IO-pool nondeterminism: the same multiset of
        // unclocked events inserted in two different orders must export
        // byte-identically.
        let record = |ids: &[u64]| {
            let bus = TraceBus::recording();
            for &id in ids {
                bus.event(
                    "store",
                    "flash_read",
                    0.001 * id as f64,
                    &[("chunk", Arg::U(id))],
                );
                bus.event("link:shard0", "demand", 0.002, &[("bytes", Arg::U(100 + id))]);
            }
            bus.to_chrome_json()
        };
        let a = record(&[1, 2, 3, 4, 5]);
        let b = record(&[4, 2, 5, 1, 3]);
        assert_eq!(a, b, "unclocked export must not depend on thread arrival order");
    }

    #[test]
    fn clocked_events_sort_by_timestamp_per_track() {
        let bus = TraceBus::recording();
        bus.instant("sched", "release", 2.0, &[]);
        bus.instant("sched", "queued", 1.0, &[]);
        bus.instant("sched", "queued", 0.5, &[]);
        let doc = bus.to_chrome_json();
        let i1 = doc.find("\"ts\":500000.000").expect("0.5s event");
        let i2 = doc.find("\"ts\":1000000.000").expect("1.0s event");
        let i3 = doc.find("\"ts\":2000000.000").expect("2.0s event");
        assert!(i1 < i2 && i2 < i3, "clocked rows must be time-ordered");
    }

    #[test]
    fn unclocked_layout_is_sequential_and_monotone() {
        let bus = TraceBus::recording();
        bus.event("store", "a", 0.5, &[]);
        bus.event("store", "b", 0.25, &[]);
        let doc = bus.to_chrome_json();
        // sorted by body: "a" first at ts 0, then "b" at 0.5s
        let ia = doc.find("\"name\":\"a\"").unwrap();
        let ib = doc.find("\"name\":\"b\"").unwrap();
        assert!(ia < ib);
        assert!(doc.contains("\"ts\":0.000,\"name\":\"a\""), "{doc}");
        assert!(doc.contains("\"ts\":500000.000,\"name\":\"b\""), "{doc}");
    }

    #[test]
    fn tracks_become_named_tids() {
        let bus = TraceBus::recording();
        bus.mark("zeta", "z", &[]);
        bus.mark("alpha", "a", &[]);
        let doc = bus.to_chrome_json();
        // BTreeMap order: alpha=1, zeta=2
        assert!(doc.contains("\"tid\":1,\"name\":\"thread_name\",\"args\":{\"name\":\"alpha\"}"));
        assert!(doc.contains("\"tid\":2,\"name\":\"thread_name\",\"args\":{\"name\":\"zeta\"}"));
    }

    #[test]
    fn attribution_components_sum_to_latency() {
        let p = path(7, 0.125, 0.25, 0.0625, 0.03125, 0.5, 0.015625);
        assert!(p.sum_abs_err() < 1e-12, "{}", p.sum_abs_err());
        assert_eq!(p.dominant().0, "compute");
        let bus = TraceBus::recording();
        bus.request_path(p.clone());
        bus.request_path(path(3, 1.0, 0.0, 0.0, 0.0, 0.1, 0.0));
        assert!(bus.max_attribution_err() < 1e-12);
        // paths() sorts by request id
        let ids: Vec<u64> = bus.paths().iter().map(|p| p.request_id).collect();
        assert_eq!(ids, vec![3, 7]);
        assert!(bus.to_chrome_json().contains("\"dominant\":\"queue\""));
    }

    #[test]
    fn same_recording_sequence_exports_byte_identically() {
        let run = || {
            let bus = TraceBus::recording();
            bus.instant("sched", "queued", 0.015, &[("req", Arg::U(4))]);
            bus.span(
                "worker0:H100",
                "prefill",
                0.5,
                0.125,
                &[("batch", Arg::U(0)), ("reqs", Arg::U(4))],
            );
            bus.event("store", "hot_hit", 0.0, &[("chunk", Arg::U(9))]);
            bus.request_path(path(4, 0.2, 0.1, 0.0, 0.05, 0.375, 0.0));
            bus.to_chrome_json()
        };
        assert_eq!(run(), run());
    }

    #[test]
    fn string_args_escape() {
        let bus = TraceBus::recording();
        bus.mark("t", "q", &[("text", Arg::S("a\"b\\c\nd".into()))]);
        assert!(bus.to_chrome_json().contains("a\\\"b\\\\c\\nd"));
    }
}
