//! Word-level tokenizer substrate.
//!
//! The paper tokenizes with the LLaMA BPE tokenizer; every measured
//! quantity, however, depends only on *token counts*, so a deterministic
//! word-level tokenizer with a frequency-built vocabulary preserves all
//! behaviours (chunk sizes, query lengths, materialized KV sizes) while
//! staying dependency-free. Unknown words hash into a reserved band so
//! encoding is total and deterministic.

use std::collections::HashMap;

/// Special token ids (kept at the bottom of every vocabulary).
pub const PAD: u32 = 0;
pub const BOS: u32 = 1;
pub const EOS: u32 = 2;
pub const UNK_BAND: u32 = 3; // unknown words hash into [UNK_BAND, unk_end)
const N_SPECIAL: u32 = 3;

/// Deterministic FNV-1a (no external deps, stable across runs/platforms).
fn fnv1a(s: &str) -> u64 {
    let mut h: u64 = 0xcbf29ce484222325;
    for b in s.as_bytes() {
        h ^= *b as u64;
        h = h.wrapping_mul(0x100000001b3);
    }
    h
}

/// Word-level tokenizer with a fixed-size vocabulary.
#[derive(Debug, Clone)]
pub struct Tokenizer {
    vocab_size: u32,
    /// Fraction of the vocab reserved for hashed unknown words.
    unk_end: u32,
    word_to_id: HashMap<String, u32>,
    id_to_word: Vec<String>,
}

impl Tokenizer {
    /// Build a vocabulary from a corpus: the most frequent words receive
    /// dedicated ids above the hash band; everything else hashes.
    pub fn from_corpus<'a>(texts: impl IntoIterator<Item = &'a str>, vocab_size: u32) -> Self {
        assert!(vocab_size > 64, "vocab too small: {vocab_size}");
        let unk_end = N_SPECIAL + (vocab_size / 8).max(16); // 1/8th hash band
        let mut freq: HashMap<&str, u64> = HashMap::new();
        for t in texts {
            for w in t.split_whitespace() {
                *freq.entry(w).or_default() += 1;
            }
        }
        let mut by_freq: Vec<(&str, u64)> = freq.into_iter().collect();
        by_freq.sort_by(|a, b| b.1.cmp(&a.1).then(a.0.cmp(b.0)));
        let capacity = (vocab_size - unk_end) as usize;
        let mut word_to_id = HashMap::new();
        let mut id_to_word = vec![String::new(); vocab_size as usize];
        id_to_word[PAD as usize] = "<pad>".into();
        id_to_word[BOS as usize] = "<bos>".into();
        id_to_word[EOS as usize] = "<eos>".into();
        for (i, (w, _)) in by_freq.into_iter().take(capacity).enumerate() {
            let id = unk_end + i as u32;
            word_to_id.insert(w.to_string(), id);
            id_to_word[id as usize] = w.to_string();
        }
        Tokenizer { vocab_size, unk_end, word_to_id, id_to_word }
    }

    /// Vocabulary-free tokenizer: every word hashes (used when no corpus
    /// is available yet, e.g. pure throughput benchmarks).
    pub fn hashed(vocab_size: u32) -> Self {
        Tokenizer {
            vocab_size,
            unk_end: vocab_size,
            word_to_id: HashMap::new(),
            id_to_word: vec![String::new(); vocab_size as usize],
        }
    }

    pub fn vocab_size(&self) -> u32 {
        self.vocab_size
    }

    fn hash_id(&self, w: &str) -> u32 {
        let band = self.unk_end - N_SPECIAL;
        UNK_BAND + (fnv1a(w) % band as u64) as u32
    }

    /// Encode text to token ids (no implicit BOS/EOS).
    pub fn encode(&self, text: &str) -> Vec<u32> {
        text.split_whitespace()
            .map(|w| *self.word_to_id.get(w).unwrap_or(&self.hash_id(w)))
            .collect()
    }

    /// Decode ids to text; hashed/unknown ids render as `<unk:ID>`.
    pub fn decode(&self, ids: &[u32]) -> String {
        ids.iter()
            .map(|&id| {
                let w = self.id_to_word.get(id as usize).map(String::as_str).unwrap_or("");
                if w.is_empty() {
                    format!("<unk:{id}>")
                } else {
                    w.to_string()
                }
            })
            .collect::<Vec<_>>()
            .join(" ")
    }

    /// Encode and pad/truncate to exactly `len` tokens (PAD-filled);
    /// returns (tokens, live_len).
    pub fn encode_block(&self, text: &str, len: usize) -> (Vec<u32>, usize) {
        let mut ids = self.encode(text);
        let live = ids.len().min(len);
        ids.truncate(len);
        ids.resize(len, PAD);
        (ids, live)
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn tok() -> Tokenizer {
        Tokenizer::from_corpus(["the cat sat on the mat", "the dog ate the bone"], 512)
    }

    #[test]
    fn frequent_words_roundtrip() {
        let t = tok();
        let ids = t.encode("the cat sat");
        assert_eq!(t.decode(&ids), "the cat sat");
    }

    #[test]
    fn unknown_words_hash_deterministically() {
        let t = tok();
        let a = t.encode("zyzzyva");
        let b = t.encode("zyzzyva");
        assert_eq!(a, b);
        assert!(a[0] >= UNK_BAND && a[0] < t.unk_end);
    }

    #[test]
    fn ids_within_vocab() {
        let t = tok();
        for id in t.encode("completely novel words never seen before xyz qqq") {
            assert!(id < t.vocab_size());
        }
    }

    #[test]
    fn encode_block_pads_and_truncates() {
        let t = tok();
        let (ids, live) = t.encode_block("the cat", 5);
        assert_eq!(live, 2);
        assert_eq!(ids.len(), 5);
        assert_eq!(&ids[2..], &[PAD, PAD, PAD]);
        let (ids, live) = t.encode_block("the cat sat on the mat", 3);
        assert_eq!((ids.len(), live), (3, 3));
    }

    #[test]
    fn hashed_mode_total() {
        let t = Tokenizer::hashed(1024);
        assert!(!t.encode("anything at all").is_empty());
    }

    // property sweep: random word lists (seeded, deterministic)
    #[test]
    fn prop_encode_is_deterministic_and_bounded() {
        let t = tok();
        let mut rng = crate::workload::Rng::new(0xbeef);
        for _ in 0..100 {
            let n = 1 + rng.below(49);
            let words: Vec<String> = (0..n)
                .map(|_| {
                    let len = 1 + rng.below(8);
                    (0..len).map(|_| (b'a' + rng.below(26) as u8) as char).collect()
                })
                .collect();
            let text = words.join(" ");
            let a = t.encode(&text);
            assert_eq!(a, t.encode(&text));
            assert_eq!(a.len(), words.len());
            for id in a {
                assert!(id < t.vocab_size());
            }
        }
    }

    #[test]
    fn prop_known_vocab_decode_encode_roundtrip() {
        let t = tok();
        for n in 1..20 {
            let text = vec!["the"; n].join(" ");
            let ids = t.encode(&text);
            assert_eq!(t.decode(&ids), text);
        }
    }
}
