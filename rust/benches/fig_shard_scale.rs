//! Shard-scaling bench — `load_many` miss throughput vs shard count,
//! plus the serve-time value of retrieval-aware hot-tier prefetch.
//!
//! Three phases:
//!
//! 1. **JBOD scaling** (no artifacts needed): materialize one corpus per
//!    shard count, then load it back cold in `load_many` batches and
//!    measure wall time. Per-chunk simulated device time is identical at
//!    every shard count, so any wall-time win is pure *overlap* across
//!    independent device throttles. Shape to reproduce: near-linear
//!    scaling up to 4 shards (≥3x aggregate bandwidth at equal total
//!    bytes) once the batch is wide enough to cover the shards.
//! 2. **Prefetch** (no artifacts needed): a Zipf access stream served in
//!    batches from a tiered sharded store; warming batch *n+1* between
//!    demand batches (the work the overlap pipeline hides under decode)
//!    collapses the demand-visible load wall. Emits the hot tier's
//!    per-batch hit/miss/eviction telemetry series.
//! 3. **Overlap pipeline** (needs `make artifacts`; skipped otherwise):
//!    `serve_overlapped_with` prefetch off vs on at the same tier
//!    budget, reporting `exec_stall_secs`.
//!
//! `--smoke` shrinks everything for CI; `--json PATH` writes the rows
//! and telemetry series as JSON.

use std::fmt::Write as _;

use matkv::coordinator::{serve_overlapped_with, OverlapOptions, Scenario, ScenarioSpec, ServeMode};
use matkv::hwsim::StorageProfile;
use matkv::kvstore::{series_to_json, KvChunk, KvFormat, KvStore, TierMetrics};
use matkv::util::bench::Table;
use matkv::util::cli::Args;
use matkv::util::tempdir::TempDir;
use matkv::vectordb::ChunkId;
use matkv::workload::{Rng, Zipf};

fn chunk(seed: u32, seq: u32) -> KvChunk {
    let plane = (2 * 2 * seq * 8) as usize;
    KvChunk {
        config_id: 0x5ca1e,
        n_layers: 2,
        n_kv_heads: 2,
        seq_len: seq,
        head_dim: 8,
        k: (0..plane).map(|i| ((i + seed as usize) % 1024) as f32).collect(),
        v: (0..plane).map(|i| -(((i + seed as usize) % 1024) as f32)).collect(),
    }
}

/// A profile whose per-chunk read time is exactly `chunk_secs` — slow
/// enough that wall-time differences are dominated by the simulated
/// devices, fast enough that the full sweep stays CI-friendly.
fn sim_profile(file_bytes: usize, chunk_secs: f64) -> StorageProfile {
    StorageProfile {
        name: "sim-flash".into(),
        read_bw: file_bytes as f64 / chunk_secs,
        write_bw: 1e12,
        latency_s: 0.0,
        power_active: 1.0,
        power_idle: 0.0,
        usd_per_byte: 0.0,
    }
}

/// Materialize `n_chunks` under `dir` as an `n_shards` store and hand it
/// back with throttling enabled at `profile`.
fn build_store(
    dir: &TempDir,
    profile: &StorageProfile,
    n_shards: usize,
    n_chunks: usize,
    seq: u32,
) -> anyhow::Result<KvStore> {
    let mut s = KvStore::open_sharded(dir.path(), profile.clone(), n_shards)?;
    s.disable_throttle();
    for i in 0..n_chunks {
        s.store_sync(i as u64, &chunk(i as u32, seq))?;
    }
    s.set_profile(profile.clone()); // fresh, *enabled* per-shard throttles
    Ok(s)
}

fn main() -> anyhow::Result<()> {
    let args = Args::from_env().map_err(|e| anyhow::anyhow!(e))?;
    let smoke = args.flag("smoke");
    // 128 chunks keep the 4-shard routing imbalance small (max shard ≈
    // 35/128 → 3.66x ideal speedup), so the ≥3x acceptance shape has
    // headroom over pool/scheduling overhead.
    let n_chunks = args.usize("chunks", if smoke { 16 } else { 128 });
    let seq = args.usize("chunk-tokens", 256) as u32;
    let chunk_secs = args.f64("chunk-secs", if smoke { 0.002 } else { 0.005 });
    let shard_counts: Vec<usize> = if smoke { vec![1, 2, 4] } else { vec![1, 2, 4, 8] };
    let batch_sizes: Vec<usize> = if smoke { vec![n_chunks] } else { vec![4, 16, n_chunks] };

    let file_bytes = chunk(0, seq).file_bytes(KvFormat::V2);
    let total_mb = (file_bytes * n_chunks) as f64 / 1e6;
    let profile = sim_profile(file_bytes, chunk_secs);
    eprintln!(
        "[fig_shard_scale] {n_chunks} chunks x {seq} tokens ({total_mb:.1} MB), \
         {:.1}ms simulated device time per chunk",
        chunk_secs * 1e3
    );

    // ---- phase 1: JBOD miss-throughput scaling -------------------------
    let mut table = Table::new(
        &format!("load_many miss throughput vs shard count ({n_chunks} chunks, cold)"),
        &["shards", "batch", "wall (s)", "agg MB/s", "speedup", "dev sum (s)", "peak q"],
    );
    let mut json_rows = String::new();
    let mut speedup_at_4 = 0.0;
    for &batch in &batch_sizes {
        let mut base_wall = 0.0;
        for &n in &shard_counts {
            let dir = TempDir::new("matkv-fig-shard")?;
            let store = build_store(&dir, &profile, n, n_chunks, seq)?;
            let ids: Vec<ChunkId> = (0..n_chunks as u64).collect();
            let t0 = std::time::Instant::now();
            let mut device_sum = 0.0;
            for group in ids.chunks(batch) {
                for l in store.load_many(group)? {
                    device_sum += l.device_secs;
                }
            }
            let wall = t0.elapsed().as_secs_f64();
            if n == 1 {
                base_wall = wall;
            }
            let speedup = base_wall / wall;
            if n == 4 && batch == *batch_sizes.last().unwrap() {
                speedup_at_4 = speedup;
            }
            let peak_q = store.shard_peak_queues().into_iter().max().unwrap_or(0);
            table.row(&[
                n.to_string(),
                batch.to_string(),
                format!("{wall:.3}"),
                format!("{:.1}", total_mb / wall),
                format!("{speedup:.2}x"),
                format!("{device_sum:.3}"),
                peak_q.to_string(),
            ]);
            let _ = write!(
                json_rows,
                "{}{{\"shards\":{n},\"batch\":{batch},\"wall_secs\":{wall:.6},\
                 \"agg_mbps\":{:.3},\"speedup\":{speedup:.4},\"device_secs_sum\":{device_sum:.6},\
                 \"peak_queue\":{peak_q}}}",
                if json_rows.is_empty() { "" } else { "," },
                total_mb / wall,
            );
        }
    }
    table.print();
    println!(
        "\n4-shard speedup at batch {}: {speedup_at_4:.2}x (target: >=3x — per-chunk device \
         time is constant, the win is overlap across independent devices)",
        batch_sizes.last().unwrap()
    );

    // ---- phase 2: retrieval-aware prefetch on a tiered store -----------
    let accesses = args.usize("accesses", if smoke { 64 } else { 512 });
    let serve_batch = args.usize("serve-batch", 8);
    let pf_shards = shard_counts.last().copied().unwrap_or(1).min(4);
    let tier_budget = chunk(0, seq).dram_bytes() * n_chunks / 4; // 25% of corpus
    let zipf = Zipf::new(n_chunks, 1.0);
    let mut rng = Rng::new(777);
    let stream: Vec<ChunkId> = (0..accesses).map(|_| zipf.sample(&mut rng) as u64).collect();
    let batches: Vec<&[ChunkId]> = stream.chunks(serve_batch).collect();

    let mut walls = Vec::new();
    let mut series = Vec::new();
    let mut warmed_total = 0usize;
    for prefetch in [false, true] {
        let dir = TempDir::new("matkv-fig-shard-pf")?;
        let mut store = build_store(&dir, &profile, pf_shards, n_chunks, seq)?;
        store.set_hot_tier(tier_budget);
        let mut demand_wall = 0.0;
        for (i, group) in batches.iter().enumerate() {
            if prefetch {
                // The work the overlap pipeline's prefetcher does under
                // batch i's *decode*; not counted against the demand wall.
                if let Some(next) = batches.get(i + 1) {
                    warmed_total += store.prefetch_many(next).warmed;
                }
            }
            let t0 = std::time::Instant::now();
            store.load_many(group)?;
            demand_wall += t0.elapsed().as_secs_f64();
            if let Some(tier) = store.hot_tier() {
                tier.sample();
            }
        }
        walls.push(demand_wall);
        series.push(store.hot_tier().map(|t| t.stats.series()).unwrap_or_default());
    }
    let mut pf_table = Table::new(
        &format!(
            "prefetch: demand-visible load wall ({accesses} Zipf(1.0) accesses, batch \
             {serve_batch}, {pf_shards} shards, 25% tier)"
        ),
        &["mode", "demand load wall (s)", "vs baseline"],
    );
    pf_table.row(&["demand only".into(), format!("{:.3}", walls[0]), "1.00x".into()]);
    pf_table.row(&[
        "with prefetch".into(),
        format!("{:.3}", walls[1]),
        format!("{:.2}x", walls[0] / walls[1]),
    ]);
    pf_table.print();
    println!(
        "\nprefetch warmed {warmed_total} chunks ahead of demand; the demand path's \
         device reads shrink to the tier's misses."
    );

    // ---- phase 3: overlap pipeline exec stalls (needs artifacts) -------
    let mut overlap_json = String::from("null");
    if matkv::manifest::artifacts_present() {
        let mut stalls = Vec::new();
        for prefetch in [false, true] {
            let sc = Scenario::build(ScenarioSpec {
                n_docs: if smoke { 6 } else { 12 },
                doc_tokens: 256,
                storage: StorageProfile::ssd_9100pro(),
                hot_tier_bytes: 512 << 20,
                shards: pf_shards,
                seed: 21,
                ..ScenarioSpec::default()
            })?;
            let reqs = sc.requests(if smoke { 8 } else { 24 }, 2, 8);
            let opts = OverlapOptions { prefetch, ..OverlapOptions::default() };
            let (_, _, rep) =
                serve_overlapped_with(&sc.engine, &reqs, 4, ServeMode::MatKv, &opts)?;
            println!(
                "overlap ({}): exec stalls {:.4}s, loader busy {:.3}s, prefetch warmed {}",
                if prefetch { "prefetch on " } else { "prefetch off" },
                rep.exec_stall_secs,
                rep.loader_busy_secs,
                rep.prefetch_warmed,
            );
            stalls.push(rep.exec_stall_secs);
        }
        println!(
            "exec_stall_secs {:.4}s -> {:.4}s with retrieval-aware prefetch at the same \
             tier budget",
            stalls[0], stalls[1]
        );
        overlap_json = format!(
            "{{\"exec_stall_secs_baseline\":{:.6},\"exec_stall_secs_prefetch\":{:.6}}}",
            stalls[0], stalls[1]
        );
    } else {
        println!(
            "\n[fig_shard_scale] overlap-pipeline phase skipped: AOT artifacts not built \
             (run `make artifacts`)"
        );
    }

    if let Some(path) = args.opt("json") {
        let doc = format!(
            "{{\"bench\":\"fig_shard_scale\",\"smoke\":{smoke},\"chunks\":{n_chunks},\
             \"chunk_tokens\":{seq},\"file_bytes\":{file_bytes},\
             \"scale_rows\":[{json_rows}],\
             \"prefetch\":{{\"demand_wall_secs\":{:.6},\"prefetch_wall_secs\":{:.6},\
             \"warmed\":{warmed_total},\"series_baseline\":{},\"series_prefetch\":{}}},\
             \"overlap\":{overlap_json}}}",
            walls[0],
            walls[1],
            series_to_json(&series[0]),
            series_to_json(&series[1]),
        );
        std::fs::write(path, doc)?;
        eprintln!("[fig_shard_scale] wrote {path}");
    }
    Ok(())
}
