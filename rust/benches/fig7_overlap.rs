//! Fig 7 — effect of overlapping KV loading with decode, on the
//! "8B-class" (small) and "70B-class" (base) configs. Paper: MatKV w/
//! overlap achieves ~2x over Vanilla; the increment of overlap over
//! basic MatKV is modest when decode dominates. We report measured
//! wall-clock (where the loader thread and the simulated storage device
//! genuinely overlap with device compute) and simulated H100 time.

use matkv::coordinator::{serve_overlapped, Scenario, ScenarioSpec, ServeMode};
use matkv::hwsim::{ArchSpec, DeviceProfile, StorageProfile};
use matkv::util::bench::{fmt_secs, Table};
use matkv::util::cli::Args;

fn main() -> anyhow::Result<()> {
    let args = Args::from_env().map_err(|e| anyhow::anyhow!(e))?;
    let n = args.usize("requests", 16);
    let h100 = DeviceProfile::h100();
    let ssd = StorageProfile::raid0_4x9100();

    for (config, batch) in [("small", 8usize), ("base", 8)] {
        let arch = ArchSpec::standin_for(config);
        let sc = Scenario::build(ScenarioSpec {
            config: config.into(),
            storage: StorageProfile::raid0_4x9100(),
            n_docs: 12,
            doc_tokens: 1024,
            seed: 8,
            ..ScenarioSpec::default()
        })?;
        let reqs = sc.requests(n, 2, 20);

        let mut table = Table::new(
            &format!("Fig 7 — overlap effect, {config} config, batch {batch}, {n} reqs"),
            &["system", "wall", "sim H100 total", "vs Vanilla"],
        );
        let (_, v) = sc.engine.serve_all(&reqs, batch, ServeMode::Vanilla)?;
        let v_sim = v.total_secs_on(&arch, &h100, &ssd);
        table.row(&["Vanilla".into(), fmt_secs(v.total_wall_secs), fmt_secs(v_sim), "1.00x".into()]);

        let (_, m) = sc.engine.serve_all(&reqs, batch, ServeMode::MatKv)?;
        let m_sim = m.total_secs_on(&arch, &h100, &ssd);
        table.row(&[
            "MatKV".into(),
            fmt_secs(m.total_wall_secs),
            fmt_secs(m_sim),
            format!("{:.2}x", v_sim / m_sim),
        ]);

        let (_, mo, rep) = serve_overlapped(&sc.engine, &reqs, batch, ServeMode::MatKv)?;
        // overlap hides the load under decode of the previous batch;
        // only the first batch's load (pipeline fill) is exposed
        let gpu = mo.prefill_secs_on(&arch, &h100) + mo.decode_secs_on(&arch, &h100);
        let io = mo.load_secs_on(&arch, &ssd) + mo.upload_secs_on(&arch, &h100);
        let mo_sim = gpu.max(io) + io / rep.batches.max(1) as f64;
        table.row(&[
            "MatKV+OL".into(),
            fmt_secs(mo.total_wall_secs),
            fmt_secs(mo_sim),
            format!("{:.2}x", v_sim / mo_sim),
        ]);
        table.print();
        println!(
            "  overlap report: loader busy {:.2}s, exec busy {:.2}s, exec stalled {:.3}s",
            rep.loader_busy_secs, rep.exec_busy_secs, rep.exec_stall_secs
        );
    }
    println!("\npaper shape: MatKV+overlap ~2x over Vanilla on both model classes.");
    Ok(())
}
