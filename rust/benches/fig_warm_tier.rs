//! Warm-tier split bench — hot/warm partitions of a **fixed total DRAM
//! budget**, plus the table-VI fidelity cost of serving q8 chunks.
//!
//! MatKV's recompute-vs-storage trade recurs inside DRAM: a q8 plane
//! costs ~4x fewer resident bytes than the hot tier's f32 copy, so
//! giving part of the budget to a quantized warm tier holds strictly
//! more chunks — at the price of a modeled dequant pass per warm hit and
//! bounded quantization error in the served planes. Two phases:
//!
//! 1. **Equal-budget split sweep** (no artifacts needed): the same
//!    Zipf(1.0) access stream replayed against hot/warm splits of one
//!    DRAM budget — 100/0, 75/25, 50/50 on the q8 codec, plus 50/50 on
//!    q4 (same bytes, ~2x the warm chunks, coarser error bound, its own
//!    dequant rate). Shape to reproduce: at equal
//!    total bytes, every split with a warm share serves **strictly more
//!    chunks from DRAM** and issues **strictly fewer device reads** than
//!    hot-only, with the dequant seconds reported as the price. Emits
//!    both tiers' telemetry series (tier-labeled).
//! 2. **Fidelity** (needs `make artifacts`; skipped otherwise): the same
//!    request list served by a pure-f32 deployment and by one whose hot
//!    tier is small enough that repeat traffic is warm-served; outputs
//!    compared with the table-VI harness (token-F1 + exact-prefix).
//!    Target: mean token-F1 ≥ 0.95 vs the pure-f32 baseline.
//!
//! `--smoke` shrinks everything for CI; `--json PATH` writes rows,
//! telemetry and fidelity as JSON.

use std::fmt::Write as _;
use std::sync::atomic::Ordering;

use matkv::coordinator::baselines::fidelity;
use matkv::coordinator::{Scenario, ScenarioSpec, ServeMode};
use matkv::hwsim::StorageProfile;
use matkv::kvstore::{series_to_json, KvChunk, KvStore, TierMetrics, WarmMode};
use matkv::util::bench::Table;
use matkv::util::cli::Args;
use matkv::util::tempdir::TempDir;
use matkv::workload::{Rng, Zipf};

fn chunk(seed: u32, seq: u32) -> KvChunk {
    let plane = (2 * 2 * seq * 8) as usize;
    KvChunk {
        config_id: 0x9a12,
        n_layers: 2,
        n_kv_heads: 2,
        seq_len: seq,
        head_dim: 8,
        // off-grid payload: the q8 round trip is genuinely lossy here,
        // exercising the real dequant path (bounded by the codec tests)
        k: (0..plane).map(|i| ((i + seed as usize) as f32 * 0.37).sin() * 3.0).collect(),
        v: (0..plane).map(|i| ((i + seed as usize) as f32 * 0.53).cos() * 3.0).collect(),
    }
}

struct SplitRow {
    hot_pct: usize,
    warm_pct: usize,
    mode: &'static str,
    dram_served: u64,
    hot_hits: u64,
    warm_hits: u64,
    device_reads: u64,
    device_secs: f64,
    dequant_secs: f64,
    resident_chunks: usize,
    hot_series: String,
    warm_series: String,
}

fn main() -> anyhow::Result<()> {
    let args = Args::from_env().map_err(|e| anyhow::anyhow!(e))?;
    let smoke = args.flag("smoke");
    let n_chunks = args.usize("chunks", if smoke { 64 } else { 192 });
    let accesses = args.usize("accesses", if smoke { 800 } else { 4000 });
    let seq = args.usize("chunk-tokens", 128) as u32;
    let serve_batch = args.usize("serve-batch", 8);
    let budget_pct = args.usize("budget-pct", 25);
    let skew = args.f64("skew", 1.0);

    // Materialize once; every split reopens the same files with fresh
    // tiers so counters start clean.
    let dir = TempDir::new("matkv-fig-warm")?;
    {
        let mut w = KvStore::open(dir.path(), StorageProfile::ssd_9100pro())?;
        w.disable_throttle();
        for i in 0..n_chunks {
            w.store_sync(i as u64, &chunk(i as u32, seq))?;
        }
    }
    let per_chunk = chunk(0, seq).dram_bytes();
    let total_budget = per_chunk * n_chunks * budget_pct / 100;
    eprintln!(
        "[fig_warm_tier] {n_chunks} chunks x {seq} tokens, {accesses} Zipf({skew}) accesses, \
         total DRAM budget {:.1} MB ({budget_pct}% of corpus) split hot/warm",
        total_budget as f64 / 1e6
    );

    // ---- phase 1: equal-budget hot/warm split sweep --------------------
    let mut rows: Vec<SplitRow> = Vec::new();
    // Same splits as before, plus the 50/50 budget on the q4 codec: the
    // same warm bytes hold ~2x the chunks of q8, at a coarser error
    // bound and the q4 dequant rate.
    for &(hot_pct, warm_pct, mode) in &[
        (100usize, 0usize, WarmMode::Q8),
        (75, 25, WarmMode::Q8),
        (50, 50, WarmMode::Q8),
        (50, 50, WarmMode::Q4),
    ] {
        let mut store = KvStore::open(dir.path(), StorageProfile::ssd_9100pro())?;
        store.disable_throttle(); // device_secs still computed
        store.set_hot_tier(total_budget * hot_pct / 100);
        store.set_warm_tier(total_budget * warm_pct / 100);
        store.set_warm_mode(mode);
        let zipf = Zipf::new(n_chunks, skew);
        let mut rng = Rng::new(4242);
        let stream: Vec<u64> = (0..accesses).map(|_| zipf.sample(&mut rng) as u64).collect();
        let (mut dram_served, mut warm_hits, mut device_secs) = (0u64, 0u64, 0.0f64);
        for group in stream.chunks(serve_batch) {
            for l in store.load_many(group)? {
                dram_served += l.from_cache as u64;
                warm_hits += l.from_warm as u64;
                device_secs += l.device_secs;
            }
            if let Some(t) = store.hot_tier() {
                t.sample();
            }
            if let Some(t) = store.warm_tier() {
                t.sample();
            }
        }
        let hot_hits = store
            .hot_tier()
            .map(|t| t.stats.hits.load(Ordering::Relaxed))
            .unwrap_or(0);
        // whichever codec clock the mode charged — the rows stay
        // comparable as "modeled dequant seconds paid for the split"
        let dequant_secs = store
            .warm_tier()
            .map(|t| t.stats.dequant_secs() + t.stats.q4_dequant_secs())
            .unwrap_or(0.0);
        let resident_chunks = store.hot_tier().map(|t| t.len()).unwrap_or(0)
            + store.warm_tier().map(|t| t.len()).unwrap_or(0);
        rows.push(SplitRow {
            hot_pct,
            warm_pct,
            mode: mode.label(),
            dram_served,
            hot_hits,
            warm_hits,
            device_reads: store.stats.reads.load(Ordering::Relaxed),
            device_secs,
            dequant_secs,
            resident_chunks,
            hot_series: store
                .hot_tier()
                .map(|t| series_to_json(&t.stats.series()))
                .unwrap_or_else(|| "[]".into()),
            warm_series: store
                .warm_tier()
                .map(|t| series_to_json(&t.stats.series()))
                .unwrap_or_else(|| "[]".into()),
        });
    }

    let mut table = Table::new(
        &format!(
            "hot/warm split of a fixed DRAM budget ({:.1} MB, {accesses} Zipf({skew}) accesses)",
            total_budget as f64 / 1e6
        ),
        &[
            "split h/w",
            "codec",
            "resident",
            "DRAM-served",
            "hot hits",
            "warm hits",
            "device reads",
            "device (s)",
            "dequant (s)",
        ],
    );
    for r in &rows {
        table.row(&[
            format!("{}/{}", r.hot_pct, r.warm_pct),
            r.mode.to_string(),
            r.resident_chunks.to_string(),
            r.dram_served.to_string(),
            r.hot_hits.to_string(),
            r.warm_hits.to_string(),
            r.device_reads.to_string(),
            format!("{:.4}", r.device_secs),
            format!("{:.5}", r.dequant_secs),
        ]);
    }
    table.print();

    let base = &rows[0];
    for r in &rows[1..] {
        println!(
            "{}/{} {} vs hot-only at equal DRAM bytes: DRAM-served {} -> {} ({:+}), device reads \
             {} -> {} ({:+}), dequant price {:.5}s",
            r.hot_pct,
            r.warm_pct,
            r.mode,
            base.dram_served,
            r.dram_served,
            r.dram_served as i64 - base.dram_served as i64,
            base.device_reads,
            r.device_reads,
            r.device_reads as i64 - base.device_reads as i64,
            r.dequant_secs,
        );
        if r.dram_served <= base.dram_served || r.device_reads >= base.device_reads {
            eprintln!(
                "[fig_warm_tier] WARNING: split {}/{} ({}) did not strictly beat hot-only \
                 (DRAM-served {} vs {}, reads {} vs {})",
                r.hot_pct, r.warm_pct, r.mode, r.dram_served, base.dram_served,
                r.device_reads, base.device_reads
            );
        }
    }

    // ---- phase 2: table-VI fidelity of q8-served chunks ----------------
    let mut fidelity_json = String::from("null");
    if matkv::manifest::artifacts_present() {
        let n_docs = if smoke { 8 } else { 16 };
        let doc_tokens = 256usize;
        let n_reqs = if smoke { 12 } else { 32 };
        // Size the candidate's hot tier to ~2 chunks so repeat traffic is
        // served from the warm tier, not the hot one.
        let kv_chunk_bytes = {
            let m = matkv::Manifest::load(matkv::artifacts_dir())?;
            let cfg = m.config("tiny")?;
            let plane = cfg.n_layers * cfg.n_kv_heads * doc_tokens * cfg.head_dim;
            std::mem::size_of::<KvChunk>() + 8 * plane
        };
        fn serve_twice(
            spec: ScenarioSpec,
            n_reqs: usize,
        ) -> anyhow::Result<(
            Vec<matkv::coordinator::Response>,
            matkv::coordinator::PhaseBreakdown,
        )> {
            let sc = Scenario::build(spec)?;
            let reqs = sc.requests(n_reqs, 2, 8);
            sc.engine.serve_all(&reqs, 4, ServeMode::MatKv)?; // warm-up pass
            sc.engine.serve_all(&reqs, 4, ServeMode::MatKv)
        }
        let (reference, _) = serve_twice(ScenarioSpec {
            n_docs,
            doc_tokens,
            storage: StorageProfile::ssd_9100pro(),
            hot_tier_bytes: 64 << 20, // everything stays f32
            seed: 33,
            ..ScenarioSpec::default()
        }, n_reqs)?;
        let (candidate, cm) = serve_twice(ScenarioSpec {
            n_docs,
            doc_tokens,
            storage: StorageProfile::ssd_9100pro(),
            hot_tier_bytes: 2 * kv_chunk_bytes,
            warm_tier_bytes: 16 << 20,
            seed: 33,
            ..ScenarioSpec::default()
        }, n_reqs)?;
        let f = fidelity(&reference, &candidate);
        println!(
            "\nfidelity of q8-served chunks vs pure f32 ({} pairs, {} warm hits in the \
             measured pass): token-F1 {:.4}, exact-prefix {:.1} tokens, {} exact matches \
             (target: mean F1 >= 0.95)",
            f.pairs, cm.warm_hits, f.mean_f1, f.mean_prefix, f.exact
        );
        if cm.warm_hits == 0 {
            eprintln!(
                "[fig_warm_tier] WARNING: candidate pass served no warm hits — fidelity \
                 comparison is vacuous"
            );
        }
        if f.mean_f1 < 0.95 {
            eprintln!("[fig_warm_tier] WARNING: mean token-F1 {:.4} below the 0.95 target", f.mean_f1);
        }
        fidelity_json = format!(
            "{{\"pairs\":{},\"warm_hits\":{},\"mean_f1\":{:.6},\"mean_prefix\":{:.3},\
             \"exact\":{},\"dequant_secs\":{:.6}}}",
            f.pairs, cm.warm_hits, f.mean_f1, f.mean_prefix, f.exact, cm.dequant_secs
        );
    } else {
        println!(
            "\n[fig_warm_tier] fidelity phase skipped: AOT artifacts not built \
             (run `make artifacts`)"
        );
    }

    if let Some(path) = args.opt("json") {
        let mut split_rows = String::new();
        for r in &rows {
            let _ = write!(
                split_rows,
                "{}{{\"hot_pct\":{},\"warm_pct\":{},\"warm_mode\":\"{}\",\
                 \"resident_chunks\":{},\
                 \"dram_served\":{},\"hot_hits\":{},\"warm_hits\":{},\"device_reads\":{},\
                 \"device_secs\":{:.6},\"dequant_secs\":{:.6},\
                 \"hot_series\":{},\"warm_series\":{}}}",
                if split_rows.is_empty() { "" } else { "," },
                r.hot_pct,
                r.warm_pct,
                r.mode,
                r.resident_chunks,
                r.dram_served,
                r.hot_hits,
                r.warm_hits,
                r.device_reads,
                r.device_secs,
                r.dequant_secs,
                r.hot_series,
                r.warm_series,
            );
        }
        let doc = format!(
            "{{\"bench\":\"fig_warm_tier\",\"smoke\":{smoke},\"chunks\":{n_chunks},\
             \"accesses\":{accesses},\"chunk_tokens\":{seq},\"budget_pct\":{budget_pct},\
             \"total_budget_bytes\":{total_budget},\"skew\":{skew},\
             \"splits\":[{split_rows}],\"fidelity\":{fidelity_json}}}"
        );
        std::fs::write(path, doc)?;
        eprintln!("[fig_warm_tier] wrote {path}");
    }
    Ok(())
}
