//! Micro-benchmarks of the L3 hot paths (the §Perf profiling input):
//! KV load+decode, state splice, state upload, one decode step, logits
//! read, vector search. Warmup + repeated timed iterations via
//! util::bench (criterion is unavailable offline).

use matkv::hwsim::StorageProfile;
use matkv::kvstore::{KvChunk, KvStore};
use matkv::runtime::{HostState, ModelSession};
use matkv::util::bench::measure;
use matkv::util::cli::Args;
use matkv::util::tempdir::TempDir;
use matkv::vectordb::{FlatIndex, HashEmbedder, VectorIndex};
use matkv::Manifest;

fn main() -> anyhow::Result<()> {
    let args = Args::from_env().map_err(|e| anyhow::anyhow!(e))?;
    let iters = args.usize("iters", 20);
    let m = Manifest::load(matkv::artifacts_dir())?;
    let cfg = m.config("small")?.clone();

    println!("=== hotpath micro-benchmarks (config=small, iters={iters}) ===");

    // --- kvstore: load a 1024-token chunk (throttle disabled: pure code path)
    let dir = TempDir::new("matkv-micro")?;
    let mut store = KvStore::open(dir.path(), StorageProfile::dram())?;
    store.disable_throttle();
    let plane = cfg.n_layers * cfg.n_kv_heads * 1024 * cfg.head_dim;
    let chunk = KvChunk {
        config_id: 1,
        n_layers: cfg.n_layers as u32,
        n_kv_heads: cfg.n_kv_heads as u32,
        seq_len: 1024,
        head_dim: cfg.head_dim as u32,
        k: vec![0.5; plane],
        v: vec![-0.5; plane],
    };
    store.store_sync(1, &chunk)?;
    let mb = store.encoded_bytes(&chunk) as f64 / 1e6;
    let s = measure(3, iters, || store.load(1).unwrap());
    println!("kvstore.load ({mb:.1} MB v2 file)    : {s}  ({:.0} MB/s)", mb / s.mean);

    // --- kvstore: same load served by the DRAM hot tier (Arc clone, no
    // file read, no decode)
    let mut hot_store = KvStore::open(dir.path(), StorageProfile::dram())?;
    hot_store.disable_throttle();
    hot_store.set_hot_tier(256 << 20);
    hot_store.load(1)?; // warm the tier
    let s = measure(3, iters, || hot_store.load(1).unwrap());
    println!("kvstore.load (hot-tier hit)       : {s}");

    // --- quantized codecs: measured throughput side by side with the
    // modeled bytes/sec constants the simulator charges for warm-tier
    // and v4 cool-path traffic (the constants stand in for an
    // accelerator-side unpack; this cross-check catches them drifting
    // absurdly far from what any real code path achieves)
    {
        use matkv::hwsim::profiles::{
            Q4_DEQUANT_BYTES_PER_SEC, Q4_QUANT_BYTES_PER_SEC, Q8_DEQUANT_BYTES_PER_SEC,
            Q8_QUANT_BYTES_PER_SEC,
        };
        use matkv::kvstore::{dequantize, dequantize_q4, quantize, quantize_q4};
        let q8 = quantize(&chunk);
        let q4 = quantize_q4(&chunk);
        let q8_payload = q8.q8_bytes() as f64;
        let q4_payload = q4.q4_bytes() as f64;
        let rows: [(&str, f64, f64); 4] = [
            ("quantize q8", q8_payload / measure(3, iters, || quantize(&chunk)).mean, Q8_QUANT_BYTES_PER_SEC),
            ("dequantize q8", q8_payload / measure(3, iters, || dequantize(&q8)).mean, Q8_DEQUANT_BYTES_PER_SEC),
            ("quantize q4", q4_payload / measure(3, iters, || quantize_q4(&chunk)).mean, Q4_QUANT_BYTES_PER_SEC),
            ("dequantize q4", q4_payload / measure(3, iters, || dequantize_q4(&q4)).mean, Q4_DEQUANT_BYTES_PER_SEC),
        ];
        let f32_mb = (chunk.k.len() + chunk.v.len()) as f64 * 4.0 / 1e6;
        for (name, measured, modeled) in rows {
            println!(
                "{name:14} ({f32_mb:.1} MB f32 chunk) : measured {:.2} GB/s payload | modeled {:.1} GB/s",
                measured / 1e9,
                modeled / 1e9,
            );
            let ratio = modeled / measured;
            if !(0.25..=4.0).contains(&ratio) {
                eprintln!(
                    "[hotpath_micro] WARNING: {name} modeled rate diverges {ratio:.1}x from \
                     this host's codec ({:.2} vs {:.2} GB/s)",
                    modeled / 1e9,
                    measured / 1e9,
                );
            }
        }
    }

    // --- state splice (host memcpy choreography)
    let mut host = HostState::zeros(&cfg, 8, cfg.max_ctx);
    let s = measure(3, iters, || host.splice_chunk(3, 0, &chunk).unwrap());
    println!("HostState.splice_chunk ({mb:.1} MB)  : {s}  ({:.0} MB/s)", mb / s.mean);

    // --- session: upload, decode step, logits read
    let sess = ModelSession::new(&m, "small")?;
    let host8 = HostState::zeros(&cfg, 8, cfg.max_ctx);
    let s = measure(2, iters.min(10), || sess.upload_state(&host8).unwrap());
    let state_mb = host8.data.len() as f64 * 4.0 / 1e6;
    println!("upload_state (b=8, {state_mb:.0} MB)   : {s}  ({:.0} MB/s)", state_mb / s.mean);

    // the AOT entries donate the state buffer, so the decode loop must
    // chain states exactly as the engine does
    let mut state = sess.upload_state(&host8)?;
    sess.warmup(&[(1, 8, cfg.max_ctx)])?;
    let tokens = vec![5i32; 8];
    let qlen = vec![1i32; 8];
    let clen = vec![128i32; 8];
    let s = measure(3, iters, || {
        state = sess.step(&tokens, &qlen, &clen, &state).unwrap();
    });
    println!("decode step (s=1, b=8)            : {s}");

    let s = measure(3, iters, || sess.read_logits(&state).unwrap());
    println!("read_logits (b=8 x {} vocab)    : {s}", cfg.vocab);

    // --- trace recorder: the disabled path must be free on the
    // hottest instrumented loop. Per reservation the recorder adds one
    // relaxed atomic branch; wiring a disabled bus must stay within 2%
    // of the never-wired link (a recording bus shown for contrast).
    {
        use matkv::hwsim::{Link, LinkClock, TrafficClass};
        use matkv::trace::TraceBus;
        let inner = 20_000usize;
        let mut run = |link: &Link, reps: usize| {
            let mut t = 0.0f64;
            measure(3, reps, || {
                for i in 0..inner {
                    t = link.reserve_at(t, 4096 + (i & 1023), TrafficClass::H2D).end;
                }
            })
        };
        let bare = Link::new("pcie", 64e9, 0.0, LinkClock::Virtual);
        let s_bare = run(&bare, iters);
        println!("link.reserve_at x{inner} (no trace)  : {s_bare}");
        let wired = Link::new("pcie", 64e9, 0.0, LinkClock::Virtual);
        wired.set_trace(TraceBus::disabled(), "link:micro");
        let s_wired = run(&wired, iters);
        let overhead = s_wired.mean / s_bare.mean - 1.0;
        println!(
            "link.reserve_at x{inner} (trace off) : {s_wired}  ({:+.2}% vs no trace)",
            overhead * 100.0
        );
        if overhead > 0.02 {
            eprintln!(
                "[hotpath_micro] WARNING: disabled-path trace recorder costs {:.2}% on \
                 the link hot loop (> 2%) — the trace_on gate is not cheap enough",
                overhead * 100.0
            );
        }
        let rec = Link::new("pcie", 64e9, 0.0, LinkClock::Virtual);
        let bus = TraceBus::recording();
        rec.set_trace(bus.clone(), "link:micro");
        let s_rec = run(&rec, iters.min(5));
        println!(
            "link.reserve_at x{inner} (recording) : {s_rec}  ({} events kept)",
            bus.len()
        );
    }

    // --- metrics registry: a Counter increment is one relaxed atomic
    // fetch_add behind an Arc — instrumenting a hot loop with a
    // registry counter must stay within 2% of bumping a raw field.
    {
        use std::sync::atomic::{AtomicU64, Ordering};
        let inner = 20_000usize;
        let raw = AtomicU64::new(0);
        let s_raw = measure(3, iters, || {
            for i in 0..inner {
                raw.fetch_add((i & 1) as u64 + 1, Ordering::Relaxed);
            }
        });
        println!("counter x{inner} (raw AtomicU64)     : {s_raw}");
        let reg = matkv::obs::MetricsRegistry::new();
        let c = reg.counter("matkv.micro.events", &[], "hot-loop overhead probe")?;
        let s_reg = measure(3, iters, || {
            for i in 0..inner {
                c.add((i & 1) as u64 + 1);
            }
        });
        let overhead = s_reg.mean / s_raw.mean - 1.0;
        println!(
            "counter x{inner} (registry Counter)  : {s_reg}  ({:+.2}% vs raw field)",
            overhead * 100.0
        );
        if overhead > 0.02 {
            eprintln!(
                "[hotpath_micro] WARNING: registry counter increments cost {:.2}% over a \
                 raw atomic field (> 2%) — the instrument handle is not cheap enough",
                overhead * 100.0
            );
        }
    }

    // --- vector search over 10K docs
    let emb = HashEmbedder::new(128, 7);
    let mut ix = FlatIndex::new(128);
    for i in 0..10_000u64 {
        ix.insert(i, emb.embed(&[(i % 997) as u32, (i % 31) as u32, (i % 7) as u32]));
    }
    let q = emb.embed(&[3, 9, 27]);
    let s = measure(3, iters, || ix.search(&q, 10));
    println!("FlatIndex.search (10K x 128d)     : {s}");

    Ok(())
}
