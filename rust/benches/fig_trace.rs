//! fig_trace — where did the p99 go? Critical-path attribution from
//! the deterministic trace, at high offered load.
//!
//! The tracing subsystem (`matkv::trace`) records every dispatch
//! window and link reservation on the virtual clock, and the fleet
//! attributes each request's end-to-end latency to six components
//! (queue / storage / bus / PCIe wire / compute / retry) that must sum
//! back to the latency within epsilon. This bench drives the same
//! transfer-dominant regime as `fig_bus` — large chunks, high top-k,
//! 2-token outputs, one mixed fleet — at a single high offered rate,
//! with PCIe contention on, and asks the trace the tail question
//! directly: for the **worst-latency request**, which component
//! dominates?
//!
//! Acceptance shape: under contention the answer must be the
//! interconnect — time *queued* (on the H2D links or behind earlier
//! batches the links delayed), not storage or compute (WARNING
//! otherwise — CI asserts the attribution error and span counts via
//! `trace_smoke.json`). Two independent traced dispatches of the same
//! plan must export byte-identical files; the bench checks that here
//! rather than trusting the unit tests alone.
//!
//! Pure-rust: golden manifest retrieval, stand-in architecture costs,
//! virtual clock. `--smoke` shrinks everything; `--json PATH` writes
//! the assertion document; `--trace PATH` writes the Perfetto file.

use std::sync::{Arc, Mutex};

use matkv::coordinator::engine::{EngineOptions, LoaderCtx, Retrieval};
use matkv::coordinator::{
    BatchPolicy, Fleet, FleetCostModel, FleetSpec, Routing, SchedOptions, SchedPolicy, Scheduler,
};
use matkv::hwsim::{ArchSpec, StorageProfile};
use matkv::kvstore::KvStore;
use matkv::obs::{MetricsRegistry, Sampler};
use matkv::manifest::Manifest;
use matkv::trace::TraceBus;
use matkv::util::bench::Table;
use matkv::util::cli::Args;
use matkv::util::tempdir::TempDir;
use matkv::workload::{ArrivalGen, Corpus, TimedRequest, TurboRagProfile};

fn main() -> anyhow::Result<()> {
    let args = Args::from_env().map_err(|e| anyhow::anyhow!(e))?;
    let smoke = args.flag("smoke");
    let n_docs = args.usize("docs", if smoke { 32 } else { 64 });
    let requests = args.usize("requests", if smoke { 48 } else { 160 });
    let batch = args.usize("batch", 8);
    let skew = args.f64("skew", 1.1);
    let rate = args.f64("rate", 400.0);
    let contention = match args.str("pcie-contention", "on").as_str() {
        "on" => true,
        "off" => false,
        other => anyhow::bail!("--pcie-contention takes on|off, got {other}"),
    };
    // The fig_bus transfer-dominant regime: the upload is the batch.
    let chunk_tokens = 1024usize;
    let top_k = 8usize;
    let output_tokens = 2usize;
    let fleet_spec = "h100:1,rtx4090:3";

    let m = Manifest::load_or_golden()?;
    let cfg = m.config("tiny")?.clone();
    let corpus = Corpus::generate(n_docs, 64, n_docs, 42);

    let retrieval = {
        let opts = EngineOptions::for_config(&m, "tiny")?;
        Arc::new(Retrieval::for_corpus(corpus.texts(), cfg.vocab as u32, opts.embed_dim))
    };
    {
        let mut ix = retrieval.index.write().unwrap();
        for d in &corpus.docs {
            let (ids, _) = retrieval.tokenizer.encode_block(&d.text, chunk_tokens);
            ix.insert(d.id, retrieval.embedder.embed(&ids));
        }
    }
    let dir = TempDir::new("matkv-fig-trace")?;
    let mut kv = KvStore::open_sharded(dir.path(), StorageProfile::ssd_9100pro(), 1)?;
    kv.disable_throttle();
    let kv = Arc::new(kv);

    let model = FleetCostModel {
        arch: ArchSpec::llama_70b(),
        storage: StorageProfile::dram(),
        chunk_tokens,
        query_tokens: 20,
        chunk_step: 256,
    };
    let spec = FleetSpec::parse(fleet_spec)?;
    let estimator = Fleet::new(&spec, Routing::RoleAware, model.clone()).service_estimator();

    eprintln!(
        "[fig_trace] {requests} reqs Zipf({skew}) @ {rate}/s over {n_docs} docs, top-k {top_k}, \
         {chunk_tokens}-token chunks, fleet {fleet_spec}, pcie {}",
        if contention { "queued" } else { "flat" }
    );

    let trace_reqs: Vec<TimedRequest> = ArrivalGen::new(
        TurboRagProfile { top_k, query_tokens: 20.0, output_tokens },
        corpus.n_topics,
        skew,
        rate,
        7,
    )
    .take(&corpus, requests);
    let ctx = LoaderCtx {
        retrieval: retrieval.clone(),
        kv: kv.clone(),
        cfg: cfg.clone(),
        opts: EngineOptions::for_config(&m, "tiny")?,
    };
    let mut sched = Scheduler::new(
        ctx,
        SchedOptions {
            batch: BatchPolicy { max_batch: batch, max_wait_secs: 0.05 },
            policy: SchedPolicy::Fifo,
            service_estimate_secs: 0.0,
            estimator: Some(estimator.clone()),
        },
    );
    let sched_bus = TraceBus::recording();
    sched.set_trace(sched_bus.clone());
    sched.enqueue_timed(trace_reqs);
    let plan = sched.plan_with_retrieval();

    // Same plan, two independently-traced dispatches: the exports must
    // be byte-identical — the bench-level restatement of the unit test,
    // over a real planned schedule. Each run carries its own metrics
    // registry + sampler, so the registry series export gets the same
    // determinism check as the trace itself.
    let run = |bus: TraceBus| -> anyhow::Result<(
        matkv::coordinator::FleetReport,
        TraceBus,
        String,
    )> {
        let reg = MetricsRegistry::new();
        let sampler = Arc::new(Mutex::new(Sampler::new(reg.clone(), 0.05)));
        let mut fleet = Fleet::new(&spec, Routing::RoleAware, model.clone());
        fleet.register_metrics(&reg)?;
        fleet.set_sampler(sampler.clone());
        fleet.set_contention(contention);
        fleet.set_trace(bus.clone());
        let rep = fleet.dispatch(&plan.batches, &|_| true);
        let series = sampler.lock().unwrap().to_json();
        Ok((rep, bus, series))
    };
    let (rep, bus, series) = run(TraceBus::recording())?;
    let (_, bus2, series2) = run(TraceBus::recording())?;
    let export = bus.to_chrome_json();
    let deterministic = export == bus2.to_chrome_json();
    if !deterministic {
        eprintln!(
            "[fig_trace] WARNING: two traced dispatches of the same plan exported \
             different bytes — the trace is not deterministic"
        );
    }
    let series_deterministic = series == series2;
    if !series_deterministic {
        eprintln!(
            "[fig_trace] WARNING: two sampled dispatches of the same plan exported \
             different series bytes — the registry sampler is not deterministic"
        );
    }

    let paths = bus.paths();
    let max_err = bus.max_attribution_err();
    if paths.len() != rep.requests {
        eprintln!(
            "[fig_trace] WARNING: {} attribution records for {} requests",
            paths.len(),
            rep.requests
        );
    }
    if max_err > 1e-6 {
        eprintln!(
            "[fig_trace] WARNING: attribution components miss end-to-end latency by \
             {max_err:.3e}s (> 1e-6)"
        );
    }

    let worst = paths
        .iter()
        .max_by(|a, b| a.latency_secs().total_cmp(&b.latency_secs()))
        .expect("dispatch produced at least one request path");
    let (dom_name, dom_secs) = worst.dominant();

    // The waterfall: the worst request's latency, component by
    // component, in path order.
    let lat = worst.latency_secs();
    let parts = [
        ("queue", worst.queue_secs),
        ("storage", worst.storage_secs),
        ("bus", worst.bus_secs),
        ("pcie", worst.pcie_secs),
        ("compute", worst.compute_secs),
        ("retry", worst.retry_secs),
    ];
    println!(
        "worst request {} on {} — {:.1}ms arrival→done (attribution err {:.2e}s over {} paths):",
        worst.request_id,
        worst.worker,
        lat * 1e3,
        max_err,
        paths.len(),
    );
    for (name, secs) in parts {
        let width = if lat > 0.0 { (40.0 * secs / lat).round() as usize } else { 0 };
        println!(
            "  {name:8} {:>9.3}ms {:>5.1}% |{}",
            secs * 1e3,
            100.0 * secs / lat.max(1e-12),
            "#".repeat(width.min(40)),
        );
    }
    println!("  dominant: {dom_name} ({:.1}ms)", dom_secs * 1e3);

    // Under contention the tail must be an interconnect story: the
    // dominant component is time spent waiting on or behind the links
    // (queue includes waiting for a worker whose links delayed earlier
    // batches; bus is this request's own queued link seconds).
    if contention && !matches!(dom_name, "queue" | "bus") {
        eprintln!(
            "[fig_trace] WARNING: with --pcie-contention on the worst request's \
             dominant component is {dom_name}, not link queueing — the contention \
             model is not shaping the tail"
        );
    }

    let mut table = Table::new(
        &format!(
            "p99 attribution — {fleet_spec}, role-aware, {rate:.0} req/s, pcie {}",
            if contention { "queued" } else { "flat" }
        ),
        &["component", "worst req (ms)", "fleet mean (ms)", "share of worst"],
    );
    let n = paths.len().max(1) as f64;
    let means = [
        ("queue", paths.iter().map(|p| p.queue_secs).sum::<f64>() / n),
        ("storage", paths.iter().map(|p| p.storage_secs).sum::<f64>() / n),
        ("bus", paths.iter().map(|p| p.bus_secs).sum::<f64>() / n),
        ("pcie", paths.iter().map(|p| p.pcie_secs).sum::<f64>() / n),
        ("compute", paths.iter().map(|p| p.compute_secs).sum::<f64>() / n),
        ("retry", paths.iter().map(|p| p.retry_secs).sum::<f64>() / n),
    ];
    for ((name, secs), (_, mean)) in parts.iter().zip(&means) {
        table.row(&[
            name.to_string(),
            format!("{:.3}", secs * 1e3),
            format!("{:.3}", mean * 1e3),
            format!("{:.1}%", 100.0 * secs / lat.max(1e-12)),
        ]);
    }
    table.print();

    if let Some(path) = args.opt("trace") {
        std::fs::write(path, &export)?;
        eprintln!("[fig_trace] wrote trace ({} events) to {path}", bus.len());
    }
    if let Some(path) = args.opt("json") {
        let doc = format!(
            "{{\"bench\":\"fig_trace\",\"smoke\":{smoke},\"requests\":{requests},\
             \"batch\":{batch},\"docs\":{n_docs},\"rate\":{rate},\"skew\":{skew},\
             \"fleet\":\"{fleet_spec}\",\"contention\":{contention},\
             \"spans\":{},\"sched_events\":{},\"paths\":{},\
             \"max_attribution_err_secs\":{:.12},\"deterministic\":{deterministic},\
             \"series_deterministic\":{series_deterministic},\
             \"worst\":{},\"dominant\":\"{dom_name}\",\"dominant_secs\":{:.9},\
             \"series\":{series}}}",
            bus.len(),
            sched_bus.len(),
            paths.len(),
            max_err,
            worst.to_json(),
            dom_secs,
        );
        std::fs::write(path, doc)?;
        eprintln!("[fig_trace] wrote {path}");
    }
    Ok(())
}
