//! Fig 8 — effect of input size (a: 1..4 retrieved chunks) and output
//! length (b: 20..100 generated tokens) on MatKV's advantage, batch 1.
//! Shape to reproduce: (a) more input chunks widen MatKV's relative gain
//! (prefill grows, load grows slower); (b) longer outputs shrink the
//! relative gain (decode dominates) but MatKV stays ahead.

use matkv::coordinator::{Scenario, ScenarioSpec, ServeMode};
use matkv::hwsim::{ArchSpec, DeviceProfile, StorageProfile};
use matkv::util::bench::Table;
use matkv::util::cli::Args;

fn main() -> anyhow::Result<()> {
    let args = Args::from_env().map_err(|e| anyhow::anyhow!(e))?;
    let n = args.usize("requests", 4);
    let h100 = DeviceProfile::h100();
    let ssd = StorageProfile::raid0_4x9100();
    let arch = ArchSpec::llama_70b();

    // 512-token documents so 4 chunks still fit the serve context.
    let sc = Scenario::build(ScenarioSpec {
        config: "base".into(),
        storage: StorageProfile::raid0_4x9100(),
        n_docs: 16,
        doc_tokens: 512,
        seed: 12,
        ..ScenarioSpec::default()
    })?;

    // --- (a) vary number of retrieved chunks -------------------------------
    let mut ta = Table::new(
        &format!("Fig 8a — input size sweep ({n} reqs, 512-tok chunks, 20 out, batch 1, sim H100 s)"),
        &["chunks", "V total", "M total", "gain"],
    );
    for top_k in 1..=4usize {
        let reqs = sc.requests(n, top_k, 20);
        let (_, v) = sc.engine.serve_all(&reqs, 1, ServeMode::Vanilla)?;
        let (_, m) = sc.engine.serve_all(&reqs, 1, ServeMode::MatKv)?;
        let (vt, mt) = (v.total_secs_on(&arch, &h100, &ssd), m.total_secs_on(&arch, &h100, &ssd));
        ta.row(&[
            top_k.to_string(),
            format!("{vt:.3}"),
            format!("{mt:.3}"),
            format!("{:.2}x", vt / mt),
        ]);
    }
    ta.print();

    // --- (b) vary output length ---------------------------------------------
    let mut tb = Table::new(
        &format!("Fig 8b — output length sweep ({n} reqs, 2 chunks, batch 1, sim H100 s)"),
        &["out tokens", "V total", "M total", "gain"],
    );
    for out in [20usize, 40, 60, 80, 100] {
        let reqs = sc.requests(n, 2, out);
        let (_, v) = sc.engine.serve_all(&reqs, 1, ServeMode::Vanilla)?;
        let (_, m) = sc.engine.serve_all(&reqs, 1, ServeMode::MatKv)?;
        let (vt, mt) = (v.total_secs_on(&arch, &h100, &ssd), m.total_secs_on(&arch, &h100, &ssd));
        tb.row(&[
            out.to_string(),
            format!("{vt:.3}"),
            format!("{mt:.3}"),
            format!("{:.2}x", vt / mt),
        ]);
    }
    tb.print();
    println!("\npaper shape: gain widens with more chunks (8a), narrows with longer outputs (8b), MatKV always ahead.");
    Ok(())
}
