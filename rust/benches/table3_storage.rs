//! Table III — impact of storage performance on MatKV load time.
//! Paper: 128 requests; per-request average load time and total load
//! time for one 9100 Pro, 4x RAID-0, and DRAM. We run a scaled request
//! count through the same pipeline, swapping the simulated storage
//! device. Shape to reproduce: DRAM < RAID-0 < single SSD, roughly
//! proportional to 1/bandwidth.

use matkv::coordinator::{Scenario, ScenarioSpec, ServeMode};
use matkv::hwsim::StorageProfile;
use matkv::util::bench::Table;
use matkv::util::cli::Args;

fn main() -> anyhow::Result<()> {
    let args = Args::from_env().map_err(|e| anyhow::anyhow!(e))?;
    let n = args.usize("requests", 16);

    let mut sc = Scenario::build(ScenarioSpec {
        config: "base".into(), // biggest KVs -> measurable load differences
        storage: StorageProfile::ssd_9100pro(),
        n_docs: 8,
        doc_tokens: 1024,
        seed: 9,
        ..ScenarioSpec::default()
    })?;
    let reqs = sc.requests(n, 2, 4);

    let mut table = Table::new(
        &format!("Table III — impact of storage performance ({n} requests, base config)"),
        &["storage", "per-req avg load (s)", "total load (s)", "wall load (s)"],
    );

    for profile in [
        StorageProfile::ssd_9100pro(),
        StorageProfile::raid0_4x9100(),
        StorageProfile::dram(),
    ] {
        let name = profile.name.clone();
        sc.set_storage(profile);
        let (_, m) = sc.engine.serve_all(&reqs, 1, ServeMode::MatKv)?;
        table.row(&[
            name,
            format!("{:.4}", m.load_device_secs / n as f64),
            format!("{:.3}", m.load_device_secs),
            format!("{:.3}", m.load_wall_secs),
        ]);
    }
    table.print();
    println!("\npaper row ratios (single : RAID : DRAM) = 0.093 : 0.027 : 0.006 per request");
    Ok(())
}
