//! Fig 5 — single-request (batch=1) prefill/decode latency, Vanilla vs
//! MatKV, on the "70B-class" (base) config. Paper ran 1,024 sequential
//! requests of 2x1024-token chunks + 20-token query + 20-token answer;
//! we run a scaled count with identical per-request shape and report
//! both measured wall-clock (CPU PJRT + simulated flash) and simulated
//! H100 phase times. Shape to reproduce: MatKV's (load + sub-prefill)
//! is well under half of Vanilla's prefill; decode dominates both.

use matkv::coordinator::{Scenario, ScenarioSpec, ServeMode};
use matkv::hwsim::{ArchSpec, DeviceProfile, StorageProfile};
use matkv::util::bench::{fmt_secs, Table};
use matkv::util::cli::Args;

fn main() -> anyhow::Result<()> {
    let args = Args::from_env().map_err(|e| anyhow::anyhow!(e))?;
    let n = args.usize("requests", 12);
    let config = args.str("config", "base");

    let sc = Scenario::build(ScenarioSpec {
        config,
        storage: StorageProfile::raid0_4x9100(),
        n_docs: 12,
        doc_tokens: 1024,
        seed: 5,
        ..ScenarioSpec::default()
    })?;
    let reqs = sc.requests(n, 2, 20);
    let h100 = DeviceProfile::h100();
    let ssd = StorageProfile::raid0_4x9100();
    let arch = ArchSpec::llama_70b(); // base stands in for the paper's 70B

    let mut table = Table::new(
        &format!("Fig 5 — single-request latency, {n} reqs of 2x1024+20 tokens (base config)"),
        &["system", "load", "prefill", "decode", "total", "simH100 prefill", "simH100 decode"],
    );
    let mut totals = Vec::new();
    for (name, mode) in [("Vanilla", ServeMode::Vanilla), ("MatKV", ServeMode::MatKv)] {
        let (_, m) = sc.engine.serve_all(&reqs, 1, mode)?;
        let sim_prefill = m.load_secs_on(&arch, &ssd)
            + m.upload_secs_on(&arch, &h100)
            + m.prefill_secs_on(&arch, &h100);
        let sim_decode = m.decode_secs_on(&arch, &h100);
        totals.push((name, sim_prefill, sim_decode));
        table.row(&[
            name.to_string(),
            fmt_secs(m.load_wall_secs),
            fmt_secs(m.prefill_wall_secs),
            fmt_secs(m.decode_wall_secs),
            fmt_secs(m.total_wall_secs),
            fmt_secs(sim_prefill),
            fmt_secs(sim_decode),
        ]);
    }
    table.print();

    let vanilla_prefill = totals[0].1;
    let matkv_prefill = totals[1].1;
    println!(
        "\nshape check: MatKV prefill path = {:.2}x of Vanilla's (paper: < 0.5x); \
         end-to-end speedup {:.2}x (paper: ~1.7x at batch 1, decode-dominated)",
        matkv_prefill / vanilla_prefill,
        (vanilla_prefill + totals[0].2) / (matkv_prefill + totals[1].2)
    );
    Ok(())
}
