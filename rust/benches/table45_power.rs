//! Tables IV & V — system-wide and GPU-only power consumption for
//! Vanilla / MatKV / MatKV+overlap. Paper: 256 requests, batch 8, H100
//! server (idle 550W); MatKV+overlap halves total energy (566 -> 279 kJ
//! system-wide; 185 -> 95 kJ GPU) mostly by finishing twice as fast at
//! similar average power. We drive the pipeline, convert phases to
//! simulated H100 time, and integrate the same power model.

use matkv::coordinator::{serve_overlapped, Scenario, ScenarioSpec, ServeMode};
use matkv::hwsim::{ArchSpec, DeviceProfile, EnergyMeter, PhaseKind, StorageProfile};
use matkv::util::bench::Table;
use matkv::util::cli::Args;

fn main() -> anyhow::Result<()> {
    let args = Args::from_env().map_err(|e| anyhow::anyhow!(e))?;
    let n = args.usize("requests", 24);
    let batch = args.usize("batch", 8);
    let h100 = DeviceProfile::h100();
    let ssd = StorageProfile::raid0_4x9100();
    let arch = ArchSpec::llama_70b();

    let sc = Scenario::build(ScenarioSpec {
        config: "base".into(),
        storage: StorageProfile::raid0_4x9100(),
        n_docs: 12,
        doc_tokens: 1024,
        seed: 16,
        ..ScenarioSpec::default()
    })?;
    let reqs = sc.requests(n, 2, 20);

    let mut sys_table = Table::new(
        &format!("Table IV — system-wide power ({n} reqs, batch {batch}, simulated H100 server)"),
        &["system", "peak (W)", "avg (W)", "time (s)", "total (kJ)"],
    );
    let mut gpu_table = Table::new(
        "Table V — GPU power (same runs)",
        &["system", "peak (W)", "avg (W)", "time (s)", "total (kJ)"],
    );

    for (name, overlap) in [("Vanilla", false), ("MatKV", false), ("MatKV (w/ Overlap)", true)] {
        let mode = if name == "Vanilla" { ServeMode::Vanilla } else { ServeMode::MatKv };
        let m = if overlap {
            let (_, m, _) = serve_overlapped(&sc.engine, &reqs, batch, mode)?;
            m
        } else {
            let (_, m) = sc.engine.serve_all(&reqs, batch, mode)?;
            m
        };

        let gpu_secs = m.prefill_secs_on(&arch, &h100)
            + m.decode_secs_on(&arch, &h100)
            + m.upload_secs_on(&arch, &h100);
        let io_secs = m.load_secs_on(&arch, &ssd);
        let mut meter = EnergyMeter::h100_server(StorageProfile::raid0_4x9100());
        match (mode, overlap) {
            (ServeMode::Vanilla, _) => meter.record(PhaseKind::GpuCompute, gpu_secs),
            (_, false) => {
                meter.record(PhaseKind::StorageIo, io_secs);
                meter.record(PhaseKind::GpuCompute, gpu_secs);
            }
            (_, true) => {
                // steady state: loads hidden under the previous batch's decode
                let hidden = io_secs.min(gpu_secs);
                meter.record(PhaseKind::Overlapped, hidden);
                meter.record(PhaseKind::GpuCompute, gpu_secs - hidden);
                meter.record(PhaseKind::StorageIo, io_secs - hidden);
            }
        }
        let sys = meter.system_report();
        let gpu = meter.gpu_report();
        sys_table.row(&[
            name.to_string(),
            format!("{:.0}", sys.peak_w),
            format!("{:.0}", sys.avg_w),
            format!("{:.2}", sys.time_s),
            format!("{:.3}", sys.total_kj),
        ]);
        gpu_table.row(&[
            name.to_string(),
            format!("{:.0}", gpu.peak_w),
            format!("{:.0}", gpu.avg_w),
            format!("{:.2}", gpu.time_s),
            format!("{:.3}", gpu.total_kj),
        ]);
    }
    sys_table.print();
    gpu_table.print();
    println!("\npaper shape: MatKV variants ~halve total energy (faster completion at similar avg W);");
    println!("overlap shows the highest instantaneous peak but the lowest total.");
    Ok(())
}
