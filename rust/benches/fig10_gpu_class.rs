//! Fig 10 — MatKV vs full recompute on a high-end (H100 + RAID-0) vs
//! low-end (RTX 4090 + PM9A3) box. Paper: MatKV@4090 is only ~1.5x
//! slower than Vanilla@H100 (vs ~3x for Vanilla@4090) at 1/30th the GPU
//! price. We drive the real pipeline once per mode and convert phase
//! costs through both device profiles (paper batch: 32 on H100, 2 on
//! 4090 — we use buckets 8 and 2).

use matkv::coordinator::{Scenario, ScenarioSpec, ServeMode};
use matkv::hwsim::{serving_profile, ArchSpec, StorageProfile};
use matkv::util::bench::Table;
use matkv::util::cli::Args;

fn main() -> anyhow::Result<()> {
    let args = Args::from_env().map_err(|e| anyhow::anyhow!(e))?;
    let n = args.usize("requests", 16);

    let sc = Scenario::build(ScenarioSpec {
        config: "small".into(),
        storage: StorageProfile::raid0_4x9100(),
        n_docs: 12,
        doc_tokens: 1024,
        seed: 10,
        ..ScenarioSpec::default()
    })?;

    // Device identities come from the serving catalog — the same rows
    // the fleet spec parser resolves — so the profile *and* its price
    // are defined in exactly one place.
    let h100 = serving_profile("h100").expect("H100 in the serving catalog");
    let r4090 = serving_profile("rtx4090").expect("RTX4090 in the serving catalog");
    let raid = StorageProfile::raid0_4x9100();
    let pm9a3 = StorageProfile::ssd_pm9a3();
    let arch = ArchSpec::llama_8b(); // paper runs this figure on 8B-class

    // high-end box: batch 8; low-end box: batch 2 (the paper's asymmetry)
    let reqs = sc.requests(n, 1, 20);
    let (_, v8) = sc.engine.serve_all(&reqs, 8, ServeMode::Vanilla)?;
    let (_, m8) = sc.engine.serve_all(&reqs, 8, ServeMode::MatKv)?;
    let (_, v2) = sc.engine.serve_all(&reqs, 2, ServeMode::Vanilla)?;
    let (_, m2) = sc.engine.serve_all(&reqs, 2, ServeMode::MatKv)?;

    let rows = [
        (
            "Vanilla @ H100 (b=8)",
            v8.prefill_secs_on(&arch, &h100) + v8.decode_secs_on(&arch, &h100),
            h100.price_usd,
        ),
        ("MatKV   @ H100 (b=8)", m8.total_secs_on(&arch, &h100, &raid), h100.price_usd),
        (
            "Vanilla @ 4090 (b=2)",
            v2.prefill_secs_on(&arch, &r4090) + v2.decode_secs_on(&arch, &r4090),
            r4090.price_usd,
        ),
        ("MatKV   @ 4090 (b=2)", m2.total_secs_on(&arch, &r4090, &pm9a3), r4090.price_usd),
    ];
    let baseline = rows[0].1;

    let mut table = Table::new(
        &format!("Fig 10 — GPU class comparison ({n} reqs, 1x1024 in, 20 out, simulated)"),
        &["configuration", "time (s)", "vs Vanilla@H100", "gpu price"],
    );
    for (name, secs, price) in rows {
        table.row(&[
            name.to_string(),
            format!("{secs:.3}"),
            format!("{:.2}x", secs / baseline),
            format!("${price:.0}"),
        ]);
    }
    table.print();
    println!("\npaper shape: MatKV@4090 ~1.5x slower than Vanilla@H100; Vanilla@4090 ~3x slower.");
    Ok(())
}
