//! fig_bus — PCIe/DMA as a contended resource: tokens/s and tail
//! latency vs offered load, with link queueing on vs off.
//!
//! The KV-offloading literature (PAPERS.md) argues the serving
//! bottleneck is not flash bandwidth but the **interconnect**: once
//! materialized KVs stream from storage through host DRAM into device
//! memory, every batch's upload competes for the same PCIe lanes. The
//! pre-refactor fleet charged transfers a flat `bytes / pcie_bw` that
//! could never queue — concurrent uploads overlapped for free, so the
//! modeled fleet saturated later than a real one would.
//!
//! This bench measures what that optimism hid. One Poisson×Zipf
//! request stream per offered rate is planned once (the scheduler's
//! release clock paced by the fleet's own estimator), then the
//! identical schedule is dispatched twice through the same mixed fleet
//! (1×H100 + 3×RTX4090, role-aware):
//!
//! * **contention on** (the new default) — each worker's H2D link
//!   grants queued slots; back-to-back uploads wait behind each other;
//! * **contention off** — links disabled: every transfer keeps its
//!   wire time but the link never queues (the old flat-charge world).
//!
//! Traffic is deliberately transfer-dominant (large chunks, high
//! top-k, 2-token outputs — the RAG short-answer regime where MatKV's
//! splice path is all upload): at low offered load the two modes agree;
//! at high load the contention-on run must show a **strictly positive
//! tokens/s or p99 gap** and nonzero link queued-seconds (WARNING
//! otherwise — CI asserts the queued-seconds via `bus_smoke.json`).
//!
//! Pure-rust: golden manifest retrieval, stand-in architecture costs,
//! virtual clock. `--smoke` shrinks everything; `--json PATH` writes
//! the document.

use std::sync::{Arc, Mutex};

use matkv::coordinator::engine::{EngineOptions, LoaderCtx, Retrieval};
use matkv::coordinator::{
    BatchPolicy, Fleet, FleetCostModel, FleetSpec, Routing, SchedOptions, SchedPolicy, Scheduler,
};
use matkv::hwsim::{ArchSpec, StorageProfile};
use matkv::kvstore::KvStore;
use matkv::obs::{MetricsRegistry, Sampler};
use matkv::manifest::Manifest;
use matkv::util::bench::Table;
use matkv::util::cli::Args;
use matkv::util::tempdir::TempDir;
use matkv::workload::{ArrivalGen, Corpus, TimedRequest, TurboRagProfile};

fn main() -> anyhow::Result<()> {
    let args = Args::from_env().map_err(|e| anyhow::anyhow!(e))?;
    let smoke = args.flag("smoke");
    let n_docs = args.usize("docs", if smoke { 32 } else { 64 });
    let requests = args.usize("requests", if smoke { 48 } else { 160 });
    let batch = args.usize("batch", 8);
    let skew = args.f64("skew", 1.1);
    // Transfer-dominant knobs: paper-scale chunks, many per request,
    // short outputs — the upload is the batch, not the decode.
    let chunk_tokens = 1024usize;
    let top_k = 8usize;
    let output_tokens = 2usize;
    let fleet_spec = "h100:1,rtx4090:3";
    let rates: Vec<f64> =
        if smoke { vec![50.0, 400.0] } else { vec![25.0, 100.0, 400.0] };

    let m = Manifest::load_or_golden()?;
    let cfg = m.config("tiny")?.clone();
    let corpus = Corpus::generate(n_docs, 64, n_docs, 42);

    // The engine's exact retrieval stack, PJRT-free (fig_fleet idiom);
    // the store only anchors the scheduler's LoaderCtx — dispatch never
    // reads it, and every chunk counts as flash-materialized.
    let retrieval = {
        let opts = EngineOptions::for_config(&m, "tiny")?;
        Arc::new(Retrieval::for_corpus(corpus.texts(), cfg.vocab as u32, opts.embed_dim))
    };
    {
        let mut ix = retrieval.index.write().unwrap();
        for d in &corpus.docs {
            let (ids, _) = retrieval.tokenizer.encode_block(&d.text, chunk_tokens);
            ix.insert(d.id, retrieval.embedder.embed(&ids));
        }
    }
    let dir = TempDir::new("matkv-fig-bus")?;
    let mut kv = KvStore::open_sharded(dir.path(), StorageProfile::ssd_9100pro(), 1)?;
    kv.disable_throttle();
    let kv = Arc::new(kv);

    // Host loads priced at DRAM speed: the storage tier is not what
    // this bench contends — all pressure lands on the H2D links.
    let model = FleetCostModel {
        arch: ArchSpec::llama_70b(),
        storage: StorageProfile::dram(),
        chunk_tokens,
        query_tokens: 20,
        chunk_step: 256,
    };
    let spec = FleetSpec::parse(fleet_spec)?;
    let estimator = Fleet::new(&spec, Routing::RoleAware, model.clone()).service_estimator();

    eprintln!(
        "[fig_bus] {requests} reqs Zipf({skew}) over {n_docs} docs, top-k {top_k}, \
         {chunk_tokens}-token chunks, fleet {fleet_spec}, rates {rates:?}/s"
    );

    struct RateRow {
        rate: f64,
        batches: usize,
        on: matkv::coordinator::FleetReport,
        off: matkv::coordinator::FleetReport,
    }
    let mut rows: Vec<RateRow> = Vec::new();
    // Registry + sampler for the highest-rate contention-on dispatch —
    // the per-worker utilization/link series behind the headline gap.
    // The later contention-off replay runs on an earlier virtual
    // timeline, so its sampler calls are monotone no-ops.
    let reg = MetricsRegistry::new();
    let sampler = Arc::new(Mutex::new(Sampler::new(reg.clone(), 0.05)));
    for (ri, &rate) in rates.iter().enumerate() {
        let trace: Vec<TimedRequest> = ArrivalGen::new(
            TurboRagProfile { top_k, query_tokens: 20.0, output_tokens },
            corpus.n_topics,
            skew,
            rate,
            7,
        )
        .take(&corpus, requests);
        let ctx = LoaderCtx {
            retrieval: retrieval.clone(),
            kv: kv.clone(),
            cfg: cfg.clone(),
            opts: EngineOptions::for_config(&m, "tiny")?,
        };
        let mut sched = Scheduler::new(
            ctx,
            SchedOptions {
                batch: BatchPolicy { max_batch: batch, max_wait_secs: 0.05 },
                policy: SchedPolicy::Fifo,
                service_estimate_secs: 0.0,
                estimator: Some(estimator.clone()),
            },
        );
        sched.enqueue_timed(trace);
        let plan = sched.plan_with_retrieval();

        // Same plan, same fleet, two dispatches: only the links differ.
        let mut fleet = Fleet::new(&spec, Routing::RoleAware, model.clone());
        if ri + 1 == rates.len() {
            fleet.register_metrics(&reg)?;
            fleet.set_sampler(sampler.clone());
        }
        fleet.set_contention(true);
        let on = fleet.dispatch(&plan.batches, &|_| true);
        fleet.set_contention(false);
        let off = fleet.dispatch(&plan.batches, &|_| true);
        rows.push(RateRow { rate, batches: plan.batches.len(), on, off });
    }

    let mut table = Table::new(
        &format!(
            "PCIe contention A/B — {fleet_spec}, role-aware ({requests} reqs, batch {batch}, \
             virtual clock)"
        ),
        &[
            "offered (req/s)",
            "batches",
            "tok/s on",
            "tok/s off",
            "p99 on (ms)",
            "p99 off (ms)",
            "link queued (s)",
            "peak backlog (s)",
        ],
    );
    for r in &rows {
        let queued: f64 = r.on.workers.iter().map(|w| w.link.queued_secs).sum();
        let peak =
            r.on.workers.iter().map(|w| w.link.peak_backlog_secs).fold(0.0f64, f64::max);
        table.row(&[
            format!("{:.0}", r.rate),
            r.batches.to_string(),
            format!("{:.1}", r.on.throughput()),
            format!("{:.1}", r.off.throughput()),
            format!("{:.0}", r.on.latency.p99 * 1e3),
            format!("{:.0}", r.off.latency.p99 * 1e3),
            format!("{queued:.3}"),
            format!("{peak:.3}"),
        ]);
    }
    table.print();

    // Acceptance shape at the highest offered rate: the queued link
    // must cost something a flat charge never could.
    let high = rows.last().expect("at least one rate");
    let queued_on: f64 = high.on.workers.iter().map(|w| w.link.queued_secs).sum();
    let tps_gap = high.off.throughput() - high.on.throughput();
    let p99_gap = high.on.latency.p99 - high.off.latency.p99;
    println!(
        "\nhigh load ({:.0} req/s): contention costs {:.1} tok/s and {:+.0}ms p99 \
         ({:.3}s queued on the links; identical wire time both runs)",
        high.rate,
        tps_gap,
        p99_gap * 1e3,
        queued_on,
    );
    if tps_gap <= 0.0 && p99_gap <= 0.0 {
        eprintln!(
            "[fig_bus] WARNING: contention-on showed no tokens/s or p99 penalty at high \
             load (tps gap {tps_gap}, p99 gap {p99_gap}) — the link model is not biting"
        );
    }
    if queued_on <= 0.0 {
        eprintln!(
            "[fig_bus] WARNING: contention-on run reports zero link queued-seconds at \
             high load — uploads never waited, check the traffic shape"
        );
    }

    if let Some(path) = args.opt("json") {
        let rate_docs: Vec<String> = rows
            .iter()
            .map(|r| {
                let queued: f64 = r.on.workers.iter().map(|w| w.link.queued_secs).sum();
                let peak = r
                    .on
                    .workers
                    .iter()
                    .map(|w| w.link.peak_backlog_secs)
                    .fold(0.0f64, f64::max);
                format!(
                    "{{\"arrival_rate\":{},\"batches\":{},\"queued_secs_on\":{:.6},\
                     \"peak_backlog_secs_on\":{:.6},\"tps_gap\":{:.6},\"p99_gap\":{:.6},\
                     \"on\":{},\"off\":{}}}",
                    r.rate,
                    r.batches,
                    queued,
                    peak,
                    r.off.throughput() - r.on.throughput(),
                    r.on.latency.p99 - r.off.latency.p99,
                    r.on.to_json(),
                    r.off.to_json(),
                )
            })
            .collect();
        let doc = format!(
            "{{\"bench\":\"fig_bus\",\"smoke\":{smoke},\"requests\":{requests},\
             \"batch\":{batch},\"docs\":{n_docs},\"top_k\":{top_k},\
             \"chunk_tokens\":{chunk_tokens},\"skew\":{skew},\"fleet\":\"{fleet_spec}\",\
             \"routing\":\"role\",\"rates\":[{}],\"high_load_queued_secs_on\":{:.6},\
             \"high_load_tps_gap\":{:.6},\"high_load_p99_gap\":{:.6},\"series\":{}}}",
            rate_docs.join(","),
            queued_on,
            tps_gap,
            p99_gap,
            sampler.lock().unwrap().to_json(),
        );
        std::fs::write(path, doc)?;
        eprintln!("[fig_bus] wrote {path}");
    }
    Ok(())
}
