//! `bench_check` — the bench-regression gate.
//!
//! Each `fig_*` smoke bench writes a JSON document; this binary
//! normalizes every document into the regression matrix
//! (`matkv::obs::check::normalize`), compares it against the committed
//! baseline in `testdata/baselines/<bench>.json`, and exits nonzero
//! with one named, direction-aware line per violated tolerance band.
//!
//! ```text
//! cargo bench --bench bench_check -- --all                  # the CI gate
//! cargo bench --bench bench_check -- --bench fig_bus        # one bench
//! cargo bench --bench bench_check -- --all --bless          # rewrite baselines
//! cargo bench --bench bench_check -- --self-test            # prove the gate bites
//! ```
//!
//! Flags: `--dir PATH` is where the smoke JSON files live (default
//! `.`); `--baselines PATH` is the baseline directory (default
//! `testdata/baselines`). `--bless` rewrites each baseline from the
//! current smoke output — measured `higher`/`lower` bands get the
//! machine's own values, invariant bands keep their semantic bounds.
//! `--self-test` needs no smoke output: for every committed baseline it
//! synthesizes a satisfying run (must pass) and then perturbs each
//! metric one at a time past its band (must fail, naming exactly that
//! metric).

use std::collections::BTreeMap;
use std::path::Path;

use anyhow::{bail, Context, Result};
use matkv::obs::check::{bless, compare, normalize, Baseline, BENCHES};
use matkv::util::cli::Args;
use matkv::util::json::Json;

fn load_current(dir: &str, bench: &str, smoke_file: &str) -> Result<BTreeMap<String, f64>> {
    let path = Path::new(dir).join(smoke_file);
    let text = std::fs::read_to_string(&path)
        .with_context(|| format!("{bench}: no smoke output at {} (run the smoke benches first, or pass --dir)", path.display()))?;
    let doc = Json::parse(&text).with_context(|| format!("{bench}: bad JSON in {smoke_file}"))?;
    let norms = normalize(bench, &doc).with_context(|| format!("{bench}: normalize failed"))?;
    Ok(norms.into_iter().map(|n| (n.name, n.current)).collect())
}

fn load_baseline(baselines: &str, bench: &str) -> Result<Baseline> {
    let path = Path::new(baselines).join(format!("{bench}.json"));
    let text = std::fs::read_to_string(&path).with_context(|| {
        format!(
            "{bench}: no committed baseline at {} (bless one with --bless)",
            path.display()
        )
    })?;
    let b = Baseline::parse(&text).with_context(|| format!("{bench}: bad baseline"))?;
    if b.bench != bench {
        bail!("{bench}: baseline file claims bench {:?}", b.bench);
    }
    Ok(b)
}

/// Check one bench; prints named diffs, returns how many there were.
fn check_one(dir: &str, baselines: &str, bench: &str, smoke_file: &str) -> Result<usize> {
    let baseline = load_baseline(baselines, bench)?;
    let current = load_current(dir, bench, smoke_file)?;
    let diffs = compare(&baseline, &current);
    if diffs.is_empty() {
        println!("[bench_check] {bench}: OK ({} metrics within bands)", baseline.metrics.len());
    } else {
        for d in &diffs {
            println!("[bench_check] REGRESSION {bench}.{}: {}", d.metric, d.message);
        }
    }
    Ok(diffs.len())
}

fn bless_one(dir: &str, baselines: &str, bench: &str, smoke_file: &str) -> Result<()> {
    let path = Path::new(dir).join(smoke_file);
    let text = std::fs::read_to_string(&path)
        .with_context(|| format!("{bench}: no smoke output at {}", path.display()))?;
    let doc = Json::parse(&text)?;
    let norms = normalize(bench, &doc)?;
    let baseline = bless(bench, &norms);
    // a blessed baseline must pass against the run that produced it
    let current: BTreeMap<String, f64> = norms.iter().map(|n| (n.name.clone(), n.current)).collect();
    let diffs = compare(&baseline, &current);
    if !diffs.is_empty() {
        for d in &diffs {
            println!("[bench_check] {bench}.{}: {}", d.metric, d.message);
        }
        bail!("{bench}: run violates its own invariants; not blessing a broken baseline");
    }
    std::fs::create_dir_all(baselines)?;
    let out = Path::new(baselines).join(format!("{bench}.json"));
    std::fs::write(&out, baseline.to_json())?;
    println!("[bench_check] blessed {} ({} bands)", out.display(), baseline.metrics.len());
    Ok(())
}

/// Prove the gate bites without any smoke output: every committed
/// baseline passes a synthesized satisfying run, and perturbing any one
/// metric past its band fails with exactly that metric named.
fn self_test(baselines: &str) -> Result<usize> {
    let mut failures = 0usize;
    let mut bands = 0usize;
    for &(bench, _) in BENCHES {
        let baseline = load_baseline(baselines, bench)?;
        let good: BTreeMap<String, f64> = baseline
            .metrics
            .iter()
            .map(|(k, b)| (k.clone(), b.satisfying_value()))
            .collect();
        let diffs = compare(&baseline, &good);
        if !diffs.is_empty() {
            for d in &diffs {
                println!("[self-test] {bench}: satisfying run still failed {}: {}", d.metric, d.message);
            }
            failures += 1;
            continue;
        }
        for (name, band) in &baseline.metrics {
            bands += 1;
            let mut perturbed = good.clone();
            perturbed.insert(name.clone(), band.violating_value());
            let diffs = compare(&baseline, &perturbed);
            if diffs.len() != 1 || diffs[0].metric != *name {
                println!(
                    "[self-test] {bench}: perturbing {name} produced {:?} instead of exactly \
                     [{name}]",
                    diffs.iter().map(|d| d.metric.clone()).collect::<Vec<_>>()
                );
                failures += 1;
            } else if !diffs[0].message.contains("direction=") {
                println!(
                    "[self-test] {bench}.{name}: diff is not direction-aware: {}",
                    diffs[0].message
                );
                failures += 1;
            }
        }
    }
    println!(
        "[self-test] {} benches, {bands} bands perturbed one at a time, {failures} failures",
        BENCHES.len()
    );
    Ok(failures)
}

fn main() -> Result<()> {
    let args = Args::from_env().map_err(|e| anyhow::anyhow!(e))?;
    let dir = args.str("dir", ".");
    let baselines = args.str("baselines", "testdata/baselines");

    if args.flag("self-test") {
        let failures = self_test(&baselines)?;
        if failures > 0 {
            std::process::exit(1);
        }
        return Ok(());
    }

    let selected: Vec<(&str, &str)> = if args.flag("all") {
        BENCHES.to_vec()
    } else if let Some(name) = args.opt("bench") {
        let hit = BENCHES.iter().find(|(b, _)| *b == name);
        match hit {
            Some(&pair) => vec![pair],
            None => bail!(
                "unknown bench {name:?}; known: {:?}",
                BENCHES.iter().map(|(b, _)| *b).collect::<Vec<_>>()
            ),
        }
    } else {
        bail!("pass --all, --bench NAME, or --self-test");
    };

    if args.flag("bless") {
        for (bench, smoke_file) in &selected {
            bless_one(&dir, &baselines, bench, smoke_file)?;
        }
        return Ok(());
    }

    let mut total = 0usize;
    for (bench, smoke_file) in &selected {
        total += check_one(&dir, &baselines, bench, smoke_file)?;
    }
    if total > 0 {
        println!("[bench_check] {total} regression(s) — failing");
        std::process::exit(1);
    }
    println!("[bench_check] all {} bench(es) within committed bands", selected.len());
    Ok(())
}
