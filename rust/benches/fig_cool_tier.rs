//! Cool-path bench — the q4 dial one level deeper, in two A/B pairs,
//! plus the table-VI fidelity cost of serving q4 chunks.
//!
//! 1. **v4 vs v3 flash format** (no artifacts needed): the same
//!    Poisson-batched Zipf(1.0) trace replayed against two stores that
//!    materialized the same corpus in the v3 (f16+checksum) and v4
//!    (q4+checksum) formats. Shape to reproduce: at equal offered load
//!    v4 moves **strictly fewer flash bytes** and spends **strictly
//!    fewer simulated device-read seconds**, with the per-load q4
//!    dequant reported as the price — the trade is priced, not free.
//! 2. **TinyLFU vs LRU admission** (no artifacts needed): a Zipf demand
//!    stream interleaved with sequential scan bursts against a small
//!    hot tier. Shape: the frequency-gated tier holds **strictly more
//!    demand hits** than plain LRU, because one-pass scan candidates
//!    (seen once) cannot displace the repeatedly-hit resident set.
//! 3. **Fidelity** (needs `make artifacts`; skipped otherwise): the
//!    table-VI harness compares a pure-f32 deployment against one whose
//!    repeat traffic is served from a **q4 warm tier**. Target: mean
//!    token-F1 >= 0.90 vs the pure-f32 baseline (looser than the q8
//!    0.95 target — twice the quantization step).
//!
//! `--smoke` shrinks everything for CI; `--json PATH` writes all three
//! phases as JSON (`cool_smoke.json` is asserted by CI).

use std::fmt::Write as _;
use std::sync::atomic::Ordering;

use matkv::coordinator::baselines::fidelity;
use matkv::coordinator::{Scenario, ScenarioSpec, ServeMode};
use matkv::hwsim::StorageProfile;
use matkv::kvstore::{AdmissionPolicy, KvChunk, KvFormat, KvStore, WarmMode};
use matkv::util::bench::Table;
use matkv::util::cli::Args;
use matkv::util::tempdir::TempDir;
use matkv::workload::{Rng, Zipf};

fn chunk(seed: u32, seq: u32) -> KvChunk {
    let plane = (2 * 2 * seq * 8) as usize;
    KvChunk {
        config_id: 0x9a12,
        n_layers: 2,
        n_kv_heads: 2,
        seq_len: seq,
        head_dim: 8,
        // off-grid payload: the q4 round trip is genuinely lossy here,
        // exercising the real codec (bounded by its property tests)
        k: (0..plane).map(|i| ((i + seed as usize) as f32 * 0.37).sin() * 3.0).collect(),
        v: (0..plane).map(|i| ((i + seed as usize) as f32 * 0.53).cos() * 3.0).collect(),
    }
}

/// Poisson(mean) batch size: count of unit-rate exponential arrivals
/// inside a `mean`-length service window (at least one, so every batch
/// carries work).
fn poisson_batch(rng: &mut Rng, mean: f64) -> usize {
    let (mut k, mut t) = (0usize, 0.0f64);
    loop {
        t += -(1.0 - rng.f64()).ln();
        if t > mean {
            break;
        }
        k += 1;
    }
    k.max(1)
}

struct FormatRow {
    format: &'static str,
    reads: u64,
    flash_bytes: u64,
    device_secs: f64,
    q4_dequant_secs: f64,
}

/// Replay one shared trace (id stream + batch boundaries) against a
/// fresh reopen of `dir`, flash-only.
fn replay_format(
    dir: &std::path::Path,
    format: &'static str,
    trace: &[Vec<u64>],
) -> anyhow::Result<FormatRow> {
    let mut store = KvStore::open(dir, StorageProfile::ssd_9100pro())?;
    store.disable_throttle(); // wall time is irrelevant; device_secs is still computed
    let (mut device_secs, mut q4_dequant_secs) = (0.0f64, 0.0f64);
    for group in trace {
        for l in store.load_many(group)? {
            device_secs += l.device_secs;
            q4_dequant_secs += l.q4_dequant_secs;
        }
    }
    Ok(FormatRow {
        format,
        reads: store.stats.reads.load(Ordering::Relaxed),
        flash_bytes: store.stats.bytes_read.load(Ordering::Relaxed),
        device_secs,
        q4_dequant_secs,
    })
}

struct ScanRow {
    policy: &'static str,
    demand_accesses: u64,
    demand_hits: u64,
    scan_accesses: u64,
    admissions_gated: u64,
}

fn main() -> anyhow::Result<()> {
    let args = Args::from_env().map_err(|e| anyhow::anyhow!(e))?;
    let smoke = args.flag("smoke");
    let n_chunks = args.usize("chunks", if smoke { 48 } else { 160 });
    let accesses = args.usize("accesses", if smoke { 600 } else { 3000 });
    let seq = args.usize("chunk-tokens", 128) as u32;
    let mean_batch = args.f64("mean-batch", 8.0);
    let skew = args.f64("skew", 1.0);

    // ---- phase 1: v4 vs v3 flash format at equal offered load ----------
    // Materialize the same corpus once per format; replay one shared
    // Poisson x Zipf trace against both so the only degree of freedom
    // is the on-disk encoding.
    let dir_v3 = TempDir::new("matkv-fig-cool-v3")?;
    let dir_v4 = TempDir::new("matkv-fig-cool-v4")?;
    for (dir, format) in [(&dir_v3, KvFormat::V3), (&dir_v4, KvFormat::V4)] {
        let mut w = KvStore::open(dir.path(), StorageProfile::ssd_9100pro())?;
        w.disable_throttle();
        w.set_format(format);
        for i in 0..n_chunks {
            w.store_sync(i as u64, &chunk(i as u32, seq))?;
        }
    }
    let zipf = Zipf::new(n_chunks, skew);
    let mut rng = Rng::new(4242);
    let mut trace: Vec<Vec<u64>> = Vec::new();
    let mut left = accesses;
    while left > 0 {
        let k = poisson_batch(&mut rng, mean_batch).min(left);
        trace.push((0..k).map(|_| zipf.sample(&mut rng) as u64).collect());
        left -= k;
    }
    eprintln!(
        "[fig_cool_tier] {n_chunks} chunks x {seq} tokens, {accesses} Zipf({skew}) accesses \
         in {} Poisson({mean_batch}) batches, v3 vs v4 flash",
        trace.len()
    );
    let v3 = replay_format(dir_v3.path(), "v3 (f16)", &trace)?;
    let v4 = replay_format(dir_v4.path(), "v4 (q4)", &trace)?;

    let mut table = Table::new(
        &format!("flash format A/B ({accesses} accesses, same trace)"),
        &["format", "reads", "flash MB", "device (s)", "q4 dequant (s)", "load total (s)"],
    );
    for r in [&v3, &v4] {
        table.row(&[
            r.format.to_string(),
            r.reads.to_string(),
            format!("{:.2}", r.flash_bytes as f64 / 1e6),
            format!("{:.4}", r.device_secs),
            format!("{:.5}", r.q4_dequant_secs),
            format!("{:.4}", r.device_secs + r.q4_dequant_secs),
        ]);
    }
    table.print();
    println!(
        "v4 vs v3 at equal offered load: flash bytes {:.2} MB -> {:.2} MB ({:.2}x), device \
         {:.4}s -> {:.4}s, dequant price {:.5}s on the load path",
        v3.flash_bytes as f64 / 1e6,
        v4.flash_bytes as f64 / 1e6,
        v3.flash_bytes as f64 / v4.flash_bytes.max(1) as f64,
        v3.device_secs,
        v4.device_secs,
        v4.q4_dequant_secs,
    );
    if v4.flash_bytes >= v3.flash_bytes || v4.device_secs >= v3.device_secs {
        eprintln!(
            "[fig_cool_tier] WARNING: v4 did not strictly beat v3 on flash bytes and \
             device seconds ({} vs {} bytes, {:.6}s vs {:.6}s)",
            v4.flash_bytes, v3.flash_bytes, v4.device_secs, v3.device_secs
        );
    }
    if v4.q4_dequant_secs <= 0.0 {
        eprintln!("[fig_cool_tier] WARNING: v4 replay charged no q4 dequant — the trade looks free");
    }

    // ---- phase 2: TinyLFU vs LRU under scan pollution ------------------
    // Zipf demand over the first `n_demand` ids, interleaved with
    // sequential scan bursts over fresh ids; the hot tier holds only a
    // sliver of the demand set, so admission policy decides whether the
    // scan flushes it.
    let n_demand = args.usize("demand-ids", if smoke { 24 } else { 64 });
    let rounds = args.usize("rounds", if smoke { 6 } else { 10 });
    let demand_per_round = args.usize("demand-per-round", if smoke { 60 } else { 150 });
    let scan_len = args.usize("scan-len", n_demand);
    let resident_target = args.usize("resident-chunks", (n_demand / 4).max(4));
    {
        // one store dir covering demand + scan ids (v3: format is not
        // under test here)
        let dir = TempDir::new("matkv-fig-cool-scan")?;
        let mut w = KvStore::open(dir.path(), StorageProfile::ssd_9100pro())?;
        w.disable_throttle();
        for i in 0..(n_demand + rounds * scan_len) {
            w.store_sync(i as u64, &chunk(i as u32, seq))?;
        }
        let file_bytes = {
            let mut probe = KvStore::open(dir.path(), StorageProfile::ssd_9100pro())?;
            probe.disable_throttle();
            probe.load_many(&[0])?[0].file_bytes
        };
        let budget = file_bytes * resident_target;
        eprintln!(
            "[fig_cool_tier] scan A/B: {n_demand} demand ids (Zipf {skew}), {rounds} rounds x \
             ({demand_per_round} demand + {scan_len}-id scan), hot tier holds ~{resident_target}"
        );
        let mut scan_rows: Vec<ScanRow> = Vec::new();
        for (policy, label) in
            [(AdmissionPolicy::Lru, "lru"), (AdmissionPolicy::TinyLfu, "tinylfu")]
        {
            let mut store = KvStore::open(dir.path(), StorageProfile::ssd_9100pro())?;
            store.disable_throttle();
            store.set_hot_tier(budget);
            store.set_admission(policy);
            let zipf = Zipf::new(n_demand, skew);
            let mut rng = Rng::new(777); // same demand stream per policy
            let (mut demand_accesses, mut demand_hits, mut scan_accesses) = (0u64, 0u64, 0u64);
            let mut next_scan_id = n_demand as u64;
            for _ in 0..rounds {
                let mut left = demand_per_round;
                while left > 0 {
                    let k = poisson_batch(&mut rng, mean_batch).min(left);
                    let group: Vec<u64> =
                        (0..k).map(|_| zipf.sample(&mut rng) as u64).collect();
                    for l in store.load_many(&group)? {
                        demand_accesses += 1;
                        demand_hits += l.from_cache as u64;
                    }
                    left -= k;
                }
                // the polluting pass: every id fresh, seen exactly once
                let scan: Vec<u64> =
                    (0..scan_len).map(|i| next_scan_id + i as u64).collect();
                next_scan_id += scan_len as u64;
                scan_accesses += scan.len() as u64;
                for group in scan.chunks((mean_batch as usize).max(1)) {
                    store.load_many(group)?;
                }
            }
            scan_rows.push(ScanRow {
                policy: label,
                demand_accesses,
                demand_hits,
                scan_accesses,
                admissions_gated: store
                    .hot_tier()
                    .map(|t| t.stats.admission_rejected.load(Ordering::Relaxed))
                    .unwrap_or(0),
            });
        }
        let mut table = Table::new(
            "hot-tier admission under scan pollution (same demand stream)",
            &["policy", "demand accesses", "demand hits", "hit %", "scan accesses", "gated"],
        );
        for r in &scan_rows {
            table.row(&[
                r.policy.to_string(),
                r.demand_accesses.to_string(),
                r.demand_hits.to_string(),
                format!("{:.1}", 100.0 * r.demand_hits as f64 / r.demand_accesses.max(1) as f64),
                r.scan_accesses.to_string(),
                r.admissions_gated.to_string(),
            ]);
        }
        table.print();
        let (lru, tlfu) = (&scan_rows[0], &scan_rows[1]);
        println!(
            "tinylfu vs lru under the same scan: demand hits {} -> {} ({:+}), {} scan \
             admissions gated off",
            lru.demand_hits,
            tlfu.demand_hits,
            tlfu.demand_hits as i64 - lru.demand_hits as i64,
            tlfu.admissions_gated,
        );
        if tlfu.demand_hits <= lru.demand_hits {
            eprintln!(
                "[fig_cool_tier] WARNING: TinyLFU did not strictly beat LRU on demand hits \
                 ({} vs {})",
                tlfu.demand_hits, lru.demand_hits
            );
        }

        // ---- phase 3: table-VI fidelity of q4-served chunks ------------
        let mut fidelity_json = String::from("null");
        if matkv::manifest::artifacts_present() {
            let n_docs = if smoke { 8 } else { 16 };
            let doc_tokens = 256usize;
            let n_reqs = if smoke { 12 } else { 32 };
            // Size the candidate's hot tier to ~2 chunks so repeat
            // traffic is warm-served (same recipe as fig_warm_tier, on
            // the q4 codec).
            let kv_chunk_bytes = {
                let m = matkv::Manifest::load(matkv::artifacts_dir())?;
                let cfg = m.config("tiny")?;
                let plane = cfg.n_layers * cfg.n_kv_heads * doc_tokens * cfg.head_dim;
                std::mem::size_of::<KvChunk>() + 8 * plane
            };
            fn serve_twice(
                spec: ScenarioSpec,
                n_reqs: usize,
            ) -> anyhow::Result<(
                Vec<matkv::coordinator::Response>,
                matkv::coordinator::PhaseBreakdown,
            )> {
                let sc = Scenario::build(spec)?;
                let reqs = sc.requests(n_reqs, 2, 8);
                sc.engine.serve_all(&reqs, 4, ServeMode::MatKv)?; // warm-up pass
                sc.engine.serve_all(&reqs, 4, ServeMode::MatKv)
            }
            let (reference, _) = serve_twice(
                ScenarioSpec {
                    n_docs,
                    doc_tokens,
                    storage: StorageProfile::ssd_9100pro(),
                    hot_tier_bytes: 64 << 20, // everything stays f32
                    seed: 33,
                    ..ScenarioSpec::default()
                },
                n_reqs,
            )?;
            let (candidate, cm) = serve_twice(
                ScenarioSpec {
                    n_docs,
                    doc_tokens,
                    storage: StorageProfile::ssd_9100pro(),
                    hot_tier_bytes: 2 * kv_chunk_bytes,
                    warm_tier_bytes: 16 << 20,
                    warm_mode: WarmMode::Q4,
                    seed: 33,
                    ..ScenarioSpec::default()
                },
                n_reqs,
            )?;
            let f = fidelity(&reference, &candidate);
            println!(
                "\nfidelity of q4-served chunks vs pure f32 ({} pairs, {} warm hits in the \
                 measured pass): token-F1 {:.4}, exact-prefix {:.1} tokens, {} exact matches \
                 (target: mean F1 >= 0.90)",
                f.pairs, cm.warm_hits, f.mean_f1, f.mean_prefix, f.exact
            );
            if cm.warm_hits == 0 {
                eprintln!(
                    "[fig_cool_tier] WARNING: candidate pass served no warm hits — fidelity \
                     comparison is vacuous"
                );
            }
            if f.mean_f1 < 0.90 {
                eprintln!(
                    "[fig_cool_tier] WARNING: mean token-F1 {:.4} below the 0.90 target",
                    f.mean_f1
                );
            }
            fidelity_json = format!(
                "{{\"pairs\":{},\"warm_hits\":{},\"mean_f1\":{:.6},\"mean_prefix\":{:.3},\
                 \"exact\":{},\"q4_dequant_secs\":{:.6}}}",
                f.pairs, cm.warm_hits, f.mean_f1, f.mean_prefix, f.exact, cm.q4_dequant_secs
            );
        } else {
            println!(
                "\n[fig_cool_tier] fidelity phase skipped: AOT artifacts not built \
                 (run `make artifacts`)"
            );
        }

        if let Some(path) = args.opt("json") {
            let mut scan_json = String::new();
            for r in &scan_rows {
                let _ = write!(
                    scan_json,
                    "{}{{\"policy\":\"{}\",\"demand_accesses\":{},\"demand_hits\":{},\
                     \"scan_accesses\":{},\"admissions_gated\":{}}}",
                    if scan_json.is_empty() { "" } else { "," },
                    r.policy,
                    r.demand_accesses,
                    r.demand_hits,
                    r.scan_accesses,
                    r.admissions_gated,
                );
            }
            let doc = format!(
                "{{\"bench\":\"fig_cool_tier\",\"smoke\":{smoke},\"chunks\":{n_chunks},\
                 \"accesses\":{accesses},\"chunk_tokens\":{seq},\"skew\":{skew},\
                 \"formats\":{{\
                 \"v3\":{{\"reads\":{},\"flash_bytes\":{},\"device_secs\":{:.6},\
                 \"q4_dequant_secs\":{:.6}}},\
                 \"v4\":{{\"reads\":{},\"flash_bytes\":{},\"device_secs\":{:.6},\
                 \"q4_dequant_secs\":{:.6}}}}},\
                 \"scan\":[{scan_json}],\"fidelity\":{fidelity_json}}}",
                v3.reads,
                v3.flash_bytes,
                v3.device_secs,
                v3.q4_dequant_secs,
                v4.reads,
                v4.flash_bytes,
                v4.device_secs,
                v4.q4_dequant_secs,
            );
            std::fs::write(path, doc)?;
            eprintln!("[fig_cool_tier] wrote {path}");
        }
    }
    Ok(())
}
