//! fig_fleet — disaggregated prefill/decode serving across a simulated
//! heterogeneous GPU fleet (the paper's Fig-10 premise at serving
//! scale).
//!
//! One Poisson×Zipf request stream is planned once by the scheduler,
//! then the identical schedule is dispatched — on the virtual clock —
//! across three fleet configurations:
//!
//! 1. `h100-alone`  — 1×H100, round-robin (everything on the big card);
//! 2. `mixed-rr`    — 1×H100 + 3×RTX4090, role-blind round-robin;
//! 3. `mixed-role`  — the same fleet under role-aware routing:
//!    KV-resident batches to the 4090 decode workers, cache-miss /
//!    prefill-heavy batches (a slice of the corpus is deliberately left
//!    unmaterialized) to the H100.
//!
//! Acceptance shape: at equal offered load, `mixed-role` must deliver
//! **strictly more tokens per joule** than `h100-alone` — decode is
//! nearly GPU-class-blind once the materialized KVs reach device
//! memory, while the desktop-class 4090 boxes draw a fraction of the
//! H100 server's watts (WARNING otherwise; the same inequality is
//! pinned at unit scale in `coordinator/fleet.rs` tests). The bench
//! JSON carries per-worker utilization and the per-request p50/p95/p99
//! latency percentiles for every configuration.
//!
//! Pure-rust: the golden metadata manifest shapes retrieval; costs run
//! through the stand-in architecture. No PJRT anywhere. `--smoke`
//! shrinks everything for CI; `--json PATH` writes the document.

use std::collections::HashSet;
use std::sync::Arc;

use matkv::coordinator::engine::{EngineOptions, LoaderCtx, Retrieval};
use matkv::coordinator::{
    BatchPolicy, Fleet, FleetCostModel, FleetSpec, Routing, SchedOptions, SchedPolicy, Scheduler,
};
use matkv::hwsim::{ArchSpec, StorageProfile};
use matkv::kvstore::store::config_id;
use matkv::kvstore::{KvChunk, KvStore};
use matkv::manifest::Manifest;
use matkv::util::bench::Table;
use matkv::util::cli::Args;
use matkv::util::tempdir::TempDir;
use matkv::vectordb::{ChunkId, VectorIndex};
use matkv::workload::{ArrivalGen, Corpus, TimedRequest, TurboRagProfile};

/// A chunk matching the golden config's dims (store accounting needs
/// realistic sizes; payload content is irrelevant to dispatch).
fn cfg_chunk(cfg: &matkv::ModelConfig, seq: usize) -> KvChunk {
    let plane = cfg.n_layers * cfg.n_kv_heads * seq * cfg.head_dim;
    KvChunk {
        config_id: config_id(cfg),
        n_layers: cfg.n_layers as u32,
        n_kv_heads: cfg.n_kv_heads as u32,
        seq_len: seq as u32,
        head_dim: cfg.head_dim as u32,
        k: vec![1.0; plane],
        v: vec![-1.0; plane],
    }
}

fn main() -> anyhow::Result<()> {
    let args = Args::from_env().map_err(|e| anyhow::anyhow!(e))?;
    let smoke = args.flag("smoke");
    let n_docs = args.usize("docs", if smoke { 16 } else { 48 });
    let doc_tokens = 256usize;
    let requests = args.usize("requests", if smoke { 48 } else { 192 });
    let batch = args.usize("batch", 8);
    let skew = args.f64("skew", 1.1);
    let rate = args.f64("arrival-rate", 200.0);
    let top_k = 2usize;
    let output_tokens = 16usize;

    let m = Manifest::load_or_golden()?;
    let cfg = m.config("tiny")?.clone();
    let opts = EngineOptions::for_config(&m, "tiny")?;
    let corpus = Corpus::generate(n_docs, 64, n_docs, 42);

    // The engine's exact retrieval stack, PJRT-free (fig_sched idiom).
    let retrieval =
        Arc::new(Retrieval::for_corpus(corpus.texts(), cfg.vocab as u32, opts.embed_dim));
    {
        let mut ix = retrieval.index.write().unwrap();
        for d in &corpus.docs {
            let (ids, _) = retrieval.tokenizer.encode_block(&d.text, doc_tokens);
            ix.insert(d.id, retrieval.embedder.embed(&ids));
        }
    }

    // Materialize 3 of every 4 docs: retrievals landing on the fourth
    // are the cache-miss/prefill-heavy traffic role-aware routing must
    // keep on the H100.
    let dir = TempDir::new("matkv-fig-fleet")?;
    let mut kv = KvStore::open_sharded(dir.path(), StorageProfile::ssd_9100pro(), 1)?;
    kv.disable_throttle();
    let tier_budget = cfg_chunk(&cfg, doc_tokens).dram_bytes() * n_docs / 4;
    kv.set_hot_tier(tier_budget);
    for d in &corpus.docs {
        if d.id % 4 != 3 {
            kv.store_sync(d.id, &cfg_chunk(&cfg, doc_tokens))?;
        }
    }
    // Pre-warm the hot tier with the low ids (Zipf's popular mass) so
    // the routing's resident-set snapshot has something to consult.
    let warm_ids: Vec<ChunkId> =
        (0..n_docs as u64).filter(|id| id % 4 != 3).take(n_docs / 4).collect();
    kv.prefetch_many(&warm_ids);
    let kv = Arc::new(kv);
    let materialized: HashSet<ChunkId> =
        (0..n_docs as u64).filter(|&id| kv.contains(id)).collect();

    // The fleet cost model prices work at the paper's headline scale
    // (the executed tiny config only shapes the retrieval distribution).
    let model = FleetCostModel {
        arch: ArchSpec::llama_70b(),
        storage: StorageProfile::ssd_9100pro(),
        chunk_tokens: doc_tokens,
        query_tokens: 20,
        chunk_step: opts.chunk_step,
    };

    // Plan ONCE — with the mixed fleet's per-batch estimator pacing the
    // release clock (priced against the real materialized set, so
    // cache-miss batches occupy the modeled executor longer) — then
    // dispatch the identical schedule on every configuration: equal
    // offered load by construction.
    let mixed_spec = FleetSpec::parse("h100:1,rtx4090:3")?;
    let mat_for_estimator = materialized.clone();
    let estimator = Fleet::new(&mixed_spec, Routing::RoleAware, model.clone())
        .service_estimator_with(Arc::new(move |id| mat_for_estimator.contains(&id)));
    let trace: Vec<TimedRequest> = ArrivalGen::new(
        TurboRagProfile { top_k, query_tokens: 20.0, output_tokens },
        corpus.n_topics,
        skew,
        rate,
        7,
    )
    .take(&corpus, requests);
    let ctx = LoaderCtx { retrieval, kv: kv.clone(), cfg: cfg.clone(), opts };
    let mut sched = Scheduler::new(
        ctx,
        SchedOptions {
            batch: BatchPolicy { max_batch: batch, max_wait_secs: 0.05 },
            policy: SchedPolicy::Fifo,
            service_estimate_secs: 0.0,
            estimator: Some(estimator),
        },
    );
    sched.enqueue_timed(trace);
    let plan = sched.plan_with_retrieval();
    eprintln!(
        "[fig_fleet] {requests} reqs @ {rate}/s Zipf({skew}) over {n_docs} docs \
         ({} materialized), batch {batch} → {} planned batches",
        materialized.len(),
        plan.batches.len(),
    );

    let snapshot = kv.resident_set();
    let configs: [(&str, &str, Routing); 3] = [
        ("h100-alone", "h100:1", Routing::RoundRobin),
        ("mixed-rr", "h100:1,rtx4090:3", Routing::RoundRobin),
        ("mixed-role", "h100:1,rtx4090:3", Routing::RoleAware),
    ];
    let mut reports = Vec::new();
    for (name, spec, routing) in configs {
        let mut fleet = Fleet::new(&FleetSpec::parse(spec)?, routing, model.clone());
        fleet.seed_resident(&snapshot);
        let rep = fleet.dispatch(&plan.batches, &|id| materialized.contains(&id));
        reports.push((name, spec, rep));
    }

    let mut table = Table::new(
        &format!(
            "Fig-10 at serving scale — fleet dispatch ({requests} reqs, batch {batch}, \
             {} batches, virtual clock)",
            plan.batches.len()
        ),
        &[
            "config",
            "workers",
            "makespan (s)",
            "tok/s",
            "energy (kJ)",
            "tok/J",
            "p50/p95/p99 (ms)",
            "util per worker",
        ],
    );
    for (name, _spec, rep) in &reports {
        let utils: Vec<String> =
            rep.workers.iter().map(|w| format!("{:.0}%", 100.0 * w.utilization)).collect();
        table.row(&[
            name.to_string(),
            rep.workers.len().to_string(),
            format!("{:.2}", rep.makespan_secs),
            format!("{:.1}", rep.throughput()),
            format!("{:.2}", rep.total_kj),
            format!("{:.4}", rep.tokens_per_joule),
            format!(
                "{:.0}/{:.0}/{:.0}",
                rep.latency.p50 * 1e3,
                rep.latency.p95 * 1e3,
                rep.latency.p99 * 1e3
            ),
            utils.join(" "),
        ]);
    }
    table.print();

    let single = &reports[0].2;
    let role = &reports[2].2;
    println!(
        "\nmixed fleet (role-aware) vs H100 alone at equal offered load: \
         {:.4} vs {:.4} tok/J ({:+.1}%), makespan {:.2}s vs {:.2}s",
        role.tokens_per_joule,
        single.tokens_per_joule,
        100.0 * (role.tokens_per_joule / single.tokens_per_joule - 1.0),
        role.makespan_secs,
        single.makespan_secs,
    );
    println!(
        "role separation: {} prefill-heavy batches on the H100, {} KV-resident batches \
         on the 4090s",
        role.prefill_batches, role.decode_batches,
    );
    if role.tokens_per_joule <= single.tokens_per_joule {
        eprintln!(
            "[fig_fleet] WARNING: role-aware mixed fleet did not beat the single H100 on \
             tokens/joule ({} vs {})",
            role.tokens_per_joule, single.tokens_per_joule
        );
    }
    if role.tokens_out != single.tokens_out {
        eprintln!(
            "[fig_fleet] WARNING: configurations served different token counts ({} vs {})",
            role.tokens_out, single.tokens_out
        );
    }

    if let Some(path) = args.opt("json") {
        let rows: Vec<String> = reports
            .iter()
            .map(|(name, spec, rep)| {
                format!("{{\"config\":\"{name}\",\"fleet\":\"{spec}\",\"report\":{}}}", rep.to_json())
            })
            .collect();
        let doc = format!(
            "{{\"bench\":\"fig_fleet\",\"smoke\":{smoke},\"requests\":{requests},\
             \"batch\":{batch},\"docs\":{n_docs},\"materialized\":{},\"skew\":{skew},\
             \"arrival_rate\":{rate},\"batches\":{},\"configs\":[{}],\
             \"role_tpj_gain_vs_single\":{:.6}}}",
            materialized.len(),
            plan.batches.len(),
            rows.join(","),
            role.tokens_per_joule - single.tokens_per_joule,
        );
        std::fs::write(path, doc)?;
        eprintln!("[fig_fleet] wrote {path}");
    }
    Ok(())
}
